//! Communicators.
//!
//! A communicator is (group, context-id pair, attributes, error handler,
//! name). Context ids separate traffic planes: each comm owns a pt2pt
//! plane and a collective plane (as MPICH does), allocated world-globally
//! and agreed upon collectively at creation.

use std::collections::HashMap;

use super::slab::Slab;
use super::world::with_ctx;
use super::{err, CommId, ErrhId, GroupId, RC};

/// Communicator object.
#[derive(Debug)]
pub struct CommObj {
    /// Member world ranks, in comm-rank order.
    pub members: Vec<usize>,
    /// The calling rank's rank within this comm.
    pub my_rank: usize,
    /// Context id for point-to-point traffic.
    pub ctx_pt2pt: u32,
    /// Context id for collective traffic.
    pub ctx_coll: u32,
    /// Per-rank collective sequence number (tag space for collectives).
    pub coll_seq: i32,
    /// Cached attributes (word-sized values, §3.3).
    pub attrs: HashMap<i32, usize>,
    /// The comm's error handler.
    pub errhandler: ErrhId,
    /// `MPI_Comm_set_name` string.
    pub name: String,
    /// Predefined comms (world/self) are not freeable.
    pub predefined: bool,
    /// ULFM: how many member failures this rank has acknowledged on this
    /// comm (`MPI_Comm_ack_failed`). A wildcard receive only reports
    /// `MPI_ERR_PROC_FAILED_PENDING` while unacknowledged failures exist.
    pub acked_failed: usize,
}

impl CommObj {
    /// Number of members.
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// World rank of comm rank `r`.
    pub fn world_rank(&self, r: usize) -> Option<usize> {
        self.members.get(r).copied()
    }

    /// Next collective tag (advances the per-comm collective sequence).
    pub fn next_coll_tag(&mut self) -> i32 {
        self.coll_seq = self.coll_seq.wrapping_add(1) & 0x3FFF_FFFF;
        self.coll_seq
    }
}

/// Install placeholder WORLD/SELF comms (plus the hidden session
/// bootstrap comm); sized at init by [`finish_predefined`] (world size
/// unknown at table construction).
pub fn install_predefined(comms: &mut Slab<CommObj>) {
    for (id, (name, ctxp, ctxc)) in [
        (super::reserved::COMM_WORLD.0, ("MPI_COMM_WORLD", 0, 1)),
        (super::reserved::COMM_SELF.0, ("MPI_COMM_SELF", 2, 3)),
        // World-spanning but never exposed through any ABI: carries only
        // `MPI_Comm_create_from_group` context-plane agreement traffic
        // (see `core::session`).
        (super::reserved::COMM_BOOTSTRAP.0, ("(session-bootstrap)", 4, 5)),
    ] {
        comms.insert_at(
            id,
            CommObj {
                members: Vec::new(),
                my_rank: 0,
                ctx_pt2pt: ctxp,
                ctx_coll: ctxc,
                coll_seq: 0,
                attrs: HashMap::new(),
                errhandler: super::reserved::ERRH_ARE_FATAL,
                name: name.to_string(),
                predefined: true,
                acked_failed: 0,
            },
        );
    }
}

/// Size the predefined comms once world size/rank are known.
pub fn finish_predefined(comms: &mut Slab<CommObj>, world_size: usize, rank: usize) {
    let w = comms.get_mut(super::reserved::COMM_WORLD.0).unwrap();
    w.members = (0..world_size).collect();
    w.my_rank = rank;
    let s = comms.get_mut(super::reserved::COMM_SELF.0).unwrap();
    s.members = vec![rank];
    s.my_rank = 0;
    // The bootstrap comm spans the world in world-rank order, so a
    // member's world rank is its bootstrap rank (session.rs relies on
    // this when addressing context-plane agreement messages).
    let b = comms.get_mut(super::reserved::COMM_BOOTSTRAP.0).unwrap();
    b.members = (0..world_size).collect();
    b.my_rank = rank;
}

/// `MPI_Comm_size`.
#[inline]
pub fn comm_size(comm: CommId) -> RC<i32> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        Ok(t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?.size() as i32)
    })
}

/// `MPI_Comm_rank`.
#[inline]
pub fn comm_rank(comm: CommId) -> RC<i32> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        Ok(t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?.my_rank as i32)
    })
}

/// `MPI_Comm_group`.
pub fn comm_group(comm: CommId) -> RC<GroupId> {
    let members = with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        Ok(t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?.members.clone())
    })?;
    super::group::group_from_members(members)
}

/// `MPI_Comm_compare`.
pub fn comm_compare(a: CommId, b: CommId) -> RC<i32> {
    use crate::abi::constants::{MPI_CONGRUENT, MPI_IDENT, MPI_SIMILAR, MPI_UNEQUAL};
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let ca = t.comms.get(a.0).ok_or(err!(MPI_ERR_COMM))?;
        let cb = t.comms.get(b.0).ok_or(err!(MPI_ERR_COMM))?;
        Ok(if a == b {
            MPI_IDENT
        } else if ca.members == cb.members {
            MPI_CONGRUENT
        } else if {
            let sa: std::collections::HashSet<_> = ca.members.iter().collect();
            let sb: std::collections::HashSet<_> = cb.members.iter().collect();
            sa == sb
        } {
            MPI_SIMILAR
        } else {
            MPI_UNEQUAL
        })
    })
}

/// `MPI_Comm_set_name` / `MPI_Comm_get_name`.
pub fn comm_set_name(comm: CommId, name: &str) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let c = t.comms.get_mut(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        c.name = name.chars().take(crate::abi::constants::MPI_MAX_OBJECT_NAME - 1).collect();
        Ok(())
    })
}

/// `MPI_Comm_get_name`.
pub fn comm_get_name(comm: CommId) -> RC<String> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        Ok(t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?.name.clone())
    })
}

/// `MPI_Comm_set_errhandler` / `MPI_Comm_get_errhandler`.
pub fn comm_set_errhandler(comm: CommId, errh: ErrhId) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        if !t.errhs.contains(errh.0) {
            return Err(err!(MPI_ERR_ERRHANDLER));
        }
        let c = t.comms.get_mut(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        c.errhandler = errh;
        Ok(())
    })
}

/// `MPI_Comm_get_errhandler`.
pub fn comm_get_errhandler(comm: CommId) -> RC<ErrhId> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        Ok(t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?.errhandler)
    })
}

/// Engine-internal: insert a fully-formed comm object.
pub fn insert_comm(
    members: Vec<usize>,
    my_rank: usize,
    ctx_pt2pt: u32,
    ctx_coll: u32,
) -> RC<CommId> {
    with_ctx(|ctx| {
        Ok(CommId(ctx.tables.borrow_mut().comms.insert(CommObj {
            members,
            my_rank,
            ctx_pt2pt,
            ctx_coll,
            coll_seq: 0,
            attrs: HashMap::new(),
            errhandler: super::reserved::ERRH_ARE_FATAL,
            name: String::new(),
            predefined: false,
            acked_failed: 0,
        })))
    })
}

/// `MPI_Comm_free` (runs attribute delete callbacks first).
pub fn comm_free(comm: CommId) -> RC<()> {
    super::attr::delete_all_attrs(comm)?;
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        match t.comms.get(comm.0) {
            Some(c) if c.predefined => Err(err!(MPI_ERR_COMM)),
            Some(_) => {
                t.comms.remove(comm.0);
                Ok(())
            }
            None => Err(err!(MPI_ERR_COMM)),
        }
    })
}

/// Pt2pt fast path: resolve (comm size, world rank of `r` or None for
/// wildcard/special, pt2pt context) without cloning the member list.
/// Takes the rank context directly: this sits on the per-message path,
/// so it must not pay a second TLS lookup.
#[inline]
pub(crate) fn comm_route(
    ctx: &super::world::RankCtx,
    comm: CommId,
    r: i32,
) -> RC<(usize, Option<usize>, u32)> {
    let t = ctx.tables.borrow();
    let c = t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?;
    let dst = if r >= 0 { c.members.get(r as usize).copied() } else { None };
    Ok((c.members.len(), dst, c.ctx_pt2pt))
}

/// World rank → comm rank (status source translation) without cloning.
#[inline]
pub(crate) fn comm_rank_of_world(comm: CommId, world_rank: i32) -> RC<Option<i32>> {
    if world_rank < 0 {
        return Ok(None);
    }
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let c = t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        Ok(c.members.iter().position(|&m| m == world_rank as usize).map(|p| p as i32))
    })
}

/// Snapshot (members, my_rank, ctx_pt2pt, ctx_coll, next coll tag) — the
/// common read collectives/pt2pt need; one borrow.
pub(crate) fn comm_snapshot(comm: CommId) -> RC<(Vec<usize>, usize, u32, u32)> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let c = t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        Ok((c.members.clone(), c.my_rank, c.ctx_pt2pt, c.ctx_coll))
    })
}

/// Advance and return the collective tag for `comm`.
pub(crate) fn advance_coll_tag(comm: CommId) -> RC<i32> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let c = t.comms.get_mut(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        Ok(c.next_coll_tag())
    })
}

/// ULFM wildcard-receive condition: does the comm owning pt2pt plane
/// `context` have a dead member whose failure this rank has not yet
/// acknowledged? While true, a wildcard receive on that plane cannot
/// safely block (its match might have been the dead rank's message) and
/// reports `MPI_ERR_PROC_FAILED_PENDING` instead. Call only when
/// [`super::world::World::any_dead`] — the per-message fast path stays
/// one load.
pub(crate) fn failure_pending_on_context(ctx: &super::world::RankCtx, context: u32) -> bool {
    let t = ctx.tables.borrow();
    for (_, c) in t.comms.iter() {
        if c.ctx_pt2pt == context {
            let dead = c.members.iter().filter(|&&m| ctx.world.is_dead(m)).count();
            return dead > c.acked_failed;
        }
    }
    false
}

/// `MPI_Comm_ack_failed` (ULFM): acknowledge up to `num_to_ack` member
/// failures on `comm`; returns how many are now acknowledged in total.
/// Once every current failure is acknowledged, wildcard receives on the
/// comm stop reporting `MPI_ERR_PROC_FAILED_PENDING` (dead senders are
/// simply excluded from matching).
pub fn comm_ack_failed(comm: CommId, num_to_ack: i32) -> RC<i32> {
    if num_to_ack < 0 {
        return Err(err!(MPI_ERR_ARG));
    }
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let c = t.comms.get_mut(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        let dead = c.members.iter().filter(|&&m| ctx.world.is_dead(m)).count();
        let acked = dead.min(num_to_ack as usize).max(c.acked_failed.min(dead));
        c.acked_failed = c.acked_failed.max(acked);
        Ok(acked as i32)
    })
}
