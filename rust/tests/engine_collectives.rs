//! Engine-level integration tests: collectives, comm management, and
//! derived datatypes across multiple ranks, on both transports.

use mpi_abi::abi::datatypes as adt;
use mpi_abi::core::collectives as coll;
use mpi_abi::core::datatype::builtin_id_of_abi;
use mpi_abi::core::reserved::COMM_WORLD;
use mpi_abi::core::{comm, datatype, engine, op};
use mpi_abi::launcher::{run_job_ok, JobSpec};

fn dt_i32() -> mpi_abi::core::DtId {
    builtin_id_of_abi(adt::MPI_INT32_T).unwrap()
}

fn dt_f64() -> mpi_abi::core::DtId {
    builtin_id_of_abi(adt::MPI_DOUBLE).unwrap()
}

fn op_sum() -> mpi_abi::core::OpId {
    op::builtin_id_of_abi(mpi_abi::abi::ops::MPI_SUM).unwrap()
}

#[test]
fn barrier_all_sizes() {
    for n in [1, 2, 3, 4, 5, 8] {
        run_job_ok(JobSpec::new(n), |_| {
            engine::init().unwrap();
            for _ in 0..3 {
                coll::barrier(COMM_WORLD).unwrap();
            }
            engine::finalize().unwrap();
        });
    }
}

#[test]
fn bcast_from_each_root() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        for root in 0..n as i32 {
            let mut data = if rank as i32 == root {
                [root * 10, root * 10 + 1, root * 10 + 2]
            } else {
                [0; 3]
            };
            coll::bcast(data.as_mut_ptr() as *mut u8, 3, dt_i32(), root, COMM_WORLD).unwrap();
            assert_eq!(data, [root * 10, root * 10 + 1, root * 10 + 2]);
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn allreduce_sum_f64() {
    let n = 5;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let send = [rank as f64, 1.0, -(rank as f64)];
        let mut recv = [0.0f64; 3];
        coll::allreduce(
            send.as_ptr() as *const u8,
            recv.as_mut_ptr() as *mut u8,
            3,
            dt_f64(),
            op_sum(),
            COMM_WORLD,
        )
        .unwrap();
        let total: f64 = (0..n).map(|r| r as f64).sum();
        assert_eq!(recv, [total, n as f64, -total]);
        engine::finalize().unwrap();
    });
}

#[test]
fn reduce_to_nonzero_root_minloc() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        #[repr(C)]
        #[derive(Clone, Copy, Debug, PartialEq)]
        struct P(f32, i32);
        let send = [P(10.0 - rank as f32, rank as i32)];
        let mut recv = [P(0.0, -1)];
        let dt = builtin_id_of_abi(adt::MPI_FLOAT_INT).unwrap();
        let op = op::builtin_id_of_abi(mpi_abi::abi::ops::MPI_MINLOC).unwrap();
        coll::reduce(
            send.as_ptr() as *const u8,
            recv.as_mut_ptr() as *mut u8,
            1,
            dt,
            op,
            2,
            COMM_WORLD,
        )
        .unwrap();
        if rank == 2 {
            // Smallest value is at the largest rank.
            assert_eq!(recv[0], P(10.0 - (n - 1) as f32, (n - 1) as i32));
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn gather_scatter_roundtrip() {
    let n = 3;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        // Gather 2 ints per rank at root 1.
        let send = [rank as i32 * 2, rank as i32 * 2 + 1];
        let mut gathered = vec![0i32; 2 * n];
        coll::gather(
            send.as_ptr() as *const u8,
            2,
            dt_i32(),
            gathered.as_mut_ptr() as *mut u8,
            2,
            dt_i32(),
            1,
            COMM_WORLD,
        )
        .unwrap();
        if rank == 1 {
            assert_eq!(gathered, vec![0, 1, 2, 3, 4, 5]);
        }
        // Scatter it back from root 1.
        let mut got = [0i32; 2];
        coll::scatter(
            gathered.as_ptr() as *const u8,
            2,
            dt_i32(),
            got.as_mut_ptr() as *mut u8,
            2,
            dt_i32(),
            1,
            COMM_WORLD,
        )
        .unwrap();
        if rank == 1 {
            assert_eq!(got, [2, 3]);
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn allgather_collects_everything() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let send = [rank as i32 + 100];
        let mut recv = vec![0i32; n];
        coll::allgather(
            send.as_ptr() as *const u8,
            1,
            dt_i32(),
            recv.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            COMM_WORLD,
        )
        .unwrap();
        assert_eq!(recv, vec![100, 101, 102, 103]);
        engine::finalize().unwrap();
    });
}

#[test]
fn alltoall_transposes() {
    let n = 3;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        // Rank r sends value r*10+d to rank d.
        let send: Vec<i32> = (0..n).map(|d| (rank * 10 + d) as i32).collect();
        let mut recv = vec![0i32; n];
        coll::alltoall(
            send.as_ptr() as *const u8,
            1,
            dt_i32(),
            recv.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            COMM_WORLD,
        )
        .unwrap();
        let expect: Vec<i32> = (0..n).map(|s| (s * 10 + rank) as i32).collect();
        assert_eq!(recv, expect);
        engine::finalize().unwrap();
    });
}

#[test]
fn scan_prefix_sums() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let send = [rank as i32 + 1]; // 1, 2, 3, 4
        let mut recv = [0i32];
        coll::scan(
            send.as_ptr() as *const u8,
            recv.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            op_sum(),
            COMM_WORLD,
        )
        .unwrap();
        let expect: i32 = (1..=rank as i32 + 1).sum();
        assert_eq!(recv[0], expect);
        // Exscan.
        let mut ex = [-1i32];
        coll::exscan(
            send.as_ptr() as *const u8,
            ex.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            op_sum(),
            COMM_WORLD,
        )
        .unwrap();
        if rank == 0 {
            assert_eq!(ex[0], -1, "rank 0 exscan buffer untouched");
        } else {
            assert_eq!(ex[0], (1..=rank as i32).sum::<i32>());
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn comm_split_even_odd() {
    let n = 5;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let color = (rank % 2) as i32;
        // Reverse key order inside each color group.
        let key = -(rank as i32);
        let sub = engine::comm_split(COMM_WORLD, color, key).unwrap().unwrap();
        let sub_size = comm::comm_size(sub).unwrap() as usize;
        let sub_rank = comm::comm_rank(sub).unwrap() as usize;
        let expected_size = if color == 0 { n.div_ceil(2) } else { n / 2 };
        assert_eq!(sub_size, expected_size);
        // Keys are negative ranks → highest world rank is sub-rank 0.
        let group: Vec<usize> = (0..n).filter(|r| r % 2 == rank % 2).collect();
        let pos = group.iter().rev().position(|&r| r == rank).unwrap();
        assert_eq!(sub_rank, pos);
        // The subcomm must work for collectives.
        let send = [1i32];
        let mut recv = [0i32];
        coll::allreduce(
            send.as_ptr() as *const u8,
            recv.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            op_sum(),
            sub,
        )
        .unwrap();
        assert_eq!(recv[0], sub_size as i32);
        comm::comm_free(sub).unwrap();
        engine::finalize().unwrap();
    });
}

#[test]
fn comm_split_undefined_gets_none() {
    run_job_ok(JobSpec::new(3), |rank| {
        engine::init().unwrap();
        let color =
            if rank == 1 { mpi_abi::abi::constants::MPI_UNDEFINED } else { 0 };
        let sub = engine::comm_split(COMM_WORLD, color, 0).unwrap();
        assert_eq!(sub.is_some(), rank != 1);
        if let Some(c) = sub {
            assert_eq!(comm::comm_size(c).unwrap(), 2);
            comm::comm_free(c).unwrap();
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn comm_dup_isolates_traffic() {
    run_job_ok(JobSpec::new(2), |rank| {
        engine::init().unwrap();
        let dup = engine::comm_dup(COMM_WORLD).unwrap();
        let dt = dt_i32();
        // Same (src, tag) on both comms; contexts must keep them separate.
        if rank == 0 {
            let a = [111i32];
            let b = [222i32];
            engine::send(a.as_ptr() as *const u8, 1, dt, 1, 7, COMM_WORLD,
                engine::SendMode::Standard).unwrap();
            engine::send(b.as_ptr() as *const u8, 1, dt, 1, 7, dup,
                engine::SendMode::Standard).unwrap();
        } else {
            // Receive in the *opposite* order: context matching must pick
            // the right message regardless.
            let mut b = [0i32];
            engine::recv(b.as_mut_ptr() as *mut u8, 1, dt, 0, 7, dup).unwrap();
            assert_eq!(b[0], 222);
            let mut a = [0i32];
            engine::recv(a.as_mut_ptr() as *mut u8, 1, dt, 0, 7, COMM_WORLD).unwrap();
            assert_eq!(a[0], 111);
        }
        comm::comm_free(dup).unwrap();
        engine::finalize().unwrap();
    });
}

#[test]
fn derived_vector_type_transfers_strided_data() {
    run_job_ok(JobSpec::new(2), |rank| {
        engine::init().unwrap();
        // Column of a 4x4 row-major i32 matrix: vector(count=4, blocklen=1,
        // stride=4).
        let vec_t = datatype::type_vector(4, 1, 4, dt_i32()).unwrap();
        datatype::type_commit(vec_t).unwrap();
        if rank == 0 {
            let m: Vec<i32> = (0..16).collect();
            engine::send(m.as_ptr() as *const u8, 1, vec_t, 1, 0, COMM_WORLD,
                engine::SendMode::Standard).unwrap();
        } else {
            // Receive as 4 contiguous ints.
            let mut col = [0i32; 4];
            let st = engine::recv(col.as_mut_ptr() as *mut u8, 4, dt_i32(), 0, 0, COMM_WORLD)
                .unwrap();
            assert_eq!(st.count_bytes, 16);
            assert_eq!(col, [0, 4, 8, 12]);
        }
        datatype::type_free(vec_t).unwrap();
        engine::finalize().unwrap();
    });
}

#[test]
fn ialltoallw_compound_request() {
    let n = 3;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let dt = dt_i32();
        let send: Vec<i32> = (0..n).map(|d| (rank * 100 + d) as i32).collect();
        let mut recv = vec![0i32; n];
        let args = coll::AlltoallwArgs {
            sendbuf: send.as_ptr() as *const u8,
            sendcounts: vec![1; n],
            sdispls: (0..n).map(|d| (d * 4) as isize).collect(),
            sendtypes: vec![dt; n],
            recvbuf: recv.as_mut_ptr() as *mut u8,
            recvcounts: vec![1; n],
            rdispls: (0..n).map(|d| (d * 4) as isize).collect(),
            recvtypes: vec![dt; n],
        };
        let req = coll::ialltoallw(&args, COMM_WORLD).unwrap();
        // Poll with test() until completion (test frees the request when
        // it completes, so stop immediately then).
        loop {
            if engine::test(req).unwrap().is_some() {
                break;
            }
            std::thread::yield_now();
        }
        let expect: Vec<i32> = (0..n).map(|s| (s * 100 + rank) as i32).collect();
        assert_eq!(recv, expect);
        engine::finalize().unwrap();
    });
}

#[test]
fn sendrecv_ring_rotation() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let dt = dt_i32();
        let right = ((rank + 1) % n) as i32;
        let left = ((rank + n - 1) % n) as i32;
        let send = [rank as i32];
        let mut recv = [0i32];
        let st = engine::sendrecv(
            send.as_ptr() as *const u8,
            1,
            dt,
            right,
            5,
            recv.as_mut_ptr() as *mut u8,
            1,
            dt,
            left,
            5,
            COMM_WORLD,
        )
        .unwrap();
        assert_eq!(recv[0], left);
        assert_eq!(st.source, left);
        engine::finalize().unwrap();
    });
}

#[test]
fn probe_then_recv() {
    run_job_ok(JobSpec::new(2), |rank| {
        engine::init().unwrap();
        let dt = dt_i32();
        if rank == 0 {
            let data = [9i32, 8, 7];
            engine::send(data.as_ptr() as *const u8, 3, dt, 1, 13, COMM_WORLD,
                engine::SendMode::Standard).unwrap();
        } else {
            let st = engine::probe(mpi_abi::abi::constants::MPI_ANY_SOURCE,
                mpi_abi::abi::constants::MPI_ANY_TAG, COMM_WORLD).unwrap();
            assert_eq!(st.tag, 13);
            assert_eq!(st.count_bytes, 12);
            let count = engine::get_count(&st, dt).unwrap();
            let mut buf = vec![0i32; count as usize];
            engine::recv(buf.as_mut_ptr() as *mut u8, count as usize, dt, st.source, st.tag,
                COMM_WORLD).unwrap();
            assert_eq!(buf, vec![9, 8, 7]);
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn ssend_completes_only_after_match() {
    run_job_ok(JobSpec::new(2), |rank| {
        engine::init().unwrap();
        let dt = dt_i32();
        if rank == 0 {
            let data = [5i32];
            let req = engine::isend(data.as_ptr() as *const u8, 1, dt, 1, 3, COMM_WORLD,
                engine::SendMode::Sync).unwrap();
            // Not matched yet (receiver delays) — test may run a few times.
            let st = engine::wait(req).unwrap();
            assert!(!st.cancelled);
        } else {
            // Delay, then receive.
            std::thread::sleep(std::time::Duration::from_millis(10));
            let mut buf = [0i32];
            engine::recv(buf.as_mut_ptr() as *mut u8, 1, dt, 0, 3, COMM_WORLD).unwrap();
            assert_eq!(buf[0], 5);
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn truncation_reports_err_truncate() {
    run_job_ok(JobSpec::new(2), |rank| {
        engine::init().unwrap();
        let dt = dt_i32();
        if rank == 0 {
            let data = [1i32, 2, 3, 4];
            engine::send(data.as_ptr() as *const u8, 4, dt, 1, 0, COMM_WORLD,
                engine::SendMode::Standard).unwrap();
        } else {
            let mut buf = [0i32; 2]; // too small
            let e = engine::recv(buf.as_mut_ptr() as *mut u8, 2, dt, 0, 0, COMM_WORLD)
                .unwrap_err();
            assert_eq!(e.class, mpi_abi::abi::errors::MPI_ERR_TRUNCATE);
            assert_eq!(buf, [1, 2], "partial data delivered");
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn wildcard_any_source_ordering() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let dt = dt_i32();
        if rank == 0 {
            let mut seen = Vec::new();
            for _ in 1..n {
                let mut buf = [0i32];
                let st = engine::recv(
                    buf.as_mut_ptr() as *mut u8,
                    1,
                    dt,
                    mpi_abi::abi::constants::MPI_ANY_SOURCE,
                    1,
                    COMM_WORLD,
                )
                .unwrap();
                assert_eq!(buf[0], st.source * 1000);
                seen.push(st.source);
            }
            seen.sort();
            assert_eq!(seen, vec![1, 2, 3]);
        } else {
            let data = [rank as i32 * 1000];
            engine::send(data.as_ptr() as *const u8, 1, dt, 0, 1, COMM_WORLD,
                engine::SendMode::Standard).unwrap();
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn gatherv_scatterv_variable_blocks() {
    let n = 3;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        // Rank r contributes r+1 ints.
        let send: Vec<i32> = (0..rank as i32 + 1).map(|i| rank as i32 * 10 + i).collect();
        let counts = [1usize, 2, 3];
        let displs = [0isize, 1, 3];
        let mut recv = vec![-1i32; 6];
        coll::gatherv(
            send.as_ptr() as *const u8,
            send.len(),
            dt_i32(),
            recv.as_mut_ptr() as *mut u8,
            &counts,
            &displs,
            dt_i32(),
            0,
            COMM_WORLD,
        )
        .unwrap();
        if rank == 0 {
            assert_eq!(recv, vec![0, 10, 11, 20, 21, 22]);
            // Scatter the variable blocks back.
        }
        let mut back = vec![0i32; rank + 1];
        coll::scatterv(
            recv.as_ptr() as *const u8,
            &counts,
            &displs,
            dt_i32(),
            back.as_mut_ptr() as *mut u8,
            rank + 1,
            dt_i32(),
            0,
            COMM_WORLD,
        )
        .unwrap();
        let expect: Vec<i32> = (0..rank as i32 + 1).map(|i| rank as i32 * 10 + i).collect();
        assert_eq!(back, expect);
        engine::finalize().unwrap();
    });
}

#[test]
fn alltoallv_variable_counts() {
    let n = 3;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        // Rank r sends (d+1) copies of r*100+d to rank d.
        let scounts: Vec<usize> = (0..n).map(|d| d + 1).collect();
        let sdispls: Vec<isize> = [0isize, 1, 3].to_vec();
        let mut send = Vec::new();
        for d in 0..n {
            for _ in 0..d + 1 {
                send.push((rank * 100 + d) as i32);
            }
        }
        // Rank r receives (r+1) ints from each sender.
        let rcounts: Vec<usize> = vec![rank + 1; n];
        let rdispls: Vec<isize> = (0..n).map(|s| (s * (rank + 1)) as isize).collect();
        let mut recv = vec![-1i32; (rank + 1) * n];
        coll::alltoallv(
            send.as_ptr() as *const u8,
            &scounts,
            &sdispls,
            dt_i32(),
            recv.as_mut_ptr() as *mut u8,
            &rcounts,
            &rdispls,
            dt_i32(),
            COMM_WORLD,
        )
        .unwrap();
        for s in 0..n {
            for j in 0..rank + 1 {
                assert_eq!(recv[s * (rank + 1) + j], (s * 100 + rank) as i32);
            }
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn ibarrier_synchronizes() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        // Stagger arrival, complete via test-loop.
        std::thread::sleep(std::time::Duration::from_micros(100 * rank as u64));
        let req = coll::ibarrier(COMM_WORLD).unwrap();
        loop {
            if engine::test(req).unwrap().is_some() {
                break;
            }
            std::thread::yield_now();
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn reduce_local_applies_op_without_communication() {
    run_job_ok(JobSpec::new(1), |_| {
        engine::init().unwrap();
        let a = [1i32, 5, 3];
        let mut b = [10i32, 2, 3];
        let abytes =
            unsafe { std::slice::from_raw_parts(a.as_ptr() as *const u8, 12) };
        let bbytes =
            unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr() as *mut u8, 12) };
        op::apply(op::builtin_id_of_abi(mpi_abi::abi::ops::MPI_MAX).unwrap(), abytes, bbytes, 3,
            dt_i32())
        .unwrap();
        assert_eq!(b, [10, 5, 3]);
        engine::finalize().unwrap();
    });
}

#[test]
fn group_algebra_via_engine() {
    run_job_ok(JobSpec::new(4), |_| {
        engine::init().unwrap();
        use mpi_abi::core::group;
        let world = comm::comm_group(COMM_WORLD).unwrap();
        let evens = group::group_incl(world, &[0, 2]).unwrap();
        let odds = group::group_excl(world, &[0, 2]).unwrap();
        assert_eq!(group::group_size(evens).unwrap(), 2);
        assert_eq!(group::group_size(odds).unwrap(), 2);
        let all = group::group_union(evens, odds).unwrap();
        assert_eq!(group::group_size(all).unwrap(), 4);
        let none = group::group_intersection(evens, odds).unwrap();
        assert_eq!(group::group_size(none).unwrap(), 0);
        let diff = group::group_difference(all, odds).unwrap();
        assert_eq!(
            group::group_compare(diff, evens).unwrap(),
            mpi_abi::abi::constants::MPI_IDENT
        );
        for g in [world, evens, odds, all, none, diff] {
            group::group_free(g).unwrap();
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn comm_create_from_subgroup() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        use mpi_abi::core::group;
        let world = comm::comm_group(COMM_WORLD).unwrap();
        let first_two = group::group_incl(world, &[0, 1]).unwrap();
        let sub = engine::comm_create(COMM_WORLD, first_two).unwrap();
        if rank < 2 {
            let c = sub.expect("members get a comm");
            assert_eq!(comm::comm_size(c).unwrap(), 2);
            assert_eq!(comm::comm_rank(c).unwrap(), rank as i32);
            // And it works.
            coll::barrier(c).unwrap();
            comm::comm_free(c).unwrap();
        } else {
            assert!(sub.is_none(), "non-members get COMM_NULL");
        }
        group::group_free(world).unwrap();
        group::group_free(first_two).unwrap();
        engine::finalize().unwrap();
    });
}

// --- Nonblocking collectives over the schedule engine -----------------------

#[test]
fn nonblocking_out_of_order_completion() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        // Issue ibcast then iallreduce; complete them in reverse order.
        let mut bc = if rank == 0 { [5i32, 6, 7] } else { [0i32; 3] };
        let breq = coll::ibcast(bc.as_mut_ptr() as *mut u8, 3, dt_i32(), 0, COMM_WORLD).unwrap();
        let send = [rank as i32 + 1];
        let mut recv = [0i32];
        let areq = coll::iallreduce(
            send.as_ptr() as *const u8,
            recv.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            op_sum(),
            COMM_WORLD,
        )
        .unwrap();
        let st = engine::wait(areq).unwrap();
        assert_eq!(st.error, 0);
        assert_eq!(recv[0], (1..=n as i32).sum::<i32>());
        engine::wait(breq).unwrap();
        assert_eq!(bc, [5, 6, 7]);
        engine::finalize().unwrap();
    });
}

#[test]
fn iallreduce_overlaps_pt2pt_on_same_comm() {
    let n = 3;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let send = [rank as f64, 2.0];
        let mut recv = [0.0f64; 2];
        let req = coll::iallreduce(
            send.as_ptr() as *const u8,
            recv.as_mut_ptr() as *mut u8,
            2,
            dt_f64(),
            op_sum(),
            COMM_WORLD,
        )
        .unwrap();
        // Pt2pt ring on the same comm while the collective is pending.
        let right = ((rank + 1) % n) as i32;
        let left = ((rank + n - 1) % n) as i32;
        let ps = [rank as i32 * 3];
        let mut pr = [-1i32];
        let st = engine::sendrecv(
            ps.as_ptr() as *const u8,
            1,
            dt_i32(),
            right,
            9,
            pr.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            left,
            9,
            COMM_WORLD,
        )
        .unwrap();
        assert_eq!(st.source, left);
        assert_eq!(pr[0], left * 3);
        engine::wait(req).unwrap();
        let total: f64 = (0..n).map(|r| r as f64).sum();
        assert_eq!(recv, [total, 2.0 * n as f64]);
        engine::finalize().unwrap();
    });
}

#[test]
fn waitall_over_mixed_request_kinds() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let dt = dt_i32();
        let right = ((rank + 1) % n) as i32;
        let left = ((rank + n - 1) % n) as i32;
        let ps = [rank as i32 + 40];
        let mut pr = [0i32];
        let mut bc = if rank == 2 { [99i32] } else { [0i32] };
        let reqs = vec![
            engine::irecv(pr.as_mut_ptr() as *mut u8, 1, dt, left, 6, COMM_WORLD).unwrap(),
            engine::isend(ps.as_ptr() as *const u8, 1, dt, right, 6, COMM_WORLD,
                engine::SendMode::Standard).unwrap(),
            coll::ibarrier(COMM_WORLD).unwrap(),
            coll::ibcast(bc.as_mut_ptr() as *mut u8, 1, dt, 2, COMM_WORLD).unwrap(),
        ];
        let sts = engine::waitall(&reqs).unwrap();
        assert_eq!(sts.len(), 4);
        assert_eq!(pr[0], left + 40);
        assert_eq!(bc[0], 99);
        engine::finalize().unwrap();
    });
}

#[test]
fn nonblocking_collectives_on_mutex_transport() {
    use mpi_abi::core::transport::TransportKind;
    let n = 4;
    run_job_ok(JobSpec::new(n).with_transport(TransportKind::Mutex), |rank| {
        engine::init().unwrap();
        let mut bc = if rank == 1 { [17i32, 18] } else { [0i32; 2] };
        let breq = coll::ibcast(bc.as_mut_ptr() as *mut u8, 2, dt_i32(), 1, COMM_WORLD).unwrap();
        let send = [rank as i32];
        let mut recv = [0i32];
        let areq = coll::iallreduce(
            send.as_ptr() as *const u8,
            recv.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            op_sum(),
            COMM_WORLD,
        )
        .unwrap();
        for r in engine::waitall(&[breq, areq]).unwrap() {
            assert_eq!(r.error, 0);
        }
        assert_eq!(bc, [17, 18]);
        assert_eq!(recv[0], (0..n as i32).sum::<i32>());
        engine::finalize().unwrap();
    });
}

#[test]
fn igatherv_nonblocking_variable_blocks() {
    let n = 3;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let send: Vec<i32> = (0..rank as i32 + 1).map(|i| rank as i32 * 10 + i).collect();
        let counts = [1usize, 2, 3];
        let displs = [0isize, 1, 3];
        let mut recv = vec![-1i32; 6];
        let req = coll::igatherv(
            send.as_ptr() as *const u8,
            send.len(),
            dt_i32(),
            recv.as_mut_ptr() as *mut u8,
            &counts,
            &displs,
            dt_i32(),
            0,
            COMM_WORLD,
        )
        .unwrap();
        engine::wait(req).unwrap();
        if rank == 0 {
            assert_eq!(recv, vec![0, 10, 11, 20, 21, 22]);
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn iscan_iexscan_ireduce_scatter_block_concurrent() {
    let n = 4;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let dt = dt_i32();
        let op = op_sum();
        let scan_in = [rank as i32 + 1];
        let mut scan_out = [0i32];
        let ex_in = [rank as i32 + 1];
        let mut ex_out = [-5i32];
        let rsb_in: Vec<i32> = (0..2 * n as i32).map(|i| i + rank as i32).collect();
        let mut rsb_out = [0i32; 2];
        let reqs = vec![
            coll::iscan(scan_in.as_ptr() as *const u8, scan_out.as_mut_ptr() as *mut u8, 1, dt,
                op, COMM_WORLD).unwrap(),
            coll::iexscan(ex_in.as_ptr() as *const u8, ex_out.as_mut_ptr() as *mut u8, 1, dt,
                op, COMM_WORLD).unwrap(),
            coll::ireduce_scatter_block(rsb_in.as_ptr() as *const u8,
                rsb_out.as_mut_ptr() as *mut u8, 2, dt, op, COMM_WORLD).unwrap(),
        ];
        for st in engine::waitall(&reqs).unwrap() {
            assert_eq!(st.error, 0);
        }
        assert_eq!(scan_out[0], (1..=rank as i32 + 1).sum::<i32>());
        if rank == 0 {
            assert_eq!(ex_out[0], -5, "rank 0 exscan buffer untouched");
        } else {
            assert_eq!(ex_out[0], (1..=rank as i32).sum::<i32>());
        }
        let rank_sum: i32 = (0..n as i32).sum();
        let r = rank as i32;
        let nn = n as i32;
        assert_eq!(rsb_out, [2 * r * nn + rank_sum, (2 * r + 1) * nn + rank_sum]);
        engine::finalize().unwrap();
    });
}

#[test]
fn ireduce_to_nonzero_root_nonblocking() {
    let n = 5;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let send = [rank as i32, 100];
        let mut recv = [0i32; 2];
        let req = coll::ireduce(
            send.as_ptr() as *const u8,
            recv.as_mut_ptr() as *mut u8,
            2,
            dt_i32(),
            op_sum(),
            3,
            COMM_WORLD,
        )
        .unwrap();
        engine::wait(req).unwrap();
        if rank == 3 {
            assert_eq!(recv, [(0..n as i32).sum::<i32>(), 100 * n as i32]);
        }
        engine::finalize().unwrap();
    });
}

#[test]
fn many_nonblocking_collectives_in_flight() {
    // A window of nonblocking collectives on one comm, completed together:
    // the per-comm sequence must keep every schedule's traffic separate.
    let n = 3;
    run_job_ok(JobSpec::new(n), |rank| {
        engine::init().unwrap();
        let k = 8;
        let bufs: Vec<[i32; 1]> = (0..k).map(|_| [rank as i32 + 1]).collect();
        let mut outs: Vec<[i32; 1]> = (0..k).map(|_| [0]).collect();
        let mut reqs = Vec::new();
        for i in 0..k {
            reqs.push(
                coll::iallreduce(
                    bufs[i].as_ptr() as *const u8,
                    outs[i].as_mut_ptr() as *mut u8,
                    1,
                    dt_i32(),
                    op_sum(),
                    COMM_WORLD,
                )
                .unwrap(),
            );
        }
        for st in engine::waitall(&reqs).unwrap() {
            assert_eq!(st.error, 0);
        }
        for o in &outs {
            assert_eq!(o[0], (1..=n as i32).sum::<i32>());
        }
        engine::finalize().unwrap();
    });
}

// ---------------------------------------------------------------------------
// Forced collective algorithm selection (PR 10)
// ---------------------------------------------------------------------------

/// Every forced allreduce builder computes the same sums as the binomial
/// baseline, on power-of-two, prime, and composite rank counts. The
/// vector is long enough that ring and Rabenseifner segment it unevenly
/// when the count does not divide by the rank count.
#[test]
fn forced_allreduce_algorithms_all_reduce_correctly() {
    for algo in [
        coll::ALLREDUCE_BINOMIAL,
        coll::ALLREDUCE_RING,
        coll::ALLREDUCE_RECURSIVE_DOUBLING,
        coll::ALLREDUCE_RABENSEIFNER,
    ] {
        for n in [2usize, 3, 5, 7, 8] {
            let force = coll::CollAlgoForce { allreduce: algo, ..Default::default() };
            run_job_ok(JobSpec::new(n).with_coll_algo(force), move |rank| {
                engine::init().unwrap();
                let send: Vec<i32> = (0..10).map(|i| (rank as i32 + 1) * (i + 1)).collect();
                let mut recv = vec![0i32; 10];
                coll::allreduce(
                    send.as_ptr() as *const u8,
                    recv.as_mut_ptr() as *mut u8,
                    10,
                    dt_i32(),
                    op_sum(),
                    COMM_WORLD,
                )
                .unwrap();
                let ranks_sum: i32 = (1..=n as i32).sum();
                let expect: Vec<i32> = (0..10).map(|i| ranks_sum * (i + 1)).collect();
                assert_eq!(recv, expect, "algo {algo} n {n}");
                engine::finalize().unwrap();
            });
        }
    }
}

#[test]
fn forced_ring_allgather_collects_everything() {
    for n in [3usize, 5, 8] {
        let force = coll::CollAlgoForce { allgather: coll::ALLGATHER_RING, ..Default::default() };
        run_job_ok(JobSpec::new(n).with_coll_algo(force), move |rank| {
            engine::init().unwrap();
            let send = [rank as i32 + 100, -(rank as i32)];
            let mut recv = vec![0i32; 2 * n];
            coll::allgather(
                send.as_ptr() as *const u8,
                2,
                dt_i32(),
                recv.as_mut_ptr() as *mut u8,
                2,
                dt_i32(),
                COMM_WORLD,
            )
            .unwrap();
            let expect: Vec<i32> =
                (0..n).flat_map(|r| [r as i32 + 100, -(r as i32)]).collect();
            assert_eq!(recv, expect, "n {n}");
            engine::finalize().unwrap();
        });
    }
}

/// The ring builder serves allgatherv too: variable block sizes rotate
/// around the ring with per-source displacements intact.
#[test]
fn forced_ring_allgatherv_variable_blocks() {
    let n = 4;
    let force = coll::CollAlgoForce { allgather: coll::ALLGATHER_RING, ..Default::default() };
    run_job_ok(JobSpec::new(n).with_coll_algo(force), move |rank| {
        engine::init().unwrap();
        // Rank r contributes r+1 ints: r*10, r*10+1, ...
        let send: Vec<i32> = (0..rank as i32 + 1).map(|i| rank as i32 * 10 + i).collect();
        let counts: Vec<usize> = (0..n).map(|r| r + 1).collect();
        let displs: Vec<isize> = {
            let mut d = vec![0isize; n];
            for r in 1..n {
                d[r] = d[r - 1] + counts[r - 1] as isize;
            }
            d
        };
        let total: usize = counts.iter().sum();
        let mut recv = vec![-1i32; total];
        coll::allgatherv(
            send.as_ptr() as *const u8,
            send.len(),
            dt_i32(),
            recv.as_mut_ptr() as *mut u8,
            &counts,
            &displs,
            dt_i32(),
            COMM_WORLD,
        )
        .unwrap();
        let expect: Vec<i32> =
            (0..n as i32).flat_map(|r| (0..r + 1).map(move |i| r * 10 + i)).collect();
        assert_eq!(recv, expect);
        engine::finalize().unwrap();
    });
}

#[test]
fn forced_bruck_alltoall_transposes_non_power_of_two() {
    for n in [3usize, 5, 6, 7] {
        let force = coll::CollAlgoForce { alltoall: coll::ALLTOALL_BRUCK, ..Default::default() };
        run_job_ok(JobSpec::new(n).with_coll_algo(force), move |rank| {
            engine::init().unwrap();
            // Two ints per destination so Bruck's rotate/pack phases move
            // multi-element blocks.
            let send: Vec<i32> = (0..n)
                .flat_map(|d| [(rank * 100 + d) as i32, (d * 100 + rank) as i32])
                .collect();
            let mut recv = vec![-1i32; 2 * n];
            coll::alltoall(
                send.as_ptr() as *const u8,
                2,
                dt_i32(),
                recv.as_mut_ptr() as *mut u8,
                2,
                dt_i32(),
                COMM_WORLD,
            )
            .unwrap();
            let expect: Vec<i32> = (0..n)
                .flat_map(|s| [(s * 100 + rank) as i32, (rank * 100 + s) as i32])
                .collect();
            assert_eq!(recv, expect, "n {n}");
            engine::finalize().unwrap();
        });
    }
}

/// Forced algorithms flow through the nonblocking schedule path and the
/// mutex transport exactly as through the blocking spsc default.
#[test]
fn forced_algorithms_nonblocking_on_mutex_transport() {
    use mpi_abi::core::transport::TransportKind;
    let n = 5;
    let force = coll::CollAlgoForce {
        allreduce: coll::ALLREDUCE_RING,
        allgather: coll::ALLGATHER_RING,
        alltoall: coll::ALLTOALL_BRUCK,
    };
    run_job_ok(
        JobSpec::new(n).with_transport(TransportKind::Mutex).with_coll_algo(force),
        move |rank| {
            engine::init().unwrap();
            let ar_in = [rank as i32 + 1];
            let mut ar_out = [0i32];
            let ag_in = [rank as i32 * 7];
            let mut ag_out = vec![0i32; n];
            let a2a_in: Vec<i32> = (0..n).map(|d| (rank * 10 + d) as i32).collect();
            let mut a2a_out = vec![0i32; n];
            let reqs = vec![
                coll::iallreduce(
                    ar_in.as_ptr() as *const u8,
                    ar_out.as_mut_ptr() as *mut u8,
                    1,
                    dt_i32(),
                    op_sum(),
                    COMM_WORLD,
                )
                .unwrap(),
                coll::iallgather(
                    ag_in.as_ptr() as *const u8,
                    1,
                    dt_i32(),
                    ag_out.as_mut_ptr() as *mut u8,
                    1,
                    dt_i32(),
                    COMM_WORLD,
                )
                .unwrap(),
            ];
            for st in engine::waitall(&reqs).unwrap() {
                assert_eq!(st.error, 0);
            }
            coll::alltoall(
                a2a_in.as_ptr() as *const u8,
                1,
                dt_i32(),
                a2a_out.as_mut_ptr() as *mut u8,
                1,
                dt_i32(),
                COMM_WORLD,
            )
            .unwrap();
            assert_eq!(ar_out[0], (1..=n as i32).sum::<i32>());
            assert_eq!(ag_out, (0..n as i32).map(|r| r * 7).collect::<Vec<_>>());
            assert_eq!(
                a2a_out,
                (0..n as i32).map(|s| s * 10 + rank as i32).collect::<Vec<_>>()
            );
            engine::finalize().unwrap();
        },
    );
}

// ---------------------------------------------------------------------------
// Persistent requests (engine level)
// ---------------------------------------------------------------------------

fn dt_byte() -> mpi_abi::core::DtId {
    builtin_id_of_abi(adt::MPI_BYTE).unwrap()
}

#[test]
fn persistent_pt2pt_restart_both_transports() {
    use mpi_abi::core::transport::TransportKind;
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        run_job_ok(JobSpec::new(2).with_transport(transport), move |rank| {
            engine::init().unwrap();
            if rank == 0 {
                let mut buf = [0i32; 2];
                let req = engine::send_init(
                    buf.as_ptr() as *const u8,
                    2,
                    dt_i32(),
                    1,
                    3,
                    COMM_WORLD,
                    engine::SendMode::Standard,
                )
                .unwrap();
                for k in 0..4i32 {
                    buf = [k, k + 10];
                    engine::start(req).unwrap();
                    engine::wait(req).unwrap();
                }
                mpi_abi::core::request::request_free(req).unwrap();
            } else {
                let mut buf = [0i32; 2];
                let req = engine::recv_init(
                    buf.as_mut_ptr() as *mut u8,
                    2,
                    dt_i32(),
                    0,
                    3,
                    COMM_WORLD,
                )
                .unwrap();
                for k in 0..4i32 {
                    engine::start(req).unwrap();
                    let st = engine::wait(req).unwrap();
                    assert_eq!(st.error, 0);
                    assert_eq!(st.count_bytes, 8);
                    assert_eq!(buf, [k, k + 10], "restart {k} must see fresh data");
                }
                mpi_abi::core::request::request_free(req).unwrap();
            }
            engine::finalize().unwrap();
        });
    }
}

#[test]
fn persistent_collective_reuses_schedule() {
    run_job_ok(JobSpec::new(2), |rank| {
        engine::init().unwrap();
        let contrib = [rank as i32 + 1];
        let mut out = [0i32];
        let req = coll::allreduce_init(
            contrib.as_ptr() as *const u8,
            out.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            op_sum(),
            COMM_WORLD,
        )
        .unwrap();
        coll::barrier(COMM_WORLD).unwrap();
        let b0 = coll::schedules_built();
        for _ in 0..10 {
            engine::start(req).unwrap();
            let st = engine::wait(req).unwrap();
            assert_eq!(st.error, 0);
            assert_eq!(out[0], 3);
        }
        let delta = coll::schedules_built() - b0;
        // Rendezvous (schedule-free pt2pt) before asserting: the counter
        // is process-global and the peer's *next* collective build must
        // not land inside our measurement window.
        let peer = (1 - rank) as i32;
        let token = [0u8];
        let mut tok = [0u8];
        engine::sendrecv(
            token.as_ptr(),
            1,
            dt_byte(),
            peer,
            70,
            tok.as_mut_ptr(),
            1,
            dt_byte(),
            peer,
            70,
            COMM_WORLD,
        )
        .unwrap();
        assert_eq!(delta, 0, "persistent starts must reuse, not rebuild, the schedule");
        mpi_abi::core::request::request_free(req).unwrap();
        engine::finalize().unwrap();
    });
}

#[test]
fn request_free_accepts_inactive_persistent_collective() {
    // Regression: PR 1's request_free rejected *every* schedule-backed
    // request; inactive persistent collectives must free cleanly, while
    // active schedule-backed requests must still be rejected (covered at
    // the ABI level by testsuite/persistent.rs).
    run_job_ok(JobSpec::new(2), |_| {
        engine::init().unwrap();
        // Never started: free must succeed.
        let req = coll::barrier_init(COMM_WORLD).unwrap();
        mpi_abi::core::request::request_free(req).unwrap();
        // Started then waited: inactive again, frees as well.
        let req = coll::barrier_init(COMM_WORLD).unwrap();
        engine::start(req).unwrap();
        engine::wait(req).unwrap();
        mpi_abi::core::request::request_free(req).unwrap();
        engine::finalize().unwrap();
    });
}

#[test]
fn start_while_active_is_an_error() {
    run_job_ok(JobSpec::new(1), |_| {
        engine::init().unwrap();
        let mut buf = [0i32];
        let req = engine::recv_init(
            buf.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            mpi_abi::abi::constants::MPI_ANY_SOURCE,
            31000,
            COMM_WORLD,
        )
        .unwrap();
        engine::start(req).unwrap();
        assert!(engine::start(req).is_err(), "start on an active request must fail");
        mpi_abi::core::request::cancel(req).unwrap();
        let st = engine::wait(req).unwrap();
        assert!(st.cancelled);
        mpi_abi::core::request::request_free(req).unwrap();
        engine::finalize().unwrap();
    });
}

#[test]
fn testany_distinguishes_inactive_from_pending() {
    use mpi_abi::core::engine::TestAnyOutcome;
    run_job_ok(JobSpec::new(1), |_| {
        engine::init().unwrap();
        let mut b = [0i32];
        // One inactive persistent request: NoneActive, not Pending and
        // not a phantom completion (MPI 3.0 §3.7.5).
        let inactive = engine::recv_init(
            b.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            mpi_abi::abi::constants::MPI_PROC_NULL,
            0,
            COMM_WORLD,
        )
        .unwrap();
        assert_eq!(engine::testany(&[inactive]).unwrap(), TestAnyOutcome::NoneActive);
        // Add an active-but-unmatchable receive: Pending.
        let mut c = [0i32];
        let pending = engine::irecv(
            c.as_mut_ptr() as *mut u8,
            1,
            dt_i32(),
            mpi_abi::abi::constants::MPI_ANY_SOURCE,
            30999,
            COMM_WORLD,
        )
        .unwrap();
        assert_eq!(engine::testany(&[inactive, pending]).unwrap(), TestAnyOutcome::Pending);
        // Add a completed send: Completed at its index, skipping the
        // inactive one.
        let v = [1i32];
        let done = engine::isend(
            v.as_ptr() as *const u8,
            1,
            dt_i32(),
            mpi_abi::abi::constants::MPI_PROC_NULL,
            0,
            COMM_WORLD,
            engine::SendMode::Standard,
        )
        .unwrap();
        match engine::testany(&[inactive, pending, done]).unwrap() {
            TestAnyOutcome::Completed(2, _) => {}
            other => panic!("expected Completed(2, _), got {other:?}"),
        }
        // Clean up.
        mpi_abi::core::request::cancel(pending).unwrap();
        engine::wait(pending).unwrap();
        mpi_abi::core::request::request_free(inactive).unwrap();
        engine::finalize().unwrap();
    });
}
