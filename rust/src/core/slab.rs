//! Minimal slab allocator for engine object tables.
//!
//! Dense `u32` keys with free-list reuse — the same structure MPI
//! implementations use for handle tables, so "handle → object" is one
//! bounds-checked index.

/// Growable table of `T` with stable `u32` keys.
pub struct Slab<T> {
    slots: Vec<Option<T>>,
    free: Vec<u32>,
    live: usize,
}

impl<T> Slab<T> {
    /// Create an empty slab.
    pub fn new() -> Slab<T> {
        Slab { slots: Vec::new(), free: Vec::new(), live: 0 }
    }

    /// Insert, returning the new key.
    pub fn insert(&mut self, v: T) -> u32 {
        self.live += 1;
        if let Some(k) = self.free.pop() {
            self.slots[k as usize] = Some(v);
            k
        } else {
            self.slots.push(Some(v));
            (self.slots.len() - 1) as u32
        }
    }

    /// Insert at a specific key (used to pin predefined objects at their
    /// reserved indices during table initialization). Panics if occupied.
    pub fn insert_at(&mut self, key: u32, v: T) {
        let k = key as usize;
        if self.slots.len() <= k {
            self.slots.resize_with(k + 1, || None);
        }
        assert!(self.slots[k].is_none(), "slab slot {key} already occupied");
        self.slots[k] = Some(v);
        self.live += 1;
        // Note: we do not maintain the free list for interior holes created
        // by resize_with; init fills 0..N densely so none arise in practice.
    }

    /// Borrow the object at `key`, if live.
    pub fn get(&self, key: u32) -> Option<&T> {
        self.slots.get(key as usize).and_then(|s| s.as_ref())
    }

    /// Mutably borrow the object at `key`, if live.
    pub fn get_mut(&mut self, key: u32) -> Option<&mut T> {
        self.slots.get_mut(key as usize).and_then(|s| s.as_mut())
    }

    /// Remove and return the object at `key`.
    pub fn remove(&mut self, key: u32) -> Option<T> {
        let v = self.slots.get_mut(key as usize).and_then(|s| s.take());
        if v.is_some() {
            self.live -= 1;
            self.free.push(key);
        }
        v
    }

    /// Whether `key` names a live object.
    pub fn contains(&self, key: u32) -> bool {
        self.get(key).is_some()
    }

    /// Number of live objects.
    pub fn len(&self) -> usize {
        self.live
    }

    /// `true` when no objects are live.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Iterate `(key, &T)` over live slots.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &T)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|v| (i as u32, v)))
    }
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove() {
        let mut s = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_ne!(a, b);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.remove(a), Some("a"));
        assert_eq!(s.get(a), None);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn keys_are_reused_after_free() {
        let mut s = Slab::new();
        let a = s.insert(1);
        s.remove(a);
        let b = s.insert(2);
        assert_eq!(a, b, "free-list reuse");
    }

    #[test]
    fn insert_at_pins_reserved_slots() {
        let mut s = Slab::new();
        s.insert_at(3, "x");
        assert_eq!(s.get(3), Some(&"x"));
        // Dynamic inserts fill from the end, never colliding.
        let k = s.insert("y");
        assert_ne!(k, 3);
    }

    #[test]
    #[should_panic(expected = "already occupied")]
    fn insert_at_occupied_panics() {
        let mut s = Slab::new();
        s.insert_at(0, 1);
        s.insert_at(0, 2);
    }

    #[test]
    fn double_remove_is_none() {
        let mut s = Slab::new();
        let a = s.insert(9);
        assert_eq!(s.remove(a), Some(9));
        assert_eq!(s.remove(a), None);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn iter_skips_holes() {
        let mut s = Slab::new();
        let a = s.insert(10);
        let _b = s.insert(20);
        let _c = s.insert(30);
        s.remove(a);
        let items: Vec<_> = s.iter().map(|(_, v)| *v).collect();
        assert_eq!(items, vec![20, 30]);
    }
}
