//! MPI integer types as prescribed by the standard ABI (§5.1).
//!
//! The proposal fixes:
//!
//! ```c
//! typedef intptr_t MPI_Aint;
//! typedef int64_t  MPI_Offset;
//! typedef int64_t  MPI_Count;
//! ```
//!
//! i.e. `MPI_Aint` tracks the platform pointer width (it must hold both
//! absolute addresses *and* pointer differences, and must be signed because
//! Fortran has no unsigned integers), while `MPI_Offset`/`MPI_Count` are
//! pinned to 64 bits on every supported platform (A32O64 and A64O64).

/// `MPI_Aint`: signed integer wide enough to hold a pointer (`intptr_t`).
pub type Aint = isize;

/// `MPI_Offset`: file offsets; fixed at 64 bits for both standard ABIs.
pub type Offset = i64;

/// `MPI_Count`: must hold every value of `MPI_Aint` **and** `MPI_Offset`,
/// hence 64 bits on all A32O64/A64O64 platforms.
pub type Count = i64;

/// `MPI_Fint`: a Fortran `INTEGER`. The ABI proposal leaves this queryable
/// at runtime; the common case (and our fixed choice) is a C `int`.
pub type Fint = i32;

/// The `AnOm` ABI-variant notation from §5.1: number of bits in `MPI_Aint`
/// and in `MPI_Offset`. Mirrors the `ILP`/`LP64` convention for platform
/// ABIs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AbiVariant {
    /// Bits in `MPI_Aint` (pointer width).
    pub aint_bits: u32,
    /// Bits in `MPI_Offset`.
    pub offset_bits: u32,
}

impl AbiVariant {
    /// 32-bit addresses, 64-bit file offsets (e.g. ILP32 with LFS).
    pub const A32O64: AbiVariant = AbiVariant { aint_bits: 32, offset_bits: 64 };
    /// 64-bit addresses, 64-bit file offsets (all modern LP64 platforms).
    pub const A64O64: AbiVariant = AbiVariant { aint_bits: 64, offset_bits: 64 };

    /// The variant compiled into this build, derived from the real pointer
    /// width. Only A32O64 and A64O64 are standardized (§5.1 explicitly
    /// defers 128-bit platforms such as CHERI).
    pub const fn native() -> AbiVariant {
        AbiVariant {
            aint_bits: (core::mem::size_of::<Aint>() * 8) as u32,
            offset_bits: (core::mem::size_of::<Offset>() * 8) as u32,
        }
    }

    /// Bits in `MPI_Count` = max(aint, offset) (§5.1).
    pub const fn count_bits(self) -> u32 {
        if self.aint_bits > self.offset_bits { self.aint_bits } else { self.offset_bits }
    }

    /// `true` if this is one of the two variants the proposal standardizes.
    pub const fn is_standardized(self) -> bool {
        (self.aint_bits == 32 || self.aint_bits == 64) && self.offset_bits == 64
    }

    /// Render in the paper's `AnOm` notation, e.g. `"A64O64"`.
    pub fn notation(self) -> String {
        format!("A{}O{}", self.aint_bits, self.offset_bits)
    }
}

impl std::fmt::Display for AbiVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "A{}O{}", self.aint_bits, self.offset_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aint_is_pointer_sized_and_signed() {
        assert_eq!(core::mem::size_of::<Aint>(), core::mem::size_of::<*mut u8>());
        // Signedness: Aint must represent negative displacements.
        let a: Aint = -1;
        assert!(a < 0);
    }

    #[test]
    fn offset_and_count_are_64bit() {
        assert_eq!(core::mem::size_of::<Offset>(), 8);
        assert_eq!(core::mem::size_of::<Count>(), 8);
    }

    #[test]
    fn count_holds_aint_and_offset() {
        // MPI_Count must be at least as wide as both MPI_Aint and MPI_Offset.
        assert!(core::mem::size_of::<Count>() >= core::mem::size_of::<Aint>());
        assert!(core::mem::size_of::<Count>() >= core::mem::size_of::<Offset>());
    }

    #[test]
    fn native_variant_is_standardized() {
        let v = AbiVariant::native();
        assert!(v.is_standardized(), "unsupported platform variant {v}");
        assert_eq!(v.count_bits(), 64);
    }

    #[test]
    fn notation_matches_paper() {
        assert_eq!(AbiVariant::A64O64.notation(), "A64O64");
        assert_eq!(AbiVariant::A32O64.notation(), "A32O64");
        assert_eq!(AbiVariant::A32O64.count_bits(), 64);
    }

    #[test]
    fn a64o128_not_standardized() {
        // §5.1: an A64O128 ABI is possible but deliberately not standardized.
        let v = AbiVariant { aint_bits: 64, offset_bits: 128 };
        assert!(!v.is_standardized());
        assert_eq!(v.count_bits(), 128);
    }
}
