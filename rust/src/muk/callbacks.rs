//! Callback trampolines.
//!
//! MPI callback signatures carry no user-data pointer (§3, item 4), so a
//! translation layer cannot hand the backend a closure: it must register
//! a plain function that (a) converts the backend-ABI arguments to
//! standard-ABI ones, and (b) finds the user's function *without any
//! context argument*. Mukautuva solves this with a pool of static
//! trampoline functions, each hard-wired (by its index) to a slot in a
//! registry. We reproduce that: [`POOL_SIZE`] monomorphic trampolines
//! per callback kind per backend, slot state in rank-local storage.

use std::cell::RefCell;

use crate::abi::handles::{AbiComm, AbiDatatype};
use crate::muk::convert::{comm_to_muk, dt_to_muk, ret_code, MukBackend};

/// Trampolines per callback kind. Exceeding this returns
/// `MPI_ERR_NO_MEM`-ish errors, as a real static pool would.
pub const POOL_SIZE: usize = 32;

/// User reduction callback in standard-ABI terms.
pub type MukOpFn = fn(*const u8, *mut u8, i32, AbiDatatype);
/// User error-handler callback in standard-ABI terms.
pub type MukErrhFn = fn(AbiComm, i32);
/// User attribute-copy callback in standard-ABI terms.
pub type MukCopyFn = fn(AbiComm, i32, usize, usize) -> (bool, usize);
/// User attribute-delete callback in standard-ABI terms.
pub type MukDeleteFn = fn(AbiComm, i32, usize, usize);

thread_local! {
    static OP_SLOTS: RefCell<[Option<MukOpFn>; POOL_SIZE]> = const { RefCell::new([None; POOL_SIZE]) };
    static ERRH_SLOTS: RefCell<[Option<MukErrhFn>; POOL_SIZE]> = const { RefCell::new([None; POOL_SIZE]) };
    static COPY_SLOTS: RefCell<[Option<MukCopyFn>; POOL_SIZE]> = const { RefCell::new([None; POOL_SIZE]) };
    static DELETE_SLOTS: RefCell<[Option<MukDeleteFn>; POOL_SIZE]> = const { RefCell::new([None; POOL_SIZE]) };
}

macro_rules! slot_ops {
    ($alloc:ident, $free:ident, $slots:ident, $t:ty) => {
        /// Claim a free trampoline slot for `f`; `None` if the pool is full.
        pub fn $alloc(f: $t) -> Option<usize> {
            $slots.with(|s| {
                let mut s = s.borrow_mut();
                for (i, slot) in s.iter_mut().enumerate() {
                    if slot.is_none() {
                        *slot = Some(f);
                        return Some(i);
                    }
                }
                None
            })
        }

        /// Release a slot.
        pub fn $free(i: usize) {
            $slots.with(|s| s.borrow_mut()[i] = None);
        }
    };
}

slot_ops!(alloc_op_slot, free_op_slot, OP_SLOTS, MukOpFn);
slot_ops!(alloc_errh_slot, free_errh_slot, ERRH_SLOTS, MukErrhFn);
slot_ops!(alloc_copy_slot, free_copy_slot, COPY_SLOTS, MukCopyFn);
slot_ops!(alloc_delete_slot, free_delete_slot, DELETE_SLOTS, MukDeleteFn);

// --- The trampolines ---------------------------------------------------------

fn op_tramp<A: MukBackend, const I: usize>(
    inv: *const u8,
    inout: *mut u8,
    len: i32,
    dt: A::Datatype,
) {
    let f = OP_SLOTS.with(|s| s.borrow()[I]).expect("op trampoline slot empty");
    f(inv, inout, len, AbiDatatype(dt_to_muk::<A>(dt)));
}

fn errh_tramp<A: MukBackend, const I: usize>(c: A::Comm, code: i32) {
    let f = ERRH_SLOTS.with(|s| s.borrow()[I]).expect("errh trampoline slot empty");
    f(AbiComm(comm_to_muk::<A>(c)), ret_code::<A>(code));
}

fn copy_tramp<A: MukBackend, const I: usize>(
    c: A::Comm,
    kv: i32,
    extra: usize,
    val: usize,
) -> (bool, usize) {
    let f = COPY_SLOTS.with(|s| s.borrow()[I]).expect("copy trampoline slot empty");
    f(AbiComm(comm_to_muk::<A>(c)), kv, extra, val)
}

fn delete_tramp<A: MukBackend, const I: usize>(c: A::Comm, kv: i32, extra: usize, val: usize) {
    let f = DELETE_SLOTS.with(|s| s.borrow()[I]).expect("delete trampoline slot empty");
    f(AbiComm(comm_to_muk::<A>(c)), kv, extra, val);
}

macro_rules! tramp_table {
    ($f:ident, $A:ident) => {
        [
            $f::<$A, 0>, $f::<$A, 1>, $f::<$A, 2>, $f::<$A, 3>, $f::<$A, 4>, $f::<$A, 5>,
            $f::<$A, 6>, $f::<$A, 7>, $f::<$A, 8>, $f::<$A, 9>, $f::<$A, 10>, $f::<$A, 11>,
            $f::<$A, 12>, $f::<$A, 13>, $f::<$A, 14>, $f::<$A, 15>, $f::<$A, 16>, $f::<$A, 17>,
            $f::<$A, 18>, $f::<$A, 19>, $f::<$A, 20>, $f::<$A, 21>, $f::<$A, 22>, $f::<$A, 23>,
            $f::<$A, 24>, $f::<$A, 25>, $f::<$A, 26>, $f::<$A, 27>, $f::<$A, 28>, $f::<$A, 29>,
            $f::<$A, 30>, $f::<$A, 31>,
        ]
    };
}

/// The static trampoline pools, monomorphized per backend.
pub fn op_tramp_pool<A: MukBackend>() -> [crate::api::UserOpFn<A>; POOL_SIZE] {
    tramp_table!(op_tramp, A)
}

/// The error-handler trampoline pool for backend `A`.
pub fn errh_tramp_pool<A: MukBackend>() -> [crate::api::ErrhFn<A>; POOL_SIZE] {
    tramp_table!(errh_tramp, A)
}

/// The attribute-copy trampoline pool for backend `A`.
pub fn copy_tramp_pool<A: MukBackend>() -> [crate::api::AttrCopyFn<A>; POOL_SIZE] {
    tramp_table!(copy_tramp, A)
}

/// The attribute-delete trampoline pool for backend `A`.
pub fn delete_tramp_pool<A: MukBackend>() -> [crate::api::AttrDeleteFn<A>; POOL_SIZE] {
    tramp_table!(delete_tramp, A)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_alloc_free_cycle() {
        fn f(_: *const u8, _: *mut u8, _: i32, _: AbiDatatype) {}
        let a = alloc_op_slot(f).unwrap();
        let b = alloc_op_slot(f).unwrap();
        assert_ne!(a, b);
        free_op_slot(a);
        let c = alloc_op_slot(f).unwrap();
        assert_eq!(c, a, "slots are reused");
        free_op_slot(b);
        free_op_slot(c);
    }

    #[test]
    fn pool_exhaustion_returns_none() {
        fn f(_: AbiComm, _: i32) {}
        let mut got = Vec::new();
        while let Some(i) = alloc_errh_slot(f) {
            got.push(i);
        }
        assert_eq!(got.len(), POOL_SIZE);
        for i in got {
            free_errh_slot(i);
        }
    }

    #[test]
    fn distinct_trampolines_per_slot() {
        use crate::impls::mpich::MpichAbi;
        let pool = op_tramp_pool::<MpichAbi>();
        // Each trampoline is a distinct function (distinct code address).
        let addrs: std::collections::HashSet<usize> =
            pool.iter().map(|&f| f as usize).collect();
        assert_eq!(addrs.len(), POOL_SIZE);
    }
}
