//! Quickstart: a complete MPI program against the **standard ABI**
//! (the proposal of §5), running on 4 simulated ranks.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mpi_abi::api::{Dt, MpiAbi, OpName};
use mpi_abi::launcher::{run_job_ok, JobSpec};
use mpi_abi::native_abi::NativeAbi;

// The application is written once against the portable surface; `A` is
// "which mpi.h we compiled against".
fn app<A: MpiAbi>(_rank: usize) -> Vec<String> {
    let mut log = Vec::new();
    A::init();

    let world = A::comm_world();
    let (mut size, mut rank) = (0, 0);
    A::comm_size(world, &mut size);
    A::comm_rank(world, &mut rank);
    log.push(format!("rank {rank}/{size} up — {}", A::get_library_version()));

    // Point-to-point: ring-pass a token.
    let dt = A::datatype(Dt::Int);
    let next = (rank + 1) % size;
    let prev = (rank + size - 1) % size;
    let token = [rank * 10];
    let mut got = [0i32];
    let mut st = A::status_empty();
    if rank == 0 {
        A::send(token.as_ptr() as *const u8, 1, dt, next, 7, world);
        A::recv(got.as_mut_ptr() as *mut u8, 1, dt, prev, 7, world, &mut st);
    } else {
        A::recv(got.as_mut_ptr() as *mut u8, 1, dt, prev, 7, world, &mut st);
        A::send(token.as_ptr() as *const u8, 1, dt, next, 7, world);
    }
    log.push(format!("rank {rank}: token {} from rank {}", got[0], A::status_source(&st)));

    // Collective: global sum.
    let contrib = [rank as f64 + 1.0];
    let mut total = [0.0f64];
    A::allreduce(
        contrib.as_ptr() as *const u8,
        total.as_mut_ptr() as *mut u8,
        1,
        A::datatype(Dt::Double),
        A::op(OpName::Sum),
        world,
    );
    log.push(format!("rank {rank}: allreduce total = {}", total[0]));

    A::finalize();
    log
}

fn main() {
    let outputs = run_job_ok(JobSpec::new(4), app::<NativeAbi>);
    for rank_log in outputs {
        for line in rank_log {
            println!("{line}");
        }
    }
}
