//! `abibench` — the perf-grid runner (`BENCH_PR5.json` /
//! `BENCH_PR6.json` / `BENCH_PR10.json`).
//!
//! ```text
//! cargo run --release --bin abibench -- [--smoke|--full] [--out PATH]
//! cargo run --release --bin abibench -- --check [--out PATH]
//! cargo run --release --bin abibench -- --bandwidth [--smoke|--full] [--out PATH]
//! cargo run --release --bin abibench -- --bandwidth --check [--out PATH]
//! cargo run --release --bin abibench -- --coll [--smoke|--full] [--out PATH]
//! cargo run --release --bin abibench -- --coll --check [--out PATH]
//! ```
//!
//! Default mode is `--smoke` (CI-sized); `--full` is the mode whose
//! numbers go into PR descriptions. `--check` validates an existing
//! file instead of running: every grid cell must be present with a
//! finite number (exit code 1 otherwise).
//!
//! `--bandwidth` switches from the PR-5 latency/msgrate grid to the
//! PR-6 bandwidth curve: an `osu_bw` analogue swept across message
//! sizes for every config × transport, once pinned to the eager
//! protocol and once pinned to rendezvous, so the artifact shows the
//! eager→rendezvous crossover.
//!
//! `--coll` switches to the PR-10 collective rank-scaling grid:
//! latency vs thread-rank count for every operation × algorithm
//! column × config × transport, with the schedule algorithm pinned per
//! job, so the artifact shows the auto selector on the Pareto frontier
//! of the forced columns.
//!
//! `--out` defaults to `BENCH_PR5.json` (`BENCH_PR6.json` with
//! `--bandwidth`, `BENCH_PR10.json` with `--coll`) **at the repo root**
//! (resolved from the crate manifest, not the cwd), so running from
//! `rust/` updates the committed artifact rather than leaving a stray
//! copy.

use mpi_abi::bench::harness::{
    bw_to_json, check_bw_json, check_coll_json, check_json, coll_to_json, run_bw_harness,
    run_coll_harness, run_harness, to_json, HarnessOpts, COLL_OPS, TRANSPORTS,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut smoke = true;
    let mut check = false;
    let mut bandwidth = false;
    let mut coll = false;
    let mut out: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--smoke" => smoke = true,
            "--full" => smoke = false,
            "--check" => check = true,
            "--bandwidth" => bandwidth = true,
            "--coll" => coll = true,
            "--out" => {
                i += 1;
                out = Some(args.get(i).cloned().unwrap_or_else(|| {
                    eprintln!("--out needs a path");
                    std::process::exit(2);
                }));
            }
            other => {
                eprintln!("unknown argument {other}");
                eprintln!(
                    "usage: abibench [--bandwidth|--coll] [--smoke|--full] [--out PATH] [--check]"
                );
                std::process::exit(2);
            }
        }
        i += 1;
    }
    if bandwidth && coll {
        eprintln!("--bandwidth and --coll are mutually exclusive");
        std::process::exit(2);
    }
    let out = out.unwrap_or_else(|| {
        if coll {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR10.json").to_string()
        } else if bandwidth {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR6.json").to_string()
        } else {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR5.json").to_string()
        }
    });

    if check {
        let doc = match std::fs::read_to_string(&out) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("abibench --check: cannot read {out}: {e}");
                std::process::exit(1);
            }
        };
        let missing = if coll {
            check_coll_json(&doc)
        } else if bandwidth {
            check_bw_json(&doc)
        } else {
            check_json(&doc)
        };
        if missing.is_empty() {
            println!("abibench --check: {out} complete (every grid cell present)");
            return;
        }
        eprintln!("abibench --check: {out} is missing {} cell(s):", missing.len());
        for m in &missing {
            eprintln!("  {m}");
        }
        std::process::exit(1);
    }

    if coll {
        let result = run_coll_harness(HarnessOpts { smoke });
        let doc = coll_to_json(&result);
        if let Err(e) = std::fs::write(&out, &doc) {
            eprintln!("abibench: cannot write {out}: {e}");
            std::process::exit(1);
        }
        // Headline: the selector vs the pre-PR-10 fixed algorithm at
        // the largest swept rank count, native standard-ABI build.
        for op in COLL_OPS {
            for transport in TRANSPORTS {
                if let Some(s) = result.auto_speedup(op, "abi", transport.name()) {
                    println!(
                        "coll {op:<9} {} abi @{}r: auto is {s:.2}x vs fixed baseline",
                        transport.name(),
                        result.ranks.last().unwrap()
                    );
                }
            }
        }
        println!("wrote {out} ({} mode, {} cells)", result.mode, result.cells.len());
        return;
    }

    if bandwidth {
        let result = run_bw_harness(HarnessOpts { smoke });
        let doc = bw_to_json(&result);
        if let Err(e) = std::fs::write(&out, &doc) {
            eprintln!("abibench: cannot write {out}: {e}");
            std::process::exit(1);
        }
        // Headline: where rendezvous starts winning on the native
        // standard-ABI build, fast transport.
        match result.crossover("abi", "spsc") {
            Some(x) => println!("bandwidth   spsc abi: rendezvous wins from {x} B up"),
            None => println!("bandwidth   spsc abi: eager won at every swept size"),
        }
        println!("wrote {out} ({} mode, {} cells)", result.mode, result.cells.len());
        return;
    }

    let result = run_harness(HarnessOpts { smoke });
    let doc = to_json(&result);
    if let Err(e) = std::fs::write(&out, &doc) {
        eprintln!("abibench: cannot write {out}: {e}");
        std::process::exit(1);
    }
    // Headline: the indexed matcher vs the flat baseline on the fast
    // transport (the ratio quoted in the PR description).
    for bench in ["latency_8b", "msgrate_8b"] {
        if let Some(s) = result.speedup(bench, "abi", "spsc") {
            println!("{bench:<12} spsc abi: indexed is {s:.2}x vs MPI_ABI_FLAT_MATCH=1");
        }
    }
    println!("wrote {out} ({} mode, {} cells)", result.mode, result.cells.len());
}
