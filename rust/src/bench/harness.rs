//! The reproducible perf harness behind `cargo run --release --bin
//! abibench`: every (bench, ABI config, transport) cell of the paper's
//! evaluation grid in one run, written to a machine-readable
//! `BENCH_PR5.json` at the repo root so future PRs regress against real
//! numbers instead of prose.
//!
//! Three benches:
//!
//! * `latency_8b` — `osu_latency` analogue, 8-byte one-way ns (E3);
//! * `msgrate_8b` — `osu_mbw_mr` analogue, ns per message at window 64
//!   (E2 / Table 1);
//! * `translation_type_size` — the §6.1 `MPI_Type_size` representation-
//!   decoding cost, per call (E1/E6's smallest translation unit).
//!
//! The two pt2pt benches are additionally run with the **flat-baseline
//! matcher** (`MPI_ABI_FLAT_MATCH=1` semantics, forced per job via
//! [`JobSpec::with_flat_match`]) so the indexed matching engine's win is
//! part of the artifact: `speedup_vs_flat` in the JSON is
//! baseline-ns / indexed-ns (> 1 means the index is faster).
//!
//! Two modes: `--smoke` (seconds; the CI `bench-smoke` job) and
//! `--full` (minutes; the numbers quoted in PR descriptions).
//!
//! A second grid, `--bandwidth`, sweeps an `osu_bw` analogue across
//! message sizes (8 B → 256 MiB in `--full`) for every config ×
//! transport, once with the protocol pinned to **eager**
//! (`rndv_threshold = usize::MAX`) and once pinned to **rendezvous**
//! (`rndv_threshold = 0`), so the committed `BENCH_PR6.json` shows the
//! eager→rendezvous crossover the default 64 KiB threshold sits on.
//!
//! A third grid, `--coll`, is the PR-10 rank-scaling sweep: collective
//! latency vs thread-rank count (4 → 256 in `--full`) for every
//! operation × algorithm column × config × transport, with the
//! algorithm pinned per job via [`JobSpec::with_coll_algo`]. The
//! committed `BENCH_PR10.json` shows the selector's `auto` column
//! sitting on the per-point Pareto frontier of the forced columns.

use crate::api::MpiAbi;
use crate::apps::osu::{
    bw, coll_latency, latency, mbw_mr, type_size_ns, BwParams, CollBench, CollParams,
    LatencyParams, MbwMrParams,
};
use crate::apps::{with_abi, AbiApp, AbiConfig};
use crate::core::collectives::{
    CollAlgoForce, ALLGATHER_GATHER_BCAST, ALLGATHER_RING, ALLREDUCE_BINOMIAL,
    ALLREDUCE_RABENSEIFNER, ALLREDUCE_RECURSIVE_DOUBLING, ALLREDUCE_RING, ALLTOALL_BRUCK,
    ALLTOALL_PAIRWISE,
};
use crate::core::transport::TransportKind;
use crate::launcher::{run_job_ok, JobSpec};

/// The benches the harness runs, in grid order.
pub const BENCHES: [&str; 3] = ["latency_8b", "msgrate_8b", "translation_type_size"];

/// The two transports of every grid.
pub const TRANSPORTS: [TransportKind; 2] = [TransportKind::Spsc, TransportKind::Mutex];

/// One measured cell of the grid.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Bench name (one of [`BENCHES`]).
    pub bench: &'static str,
    /// ABI configuration name ([`AbiConfig::name`]).
    pub config: &'static str,
    /// Transport name ([`TransportKind::name`]).
    pub transport: &'static str,
    /// Nanoseconds per event (one-way message, one message, one call).
    pub ns: f64,
}

/// Harness options (parsed by the `abibench` binary).
#[derive(Clone, Copy, Debug)]
pub struct HarnessOpts {
    /// Smoke mode: iteration counts small enough for CI.
    pub smoke: bool,
}

/// Iteration counts for one mode.
struct Sizing {
    lat_iters: usize,
    lat_warmup: usize,
    mbw_iters: usize,
    mbw_warmup: usize,
    ts_iters: usize,
    reps: usize,
}

impl Sizing {
    fn of(opts: HarnessOpts) -> Sizing {
        if opts.smoke {
            Sizing {
                lat_iters: 200,
                lat_warmup: 20,
                mbw_iters: 60,
                mbw_warmup: 10,
                ts_iters: 20_000,
                reps: 1,
            }
        } else {
            Sizing {
                lat_iters: 1000,
                lat_warmup: 100,
                mbw_iters: 1000,
                mbw_warmup: 100,
                ts_iters: 200_000,
                reps: 3,
            }
        }
    }
}

struct LatencyRun {
    transport: TransportKind,
    flat: bool,
    iters: usize,
    warmup: usize,
    reps: usize,
}

impl AbiApp<f64> for LatencyRun {
    fn run<A: MpiAbi>(self) -> f64 {
        let mut best = f64::MAX;
        for _ in 0..self.reps {
            let spec = JobSpec::new(2)
                .with_transport(self.transport)
                .with_flat_match(self.flat);
            let out = run_job_ok(spec, |_| {
                A::init();
                let r = latency::<A>(LatencyParams {
                    msg_size: 8,
                    iters: self.iters,
                    warmup: self.warmup,
                });
                A::finalize();
                r
            });
            best = best.min(out[0]);
        }
        best * 1e9
    }
}

struct MsgRateRun {
    transport: TransportKind,
    flat: bool,
    iters: usize,
    warmup: usize,
    reps: usize,
}

impl AbiApp<f64> for MsgRateRun {
    fn run<A: MpiAbi>(self) -> f64 {
        let mut best_rate = 0.0f64;
        for _ in 0..self.reps {
            let spec = JobSpec::new(2)
                .with_transport(self.transport)
                .with_flat_match(self.flat);
            let out = run_job_ok(spec, |_| {
                A::init();
                let r = mbw_mr::<A>(MbwMrParams {
                    msg_size: 8,
                    window: 64,
                    iters: self.iters,
                    warmup: self.warmup,
                });
                A::finalize();
                r
            });
            best_rate = best_rate.max(out[0]);
        }
        1e9 / best_rate // ns per message
    }
}

struct TypeSizeRun {
    iters: usize,
}

impl AbiApp<f64> for TypeSizeRun {
    fn run<A: MpiAbi>(self) -> f64 {
        type_size_ns::<A>(self.iters)
    }
}

fn measure(
    bench: &'static str,
    config: AbiConfig,
    transport: TransportKind,
    flat: bool,
    s: &Sizing,
) -> f64 {
    match bench {
        "latency_8b" => with_abi(
            config,
            LatencyRun {
                transport,
                flat,
                iters: s.lat_iters,
                warmup: s.lat_warmup,
                reps: s.reps,
            },
        ),
        "msgrate_8b" => with_abi(
            config,
            MsgRateRun {
                transport,
                flat,
                iters: s.mbw_iters,
                warmup: s.mbw_warmup,
                reps: s.reps,
            },
        ),
        "translation_type_size" => with_abi(config, TypeSizeRun { iters: s.ts_iters }),
        _ => unreachable!("unknown bench {bench}"),
    }
}

/// The full harness result: every indexed cell, the flat-baseline cells
/// of the two pt2pt benches, and the headline speedups.
pub struct HarnessResult {
    /// Mode the grid was run in (`"smoke"` / `"full"`).
    pub mode: &'static str,
    /// Indexed-matcher cells: every (bench, config, transport).
    pub cells: Vec<Cell>,
    /// Flat-baseline cells (`latency_8b` / `msgrate_8b` only).
    pub flat_baseline: Vec<Cell>,
    /// Rank-0 pvar snapshot from the scripted probe exchange
    /// ([`pvar_probe`]), embedded in the JSON `meta` block.
    pub probe_pvars: Vec<(&'static str, u64)>,
}

impl HarnessResult {
    /// baseline-ns / indexed-ns for a (bench, config, transport) — the
    /// indexed matcher's speedup (> 1 = faster than flat).
    pub fn speedup(&self, bench: &str, config: &str, transport: &str) -> Option<f64> {
        let pick = |cells: &[Cell]| {
            cells
                .iter()
                .find(|c| c.bench == bench && c.config == config && c.transport == transport)
                .map(|c| c.ns)
        };
        Some(pick(&self.flat_baseline)? / pick(&self.cells)?)
    }
}

/// Run the whole grid. Progress goes to stderr (one line per cell), so
/// redirecting stdout still yields a clean report.
pub fn run_harness(opts: HarnessOpts) -> HarnessResult {
    // Keep XLA client init out of message timings (as the benches do).
    std::env::set_var("MPI_ABI_NO_XLA", "1");
    let s = Sizing::of(opts);
    let mut cells = Vec::new();
    let mut flat_baseline = Vec::new();
    for bench in BENCHES {
        for config in AbiConfig::ALL {
            if bench == "translation_type_size" {
                // Transport-independent (no job runs): measure once per
                // config and publish the same value to both transport
                // cells so the grid stays rectangular without passing
                // re-measurement noise off as a transport effect.
                let ns = measure(bench, config, TRANSPORTS[0], false, &s);
                eprintln!("  [abibench] {bench:<22} {:<11} both  {ns:>12.1} ns", config.name());
                for transport in TRANSPORTS {
                    cells.push(Cell {
                        bench,
                        config: config.name(),
                        transport: transport.name(),
                        ns,
                    });
                }
                continue;
            }
            for transport in TRANSPORTS {
                let ns = measure(bench, config, transport, false, &s);
                eprintln!(
                    "  [abibench] {bench:<22} {:<11} {:<5} {:>12.1} ns",
                    config.name(),
                    transport.name(),
                    ns
                );
                cells.push(Cell {
                    bench,
                    config: config.name(),
                    transport: transport.name(),
                    ns,
                });
                let ns = measure(bench, config, transport, true, &s);
                eprintln!(
                    "  [abibench] {bench:<22} {:<11} {:<5} {:>12.1} ns  (flat baseline)",
                    config.name(),
                    transport.name(),
                    ns
                );
                flat_baseline.push(Cell {
                    bench,
                    config: config.name(),
                    transport: transport.name(),
                    ns,
                });
            }
        }
    }
    HarnessResult {
        mode: if opts.smoke { "smoke" } else { "full" },
        cells,
        flat_baseline,
        probe_pvars: pvar_probe(),
    }
}

/// A tiny deterministic 2-rank ping-pong whose rank-0 pvar snapshot
/// rides along in the BENCH json `meta` block — live proof the MPI_T
/// counters tick, committed next to the numbers they describe. Queue
/// depths and high-watermarks in the snapshot are timing-dependent;
/// the posted/byte counters are exact for the scripted exchange.
pub fn pvar_probe() -> Vec<(&'static str, u64)> {
    use crate::core::reserved::COMM_WORLD;
    use crate::core::{datatype, engine, obs};
    let out = run_job_ok(JobSpec::new(2), |rank| {
        engine::init().unwrap();
        let dt = datatype::builtin_id_of_abi(crate::abi::datatypes::MPI_BYTE).unwrap();
        let mut buf = [0u8; 8];
        let snap = if rank == 0 {
            engine::send(
                buf.as_ptr(),
                8,
                dt,
                1,
                7,
                COMM_WORLD,
                engine::SendMode::Standard,
            )
            .unwrap();
            engine::recv(buf.as_mut_ptr(), 8, dt, 1, 8, COMM_WORLD).unwrap();
            obs::pvar_snapshot()
        } else {
            engine::recv(buf.as_mut_ptr(), 8, dt, 0, 7, COMM_WORLD).unwrap();
            engine::send(
                buf.as_ptr(),
                8,
                dt,
                0,
                8,
                COMM_WORLD,
                engine::SendMode::Standard,
            )
            .unwrap();
            Vec::new()
        };
        engine::finalize().unwrap();
        snap
    });
    out.into_iter().next().unwrap_or_default()
}

/// The shared `meta` provenance block of both BENCH documents: what ran,
/// with which knobs, when, and the probe's pvar snapshot. `--check`
/// ignores it entirely — the needle-based validators only look inside
/// the cell arrays — so regenerated and committed documents can differ
/// here without failing CI.
fn meta_json(mode: &str, probe_pvars: &[(&'static str, u64)]) -> String {
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut m = String::new();
    m.push_str("  \"meta\": {\n");
    m.push_str(&format!("    \"mode\": \"{mode}\",\n"));
    m.push_str(&format!(
        "    \"transports\": [{}],\n",
        TRANSPORTS.map(|t| format!("\"{}\"", t.name())).join(", ")
    ));
    m.push_str(&format!(
        "    \"rndv_threshold_default\": {},\n",
        crate::core::world::RNDV_THRESHOLD_DEFAULT
    ));
    m.push_str(&format!("    \"timestamp_unix\": {ts},\n"));
    if probe_pvars.is_empty() {
        m.push_str("    \"probe_pvars\": {}\n");
    } else {
        m.push_str("    \"probe_pvars\": {\n");
        let pv: Vec<String> =
            probe_pvars.iter().map(|(n, v)| format!("      \"{n}\": {v}")).collect();
        m.push_str(&pv.join(",\n"));
        m.push_str("\n    }\n");
    }
    m.push_str("  },\n");
    m
}

fn json_cell(c: &Cell) -> String {
    format!(
        "    {{\"bench\": \"{}\", \"config\": \"{}\", \"transport\": \"{}\", \"ns\": {:.2}}}",
        c.bench, c.config, c.transport, c.ns
    )
}

/// Render the result as the `BENCH_PR5.json` document (hand-rolled:
/// serde is not in the offline crate set).
pub fn to_json(r: &HarnessResult) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": 1,\n");
    out.push_str("  \"pr\": 5,\n");
    out.push_str("  \"generated_by\": \"abibench\",\n");
    out.push_str(&format!("  \"mode\": \"{}\",\n", r.mode));
    out.push_str(&meta_json(r.mode, &r.probe_pvars));
    out.push_str(&format!(
        "  \"benches\": [{}],\n",
        BENCHES.map(|b| format!("\"{b}\"")).join(", ")
    ));
    out.push_str(&format!(
        "  \"configs\": [{}],\n",
        AbiConfig::ALL.map(|c| format!("\"{}\"", c.name())).join(", ")
    ));
    out.push_str(&format!(
        "  \"transports\": [{}],\n",
        TRANSPORTS.map(|t| format!("\"{}\"", t.name())).join(", ")
    ));
    out.push_str("  \"cells\": [\n");
    let lines: Vec<String> = r.cells.iter().map(json_cell).collect();
    out.push_str(&lines.join(",\n"));
    out.push_str("\n  ],\n");
    out.push_str("  \"flat_baseline\": [\n");
    let lines: Vec<String> = r.flat_baseline.iter().map(json_cell).collect();
    out.push_str(&lines.join(",\n"));
    out.push_str("\n  ],\n");
    out.push_str("  \"speedup_vs_flat\": {\n");
    let mut sp = Vec::new();
    for bench in ["latency_8b", "msgrate_8b"] {
        for transport in TRANSPORTS {
            // Headline: the native standard-ABI build (the paper's
            // "MPICH dev UCX ABI" row).
            if let Some(s) = r.speedup(bench, "abi", transport.name()) {
                sp.push(format!(
                    "    \"{}_{}\": {:.3}",
                    bench,
                    transport.name(),
                    s
                ));
            }
        }
    }
    out.push_str(&sp.join(",\n"));
    out.push_str("\n  }\n");
    out.push_str("}\n");
    out
}

/// Validate a previously written `BENCH_PR5.json`: every (bench,
/// config, transport) cell present **in the `cells` array** with a
/// numeric value, and every (pt2pt bench, config, transport) cell in
/// the `flat_baseline` array. Each grid is checked inside its own array
/// section so a cell present only in the *other* section cannot mask a
/// hole. Returns the list of missing cells (empty = complete). The CI
/// `bench-smoke` job runs this via `abibench --check` after
/// regenerating the file.
pub fn check_json(doc: &str) -> Vec<String> {
    let mut missing = Vec::new();
    let sections = (doc.find("\"cells\": ["), doc.find("\"flat_baseline\": ["));
    let (cells_sec, flat_sec) = match sections {
        (Some(c), Some(f)) if c < f => (&doc[c..f], &doc[f..]),
        _ => {
            missing.push("\"cells\" and \"flat_baseline\" arrays, in that order".to_string());
            return missing;
        }
    };
    check_grid(cells_sec, &BENCHES, "cells", &mut missing);
    check_grid(flat_sec, &["latency_8b", "msgrate_8b"], "flat_baseline", &mut missing);
    missing
}

/// Check one array section for every (bench, config, transport) cell.
fn check_grid(section: &str, benches: &[&str], label: &str, missing: &mut Vec<String>) {
    for &bench in benches {
        for config in AbiConfig::ALL {
            for transport in TRANSPORTS {
                let needle = format!(
                    "\"bench\": \"{}\", \"config\": \"{}\", \"transport\": \"{}\", \"ns\": ",
                    bench,
                    config.name(),
                    transport.name()
                );
                match section.find(&needle) {
                    Some(pos) => {
                        let rest = &section[pos + needle.len()..];
                        let num: String = rest
                            .chars()
                            .take_while(|c| c.is_ascii_digit() || *c == '.' || *c == '-')
                            .collect();
                        if num.parse::<f64>().map(|v| v.is_finite()).unwrap_or(false) {
                            continue;
                        }
                        missing.push(format!("{label}: {needle}<non-numeric>"));
                    }
                    None => missing.push(format!("{label}: {needle}")),
                }
            }
        }
    }
}

// --- Bandwidth curve (`--bandwidth`, BENCH_PR6.json) ---

/// The two protocol columns of the bandwidth grid: the same transfer
/// with the switch pinned to each side of the threshold.
pub const PROTOCOLS: [&str; 2] = ["eager", "rndv"];

/// Message sizes of the bandwidth sweep: 8 B × powers of 4, capped at
/// 512 KiB in smoke mode (still straddles the 64 KiB default threshold,
/// so CI sees the crossover) and 256 MiB in full mode.
pub fn bw_sizes(smoke: bool) -> Vec<usize> {
    let max = if smoke { 512 * 1024 } else { 256 * 1024 * 1024 };
    let mut v = vec![8usize];
    while *v.last().unwrap() < max {
        let next = v.last().unwrap() * 4;
        v.push(next.min(max));
    }
    v
}

/// One measured point of the bandwidth curve.
#[derive(Clone, Debug)]
pub struct BwCell {
    /// Message size in bytes.
    pub size: usize,
    /// ABI configuration name ([`AbiConfig::name`]).
    pub config: &'static str,
    /// Transport name ([`TransportKind::name`]).
    pub transport: &'static str,
    /// `"eager"` or `"rndv"` (one of [`PROTOCOLS`]).
    pub protocol: &'static str,
    /// Uni-directional bandwidth, MB/s (10^6 bytes per second).
    pub mb_s: f64,
}

/// The bandwidth-sweep result behind `BENCH_PR6.json`.
pub struct BwResult {
    /// Mode the sweep was run in (`"smoke"` / `"full"`).
    pub mode: &'static str,
    /// The sizes swept (ascending).
    pub sizes: Vec<usize>,
    /// Every (size, config, transport, protocol) point.
    pub cells: Vec<BwCell>,
    /// Rank-0 pvar snapshot from the scripted probe exchange
    /// ([`pvar_probe`]), embedded in the JSON `meta` block.
    pub probe_pvars: Vec<(&'static str, u64)>,
}

impl BwResult {
    fn mb_s(&self, size: usize, config: &str, transport: &str, protocol: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| {
                c.size == size
                    && c.config == config
                    && c.transport == transport
                    && c.protocol == protocol
            })
            .map(|c| c.mb_s)
    }

    /// Smallest swept size at which the rendezvous column meets or beats
    /// eager for this (config, transport) — the measured crossover the
    /// default `MPI_ABI_RNDV_THRESHOLD` should sit near. `None` if the
    /// rendezvous column never wins within the sweep.
    pub fn crossover(&self, config: &str, transport: &str) -> Option<usize> {
        self.sizes.iter().copied().find(|&s| {
            match (self.mb_s(s, config, transport, "rndv"), self.mb_s(s, config, transport, "eager"))
            {
                (Some(r), Some(e)) => r >= e,
                _ => false,
            }
        })
    }
}

/// One point of the sweep: best-of-`reps` bandwidth with the protocol
/// pinned via the job's rendezvous threshold.
struct BwRun {
    transport: TransportKind,
    msg_size: usize,
    rndv_threshold: usize,
    window: usize,
    iters: usize,
    warmup: usize,
    reps: usize,
}

impl AbiApp<f64> for BwRun {
    fn run<A: MpiAbi>(self) -> f64 {
        let mut best = 0.0f64;
        for _ in 0..self.reps {
            let spec = JobSpec::new(2)
                .with_transport(self.transport)
                .with_rndv_threshold(self.rndv_threshold);
            let out = run_job_ok(spec, |_| {
                A::init();
                let r = bw::<A>(BwParams {
                    msg_size: self.msg_size,
                    window: self.window,
                    iters: self.iters,
                    warmup: self.warmup,
                });
                A::finalize();
                r
            });
            best = best.max(out[0]);
        }
        best / 1e6 // bytes/s -> MB/s
    }
}

/// Per-size iteration shaping: bound both the resident window
/// (`window × size`) and the total bytes moved per measurement so the
/// 256 MiB points do not dominate wall-clock or memory.
fn bw_shape(size: usize, smoke: bool) -> (usize, usize, usize) {
    let window_cap_bytes = 4 << 20; // 4 MiB of posted sends at once
    let window = (window_cap_bytes / size).clamp(1, 64);
    let target_bytes = if smoke { 8 << 20 } else { 512 << 20 };
    let iters = (target_bytes / (size * window)).clamp(2, if smoke { 200 } else { 2000 });
    let warmup = (iters / 10).max(1);
    (window, iters, warmup)
}

/// Run the bandwidth sweep. Progress goes to stderr, one line per
/// (size, config, transport) pair showing both protocol columns.
pub fn run_bw_harness(opts: HarnessOpts) -> BwResult {
    std::env::set_var("MPI_ABI_NO_XLA", "1");
    let sizes = bw_sizes(opts.smoke);
    let reps = if opts.smoke { 1 } else { 3 };
    let mut cells = Vec::new();
    for &size in &sizes {
        let (window, iters, warmup) = bw_shape(size, opts.smoke);
        for config in AbiConfig::ALL {
            for transport in TRANSPORTS {
                let mut row = [0.0f64; 2];
                for (pi, protocol) in PROTOCOLS.into_iter().enumerate() {
                    // Pin the protocol: eager = threshold no send can
                    // exceed; rndv = threshold every nonempty send
                    // exceeds.
                    let threshold = if protocol == "eager" { usize::MAX } else { 0 };
                    let mb_s = with_abi(
                        config,
                        BwRun {
                            transport,
                            msg_size: size,
                            rndv_threshold: threshold,
                            window,
                            iters,
                            warmup,
                            reps,
                        },
                    );
                    row[pi] = mb_s;
                    cells.push(BwCell {
                        size,
                        config: config.name(),
                        transport: transport.name(),
                        protocol,
                        mb_s,
                    });
                }
                eprintln!(
                    "  [abibench] bw {size:>10} B  {:<11} {:<5} eager {:>10.1} MB/s  rndv {:>10.1} MB/s",
                    config.name(),
                    transport.name(),
                    row[0],
                    row[1],
                );
            }
        }
    }
    BwResult {
        mode: if opts.smoke { "smoke" } else { "full" },
        sizes,
        cells,
        probe_pvars: pvar_probe(),
    }
}

fn bw_json_cell(c: &BwCell) -> String {
    format!(
        "    {{\"size\": {}, \"config\": \"{}\", \"transport\": \"{}\", \"protocol\": \"{}\", \"mb_s\": {:.2}}}",
        c.size, c.config, c.transport, c.protocol, c.mb_s
    )
}

/// Render the sweep as the `BENCH_PR6.json` document.
pub fn bw_to_json(r: &BwResult) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": 1,\n");
    out.push_str("  \"pr\": 6,\n");
    out.push_str("  \"generated_by\": \"abibench --bandwidth\",\n");
    out.push_str(&format!("  \"mode\": \"{}\",\n", r.mode));
    out.push_str(&meta_json(r.mode, &r.probe_pvars));
    out.push_str(&format!(
        "  \"rndv_threshold_default\": {},\n",
        crate::core::world::RNDV_THRESHOLD_DEFAULT
    ));
    out.push_str(&format!(
        "  \"msg_sizes\": [{}],\n",
        r.sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(", ")
    ));
    out.push_str(&format!(
        "  \"configs\": [{}],\n",
        AbiConfig::ALL.map(|c| format!("\"{}\"", c.name())).join(", ")
    ));
    out.push_str(&format!(
        "  \"transports\": [{}],\n",
        TRANSPORTS.map(|t| format!("\"{}\"", t.name())).join(", ")
    ));
    out.push_str(&format!(
        "  \"protocols\": [{}],\n",
        PROTOCOLS.map(|p| format!("\"{p}\"")).join(", ")
    ));
    out.push_str("  \"cells\": [\n");
    let lines: Vec<String> = r.cells.iter().map(bw_json_cell).collect();
    out.push_str(&lines.join(",\n"));
    out.push_str("\n  ],\n");
    out.push_str("  \"crossover_bytes\": {\n");
    let mut xs = Vec::new();
    for config in AbiConfig::ALL {
        for transport in TRANSPORTS {
            let x = r
                .crossover(config.name(), transport.name())
                .map(|s| s.to_string())
                .unwrap_or_else(|| "null".to_string());
            xs.push(format!("    \"{}_{}\": {}", config.name(), transport.name(), x));
        }
    }
    out.push_str(&xs.join(",\n"));
    out.push_str("\n  }\n");
    out.push_str("}\n");
    out
}

/// Validate a previously written `BENCH_PR6.json`: the `msg_sizes`
/// array is read back from the document itself, then every
/// (size, config, transport, protocol) cell must be present with a
/// finite bandwidth. The CI `bench-bandwidth` job runs this via
/// `abibench --bandwidth --check` after regenerating the file.
pub fn check_bw_json(doc: &str) -> Vec<String> {
    let mut missing = Vec::new();
    let sizes: Vec<usize> = match doc.find("\"msg_sizes\": [") {
        Some(p) => {
            let rest = &doc[p + "\"msg_sizes\": [".len()..];
            match rest.find(']') {
                Some(end) => rest[..end]
                    .split(',')
                    .filter_map(|s| s.trim().parse::<usize>().ok())
                    .collect(),
                None => Vec::new(),
            }
        }
        None => Vec::new(),
    };
    if sizes.is_empty() {
        missing.push("\"msg_sizes\" array with at least one size".to_string());
        return missing;
    }
    for &size in &sizes {
        for config in AbiConfig::ALL {
            for transport in TRANSPORTS {
                for protocol in PROTOCOLS {
                    let needle = format!(
                        "\"size\": {}, \"config\": \"{}\", \"transport\": \"{}\", \"protocol\": \"{}\", \"mb_s\": ",
                        size,
                        config.name(),
                        transport.name(),
                        protocol
                    );
                    match doc.find(&needle) {
                        Some(pos) => {
                            let rest = &doc[pos + needle.len()..];
                            let num: String = rest
                                .chars()
                                .take_while(|c| c.is_ascii_digit() || *c == '.' || *c == '-')
                                .collect();
                            if num.parse::<f64>().map(|v| v.is_finite()).unwrap_or(false) {
                                continue;
                            }
                            missing.push(format!("{needle}<non-numeric>"));
                        }
                        None => missing.push(needle),
                    }
                }
            }
        }
    }
    missing
}

// --- Collective scaling grid (`--coll`, BENCH_PR10.json) ---

/// The collective operations of the scaling grid, in grid order.
pub const COLL_OPS: [&str; 4] = ["barrier", "allreduce", "allgather", "alltoall"];

/// Thread-rank counts of the scaling sweep. Smoke mode stops at 16 so
/// the CI `coll-scaling` job stays inside a small container; the
/// committed artifact is generated with `--full` and carries the whole
/// 4 → 256 curve.
pub fn coll_ranks(smoke: bool) -> Vec<usize> {
    if smoke {
        vec![4, 16]
    } else {
        vec![4, 16, 64, 256]
    }
}

/// Payload sizes of the sweep (bytes; allreduce: full vector,
/// allgather/alltoall: per-peer block). Full mode carries both regimes:
/// 64 B, where the latency-bound algorithms (recursive doubling, Bruck)
/// earn their keep, and 16 KiB, where the bandwidth-bound ones
/// (Rabenseifner, ring) do — no single size shows both, because
/// pairwise alltoall is already bandwidth-optimal at large blocks.
/// Smoke keeps one mid-size point so CI stays cheap.
pub fn coll_msg_sizes(smoke: bool) -> Vec<usize> {
    if smoke {
        vec![256]
    } else {
        vec![64, 16 * 1024]
    }
}

/// Algorithm columns per operation, `"auto"` (the tuning-table
/// selector) always first. Barrier has a single dissemination schedule,
/// so its only column is the selector itself.
pub fn coll_algos(op: &str) -> &'static [&'static str] {
    match op {
        "allreduce" => &["auto", "binomial", "ring", "recursive_doubling", "rabenseifner"],
        "allgather" => &["auto", "gather_bcast", "ring"],
        "alltoall" => &["auto", "pairwise", "bruck"],
        "barrier" => &["auto"],
        _ => &[],
    }
}

/// The forced-baseline column per operation (the pre-PR-10 fixed
/// algorithm the selector must beat at scale).
pub fn coll_baseline(op: &str) -> Option<&'static str> {
    match op {
        "allreduce" => Some("binomial"),
        "allgather" => Some("gather_bcast"),
        "alltoall" => Some("pairwise"),
        _ => None,
    }
}

/// Translate an (op, algorithm-column) pair into the per-job force
/// word. `"auto"` leaves every field 0 = tuning table.
pub fn coll_force(op: &str, algo: &str) -> CollAlgoForce {
    let mut f = CollAlgoForce::default();
    match (op, algo) {
        (_, "auto") => {}
        ("allreduce", "binomial") => f.allreduce = ALLREDUCE_BINOMIAL,
        ("allreduce", "ring") => f.allreduce = ALLREDUCE_RING,
        ("allreduce", "recursive_doubling") => f.allreduce = ALLREDUCE_RECURSIVE_DOUBLING,
        ("allreduce", "rabenseifner") => f.allreduce = ALLREDUCE_RABENSEIFNER,
        ("allgather", "gather_bcast") => f.allgather = ALLGATHER_GATHER_BCAST,
        ("allgather", "ring") => f.allgather = ALLGATHER_RING,
        ("alltoall", "pairwise") => f.alltoall = ALLTOALL_PAIRWISE,
        ("alltoall", "bruck") => f.alltoall = ALLTOALL_BRUCK,
        _ => unreachable!("unknown coll column {op}/{algo}"),
    }
    f
}

/// One measured point of the scaling grid.
#[derive(Clone, Debug)]
pub struct CollCell {
    /// Operation name (one of [`COLL_OPS`]).
    pub op: &'static str,
    /// Algorithm column (one of [`coll_algos`]`(op)`).
    pub algo: &'static str,
    /// Thread-rank count of the job.
    pub ranks: usize,
    /// Payload bytes (one of [`coll_msg_sizes`]; ignored by barrier,
    /// which is measured once and published to every size cell).
    pub msg: usize,
    /// ABI configuration name ([`AbiConfig::name`]).
    pub config: &'static str,
    /// Transport name ([`TransportKind::name`]).
    pub transport: &'static str,
    /// Nanoseconds per collective call.
    pub ns: f64,
}

/// The scaling-sweep result behind `BENCH_PR10.json`.
pub struct CollResult {
    /// Mode the sweep was run in (`"smoke"` / `"full"`).
    pub mode: &'static str,
    /// Rank counts swept (ascending).
    pub ranks: Vec<usize>,
    /// Payload sizes swept (ascending).
    pub sizes: Vec<usize>,
    /// Every (op, algo, ranks, config, transport) point.
    pub cells: Vec<CollCell>,
    /// Rank-0 pvar snapshot from the scripted probe exchange
    /// ([`pvar_probe`]), embedded in the JSON `meta` block.
    pub probe_pvars: Vec<(&'static str, u64)>,
}

impl CollResult {
    /// Latency of one grid point, if present.
    pub fn ns(
        &self,
        op: &str,
        algo: &str,
        ranks: usize,
        msg: usize,
        config: &str,
        transport: &str,
    ) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| {
                c.op == op
                    && c.algo == algo
                    && c.ranks == ranks
                    && c.msg == msg
                    && c.config == config
                    && c.transport == transport
            })
            .map(|c| c.ns)
    }

    /// Best baseline-ns / auto-ns across payload sizes at the largest
    /// swept rank count — the selector's speedup over the pre-PR-10
    /// fixed algorithm in whichever regime favors it most (> 1 = the
    /// tuning table picked a better schedule at scale).
    pub fn auto_speedup(&self, op: &str, config: &str, transport: &str) -> Option<f64> {
        let base = coll_baseline(op)?;
        let &top = self.ranks.last()?;
        self.sizes
            .iter()
            .filter_map(|&msg| {
                Some(
                    self.ns(op, base, top, msg, config, transport)?
                        / self.ns(op, "auto", top, msg, config, transport)?,
                )
            })
            .fold(None, |acc: Option<f64>, s| Some(acc.map_or(s, |a| a.max(s))))
    }
}

/// One point of the sweep: best-of-`reps` latency with the algorithm
/// pinned via the job's force word.
struct CollRun {
    transport: TransportKind,
    ranks: usize,
    bench: CollBench,
    force: CollAlgoForce,
    msg_size: usize,
    iters: usize,
    warmup: usize,
    reps: usize,
}

impl AbiApp<f64> for CollRun {
    fn run<A: MpiAbi>(self) -> f64 {
        let mut best = f64::MAX;
        for _ in 0..self.reps {
            let spec = JobSpec::new(self.ranks)
                .with_transport(self.transport)
                .with_coll_algo(self.force);
            let out = run_job_ok(spec, |_| {
                A::init();
                let r = coll_latency::<A>(CollParams {
                    bench: self.bench,
                    msg_size: self.msg_size,
                    iters: self.iters,
                    warmup: self.warmup,
                });
                A::finalize();
                r
            });
            best = best.min(out[0]);
        }
        best * 1e9
    }
}

/// Per-rank-count iteration shaping: big jobs run fewer timed calls so
/// the 256-rank alltoall points don't dominate wall-clock.
fn coll_shape(ranks: usize, smoke: bool) -> (usize, usize, usize) {
    let iters = if smoke { 20 } else { (2000 / ranks).clamp(20, 200) };
    let warmup = (iters / 5).max(2);
    let reps = if smoke { 1 } else { 3 };
    (iters, warmup, reps)
}

/// Run the scaling sweep. Progress goes to stderr, one line per grid
/// point.
pub fn run_coll_harness(opts: HarnessOpts) -> CollResult {
    std::env::set_var("MPI_ABI_NO_XLA", "1");
    let ranks_axis = coll_ranks(opts.smoke);
    let sizes = coll_msg_sizes(opts.smoke);
    let mut cells = Vec::new();
    for op in COLL_OPS {
        let bench = CollBench::parse(op).expect("COLL_OPS entries parse");
        for &ranks in &ranks_axis {
            let (iters, warmup, reps) = coll_shape(ranks, opts.smoke);
            for &algo in coll_algos(op) {
                for config in AbiConfig::ALL {
                    for transport in TRANSPORTS {
                        // Barrier moves no payload: measure once and
                        // publish the same value to every size cell so
                        // the grid stays rectangular without passing
                        // re-measurement noise off as a size effect.
                        let mut once: Option<f64> = None;
                        for &msg in &sizes {
                            let ns = match (op, once) {
                                ("barrier", Some(ns)) => ns,
                                _ => {
                                    let ns = with_abi(
                                        config,
                                        CollRun {
                                            transport,
                                            ranks,
                                            bench,
                                            force: coll_force(op, algo),
                                            msg_size: msg,
                                            iters,
                                            warmup,
                                            reps,
                                        },
                                    );
                                    eprintln!(
                                        "  [abibench] coll {op:<9} {algo:<18} {ranks:>3}r {msg:>6} B {:<11} {:<5} {ns:>14.1} ns",
                                        config.name(),
                                        transport.name(),
                                    );
                                    once = Some(ns);
                                    ns
                                }
                            };
                            cells.push(CollCell {
                                op,
                                algo,
                                ranks,
                                msg,
                                config: config.name(),
                                transport: transport.name(),
                                ns,
                            });
                        }
                    }
                }
            }
        }
    }
    CollResult {
        mode: if opts.smoke { "smoke" } else { "full" },
        ranks: ranks_axis,
        sizes,
        cells,
        probe_pvars: pvar_probe(),
    }
}

fn coll_json_cell(c: &CollCell) -> String {
    format!(
        "    {{\"op\": \"{}\", \"algo\": \"{}\", \"ranks\": {}, \"msg\": {}, \"config\": \"{}\", \"transport\": \"{}\", \"ns\": {:.1}}}",
        c.op, c.algo, c.ranks, c.msg, c.config, c.transport, c.ns
    )
}

/// Render the sweep as the `BENCH_PR10.json` document.
pub fn coll_to_json(r: &CollResult) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": 1,\n");
    out.push_str("  \"pr\": 10,\n");
    out.push_str("  \"generated_by\": \"abibench --coll\",\n");
    out.push_str(&format!("  \"mode\": \"{}\",\n", r.mode));
    out.push_str(&meta_json(r.mode, &r.probe_pvars));
    out.push_str(&format!(
        "  \"coll_msg_sizes\": [{}],\n",
        r.sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(", ")
    ));
    out.push_str(&format!(
        "  \"coll_ranks\": [{}],\n",
        r.ranks.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(", ")
    ));
    out.push_str(&format!(
        "  \"coll_ops\": [{}],\n",
        COLL_OPS.map(|o| format!("\"{o}\"")).join(", ")
    ));
    out.push_str("  \"coll_algos\": {\n");
    let algos: Vec<String> = COLL_OPS
        .iter()
        .map(|&op| {
            format!(
                "    \"{op}\": [{}]",
                coll_algos(op).iter().map(|a| format!("\"{a}\"")).collect::<Vec<_>>().join(", ")
            )
        })
        .collect();
    out.push_str(&algos.join(",\n"));
    out.push_str("\n  },\n");
    out.push_str(&format!(
        "  \"configs\": [{}],\n",
        AbiConfig::ALL.map(|c| format!("\"{}\"", c.name())).join(", ")
    ));
    out.push_str(&format!(
        "  \"transports\": [{}],\n",
        TRANSPORTS.map(|t| format!("\"{}\"", t.name())).join(", ")
    ));
    out.push_str("  \"cells\": [\n");
    let lines: Vec<String> = r.cells.iter().map(coll_json_cell).collect();
    out.push_str(&lines.join(",\n"));
    out.push_str("\n  ],\n");
    out.push_str("  \"auto_speedup_vs_baseline_at_max_ranks\": {\n");
    let mut sp = Vec::new();
    for op in COLL_OPS {
        if coll_baseline(op).is_none() {
            continue;
        }
        for transport in TRANSPORTS {
            // Headline: the native standard-ABI build.
            if let Some(s) = r.auto_speedup(op, "abi", transport.name()) {
                sp.push(format!("    \"{}_{}\": {:.3}", op, transport.name(), s));
            }
        }
    }
    out.push_str(&sp.join(",\n"));
    out.push_str("\n  }\n");
    out.push_str("}\n");
    out
}

/// Validate a previously written `BENCH_PR10.json`: the `coll_ranks`
/// array is read back from the document itself, then every (op, algo,
/// ranks, config, transport) cell must be present with a finite
/// latency. The CI `coll-scaling` job runs this via `abibench --coll
/// --check` against the committed artifact.
pub fn check_coll_json(doc: &str) -> Vec<String> {
    let mut missing = Vec::new();
    fn usize_list(doc: &str, key: &str) -> Vec<usize> {
        let head = format!("\"{key}\": [");
        match doc.find(&head) {
            Some(p) => {
                let rest = &doc[p + head.len()..];
                match rest.find(']') {
                    Some(end) => rest[..end]
                        .split(',')
                        .filter_map(|s| s.trim().parse::<usize>().ok())
                        .collect(),
                    None => Vec::new(),
                }
            }
            None => Vec::new(),
        }
    }
    let ranks = usize_list(doc, "coll_ranks");
    let sizes = usize_list(doc, "coll_msg_sizes");
    if ranks.is_empty() {
        missing.push("\"coll_ranks\" array with at least one rank count".to_string());
        return missing;
    }
    if sizes.is_empty() {
        missing.push("\"coll_msg_sizes\" array with at least one size".to_string());
        return missing;
    }
    for op in COLL_OPS {
        for &algo in coll_algos(op) {
            for &ranks in &ranks {
                for &msg in &sizes {
                    for config in AbiConfig::ALL {
                        for transport in TRANSPORTS {
                            let needle = format!(
                                "\"op\": \"{}\", \"algo\": \"{}\", \"ranks\": {}, \"msg\": {}, \"config\": \"{}\", \"transport\": \"{}\", \"ns\": ",
                                op,
                                algo,
                                ranks,
                                msg,
                                config.name(),
                                transport.name()
                            );
                            match doc.find(&needle) {
                                Some(pos) => {
                                    let rest = &doc[pos + needle.len()..];
                                    let num: String = rest
                                        .chars()
                                        .take_while(|c| {
                                            c.is_ascii_digit() || *c == '.' || *c == '-'
                                        })
                                        .collect();
                                    if num.parse::<f64>().map(|v| v.is_finite()).unwrap_or(false)
                                    {
                                        continue;
                                    }
                                    missing.push(format!("{needle}<non-numeric>"));
                                }
                                None => missing.push(needle),
                            }
                        }
                    }
                }
            }
        }
    }
    missing
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_result() -> HarnessResult {
        let mut cells = Vec::new();
        let mut flat = Vec::new();
        for bench in BENCHES {
            for config in AbiConfig::ALL {
                for transport in TRANSPORTS {
                    cells.push(Cell {
                        bench,
                        config: config.name(),
                        transport: transport.name(),
                        ns: 100.0,
                    });
                    if bench != "translation_type_size" {
                        flat.push(Cell {
                            bench,
                            config: config.name(),
                            transport: transport.name(),
                            ns: 150.0,
                        });
                    }
                }
            }
        }
        HarnessResult { mode: "smoke", cells, flat_baseline: flat, probe_pvars: Vec::new() }
    }

    #[test]
    fn json_roundtrips_the_completeness_check() {
        let doc = to_json(&fake_result());
        assert!(check_json(&doc).is_empty(), "generated JSON must be complete");
    }

    #[test]
    fn check_flags_missing_cells() {
        let doc = to_json(&fake_result());
        // Break only the first occurrence — the `cells` array entry; its
        // flat_baseline twin must NOT mask the hole.
        let broken = doc.replacen(
            "\"bench\": \"latency_8b\", \"config\": \"mpich\", \"transport\": \"spsc\"",
            "\"bench\": \"gone\", \"config\": \"mpich\", \"transport\": \"spsc\"",
            1,
        );
        let missing = check_json(&broken);
        assert_eq!(missing.len(), 1, "{missing:?}");
        assert!(missing[0].starts_with("cells: "), "{missing:?}");
    }

    #[test]
    fn check_validates_flat_baseline_section_too() {
        let doc = to_json(&fake_result());
        // Remove the flat_baseline array entirely: structural failure.
        let broken = doc.replace("\"flat_baseline\": [", "\"flat_gone\": [");
        assert!(!check_json(&broken).is_empty());
        // Break one flat cell (second occurrence of the needle).
        let pos = doc.rfind("\"bench\": \"msgrate_8b\", \"config\": \"abi\"").unwrap();
        let broken = format!("{}{}", &doc[..pos], doc[pos..].replacen("msgrate_8b", "gone", 1));
        let missing = check_json(&broken);
        assert_eq!(missing.len(), 1, "{missing:?}");
        assert!(missing[0].starts_with("flat_baseline: "), "{missing:?}");
    }

    #[test]
    fn speedup_is_baseline_over_indexed() {
        let r = fake_result();
        let s = r.speedup("latency_8b", "abi", "spsc").unwrap();
        assert!((s - 1.5).abs() < 1e-9);
    }

    #[test]
    fn smoke_grid_sizing_is_small() {
        let s = Sizing::of(HarnessOpts { smoke: true });
        assert!(s.lat_iters <= 1000 && s.reps == 1);
    }

    fn fake_bw_result(smoke: bool) -> BwResult {
        let sizes = bw_sizes(smoke);
        let mut cells = Vec::new();
        for &size in &sizes {
            for config in AbiConfig::ALL {
                for transport in TRANSPORTS {
                    for protocol in PROTOCOLS {
                        // Synthetic curve: eager flat, rendezvous wins
                        // from 128 KiB up.
                        let mb_s = if protocol == "rndv" && size >= 128 * 1024 {
                            2000.0
                        } else if protocol == "rndv" {
                            500.0
                        } else {
                            1000.0
                        };
                        cells.push(BwCell {
                            size,
                            config: config.name(),
                            transport: transport.name(),
                            protocol,
                            mb_s,
                        });
                    }
                }
            }
        }
        BwResult {
            mode: if smoke { "smoke" } else { "full" },
            sizes,
            cells,
            probe_pvars: Vec::new(),
        }
    }

    #[test]
    fn bw_sizes_span_the_threshold() {
        for smoke in [true, false] {
            let s = bw_sizes(smoke);
            assert_eq!(s[0], 8);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "ascending: {s:?}");
            // Both modes must straddle the default 64 KiB threshold.
            assert!(s.iter().any(|&x| x < crate::core::world::RNDV_THRESHOLD_DEFAULT));
            assert!(s.iter().any(|&x| x > crate::core::world::RNDV_THRESHOLD_DEFAULT));
        }
        assert_eq!(*bw_sizes(true).last().unwrap(), 512 * 1024);
        assert_eq!(*bw_sizes(false).last().unwrap(), 256 * 1024 * 1024);
    }

    #[test]
    fn bw_shape_bounds_resident_window() {
        for &size in &bw_sizes(false) {
            let (window, iters, warmup) = bw_shape(size, true);
            assert!(window >= 1 && iters >= 2 && warmup >= 1);
            // Never more than ~4 MiB of posted sends, except a single
            // message that is itself larger.
            assert!(window == 1 || window * size <= 4 << 20, "size {size} window {window}");
        }
    }

    #[test]
    fn bw_json_roundtrips_the_completeness_check() {
        for smoke in [true, false] {
            let doc = bw_to_json(&fake_bw_result(smoke));
            assert!(check_bw_json(&doc).is_empty(), "generated bandwidth JSON must be complete");
        }
    }

    #[test]
    fn bw_check_flags_missing_cells() {
        let doc = bw_to_json(&fake_bw_result(true));
        let broken = doc.replacen("\"protocol\": \"rndv\"", "\"protocol\": \"gone\"", 1);
        assert_eq!(check_bw_json(&broken).len(), 1);
        assert!(check_bw_json("{}").len() == 1, "missing msg_sizes is structural");
    }

    #[test]
    fn bw_crossover_finds_first_rndv_win() {
        let r = fake_bw_result(true);
        assert_eq!(r.crossover("abi", "spsc"), Some(128 * 1024));
        assert_eq!(r.crossover("nope", "spsc"), None);
    }

    fn fake_coll_result(smoke: bool) -> CollResult {
        let ranks = coll_ranks(smoke);
        let sizes = coll_msg_sizes(smoke);
        let mut cells = Vec::new();
        for op in COLL_OPS {
            for &algo in coll_algos(op) {
                for &r in &ranks {
                    for &msg in &sizes {
                        for config in AbiConfig::ALL {
                            for transport in TRANSPORTS {
                                // Synthetic curves: auto tracks the best
                                // forced column, the baseline grows
                                // fastest.
                                let ns = match algo {
                                    "auto" => 100.0 * r as f64,
                                    a if Some(a) == coll_baseline(op) => 250.0 * r as f64,
                                    _ => 150.0 * r as f64,
                                };
                                cells.push(CollCell {
                                    op,
                                    algo,
                                    ranks: r,
                                    msg,
                                    config: config.name(),
                                    transport: transport.name(),
                                    ns,
                                });
                            }
                        }
                    }
                }
            }
        }
        CollResult {
            mode: if smoke { "smoke" } else { "full" },
            ranks,
            sizes,
            cells,
            probe_pvars: Vec::new(),
        }
    }

    #[test]
    fn coll_ranks_scale_to_256_in_full_mode() {
        assert_eq!(coll_ranks(true), vec![4, 16]);
        assert_eq!(coll_ranks(false), vec![4, 16, 64, 256]);
    }

    #[test]
    fn coll_force_pins_exactly_one_op() {
        let f = coll_force("allreduce", "rabenseifner");
        assert_eq!(f.allreduce, ALLREDUCE_RABENSEIFNER);
        assert_eq!((f.allgather, f.alltoall), (0, 0));
        assert_eq!(coll_force("alltoall", "bruck").alltoall, ALLTOALL_BRUCK);
        assert_eq!(coll_force("barrier", "auto"), CollAlgoForce::default());
    }

    #[test]
    fn coll_json_roundtrips_the_completeness_check() {
        for smoke in [true, false] {
            let doc = coll_to_json(&fake_coll_result(smoke));
            assert!(check_coll_json(&doc).is_empty(), "generated coll JSON must be complete");
        }
    }

    #[test]
    fn coll_check_flags_missing_cells() {
        let doc = coll_to_json(&fake_coll_result(true));
        let broken = doc.replacen("\"algo\": \"rabenseifner\"", "\"algo\": \"gone\"", 1);
        assert_eq!(check_coll_json(&broken).len(), 1);
        assert_eq!(check_coll_json("{}").len(), 1, "missing coll_ranks is structural");
    }

    #[test]
    fn coll_auto_speedup_is_baseline_over_auto() {
        let r = fake_coll_result(false);
        let s = r.auto_speedup("allreduce", "abi", "spsc").unwrap();
        assert!((s - 2.5).abs() < 1e-9, "{s}");
        assert!(r.auto_speedup("barrier", "abi", "spsc").is_none());
    }
}
