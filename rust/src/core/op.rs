//! Reduction operations.
//!
//! Builtin ops are applied with typed scalar loops; large contiguous f32/f64
//! SUM/PROD/MIN/MAX buffers are offloaded to the AOT-compiled XLA
//! executable (the Pallas kernel lowered by `python/compile/aot.py`) via
//! [`crate::runtime::try_xla_reduce`] when the runtime is enabled.
//!
//! User-defined ops are closures installed by an ABI layer; the closure
//! receives raw buffers plus the engine datatype id and converts to the
//! registering ABI's representation before calling the user function —
//! the callback-translation problem of §6.2 in miniature.

use super::datatype::{scalar_kind, ScalarKind};
use super::slab::Slab;
use super::world::with_ctx;
use super::{err, DtId, OpId, RC};
use crate::abi::ops as aop;

/// Builtin reduction operators, in A.1 order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[allow(missing_docs)] // variants mirror the MPI_* op names 1:1
pub enum BuiltinOp {
    Null,
    Sum,
    Min,
    Max,
    Prod,
    Band,
    Bor,
    Bxor,
    Land,
    Lor,
    Lxor,
    Minloc,
    Maxloc,
    Replace,
    NoOp,
}

impl BuiltinOp {
    /// Map a standard-ABI op constant.
    pub fn from_abi(v: usize) -> Option<BuiltinOp> {
        use BuiltinOp::*;
        Some(match v {
            aop::MPI_OP_NULL => Null,
            aop::MPI_SUM => Sum,
            aop::MPI_MIN => Min,
            aop::MPI_MAX => Max,
            aop::MPI_PROD => Prod,
            aop::MPI_BAND => Band,
            aop::MPI_BOR => Bor,
            aop::MPI_BXOR => Bxor,
            aop::MPI_LAND => Land,
            aop::MPI_LOR => Lor,
            aop::MPI_LXOR => Lxor,
            aop::MPI_MINLOC => Minloc,
            aop::MPI_MAXLOC => Maxloc,
            aop::MPI_REPLACE => Replace,
            aop::MPI_NO_OP => NoOp,
            _ => return None,
        })
    }

    /// The standard-ABI constant of this operator.
    pub fn to_abi(self) -> usize {
        use BuiltinOp::*;
        match self {
            Null => aop::MPI_OP_NULL,
            Sum => aop::MPI_SUM,
            Min => aop::MPI_MIN,
            Max => aop::MPI_MAX,
            Prod => aop::MPI_PROD,
            Band => aop::MPI_BAND,
            Bor => aop::MPI_BOR,
            Bxor => aop::MPI_BXOR,
            Land => aop::MPI_LAND,
            Lor => aop::MPI_LOR,
            Lxor => aop::MPI_LXOR,
            Minloc => aop::MPI_MINLOC,
            Maxloc => aop::MPI_MAXLOC,
            Replace => aop::MPI_REPLACE,
            NoOp => aop::MPI_NO_OP,
        }
    }
}

/// In A.1 order; index = reserved op id.
pub const BUILTIN_ORDER: [BuiltinOp; 15] = [
    BuiltinOp::Null,
    BuiltinOp::Sum,
    BuiltinOp::Min,
    BuiltinOp::Max,
    BuiltinOp::Prod,
    BuiltinOp::Band,
    BuiltinOp::Bor,
    BuiltinOp::Bxor,
    BuiltinOp::Land,
    BuiltinOp::Lor,
    BuiltinOp::Lxor,
    BuiltinOp::Minloc,
    BuiltinOp::Maxloc,
    BuiltinOp::Replace,
    BuiltinOp::NoOp,
];

/// User op callback: `(invec, inoutvec, count, dt)` over packed buffers.
pub type UserOpFn = Box<dyn Fn(*const u8, *mut u8, i32, DtId)>;

/// How an op object reduces: a builtin operator or a user callback.
pub enum OpKind {
    /// One of the predefined operators.
    Builtin(BuiltinOp),
    /// User-defined op (`MPI_Op_create`).
    User {
        /// The (representation-converted) user callback.
        f: UserOpFn,
        /// Whether the user declared the op commutative.
        commute: bool,
    },
}

/// Reduction-op table entry.
pub struct OpObj {
    /// What the op does.
    pub kind: OpKind,
    /// Predefined ops are not freeable.
    pub predefined: bool,
}

/// Install the builtin ops at their reserved ids (A.1 order).
pub fn install_predefined(ops: &mut Slab<OpObj>) {
    for (i, &b) in BUILTIN_ORDER.iter().enumerate() {
        ops.insert_at(i as u32, OpObj { kind: OpKind::Builtin(b), predefined: true });
    }
}

/// Engine op id for a standard-ABI op constant.
pub fn builtin_id_of_abi(v: usize) -> Option<OpId> {
    BuiltinOp::from_abi(v)
        .and_then(|b| BUILTIN_ORDER.iter().position(|&x| x == b))
        .map(|i| OpId(i as u32))
}

/// Standard-ABI constant for a builtin op id.
pub fn abi_of_builtin_id(op: OpId) -> Option<usize> {
    BUILTIN_ORDER.get(op.0 as usize).map(|b| b.to_abi())
}

/// `MPI_Op_create`.
pub fn op_create(f: UserOpFn, commute: bool) -> RC<OpId> {
    with_ctx(|ctx| {
        Ok(OpId(ctx.tables.borrow_mut().ops.insert(OpObj {
            kind: OpKind::User { f, commute },
            predefined: false,
        })))
    })
}

/// `MPI_Op_free`.
pub fn op_free(op: OpId) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        match t.ops.get(op.0) {
            Some(o) if o.predefined => Err(err!(MPI_ERR_OP)),
            Some(_) => {
                t.ops.remove(op.0);
                Ok(())
            }
            None => Err(err!(MPI_ERR_OP)),
        }
    })
}

/// Apply `op` over packed buffers: `inout[i] = op(in[i], inout[i])`.
/// `count` items of datatype `dt`. This is `MPI_Reduce_local` and the
/// combine step of every reduction collective.
pub fn apply(op: OpId, inbuf: &[u8], inout: &mut [u8], count: usize, dt: DtId) -> RC<()> {
    // Snapshot what we need, then release borrows (user fn may call MPI).
    enum Plan {
        Builtin(BuiltinOp),
        User(UserOpFn),
    }
    let plan = with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let o = t.ops.get_mut(op.0).ok_or(err!(MPI_ERR_OP))?;
        Ok(match &mut o.kind {
            OpKind::Builtin(b) => Plan::Builtin(*b),
            OpKind::User { f, .. } => {
                let taken = std::mem::replace(f, Box::new(|_, _, _, _| {}));
                Plan::User(taken)
            }
        })
    })?;
    match plan {
        Plan::Builtin(b) => {
            let abi_dt = super::datatype::leaf_builtin(dt)?.ok_or(err!(MPI_ERR_TYPE))?;
            let elem_size = crate::abi::datatypes::platform_size_of(abi_dt)
                .ok_or(err!(MPI_ERR_TYPE))?;
            let nscalars = inout.len() / elem_size.max(1);
            debug_assert!(nscalars >= count, "packed buffers shorter than count");
            apply_builtin(b, scalar_kind(abi_dt), inbuf, inout, nscalars)
        }
        Plan::User(f) => {
            f(inbuf.as_ptr(), inout.as_mut_ptr(), count as i32, dt);
            // Reinstall the user function.
            with_ctx(|ctx| {
                let mut t = ctx.tables.borrow_mut();
                if let Some(o) = t.ops.get_mut(op.0) {
                    if let OpKind::User { f: slot, .. } = &mut o.kind {
                        *slot = f;
                    }
                }
                Ok(())
            })
        }
    }
}

/// Scalar arithmetic used by the builtin ops. Integer sum/prod wrap (C
/// unsigned-overflow semantics; MPI leaves signed overflow undefined).
pub trait Scalar: Copy {
    fn op_sum(self, o: Self) -> Self;
    fn op_prod(self, o: Self) -> Self;
    fn op_min(self, o: Self) -> Self;
    fn op_max(self, o: Self) -> Self;
}

macro_rules! impl_scalar_int {
    ($($t:ty),*) => {$(
        impl Scalar for $t {
            #[inline(always)] fn op_sum(self, o: Self) -> Self { self.wrapping_add(o) }
            #[inline(always)] fn op_prod(self, o: Self) -> Self { self.wrapping_mul(o) }
            #[inline(always)] fn op_min(self, o: Self) -> Self { if self < o { self } else { o } }
            #[inline(always)] fn op_max(self, o: Self) -> Self { if self > o { self } else { o } }
        }
    )*};
}
impl_scalar_int!(i8, u8, i16, u16, i32, u32, i64, u64);

macro_rules! impl_scalar_float {
    ($($t:ty),*) => {$(
        impl Scalar for $t {
            #[inline(always)] fn op_sum(self, o: Self) -> Self { self + o }
            #[inline(always)] fn op_prod(self, o: Self) -> Self { self * o }
            #[inline(always)] fn op_min(self, o: Self) -> Self { if self < o { self } else { o } }
            #[inline(always)] fn op_max(self, o: Self) -> Self { if self > o { self } else { o } }
        }
    )*};
}
impl_scalar_float!(f32, f64);

/// Elementwise `inout[i] = f(in[i], inout[i])` over reinterpreted scalars.
#[inline(always)]
fn binloop<T: Copy>(inbuf: &[u8], inout: &mut [u8], n: usize, f: impl Fn(T, T) -> T) {
    let a = inbuf.as_ptr() as *const T;
    let b = inout.as_mut_ptr() as *mut T;
    for i in 0..n {
        unsafe {
            let x = a.add(i).read_unaligned();
            let y = b.add(i).read_unaligned();
            b.add(i).write_unaligned(f(x, y));
        }
    }
}

macro_rules! arith_dispatch {
    ($kind:expr, $inbuf:expr, $inout:expr, $n:expr, $op:ident) => {
        match $kind {
            ScalarKind::I8 => Ok(binloop($inbuf, $inout, $n, <i8 as Scalar>::$op)),
            ScalarKind::U8 => Ok(binloop($inbuf, $inout, $n, <u8 as Scalar>::$op)),
            ScalarKind::I16 => Ok(binloop($inbuf, $inout, $n, <i16 as Scalar>::$op)),
            ScalarKind::U16 => Ok(binloop($inbuf, $inout, $n, <u16 as Scalar>::$op)),
            ScalarKind::I32 => Ok(binloop($inbuf, $inout, $n, <i32 as Scalar>::$op)),
            ScalarKind::U32 => Ok(binloop($inbuf, $inout, $n, <u32 as Scalar>::$op)),
            ScalarKind::I64 => Ok(binloop($inbuf, $inout, $n, <i64 as Scalar>::$op)),
            ScalarKind::U64 => Ok(binloop($inbuf, $inout, $n, <u64 as Scalar>::$op)),
            ScalarKind::F32 => Ok(binloop($inbuf, $inout, $n, <f32 as Scalar>::$op)),
            ScalarKind::F64 => Ok(binloop($inbuf, $inout, $n, <f64 as Scalar>::$op)),
            _ => Err(err!(MPI_ERR_OP)),
        }
    };
}

macro_rules! bitwise_dispatch {
    ($kind:expr, $inbuf:expr, $inout:expr, $n:expr, $f:tt) => {
        match $kind {
            ScalarKind::I8 | ScalarKind::U8 | ScalarKind::Bool | ScalarKind::Bytes => {
                Ok(binloop::<u8>($inbuf, $inout, $n, |x, y| x $f y))
            }
            ScalarKind::I16 | ScalarKind::U16 => {
                Ok(binloop::<u16>($inbuf, $inout, $n, |x, y| x $f y))
            }
            ScalarKind::I32 | ScalarKind::U32 => {
                Ok(binloop::<u32>($inbuf, $inout, $n, |x, y| x $f y))
            }
            ScalarKind::I64 | ScalarKind::U64 => {
                Ok(binloop::<u64>($inbuf, $inout, $n, |x, y| x $f y))
            }
            _ => Err(err!(MPI_ERR_OP)),
        }
    };
}

macro_rules! logical_dispatch {
    ($kind:expr, $inbuf:expr, $inout:expr, $n:expr, $f:expr) => {
        match $kind {
            ScalarKind::I8 | ScalarKind::U8 | ScalarKind::Bool => {
                Ok(binloop::<u8>($inbuf, $inout, $n, |x, y| ($f)(x != 0, y != 0) as u8))
            }
            ScalarKind::I16 | ScalarKind::U16 => {
                Ok(binloop::<u16>($inbuf, $inout, $n, |x, y| ($f)(x != 0, y != 0) as u16))
            }
            ScalarKind::I32 | ScalarKind::U32 => {
                Ok(binloop::<u32>($inbuf, $inout, $n, |x, y| ($f)(x != 0, y != 0) as u32))
            }
            ScalarKind::I64 | ScalarKind::U64 => {
                Ok(binloop::<u64>($inbuf, $inout, $n, |x, y| ($f)(x != 0, y != 0) as u64))
            }
            _ => Err(err!(MPI_ERR_OP)),
        }
    };
}

/// Loc-pair loop: (value, index) pairs, packed.
macro_rules! loc_loop {
    ($vt:ty, $inbuf:expr, $inout:expr, $n:expr, $min:expr) => {{
        #[repr(C)]
        #[derive(Clone, Copy)]
        struct Pair {
            v: $vt,
            i: i32,
        }
        let a = $inbuf.as_ptr() as *const Pair;
        let b = $inout.as_mut_ptr() as *mut Pair;
        for k in 0..$n {
            unsafe {
                let x = a.add(k).read_unaligned();
                let y = b.add(k).read_unaligned();
                let pick_x = if $min {
                    x.v < y.v || (x.v == y.v && x.i < y.i)
                } else {
                    x.v > y.v || (x.v == y.v && x.i < y.i)
                };
                if pick_x {
                    b.add(k).write_unaligned(x);
                }
            }
        }
        Ok(())
    }};
}

/// Typed builtin application over `n` packed scalars.
pub fn apply_builtin(
    b: BuiltinOp,
    kind: ScalarKind,
    inbuf: &[u8],
    inout: &mut [u8],
    n: usize,
) -> RC<()> {
    debug_assert!(inbuf.len() >= inout.len());
    // Hot-path offload: large contiguous float reductions run on the
    // AOT-compiled Pallas kernel through PJRT, when available.
    if crate::runtime::try_xla_reduce(b, kind, inbuf, inout, n) {
        return Ok(());
    }
    use BuiltinOp::*;
    match b {
        Null => Err(err!(MPI_ERR_OP)),
        NoOp => Ok(()),
        Replace => {
            inout.copy_from_slice(&inbuf[..inout.len()]);
            Ok(())
        }
        Sum => arith_dispatch!(kind, inbuf, inout, n, op_sum),
        Prod => arith_dispatch!(kind, inbuf, inout, n, op_prod),
        Min => arith_dispatch!(kind, inbuf, inout, n, op_min),
        Max => arith_dispatch!(kind, inbuf, inout, n, op_max),
        Band => bitwise_dispatch!(kind, inbuf, inout, n, &),
        Bor => bitwise_dispatch!(kind, inbuf, inout, n, |),
        Bxor => bitwise_dispatch!(kind, inbuf, inout, n, ^),
        Land => logical_dispatch!(kind, inbuf, inout, n, |x: bool, y: bool| x && y),
        Lor => logical_dispatch!(kind, inbuf, inout, n, |x: bool, y: bool| x || y),
        Lxor => logical_dispatch!(kind, inbuf, inout, n, |x: bool, y: bool| x ^ y),
        Minloc => match kind {
            ScalarKind::FloatInt => loc_loop!(f32, inbuf, inout, n, true),
            ScalarKind::DoubleInt => loc_loop!(f64, inbuf, inout, n, true),
            ScalarKind::IntInt => loc_loop!(i32, inbuf, inout, n, true),
            _ => Err(err!(MPI_ERR_OP)),
        },
        Maxloc => match kind {
            ScalarKind::FloatInt => loc_loop!(f32, inbuf, inout, n, false),
            ScalarKind::DoubleInt => loc_loop!(f64, inbuf, inout, n, false),
            ScalarKind::IntInt => loc_loop!(i32, inbuf, inout, n, false),
            _ => Err(err!(MPI_ERR_OP)),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bytes_of<T: Copy>(v: &[T]) -> Vec<u8> {
        unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)).to_vec()
        }
    }

    fn from_bytes<T: Copy>(b: &[u8]) -> Vec<T> {
        let n = b.len() / std::mem::size_of::<T>();
        (0..n)
            .map(|i| unsafe { (b.as_ptr() as *const T).add(i).read_unaligned() })
            .collect()
    }

    #[test]
    fn sum_f32() {
        let a = bytes_of(&[1.0f32, 2.0, 3.0]);
        let mut b = bytes_of(&[10.0f32, 20.0, 30.0]);
        apply_builtin(BuiltinOp::Sum, ScalarKind::F32, &a, &mut b, 3).unwrap();
        assert_eq!(from_bytes::<f32>(&b), vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn sum_wraps_integers() {
        let a = bytes_of(&[i32::MAX]);
        let mut b = bytes_of(&[1i32]);
        apply_builtin(BuiltinOp::Sum, ScalarKind::I32, &a, &mut b, 1).unwrap();
        assert_eq!(from_bytes::<i32>(&b), vec![i32::MIN]);
    }

    #[test]
    fn min_max_prod() {
        let a = bytes_of(&[5i64, -7, 2]);
        let mut b = bytes_of(&[3i64, -2, 10]);
        apply_builtin(BuiltinOp::Min, ScalarKind::I64, &a, &mut b.clone(), 3).unwrap();
        let mut bm = bytes_of(&[3i64, -2, 10]);
        apply_builtin(BuiltinOp::Max, ScalarKind::I64, &a, &mut bm, 3).unwrap();
        assert_eq!(from_bytes::<i64>(&bm), vec![5, -2, 10]);
        apply_builtin(BuiltinOp::Prod, ScalarKind::I64, &a, &mut b, 3).unwrap();
        assert_eq!(from_bytes::<i64>(&b), vec![15, 14, 20]);
    }

    #[test]
    fn bitwise_ops() {
        let a = bytes_of(&[0b1100u32]);
        let mut band = bytes_of(&[0b1010u32]);
        apply_builtin(BuiltinOp::Band, ScalarKind::U32, &a, &mut band, 1).unwrap();
        assert_eq!(from_bytes::<u32>(&band), vec![0b1000]);
        let mut bxor = bytes_of(&[0b1010u32]);
        apply_builtin(BuiltinOp::Bxor, ScalarKind::U32, &a, &mut bxor, 1).unwrap();
        assert_eq!(from_bytes::<u32>(&bxor), vec![0b0110]);
    }

    #[test]
    fn logical_ops_normalize() {
        let a = bytes_of(&[7i32, 0]);
        let mut b = bytes_of(&[2i32, 0]);
        apply_builtin(BuiltinOp::Land, ScalarKind::I32, &a, &mut b, 2).unwrap();
        assert_eq!(from_bytes::<i32>(&b), vec![1, 0]);
    }

    #[test]
    fn minloc_ties_pick_lower_index() {
        #[repr(C)]
        #[derive(Clone, Copy)]
        struct P(f32, i32);
        let a = bytes_of(&[P(1.0, 3)]);
        let mut b = bytes_of(&[P(1.0, 5)]);
        apply_builtin(BuiltinOp::Minloc, ScalarKind::FloatInt, &a, &mut b, 1).unwrap();
        let out: Vec<P> = from_bytes(&b);
        assert_eq!(out[0].1, 3);
    }

    #[test]
    fn maxloc_picks_max() {
        #[repr(C)]
        #[derive(Clone, Copy)]
        struct P(f64, i32);
        let a = bytes_of(&[P(2.0, 1), P(0.5, 1)]);
        let mut b = bytes_of(&[P(1.0, 0), P(1.5, 0)]);
        apply_builtin(BuiltinOp::Maxloc, ScalarKind::DoubleInt, &a, &mut b, 2).unwrap();
        let out: Vec<P> = from_bytes(&b);
        assert_eq!((out[0].0, out[0].1), (2.0, 1));
        assert_eq!((out[1].0, out[1].1), (1.5, 0));
    }

    #[test]
    fn replace_and_noop() {
        let a = bytes_of(&[9i32]);
        let mut b = bytes_of(&[1i32]);
        apply_builtin(BuiltinOp::Replace, ScalarKind::I32, &a, &mut b, 1).unwrap();
        assert_eq!(from_bytes::<i32>(&b), vec![9]);
        let mut c = bytes_of(&[1i32]);
        apply_builtin(BuiltinOp::NoOp, ScalarKind::I32, &a, &mut c, 1).unwrap();
        assert_eq!(from_bytes::<i32>(&c), vec![1]);
    }

    #[test]
    fn sum_on_bytes_kind_is_an_error() {
        let a = [0u8; 4];
        let mut b = [0u8; 4];
        let e = apply_builtin(BuiltinOp::Sum, ScalarKind::Bytes, &a, &mut b, 4).unwrap_err();
        assert_eq!(e.class, crate::abi::errors::MPI_ERR_OP);
    }

    #[test]
    fn abi_mapping_roundtrip() {
        for (i, &b) in BUILTIN_ORDER.iter().enumerate() {
            assert_eq!(builtin_id_of_abi(b.to_abi()), Some(OpId(i as u32)));
            assert_eq!(abi_of_builtin_id(OpId(i as u32)), Some(b.to_abi()));
        }
        assert_eq!(builtin_id_of_abi(0b0000100101), None);
    }
}
