//! The 10-bit modified Huffman code for predefined handle constants
//! (§5.4 + Appendix A).
//!
//! Key properties the paper requires, all enforced by tests here:
//!
//! * **Zero is always invalid** — uninitialized handles are detectable.
//! * **Null handles** are "the non-zero bits of the handle kind followed by
//!   zeros" (e.g. `MPI_COMM_NULL = 0b01_0000_0000`).
//! * The whole code fits in **10 bits** → the zero page of common OSes, so
//!   heap-allocated user handles can never collide with predefined ones.
//! * **Half of the code space** (`0b10…` and `0b11…`) is reserved for
//!   datatypes, since they are the majority of predefined handles.
//! * Fixed-size datatypes carry `log2(size)` in bit positions 3..6 so that
//!   e.g. `MPI_INT32_T`'s 4-byte size can be read with a mask + shift,
//!   MPICH-style, with no memory access.
//! * Decoding the *kind* of any handle takes a couple of bit tests, which
//!   is what lets implementations error-check handles "simply by applying
//!   a bitmask".

/// Maximum value of the Huffman code: predefined handles live in
/// `1..=HUFFMAN_MAX` (10 bits). Anything above is a user handle.
pub const HUFFMAN_MAX: usize = 0x3FF;

/// The handle kinds distinguishable from the bit pattern alone.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum HandleKind {
    /// `0b0000000000`: uninitialized/invalid.
    Invalid,
    /// `0b00001xxxxx`: reduction operations (A.1).
    Op,
    /// `0b01000000xx`: communicators.
    Comm,
    /// `0b010000010x`: groups.
    Group,
    /// `0b01000010xx`: RMA windows.
    Win,
    /// `0b01000011xx`: files.
    File,
    /// `0b0100010000 + reserved`: sessions.
    Session,
    /// `0b010001010x`: messages (mprobe).
    Message,
    /// `0b01000110xx`: error handlers.
    Errhandler,
    /// `0b01001xxxxx`: requests.
    Request,
    /// `0b10xxxxxxxx` / `0b11xxxxxxxx`: datatypes (A.3).
    Datatype,
    /// Codes inside the 10-bit space that are reserved for future handle
    /// types or future constants of existing types.
    Reserved,
}

/// Decode the kind of a predefined (zero-page) handle value.
///
/// For values above [`HUFFMAN_MAX`] this returns `None`: the value is a
/// runtime (user) handle and its kind is known from context, not bits.
pub fn decode(value: usize) -> Option<HandleKind> {
    if value > HUFFMAN_MAX {
        return None;
    }
    let v = value as u16;
    Some(kind_of(v))
}

/// Kind decode over the 10-bit space. Pure bit tests — this is the
/// "fast error checking ... simply by applying a bitmask" path.
pub fn kind_of(v: u16) -> HandleKind {
    debug_assert!(v as usize <= HUFFMAN_MAX);
    if v == 0 {
        return HandleKind::Invalid;
    }
    if v & 0b10_0000_0000 != 0 {
        // 0b1x_xxxx_xxxx: the datatype half of the code space.
        return HandleKind::Datatype;
    }
    if v & 0b01_0000_0000 != 0 {
        // 0b01_xxxx_xxxx: "other handles" (A.2).
        return match (v >> 2) & 0b11_1111 {
            0b00_0000 => {
                if v & 0b11 == 0b11 {
                    HandleKind::Reserved // 0b0100000011 reserved comm
                } else {
                    HandleKind::Comm
                }
            }
            0b00_0001 => {
                if v & 0b10 == 0 {
                    HandleKind::Group // 0b010000010x
                } else {
                    HandleKind::Reserved // 0b01000001 1x reserved group
                }
            }
            0b00_0010 => HandleKind::Win,  // 0b01000010xx
            0b00_0011 => HandleKind::File, // 0b01000011xx
            0b00_0100 => HandleKind::Session,
            0b00_0101 => {
                if v & 0b10 == 0 {
                    HandleKind::Message // 0b010001010x
                } else {
                    HandleKind::Reserved
                }
            }
            0b00_0110 => HandleKind::Errhandler, // 0b01000110xx
            0b00_0111 => HandleKind::Reserved,
            k if (0b00_1000..0b01_0000).contains(&k) => HandleKind::Request, // 0b01001xxxxx
            _ => HandleKind::Reserved, // 0b01 (rest): reserved handles
        };
    }
    // 0b00_xxxx_xxxx:
    if v & 0b00_1110_0000 == 0b00_0010_0000 {
        // 0b0000100000..0b0000111111: ops (A.1).
        HandleKind::Op
    } else {
        HandleKind::Reserved
    }
}

/// `true` iff `value` is in the predefined 10-bit zero-page range
/// (including 0, the invalid handle).
pub fn is_zero_page(value: usize) -> bool {
    value <= HUFFMAN_MAX
}

/// `true` iff `value` is the null handle for its kind: the non-zero kind
/// bits followed by zeros (§5.4).
pub fn is_null_handle(value: usize) -> bool {
    matches!(
        value,
        v if v == crate::abi::ops::MPI_OP_NULL
            || v == crate::abi::handles::MPI_COMM_NULL
            || v == crate::abi::handles::MPI_GROUP_NULL
            || v == crate::abi::handles::MPI_WIN_NULL
            || v == crate::abi::handles::MPI_FILE_NULL
            || v == crate::abi::handles::MPI_SESSION_NULL
            || v == crate::abi::handles::MPI_MESSAGE_NULL
            || v == crate::abi::handles::MPI_ERRHANDLER_NULL
            || v == crate::abi::handles::MPI_REQUEST_NULL
            || v == crate::abi::datatypes::MPI_DATATYPE_NULL
    )
}

// ---------------------------------------------------------------------------
// Datatype sub-decoding (A.3)
// ---------------------------------------------------------------------------

/// Datatype encoding class, from the prefix bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatatypeClass {
    /// `0b1000xxxxxx`: size depends on the platform ABI (C `int`, `long`,
    /// `MPI_Aint`, …) and is deliberately *not* encoded (§5.4: encoding it
    /// would make the constant a function of the platform ABI and force
    /// e.g. Julia to determine the platform ABI).
    VariableSize,
    /// `0b1001xxxxxx`: fixed-size type with `log2(size)` in bits 3..6.
    FixedSize,
    /// Anything else in the datatype half: reserved for future datatypes.
    Reserved,
}

/// Classify a datatype handle value.
pub fn datatype_class(v: usize) -> DatatypeClass {
    debug_assert!(kind_of(v as u16) == HandleKind::Datatype);
    match (v >> 6) & 0b1111 {
        0b1000 => DatatypeClass::VariableSize,
        0b1001 => DatatypeClass::FixedSize,
        _ => DatatypeClass::Reserved,
    }
}

/// Extract the size in bytes of a **fixed-size** datatype from the handle
/// bits alone: `size = 2^(bits 3..6)`. Returns `None` for variable-size or
/// reserved encodings.
///
/// This is the standard-ABI analogue of MPICH's
/// `MPIR_Datatype_get_basic_size(a) (((a)&0x0000ff00)>>8)` — the §6.1
/// experiment measures exactly this path.
#[inline(always)]
pub fn fixed_size_of(v: usize) -> Option<usize> {
    if (v >> 6) & 0b1111 == 0b1001 {
        Some(1usize << ((v >> 3) & 0b111))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abi::datatypes::*;
    use crate::abi::handles::*;
    use crate::abi::ops::*;

    #[test]
    fn zero_is_invalid() {
        assert_eq!(decode(0), Some(HandleKind::Invalid));
    }

    #[test]
    fn above_zero_page_is_user() {
        assert_eq!(decode(HUFFMAN_MAX + 1), None);
        assert!(!is_zero_page(0x400));
        assert!(is_zero_page(0x3FF));
    }

    #[test]
    fn op_kinds() {
        for v in [
            MPI_OP_NULL, MPI_SUM, MPI_MIN, MPI_MAX, MPI_PROD, MPI_BAND, MPI_BOR, MPI_BXOR,
            MPI_LAND, MPI_LOR, MPI_LXOR, MPI_MINLOC, MPI_MAXLOC, MPI_REPLACE, MPI_NO_OP,
        ] {
            assert_eq!(kind_of(v as u16), HandleKind::Op, "value {v:#012b}");
        }
    }

    #[test]
    fn appendix_a1_exact_values() {
        // Spot-check the exact binary constants printed in Appendix A.1.
        assert_eq!(MPI_OP_NULL, 0b0000100000);
        assert_eq!(MPI_SUM, 0b0000100001);
        assert_eq!(MPI_MIN, 0b0000100010);
        assert_eq!(MPI_MAX, 0b0000100011);
        assert_eq!(MPI_PROD, 0b0000100100);
        assert_eq!(MPI_BAND, 0b0000101000);
        assert_eq!(MPI_BOR, 0b0000101001);
        assert_eq!(MPI_BXOR, 0b0000101010);
        assert_eq!(MPI_LAND, 0b0000110000);
        assert_eq!(MPI_LOR, 0b0000110001);
        assert_eq!(MPI_LXOR, 0b0000110010);
        assert_eq!(MPI_MINLOC, 0b0000111000);
        assert_eq!(MPI_MAXLOC, 0b0000111001);
        assert_eq!(MPI_REPLACE, 0b0000111100);
        assert_eq!(MPI_NO_OP, 0b0000111101);
    }

    #[test]
    fn appendix_a2_exact_values() {
        assert_eq!(MPI_COMM_NULL, 0b0100000000);
        assert_eq!(MPI_COMM_WORLD, 0b0100000001);
        assert_eq!(MPI_COMM_SELF, 0b0100000010);
        assert_eq!(MPI_GROUP_NULL, 0b0100000100);
        assert_eq!(MPI_GROUP_EMPTY, 0b0100000101);
        assert_eq!(MPI_WIN_NULL, 0b0100001000);
        assert_eq!(MPI_FILE_NULL, 0b0100001100);
        assert_eq!(MPI_SESSION_NULL, 0b0100010000);
        assert_eq!(MPI_MESSAGE_NULL, 0b0100010100);
        assert_eq!(MPI_MESSAGE_NO_PROC, 0b0100010101);
        assert_eq!(MPI_ERRHANDLER_NULL, 0b0100011000);
        assert_eq!(MPI_ERRORS_ARE_FATAL, 0b0100011001);
        assert_eq!(MPI_ERRORS_RETURN, 0b0100011010);
        assert_eq!(MPI_ERRORS_ABORT, 0b0100011011);
        assert_eq!(MPI_REQUEST_NULL, 0b0100100000);
    }

    #[test]
    fn handle_kind_decode_a2() {
        assert_eq!(kind_of(MPI_COMM_WORLD as u16), HandleKind::Comm);
        assert_eq!(kind_of(MPI_COMM_SELF as u16), HandleKind::Comm);
        assert_eq!(kind_of(MPI_GROUP_EMPTY as u16), HandleKind::Group);
        assert_eq!(kind_of(MPI_WIN_NULL as u16), HandleKind::Win);
        assert_eq!(kind_of(MPI_FILE_NULL as u16), HandleKind::File);
        assert_eq!(kind_of(MPI_SESSION_NULL as u16), HandleKind::Session);
        assert_eq!(kind_of(MPI_MESSAGE_NO_PROC as u16), HandleKind::Message);
        assert_eq!(kind_of(MPI_ERRORS_RETURN as u16), HandleKind::Errhandler);
        assert_eq!(kind_of(MPI_REQUEST_NULL as u16), HandleKind::Request);
        // 0b0100000011 is explicitly "reserved comm" in A.2 — we treat it
        // as Reserved so uninitialized garbage isn't misidentified.
        assert_eq!(kind_of(0b0100000011), HandleKind::Reserved);
    }

    #[test]
    fn null_handles_are_kind_bits_then_zeros() {
        for v in [
            MPI_COMM_NULL, MPI_GROUP_NULL, MPI_WIN_NULL, MPI_FILE_NULL, MPI_SESSION_NULL,
            MPI_MESSAGE_NULL, MPI_ERRHANDLER_NULL, MPI_REQUEST_NULL, MPI_OP_NULL,
            MPI_DATATYPE_NULL,
        ] {
            assert!(is_null_handle(v), "{v:#012b}");
            assert_ne!(v, 0, "null handles must be nonzero so 0 stays invalid");
        }
        assert!(!is_null_handle(MPI_COMM_WORLD));
        assert!(!is_null_handle(MPI_SUM));
    }

    #[test]
    fn datatype_half_of_code_space() {
        // Half the Huffman bits are reserved for datatypes (§5.4): every
        // value with the top bit of the 10-bit code set decodes as Datatype.
        for v in 0b10_0000_0000usize..=HUFFMAN_MAX {
            assert_eq!(kind_of(v as u16), HandleKind::Datatype);
        }
    }

    #[test]
    fn appendix_a3_exact_values() {
        assert_eq!(MPI_DATATYPE_NULL, 0b1000000000);
        assert_eq!(MPI_AINT, 0b1000000001);
        assert_eq!(MPI_COUNT, 0b1000000010);
        assert_eq!(MPI_OFFSET, 0b1000000011);
        assert_eq!(MPI_PACKED, 0b1000000111);
        assert_eq!(MPI_SHORT, 0b1000001000);
        assert_eq!(MPI_INT, 0b1000001001);
        assert_eq!(MPI_LONG, 0b1000001010);
        assert_eq!(MPI_LONG_LONG, 0b1000001011);
        assert_eq!(MPI_UNSIGNED_SHORT, 0b1000001100);
        assert_eq!(MPI_UNSIGNED, 0b1000001101);
        assert_eq!(MPI_UNSIGNED_LONG, 0b1000001110);
        assert_eq!(MPI_UNSIGNED_LONG_LONG, 0b1000001111);
        assert_eq!(MPI_FLOAT, 0b1000010000);
        assert_eq!(MPI_INT8_T, 0b1001000000);
        assert_eq!(MPI_UINT8_T, 0b1001000001);
        assert_eq!(MPI_CHAR, 0b1001000011);
        assert_eq!(MPI_SIGNED_CHAR, 0b1001000100);
        assert_eq!(MPI_UNSIGNED_CHAR, 0b1001000101);
        assert_eq!(MPI_BYTE, 0b1001000111);
        assert_eq!(MPI_INT16_T, 0b1001001000);
        assert_eq!(MPI_UINT16_T, 0b1001001001);
        assert_eq!(MPI_INT32_T, 0b1001010000);
        assert_eq!(MPI_UINT32_T, 0b1001010001);
        assert_eq!(MPI_INT64_T, 0b1001011000);
        assert_eq!(MPI_UINT64_T, 0b1001011001);
    }

    #[test]
    fn fixed_size_extraction() {
        // §5.4's worked examples: MPI_BYTE = 0b1001_000_111 → size 2^0 = 1;
        // MPI_INT32_T = 0b1001_010_000 → size 2^2 = 4.
        assert_eq!(fixed_size_of(MPI_BYTE), Some(1));
        assert_eq!(fixed_size_of(MPI_CHAR), Some(1));
        assert_eq!(fixed_size_of(MPI_INT8_T), Some(1));
        assert_eq!(fixed_size_of(MPI_INT16_T), Some(2));
        assert_eq!(fixed_size_of(MPI_INT32_T), Some(4));
        assert_eq!(fixed_size_of(MPI_UINT32_T), Some(4));
        assert_eq!(fixed_size_of(MPI_FLOAT32_T), Some(4));
        assert_eq!(fixed_size_of(MPI_INT64_T), Some(8));
        assert_eq!(fixed_size_of(MPI_FLOAT64_T), Some(8));
        // Variable-size types do not encode a size.
        assert_eq!(fixed_size_of(MPI_INT), None);
        assert_eq!(fixed_size_of(MPI_FLOAT), None);
        assert_eq!(fixed_size_of(MPI_AINT), None);
    }

    #[test]
    fn datatype_classes() {
        assert_eq!(datatype_class(MPI_INT), DatatypeClass::VariableSize);
        assert_eq!(datatype_class(MPI_FLOAT), DatatypeClass::VariableSize);
        assert_eq!(datatype_class(MPI_INT32_T), DatatypeClass::FixedSize);
        assert_eq!(datatype_class(MPI_BYTE), DatatypeClass::FixedSize);
        // 0b1010… is not yet allocated.
        assert_eq!(datatype_class(0b1010000000), DatatypeClass::Reserved);
    }

    #[test]
    fn all_predefined_constants_are_unique() {
        let all = crate::abi::all_predefined_handles();
        let mut seen = std::collections::HashSet::new();
        for (name, v) in all {
            assert!(seen.insert(v), "duplicate handle value {v:#012b} for {name}");
            assert!(is_zero_page(v), "{name} escapes the zero page");
        }
    }

    #[test]
    fn code_space_has_room_to_grow() {
        // §5.4: "sufficient free space to allow many new handle types and
        // new handle constants ... without breaking changes".
        let used = crate::abi::all_predefined_handles().len();
        assert!(used < HUFFMAN_MAX / 2, "only {used} of 1024 codes used");
    }
}
