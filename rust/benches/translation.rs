//! E6/E7 — translation-cost ablations: what each piece of Mukautuva's
//! per-call work costs (handle conversion, status conversion, error-code
//! mapping, the dlsym-resolved indirect call) and the §6.3 worst case
//! (constant conversion scans bounded by O(N_predefined)).

use mpi_abi::abi::handles as std_h;
use mpi_abi::abi::status::AbiStatus;
use mpi_abi::bench::bench;
use mpi_abi::core::request::StatusCore;
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::muk::convert;
use mpi_abi::muk::{symbols, Backend, BackendSel};

const ITERS: usize = 200_000;

fn main() {
    println!("\nE6/E7 — per-call translation cost ablations");
    let mut sink = 0usize;

    // Handle conversions, both directions, both backends.
    let s = bench("convert/comm_to_impl (mpich)", 2, 10, ITERS, || {
        sink ^= convert::comm_to_impl::<MpichAbi>(std::hint::black_box(std_h::MPI_COMM_WORLD))
            as usize;
    });
    println!("{}", s.report());
    let s = bench("convert/comm_to_impl (ompi)", 2, 10, ITERS, || {
        sink ^= convert::comm_to_impl::<OmpiAbi>(std::hint::black_box(std_h::MPI_COMM_WORLD)).0
            as usize;
    });
    println!("{}", s.report());
    let s = bench("convert/dt_to_impl predefined (mpich)", 2, 10, ITERS, || {
        sink ^= convert::dt_to_impl::<MpichAbi>(std::hint::black_box(
            mpi_abi::abi::datatypes::MPI_DOUBLE,
        )) as usize;
    });
    println!("{}", s.report());
    let s = bench("convert/dt_to_impl user-handle (mpich)", 2, 10, ITERS, || {
        // User handles bypass the predefined table: pure word reinterpret.
        sink ^= convert::dt_to_impl::<MpichAbi>(std::hint::black_box(0x8C00_0042usize)) as usize;
    });
    println!("{}", s.report());

    // Status conversion (backend layout → standard 32-byte status).
    let core = StatusCore::success(3, 42, 8);
    let mpich_status =
        <mpi_abi::impls::mpich::MpichRepr as mpi_abi::impls::repr::Repr>::status_from_core(&core);
    let mut out = AbiStatus::empty();
    let s = bench("convert/status mpich→std (incl count)", 2, 10, ITERS, || {
        out = convert::status_to_muk::<MpichAbi>(std::hint::black_box(&mpich_status));
    });
    println!("{}", s.report());
    std::hint::black_box(out);

    // Error-code mapping: success fast path vs error path.
    let s = bench("convert/ret_code success fast path", 2, 10, ITERS, || {
        sink ^= convert::ret_code::<MpichAbi>(std::hint::black_box(0)) as usize;
    });
    println!("{}", s.report());
    let ec = mpi_abi::impls::mpich::err_code(mpi_abi::abi::errors::MPI_ERR_TRUNCATE);
    let s = bench("convert/ret_code error path", 2, 10, ITERS, || {
        sink ^= convert::ret_code::<MpichAbi>(std::hint::black_box(ec)) as usize;
    });
    println!("{}", s.report());

    // The dlsym-resolved indirect call itself: vtable type_size vs a
    // direct (monomorphized) call — the pure dispatch overhead.
    let vt = mpi_abi::muk::OverMpich::vtable();
    let s = bench("dispatch/vtable indirect call (type_size)", 2, 10, ITERS, || {
        let mut o = 0;
        (vt.type_size)(std::hint::black_box(mpi_abi::abi::datatypes::MPI_INT), &mut o);
        sink ^= o as usize;
    });
    println!("{}", s.report());
    let s = bench("dispatch/direct call (type_size)", 2, 10, ITERS, || {
        let mut o = 0;
        use mpi_abi::api::MpiAbi;
        MpichAbi::type_size(
            std::hint::black_box(MpichAbi::datatype(mpi_abi::api::Dt::Int)),
            &mut o,
        );
        sink ^= o as usize;
    });
    println!("{}", s.report());

    // E7 (§6.3): worst-case predefined-constant conversion — a linear
    // scan over all predefined handles (what an implementation without a
    // table pays, O(N_predefined)) vs our O(1) table.
    let all = mpi_abi::abi::all_predefined_handles();
    let s = bench("constants/linear scan O(N_predefined)", 2, 10, ITERS / 10, || {
        let target = std::hint::black_box(mpi_abi::abi::datatypes::MPI_UINT64_T);
        sink ^= all.iter().position(|&(_, v)| v == target).unwrap_or(0);
    });
    println!("{}", s.report());
    let s = bench("constants/table lookup O(1)", 2, 10, ITERS, || {
        sink ^= mpi_abi::core::datatype::builtin_id_of_abi(std::hint::black_box(
            mpi_abi::abi::datatypes::MPI_UINT64_T,
        ))
        .map(|d| d.0 as usize)
        .unwrap_or(0);
    });
    println!("{}", s.report());

    // dlsym resolution cost (startup, not per-call — but worth recording).
    let s = bench("startup/dlsym one symbol", 2, 10, 10_000, || {
        let st = symbols(Backend::Mpich);
        let f: fn(usize, &mut i32) -> i32 =
            unsafe { st.dlsym(std::hint::black_box("WRAP_comm_size")) };
        sink ^= f as usize;
    });
    println!("{}", s.report());

    std::hint::black_box(sink);
    println!("\nshape: every per-call conversion is single-digit ns — invisible next to the ≥500 ns message cost (§6.1), matching the paper's \"trivial overhead\" claim for non-callback paths.");
}
