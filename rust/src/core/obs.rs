//! The observability subsystem: pvar/cvar registry, the MPI_T tools
//! interface, and the engine event tracer.
//!
//! The paper's strongest use case for a standard ABI is tools — a
//! profiler must attach to *any* implementation without recompiling.
//! This module is the engine side of that story:
//!
//! * **Performance variables (pvars)** — per-rank counters the engine
//!   bumps on its hot paths ([`ObsRank`]) plus job-wide atomics that
//!   used to live as ad-hoc one-offs on `World` ([`WorldObs`]). The
//!   registry ([`PVARS`]) pins index order: it is ABI surface, like a
//!   constants table.
//! * **Control variables (cvars)** — the existing `rndv_threshold` and
//!   `flat_match` knobs plus the trace flag, readable (and for the
//!   first two, writable) through [`CVARS`].
//! * **The MPI_T subset** — `MPI_T_init_thread` through
//!   `MPI_T_pvar_reset`, with its own init refcount separate from
//!   `MPI_Init` (MPI-4 §15.3: tools attach before MPI starts). MPI_T
//!   errors return their code directly — they never invoke a
//!   communicator error handler.
//! * **The trace ring** — compact timestamped event records pushed by
//!   [`trace`]; one branch on a cached bool when disabled. Rings merge
//!   into the world-level sink at finalize/unbind and render as Chrome
//!   trace-event JSON ([`chrome_trace_json`]), one lane per rank.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::world::{with_ctx, RankCtx};
use super::{err, RC};
use crate::abi::constants as k;

// ---------------------------------------------------------------------------
// Job-wide counters (the migrated World one-offs)
// ---------------------------------------------------------------------------

/// Job-global observability counters, embedded in
/// [`crate::core::world::World`]. These were ad-hoc fields on `World`
/// before the registry existed; they now live here so every counter in
/// the engine uses one mechanism with one memory-ordering policy:
/// **Relaxed** — counters need atomicity, not ordering, and none of
/// them guards any other memory.
#[derive(Default)]
pub struct WorldObs {
    /// Payload bytes currently in flight inside rendezvous chunks
    /// (incremented at chunk enqueue, decremented at consume).
    pub rndv_inflight: AtomicU64,
    /// High-water mark of `rndv_inflight` — the bounded-buffering
    /// witness `tests/rendezvous.rs` asserts on.
    pub rndv_inflight_peak: AtomicU64,
    /// Collective-schedule constructions in this job (all ranks).
    pub sched_builds: AtomicU64,
    /// Collective-schedule re-arms (`MPI_Start` on a persistent
    /// collective): the reuse the schedule engine exists to deliver.
    pub sched_reuses: AtomicU64,
    /// Communicators revoked (ULFM `MPI_Comm_revoke`). Counts *comms*,
    /// not context planes — a revoke poisons both of a comm's planes
    /// but bumps this once, and only when the comm was not already
    /// revoked.
    pub comms_revoked: AtomicU64,
}

impl WorldObs {
    /// Fresh (all-zero) counters for a new world.
    pub fn new() -> WorldObs {
        WorldObs::default()
    }

    /// Account `bytes` of rendezvous chunk payload entering the fabric.
    pub(crate) fn note_rndv_enqueue(&self, bytes: u64) {
        let now = self.rndv_inflight.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.rndv_inflight_peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Account `bytes` of rendezvous chunk payload consumed at a receiver.
    pub(crate) fn note_rndv_consume(&self, bytes: u64) {
        self.rndv_inflight.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Record one collective-schedule construction.
    pub(crate) fn note_sched_build(&self) {
        self.sched_builds.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one collective-schedule re-arm.
    pub(crate) fn note_sched_reuse(&self) {
        self.sched_reuses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one newly revoked communicator.
    pub(crate) fn note_comm_revoked(&self) {
        self.comms_revoked.fetch_add(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Per-rank counters + MPI_T state + trace ring
// ---------------------------------------------------------------------------

/// Collective-algorithm ids, recorded per schedule by the selection
/// layer ([`crate::core::collectives`]) and surfaced three ways: the
/// `coll_sel_*` pvars count selections per algorithm, the trace ring's
/// [`TraceKind::CollStep`] events carry the id in the high byte of `b`,
/// and `0` everywhere means "no algorithm stamped" (pre-selection
/// schedules: bcast, reduce, barrier, …).
pub const COLL_ALGO_BINOMIAL: u8 = 1;
/// Ring reduce-scatter + ring allgather allreduce (also ring allgather).
pub const COLL_ALGO_RING: u8 = 2;
/// Recursive-doubling allreduce.
pub const COLL_ALGO_RECURSIVE_DOUBLING: u8 = 3;
/// Rabenseifner allreduce (recursive-halving reduce-scatter + doubling
/// allgather).
pub const COLL_ALGO_RABENSEIFNER: u8 = 4;
/// Bruck alltoall (log-round block exchange).
pub const COLL_ALGO_BRUCK: u8 = 5;
/// Pairwise/linear alltoall (the alltoallw engine).
pub const COLL_ALGO_PAIRWISE: u8 = 6;
/// Number of distinct algorithm ids (the `coll_sel` array length).
pub const NUM_COLL_ALGOS: usize = 6;

/// Per-rank observability state, one per [`RankCtx`]. Counters are
/// plain [`Cell`]s — each rank is single-threaded, so no atomics —
/// bumped by the engine's pt2pt paths and read through the pvar
/// registry.
pub struct ObsRank {
    /// Point-to-point sends posted (eager + rendezvous; `MPI_PROC_NULL`
    /// sends carry no message and are not counted).
    pub sends_posted: Cell<u64>,
    /// Point-to-point receives posted (blocking, nonblocking, and
    /// persistent starts; `MPI_PROC_NULL` excluded likewise).
    pub recvs_posted: Cell<u64>,
    /// Sends that went eager (at or below the threshold).
    pub eager_msgs: Cell<u64>,
    /// Packed payload bytes of those eager sends.
    pub eager_bytes: Cell<u64>,
    /// Sends that went rendezvous (RTS/CTS + chunk streaming).
    pub rndv_msgs: Cell<u64>,
    /// Announced packed bytes of those rendezvous sends.
    pub rndv_bytes: Cell<u64>,
    /// High-water mark of any single destination's deferred-send queue
    /// (transport backpressure depth).
    pub pending_send_hwm: Cell<u64>,
    /// Operations this rank completed with `MPI_ERR_PROC_FAILED`
    /// (failed sends, receives, and rendezvous streams against a dead
    /// peer — the ULFM fault-propagation witness).
    pub ops_failed_proc: Cell<u64>,
    /// Collective-schedule selections per algorithm id (index
    /// `algo - 1`; see [`COLL_ALGO_BINOMIAL`] and friends) — how often
    /// the tuning table (or a forced override) picked each variant.
    pub coll_sel: [Cell<u64>; NUM_COLL_ALGOS],
    /// `MPI_T_init_thread` refcount: every MPI_T call below errors
    /// `MPI_T_ERR_NOT_INITIALIZED` while this is zero.
    t_init_count: Cell<u32>,
    /// Sessions and handles of the tools interface.
    t_state: RefCell<TState>,
    /// Tracing enabled for this rank (copied from the world at bind —
    /// the one branch the disabled case pays).
    pub trace_on: Cell<bool>,
    /// The event ring (only touched when `trace_on`).
    ring: RefCell<TraceRing>,
}

impl ObsRank {
    /// Fresh per-rank state; `trace_on` comes from the world's flag at
    /// bind time.
    pub fn new(trace_on: bool) -> ObsRank {
        ObsRank {
            sends_posted: Cell::new(0),
            recvs_posted: Cell::new(0),
            eager_msgs: Cell::new(0),
            eager_bytes: Cell::new(0),
            rndv_msgs: Cell::new(0),
            rndv_bytes: Cell::new(0),
            pending_send_hwm: Cell::new(0),
            ops_failed_proc: Cell::new(0),
            coll_sel: Default::default(),
            t_init_count: Cell::new(0),
            t_state: RefCell::new(TState::default()),
            trace_on: Cell::new(trace_on),
            ring: RefCell::new(TraceRing::new(TRACE_RING_CAP)),
        }
    }

    /// Fetch-max a [`Cell`] high-water mark.
    #[inline]
    pub(crate) fn note_pending_depth(&self, depth: u64) {
        if depth > self.pending_send_hwm.get() {
            self.pending_send_hwm.set(depth);
        }
    }

    /// Record one operation completed with `MPI_ERR_PROC_FAILED`.
    pub(crate) fn note_op_failed_proc(&self) {
        self.ops_failed_proc.set(self.ops_failed_proc.get() + 1);
    }

    /// Record one collective-algorithm selection (id `0` = unstamped
    /// schedule, not counted).
    pub(crate) fn note_coll_algo(&self, algo: u8) {
        if algo == 0 || algo as usize > NUM_COLL_ALGOS {
            return;
        }
        let c = &self.coll_sel[algo as usize - 1];
        c.set(c.get() + 1);
    }
}

/// MPI_T sessions and handles of one rank. Handles are indices into
/// these vectors; the subset has no free calls, so entries live until
/// the last `MPI_T_finalize` clears everything (after which stale
/// handles fail range checks with the proper `MPI_T_ERR_*`).
#[derive(Default)]
struct TState {
    /// Pvar sessions; a session is its vector of bound handles.
    sessions: Vec<PvarSession>,
    /// Cvar handles: each is just the cvar index it was bound to.
    cvar_handles: Vec<usize>,
}

#[derive(Default)]
struct PvarSession {
    handles: Vec<PvarHandle>,
}

/// One bound pvar handle. COUNTER-class variables read relative to
/// `baseline` (set at alloc, moved by start/reset), so a tool measures
/// *its* interval regardless of traffic before it attached — this is
/// also what makes the exact-count battery robust to setup exchanges.
struct PvarHandle {
    index: usize,
    baseline: u64,
}

// ---------------------------------------------------------------------------
// The registry
// ---------------------------------------------------------------------------

/// Descriptor of one performance variable.
pub struct PvarDesc {
    /// Variable name (`MPI_T_pvar_get_info`).
    pub name: &'static str,
    /// Variable class (`MPI_T_PVAR_CLASS_*`).
    pub class: i32,
    /// Verbosity level (`MPI_T_VERBOSITY_*`).
    pub verbosity: i32,
}

/// The pvar registry, in **fixed index order** — indices are ABI
/// surface (a tool that caches index 4 must keep reading rendezvous
/// message counts), so new variables append, never insert.
pub const PVARS: &[PvarDesc] = &[
    PvarDesc {
        name: "sends_posted",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    PvarDesc {
        name: "recvs_posted",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    PvarDesc {
        name: "eager_msgs",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    PvarDesc {
        name: "eager_bytes",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    PvarDesc {
        name: "rndv_msgs",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    PvarDesc {
        name: "rndv_bytes",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    PvarDesc {
        name: "unexpected_depth",
        class: k::MPI_T_PVAR_CLASS_LEVEL,
        verbosity: k::MPI_T_VERBOSITY_USER_DETAIL,
    },
    PvarDesc {
        name: "unexpected_hwm",
        class: k::MPI_T_PVAR_CLASS_HIGHWATERMARK,
        verbosity: k::MPI_T_VERBOSITY_USER_DETAIL,
    },
    PvarDesc {
        name: "posted_depth",
        class: k::MPI_T_PVAR_CLASS_LEVEL,
        verbosity: k::MPI_T_VERBOSITY_USER_DETAIL,
    },
    PvarDesc {
        name: "posted_hwm",
        class: k::MPI_T_PVAR_CLASS_HIGHWATERMARK,
        verbosity: k::MPI_T_VERBOSITY_USER_DETAIL,
    },
    PvarDesc {
        name: "match_attempts",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
    PvarDesc {
        name: "wildcard_matches",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
    PvarDesc {
        name: "pending_send_depth",
        class: k::MPI_T_PVAR_CLASS_LEVEL,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
    PvarDesc {
        name: "pending_send_hwm",
        class: k::MPI_T_PVAR_CLASS_HIGHWATERMARK,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
    PvarDesc {
        name: "rndv_inflight_peak",
        class: k::MPI_T_PVAR_CLASS_HIGHWATERMARK,
        verbosity: k::MPI_T_VERBOSITY_MPIDEV_BASIC,
    },
    PvarDesc {
        name: "sched_builds",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_MPIDEV_BASIC,
    },
    PvarDesc {
        name: "sched_reuses",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_MPIDEV_BASIC,
    },
    PvarDesc {
        name: "ranks_failed",
        class: k::MPI_T_PVAR_CLASS_LEVEL,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    PvarDesc {
        name: "ops_failed_proc",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    PvarDesc {
        name: "comms_revoked",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    // Indices 20..=25: collective-algorithm selection counts, one per
    // id in [`COLL_ALGO_BINOMIAL`]..[`COLL_ALGO_PAIRWISE`] order.
    PvarDesc {
        name: "coll_sel_binomial",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
    PvarDesc {
        name: "coll_sel_ring",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
    PvarDesc {
        name: "coll_sel_recursive_doubling",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
    PvarDesc {
        name: "coll_sel_rabenseifner",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
    PvarDesc {
        name: "coll_sel_bruck",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
    PvarDesc {
        name: "coll_sel_pairwise",
        class: k::MPI_T_PVAR_CLASS_COUNTER,
        verbosity: k::MPI_T_VERBOSITY_TUNER_DETAIL,
    },
];

/// Descriptor of one control variable.
pub struct CvarDesc {
    /// Variable name (`MPI_T_cvar_get_info`).
    pub name: &'static str,
    /// Scope (`MPI_T_SCOPE_LOCAL` = writable per rank,
    /// `MPI_T_SCOPE_READONLY` = write returns
    /// `MPI_T_ERR_CVAR_SET_NEVER`).
    pub scope: i32,
    /// Verbosity level.
    pub verbosity: i32,
}

/// Cvar index of `rndv_threshold`.
pub const CVAR_RNDV_THRESHOLD: usize = 0;
/// Cvar index of `flat_match`.
pub const CVAR_FLAT_MATCH: usize = 1;
/// Cvar index of `trace_enabled`.
pub const CVAR_TRACE_ENABLED: usize = 2;
/// Cvar index of `coll_allreduce_algo`.
pub const CVAR_COLL_ALLREDUCE_ALGO: usize = 3;
/// Cvar index of `coll_allgather_algo`.
pub const CVAR_COLL_ALLGATHER_ALGO: usize = 4;
/// Cvar index of `coll_alltoall_algo`.
pub const CVAR_COLL_ALLTOALL_ALGO: usize = 5;

/// The cvar registry, fixed index order like [`PVARS`]. Writing
/// `rndv_threshold` retargets **this rank's** live protocol switch (and
/// the world default for later binds); writing `flat_match` only
/// changes the world default — a rank's matcher is fixed at bind.
pub const CVARS: &[CvarDesc] = &[
    CvarDesc {
        name: "rndv_threshold",
        scope: k::MPI_T_SCOPE_LOCAL,
        verbosity: k::MPI_T_VERBOSITY_TUNER_BASIC,
    },
    CvarDesc {
        name: "flat_match",
        scope: k::MPI_T_SCOPE_LOCAL,
        verbosity: k::MPI_T_VERBOSITY_TUNER_BASIC,
    },
    CvarDesc {
        name: "trace_enabled",
        scope: k::MPI_T_SCOPE_READONLY,
        verbosity: k::MPI_T_VERBOSITY_USER_BASIC,
    },
    // Indices 3..=5: forced collective-algorithm choices, one per
    // operation. Values are the force codes of
    // [`crate::core::collectives`] (`0` = auto/tuning table). Writes
    // retarget **this rank's** live selector and the world default for
    // ranks bound later (the `rndv_threshold` pattern).
    CvarDesc {
        name: "coll_allreduce_algo",
        scope: k::MPI_T_SCOPE_LOCAL,
        verbosity: k::MPI_T_VERBOSITY_TUNER_BASIC,
    },
    CvarDesc {
        name: "coll_allgather_algo",
        scope: k::MPI_T_SCOPE_LOCAL,
        verbosity: k::MPI_T_VERBOSITY_TUNER_BASIC,
    },
    CvarDesc {
        name: "coll_alltoall_algo",
        scope: k::MPI_T_SCOPE_LOCAL,
        verbosity: k::MPI_T_VERBOSITY_TUNER_BASIC,
    },
];

/// Read pvar `i`'s current absolute value for this rank.
fn pvar_value(ctx: &RankCtx, i: usize) -> u64 {
    let o = &ctx.obs;
    match i {
        0 => o.sends_posted.get(),
        1 => o.recvs_posted.get(),
        2 => o.eager_msgs.get(),
        3 => o.eager_bytes.get(),
        4 => o.rndv_msgs.get(),
        5 => o.rndv_bytes.get(),
        6 => ctx.state.borrow().match_index.unexpected_len() as u64,
        7 => ctx.state.borrow().match_index.stats.unexpected_hwm,
        8 => ctx.state.borrow().match_index.posted_len() as u64,
        9 => ctx.state.borrow().match_index.stats.posted_hwm,
        10 => ctx.state.borrow().match_index.stats.attempts,
        11 => ctx.state.borrow().match_index.stats.wildcard_matches,
        12 => ctx.state.borrow().pending_sends.values().map(|q| q.len() as u64).sum(),
        13 => o.pending_send_hwm.get(),
        14 => ctx.world.obs.rndv_inflight_peak.load(Ordering::Relaxed),
        15 => ctx.world.obs.sched_builds.load(Ordering::Relaxed),
        16 => ctx.world.obs.sched_reuses.load(Ordering::Relaxed),
        17 => ctx.world.ranks_failed(),
        18 => ctx.obs.ops_failed_proc.get(),
        19 => ctx.world.obs.comms_revoked.load(Ordering::Relaxed),
        i @ 20..=25 => o.coll_sel[i - 20].get(),
        _ => 0,
    }
}

/// Take a named snapshot of every pvar (abibench provenance blocks and
/// diagnostics — no MPI_T session needed, values are absolute).
pub fn pvar_snapshot() -> Vec<(&'static str, u64)> {
    super::world::try_ctx(|ctx| match ctx {
        Some(ctx) => {
            (0..PVARS.len()).map(|i| (PVARS[i].name, pvar_value(ctx, i))).collect()
        }
        None => Vec::new(),
    })
}

// ---------------------------------------------------------------------------
// The MPI_T call subset (engine level)
// ---------------------------------------------------------------------------

fn t_check(ctx: &RankCtx) -> RC<()> {
    if ctx.obs.t_init_count.get() == 0 {
        return Err(err!(MPI_T_ERR_NOT_INITIALIZED));
    }
    Ok(())
}

/// `MPI_T_init_thread`: open one tools-interface epoch (refcounted,
/// independent of `MPI_Init`). Returns the provided thread level —
/// ranks are single-threaded here, so `MPI_THREAD_SINGLE`.
pub fn t_init_thread(_required: i32) -> RC<i32> {
    with_ctx(|ctx| {
        ctx.obs.t_init_count.set(ctx.obs.t_init_count.get() + 1);
        Ok(k::MPI_THREAD_SINGLE)
    })
}

/// `MPI_T_finalize`: close one epoch; the last close invalidates every
/// session and handle.
pub fn t_finalize() -> RC<()> {
    with_ctx(|ctx| {
        let n = ctx.obs.t_init_count.get();
        if n == 0 {
            return Err(err!(MPI_T_ERR_NOT_INITIALIZED));
        }
        ctx.obs.t_init_count.set(n - 1);
        if n == 1 {
            let mut st = ctx.obs.t_state.borrow_mut();
            st.sessions.clear();
            st.cvar_handles.clear();
        }
        Ok(())
    })
}

/// `MPI_T_cvar_get_num`.
pub fn t_cvar_get_num() -> RC<i32> {
    with_ctx(|ctx| {
        t_check(ctx)?;
        Ok(CVARS.len() as i32)
    })
}

/// `MPI_T_cvar_get_info`: (name, verbosity, bind, scope).
pub fn t_cvar_get_info(index: i32) -> RC<(String, i32, i32, i32)> {
    with_ctx(|ctx| {
        t_check(ctx)?;
        let d = usize::try_from(index)
            .ok()
            .and_then(|i| CVARS.get(i))
            .ok_or(err!(MPI_T_ERR_INVALID_INDEX))?;
        Ok((d.name.to_string(), d.verbosity, k::MPI_T_BIND_NO_OBJECT, d.scope))
    })
}

/// `MPI_T_cvar_handle_alloc` (bind is always `MPI_T_BIND_NO_OBJECT`).
pub fn t_cvar_handle_alloc(index: i32) -> RC<i32> {
    with_ctx(|ctx| {
        t_check(ctx)?;
        let i = usize::try_from(index).ok().filter(|&i| i < CVARS.len());
        let i = i.ok_or(err!(MPI_T_ERR_INVALID_INDEX))?;
        let mut st = ctx.obs.t_state.borrow_mut();
        st.cvar_handles.push(i);
        Ok(st.cvar_handles.len() as i32 - 1)
    })
}

fn cvar_of_handle(ctx: &RankCtx, handle: i32) -> RC<usize> {
    t_check(ctx)?;
    usize::try_from(handle)
        .ok()
        .and_then(|h| ctx.obs.t_state.borrow().cvar_handles.get(h).copied())
        .ok_or(err!(MPI_T_ERR_INVALID_HANDLE))
}

/// `MPI_T_cvar_read`.
pub fn t_cvar_read(handle: i32) -> RC<i64> {
    with_ctx(|ctx| {
        let i = cvar_of_handle(ctx, handle)?;
        Ok(match i {
            CVAR_RNDV_THRESHOLD => ctx.state.borrow().rndv_threshold as i64,
            CVAR_FLAT_MATCH => ctx.state.borrow().match_index.is_flat() as i64,
            CVAR_TRACE_ENABLED => ctx.obs.trace_on.get() as i64,
            CVAR_COLL_ALLREDUCE_ALGO => ctx.state.borrow().coll_algo.allreduce as i64,
            CVAR_COLL_ALLGATHER_ALGO => ctx.state.borrow().coll_algo.allgather as i64,
            CVAR_COLL_ALLTOALL_ALGO => ctx.state.borrow().coll_algo.alltoall as i64,
            _ => 0,
        })
    })
}

/// `MPI_T_cvar_write`. `rndv_threshold` takes effect immediately on
/// this rank's protocol switch; `flat_match` only changes the world
/// default for ranks bound later (a live matcher is fixed at bind);
/// `trace_enabled` is read-only.
pub fn t_cvar_write(handle: i32, value: i64) -> RC<()> {
    with_ctx(|ctx| {
        let i = cvar_of_handle(ctx, handle)?;
        if CVARS[i].scope == k::MPI_T_SCOPE_READONLY || CVARS[i].scope == k::MPI_T_SCOPE_CONSTANT {
            return Err(err!(MPI_T_ERR_CVAR_SET_NEVER));
        }
        if value < 0 {
            return Err(err!(MPI_ERR_ARG));
        }
        match i {
            CVAR_RNDV_THRESHOLD => {
                ctx.world.set_rndv_threshold(value as usize);
                ctx.state.borrow_mut().rndv_threshold = value as usize;
            }
            CVAR_FLAT_MATCH => ctx.world.set_flat_match(value != 0),
            CVAR_COLL_ALLREDUCE_ALGO | CVAR_COLL_ALLGATHER_ALGO | CVAR_COLL_ALLTOALL_ALGO => {
                if value > u8::MAX as i64 {
                    return Err(err!(MPI_ERR_ARG));
                }
                let mut force = ctx.state.borrow().coll_algo;
                match i {
                    CVAR_COLL_ALLREDUCE_ALGO => force.allreduce = value as u8,
                    CVAR_COLL_ALLGATHER_ALGO => force.allgather = value as u8,
                    _ => force.alltoall = value as u8,
                }
                ctx.world.set_coll_algo(force);
                ctx.state.borrow_mut().coll_algo = force;
            }
            _ => {}
        }
        Ok(())
    })
}

/// `MPI_T_pvar_get_num`.
pub fn t_pvar_get_num() -> RC<i32> {
    with_ctx(|ctx| {
        t_check(ctx)?;
        Ok(PVARS.len() as i32)
    })
}

/// `MPI_T_pvar_get_info`: (name, verbosity, class, bind).
pub fn t_pvar_get_info(index: i32) -> RC<(String, i32, i32, i32)> {
    with_ctx(|ctx| {
        t_check(ctx)?;
        let d = usize::try_from(index)
            .ok()
            .and_then(|i| PVARS.get(i))
            .ok_or(err!(MPI_T_ERR_INVALID_INDEX))?;
        Ok((d.name.to_string(), d.verbosity, d.class, k::MPI_T_BIND_NO_OBJECT))
    })
}

/// `MPI_T_pvar_session_create`.
pub fn t_pvar_session_create() -> RC<i32> {
    with_ctx(|ctx| {
        t_check(ctx)?;
        let mut st = ctx.obs.t_state.borrow_mut();
        st.sessions.push(PvarSession::default());
        Ok(st.sessions.len() as i32 - 1)
    })
}

fn check_session(ctx: &RankCtx, session: i32) -> RC<usize> {
    t_check(ctx)?;
    usize::try_from(session)
        .ok()
        .filter(|&s| s < ctx.obs.t_state.borrow().sessions.len())
        .ok_or(err!(MPI_T_ERR_INVALID_SESSION))
}

/// `MPI_T_pvar_handle_alloc`: bind pvar `index` into `session`. The
/// handle's COUNTER baseline starts here.
pub fn t_pvar_handle_alloc(session: i32, index: i32) -> RC<i32> {
    with_ctx(|ctx| {
        let s = check_session(ctx, session)?;
        let i = usize::try_from(index).ok().filter(|&i| i < PVARS.len());
        let i = i.ok_or(err!(MPI_T_ERR_INVALID_INDEX))?;
        let baseline = pvar_value(ctx, i);
        let mut st = ctx.obs.t_state.borrow_mut();
        let handles = &mut st.sessions[s].handles;
        handles.push(PvarHandle { index: i, baseline });
        Ok(handles.len() as i32 - 1)
    })
}

/// Resolve (session, handle) to the handle's pvar index, checking both.
fn resolve_handle(ctx: &RankCtx, session: i32, handle: i32) -> RC<(usize, usize)> {
    let s = check_session(ctx, session)?;
    let h = usize::try_from(handle)
        .ok()
        .filter(|&h| h < ctx.obs.t_state.borrow().sessions[s].handles.len())
        .ok_or(err!(MPI_T_ERR_INVALID_HANDLE))?;
    Ok((s, h))
}

/// `MPI_T_pvar_start`: re-baseline a COUNTER handle so reads measure
/// from this moment (LEVEL/HIGHWATERMARK variables are continuous —
/// start succeeds without effect).
pub fn t_pvar_start(session: i32, handle: i32) -> RC<()> {
    with_ctx(|ctx| {
        let (s, h) = resolve_handle(ctx, session, handle)?;
        let i = ctx.obs.t_state.borrow().sessions[s].handles[h].index;
        if PVARS[i].class == k::MPI_T_PVAR_CLASS_COUNTER {
            let v = pvar_value(ctx, i);
            ctx.obs.t_state.borrow_mut().sessions[s].handles[h].baseline = v;
        }
        Ok(())
    })
}

/// `MPI_T_pvar_read`: COUNTER handles read relative to their baseline;
/// LEVEL and HIGHWATERMARK handles read absolute.
pub fn t_pvar_read(session: i32, handle: i32) -> RC<i64> {
    with_ctx(|ctx| {
        let (s, h) = resolve_handle(ctx, session, handle)?;
        let (i, baseline) = {
            let st = ctx.obs.t_state.borrow();
            let ph = &st.sessions[s].handles[h];
            (ph.index, ph.baseline)
        };
        let v = pvar_value(ctx, i);
        Ok(if PVARS[i].class == k::MPI_T_PVAR_CLASS_COUNTER {
            v.saturating_sub(baseline) as i64
        } else {
            v as i64
        })
    })
}

/// `MPI_T_pvar_reset`: zero a COUNTER handle's view (re-baseline);
/// no-op success for the other classes.
pub fn t_pvar_reset(session: i32, handle: i32) -> RC<()> {
    t_pvar_start(session, handle)
}

// ---------------------------------------------------------------------------
// The trace ring
// ---------------------------------------------------------------------------

/// What happened, compactly. The two payload words `a`/`b` are
/// kind-specific (documented per variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// A receive was posted. `a` = context plane, `b` = tag
    /// (`u32::MAX` for `MPI_ANY_TAG`).
    Post,
    /// A message matched a receive. `a` = source world rank, `b` = tag.
    Match,
    /// Rendezvous RTS sent. `a` = destination world rank, `b` =
    /// announced total bytes (saturating).
    Rts,
    /// Rendezvous CTS sent (stream opened). `a` = sender world rank,
    /// `b` = initial credit bytes (saturating).
    Cts,
    /// Mid-stream credit re-grant. `a` = sender world rank, `b` = new
    /// cumulative credit bytes (saturating).
    ChunkGrant,
    /// A request completed and was retired. `a` = request id, `b` = 0.
    Complete,
    /// One collective-schedule step executed. `a` = context plane,
    /// `b` = algorithm id ([`COLL_ALGO_BINOMIAL`] etc., `0` for
    /// unstamped schedules) in the high byte and the program counter of
    /// the executed step in the low 24 bits.
    CollStep,
    /// RMA epoch transition. `a` = window id, `b` = 0 fence / 1 lock /
    /// 2 unlock.
    RmaEpoch,
}

impl TraceKind {
    /// Chrome trace-event name.
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Post => "post",
            TraceKind::Match => "match",
            TraceKind::Rts => "rts",
            TraceKind::Cts => "cts",
            TraceKind::ChunkGrant => "chunk-grant",
            TraceKind::Complete => "complete",
            TraceKind::CollStep => "coll-step",
            TraceKind::RmaEpoch => "rma-epoch",
        }
    }
}

/// One compact trace record: 16 bytes.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Nanoseconds since job start.
    pub ts_ns: u64,
    /// Event kind.
    pub kind: TraceKind,
    /// Kind-specific payload word (see [`TraceKind`]).
    pub a: u32,
    /// Kind-specific payload word (see [`TraceKind`]).
    pub b: u32,
}

/// Ring capacity per rank: bounded memory however long the job runs;
/// the oldest events are overwritten and counted as dropped.
pub const TRACE_RING_CAP: usize = 65536;

/// Fixed-capacity event ring. Chronological drain even after wrap.
pub struct TraceRing {
    events: Vec<TraceEvent>,
    cap: usize,
    /// Overwrite position once full (index of the *oldest* event).
    head: usize,
    /// Events overwritten after the ring filled.
    dropped: u64,
}

impl TraceRing {
    /// Empty ring with room for `cap` events.
    pub fn new(cap: usize) -> TraceRing {
        TraceRing { events: Vec::new(), cap: cap.max(1), head: 0, dropped: 0 }
    }

    /// Append, overwriting the oldest event when full.
    pub fn push(&mut self, e: TraceEvent) {
        if self.events.len() < self.cap {
            self.events.push(e);
        } else {
            self.events[self.head] = e;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events recorded (≤ capacity).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events overwritten after the ring filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Take everything, oldest first, leaving the ring empty.
    pub fn drain(&mut self) -> Vec<TraceEvent> {
        let head = std::mem::take(&mut self.head);
        let mut v = std::mem::take(&mut self.events);
        v.rotate_left(head);
        v
    }
}

/// Record one event — **the** hot-path entry: one branch on a cached
/// bool when tracing is off.
#[inline(always)]
pub(crate) fn trace(ctx: &RankCtx, kind: TraceKind, a: u32, b: u32) {
    if !ctx.obs.trace_on.get() {
        return;
    }
    trace_slow(ctx, kind, a, b);
}

#[cold]
fn trace_slow(ctx: &RankCtx, kind: TraceKind, a: u32, b: u32) {
    let ts_ns = ctx.world.elapsed_ns();
    ctx.obs.ring.borrow_mut().push(TraceEvent { ts_ns, kind, a, b });
}

/// Move this rank's recorded events into the world-level sink (called
/// at finalize and again — idempotently — at unbind, so sessions-only
/// apps are covered too). Empty rings push nothing.
pub(crate) fn flush_trace(ctx: &RankCtx) {
    let events = {
        let mut ring = ctx.obs.ring.borrow_mut();
        if ring.is_empty() {
            return;
        }
        ring.drain()
    };
    ctx.world.push_trace(ctx.rank, events);
}

/// The world-level merge sink: per-rank event batches, appended at
/// flush time, drained by the launcher's traced run path.
pub type TraceSink = Mutex<Vec<(usize, Vec<TraceEvent>)>>;

/// Render merged per-rank events as Chrome trace-event JSON (open in
/// `chrome://tracing` / Perfetto): instant events, one lane (`tid`)
/// per rank, timestamps in microseconds.
pub fn chrome_trace_json(ranks: &[(usize, Vec<TraceEvent>)]) -> String {
    let mut out = String::with_capacity(256 + ranks.iter().map(|(_, v)| v.len() * 96).sum::<usize>());
    out.push_str("{\n  \"displayTimeUnit\": \"ns\",\n  \"traceEvents\": [");
    let mut first = true;
    for (rank, events) in ranks {
        for e in events {
            if !first {
                out.push(',');
            }
            first = false;
            // ts is in microseconds by the trace-event spec; keep ns
            // resolution via the fractional part.
            let us = e.ts_ns as f64 / 1000.0;
            out.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"ph\": \"i\", \"s\": \"t\", \"ts\": {us:.3}, \
                 \"pid\": 0, \"tid\": {rank}, \"args\": {{\"a\": {}, \"b\": {}}}}}",
                e.kind.name(),
                e.a,
                e.b
            ));
        }
    }
    out.push_str("\n  ]\n}\n");
    out
}

/// Read the `MPI_ABI_TRACE` env flag (value `1` enables tracing).
pub fn trace_env() -> bool {
    std::env::var("MPI_ABI_TRACE").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts: u64) -> TraceEvent {
        TraceEvent { ts_ns: ts, kind: TraceKind::Post, a: 0, b: 0 }
    }

    #[test]
    fn ring_drains_chronologically_after_wrap() {
        let mut r = TraceRing::new(4);
        for t in 0..6 {
            r.push(ev(t));
        }
        assert_eq!(r.dropped(), 2);
        let got: Vec<u64> = r.drain().into_iter().map(|e| e.ts_ns).collect();
        assert_eq!(got, vec![2, 3, 4, 5]);
        assert!(r.is_empty());
    }

    #[test]
    fn ring_below_capacity_keeps_order() {
        let mut r = TraceRing::new(8);
        for t in [5, 1, 9] {
            r.push(ev(t));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 0);
        let got: Vec<u64> = r.drain().into_iter().map(|e| e.ts_ns).collect();
        assert_eq!(got, vec![5, 1, 9]);
    }

    #[test]
    fn registry_indices_are_stable_abi_surface() {
        // The exact order tools rely on; growing the table appends.
        let names: Vec<&str> = PVARS.iter().map(|p| p.name).collect();
        assert_eq!(
            names,
            vec![
                "sends_posted",
                "recvs_posted",
                "eager_msgs",
                "eager_bytes",
                "rndv_msgs",
                "rndv_bytes",
                "unexpected_depth",
                "unexpected_hwm",
                "posted_depth",
                "posted_hwm",
                "match_attempts",
                "wildcard_matches",
                "pending_send_depth",
                "pending_send_hwm",
                "rndv_inflight_peak",
                "sched_builds",
                "sched_reuses",
                "ranks_failed",
                "ops_failed_proc",
                "comms_revoked",
                "coll_sel_binomial",
                "coll_sel_ring",
                "coll_sel_recursive_doubling",
                "coll_sel_rabenseifner",
                "coll_sel_bruck",
                "coll_sel_pairwise",
            ]
        );
        assert_eq!(CVARS[CVAR_RNDV_THRESHOLD].name, "rndv_threshold");
        assert_eq!(CVARS[CVAR_FLAT_MATCH].name, "flat_match");
        assert_eq!(CVARS[CVAR_TRACE_ENABLED].name, "trace_enabled");
        assert_eq!(CVARS[CVAR_TRACE_ENABLED].scope, k::MPI_T_SCOPE_READONLY);
        assert_eq!(CVARS[CVAR_COLL_ALLREDUCE_ALGO].name, "coll_allreduce_algo");
        assert_eq!(CVARS[CVAR_COLL_ALLGATHER_ALGO].name, "coll_allgather_algo");
        assert_eq!(CVARS[CVAR_COLL_ALLTOALL_ALGO].name, "coll_alltoall_algo");
        assert_eq!(PVARS.len(), 20 + NUM_COLL_ALGOS);
        // Every class and verbosity is a legal constant.
        for p in PVARS {
            assert!((1..=3).contains(&p.class), "{}", p.name);
            assert!((1..=9).contains(&p.verbosity), "{}", p.name);
        }
    }

    #[test]
    fn chrome_json_shape() {
        let events = vec![(
            1usize,
            vec![
                TraceEvent { ts_ns: 1500, kind: TraceKind::Rts, a: 2, b: 4096 },
                TraceEvent { ts_ns: 2500, kind: TraceKind::Complete, a: 7, b: 0 },
            ],
        )];
        let json = chrome_trace_json(&events);
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"name\": \"rts\""));
        assert!(json.contains("\"ts\": 1.500"));
        assert!(json.contains("\"tid\": 1"));
        assert!(json.contains("\"ph\": \"i\""));
        // Empty input still renders a valid document.
        assert!(chrome_trace_json(&[]).contains("\"traceEvents\": [\n  ]"));
    }
}
