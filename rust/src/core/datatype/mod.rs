//! The datatype engine: builtin types, derived type constructors, and the
//! size/extent algebra.
//!
//! Derived datatypes are what make ABI translation of `alltoallw`-style
//! vector-of-datatype arguments interesting (§6.2), so the engine supports
//! the full constructor family: contiguous, vector/hvector,
//! indexed/hindexed, struct, resized, dup.

pub mod pack;

use once_cell::sync::Lazy;

use super::slab::Slab;
use super::world::with_ctx;
use super::{err, DtId, RC};
use crate::abi::datatypes as adt;

/// Scalar element classes, for reduction-op dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[allow(missing_docs)] // numeric variants name their machine type 1:1
pub enum ScalarKind {
    I8,
    U8,
    I16,
    U16,
    I32,
    U32,
    I64,
    U64,
    F32,
    F64,
    /// C `_Bool` / logical.
    Bool,
    /// (float, int) pair for MINLOC/MAXLOC.
    FloatInt,
    /// (double, int) pair.
    DoubleInt,
    /// (int, int) pair.
    IntInt,
    /// Untyped bytes (BYTE, CHAR, PACKED…): only bitwise ops legal-ish.
    Bytes,
}

/// Structure of a datatype.
#[allow(missing_docs)] // constructor parameters; the variant docs name them
pub enum TypeKind {
    /// Predefined scalar; `abi_dt` is the standard-ABI constant (canonical
    /// name of the builtin, independent of which impl ABI is in use).
    Builtin { abi_dt: usize },
    /// `count` back-to-back children (`MPI_Type_contiguous`).
    Contiguous { count: usize, child: DtId },
    /// `count` blocks of `blocklen` children, `stride_bytes` apart
    /// (stride given in elements for Vector, bytes for Hvector).
    Vector { count: usize, blocklen: usize, stride_bytes: isize, child: DtId },
    /// Blocks of (blocklen, displacement-in-bytes).
    Indexed { blocks: Vec<(usize, isize)>, child: DtId },
    /// Blocks of (blocklen, displacement-in-bytes, type).
    Struct { blocks: Vec<(usize, isize, DtId)> },
    /// `MPI_Type_create_resized`: child with overridden lb/extent.
    Resized { child: DtId },
    /// `MPI_Type_dup`.
    Dup { child: DtId },
}

/// A datatype object.
pub struct DatatypeObj {
    /// The typemap structure.
    pub kind: TypeKind,
    /// Packed payload bytes per item.
    pub size: usize,
    /// Memory span per item (for iterating arrays of this type).
    pub extent: isize,
    /// Lower bound (byte offset of the first element).
    pub lb: isize,
    /// `MPI_Type_commit` was called.
    pub committed: bool,
    /// Predefined datatypes are not freeable.
    pub predefined: bool,
    /// `MPI_Type_get_envelope` combiner.
    pub combiner: i32,
    /// `true` iff memory layout == packed layout (no holes): enables the
    /// single-memcpy send fast path.
    pub contiguous: bool,
    /// Cached **pack plan**: the typemap flattened once, at construction,
    /// into `(byte offset, length)` contiguous runs of one item.
    /// Pack/unpack walk this list instead of recursing the typemap on
    /// every call — the amortization that makes persistent operations
    /// (and every collective's accumulator staging) cheap. `None` for
    /// typemaps that flatten to more than [`PLAN_MAX_SEGMENTS`] runs;
    /// those take the recursive path.
    pub plan: Option<Vec<(isize, usize)>>,
}

/// Cap on cached pack-plan segments. A typemap that flattens to more
/// contiguous runs than this is packed recursively instead — the cache
/// would cost more memory than the traversal saves.
pub const PLAN_MAX_SEGMENTS: usize = 256;

/// Append a run to a plan under construction, merging with the previous
/// run when memory-adjacent (keeps plans short for contiguous layouts).
/// `None` = segment budget exceeded.
fn plan_push(out: &mut Vec<(isize, usize)>, off: isize, len: usize) -> Option<()> {
    if len == 0 {
        return Some(());
    }
    if let Some(last) = out.last_mut() {
        if last.0 + last.1 as isize == off {
            last.1 += len;
            return Some(());
        }
    }
    if out.len() >= PLAN_MAX_SEGMENTS {
        return None;
    }
    out.push((off, len));
    Some(())
}

/// Splice a child's cached plan at byte offset `base`. Children are
/// always constructed (and planned) before their parents, so an
/// unplannable child makes the parent unplannable too.
fn plan_splice(
    dtypes: &Slab<DatatypeObj>,
    child: DtId,
    base: isize,
    out: &mut Vec<(isize, usize)>,
) -> Option<()> {
    let c = dtypes.get(child.0)?;
    let p = c.plan.as_ref()?;
    for &(off, len) in p {
        plan_push(out, base + off, len)?;
    }
    Some(())
}

/// Flatten `obj`'s typemap into a pack plan (pack order = typemap
/// order). Returns `None` when the layout exceeds the segment budget.
fn build_plan(dtypes: &Slab<DatatypeObj>, obj: &DatatypeObj) -> Option<Vec<(isize, usize)>> {
    let mut out = Vec::new();
    match &obj.kind {
        TypeKind::Builtin { .. } => {
            plan_push(&mut out, 0, obj.size)?;
        }
        TypeKind::Contiguous { count, child } => {
            let cext = dtypes.get(child.0)?.extent;
            for i in 0..*count {
                plan_splice(dtypes, *child, cext * i as isize, &mut out)?;
            }
        }
        TypeKind::Vector { count, blocklen, stride_bytes, child } => {
            let cext = dtypes.get(child.0)?.extent;
            for i in 0..*count {
                let b = *stride_bytes * i as isize;
                for j in 0..*blocklen {
                    plan_splice(dtypes, *child, b + cext * j as isize, &mut out)?;
                }
            }
        }
        TypeKind::Indexed { blocks, child } => {
            let cext = dtypes.get(child.0)?.extent;
            for &(len, disp) in blocks {
                for j in 0..len {
                    plan_splice(dtypes, *child, disp + cext * j as isize, &mut out)?;
                }
            }
        }
        TypeKind::Struct { blocks } => {
            for &(len, disp, t) in blocks {
                let cext = dtypes.get(t.0)?.extent;
                for j in 0..len {
                    plan_splice(dtypes, t, disp + cext * j as isize, &mut out)?;
                }
            }
        }
        TypeKind::Resized { child } | TypeKind::Dup { child } => {
            plan_splice(dtypes, *child, 0, &mut out)?;
        }
    }
    Some(out)
}

/// Install all builtin datatypes at their reserved ids
/// (index in [`adt::PREDEFINED_DATATYPES`]).
pub fn install_predefined(dtypes: &mut Slab<DatatypeObj>) {
    for (i, &(_, abi_dt)) in adt::PREDEFINED_DATATYPES.iter().enumerate() {
        let size = adt::platform_size_of(abi_dt).unwrap_or(0);
        let plan = if size > 0 { vec![(0, size)] } else { Vec::new() };
        dtypes.insert_at(
            i as u32,
            DatatypeObj {
                kind: TypeKind::Builtin { abi_dt },
                size,
                extent: size as isize,
                lb: 0,
                committed: true,
                predefined: true,
                combiner: crate::abi::constants::MPI_COMBINER_NAMED,
                contiguous: true,
                plan: Some(plan),
            },
        );
    }
}

/// Builtin dt id (slab index) for a standard-ABI datatype constant.
/// O(1): a 1024-entry table indexed by the Huffman value.
pub fn builtin_id_of_abi(abi_dt: usize) -> Option<DtId> {
    static TABLE: Lazy<[u16; 1024]> = Lazy::new(|| {
        let mut t = [u16::MAX; 1024];
        for (i, &(_, v)) in adt::PREDEFINED_DATATYPES.iter().enumerate() {
            t[v] = i as u16;
        }
        t
    });
    if abi_dt < 1024 {
        let i = TABLE[abi_dt];
        (i != u16::MAX).then(|| DtId(i as u32))
    } else {
        None
    }
}

/// Standard-ABI constant of a builtin dt id (inverse of
/// [`builtin_id_of_abi`]).
pub fn abi_of_builtin_id(dt: DtId) -> Option<usize> {
    adt::PREDEFINED_DATATYPES.get(dt.0 as usize).map(|&(_, v)| v)
}

/// Scalar kind of a *builtin* standard-ABI datatype.
pub fn scalar_kind(abi_dt: usize) -> ScalarKind {
    use ScalarKind::*;
    match abi_dt {
        adt::MPI_INT8_T | adt::MPI_SIGNED_CHAR => I8,
        adt::MPI_UINT8_T | adt::MPI_UNSIGNED_CHAR => U8,
        adt::MPI_INT16_T | adt::MPI_SHORT => I16,
        adt::MPI_UINT16_T | adt::MPI_UNSIGNED_SHORT => U16,
        adt::MPI_INT32_T | adt::MPI_INT | adt::MPI_INTEGER => I32,
        adt::MPI_UINT32_T | adt::MPI_UNSIGNED => U32,
        adt::MPI_INT64_T | adt::MPI_LONG | adt::MPI_LONG_LONG | adt::MPI_AINT
        | adt::MPI_COUNT | adt::MPI_OFFSET => I64,
        adt::MPI_UINT64_T | adt::MPI_UNSIGNED_LONG | adt::MPI_UNSIGNED_LONG_LONG => U64,
        adt::MPI_FLOAT | adt::MPI_FLOAT32_T | adt::MPI_REAL => F32,
        adt::MPI_DOUBLE | adt::MPI_FLOAT64_T | adt::MPI_DOUBLE_PRECISION => F64,
        adt::MPI_C_BOOL | adt::MPI_LOGICAL => Bool,
        adt::MPI_FLOAT_INT => FloatInt,
        adt::MPI_DOUBLE_INT => DoubleInt,
        adt::MPI_2INT => IntInt,
        _ => Bytes,
    }
}

pub(crate) fn get_obj<R>(dt: DtId, f: impl FnOnce(&DatatypeObj) -> R) -> RC<R> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        Ok(f(t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?))
    })
}

/// `MPI_Type_size`.
#[inline]
pub fn type_size(dt: DtId) -> RC<usize> {
    get_obj(dt, |o| o.size)
}

/// `MPI_Type_get_extent` → (lb, extent).
pub fn type_get_extent(dt: DtId) -> RC<(isize, isize)> {
    get_obj(dt, |o| (o.lb, o.extent))
}

/// `MPI_Type_get_envelope` (combiner only; reconstruction args omitted).
pub fn type_get_combiner(dt: DtId) -> RC<i32> {
    get_obj(dt, |o| o.combiner)
}

/// `MPI_Type_commit`.
pub fn type_commit(dt: DtId) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        t.dtypes.get_mut(dt.0).ok_or(err!(MPI_ERR_TYPE))?.committed = true;
        Ok(())
    })
}

/// `MPI_Type_free`.
pub fn type_free(dt: DtId) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        match t.dtypes.get(dt.0) {
            Some(o) if o.predefined => Err(err!(MPI_ERR_TYPE)),
            Some(_) => {
                t.dtypes.remove(dt.0);
                Ok(())
            }
            None => Err(err!(MPI_ERR_TYPE)),
        }
    })
}

fn insert(mut obj: DatatypeObj) -> RC<DtId> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        // Flatten the typemap once, at construction: every later
        // pack/unpack of this type walks the cached plan.
        obj.plan = build_plan(&t.dtypes, &obj);
        Ok(DtId(t.dtypes.insert(obj)))
    })
}

fn child_props(child: DtId) -> RC<(usize, isize, isize, bool)> {
    get_obj(child, |o| (o.size, o.extent, o.lb, o.contiguous))
}

/// `MPI_Type_contiguous`.
pub fn type_contiguous(count: usize, child: DtId) -> RC<DtId> {
    let (csize, cext, clb, ccontig) = child_props(child)?;
    insert(DatatypeObj {
        kind: TypeKind::Contiguous { count, child },
        size: csize * count,
        extent: cext * count as isize,
        lb: clb,
        committed: false,
        predefined: false,
        combiner: crate::abi::constants::MPI_COMBINER_CONTIGUOUS,
        plan: None,
        contiguous: ccontig && cext == csize as isize,
    })
}

/// `MPI_Type_vector` (stride in elements).
pub fn type_vector(count: usize, blocklen: usize, stride: isize, child: DtId) -> RC<DtId> {
    let (_, cext, _, _) = child_props(child)?;
    type_hvector_bytes(
        count,
        blocklen,
        stride * cext,
        child,
        crate::abi::constants::MPI_COMBINER_VECTOR,
    )
}

/// `MPI_Type_create_hvector` (stride in bytes).
pub fn type_hvector(count: usize, blocklen: usize, stride_bytes: isize, child: DtId) -> RC<DtId> {
    type_hvector_bytes(
        count,
        blocklen,
        stride_bytes,
        child,
        crate::abi::constants::MPI_COMBINER_HVECTOR,
    )
}

fn type_hvector_bytes(
    count: usize,
    blocklen: usize,
    stride_bytes: isize,
    child: DtId,
    combiner: i32,
) -> RC<DtId> {
    let (csize, cext, clb, _) = child_props(child)?;
    let block_span = blocklen as isize * cext;
    let (mut lo, mut hi) = (clb, block_span);
    if count > 0 {
        let last = (count - 1) as isize * stride_bytes;
        lo = lo.min(clb + last.min(0));
        hi = hi.max(last + block_span);
    }
    insert(DatatypeObj {
        kind: TypeKind::Vector { count, blocklen, stride_bytes, child },
        size: csize * blocklen * count,
        extent: hi - lo.min(0),
        lb: lo.min(0),
        committed: false,
        predefined: false,
        combiner,
        plan: None,
        contiguous: false,
    })
}

/// `MPI_Type_indexed` (displacements in elements of `child`).
pub fn type_indexed(blocks: &[(usize, isize)], child: DtId) -> RC<DtId> {
    let (_, cext, _, _) = child_props(child)?;
    let byte_blocks: Vec<(usize, isize)> =
        blocks.iter().map(|&(len, disp)| (len, disp * cext)).collect();
    indexed_common(byte_blocks, child, crate::abi::constants::MPI_COMBINER_INDEXED)
}

/// `MPI_Type_create_hindexed` (displacements in bytes).
pub fn type_hindexed(blocks: &[(usize, isize)], child: DtId) -> RC<DtId> {
    indexed_common(blocks.to_vec(), child, crate::abi::constants::MPI_COMBINER_HINDEXED)
}

fn indexed_common(blocks: Vec<(usize, isize)>, child: DtId, combiner: i32) -> RC<DtId> {
    let (csize, cext, _, _) = child_props(child)?;
    let size = blocks.iter().map(|&(len, _)| len * csize).sum();
    let mut lo = 0isize;
    let mut hi = 0isize;
    for &(len, disp) in &blocks {
        lo = lo.min(disp);
        hi = hi.max(disp + len as isize * cext);
    }
    insert(DatatypeObj {
        kind: TypeKind::Indexed { blocks, child },
        size,
        extent: hi - lo,
        lb: lo,
        committed: false,
        predefined: false,
        combiner,
        plan: None,
        contiguous: false,
    })
}

/// `MPI_Type_create_struct`.
pub fn type_struct(blocks: &[(usize, isize, DtId)]) -> RC<DtId> {
    let mut size = 0usize;
    let mut lo = 0isize;
    let mut hi = 0isize;
    for &(len, disp, t) in blocks {
        let (csize, cext, clb, _) = child_props(t)?;
        size += len * csize;
        lo = lo.min(disp + clb);
        hi = hi.max(disp + len as isize * cext);
    }
    insert(DatatypeObj {
        kind: TypeKind::Struct { blocks: blocks.to_vec() },
        size,
        extent: hi - lo,
        lb: lo,
        committed: false,
        predefined: false,
        combiner: crate::abi::constants::MPI_COMBINER_STRUCT,
        plan: None,
        contiguous: false,
    })
}

/// `MPI_Type_create_resized`.
pub fn type_resized(child: DtId, lb: isize, extent: isize) -> RC<DtId> {
    let (csize, _, _, _) = child_props(child)?;
    insert(DatatypeObj {
        kind: TypeKind::Resized { child },
        size: csize,
        extent,
        lb,
        committed: false,
        predefined: false,
        combiner: crate::abi::constants::MPI_COMBINER_RESIZED,
        plan: None,
        contiguous: false,
    })
}

/// `MPI_Type_dup`.
pub fn type_dup(child: DtId) -> RC<DtId> {
    let (csize, cext, clb, ccontig) = child_props(child)?;
    insert(DatatypeObj {
        kind: TypeKind::Dup { child },
        size: csize,
        extent: cext,
        lb: clb,
        committed: true,
        predefined: false,
        combiner: crate::abi::constants::MPI_COMBINER_DUP,
        plan: None,
        contiguous: ccontig,
    })
}

/// Flatten `count` items of `dt` into absolute `(byte offset, length)`
/// runs — the cached pack plan repeated at the type's extent stride.
/// This is how RMA describes a *target* layout on the wire: the origin
/// flattens its (origin-side) description of the target datatype and the
/// target applies plain byte runs, never needing the origin's handle.
/// Errors with `MPI_ERR_TYPE` for typemaps too irregular to plan
/// (beyond [`PLAN_MAX_SEGMENTS`] runs).
pub fn flatten(dt: DtId, count: usize) -> RC<Vec<(isize, usize)>> {
    get_obj(dt, |o| {
        let plan = o.plan.as_ref().ok_or(err!(MPI_ERR_TYPE))?;
        let mut out = Vec::with_capacity(plan.len() * count);
        for i in 0..count {
            let base = o.extent * i as isize;
            for &(off, len) in plan {
                // Re-merge runs that become adjacent across items.
                if let Some((loff, llen)) = out.last_mut() {
                    if *loff + *llen as isize == base + off {
                        *llen += len;
                        continue;
                    }
                }
                out.push((base + off, len));
            }
        }
        Ok(out)
    })?
}

/// Sizes (bytes) of the *basic elements* of one item of `dt`, in typemap
/// order — what `MPI_Get_elements` counts. Pair types (`MPI_FLOAT_INT`,
/// …) contribute their two components separately.
pub fn leaf_sizes(dt: DtId) -> RC<Vec<usize>> {
    enum Step {
        Leaf(Vec<usize>),
        Repeat(DtId, usize),
        Blocks(Vec<(usize, DtId)>),
    }
    let step = get_obj(dt, |o| match &o.kind {
        TypeKind::Builtin { abi_dt } => Step::Leaf(builtin_leaves(*abi_dt, o.size)),
        TypeKind::Contiguous { count, child } => Step::Repeat(*child, *count),
        TypeKind::Vector { count, blocklen, child, .. } => {
            Step::Repeat(*child, count * blocklen)
        }
        TypeKind::Indexed { blocks, child } => {
            Step::Repeat(*child, blocks.iter().map(|&(len, _)| len).sum())
        }
        TypeKind::Struct { blocks } => {
            Step::Blocks(blocks.iter().map(|&(len, _, t)| (len, t)).collect())
        }
        TypeKind::Resized { child } | TypeKind::Dup { child } => Step::Repeat(*child, 1),
    })?;
    match step {
        Step::Leaf(v) => Ok(v),
        Step::Repeat(child, repeat) => {
            let inner = leaf_sizes(child)?;
            let mut out = Vec::with_capacity(inner.len() * repeat);
            for _ in 0..repeat {
                out.extend_from_slice(&inner);
            }
            Ok(out)
        }
        Step::Blocks(blocks) => {
            let mut out = Vec::new();
            for (len, t) in blocks {
                let inner = leaf_sizes(t)?;
                for _ in 0..len {
                    out.extend_from_slice(&inner);
                }
            }
            Ok(out)
        }
    }
}

/// Basic-element decomposition of a builtin: every MINLOC/MAXLOC pair
/// type splits into its two components (including the ones
/// [`scalar_kind`] lumps into `Bytes`); every other builtin is a single
/// element of its own size.
fn builtin_leaves(abi_dt: usize, size: usize) -> Vec<usize> {
    match abi_dt {
        adt::MPI_LONG_INT => vec![size - 4, 4], // (long, int); long is platform-wide
        adt::MPI_SHORT_INT => vec![2, 4],
        adt::MPI_LONG_DOUBLE_INT => vec![size - 4, 4],
        adt::MPI_2REAL => vec![4, 4],
        adt::MPI_2DOUBLE_PRECISION => vec![8, 8],
        adt::MPI_2INTEGER => vec![4, 4],
        _ => match scalar_kind(abi_dt) {
            ScalarKind::FloatInt => vec![4, 4],
            ScalarKind::DoubleInt => vec![8, 4],
            ScalarKind::IntInt => vec![4, 4],
            _ => vec![size],
        },
    }
}

/// Leaf builtin of a (possibly nested) datatype, if it reduces to a single
/// uniform builtin — used by the reduction-op engine.
pub fn leaf_builtin(dt: DtId) -> RC<Option<usize>> {
    let kind_child = get_obj(dt, |o| match &o.kind {
        TypeKind::Builtin { abi_dt } => Ok(Some(*abi_dt)),
        TypeKind::Contiguous { child, .. }
        | TypeKind::Vector { child, .. }
        | TypeKind::Indexed { child, .. }
        | TypeKind::Resized { child }
        | TypeKind::Dup { child } => Err(*child),
        TypeKind::Struct { .. } => Ok(None),
    })?;
    match kind_child {
        Ok(v) => Ok(v),
        Err(child) => leaf_builtin(child),
    }
}
