//! The ABI-agnostic MPI test suite (E4).
//!
//! §6.2 reports that the MPICH test suite originally *assumed the MPICH
//! ABI* and could not validate other ABIs or translation layers; the
//! fixed suite plus IMB/OMB is what Mukautuva passes. This module is
//! that artifact for our system: every test is written against the
//! portable [`MpiAbi`] surface only (no representation assumptions), so
//! the same source runs against all five configurations:
//! `mpich`, `ompi`, `muk(mpich)`, `muk(ompi)`, and the native `abi`.
//!
//! Tests are *collective*: every rank of the job runs [`run_all`] and
//! each test body executes on all ranks (like an MPICH test binary under
//! `mpiexec`). Results are combined with a logical-AND allreduce so every
//! rank reports the same verdict.

mod bigcount;
mod coll;
mod comm_attr;
mod dtype;
mod env;
mod matching;
mod mpi_t;
mod persistent;
mod pt2pt;
mod rma;
mod session;
pub mod ulfm;

use crate::api::MpiAbi;

/// Outcome of one test on this rank.
#[derive(Clone, Debug)]
pub struct TestResult {
    pub name: &'static str,
    pub passed: bool,
    pub message: String,
}

/// A suite test: runs on every rank; `Err` = failure message.
/// Generic test fns monomorphized for an ABI coerce to this.
pub type TestFn = fn(usize) -> Result<(), String>;

/// The full registry, in execution order.
pub fn registry<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    let mut v: Vec<(&'static str, TestFn)> = Vec::new();
    v.extend(env::tests::<A>());
    v.extend(pt2pt::tests::<A>());
    v.extend(matching::tests::<A>());
    v.extend(persistent::tests::<A>());
    v.extend(dtype::tests::<A>());
    v.extend(coll::tests::<A>());
    v.extend(comm_attr::tests::<A>());
    v.extend(rma::tests::<A>());
    v.extend(session::tests::<A>());
    v.extend(mpi_t::tests::<A>());
    v
}

/// The large-count battery alone (`MPI_Count` round-trips above
/// `INT_MAX`, sparse > 2 GiB-logical transfers, `MPI_Aint`
/// displacements beyond 2 GiB) — run standalone under all five ABI
/// configs and both transports by `tests/bigcount.rs`. Not part of
/// [`registry`]: its sparse multi-GiB virtual allocations are
/// per-battery, not per-suite-run.
pub fn bigcount_registry<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    bigcount::tests::<A>()
}

/// The sessions battery alone (init/finalize ordering, pset queries,
/// `MPI_Comm_create_from_group` tag disambiguation) — what the CI
/// `sessions` job runs per ABI config via `tests/sessions.rs`.
pub fn session_registry<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    session::tests::<A>()
}

/// The MPI_T battery alone (registry enumeration, error paths, and the
/// scripted exchange with bitwise-exact counter pvars) — run standalone
/// under all five ABI configs *and both transports* by `tests/mpi_t.rs`
/// and the CI `observability` job.
pub fn mpi_t_registry<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    mpi_t::tests::<A>()
}

/// The message-matching battery alone (posted order × arrival order
/// under every wildcard interleaving, across two context planes) — run
/// standalone under all five ABI configs *and both transports* by
/// `tests/matching.rs`.
pub fn matching_registry<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    matching::tests::<A>()
}

/// The ULFM fault-tolerance battery. **Not** part of [`registry`]: each
/// scenario launches its own job with a [`crate::launcher::JobSpec`]
/// kill spec (the AND-allreduce harness is itself a collective a dead
/// rank would poison). Run under all five ABI configs *and both
/// transports* by `tests/ulfm.rs` and the CI `fault-tolerance` job.
pub fn ulfm_scenarios<A: MpiAbi>() -> Vec<(&'static str, ulfm::UlfmScenario)> {
    ulfm::scenarios::<A>()
}

/// Run the whole suite under ABI `A`. Call from every rank of a running
/// job *after* `A::init()`. Returns per-test results (identical on all
/// ranks: verdicts are AND-reduced).
pub fn run_all<A: MpiAbi>(rank: usize) -> Vec<TestResult> {
    run_registry::<A>(rank, registry::<A>())
}

/// Run an explicit test list (the full [`registry`] or a focused one
/// like [`session_registry`]) with the usual AND-reduced verdicts.
pub fn run_registry<A: MpiAbi>(
    rank: usize,
    tests: Vec<(&'static str, TestFn)>,
) -> Vec<TestResult> {
    let mut results = Vec::new();
    for (name, f) in tests {
        let local = f(rank);
        // Synchronize & combine verdicts: 1 = pass.
        let mine: i32 = if local.is_ok() { 1 } else { 0 };
        let mut all: i32 = 0;
        let rc = A::allreduce(
            &mine as *const i32 as *const u8,
            &mut all as *mut i32 as *mut u8,
            1,
            A::datatype(crate::api::Dt::Int),
            A::op(crate::api::OpName::Min),
            A::comm_world(),
        );
        let passed = rc == 0 && all == 1;
        results.push(TestResult {
            name,
            passed,
            message: match local {
                Ok(()) if passed => String::new(),
                Ok(()) => "failed on another rank".to_string(),
                Err(m) => m,
            },
        });
    }
    results
}

/// Render a suite report (rank 0 of the job usually prints this).
pub fn report(abi_name: &str, results: &[TestResult]) -> String {
    let passed = results.iter().filter(|r| r.passed).count();
    let mut out = format!("== test suite [{abi_name}]: {passed}/{} passed ==\n", results.len());
    for r in results {
        if r.passed {
            out.push_str(&format!("  ok   {}\n", r.name));
        } else {
            out.push_str(&format!("  FAIL {} — {}\n", r.name, r.message));
        }
    }
    out
}

/// Helpers shared by the test modules.
pub(crate) mod util {
    /// Assert-style helper returning Err instead of panicking (a panic
    /// would abort the whole job and mask which test failed).
    macro_rules! check {
        ($cond:expr, $($fmt:tt)*) => {
            if !($cond) {
                return Err(format!($($fmt)*));
            }
        };
    }
    macro_rules! check_rc {
        ($rc:expr, $what:expr) => {{
            let rc = $rc;
            if rc != 0 {
                return Err(format!("{} returned rc {}", $what, rc));
            }
        }};
    }
    pub(crate) use check;
    pub(crate) use check_rc;

    pub fn ptr<T>(v: &T) -> *const u8 {
        v as *const T as *const u8
    }

    pub fn ptr_mut<T>(v: &mut T) -> *mut u8 {
        v as *mut T as *mut u8
    }

    pub fn slice_ptr<T>(v: &[T]) -> *const u8 {
        v.as_ptr() as *const u8
    }

    pub fn slice_ptr_mut<T>(v: &mut [T]) -> *mut u8 {
        v.as_mut_ptr() as *mut u8
    }
}
