//! Collective operations, expressed as per-rank schedules over each
//! communicator's dedicated collective context plane.
//!
//! Algorithms: dissemination barrier, binomial-tree bcast/reduce,
//! linear (root-rooted) gather/scatter familes, linear scan — plus
//! *selectable* variants for the unrooted heavyweights: allreduce
//! (binomial reduce+bcast, ring, recursive doubling, Rabenseifner),
//! allgather(v) (gather+bcast, ring) and uniform alltoall (pairwise,
//! Bruck). A tuning table keyed on (packed bytes, comm size) picks the
//! variant per call — see [`pick_allreduce`] & co. — overridable
//! per-operation through the `coll_*_algo` cvars and the
//! `MPI_ABI_COLL_ALGO` environment variable, so tests can force every
//! choice. All collectives advance a per-comm collective tag so
//! consecutive collectives never cross-match.
//!
//! Every algorithm lives exactly once, as a schedule builder in
//! [`sched`]; the nonblocking entry points (`ibcast`, `iallreduce`, …)
//! return the schedule's request, and the blocking entry points are
//! `wait(i<coll>())` over the same schedules.

mod alltoall;
mod bcast_reduce;
mod gather_scatter;
pub mod sched;

pub use alltoall::{alltoall, alltoall_bytes, alltoallv, alltoallw, AlltoallwArgs};
pub use bcast_reduce::{allreduce, bcast, exscan, reduce, reduce_scatter_block, scan};
pub use gather_scatter::{allgather, allgatherv, gather, gatherv, scatter, scatterv};
pub use sched::{
    iallgather, iallgatherv, iallreduce, ialltoall, ialltoallv, ialltoallw, ibarrier, ibcast,
    iexscan, igather, igatherv, ireduce, ireduce_scatter_block, iscan, iscatter, iscatterv,
};
pub use sched::{
    allreduce_init, alltoall_init, barrier_init, bcast_init, gather_init, scatter_init,
    schedules_built,
};

use super::comm::{advance_coll_tag, comm_snapshot};
use super::request::{enqueue_send, progress};
use super::transport::{Envelope, MsgKind, Payload};
use super::world::{with_ctx, RankCtx};
use super::{err, CommId, DtId, MpiError, RC, ReqId};

/// Snapshot of what a collective needs: members, my comm rank, the
/// collective context id, and this collective's tag.
pub(crate) struct CollCtx {
    pub members: Vec<usize>,
    pub my_rank: usize,
    pub context: u32,
    pub tag: i32,
}

impl CollCtx {
    pub fn size(&self) -> usize {
        self.members.len()
    }
}

/// Begin a collective on `comm` (advances the collective sequence).
///
/// The returned tag is the collective's *base* tag; each collective may
/// use up to [`PHASES_PER_COLL`] consecutive tags (`base..base+32`) for
/// internal rounds (e.g. dissemination-barrier rounds), guaranteed not to
/// collide with neighbouring collectives on the same comm.
pub(crate) fn coll_begin(comm: CommId) -> RC<CollCtx> {
    let (members, my_rank, _p, context) = comm_snapshot(comm)?;
    let seq = advance_coll_tag(comm)?;
    Ok(CollCtx { members, my_rank, context, tag: (seq & 0xFF_FFFF) * PHASES_PER_COLL })
}

/// Tag slots reserved per collective for internal phases/rounds.
pub(crate) const PHASES_PER_COLL: i32 = 32;

// ---------------------------------------------------------------------------
// Collective algorithm selection
// ---------------------------------------------------------------------------

/// Per-operation algorithm overrides: 0 = auto (tuning table), else one
/// of the per-op force codes below. Carried on the [`World`] as the job
/// default (set from `MPI_ABI_COLL_ALGO` or
/// [`crate::launcher::JobSpec::with_coll_algo`]), copied per rank at
/// bind, and writable per rank through the `coll_*_algo` cvars.
///
/// [`World`]: crate::core::world::World
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CollAlgoForce {
    pub allreduce: u8,
    pub allgather: u8,
    pub alltoall: u8,
}

impl CollAlgoForce {
    /// Pack into one `u32` (the [`World`] stores it in a single atomic).
    ///
    /// [`World`]: crate::core::world::World
    pub fn pack(self) -> u32 {
        (self.allreduce as u32) | ((self.allgather as u32) << 8) | ((self.alltoall as u32) << 16)
    }

    pub fn unpack(v: u32) -> CollAlgoForce {
        CollAlgoForce {
            allreduce: (v & 0xFF) as u8,
            allgather: ((v >> 8) & 0xFF) as u8,
            alltoall: ((v >> 16) & 0xFF) as u8,
        }
    }
}

/// Auto-select from the tuning table (the zero value of every force code).
pub const COLL_AUTO: u8 = 0;
/// Allreduce force codes (cvar `coll_allreduce_algo`).
pub const ALLREDUCE_BINOMIAL: u8 = 1;
pub const ALLREDUCE_RING: u8 = 2;
pub const ALLREDUCE_RECURSIVE_DOUBLING: u8 = 3;
pub const ALLREDUCE_RABENSEIFNER: u8 = 4;
/// Allgather(v) force codes (cvar `coll_allgather_algo`).
pub const ALLGATHER_GATHER_BCAST: u8 = 1;
pub const ALLGATHER_RING: u8 = 2;
/// Uniform-alltoall force codes (cvar `coll_alltoall_algo`).
pub const ALLTOALL_PAIRWISE: u8 = 1;
pub const ALLTOALL_BRUCK: u8 = 2;

/// One tuning-table band: the first row whose bounds cover the call's
/// (packed bytes, comm size) wins. Bounds are inclusive.
struct CollTuneRow {
    max_bytes: usize,
    max_ranks: usize,
    algo: u8,
}

/// Allreduce tuning: latency-bound small messages take recursive
/// doubling (⌈log2 n⌉ rounds — half the binomial reduce+bcast depth at
/// every comm size, so there is no small-n binomial band), the mid band
/// takes Rabenseifner (log rounds at half the data per round), and
/// large messages at scale take the bandwidth-optimal ring. n ≤ 2 is
/// forced binomial by [`pick_allreduce`] before the table is consulted.
const ALLREDUCE_TUNING: &[CollTuneRow] = &[
    CollTuneRow { max_bytes: 2048, max_ranks: usize::MAX, algo: ALLREDUCE_RECURSIVE_DOUBLING },
    CollTuneRow { max_bytes: 64 * 1024, max_ranks: usize::MAX, algo: ALLREDUCE_RABENSEIFNER },
    CollTuneRow { max_bytes: usize::MAX, max_ranks: 8, algo: ALLREDUCE_RABENSEIFNER },
    CollTuneRow { max_bytes: usize::MAX, max_ranks: usize::MAX, algo: ALLREDUCE_RING },
];

/// Allgather tuning: tiny comms take the ring outright (n−1 rounds ≤
/// the two binomial trees' 2·⌈log2 n⌉ when n ≤ 8), mid-size comms with
/// small totals keep the gather+bcast baseline (2·⌈log2 n⌉ envelopes
/// beat the ring's n−1 while envelope cost dominates payload cost),
/// and large totals take the ring at every size (no root hotspot, each
/// link carries the total exactly once instead of the bcast tree's
/// log2 n times).
const ALLGATHER_TUNING: &[CollTuneRow] = &[
    CollTuneRow { max_bytes: 32 * 1024, max_ranks: 8, algo: ALLGATHER_RING },
    CollTuneRow { max_bytes: 32 * 1024, max_ranks: usize::MAX, algo: ALLGATHER_GATHER_BCAST },
    CollTuneRow { max_bytes: usize::MAX, max_ranks: usize::MAX, algo: ALLGATHER_RING },
];

/// Alltoall tuning: Bruck trades n−1 envelopes for ⌈log2 n⌉ envelopes of
/// n/2 blocks each — a win when blocks are small and ranks are many.
const ALLTOALL_TUNING: &[CollTuneRow] = &[
    CollTuneRow { max_bytes: 2048, max_ranks: 7, algo: ALLTOALL_PAIRWISE },
    CollTuneRow { max_bytes: 2048, max_ranks: usize::MAX, algo: ALLTOALL_BRUCK },
    CollTuneRow { max_bytes: usize::MAX, max_ranks: usize::MAX, algo: ALLTOALL_PAIRWISE },
];

fn tune(table: &[CollTuneRow], bytes: usize, n: usize) -> u8 {
    table
        .iter()
        .find(|row| bytes <= row.max_bytes && n <= row.max_ranks)
        .map(|row| row.algo)
        .unwrap_or(COLL_AUTO)
}

/// Pick the allreduce variant for (force, packed bytes, comm size, op
/// commutativity). Segment-reordering variants (everything but binomial)
/// change the fold bracketing, so non-commutative user ops always take
/// the baseline; Rabenseifner's 2·log2(p) exchange phases must also fit
/// the [`PHASES_PER_COLL`] tag band (they stop fitting only beyond 2^14
/// ranks, where the guard falls back to the 2-phase ring).
pub(crate) fn pick_allreduce(force: u8, bytes: usize, n: usize, commutative: bool) -> u8 {
    let force = if force <= ALLREDUCE_RABENSEIFNER { force } else { COLL_AUTO };
    let algo = match force {
        COLL_AUTO => {
            if !commutative || n <= 2 {
                ALLREDUCE_BINOMIAL
            } else {
                tune(ALLREDUCE_TUNING, bytes, n)
            }
        }
        f => f,
    };
    if algo == ALLREDUCE_RABENSEIFNER && n > (1 << 14) {
        ALLREDUCE_RING
    } else {
        algo
    }
}

/// Pick the allgather(v) variant for (force, total packed bytes, comm
/// size).
pub(crate) fn pick_allgather(force: u8, total_bytes: usize, n: usize) -> u8 {
    let force = if force <= ALLGATHER_RING { force } else { COLL_AUTO };
    match force {
        COLL_AUTO => tune(ALLGATHER_TUNING, total_bytes, n),
        f => f,
    }
}

/// Pick the uniform-alltoall variant for (force, per-block packed bytes,
/// comm size).
pub(crate) fn pick_alltoall(force: u8, blk_bytes: usize, n: usize) -> u8 {
    let force = if force <= ALLTOALL_BRUCK { force } else { COLL_AUTO };
    match force {
        COLL_AUTO => tune(ALLTOALL_TUNING, blk_bytes, n),
        f => f,
    }
}

/// Parse a `MPI_ABI_COLL_ALGO`-style override string:
/// `"allreduce=ring,allgather=ring,alltoall=bruck"`. Names or numeric
/// force codes are accepted; unknown keys and names fall back to auto.
pub fn parse_coll_algo(s: &str) -> CollAlgoForce {
    fn code(name: &str, table: &[(&str, u8)]) -> u8 {
        let name = name.trim();
        table
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, c)| *c)
            .or_else(|| name.parse::<u8>().ok())
            .unwrap_or(COLL_AUTO)
    }
    let mut f = CollAlgoForce::default();
    for part in s.split(',') {
        let Some((op, name)) = part.split_once('=') else { continue };
        match op.trim() {
            "allreduce" => {
                f.allreduce = code(name, &[
                    ("auto", COLL_AUTO),
                    ("binomial", ALLREDUCE_BINOMIAL),
                    ("ring", ALLREDUCE_RING),
                    ("rd", ALLREDUCE_RECURSIVE_DOUBLING),
                    ("recursive_doubling", ALLREDUCE_RECURSIVE_DOUBLING),
                    ("rabenseifner", ALLREDUCE_RABENSEIFNER),
                ]);
            }
            "allgather" => {
                f.allgather = code(name, &[
                    ("auto", COLL_AUTO),
                    ("gather_bcast", ALLGATHER_GATHER_BCAST),
                    ("binomial", ALLGATHER_GATHER_BCAST),
                    ("ring", ALLGATHER_RING),
                ]);
            }
            "alltoall" => {
                f.alltoall = code(name, &[
                    ("auto", COLL_AUTO),
                    ("pairwise", ALLTOALL_PAIRWISE),
                    ("bruck", ALLTOALL_BRUCK),
                ]);
            }
            _ => {}
        }
    }
    f
}

/// Job-default override from the `MPI_ABI_COLL_ALGO` environment
/// variable (read once at [`World`] construction).
///
/// [`World`]: crate::core::world::World
pub fn coll_algo_env() -> CollAlgoForce {
    match std::env::var("MPI_ABI_COLL_ALGO") {
        Ok(s) => parse_coll_algo(&s),
        Err(_) => CollAlgoForce::default(),
    }
}

/// Send raw bytes to comm rank `dst` on the collective plane.
pub(crate) fn coll_send(ctx: &RankCtx, cc: &CollCtx, dst: usize, payload: Payload) {
    let env = Envelope {
        src: ctx.rank as u32,
        context: cc.context,
        tag: cc.tag,
        kind: MsgKind::Eager,
        seq: 0,
        payload,
    };
    enqueue_send(ctx, cc.members[dst], env);
}

/// Blocking receive of raw bytes from comm rank `src` on the collective
/// plane (bypasses the request engine: collective internals own their
/// buffers).
///
/// ULFM-aware: these spins back the *creation-time* byte exchanges
/// (`comm_dup`/`comm_split` bootstrap, engine agreement rounds), which
/// run before the new comm exists — a peer dying mid-create must surface
/// `MPI_ERR_PROC_FAILED` here rather than hang the spin. Checked only on
/// a miss, so bytes the peer sent before dying still flow through.
pub(crate) fn coll_recv(ctx: &RankCtx, cc: &CollCtx, src: usize) -> RC<Payload> {
    let want_src = cc.members[src] as i32;
    loop {
        progress(ctx);
        // Exact (src, tag) probe of the unexpected index — O(1).
        if let Some(env) =
            ctx.state.borrow_mut().match_index.take_unexpected(cc.context, want_src, cc.tag)
        {
            return Ok(env.payload);
        }
        if ctx.world.is_revoked(cc.context) {
            return Err(err!(MPI_ERR_REVOKED));
        }
        if ctx.world.is_dead(cc.members[src]) {
            ctx.obs.note_op_failed_proc();
            return Err(err!(MPI_ERR_PROC_FAILED));
        }
        std::thread::yield_now();
    }
}

/// Block until the collective request `rid` completes, surfacing any
/// error class its schedule recorded. The blocking collectives are all
/// `submit schedule → wait_coll`.
pub(crate) fn wait_coll(rid: ReqId) -> RC<()> {
    with_ctx(|ctx| {
        let st = super::request::wait_one(ctx, rid)?;
        if st.error != 0 {
            return Err(MpiError::new(st.error));
        }
        Ok(())
    })
}

/// `MPI_Barrier` = wait(`MPI_Ibarrier`): dissemination algorithm
/// (⌈log2 n⌉ rounds), one tag phase per round so a racing peer's later
/// round never cross-matches.
pub fn barrier(comm: CommId) -> RC<()> {
    wait_coll(sched::ibarrier(comm)?)
}

/// Engine-internal: broadcast a fixed byte buffer (used by comm creation
/// before the new comm exists).
pub fn bcast_bytes(buf: &mut [u8], root: usize, comm: CommId) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        bcast_bytes_cc(ctx, &cc, buf, root)
    })
}

/// Binomial-tree byte broadcast over an existing CollCtx.
pub(crate) fn bcast_bytes_cc(ctx: &RankCtx, cc: &CollCtx, buf: &mut [u8], root: usize) -> RC<()> {
    let n = cc.size();
    if n <= 1 {
        return Ok(());
    }
    // Virtual ranks with root at 0.
    let vrank = (cc.my_rank + n - root) % n;
    // Receive from parent (unless root).
    if vrank != 0 {
        let parent = parent_of(vrank);
        let parent_real = (parent + root) % n;
        let p = coll_recv(ctx, cc, parent_real)?;
        let data = p.as_slice();
        let take = data.len().min(buf.len());
        buf[..take].copy_from_slice(&data[..take]);
    }
    // Forward to children.
    for child in children_of(vrank, n) {
        let child_real = (child + root) % n;
        coll_send(ctx, cc, child_real, Payload::from_slice(buf));
    }
    Ok(())
}

/// Engine-level `MPI_Allgatherv_c`: the embiggened allgatherv — per-rank
/// receive counts as `MPI_Count` and displacements as `MPI_Aint` (in
/// units of `recvtype` extent), so block `r` may start beyond 2 GiB.
/// Linear exchange on the collective plane: every rank contributes
/// `sendcount` items of `sendtype`; rank `r`'s block unpacks as
/// `recvcounts[r]` items of `recvtype` at
/// `recvbuf + displs[r] × extent(recvtype)`.
#[allow(clippy::too_many_arguments)]
pub fn allgatherv_c(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[i64],
    displs: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        if recvcounts.len() < n || displs.len() < n {
            return Err(err!(MPI_ERR_COUNT));
        }
        if recvcounts.iter().take(n).any(|&c| c < 0) {
            return Err(err!(MPI_ERR_COUNT));
        }
        let (_, rext) = super::datatype::type_get_extent(recvtype)?;
        // Pack my contribution once; it both goes to every peer and
        // lands in my own block locally.
        let mine = {
            let t = ctx.tables.borrow();
            let mut v = Vec::new();
            super::datatype::pack::pack(&t.dtypes, sendbuf, sendcount, sendtype, &mut v)?;
            v
        };
        for r in 0..n {
            if r != cc.my_rank {
                coll_send(ctx, &cc, r, Payload::from_slice(&mine));
            }
        }
        {
            let t = ctx.tables.borrow();
            let dst = unsafe { recvbuf.offset(displs[cc.my_rank] * rext) };
            super::datatype::pack::unpack(
                &t.dtypes,
                &mine,
                dst,
                recvcounts[cc.my_rank] as usize,
                recvtype,
            )?;
        }
        for r in 0..n {
            if r == cc.my_rank {
                continue;
            }
            let p = coll_recv(ctx, &cc, r)?;
            let t = ctx.tables.borrow();
            let dst = unsafe { recvbuf.offset(displs[r] * rext) };
            super::datatype::pack::unpack(
                &t.dtypes,
                p.as_slice(),
                dst,
                recvcounts[r] as usize,
                recvtype,
            )?;
        }
        Ok(())
    })
}

/// Engine-internal: gather fixed-size byte blocks at `root`.
/// `send.len()` bytes from every rank land at `recv[r*send.len()..]`.
pub fn gather_bytes(send: &[u8], recv: &mut [u8], root: usize, comm: CommId) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let blk = send.len();
        if cc.my_rank == root {
            recv[root * blk..(root + 1) * blk].copy_from_slice(send);
            for r in 0..n {
                if r == root {
                    continue;
                }
                let p = coll_recv(ctx, &cc, r)?;
                recv[r * blk..r * blk + p.len().min(blk)]
                    .copy_from_slice(&p.as_slice()[..p.len().min(blk)]);
            }
        } else {
            coll_send(ctx, &cc, root, Payload::from_slice(send));
        }
        Ok(())
    })
}

/// Engine-internal: scatter variable-size blobs from `root`; returns this
/// rank's blob.
pub fn scatter_var_bytes(blobs: &[Vec<u8>], root: usize, comm: CommId) -> RC<Vec<u8>> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        if cc.my_rank == root {
            for r in 0..n {
                if r == root {
                    continue;
                }
                coll_send(ctx, &cc, r, Payload::from_slice(&blobs[r]));
            }
            Ok(blobs[root].clone())
        } else {
            Ok(coll_recv(ctx, &cc, root)?.as_slice().to_vec())
        }
    })
}

/// Binomial-tree helpers on virtual ranks (root = 0).
pub(crate) fn parent_of(vrank: usize) -> usize {
    debug_assert!(vrank != 0);
    vrank & (vrank - 1) // clear lowest set bit
}

pub(crate) fn children_of(vrank: usize, n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut bit = 1usize;
    // Children are vrank | bit for bits below the lowest set bit of vrank
    // (or all bits for root), while in range.
    let limit = if vrank == 0 { n.next_power_of_two() } else { vrank & vrank.wrapping_neg() };
    while bit < limit {
        let c = vrank | bit;
        if c < n && c != vrank {
            out.push(c);
        }
        bit <<= 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomial_tree_shape() {
        // n = 8: 0 -> {1, 2, 4}; 2 -> {3}; 4 -> {5, 6}; 6 -> {7}.
        assert_eq!(children_of(0, 8), vec![1, 2, 4]);
        assert_eq!(children_of(2, 8), vec![3]);
        assert_eq!(children_of(4, 8), vec![5, 6]);
        assert_eq!(children_of(6, 8), vec![7]);
        assert_eq!(children_of(7, 8), Vec::<usize>::new());
        for v in 1..8 {
            let p = parent_of(v);
            assert!(children_of(p, 8).contains(&v), "{p} must parent {v}");
        }
    }

    #[test]
    fn binomial_tree_nonpow2() {
        // n = 6: every non-root has a parent, all nodes covered exactly once.
        let n = 6;
        let mut seen = vec![false; n];
        seen[0] = true;
        let mut stack = vec![0usize];
        while let Some(v) = stack.pop() {
            for c in children_of(v, n) {
                assert!(!seen[c], "child {c} visited twice");
                seen[c] = true;
                stack.push(c);
            }
        }
        assert!(seen.iter().all(|&s| s), "all ranks covered: {seen:?}");
    }
}
