//! E1 (§6.1): `MPI_Type_size` throughput across ABI mechanisms.
//!
//! The paper measures ≈11.5 ns/query for both MPICH (size encoded in the
//! handle bits) and Open MPI (descriptor dereference) on an EPYC 7413,
//! and concludes the mechanism difference is negligible — and both are
//! negligible against the ≥500 ns cost of any actual message. This bench
//! reproduces the comparison across all five configurations plus the raw
//! decode primitives.

use mpi_abi::api::{Dt, MpiAbi};
use mpi_abi::apps::AbiConfig;
use mpi_abi::bench::{bench, Table};
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::muk::{MukMpich, MukOmpi};
use mpi_abi::native_abi::NativeAbi;

const ITERS: usize = 200_000;

fn measure<A: MpiAbi>() -> f64 {
    let dts = [
        A::datatype(Dt::Char),
        A::datatype(Dt::Int),
        A::datatype(Dt::Float),
        A::datatype(Dt::Double),
        A::datatype(Dt::Int64),
        A::datatype(Dt::Int32),
    ];
    let mut sink = 0i64;
    let s = bench(&format!("type_size/{}", A::NAME), 2, 10, ITERS, || {
        for &d in &dts {
            let mut out = 0;
            A::type_size(std::hint::black_box(d), &mut out);
            sink = sink.wrapping_add(out as i64);
        }
    });
    std::hint::black_box(sink);
    println!("{}", s.report());
    s.mean / dts.len() as f64
}

fn main() {
    println!("\nE1 — MPI_Type_size throughput (paper §6.1: ≈11.5 ns both ABIs)");
    let mut table = Table::new(
        "MPI_Type_size mechanisms",
        &["ABI", "mechanism", "ns/query"],
    );
    let rows: Vec<(AbiConfig, &str, f64)> = vec![
        (AbiConfig::Mpich, "handle-bit decode (0x..ff00>>8)", measure::<MpichAbi>()),
        (AbiConfig::Ompi, "descriptor load (352-B struct)", measure::<OmpiAbi>()),
        (AbiConfig::NativeAbi, "Huffman bits + compact table", measure::<NativeAbi>()),
        (AbiConfig::MukMpich, "dlsym vtable + convert + decode", measure::<MukMpich>()),
        (AbiConfig::MukOmpi, "dlsym vtable + convert + load", measure::<MukOmpi>()),
    ];
    for (abi, mech, t) in &rows {
        table.row(&[
            abi.name().to_string(),
            mech.to_string(),
            format!("{:.2}", t * 1e9),
        ]);
    }
    println!("{}", table.render());

    // Raw decode primitives (no call overhead), for the §Perf log.
    let mut sink = 0usize;
    let s = bench("raw/huffman_fixed_size_of", 2, 10, ITERS, || {
        sink ^= mpi_abi::abi::huffman::fixed_size_of(std::hint::black_box(
            mpi_abi::abi::datatypes::MPI_INT32_T,
        ))
        .unwrap_or(0);
    });
    println!("{}", s.report());
    let s = bench("raw/mpich_basic_size_macro", 2, 10, ITERS, || {
        sink ^= mpi_abi::impls::mpich::datatype_get_basic_size(std::hint::black_box(
            mpi_abi::impls::mpich::dt_handle(4, 9),
        )) as usize;
    });
    println!("{}", s.report());
    std::hint::black_box(sink);

    // Shape check (paper: both mechanisms within noise of each other,
    // and far below the 500 ns message cost).
    let native = [rows[0].2, rows[1].2, rows[2].2];
    let max = native.iter().cloned().fold(0.0, f64::max);
    let min = native.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "shape: native mechanisms within {:.1}x of each other (paper: ~1x); all ≤ 500ns msg cost: {}",
        max / min,
        max * 1e9 < 500.0
    );
}
