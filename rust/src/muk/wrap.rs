//! The `impl-wrap.so` half of Mukautuva: `WRAP_*` functions compiled
//! against one backend, exposed through a name→address symbol table
//! that `libmuk` resolves with [`SymbolTable::dlsym`].
//!
//! Every function takes standard-ABI-word arguments, converts handles
//! and constants to the backend representation (see
//! [`crate::muk::convert`]), calls the backend, and converts results
//! back — the paper's `WRAP_Comm_size` listing, for the whole API.

use std::collections::HashMap;

use crate::abi::handles as std_h;
use crate::abi::status::AbiStatus;
use crate::muk::callbacks;
use crate::muk::convert::*;
use crate::muk::word::AsWord;

/// A "shared library": WRAP symbol name → function address.
pub struct SymbolTable {
    map: HashMap<&'static str, *const ()>,
    /// Which backend's `mpi.h` this wrap library was "compiled" against.
    pub backend_name: &'static str,
}

// Function addresses are valid process-wide.
unsafe impl Send for SymbolTable {}
unsafe impl Sync for SymbolTable {}

impl SymbolTable {
    /// `dlsym`: resolve a typed function pointer by name. Panics on a
    /// missing symbol (a real dlsym failure would abort muk's init too).
    ///
    /// # Safety
    /// `T` must be the fn-pointer type the symbol was registered with.
    pub unsafe fn dlsym<T: Copy>(&self, name: &str) -> T {
        let p = self
            .map
            .get(name)
            .unwrap_or_else(|| panic!("dlsym: missing symbol {name} in {}", self.backend_name));
        assert_eq!(std::mem::size_of::<T>(), std::mem::size_of::<*const ()>());
        unsafe { std::mem::transmute_copy::<*const (), T>(p) }
    }

    /// Number of exported WRAP symbols.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// `true` when no symbols are exported (never, in practice).
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Whether `name` resolves (a `dlsym != NULL` probe, without the
    /// panic): how `tests/spec_sync.rs` checks the SPEC §9 symbol rows.
    pub fn has(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }
}

// --- WRAP functions -----------------------------------------------------------

/// `WRAP_init`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn init<A: MukBackend>() -> i32 {
    ret_code::<A>(A::init())
}

/// `WRAP_finalize`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn finalize<A: MukBackend>() -> i32 {
    ret_code::<A>(A::finalize())
}

/// `WRAP_initialized`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn initialized<A: MukBackend>() -> bool {
    A::initialized()
}

/// `WRAP_finalized`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn finalized<A: MukBackend>() -> bool {
    A::finalized()
}

/// `WRAP_abort`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn abort<A: MukBackend>(comm: usize, code: i32) -> i32 {
    ret_code::<A>(A::abort(comm_to_impl::<A>(comm), code))
}

/// `WRAP_wtime`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn wtime<A: MukBackend>() -> f64 {
    A::wtime()
}

/// `WRAP_get_library_version`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn get_library_version<A: MukBackend>(out: &mut String) -> i32 {
    *out = format!("{} via mukautuva", A::get_library_version());
    0
}

/// `WRAP_get_version`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn get_version<A: MukBackend>(v: &mut i32, sub: &mut i32) -> i32 {
    let (a, b) = A::get_version();
    *v = a;
    *sub = b;
    0
}

/// `WRAP_get_processor_name`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn get_processor_name<A: MukBackend>(out: &mut String) -> i32 {
    *out = A::get_processor_name();
    0
}

/// `WRAP_comm_size`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_size<A: MukBackend>(comm: usize, out: &mut i32) -> i32 {
    ret_code::<A>(A::comm_size(comm_to_impl::<A>(comm), out))
}

/// `WRAP_comm_rank`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_rank<A: MukBackend>(comm: usize, out: &mut i32) -> i32 {
    ret_code::<A>(A::comm_rank(comm_to_impl::<A>(comm), out))
}

/// `WRAP_comm_dup`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_dup<A: MukBackend>(comm: usize, out: &mut usize) -> i32 {
    let mut c = A::comm_null();
    let rc = A::comm_dup(comm_to_impl::<A>(comm), &mut c);
    if rc == 0 {
        *out = comm_to_muk::<A>(c);
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_split`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_split<A: MukBackend>(comm: usize, color: i32, key: i32, out: &mut usize) -> i32 {
    let color = if color == crate::abi::constants::MPI_UNDEFINED { A::undefined() } else { color };
    let mut c = A::comm_null();
    let rc = A::comm_split(comm_to_impl::<A>(comm), color, key, &mut c);
    if rc == 0 {
        *out = comm_to_muk::<A>(c);
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_split_type`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_split_type<A: MukBackend>(
    comm: usize,
    split_type: i32,
    key: i32,
    out: &mut usize,
) -> i32 {
    // Undefined checked before shared: OMPI numbers shared as 0, which
    // no ABI uses for undefined, so the order is unambiguous.
    let split_type = if split_type == crate::abi::constants::MPI_UNDEFINED {
        A::undefined()
    } else if split_type == crate::abi::constants::MPI_COMM_TYPE_SHARED {
        A::comm_type_shared()
    } else {
        split_type
    };
    let mut c = A::comm_null();
    let rc = A::comm_split_type(comm_to_impl::<A>(comm), split_type, key, &mut c);
    if rc == 0 {
        *out = comm_to_muk::<A>(c);
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_free`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_free<A: MukBackend>(comm: &mut usize) -> i32 {
    let mut c = comm_to_impl::<A>(*comm);
    let rc = A::comm_free(&mut c);
    if rc == 0 {
        *comm = std_h::MPI_COMM_NULL;
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_compare`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_compare<A: MukBackend>(a: usize, b: usize, out: &mut i32) -> i32 {
    ret_code::<A>(A::comm_compare(comm_to_impl::<A>(a), comm_to_impl::<A>(b), out))
}

/// `WRAP_comm_set_name`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_set_name<A: MukBackend>(comm: usize, name: &str) -> i32 {
    ret_code::<A>(A::comm_set_name(comm_to_impl::<A>(comm), name))
}

/// `WRAP_comm_get_name`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_get_name<A: MukBackend>(comm: usize, out: &mut String) -> i32 {
    ret_code::<A>(A::comm_get_name(comm_to_impl::<A>(comm), out))
}

/// `WRAP_comm_group`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_group<A: MukBackend>(comm: usize, out: &mut usize) -> i32 {
    let mut g = A::Group::from_word(0);
    let rc = A::comm_group(comm_to_impl::<A>(comm), &mut g);
    if rc == 0 {
        *out = g.to_word();
    }
    ret_code::<A>(rc)
}

/// `WRAP_group_size`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn group_size<A: MukBackend>(g: usize, out: &mut i32) -> i32 {
    ret_code::<A>(A::group_size(group_to_impl::<A>(g), out))
}

/// `WRAP_group_rank`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn group_rank<A: MukBackend>(g: usize, out: &mut i32) -> i32 {
    let rc = A::group_rank(group_to_impl::<A>(g), out);
    if rc == 0 && *out == A::undefined() {
        *out = crate::abi::constants::MPI_UNDEFINED;
    }
    ret_code::<A>(rc)
}

/// `WRAP_group_incl`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn group_incl<A: MukBackend>(g: usize, ranks: &[i32], out: &mut usize) -> i32 {
    let mut n = A::Group::from_word(0);
    let rc = A::group_incl(group_to_impl::<A>(g), ranks, &mut n);
    if rc == 0 {
        *out = n.to_word();
    }
    ret_code::<A>(rc)
}

/// `WRAP_group_translate_ranks`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn group_translate_ranks<A: MukBackend>(
    a: usize,
    ranks: &[i32],
    b: usize,
    out: &mut [i32],
) -> i32 {
    let conv: Vec<i32> = ranks.iter().map(|&r| src_to_impl::<A>(r)).collect();
    let rc = A::group_translate_ranks(group_to_impl::<A>(a), &conv, group_to_impl::<A>(b), out);
    if rc == 0 {
        for o in out.iter_mut() {
            if *o == A::undefined() {
                *o = crate::abi::constants::MPI_UNDEFINED;
            } else if *o == A::proc_null() {
                *o = crate::abi::constants::MPI_PROC_NULL;
            }
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_group_free`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn group_free<A: MukBackend>(g: &mut usize) -> i32 {
    let mut h = group_to_impl::<A>(*g);
    let rc = A::group_free(&mut h);
    if rc == 0 {
        *g = std_h::MPI_GROUP_NULL;
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_set_errhandler`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_set_errhandler<A: MukBackend>(comm: usize, e: usize) -> i32 {
    ret_code::<A>(A::comm_set_errhandler(comm_to_impl::<A>(comm), errh_to_impl::<A>(e)))
}

/// `WRAP_comm_get_errhandler`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_get_errhandler<A: MukBackend>(comm: usize, out: &mut usize) -> i32 {
    let mut e = A::errhandler_fatal();
    let rc = A::comm_get_errhandler(comm_to_impl::<A>(comm), &mut e);
    if rc == 0 {
        *out = errh_to_muk::<A>(e);
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_create_errhandler`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_create_errhandler<A: MukBackend>(f: callbacks::MukErrhFn, out: &mut usize) -> i32 {
    let Some(slot) = callbacks::alloc_errh_slot(f) else {
        return crate::abi::errors::MPI_ERR_NO_MEM;
    };
    let tramp = callbacks::errh_tramp_pool::<A>()[slot];
    let mut e = A::errhandler_fatal();
    let rc = A::comm_create_errhandler(tramp, &mut e);
    if rc == 0 {
        *out = e.to_word();
        crate::muk::state::remember_errh_slot(e.to_word(), slot);
    } else {
        callbacks::free_errh_slot(slot);
    }
    ret_code::<A>(rc)
}

/// `WRAP_errhandler_free`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn errhandler_free<A: MukBackend>(e: &mut usize) -> i32 {
    let mut h = errh_to_impl::<A>(*e);
    let rc = A::errhandler_free(&mut h);
    if rc == 0 {
        if let Some(slot) = crate::muk::state::forget_errh_slot(*e) {
            callbacks::free_errh_slot(slot);
        }
        *e = std_h::MPI_ERRHANDLER_NULL;
    }
    ret_code::<A>(rc)
}

/// `WRAP_send`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn send<A: MukBackend>(
    buf: *const u8,
    count: i32,
    dt: usize,
    dest: i32,
    tag: i32,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::send(buf, count, dt_to_impl::<A>(dt), dest_to_impl::<A>(dest), tag,
        comm_to_impl::<A>(comm)))
}

/// `WRAP_ssend`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn ssend<A: MukBackend>(
    buf: *const u8,
    count: i32,
    dt: usize,
    dest: i32,
    tag: i32,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::ssend(buf, count, dt_to_impl::<A>(dt), dest_to_impl::<A>(dest), tag,
        comm_to_impl::<A>(comm)))
}

/// `WRAP_recv`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn recv<A: MukBackend>(
    buf: *mut u8,
    count: i32,
    dt: usize,
    src: i32,
    tag: i32,
    comm: usize,
    status: *mut AbiStatus,
) -> i32 {
    let mut s = A::status_empty();
    let rc = A::recv(buf, count, dt_to_impl::<A>(dt), src_to_impl::<A>(src),
        tag_to_impl::<A>(tag), comm_to_impl::<A>(comm), &mut s);
    if !status.is_null() {
        unsafe { *status = status_to_muk::<A>(&s) };
    }
    ret_code::<A>(rc)
}

/// `WRAP_isend`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn isend<A: MukBackend>(
    buf: *const u8,
    count: i32,
    dt: usize,
    dest: i32,
    tag: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::isend(buf, count, dt_to_impl::<A>(dt), dest_to_impl::<A>(dest), tag,
        comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_issend`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn issend<A: MukBackend>(
    buf: *const u8,
    count: i32,
    dt: usize,
    dest: i32,
    tag: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::issend(buf, count, dt_to_impl::<A>(dt), dest_to_impl::<A>(dest), tag,
        comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_irecv`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn irecv<A: MukBackend>(
    buf: *mut u8,
    count: i32,
    dt: usize,
    src: i32,
    tag: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::irecv(buf, count, dt_to_impl::<A>(dt), src_to_impl::<A>(src),
        tag_to_impl::<A>(tag), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_wait`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn wait<A: MukBackend>(req: &mut usize, status: *mut AbiStatus) -> i32 {
    let mut r = req_to_impl::<A>(*req);
    let mut s = A::status_empty();
    let rc = A::wait(&mut r, &mut s);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
        if !status.is_null() {
            unsafe { *status = status_to_muk::<A>(&s) };
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_test`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn test<A: MukBackend>(req: &mut usize, flag: &mut bool, status: *mut AbiStatus) -> i32 {
    let mut r = req_to_impl::<A>(*req);
    let mut s = A::status_empty();
    let rc = A::test(&mut r, flag, &mut s);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
        if *flag && !status.is_null() {
            unsafe { *status = status_to_muk::<A>(&s) };
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_waitall`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn waitall<A: MukBackend>(reqs: &mut [usize], statuses: *mut AbiStatus) -> i32 {
    let mut rs: Vec<A::Request> = reqs.iter().map(|&r| req_to_impl::<A>(r)).collect();
    let mut ss = vec![A::status_empty(); rs.len()];
    let rc = A::waitall(&mut rs, &mut ss);
    if rc == 0 {
        for (i, r) in rs.iter().enumerate() {
            reqs[i] = req_to_muk::<A>(*r);
            if !statuses.is_null() {
                unsafe { *statuses.add(i) = status_to_muk::<A>(&ss[i]) };
            }
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_testall`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn testall<A: MukBackend>(reqs: &mut [usize], flag: &mut bool, statuses: *mut AbiStatus) -> i32 {
    let mut rs: Vec<A::Request> = reqs.iter().map(|&r| req_to_impl::<A>(r)).collect();
    let mut ss = vec![A::status_empty(); rs.len()];
    let rc = A::testall(&mut rs, flag, &mut ss);
    if rc == 0 && *flag {
        for (i, r) in rs.iter().enumerate() {
            reqs[i] = req_to_muk::<A>(*r);
            if !statuses.is_null() {
                unsafe { *statuses.add(i) = status_to_muk::<A>(&ss[i]) };
            }
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_waitany`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn waitany<A: MukBackend>(reqs: &mut [usize], index: &mut i32, status: *mut AbiStatus) -> i32 {
    let mut rs: Vec<A::Request> = reqs.iter().map(|&r| req_to_impl::<A>(r)).collect();
    let mut s = A::status_empty();
    let rc = A::waitany(&mut rs, index, &mut s);
    if rc == 0 {
        if *index == A::undefined() {
            // No active request in the list (all null or inactive
            // persistent): MPI_UNDEFINED + an *empty* status, same as
            // the backend path reports (MPI 3.0 §3.7.5).
            *index = crate::abi::constants::MPI_UNDEFINED;
            if !status.is_null() {
                unsafe { *status = status_to_muk::<A>(&A::status_empty()) };
            }
        } else if *index >= 0 {
            let i = *index as usize;
            reqs[i] = req_to_muk::<A>(rs[i]);
            if !status.is_null() {
                unsafe { *status = status_to_muk::<A>(&s) };
            }
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_testany`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn testany<A: MukBackend>(
    reqs: &mut [usize],
    index: &mut i32,
    flag: &mut bool,
    status: *mut AbiStatus,
) -> i32 {
    let mut rs: Vec<A::Request> = reqs.iter().map(|&r| req_to_impl::<A>(r)).collect();
    let mut s = A::status_empty();
    let rc = A::testany(&mut rs, index, flag, &mut s);
    if rc == 0 && *flag {
        if *index == A::undefined() {
            *index = crate::abi::constants::MPI_UNDEFINED;
            if !status.is_null() {
                unsafe { *status = status_to_muk::<A>(&A::status_empty()) };
            }
        } else if *index >= 0 {
            let i = *index as usize;
            reqs[i] = req_to_muk::<A>(rs[i]);
            if !status.is_null() {
                unsafe { *status = status_to_muk::<A>(&s) };
            }
        }
    }
    ret_code::<A>(rc)
}

/// Shared body of WRAP_waitsome/WRAP_testsome: convert the request
/// words in, call the backend entry point, and convert the completed
/// indices' handles + statuses (and the `MPI_UNDEFINED` outcount) back.
fn some_via<A, F>(
    call: F,
    reqs: &mut [usize],
    outcount: &mut i32,
    indices: &mut [i32],
    statuses: *mut AbiStatus,
) -> i32
where
    A: MukBackend,
    F: FnOnce(&mut [A::Request], &mut i32, &mut [i32], &mut [A::Status]) -> i32,
{
    let mut rs: Vec<A::Request> = reqs.iter().map(|&r| req_to_impl::<A>(r)).collect();
    let mut ss = vec![A::status_empty(); rs.len()];
    let rc = call(&mut rs, outcount, indices, &mut ss);
    if rc == 0 {
        if *outcount == A::undefined() {
            *outcount = crate::abi::constants::MPI_UNDEFINED;
        } else {
            for j in 0..*outcount as usize {
                let i = indices[j] as usize;
                reqs[i] = req_to_muk::<A>(rs[i]);
                if !statuses.is_null() {
                    unsafe { *statuses.add(j) = status_to_muk::<A>(&ss[j]) };
                }
            }
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_waitsome`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn waitsome<A: MukBackend>(
    reqs: &mut [usize],
    outcount: &mut i32,
    indices: &mut [i32],
    statuses: *mut AbiStatus,
) -> i32 {
    some_via::<A, _>(A::waitsome, reqs, outcount, indices, statuses)
}

/// `WRAP_testsome`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn testsome<A: MukBackend>(
    reqs: &mut [usize],
    outcount: &mut i32,
    indices: &mut [i32],
    statuses: *mut AbiStatus,
) -> i32 {
    some_via::<A, _>(A::testsome, reqs, outcount, indices, statuses)
}

/// `WRAP_probe`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn probe<A: MukBackend>(src: i32, tag: i32, comm: usize, status: *mut AbiStatus) -> i32 {
    let mut s = A::status_empty();
    let rc = A::probe(src_to_impl::<A>(src), tag_to_impl::<A>(tag), comm_to_impl::<A>(comm),
        &mut s);
    if rc == 0 && !status.is_null() {
        unsafe { *status = status_to_muk::<A>(&s) };
    }
    ret_code::<A>(rc)
}

/// `WRAP_iprobe`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn iprobe<A: MukBackend>(
    src: i32,
    tag: i32,
    comm: usize,
    flag: &mut bool,
    status: *mut AbiStatus,
) -> i32 {
    let mut s = A::status_empty();
    let rc = A::iprobe(src_to_impl::<A>(src), tag_to_impl::<A>(tag), comm_to_impl::<A>(comm),
        flag, &mut s);
    if rc == 0 && *flag && !status.is_null() {
        unsafe { *status = status_to_muk::<A>(&s) };
    }
    ret_code::<A>(rc)
}

/// `WRAP_cancel`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn cancel<A: MukBackend>(req: &mut usize) -> i32 {
    let mut r = req_to_impl::<A>(*req);
    ret_code::<A>(A::cancel(&mut r))
}

/// `WRAP_request_free`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn request_free<A: MukBackend>(req: &mut usize) -> i32 {
    let mut r = req_to_impl::<A>(*req);
    let rc = A::request_free(&mut r);
    if rc == 0 {
        *req = std_h::MPI_REQUEST_NULL;
    }
    ret_code::<A>(rc)
}

// --- Persistent point-to-point -------------------------------------------------
//
// The init calls convert like their nonblocking cousins; start/startall
// pass the request word through the union both ways. The backend keeps
// persistent handles alive across wait/test, so the word the app holds
// stays valid — exactly the lifecycle the standard ABI mandates.

/// `WRAP_send_init`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn send_init<A: MukBackend>(
    buf: *const u8,
    count: i32,
    dt: usize,
    dest: i32,
    tag: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::send_init(buf, count, dt_to_impl::<A>(dt), dest_to_impl::<A>(dest), tag,
        comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_ssend_init`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn ssend_init<A: MukBackend>(
    buf: *const u8,
    count: i32,
    dt: usize,
    dest: i32,
    tag: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::ssend_init(buf, count, dt_to_impl::<A>(dt), dest_to_impl::<A>(dest), tag,
        comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_recv_init`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn recv_init<A: MukBackend>(
    buf: *mut u8,
    count: i32,
    dt: usize,
    src: i32,
    tag: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::recv_init(buf, count, dt_to_impl::<A>(dt), src_to_impl::<A>(src),
        tag_to_impl::<A>(tag), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_start`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn start<A: MukBackend>(req: &mut usize) -> i32 {
    let mut r = req_to_impl::<A>(*req);
    let rc = A::start(&mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_startall`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn startall<A: MukBackend>(reqs: &mut [usize]) -> i32 {
    let mut rs: Vec<A::Request> = reqs.iter().map(|&r| req_to_impl::<A>(r)).collect();
    let rc = A::startall(&mut rs);
    if rc == 0 {
        for (i, r) in rs.iter().enumerate() {
            reqs[i] = req_to_muk::<A>(*r);
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_sendrecv`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn sendrecv<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    dest: i32,
    sendtag: i32,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    src: i32,
    recvtag: i32,
    comm: usize,
    status: *mut AbiStatus,
) -> i32 {
    let mut s = A::status_empty();
    let rc = A::sendrecv(
        sendbuf,
        sendcount,
        dt_to_impl::<A>(sendtype),
        dest_to_impl::<A>(dest),
        sendtag,
        recvbuf,
        recvcount,
        dt_to_impl::<A>(recvtype),
        src_to_impl::<A>(src),
        tag_to_impl::<A>(recvtag),
        comm_to_impl::<A>(comm),
        &mut s,
    );
    if rc == 0 && !status.is_null() {
        unsafe { *status = status_to_muk::<A>(&s) };
    }
    ret_code::<A>(rc)
}

/// `WRAP_type_size`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn type_size<A: MukBackend>(dt: usize, out: &mut i32) -> i32 {
    ret_code::<A>(A::type_size(dt_to_impl::<A>(dt), out))
}

/// `WRAP_type_get_extent`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn type_get_extent<A: MukBackend>(dt: usize, lb: &mut isize, extent: &mut isize) -> i32 {
    ret_code::<A>(A::type_get_extent(dt_to_impl::<A>(dt), lb, extent))
}

/// `WRAP_type_contiguous`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn type_contiguous<A: MukBackend>(count: i32, child: usize, out: &mut usize) -> i32 {
    let mut d = A::datatype(crate::api::Dt::Byte);
    let rc = A::type_contiguous(count, dt_to_impl::<A>(child), &mut d);
    if rc == 0 {
        *out = dt_to_muk::<A>(d);
    }
    ret_code::<A>(rc)
}

/// `WRAP_type_vector`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn type_vector<A: MukBackend>(
    count: i32,
    blocklen: i32,
    stride: i32,
    child: usize,
    out: &mut usize,
) -> i32 {
    let mut d = A::datatype(crate::api::Dt::Byte);
    let rc = A::type_vector(count, blocklen, stride, dt_to_impl::<A>(child), &mut d);
    if rc == 0 {
        *out = dt_to_muk::<A>(d);
    }
    ret_code::<A>(rc)
}

/// `WRAP_type_create_struct`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn type_create_struct<A: MukBackend>(
    blocks: &[(i32, isize, usize)],
    out: &mut usize,
) -> i32 {
    // Vector-of-datatypes conversion: the §6.2 pain point.
    let conv: Vec<(i32, isize, A::Datatype)> =
        blocks.iter().map(|&(l, d, t)| (l, d, dt_to_impl::<A>(t))).collect();
    let mut d = A::datatype(crate::api::Dt::Byte);
    let rc = A::type_create_struct(&conv, &mut d);
    if rc == 0 {
        *out = dt_to_muk::<A>(d);
    }
    ret_code::<A>(rc)
}

/// `WRAP_type_commit`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn type_commit<A: MukBackend>(dt: &mut usize) -> i32 {
    let mut d = dt_to_impl::<A>(*dt);
    let rc = A::type_commit(&mut d);
    if rc == 0 {
        *dt = dt_to_muk::<A>(d);
    }
    ret_code::<A>(rc)
}

/// `WRAP_type_free`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn type_free<A: MukBackend>(dt: &mut usize) -> i32 {
    let mut d = dt_to_impl::<A>(*dt);
    let rc = A::type_free(&mut d);
    if rc == 0 {
        *dt = crate::abi::datatypes::MPI_DATATYPE_NULL;
    }
    ret_code::<A>(rc)
}

/// `WRAP_type_dup`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn type_dup<A: MukBackend>(dt: usize, out: &mut usize) -> i32 {
    let mut d = A::datatype(crate::api::Dt::Byte);
    let rc = A::type_dup(dt_to_impl::<A>(dt), &mut d);
    if rc == 0 {
        *out = dt_to_muk::<A>(d);
    }
    ret_code::<A>(rc)
}

/// `WRAP_op_create`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn op_create<A: MukBackend>(f: callbacks::MukOpFn, commute: bool, out: &mut usize) -> i32 {
    let Some(slot) = callbacks::alloc_op_slot(f) else {
        return crate::abi::errors::MPI_ERR_NO_MEM;
    };
    let tramp = callbacks::op_tramp_pool::<A>()[slot];
    let mut o = A::op(crate::api::OpName::Sum);
    let rc = A::op_create(tramp, commute, &mut o);
    if rc == 0 {
        *out = o.to_word();
        crate::muk::state::remember_op_slot(o.to_word(), slot);
    } else {
        callbacks::free_op_slot(slot);
    }
    ret_code::<A>(rc)
}

/// `WRAP_op_free`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn op_free<A: MukBackend>(op: &mut usize) -> i32 {
    let mut o = op_to_impl::<A>(*op);
    let rc = A::op_free(&mut o);
    if rc == 0 {
        if let Some(slot) = crate::muk::state::forget_op_slot(*op) {
            callbacks::free_op_slot(slot);
        }
        *op = crate::abi::ops::MPI_OP_NULL;
    }
    ret_code::<A>(rc)
}

/// `WRAP_barrier`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn barrier<A: MukBackend>(comm: usize) -> i32 {
    ret_code::<A>(A::barrier(comm_to_impl::<A>(comm)))
}

/// `WRAP_bcast`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn bcast<A: MukBackend>(buf: *mut u8, count: i32, dt: usize, root: i32, comm: usize) -> i32 {
    ret_code::<A>(A::bcast(buf, count, dt_to_impl::<A>(dt), root, comm_to_impl::<A>(comm)))
}

/// `WRAP_reduce`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn reduce<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: i32,
    dt: usize,
    op: usize,
    root: i32,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::reduce(buf_to_impl::<A>(sendbuf), recvbuf, count, dt_to_impl::<A>(dt),
        op_to_impl::<A>(op), root, comm_to_impl::<A>(comm)))
}

/// `WRAP_allreduce`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn allreduce<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: i32,
    dt: usize,
    op: usize,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::allreduce(buf_to_impl::<A>(sendbuf), recvbuf, count, dt_to_impl::<A>(dt),
        op_to_impl::<A>(op), comm_to_impl::<A>(comm)))
}

/// `WRAP_gather`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn gather<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    root: i32,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::gather(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcount, dt_to_impl::<A>(recvtype), root, comm_to_impl::<A>(comm)))
}

/// `WRAP_scatter`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn scatter<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    root: i32,
    comm: usize,
) -> i32 {
    let rb = recvbuf_to_impl::<A>(recvbuf);
    ret_code::<A>(A::scatter(sendbuf, sendcount, dt_to_impl::<A>(sendtype), rb, recvcount,
        dt_to_impl::<A>(recvtype), root, comm_to_impl::<A>(comm)))
}

/// `WRAP_allgather`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn allgather<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::allgather(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcount, dt_to_impl::<A>(recvtype), comm_to_impl::<A>(comm)))
}

/// `WRAP_alltoall`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn alltoall<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::alltoall(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcount, dt_to_impl::<A>(recvtype), comm_to_impl::<A>(comm)))
}

/// `WRAP_alltoallw`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn alltoallw<A: MukBackend>(
    sendbuf: *const u8,
    sendcounts: &[i32],
    sdispls: &[i32],
    sendtypes: &[usize],
    recvbuf: *mut u8,
    recvcounts: &[i32],
    rdispls: &[i32],
    recvtypes: &[usize],
    comm: usize,
) -> i32 {
    // Vectors of datatype handles: convert whole arrays (§6.2).
    let st: Vec<A::Datatype> = sendtypes.iter().map(|&t| dt_to_impl::<A>(t)).collect();
    let rt: Vec<A::Datatype> = recvtypes.iter().map(|&t| dt_to_impl::<A>(t)).collect();
    ret_code::<A>(A::alltoallw(buf_to_impl::<A>(sendbuf), sendcounts, sdispls, &st, recvbuf,
        recvcounts, rdispls, &rt, comm_to_impl::<A>(comm)))
}

/// `WRAP_ialltoallw`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn ialltoallw<A: MukBackend>(
    sendbuf: *const u8,
    sendcounts: &[i32],
    sdispls: &[i32],
    sendtypes: &[usize],
    recvbuf: *mut u8,
    recvcounts: &[i32],
    rdispls: &[i32],
    recvtypes: &[usize],
    comm: usize,
    req: &mut usize,
) -> i32 {
    let st: Vec<A::Datatype> = sendtypes.iter().map(|&t| dt_to_impl::<A>(t)).collect();
    let rt: Vec<A::Datatype> = recvtypes.iter().map(|&t| dt_to_impl::<A>(t)).collect();
    let mut r = A::request_null();
    let rc = A::ialltoallw(buf_to_impl::<A>(sendbuf), sendcounts, sdispls, &st, recvbuf,
        recvcounts, rdispls, &rt, comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
        // The converted datatype vectors are temporary state that must
        // live until the request completes: park them in the request map
        // (the §6.2 mechanism whose lookup cost E5 measures).
        crate::muk::state::reqmap_insert(
            *req,
            crate::muk::state::WState {
                sendtypes: sendtypes.to_vec(),
                recvtypes: recvtypes.to_vec(),
            },
        );
    }
    ret_code::<A>(rc)
}

/// `WRAP_scan`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn scan<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: i32,
    dt: usize,
    op: usize,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::scan(buf_to_impl::<A>(sendbuf), recvbuf, count, dt_to_impl::<A>(dt),
        op_to_impl::<A>(op), comm_to_impl::<A>(comm)))
}

/// `WRAP_exscan`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn exscan<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: i32,
    dt: usize,
    op: usize,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::exscan(buf_to_impl::<A>(sendbuf), recvbuf, count, dt_to_impl::<A>(dt),
        op_to_impl::<A>(op), comm_to_impl::<A>(comm)))
}

/// `WRAP_reduce_scatter_block`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn reduce_scatter_block<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    recvcount: i32,
    dt: usize,
    op: usize,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::reduce_scatter_block(buf_to_impl::<A>(sendbuf), recvbuf, recvcount,
        dt_to_impl::<A>(dt), op_to_impl::<A>(op), comm_to_impl::<A>(comm)))
}

// --- Nonblocking collectives ---------------------------------------------------
//
// Each converts the standard-ABI handles into the backend representation,
// forwards, and converts the resulting request handle back — the
// request-heavy paths the paper's §6.2 worries about.

/// `WRAP_ibarrier`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn ibarrier<A: MukBackend>(comm: usize, req: &mut usize) -> i32 {
    let mut r = A::request_null();
    let rc = A::ibarrier(comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_ibcast`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn ibcast<A: MukBackend>(
    buf: *mut u8,
    count: i32,
    dt: usize,
    root: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::ibcast(buf, count, dt_to_impl::<A>(dt), root, comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_ireduce`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn ireduce<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: i32,
    dt: usize,
    op: usize,
    root: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::ireduce(buf_to_impl::<A>(sendbuf), recvbuf, count, dt_to_impl::<A>(dt),
        op_to_impl::<A>(op), root, comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_iallreduce`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn iallreduce<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: i32,
    dt: usize,
    op: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::iallreduce(buf_to_impl::<A>(sendbuf), recvbuf, count, dt_to_impl::<A>(dt),
        op_to_impl::<A>(op), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_igather`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn igather<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    root: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::igather(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcount, dt_to_impl::<A>(recvtype), root, comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_igatherv`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn igatherv<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcounts: &[i32],
    displs: &[i32],
    recvtype: usize,
    root: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::igatherv(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcounts, displs, dt_to_impl::<A>(recvtype), root, comm_to_impl::<A>(comm),
        &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_iscatter`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn iscatter<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    root: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let rb = recvbuf_to_impl::<A>(recvbuf);
    let mut r = A::request_null();
    let rc = A::iscatter(sendbuf, sendcount, dt_to_impl::<A>(sendtype), rb, recvcount,
        dt_to_impl::<A>(recvtype), root, comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_iscatterv`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn iscatterv<A: MukBackend>(
    sendbuf: *const u8,
    sendcounts: &[i32],
    displs: &[i32],
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    root: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let rb = recvbuf_to_impl::<A>(recvbuf);
    let mut r = A::request_null();
    let rc = A::iscatterv(sendbuf, sendcounts, displs, dt_to_impl::<A>(sendtype), rb, recvcount,
        dt_to_impl::<A>(recvtype), root, comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_iallgather`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn iallgather<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::iallgather(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcount, dt_to_impl::<A>(recvtype), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_iallgatherv`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn iallgatherv<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcounts: &[i32],
    displs: &[i32],
    recvtype: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::iallgatherv(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcounts, displs, dt_to_impl::<A>(recvtype), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_ialltoall`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn ialltoall<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::ialltoall(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcount, dt_to_impl::<A>(recvtype), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_ialltoallv`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn ialltoallv<A: MukBackend>(
    sendbuf: *const u8,
    sendcounts: &[i32],
    sdispls: &[i32],
    sendtype: usize,
    recvbuf: *mut u8,
    recvcounts: &[i32],
    rdispls: &[i32],
    recvtype: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::ialltoallv(buf_to_impl::<A>(sendbuf), sendcounts, sdispls,
        dt_to_impl::<A>(sendtype), recvbuf, recvcounts, rdispls, dt_to_impl::<A>(recvtype),
        comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_iscan`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn iscan<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: i32,
    dt: usize,
    op: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::iscan(buf_to_impl::<A>(sendbuf), recvbuf, count, dt_to_impl::<A>(dt),
        op_to_impl::<A>(op), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_iexscan`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn iexscan<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: i32,
    dt: usize,
    op: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::iexscan(buf_to_impl::<A>(sendbuf), recvbuf, count, dt_to_impl::<A>(dt),
        op_to_impl::<A>(op), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_ireduce_scatter_block`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn ireduce_scatter_block<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    recvcount: i32,
    dt: usize,
    op: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::ireduce_scatter_block(buf_to_impl::<A>(sendbuf), recvbuf, recvcount,
        dt_to_impl::<A>(dt), op_to_impl::<A>(op), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

// --- Persistent collectives (MPI-4) --------------------------------------------

/// `WRAP_barrier_init`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn barrier_init<A: MukBackend>(comm: usize, req: &mut usize) -> i32 {
    let mut r = A::request_null();
    let rc = A::barrier_init(comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_bcast_init`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn bcast_init<A: MukBackend>(
    buf: *mut u8,
    count: i32,
    dt: usize,
    root: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc =
        A::bcast_init(buf, count, dt_to_impl::<A>(dt), root, comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_allreduce_init`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn allreduce_init<A: MukBackend>(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: i32,
    dt: usize,
    op: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::allreduce_init(buf_to_impl::<A>(sendbuf), recvbuf, count, dt_to_impl::<A>(dt),
        op_to_impl::<A>(op), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_gather_init`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn gather_init<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    root: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::gather_init(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcount, dt_to_impl::<A>(recvtype), root, comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_scatter_init`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn scatter_init<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    root: i32,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let rb = recvbuf_to_impl::<A>(recvbuf);
    let mut r = A::request_null();
    let rc = A::scatter_init(sendbuf, sendcount, dt_to_impl::<A>(sendtype), rb, recvcount,
        dt_to_impl::<A>(recvtype), root, comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_alltoall_init`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn alltoall_init<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i32,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcount: i32,
    recvtype: usize,
    comm: usize,
    req: &mut usize,
) -> i32 {
    let mut r = A::request_null();
    let rc = A::alltoall_init(buf_to_impl::<A>(sendbuf), sendcount, dt_to_impl::<A>(sendtype),
        recvbuf, recvcount, dt_to_impl::<A>(recvtype), comm_to_impl::<A>(comm), &mut r);
    if rc == 0 {
        *req = req_to_muk::<A>(r);
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_create_keyval`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_create_keyval<A: MukBackend>(
    copy: Option<callbacks::MukCopyFn>,
    delete: Option<callbacks::MukDeleteFn>,
    extra_state: usize,
    out: &mut i32,
) -> i32 {
    let mut slots = (None, None);
    let copy_t = match copy {
        Some(f) => {
            let Some(s) = callbacks::alloc_copy_slot(f) else {
                return crate::abi::errors::MPI_ERR_NO_MEM;
            };
            slots.0 = Some(s);
            Some(callbacks::copy_tramp_pool::<A>()[s])
        }
        None => None,
    };
    let delete_t = match delete {
        Some(f) => {
            let Some(s) = callbacks::alloc_delete_slot(f) else {
                if let Some(cs) = slots.0 {
                    callbacks::free_copy_slot(cs);
                }
                return crate::abi::errors::MPI_ERR_NO_MEM;
            };
            slots.1 = Some(s);
            Some(callbacks::delete_tramp_pool::<A>()[s])
        }
        None => None,
    };
    let rc = A::comm_create_keyval(copy_t, delete_t, extra_state, out);
    if rc == 0 {
        crate::muk::state::remember_keyval_slots(*out, slots.0, slots.1);
    } else {
        if let Some(s) = slots.0 {
            callbacks::free_copy_slot(s);
        }
        if let Some(s) = slots.1 {
            callbacks::free_delete_slot(s);
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_free_keyval`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_free_keyval<A: MukBackend>(keyval: &mut i32) -> i32 {
    let kv = *keyval;
    let rc = A::comm_free_keyval(keyval);
    if rc == 0 {
        if let Some((c, d)) = crate::muk::state::forget_keyval_slots(kv) {
            if let Some(s) = c {
                callbacks::free_copy_slot(s);
            }
            if let Some(s) = d {
                callbacks::free_delete_slot(s);
            }
        }
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_set_attr`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_set_attr<A: MukBackend>(comm: usize, keyval: i32, value: usize) -> i32 {
    ret_code::<A>(A::comm_set_attr(comm_to_impl::<A>(comm), keyval, value))
}

/// `WRAP_comm_get_attr`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_get_attr<A: MukBackend>(
    comm: usize,
    keyval: i32,
    value: &mut usize,
    flag: &mut bool,
) -> i32 {
    ret_code::<A>(A::comm_get_attr(comm_to_impl::<A>(comm), keyval, value, flag))
}

/// `WRAP_comm_delete_attr`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_delete_attr<A: MukBackend>(comm: usize, keyval: i32) -> i32 {
    ret_code::<A>(A::comm_delete_attr(comm_to_impl::<A>(comm), keyval))
}

/// `WRAP_info_create`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn info_create<A: MukBackend>(out: &mut usize) -> i32 {
    let mut i = A::info_null();
    let rc = A::info_create(&mut i);
    if rc == 0 {
        *out = i.to_word();
    }
    ret_code::<A>(rc)
}

/// `WRAP_info_set`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn info_set<A: MukBackend>(info: usize, key: &str, value: &str) -> i32 {
    ret_code::<A>(A::info_set(info_to_impl::<A>(info), key, value))
}

/// `WRAP_info_get`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn info_get<A: MukBackend>(info: usize, key: &str, out: &mut String, flag: &mut bool) -> i32 {
    ret_code::<A>(A::info_get(info_to_impl::<A>(info), key, out, flag))
}

/// `WRAP_info_free`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn info_free<A: MukBackend>(info: &mut usize) -> i32 {
    let mut i = info_to_impl::<A>(*info);
    let rc = A::info_free(&mut i);
    if rc == 0 {
        *info = std_h::MPI_INFO_NULL;
    }
    ret_code::<A>(rc)
}

// --- One-sided communication -----------------------------------------------
//
// Window handles ride the word union like every other handle; the §5.4
// constants that differ per backend (lock types, assertion bitmasks)
// are translated by value, not bit pattern.

/// `WRAP_win_create`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn win_create<A: MukBackend>(
    base: *mut u8,
    size: isize,
    disp_unit: i32,
    info: usize,
    comm: usize,
    win: &mut usize,
) -> i32 {
    let mut w = A::win_null();
    let rc = A::win_create(base, size, disp_unit, info_to_impl::<A>(info),
        comm_to_impl::<A>(comm), &mut w);
    if rc == 0 {
        *win = win_to_muk::<A>(w);
    }
    ret_code::<A>(rc)
}

/// `WRAP_win_allocate`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn win_allocate<A: MukBackend>(
    size: isize,
    disp_unit: i32,
    info: usize,
    comm: usize,
    baseptr: &mut *mut u8,
    win: &mut usize,
) -> i32 {
    let mut w = A::win_null();
    let rc = A::win_allocate(size, disp_unit, info_to_impl::<A>(info), comm_to_impl::<A>(comm),
        baseptr, &mut w);
    if rc == 0 {
        *win = win_to_muk::<A>(w);
    }
    ret_code::<A>(rc)
}

/// `WRAP_win_free`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn win_free<A: MukBackend>(win: &mut usize) -> i32 {
    let mut w = win_to_impl::<A>(*win);
    let rc = A::win_free(&mut w);
    if rc == 0 {
        *win = std_h::MPI_WIN_NULL;
    }
    ret_code::<A>(rc)
}

/// `WRAP_win_fence`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn win_fence<A: MukBackend>(assert: i32, win: usize) -> i32 {
    ret_code::<A>(A::win_fence(assert_to_impl::<A>(assert), win_to_impl::<A>(win)))
}

/// `WRAP_win_lock`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn win_lock<A: MukBackend>(lock_type: i32, rank: i32, assert: i32, win: usize) -> i32 {
    ret_code::<A>(A::win_lock(lock_type_to_impl::<A>(lock_type), rank,
        assert_to_impl::<A>(assert), win_to_impl::<A>(win)))
}

/// `WRAP_win_unlock`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn win_unlock<A: MukBackend>(rank: i32, win: usize) -> i32 {
    ret_code::<A>(A::win_unlock(rank, win_to_impl::<A>(win)))
}

/// `WRAP_win_flush`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn win_flush<A: MukBackend>(rank: i32, win: usize) -> i32 {
    ret_code::<A>(A::win_flush(rank, win_to_impl::<A>(win)))
}

/// `WRAP_put`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn put<A: MukBackend>(
    origin: *const u8,
    origin_count: i32,
    origin_dt: usize,
    target_rank: i32,
    target_disp: isize,
    target_count: i32,
    target_dt: usize,
    win: usize,
) -> i32 {
    ret_code::<A>(A::put(origin, origin_count, dt_to_impl::<A>(origin_dt),
        dest_to_impl::<A>(target_rank), target_disp, target_count, dt_to_impl::<A>(target_dt),
        win_to_impl::<A>(win)))
}

/// `WRAP_get`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn get<A: MukBackend>(
    origin: *mut u8,
    origin_count: i32,
    origin_dt: usize,
    target_rank: i32,
    target_disp: isize,
    target_count: i32,
    target_dt: usize,
    win: usize,
) -> i32 {
    ret_code::<A>(A::get(origin, origin_count, dt_to_impl::<A>(origin_dt),
        dest_to_impl::<A>(target_rank), target_disp, target_count, dt_to_impl::<A>(target_dt),
        win_to_impl::<A>(win)))
}

/// `WRAP_accumulate`: translate handles/constants at the boundary, call the backend, translate results back.
#[allow(clippy::too_many_arguments)]
pub fn accumulate<A: MukBackend>(
    origin: *const u8,
    origin_count: i32,
    origin_dt: usize,
    target_rank: i32,
    target_disp: isize,
    target_count: i32,
    target_dt: usize,
    op: usize,
    win: usize,
) -> i32 {
    ret_code::<A>(A::accumulate(origin, origin_count, dt_to_impl::<A>(origin_dt),
        dest_to_impl::<A>(target_rank), target_disp, target_count, dt_to_impl::<A>(target_dt),
        op_to_impl::<A>(op), win_to_impl::<A>(win)))
}

/// `WRAP_get_elements`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn get_elements<A: MukBackend>(status: *const AbiStatus, dt: usize, out: &mut i32) -> i32 {
    // Rebuild a backend-layout status carrying the muk status's byte
    // count (the wrap library knows the backend layout — it is compiled
    // against that mpi.h), then let the backend resolve the leaf
    // decomposition through its own datatype representation.
    let s = unsafe { &*status };
    let b = A::status_with_bytes(s.count_bytes());
    *out = A::get_elements(&b, dt_to_impl::<A>(dt));
    if *out == A::undefined() {
        *out = crate::abi::constants::MPI_UNDEFINED;
    }
    0
}

/// `WRAP_get_count`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn get_count<A: MukBackend>(status: *const AbiStatus, dt: usize, out: &mut i32) -> i32 {
    // Counts live in the MUK status's reserved fields after conversion.
    let s = unsafe { &*status };
    let mut size = 0;
    let rc = A::type_size(dt_to_impl::<A>(dt), &mut size);
    if rc != 0 {
        return ret_code::<A>(rc);
    }
    if size == 0 {
        *out = 0;
        return 0;
    }
    let bytes = s.count_bytes();
    *out = if bytes % size as u64 != 0 {
        crate::abi::constants::MPI_UNDEFINED
    } else if bytes / size as u64 > i32::MAX as u64 {
        // MPI-4.1 §3.2.5: count exceeds `int` range — the classic entry
        // point reports MPI_UNDEFINED; `WRAP_get_count_c` is lossless.
        crate::abi::constants::MPI_UNDEFINED
    } else {
        (bytes / size as u64) as i32
    };
    0
}

/// `WRAP_get_count_c`: the embiggened `MPI_Get_count_c` — the count
/// crosses the wrap boundary as a 64-bit `MPI_Count`, so transfers
/// beyond `INT_MAX` items round-trip without truncation.
pub fn get_count_c<A: MukBackend>(status: *const AbiStatus, dt: usize, out: &mut i64) -> i32 {
    let s = unsafe { &*status };
    let mut size: i64 = 0;
    let rc = A::type_size_c(dt_to_impl::<A>(dt), &mut size);
    if rc != 0 {
        return ret_code::<A>(rc);
    }
    if size == 0 {
        *out = 0;
        return 0;
    }
    let bytes = s.count_bytes();
    *out = if bytes % size as u64 != 0 {
        crate::abi::constants::MPI_UNDEFINED as i64
    } else {
        (bytes / size as u64) as i64
    };
    0
}

/// `WRAP_get_elements_c`: `MPI_Get_elements_c` — basic-element count as
/// `MPI_Count`, resolved through the backend's datatype representation.
pub fn get_elements_c<A: MukBackend>(status: *const AbiStatus, dt: usize, out: &mut i64) -> i32 {
    let s = unsafe { &*status };
    let b = A::status_with_bytes(s.count_bytes());
    let rc = A::get_elements_c(&b, dt_to_impl::<A>(dt), out);
    if rc == 0 && *out == A::undefined() as i64 {
        *out = crate::abi::constants::MPI_UNDEFINED as i64;
    }
    ret_code::<A>(rc)
}

/// `WRAP_status_set_elements_c`: `MPI_Status_set_elements_c` — rewrite
/// the muk status's hidden byte count from an `MPI_Count` element count
/// (the datatype size comes from the backend).
pub fn status_set_elements_c<A: MukBackend>(status: *mut AbiStatus, dt: usize, count: i64) -> i32 {
    if count < 0 {
        return crate::abi::errors::MPI_ERR_COUNT;
    }
    let mut size: i64 = 0;
    let rc = A::type_size_c(dt_to_impl::<A>(dt), &mut size);
    if rc != 0 {
        return ret_code::<A>(rc);
    }
    let Some(bytes) = (count as u64).checked_mul(size as u64) else {
        return crate::abi::errors::MPI_ERR_COUNT;
    };
    let s = unsafe { &mut *status };
    let cancelled = s.cancelled();
    s.set_count_and_cancelled(bytes, cancelled);
    0
}

/// `WRAP_type_size_c`: `MPI_Type_size_c` — datatype size as `MPI_Count`.
pub fn type_size_c<A: MukBackend>(dt: usize, out: &mut i64) -> i32 {
    ret_code::<A>(A::type_size_c(dt_to_impl::<A>(dt), out))
}

/// `WRAP_type_contiguous_c`: `MPI_Type_contiguous_c` — large-count
/// contiguous datatype constructor.
pub fn type_contiguous_c<A: MukBackend>(count: i64, child: usize, out: &mut usize) -> i32 {
    let mut d = A::datatype(crate::api::Dt::Byte);
    let rc = A::type_contiguous_c(count, dt_to_impl::<A>(child), &mut d);
    if rc == 0 {
        *out = dt_to_muk::<A>(d);
    }
    ret_code::<A>(rc)
}

/// `WRAP_type_vector_c`: `MPI_Type_vector_c` — large-count vector
/// constructor (sparse multi-GiB extents under bounded memory).
pub fn type_vector_c<A: MukBackend>(
    count: i64,
    blocklen: i64,
    stride: i64,
    child: usize,
    out: &mut usize,
) -> i32 {
    let mut d = A::datatype(crate::api::Dt::Byte);
    let rc = A::type_vector_c(count, blocklen, stride, dt_to_impl::<A>(child), &mut d);
    if rc == 0 {
        *out = dt_to_muk::<A>(d);
    }
    ret_code::<A>(rc)
}

/// `WRAP_send_c`: `MPI_Send_c` — standard-mode send with an `MPI_Count`
/// count word.
pub fn send_c<A: MukBackend>(
    buf: *const u8,
    count: i64,
    dt: usize,
    dest: i32,
    tag: i32,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::send_c(buf, count, dt_to_impl::<A>(dt), dest_to_impl::<A>(dest), tag,
        comm_to_impl::<A>(comm)))
}

/// `WRAP_recv_c`: `MPI_Recv_c` — receive with an `MPI_Count` count word.
pub fn recv_c<A: MukBackend>(
    buf: *mut u8,
    count: i64,
    dt: usize,
    src: i32,
    tag: i32,
    comm: usize,
    status: *mut AbiStatus,
) -> i32 {
    let mut s = A::status_empty();
    let rc = A::recv_c(buf, count, dt_to_impl::<A>(dt), src_to_impl::<A>(src),
        tag_to_impl::<A>(tag), comm_to_impl::<A>(comm), &mut s);
    if !status.is_null() {
        unsafe { *status = status_to_muk::<A>(&s) };
    }
    ret_code::<A>(rc)
}

/// `WRAP_allgatherv_c`: `MPI_Allgatherv_c` — per-rank counts cross the
/// boundary as `MPI_Count[]` and displacements as `MPI_Aint[]`.
#[allow(clippy::too_many_arguments)]
pub fn allgatherv_c<A: MukBackend>(
    sendbuf: *const u8,
    sendcount: i64,
    sendtype: usize,
    recvbuf: *mut u8,
    recvcounts: &[i64],
    displs: &[isize],
    recvtype: usize,
    comm: usize,
) -> i32 {
    ret_code::<A>(A::allgatherv_c(
        buf_to_impl::<A>(sendbuf),
        sendcount,
        dt_to_impl::<A>(sendtype),
        recvbuf,
        crate::api::Counts::Count(recvcounts),
        crate::api::Displs::Aint(displs),
        dt_to_impl::<A>(recvtype),
        comm_to_impl::<A>(comm),
    ))
}

// --- Sessions (MPI-4) --------------------------------------------------------
//
// The session handle rides the word union like every other handle kind;
// the only constant to translate is `MPI_SESSION_NULL`. The pset-name
// and tag-string arguments are plain strings — nothing ABI-specific.

/// `WRAP_session_init`: translate the info/errhandler handles, call the
/// backend, hand back the session word.
pub fn session_init<A: MukBackend>(info: usize, errh: usize, session: &mut usize) -> i32 {
    let mut s = A::session_null();
    let rc = A::session_init(info_to_impl::<A>(info), errh_to_impl::<A>(errh), &mut s);
    if rc == 0 {
        *session = session_to_muk::<A>(s);
    }
    ret_code::<A>(rc)
}

/// `WRAP_session_finalize`: nulls the muk-side word on success.
pub fn session_finalize<A: MukBackend>(session: &mut usize) -> i32 {
    let mut s = session_to_impl::<A>(*session);
    let rc = A::session_finalize(&mut s);
    if rc == 0 {
        *session = std_h::MPI_SESSION_NULL;
    }
    ret_code::<A>(rc)
}

/// `WRAP_session_get_num_psets`.
pub fn session_get_num_psets<A: MukBackend>(session: usize, out: &mut i32) -> i32 {
    ret_code::<A>(A::session_get_num_psets(session_to_impl::<A>(session), out))
}

/// `WRAP_session_get_nth_pset`.
pub fn session_get_nth_pset<A: MukBackend>(session: usize, n: i32, out: &mut String) -> i32 {
    ret_code::<A>(A::session_get_nth_pset(session_to_impl::<A>(session), n, out))
}

/// `WRAP_session_get_pset_info`: the returned info handle crosses back
/// as a word (the caller frees it through `WRAP_info_free`).
pub fn session_get_pset_info<A: MukBackend>(session: usize, pset: &str, out: &mut usize) -> i32 {
    let mut i = A::info_null();
    let rc = A::session_get_pset_info(session_to_impl::<A>(session), pset, &mut i);
    if rc == 0 {
        *out = i.to_word();
    }
    ret_code::<A>(rc)
}

/// `WRAP_group_from_session_pset`.
pub fn group_from_session_pset<A: MukBackend>(session: usize, pset: &str, out: &mut usize) -> i32 {
    let mut g = A::Group::from_word(0);
    let rc = A::group_from_session_pset(session_to_impl::<A>(session), pset, &mut g);
    if rc == 0 {
        *out = g.to_word();
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_create_from_group`: the no-parent communicator
/// constructor — group and errhandler handles translate; the tag string
/// passes through untouched (it is the disambiguator, not a handle).
pub fn comm_create_from_group<A: MukBackend>(
    group: usize,
    stringtag: &str,
    info: usize,
    errh: usize,
    out: &mut usize,
) -> i32 {
    let mut c = A::comm_null();
    let rc = A::comm_create_from_group(group_to_impl::<A>(group), stringtag,
        info_to_impl::<A>(info), errh_to_impl::<A>(errh), &mut c);
    if rc == 0 {
        *out = comm_to_muk::<A>(c);
    }
    ret_code::<A>(rc)
}

// --- Tools interface (MPI_T) -----------------------------------------------------
//
// MPI_T crosses the wrap boundary untranslated: every argument is a
// plain integer (handles and sessions are i32 indices in all ABIs, per
// SPEC §11), so only the return code needs mapping back to the
// standard-ABI error numbering.

/// `WRAP_t_init_thread`.
pub fn t_init_thread<A: MukBackend>(required: i32, provided: &mut i32) -> i32 {
    ret_code::<A>(A::t_init_thread(required, provided))
}

/// `WRAP_t_finalize`.
pub fn t_finalize<A: MukBackend>() -> i32 {
    ret_code::<A>(A::t_finalize())
}

/// `WRAP_t_cvar_get_num`.
pub fn t_cvar_get_num<A: MukBackend>(num: &mut i32) -> i32 {
    ret_code::<A>(A::t_cvar_get_num(num))
}

/// `WRAP_t_cvar_get_info`.
pub fn t_cvar_get_info<A: MukBackend>(
    index: i32,
    name: &mut String,
    verbosity: &mut i32,
    bind: &mut i32,
    scope: &mut i32,
) -> i32 {
    ret_code::<A>(A::t_cvar_get_info(index, name, verbosity, bind, scope))
}

/// `WRAP_t_cvar_handle_alloc`.
pub fn t_cvar_handle_alloc<A: MukBackend>(index: i32, handle: &mut i32) -> i32 {
    ret_code::<A>(A::t_cvar_handle_alloc(index, handle))
}

/// `WRAP_t_cvar_read`.
pub fn t_cvar_read<A: MukBackend>(handle: i32, value: &mut i64) -> i32 {
    ret_code::<A>(A::t_cvar_read(handle, value))
}

/// `WRAP_t_cvar_write`.
pub fn t_cvar_write<A: MukBackend>(handle: i32, value: i64) -> i32 {
    ret_code::<A>(A::t_cvar_write(handle, value))
}

/// `WRAP_t_pvar_get_num`.
pub fn t_pvar_get_num<A: MukBackend>(num: &mut i32) -> i32 {
    ret_code::<A>(A::t_pvar_get_num(num))
}

/// `WRAP_t_pvar_get_info`.
pub fn t_pvar_get_info<A: MukBackend>(
    index: i32,
    name: &mut String,
    verbosity: &mut i32,
    class: &mut i32,
    bind: &mut i32,
) -> i32 {
    ret_code::<A>(A::t_pvar_get_info(index, name, verbosity, class, bind))
}

/// `WRAP_t_pvar_session_create`.
pub fn t_pvar_session_create<A: MukBackend>(session: &mut i32) -> i32 {
    ret_code::<A>(A::t_pvar_session_create(session))
}

/// `WRAP_t_pvar_handle_alloc`.
pub fn t_pvar_handle_alloc<A: MukBackend>(session: i32, index: i32, handle: &mut i32) -> i32 {
    ret_code::<A>(A::t_pvar_handle_alloc(session, index, handle))
}

/// `WRAP_t_pvar_start`.
pub fn t_pvar_start<A: MukBackend>(session: i32, handle: i32) -> i32 {
    ret_code::<A>(A::t_pvar_start(session, handle))
}

/// `WRAP_t_pvar_read`.
pub fn t_pvar_read<A: MukBackend>(session: i32, handle: i32, value: &mut i64) -> i32 {
    ret_code::<A>(A::t_pvar_read(session, handle, value))
}

/// `WRAP_t_pvar_reset`.
pub fn t_pvar_reset<A: MukBackend>(session: i32, handle: i32) -> i32 {
    ret_code::<A>(A::t_pvar_reset(session, handle))
}

/// `WRAP_comm_revoke`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_revoke<A: MukBackend>(comm: usize) -> i32 {
    ret_code::<A>(A::comm_revoke(comm_to_impl::<A>(comm)))
}

/// `WRAP_comm_is_revoked`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_is_revoked<A: MukBackend>(comm: usize, out: &mut bool) -> i32 {
    ret_code::<A>(A::comm_is_revoked(comm_to_impl::<A>(comm), out))
}

/// `WRAP_comm_shrink`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_shrink<A: MukBackend>(comm: usize, out: &mut usize) -> i32 {
    let mut c = A::comm_null();
    let rc = A::comm_shrink(comm_to_impl::<A>(comm), &mut c);
    if rc == 0 {
        *out = comm_to_muk::<A>(c);
    }
    ret_code::<A>(rc)
}

/// `WRAP_comm_agree`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_agree<A: MukBackend>(comm: usize, flag: &mut i32) -> i32 {
    ret_code::<A>(A::comm_agree(comm_to_impl::<A>(comm), flag))
}

/// `WRAP_comm_ack_failed`: translate handles/constants at the boundary, call the backend, translate results back.
pub fn comm_ack_failed<A: MukBackend>(comm: usize, num_to_ack: i32, num_acked: &mut i32) -> i32 {
    ret_code::<A>(A::comm_ack_failed(comm_to_impl::<A>(comm), num_to_ack, num_acked))
}

// --- The vtable and symbol table -------------------------------------------------

macro_rules! define_vtable {
    ($( $name:ident : $ty:ty ),* $(,)?) => {
        /// `libmuk`'s resolved function-pointer table (MUK_* pointers in
        /// the paper's listing).
        #[allow(non_snake_case)]
        pub struct Vtable {
            $(
                #[doc = concat!("`WRAP_", stringify!($name), "`, resolved to a typed fn pointer.")]
                pub $name: $ty,
            )*
        }

        impl Vtable {
            /// Resolve every `WRAP_*` symbol from an opened backend —
            /// the dlsym loop Mukautuva runs at init.
            pub fn resolve(st: &SymbolTable) -> Vtable {
                Vtable {
                    $( $name: unsafe { st.dlsym::<$ty>(concat!("WRAP_", stringify!($name))) }, )*
                }
            }
        }

        /// Build the WRAP symbol table for backend `A` — what compiling
        /// `impl-wrap.c` against the backend's `mpi.h` produces.
        pub fn build_symbols<A: MukBackend>(backend_name: &'static str) -> SymbolTable {
            let mut map: HashMap<&'static str, *const ()> = HashMap::new();
            $( map.insert(concat!("WRAP_", stringify!($name)), $name::<A> as *const ()); )*
            SymbolTable { map, backend_name }
        }
    };
}

define_vtable! {
    init: fn() -> i32,
    finalize: fn() -> i32,
    initialized: fn() -> bool,
    finalized: fn() -> bool,
    abort: fn(usize, i32) -> i32,
    wtime: fn() -> f64,
    get_library_version: fn(&mut String) -> i32,
    get_version: fn(&mut i32, &mut i32) -> i32,
    get_processor_name: fn(&mut String) -> i32,
    comm_size: fn(usize, &mut i32) -> i32,
    comm_rank: fn(usize, &mut i32) -> i32,
    comm_dup: fn(usize, &mut usize) -> i32,
    comm_split: fn(usize, i32, i32, &mut usize) -> i32,
    comm_split_type: fn(usize, i32, i32, &mut usize) -> i32,
    comm_free: fn(&mut usize) -> i32,
    comm_compare: fn(usize, usize, &mut i32) -> i32,
    comm_set_name: fn(usize, &str) -> i32,
    comm_get_name: fn(usize, &mut String) -> i32,
    comm_group: fn(usize, &mut usize) -> i32,
    group_size: fn(usize, &mut i32) -> i32,
    group_rank: fn(usize, &mut i32) -> i32,
    group_incl: fn(usize, &[i32], &mut usize) -> i32,
    group_translate_ranks: fn(usize, &[i32], usize, &mut [i32]) -> i32,
    group_free: fn(&mut usize) -> i32,
    comm_set_errhandler: fn(usize, usize) -> i32,
    comm_get_errhandler: fn(usize, &mut usize) -> i32,
    comm_create_errhandler: fn(callbacks::MukErrhFn, &mut usize) -> i32,
    errhandler_free: fn(&mut usize) -> i32,
    send: fn(*const u8, i32, usize, i32, i32, usize) -> i32,
    ssend: fn(*const u8, i32, usize, i32, i32, usize) -> i32,
    recv: fn(*mut u8, i32, usize, i32, i32, usize, *mut AbiStatus) -> i32,
    isend: fn(*const u8, i32, usize, i32, i32, usize, &mut usize) -> i32,
    issend: fn(*const u8, i32, usize, i32, i32, usize, &mut usize) -> i32,
    irecv: fn(*mut u8, i32, usize, i32, i32, usize, &mut usize) -> i32,
    wait: fn(&mut usize, *mut AbiStatus) -> i32,
    test: fn(&mut usize, &mut bool, *mut AbiStatus) -> i32,
    waitall: fn(&mut [usize], *mut AbiStatus) -> i32,
    testall: fn(&mut [usize], &mut bool, *mut AbiStatus) -> i32,
    waitany: fn(&mut [usize], &mut i32, *mut AbiStatus) -> i32,
    testany: fn(&mut [usize], &mut i32, &mut bool, *mut AbiStatus) -> i32,
    waitsome: fn(&mut [usize], &mut i32, &mut [i32], *mut AbiStatus) -> i32,
    testsome: fn(&mut [usize], &mut i32, &mut [i32], *mut AbiStatus) -> i32,
    probe: fn(i32, i32, usize, *mut AbiStatus) -> i32,
    iprobe: fn(i32, i32, usize, &mut bool, *mut AbiStatus) -> i32,
    cancel: fn(&mut usize) -> i32,
    request_free: fn(&mut usize) -> i32,
    send_init: fn(*const u8, i32, usize, i32, i32, usize, &mut usize) -> i32,
    ssend_init: fn(*const u8, i32, usize, i32, i32, usize, &mut usize) -> i32,
    recv_init: fn(*mut u8, i32, usize, i32, i32, usize, &mut usize) -> i32,
    start: fn(&mut usize) -> i32,
    startall: fn(&mut [usize]) -> i32,
    sendrecv: fn(*const u8, i32, usize, i32, i32, *mut u8, i32, usize, i32, i32, usize, *mut AbiStatus) -> i32,
    type_size: fn(usize, &mut i32) -> i32,
    type_get_extent: fn(usize, &mut isize, &mut isize) -> i32,
    type_contiguous: fn(i32, usize, &mut usize) -> i32,
    type_vector: fn(i32, i32, i32, usize, &mut usize) -> i32,
    type_create_struct: fn(&[(i32, isize, usize)], &mut usize) -> i32,
    type_commit: fn(&mut usize) -> i32,
    type_free: fn(&mut usize) -> i32,
    type_dup: fn(usize, &mut usize) -> i32,
    op_create: fn(callbacks::MukOpFn, bool, &mut usize) -> i32,
    op_free: fn(&mut usize) -> i32,
    barrier: fn(usize) -> i32,
    bcast: fn(*mut u8, i32, usize, i32, usize) -> i32,
    reduce: fn(*const u8, *mut u8, i32, usize, usize, i32, usize) -> i32,
    allreduce: fn(*const u8, *mut u8, i32, usize, usize, usize) -> i32,
    gather: fn(*const u8, i32, usize, *mut u8, i32, usize, i32, usize) -> i32,
    scatter: fn(*const u8, i32, usize, *mut u8, i32, usize, i32, usize) -> i32,
    allgather: fn(*const u8, i32, usize, *mut u8, i32, usize, usize) -> i32,
    alltoall: fn(*const u8, i32, usize, *mut u8, i32, usize, usize) -> i32,
    alltoallw: fn(*const u8, &[i32], &[i32], &[usize], *mut u8, &[i32], &[i32], &[usize], usize) -> i32,
    ialltoallw: fn(*const u8, &[i32], &[i32], &[usize], *mut u8, &[i32], &[i32], &[usize], usize, &mut usize) -> i32,
    scan: fn(*const u8, *mut u8, i32, usize, usize, usize) -> i32,
    exscan: fn(*const u8, *mut u8, i32, usize, usize, usize) -> i32,
    reduce_scatter_block: fn(*const u8, *mut u8, i32, usize, usize, usize) -> i32,
    ibarrier: fn(usize, &mut usize) -> i32,
    ibcast: fn(*mut u8, i32, usize, i32, usize, &mut usize) -> i32,
    ireduce: fn(*const u8, *mut u8, i32, usize, usize, i32, usize, &mut usize) -> i32,
    iallreduce: fn(*const u8, *mut u8, i32, usize, usize, usize, &mut usize) -> i32,
    igather: fn(*const u8, i32, usize, *mut u8, i32, usize, i32, usize, &mut usize) -> i32,
    igatherv: fn(*const u8, i32, usize, *mut u8, &[i32], &[i32], usize, i32, usize, &mut usize) -> i32,
    iscatter: fn(*const u8, i32, usize, *mut u8, i32, usize, i32, usize, &mut usize) -> i32,
    iscatterv: fn(*const u8, &[i32], &[i32], usize, *mut u8, i32, usize, i32, usize, &mut usize) -> i32,
    iallgather: fn(*const u8, i32, usize, *mut u8, i32, usize, usize, &mut usize) -> i32,
    iallgatherv: fn(*const u8, i32, usize, *mut u8, &[i32], &[i32], usize, usize, &mut usize) -> i32,
    ialltoall: fn(*const u8, i32, usize, *mut u8, i32, usize, usize, &mut usize) -> i32,
    ialltoallv: fn(*const u8, &[i32], &[i32], usize, *mut u8, &[i32], &[i32], usize, usize, &mut usize) -> i32,
    iscan: fn(*const u8, *mut u8, i32, usize, usize, usize, &mut usize) -> i32,
    iexscan: fn(*const u8, *mut u8, i32, usize, usize, usize, &mut usize) -> i32,
    ireduce_scatter_block: fn(*const u8, *mut u8, i32, usize, usize, usize, &mut usize) -> i32,
    barrier_init: fn(usize, &mut usize) -> i32,
    bcast_init: fn(*mut u8, i32, usize, i32, usize, &mut usize) -> i32,
    allreduce_init: fn(*const u8, *mut u8, i32, usize, usize, usize, &mut usize) -> i32,
    gather_init: fn(*const u8, i32, usize, *mut u8, i32, usize, i32, usize, &mut usize) -> i32,
    scatter_init: fn(*const u8, i32, usize, *mut u8, i32, usize, i32, usize, &mut usize) -> i32,
    alltoall_init: fn(*const u8, i32, usize, *mut u8, i32, usize, usize, &mut usize) -> i32,
    comm_create_keyval: fn(Option<callbacks::MukCopyFn>, Option<callbacks::MukDeleteFn>, usize, &mut i32) -> i32,
    comm_free_keyval: fn(&mut i32) -> i32,
    comm_set_attr: fn(usize, i32, usize) -> i32,
    comm_get_attr: fn(usize, i32, &mut usize, &mut bool) -> i32,
    comm_delete_attr: fn(usize, i32) -> i32,
    info_create: fn(&mut usize) -> i32,
    info_set: fn(usize, &str, &str) -> i32,
    info_get: fn(usize, &str, &mut String, &mut bool) -> i32,
    info_free: fn(&mut usize) -> i32,
    get_count: fn(*const AbiStatus, usize, &mut i32) -> i32,
    get_elements: fn(*const AbiStatus, usize, &mut i32) -> i32,
    get_count_c: fn(*const AbiStatus, usize, &mut i64) -> i32,
    get_elements_c: fn(*const AbiStatus, usize, &mut i64) -> i32,
    status_set_elements_c: fn(*mut AbiStatus, usize, i64) -> i32,
    type_size_c: fn(usize, &mut i64) -> i32,
    type_contiguous_c: fn(i64, usize, &mut usize) -> i32,
    type_vector_c: fn(i64, i64, i64, usize, &mut usize) -> i32,
    send_c: fn(*const u8, i64, usize, i32, i32, usize) -> i32,
    recv_c: fn(*mut u8, i64, usize, i32, i32, usize, *mut AbiStatus) -> i32,
    allgatherv_c: fn(*const u8, i64, usize, *mut u8, &[i64], &[isize], usize, usize) -> i32,
    win_create: fn(*mut u8, isize, i32, usize, usize, &mut usize) -> i32,
    win_allocate: fn(isize, i32, usize, usize, &mut *mut u8, &mut usize) -> i32,
    win_free: fn(&mut usize) -> i32,
    win_fence: fn(i32, usize) -> i32,
    win_lock: fn(i32, i32, i32, usize) -> i32,
    win_unlock: fn(i32, usize) -> i32,
    win_flush: fn(i32, usize) -> i32,
    put: fn(*const u8, i32, usize, i32, isize, i32, usize, usize) -> i32,
    get: fn(*mut u8, i32, usize, i32, isize, i32, usize, usize) -> i32,
    accumulate: fn(*const u8, i32, usize, i32, isize, i32, usize, usize, usize) -> i32,
    session_init: fn(usize, usize, &mut usize) -> i32,
    session_finalize: fn(&mut usize) -> i32,
    session_get_num_psets: fn(usize, &mut i32) -> i32,
    session_get_nth_pset: fn(usize, i32, &mut String) -> i32,
    session_get_pset_info: fn(usize, &str, &mut usize) -> i32,
    group_from_session_pset: fn(usize, &str, &mut usize) -> i32,
    comm_create_from_group: fn(usize, &str, usize, usize, &mut usize) -> i32,
    t_init_thread: fn(i32, &mut i32) -> i32,
    t_finalize: fn() -> i32,
    t_cvar_get_num: fn(&mut i32) -> i32,
    t_cvar_get_info: fn(i32, &mut String, &mut i32, &mut i32, &mut i32) -> i32,
    t_cvar_handle_alloc: fn(i32, &mut i32) -> i32,
    t_cvar_read: fn(i32, &mut i64) -> i32,
    t_cvar_write: fn(i32, i64) -> i32,
    t_pvar_get_num: fn(&mut i32) -> i32,
    t_pvar_get_info: fn(i32, &mut String, &mut i32, &mut i32, &mut i32) -> i32,
    t_pvar_session_create: fn(&mut i32) -> i32,
    t_pvar_handle_alloc: fn(i32, i32, &mut i32) -> i32,
    t_pvar_start: fn(i32, i32) -> i32,
    t_pvar_read: fn(i32, i32, &mut i64) -> i32,
    t_pvar_reset: fn(i32, i32) -> i32,
    comm_revoke: fn(usize) -> i32,
    comm_is_revoked: fn(usize, &mut bool) -> i32,
    comm_shrink: fn(usize, &mut usize) -> i32,
    comm_agree: fn(usize, &mut i32) -> i32,
    comm_ack_failed: fn(usize, i32, &mut i32) -> i32,
}
