//! Property-based tests (hand-rolled xorshift generator; proptest is not
//! in the offline crate set). Each property runs a few hundred random
//! cases with a fixed seed for reproducibility.

use mpi_abi::abi;
use mpi_abi::core::request::StatusCore;
use mpi_abi::impls::mpich::MpichRepr;
use mpi_abi::impls::ompi::OmpiRepr;
use mpi_abi::impls::repr::Repr;
use mpi_abi::native_abi::NativeRepr;

/// xorshift64* PRNG — deterministic, decent distribution.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo)
    }

    fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + (self.next() % ((hi - lo) as u64)) as i32
    }

    fn bool(&mut self) -> bool {
        self.next() & 1 == 1
    }
}

const CASES: usize = 500;

// --- Handle representation roundtrips ---------------------------------------

#[test]
fn prop_mpich_handle_roundtrips() {
    let mut rng = Rng::new(42);
    for _ in 0..CASES {
        let id = mpi_abi::core::CommId(rng.range(2, 1 << 20) as u32);
        assert_eq!(MpichRepr::comm_id(MpichRepr::comm_h(id)).unwrap(), id);
        let rid = mpi_abi::core::ReqId(rng.range(0, 1 << 20) as u32);
        assert_eq!(MpichRepr::req_id(MpichRepr::req_h(rid)).unwrap(), rid);
        // id 0 is MPI_DATATYPE_NULL: its handle is the null constant,
        // which correctly refuses conversion — start at 1.
        let did = mpi_abi::core::DtId(rng.range(1, mpi_abi::core::reserved::NUM_BUILTIN_DTYPES as u64) as u32);
        assert_eq!(MpichRepr::dt_id(MpichRepr::dt_h(did)).unwrap(), did);
        // Derived datatype ids too.
        let did = mpi_abi::core::DtId(rng.range(64, 1 << 20) as u32);
        assert_eq!(MpichRepr::dt_id(MpichRepr::dt_h(did)).unwrap(), did);
    }
}

#[test]
fn prop_native_abi_handle_roundtrips_avoid_zero_page() {
    let mut rng = Rng::new(43);
    for _ in 0..CASES {
        let id = mpi_abi::core::CommId(rng.range(2, 1 << 24) as u32);
        let h = NativeRepr::comm_h(id);
        assert!(h.0 > abi::huffman::HUFFMAN_MAX, "user handle in zero page: {:#x}", h.0);
        assert_eq!(NativeRepr::comm_id(h).unwrap(), id);
        let rid = mpi_abi::core::ReqId(rng.range(0, 1 << 24) as u32);
        let rh = NativeRepr::req_h(rid);
        assert_eq!(NativeRepr::req_id(rh).unwrap(), rid);
        // Cross-kind confusion must be rejected.
        assert!(NativeRepr::comm_id(abi::handles::AbiComm(rh.0)).is_err());
    }
}

#[test]
fn prop_muk_word_union_roundtrips() {
    use mpi_abi::muk::word::AsWord;
    let mut rng = Rng::new(44);
    for _ in 0..CASES {
        // MPICH user handles are arbitrary i32s with the DIRECT bit.
        let h = (rng.next() as u32 | 0x8000_0000) as i32;
        assert_eq!(<i32 as AsWord>::from_word(h.to_word()), h);
    }
}

// --- Huffman codec ------------------------------------------------------------

#[test]
fn prop_huffman_kind_decode_total_and_stable() {
    // Every 10-bit value decodes to exactly one kind, and twice the same.
    for v in 0..=abi::huffman::HUFFMAN_MAX {
        let a = abi::huffman::kind_of(v as u16);
        let b = abi::huffman::kind_of(v as u16);
        assert_eq!(a, b);
        // Fixed-size decode only fires for datatype kind.
        if abi::huffman::fixed_size_of(v).is_some() {
            assert_eq!(a, abi::huffman::HandleKind::Datatype, "{v:#012b}");
        }
    }
}

#[test]
fn prop_fixed_size_is_power_of_two() {
    for v in 0..=abi::huffman::HUFFMAN_MAX {
        if let Some(s) = abi::huffman::fixed_size_of(v) {
            assert!(s.is_power_of_two());
            assert!(s <= 128);
        }
    }
}

// --- Status conversion --------------------------------------------------------

fn random_status(rng: &mut Rng) -> StatusCore {
    StatusCore {
        source: rng.i32_in(0, 1 << 20),
        tag: rng.i32_in(0, 1 << 20),
        error: if rng.bool() { 0 } else { rng.i32_in(1, 60) },
        count_bytes: rng.range(0, 1 << 40),
        cancelled: rng.bool(),
    }
}

#[test]
fn prop_status_layouts_preserve_fields() {
    let mut rng = Rng::new(45);
    for _ in 0..CASES {
        let s = random_status(&mut rng);
        // MPICH layout (split 63-bit count + cancel bit).
        let m = MpichRepr::status_from_core(&s);
        assert_eq!(MpichRepr::status_source(&m), s.source);
        assert_eq!(MpichRepr::status_tag(&m), s.tag);
        assert_eq!(MpichRepr::status_count_bytes(&m), s.count_bytes);
        assert_eq!(MpichRepr::status_cancelled(&m), s.cancelled);
        // OMPI layout (size_t _ucount).
        let o = OmpiRepr::status_from_core(&s);
        assert_eq!(OmpiRepr::status_source(&o), s.source);
        assert_eq!(OmpiRepr::status_count_bytes(&o), s.count_bytes);
        assert_eq!(OmpiRepr::status_cancelled(&o), s.cancelled);
        // Standard ABI (reserved-field packing).
        let a = NativeRepr::status_from_core(&s);
        assert_eq!(NativeRepr::status_source(&a), s.source);
        assert_eq!(NativeRepr::status_count_bytes(&a), s.count_bytes);
        assert_eq!(NativeRepr::status_cancelled(&a), s.cancelled);
    }
}

#[test]
fn prop_muk_status_conversion_preserves_count() {
    use mpi_abi::impls::MpichAbi;
    let mut rng = Rng::new(46);
    for _ in 0..CASES {
        let mut s = random_status(&mut rng);
        s.error = 0;
        s.source = rng.i32_in(0, 1000);
        let backend = MpichRepr::status_from_core(&s);
        let muk = mpi_abi::muk::convert::status_to_muk::<MpichAbi>(&backend);
        assert_eq!(muk.MPI_SOURCE, s.source);
        assert_eq!(muk.MPI_TAG, s.tag);
        assert_eq!(muk.count_bytes(), s.count_bytes);
        assert_eq!(muk.cancelled(), s.cancelled);
    }
}

// --- Error code spaces ----------------------------------------------------------

#[test]
fn prop_error_codes_roundtrip_all_reprs() {
    for &(_, class) in abi::ERROR_CLASSES {
        assert_eq!(MpichRepr::class_of_err(MpichRepr::err_from_class(class)), class);
        assert_eq!(OmpiRepr::class_of_err(OmpiRepr::err_from_class(class)), class);
        assert_eq!(NativeRepr::class_of_err(NativeRepr::err_from_class(class)), class);
        if class != 0 {
            // MPICH codes are visibly different from classes (rich codes).
            assert_ne!(MpichRepr::err_from_class(class), class);
        }
    }
}

// --- Datatype engine: pack/unpack roundtrip over random layouts -----------------

#[test]
fn prop_pack_unpack_roundtrip_random_types() {
    use mpi_abi::core::datatype as dt;
    use mpi_abi::core::{engine, world};
    use mpi_abi::launcher::{run_job_ok, JobSpec};

    run_job_ok(JobSpec::new(1), |_| {
        engine::init().unwrap();
        let mut rng = Rng::new(47);
        let base = dt::builtin_id_of_abi(abi::datatypes::MPI_INT32_T).unwrap();
        for case in 0..60 {
            // Random derived type over i32: vector or indexed or struct.
            let t = match rng.range(0, 3) {
                0 => {
                    let count = rng.range(1, 5) as usize;
                    let blocklen = rng.range(1, 4) as usize;
                    let stride = blocklen as isize + rng.range(0, 3) as isize;
                    dt::type_vector(count, blocklen, stride, base).unwrap()
                }
                1 => {
                    let nblocks = rng.range(1, 4) as usize;
                    let mut blocks = Vec::new();
                    let mut disp = 0isize;
                    for _ in 0..nblocks {
                        let len = rng.range(1, 4) as usize;
                        blocks.push((len, disp));
                        disp += len as isize + rng.range(0, 3) as isize;
                    }
                    dt::type_indexed(&blocks, base).unwrap()
                }
                _ => {
                    let count = rng.range(1, 6) as usize;
                    dt::type_contiguous(count, base).unwrap()
                }
            };
            dt::type_commit(t).unwrap();
            let (lb, extent) = dt::type_get_extent(t).unwrap();
            assert!(lb <= 0 || lb >= 0, "extent query works");
            let size = dt::type_size(t).unwrap();
            assert!(size > 0 && size % 4 == 0);

            // Fill a source region, pack, unpack into a fresh region,
            // repack: the two packed streams must be identical.
            let span = (extent.unsigned_abs() + 64) as usize;
            let count = 3usize;
            let mut src = vec![0u8; span * count + 64];
            for (i, b) in src.iter_mut().enumerate() {
                *b = (rng.next() as u8).wrapping_add(i as u8);
            }
            let packed = world::with_ctx(|ctx| {
                let tables = ctx.tables.borrow();
                let mut v = Vec::new();
                dt::pack::pack(&tables.dtypes, src.as_ptr(), count, t, &mut v)?;
                Ok(v)
            })
            .unwrap();
            assert_eq!(packed.len(), size * count, "case {case}");

            let mut dst = vec![0u8; span * count + 64];
            world::with_ctx(|ctx| {
                let tables = ctx.tables.borrow();
                dt::pack::unpack(&tables.dtypes, &packed, dst.as_mut_ptr(), count, t)?;
                Ok(())
            })
            .unwrap();
            let repacked = world::with_ctx(|ctx| {
                let tables = ctx.tables.borrow();
                let mut v = Vec::new();
                dt::pack::pack(&tables.dtypes, dst.as_ptr(), count, t, &mut v)?;
                Ok(v)
            })
            .unwrap();
            assert_eq!(packed, repacked, "case {case}: pack∘unpack∘pack ≠ pack");
            dt::type_free(t).unwrap();
        }
        engine::finalize().unwrap();
    });
}

// --- Comm split invariants --------------------------------------------------------

#[test]
fn prop_comm_split_partitions_world() {
    use mpi_abi::core::{comm, engine};
    use mpi_abi::launcher::{run_job_ok, JobSpec};

    for seed in 0..8u64 {
        let n = 2 + (seed % 4) as usize; // 2..=5 ranks
        let results = run_job_ok(JobSpec::new(n), move |rank| {
            engine::init().unwrap();
            let mut rng = Rng::new(seed * 1000 + 17);
            // All ranks derive the same color assignment deterministically,
            // then pick their own entry.
            let colors: Vec<i32> = (0..n).map(|_| rng.i32_in(0, 3)).collect();
            let keys: Vec<i32> = (0..n).map(|_| rng.i32_in(-5, 5)).collect();
            let sub = engine::comm_split(
                mpi_abi::core::reserved::COMM_WORLD,
                colors[rank],
                keys[rank],
            )
            .unwrap()
            .unwrap();
            let sub_size = comm::comm_size(sub).unwrap() as usize;
            let sub_rank = comm::comm_rank(sub).unwrap() as usize;
            // Invariant 1: subcomm size = #ranks with my color.
            let same: Vec<usize> = (0..n).filter(|&r| colors[r] == colors[rank]).collect();
            assert_eq!(sub_size, same.len());
            // Invariant 2: my sub-rank equals my position under (key, rank)
            // ordering.
            let mut ordered = same.clone();
            ordered.sort_by_key(|&r| (keys[r], r));
            assert_eq!(sub_rank, ordered.iter().position(|&r| r == rank).unwrap());
            comm::comm_free(sub).unwrap();
            engine::finalize().unwrap();
            (colors[rank], sub_size)
        });
        // Invariant 3 (cross-rank): total of each color's subcomm sizes
        // covers the world exactly once.
        let total: usize = {
            let mut seen = std::collections::HashMap::new();
            for (color, size) in &results {
                seen.insert(*color, *size);
            }
            results.iter().map(|_| 1).sum()
        };
        assert_eq!(total, n);
    }
}

// --- Mixed-size soak: protocol and matcher choices are invisible ----------------------

/// One soak run: rank 0 fires a random mixed-size message stream (sizes
/// straddling the eager/rendezvous threshold, tags interleaved), rank 1
/// posts every receive up front and waits. Returns rank 1's received
/// bytes, concatenated in message order.
fn soak_run(seed: u64, flat: Option<bool>, rndv_threshold: Option<usize>) -> Vec<u8> {
    use mpi_abi::api::{Dt, MpiAbi};
    use mpi_abi::launcher::{run_job_ok, JobSpec};
    use mpi_abi::native_abi::NativeAbi;
    type A = NativeAbi;

    let mut spec = JobSpec::new(2);
    if let Some(f) = flat {
        spec = spec.with_flat_match(f);
    }
    if let Some(t) = rndv_threshold {
        spec = spec.with_rndv_threshold(t);
    }
    let outs = run_job_ok(spec, move |rank| {
        assert_eq!(A::init(), 0);
        let dt = A::datatype(Dt::Byte);
        let world = A::comm_world();
        // Both ranks derive the identical traffic schedule.
        let mut rng = Rng::new(seed * 7919 + 1);
        let n_msgs = 40usize;
        let sizes: Vec<usize> = (0..n_msgs).map(|_| rng.range(1, 150_000) as usize).collect();
        let tags: Vec<i32> = (0..n_msgs).map(|_| rng.i32_in(0, 4)).collect();
        let payload = |i: usize| -> Vec<u8> {
            (0..sizes[i]).map(|b| (b as u8) ^ (i as u8).wrapping_mul(37)).collect()
        };
        let mut received = Vec::new();
        if rank == 0 {
            for i in 0..n_msgs {
                let s = payload(i);
                assert_eq!(A::send(s.as_ptr(), sizes[i] as i32, dt, 1, tags[i], world), 0);
            }
        } else {
            // Post every receive up front, in message order (per-tag
            // posted order = send order, so FIFO must resolve it), then
            // wait for the lot.
            let mut bufs: Vec<Vec<u8>> = sizes.iter().map(|&s| vec![0u8; s]).collect();
            let mut reqs = vec![A::request_null(); n_msgs];
            for i in 0..n_msgs {
                assert_eq!(
                    A::irecv(bufs[i].as_mut_ptr(), sizes[i] as i32, dt, 0, tags[i], world,
                        &mut reqs[i]),
                    0
                );
            }
            let mut sts = vec![A::status_empty(); n_msgs];
            assert_eq!(A::waitall(&mut reqs, &mut sts), 0);
            for i in 0..n_msgs {
                assert_eq!(bufs[i], payload(i), "message {i} content (seed {seed})");
                received.extend_from_slice(&bufs[i]);
            }
        }
        assert_eq!(A::finalize(), 0);
        received
    });
    outs.into_iter().nth(1).unwrap()
}

/// The same random mixed-size stream must land bitwise-identical under
/// the indexed matcher, the flat-baseline matcher, rendezvous forced
/// for every message, and eager forced for every message: protocol
/// switch and matcher choice change complexity, never bytes.
#[test]
fn prop_mixed_size_soak_protocols_bitwise_identical() {
    for seed in 0..3u64 {
        let indexed_default = soak_run(seed, None, None);
        let flat = soak_run(seed, Some(true), None);
        let all_rndv = soak_run(seed, None, Some(0));
        let all_eager = soak_run(seed, None, Some(usize::MAX));
        assert!(!indexed_default.is_empty());
        assert_eq!(indexed_default, flat, "flat matcher diverged (seed {seed})");
        assert_eq!(indexed_default, all_rndv, "forced rendezvous diverged (seed {seed})");
        assert_eq!(indexed_default, all_eager, "forced eager diverged (seed {seed})");
    }
}

// --- ULFM recovery under a random kill schedule -------------------------------------

/// Randomized fault-tolerance property: pick a random victim rank and a
/// random death tick, run the fault-tolerant Jacobi stencil
/// ([`mpi_abi::apps::halo::jacobi_ft`]), and require every survivor's
/// post-shrink residual to be **bitwise identical** to a cold-start run
/// on the shrunk rank count. `jacobi_ft` restarts from the initial
/// state after revoke → agree → shrink, so any divergence means the
/// recovery path leaked state (a partially-updated grid, a stale ghost
/// row, a wrong shrunk decomposition) — exactly the bugs this property
/// exists to catch. Checked under both the indexed matcher and the flat
/// baseline: the ULFM failure checks sit on each matcher's miss paths,
/// and neither may change the survivors' arithmetic.
#[test]
fn prop_random_kill_shrink_matches_cold_start() {
    use mpi_abi::api::MpiAbi;
    use mpi_abi::apps::halo::{jacobi, jacobi_ft, HaloMode, HaloParams};
    use mpi_abi::launcher::{run_job, run_job_ok, JobSpec, RankOutcome};
    use mpi_abi::native_abi::NativeAbi;
    type A = NativeAbi;

    let n = 32usize;
    let iters = 10usize;
    let params = || HaloParams { n, iters, mode: HaloMode::Sendrecv };

    let mut rng = Rng::new(48);
    for case in 0..6 {
        let ranks = rng.range(2, 5) as usize; // 2..=4 ranks
        let victim = rng.range(0, ranks as u64) as usize; // any rank may die
        let ticks = rng.range(1, 32); // always before the run completes

        // Oracle: a clean cold-start run on the shrunk rank count.
        let oracle = run_job_ok(JobSpec::new(ranks - 1), move |_| {
            assert_eq!(A::init(), 0);
            let (_, global) = jacobi::<A>(params());
            assert_eq!(A::finalize(), 0);
            global
        })[0];
        assert!(oracle > 0.0, "case {case}: oracle residual is trivial");

        for flat in [false, true] {
            let spec = JobSpec::new(ranks).with_kill(victim, ticks).with_flat_match(flat);
            let outs = run_job(spec, move |_| {
                assert_eq!(A::init(), 0);
                let out = jacobi_ft::<A>(params());
                // World is revoked post-recovery, so finalize's barrier
                // fails returnably — the expected ULFM endgame.
                let _ = A::finalize();
                out
            });
            for (rank, out) in outs.iter().enumerate() {
                match out {
                    RankOutcome::Killed => assert_eq!(
                        rank, victim,
                        "case {case} flat={flat}: wrong rank died"
                    ),
                    RankOutcome::Ok((shrunk, residual)) => {
                        assert_eq!(
                            *shrunk,
                            (ranks - 1) as i32,
                            "case {case} flat={flat} rank {rank}: shrunk comm size"
                        );
                        assert_eq!(
                            residual.to_bits(),
                            oracle.to_bits(),
                            "case {case} flat={flat} rank {rank}: survivor residual \
                             {residual:e} != cold-start {oracle:e} on {} ranks \
                             (victim {victim}, tick {ticks})",
                            ranks - 1
                        );
                    }
                    other => panic!("case {case} flat={flat} rank {rank}: {other:?}"),
                }
            }
        }
    }
}

// --- Collective algorithm identity ----------------------------------------------

/// Run the PR-10 collective set once under a given force word and
/// matcher, returning each rank's concatenated integer results:
/// builtin-int allreduce, derived-vector allreduce, uniform allgather,
/// uniform alltoall, and a derived-contiguous alltoall, all with
/// randomized counts derived from `seed`. Integer `MPI_SUM` wraps, so
/// every segment bracketing (binomial, ring, recursive doubling,
/// Rabenseifner) must produce bitwise-identical bytes.
fn coll_identity_run(
    ranks: usize,
    seed: u64,
    force: mpi_abi::core::collectives::CollAlgoForce,
    flat: bool,
) -> Vec<Vec<i32>> {
    use mpi_abi::api::{Dt, MpiAbi, OpName};
    use mpi_abi::launcher::{run_job_ok, JobSpec};
    use mpi_abi::native_abi::NativeAbi;
    type A = NativeAbi;

    let spec = JobSpec::new(ranks).with_flat_match(flat).with_coll_algo(force);
    run_job_ok(spec, move |rank| {
        assert_eq!(A::init(), 0);
        let world = A::comm_world();
        let int = A::datatype(Dt::Int);
        let sum = A::op(OpName::Sum);
        let (mut n, mut me) = (0, 0);
        A::comm_size(world, &mut n);
        A::comm_rank(world, &mut me);
        assert_eq!(me as usize, rank);
        let n = n as usize;
        // Every rank derives the identical size schedule; payloads mix
        // in the rank so reordering bugs cannot cancel out.
        let mut rng = Rng::new(seed * 131 + 7);
        let ar_count = rng.range(1, 600) as usize;
        let blk = rng.range(1, 5) as usize;
        let vec_count = rng.range(1, 40) as usize;
        let ag_count = rng.range(1, 200) as usize;
        let a2a_count = rng.range(1, 100) as usize;
        let gen = move |i: usize, salt: i32| -> i32 {
            (rank as i32)
                .wrapping_mul(1_000_003)
                .wrapping_add((i as i32).wrapping_mul(7919))
                .wrapping_add(salt.wrapping_mul(104_729))
        };
        let mut out = Vec::new();

        // Builtin-int allreduce.
        let sbuf: Vec<i32> = (0..ar_count).map(|i| gen(i, 1)).collect();
        let mut rbuf = vec![0i32; ar_count];
        assert_eq!(
            A::allreduce(
                sbuf.as_ptr() as *const u8,
                rbuf.as_mut_ptr() as *mut u8,
                ar_count as i32,
                int,
                sum,
                world
            ),
            0
        );
        out.extend_from_slice(&rbuf);

        // Derived-vector allreduce (stride == blocklen: hole-free, but
        // exercises the derived-type pack path in every builder).
        let mut vt = int;
        assert_eq!(A::type_vector(vec_count as i32, blk as i32, blk as i32, int, &mut vt), 0);
        assert_eq!(A::type_commit(&mut vt), 0);
        let elems = 2 * vec_count * blk;
        let sbuf2: Vec<i32> = (0..elems).map(|i| gen(i, 2)).collect();
        let mut rbuf2 = vec![0i32; elems];
        assert_eq!(
            A::allreduce(
                sbuf2.as_ptr() as *const u8,
                rbuf2.as_mut_ptr() as *mut u8,
                2,
                vt,
                sum,
                world
            ),
            0
        );
        out.extend_from_slice(&rbuf2);
        assert_eq!(A::type_free(&mut vt), 0);

        // Uniform allgather.
        let sbuf3: Vec<i32> = (0..ag_count).map(|i| gen(i, 3)).collect();
        let mut rbuf3 = vec![0i32; ag_count * n];
        assert_eq!(
            A::allgather(
                sbuf3.as_ptr() as *const u8,
                ag_count as i32,
                int,
                rbuf3.as_mut_ptr() as *mut u8,
                ag_count as i32,
                int,
                world
            ),
            0
        );
        out.extend_from_slice(&rbuf3);

        // Uniform alltoall.
        let sbuf4: Vec<i32> = (0..a2a_count * n).map(|i| gen(i, 4)).collect();
        let mut rbuf4 = vec![0i32; a2a_count * n];
        assert_eq!(
            A::alltoall(
                sbuf4.as_ptr() as *const u8,
                a2a_count as i32,
                int,
                rbuf4.as_mut_ptr() as *mut u8,
                a2a_count as i32,
                int,
                world
            ),
            0
        );
        out.extend_from_slice(&rbuf4);

        // Derived-contiguous alltoall (blk ints per element).
        let mut ct = int;
        assert_eq!(A::type_contiguous(blk as i32, int, &mut ct), 0);
        assert_eq!(A::type_commit(&mut ct), 0);
        let c5 = 1 + a2a_count % 4;
        let elems5 = c5 * blk * n;
        let sbuf5: Vec<i32> = (0..elems5).map(|i| gen(i, 5)).collect();
        let mut rbuf5 = vec![0i32; elems5];
        assert_eq!(
            A::alltoall(
                sbuf5.as_ptr() as *const u8,
                c5 as i32,
                ct,
                rbuf5.as_mut_ptr() as *mut u8,
                c5 as i32,
                ct,
                world
            ),
            0
        );
        out.extend_from_slice(&rbuf5);
        assert_eq!(A::type_free(&mut ct), 0);

        assert_eq!(A::finalize(), 0);
        out
    })
}

/// Every forced schedule builder — and the auto selector — must produce
/// bitwise-identical results on prime and non-power-of-two rank counts,
/// randomized sizes, derived datatypes, and both matchers. The first
/// force triple is the pre-PR-10 binomial/gather-bcast/pairwise
/// baseline; every later triple is compared against it.
#[test]
fn prop_forced_coll_algorithms_bitwise_identical() {
    use mpi_abi::core::collectives::{
        CollAlgoForce, ALLGATHER_GATHER_BCAST, ALLGATHER_RING, ALLREDUCE_BINOMIAL,
        ALLREDUCE_RABENSEIFNER, ALLREDUCE_RECURSIVE_DOUBLING, ALLREDUCE_RING, ALLTOALL_BRUCK,
        ALLTOALL_PAIRWISE, COLL_AUTO,
    };

    let forces = [
        (ALLREDUCE_BINOMIAL, ALLGATHER_GATHER_BCAST, ALLTOALL_PAIRWISE),
        (ALLREDUCE_RING, ALLGATHER_RING, ALLTOALL_BRUCK),
        (ALLREDUCE_RECURSIVE_DOUBLING, ALLGATHER_GATHER_BCAST, ALLTOALL_BRUCK),
        (ALLREDUCE_RABENSEIFNER, ALLGATHER_RING, ALLTOALL_PAIRWISE),
        (COLL_AUTO, COLL_AUTO, COLL_AUTO),
    ];
    for &ranks in &[3usize, 5, 6, 7] {
        for seed in 0..2u64 {
            for flat in [false, true] {
                let mut baseline: Option<Vec<Vec<i32>>> = None;
                for &(ar, ag, aa) in &forces {
                    let force =
                        CollAlgoForce { allreduce: ar, allgather: ag, alltoall: aa };
                    let got = coll_identity_run(ranks, seed, force, flat);
                    match &baseline {
                        None => baseline = Some(got),
                        Some(base) => assert_eq!(
                            base, &got,
                            "ranks {ranks} seed {seed} flat {flat} force {force:?}"
                        ),
                    }
                }
            }
        }
    }
}

// --- Message ordering under random traffic ------------------------------------------

#[test]
fn prop_fifo_per_sender_under_random_tags() {
    use mpi_abi::core::engine;
    use mpi_abi::core::reserved::COMM_WORLD;
    use mpi_abi::launcher::{run_job_ok, JobSpec};

    for seed in 0..5u64 {
        run_job_ok(JobSpec::new(2), move |rank| {
            engine::init().unwrap();
            let dt = mpi_abi::core::datatype::builtin_id_of_abi(abi::datatypes::MPI_INT32_T)
                .unwrap();
            let mut rng = Rng::new(seed + 99);
            let n_msgs = 50usize;
            // Same-tag messages must arrive in send order even when other
            // tags interleave randomly.
            let tags: Vec<i32> = (0..n_msgs).map(|_| rng.i32_in(0, 3)).collect();
            if rank == 0 {
                for (i, &t) in tags.iter().enumerate() {
                    let v = [i as i32];
                    engine::send(v.as_ptr() as *const u8, 1, dt, 1, t, COMM_WORLD,
                        engine::SendMode::Standard).unwrap();
                }
            } else {
                // Receive per tag; within a tag, sequence must ascend.
                let mut last: [i32; 3] = [-1, -1, -1];
                for t in 0..3i32 {
                    let expected = tags.iter().filter(|&&x| x == t).count();
                    for _ in 0..expected {
                        let mut v = [0i32];
                        engine::recv(v.as_mut_ptr() as *mut u8, 1, dt, 0, t, COMM_WORLD).unwrap();
                        assert!(v[0] > last[t as usize],
                            "tag {t}: out of order {} after {}", v[0], last[t as usize]);
                        last[t as usize] = v[0];
                    }
                }
            }
            engine::finalize().unwrap();
        });
    }
}
