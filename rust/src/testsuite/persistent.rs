//! Persistent-request tests (MPI_Send_init/Recv_init, MPI_Start[all],
//! and the MPI-4 persistent collectives): the request lifecycle —
//! inactive → started → complete → inactive — must behave identically
//! across every ABI configuration; it is part of the binary contract.

use super::util::*;
use super::TestFn;
use crate::api::{Dt, MpiAbi, OpName};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("persistent.send_recv_restart", send_recv_restart::<A>),
        ("persistent.ssend_restart", ssend_restart::<A>),
        ("persistent.proc_null", proc_null::<A>),
        ("persistent.wait_inactive_empty", wait_inactive_empty::<A>),
        ("persistent.waitany_ignores_inactive", waitany_ignores_inactive::<A>),
        ("persistent.start_while_active_rejected", start_while_active_rejected::<A>),
        ("persistent.free_active_pt2pt_rejected", free_active_pt2pt_rejected::<A>),
        ("persistent.free_active_sched_rejected", free_active_sched_rejected::<A>),
        ("persistent.free_inactive_collective", free_inactive_collective::<A>),
        ("persistent.restart_after_error", restart_after_error::<A>),
        ("persistent.coll_restart_fresh_data", coll_restart_fresh_data::<A>),
        ("persistent.startall_mixed", startall_mixed::<A>),
        ("persistent.gather_scatter_alltoall", gather_scatter_alltoall::<A>),
    ]
}

fn world_geometry<A: MpiAbi>() -> (i32, i32) {
    let (mut size, mut rank) = (0, 0);
    A::comm_size(A::comm_world(), &mut size);
    A::comm_rank(A::comm_world(), &mut rank);
    (size, rank)
}

/// Init once, start/wait five times; the receiver must observe each
/// round's fresh buffer contents, and the handles must survive waits.
fn send_recv_restart<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    const ROUNDS: i32 = 5;
    if me == 0 {
        let mut buf = [0i32; 4];
        let mut req = A::request_null();
        check_rc!(
            A::send_init(slice_ptr(&buf), 4, dt, 1, 7, A::comm_world(), &mut req),
            "send_init"
        );
        check!(req != A::request_null(), "send_init handle non-null");
        for k in 0..ROUNDS {
            for (i, b) in buf.iter_mut().enumerate() {
                *b = k * 100 + i as i32;
            }
            check_rc!(A::start(&mut req), "start (send)");
            let mut st = A::status_empty();
            check_rc!(A::wait(&mut req, &mut st), "wait (send)");
            check!(req != A::request_null(), "persistent handle survives wait");
        }
        check_rc!(A::request_free(&mut req), "free (send)");
        check!(req == A::request_null(), "free nulls the handle");
    } else if me == 1 {
        let mut buf = [0i32; 4];
        let mut req = A::request_null();
        check_rc!(
            A::recv_init(slice_ptr_mut(&mut buf), 4, dt, 0, 7, A::comm_world(), &mut req),
            "recv_init"
        );
        for k in 0..ROUNDS {
            check_rc!(A::start(&mut req), "start (recv)");
            let mut st = A::status_empty();
            check_rc!(A::wait(&mut req, &mut st), "wait (recv)");
            check!(req != A::request_null(), "persistent handle survives wait");
            check!(A::status_source(&st) == 0, "status source");
            check!(A::status_tag(&st) == 7, "status tag");
            check!(A::get_count(&st, dt) == 4, "status count");
            for (i, &b) in buf.iter().enumerate() {
                check!(b == k * 100 + i as i32, "round {k} payload at {i}: got {b}");
            }
        }
        check_rc!(A::request_free(&mut req), "free (recv)");
        check!(req == A::request_null(), "free nulls the handle");
    }
    Ok(())
}

/// Persistent synchronous-mode send: completes only when matched.
fn ssend_restart<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Double);
    if me == 0 {
        let mut v = [0.0f64];
        let mut req = A::request_null();
        check_rc!(A::ssend_init(slice_ptr(&v), 1, dt, 1, 9, A::comm_world(), &mut req),
            "ssend_init");
        for k in 0..3 {
            v[0] = 0.5 + k as f64;
            check_rc!(A::start(&mut req), "start (ssend)");
            let mut st = A::status_empty();
            check_rc!(A::wait(&mut req, &mut st), "wait (ssend)");
        }
        check_rc!(A::request_free(&mut req), "free (ssend)");
    } else if me == 1 {
        for k in 0..3 {
            let mut v = [0.0f64];
            let mut st = A::status_empty();
            check_rc!(
                A::recv(slice_ptr_mut(&mut v), 1, dt, 0, 9, A::comm_world(), &mut st),
                "recv"
            );
            check!(v[0] == 0.5 + k as f64, "ssend round {k} payload");
        }
    }
    Ok(())
}

/// Persistent ops on MPI_PROC_NULL complete immediately at every start.
fn proc_null<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let dt = A::datatype(Dt::Int);
    let v = [3i32];
    let mut b = [9i32];
    let mut sreq = A::request_null();
    let mut rreq = A::request_null();
    check_rc!(
        A::send_init(slice_ptr(&v), 1, dt, A::proc_null(), 0, A::comm_world(), &mut sreq),
        "send_init to null"
    );
    check_rc!(
        A::recv_init(slice_ptr_mut(&mut b), 1, dt, A::proc_null(), 0, A::comm_world(),
            &mut rreq),
        "recv_init from null"
    );
    for _ in 0..3 {
        let mut reqs = vec![sreq, rreq];
        check_rc!(A::startall(&mut reqs), "startall");
        let mut sts = vec![A::status_empty(); 2];
        check_rc!(A::waitall(&mut reqs, &mut sts), "waitall");
        sreq = reqs[0];
        rreq = reqs[1];
        check!(b[0] == 9, "buffer untouched by PROC_NULL recv");
        check!(A::status_source(&sts[1]) == A::proc_null(), "status source PROC_NULL");
    }
    check_rc!(A::request_free(&mut sreq), "free send");
    check_rc!(A::request_free(&mut rreq), "free recv");
    Ok(())
}

/// Wait/test on a never-started persistent request returns immediately
/// with an empty status and leaves the request usable (MPI 3.0 §3.7.3).
fn wait_inactive_empty<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let dt = A::datatype(Dt::Int);
    let mut b = [0i32];
    let mut req = A::request_null();
    check_rc!(
        A::recv_init(slice_ptr_mut(&mut b), 1, dt, A::any_source(), 31400, A::comm_world(),
            &mut req),
        "recv_init"
    );
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut req, &mut st), "wait on inactive");
    check!(req != A::request_null(), "handle survives wait on inactive");
    check!(A::status_source(&st) == A::proc_null(), "empty status source");
    let mut flag = false;
    check_rc!(A::test(&mut req, &mut flag, &mut st), "test on inactive");
    check!(flag, "test on inactive sets flag");
    check_rc!(A::request_free(&mut req), "free");
    Ok(())
}

/// Waitany must *ignore* inactive persistent requests (MPI 3.0 §3.7.5):
/// it picks an active completed one over them, and returns
/// `MPI_UNDEFINED` when the whole list is inactive.
fn waitany_ignores_inactive<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let dt = A::datatype(Dt::Int);
    let mut b = [0i32];
    let mut inactive = A::request_null();
    check_rc!(
        A::recv_init(slice_ptr_mut(&mut b), 1, dt, A::proc_null(), 0, A::comm_world(),
            &mut inactive),
        "recv_init"
    );
    // All-inactive list → MPI_UNDEFINED, not index 0.
    let mut reqs = vec![inactive];
    let mut idx = 0i32;
    let mut st = A::status_empty();
    check_rc!(A::waitany(&mut reqs, &mut idx, &mut st), "waitany all-inactive");
    check!(idx == A::undefined(), "all-inactive waitany must return UNDEFINED, got {idx}");
    check!(reqs[0] != A::request_null(), "inactive handle untouched");
    // Inactive + a completed active request → the active one wins.
    let v = [1i32];
    let mut done = A::request_null();
    check_rc!(
        A::isend(slice_ptr(&v), 1, dt, A::proc_null(), 0, A::comm_world(), &mut done),
        "isend to null"
    );
    let mut reqs = vec![inactive, done];
    check_rc!(A::waitany(&mut reqs, &mut idx, &mut st), "waitany mixed");
    check!(idx == 1, "waitany must skip the inactive request, got {idx}");
    inactive = reqs[0];
    check_rc!(A::request_free(&mut inactive), "free");
    Ok(())
}

/// Starting an already-active persistent request is erroneous.
fn start_while_active_rejected<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let dt = A::datatype(Dt::Int);
    let mut b = [0i32];
    let mut req = A::request_null();
    check_rc!(
        A::recv_init(slice_ptr_mut(&mut b), 1, dt, A::any_source(), 31500, A::comm_world(),
            &mut req),
        "recv_init"
    );
    check_rc!(A::start(&mut req), "first start");
    let rc = A::start(&mut req);
    check!(rc != 0, "second start while active must fail");
    // Clean up: cancel the unmatched receive, collect, free.
    check_rc!(A::cancel(&mut req), "cancel");
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut req, &mut st), "wait after cancel");
    check!(A::status_cancelled(&st), "cancelled status");
    check!(req != A::request_null(), "handle survives cancelled wait");
    check_rc!(A::request_free(&mut req), "free");
    Ok(())
}

/// request_free on an *active* persistent request must be rejected; the
/// same request frees cleanly once inactive again.
fn free_active_pt2pt_rejected<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int);
    if me == 0 {
        let v = [11i32];
        let mut req = A::request_null();
        check_rc!(A::ssend_init(slice_ptr(&v), 1, dt, 1, 6, A::comm_world(), &mut req),
            "ssend_init");
        check_rc!(A::start(&mut req), "start");
        // Unmatched synchronous send: provably still active.
        let rc = A::request_free(&mut req);
        check!(rc != 0, "free of active persistent request must fail");
        // Unblock the receiver, then complete and free legally.
        let go = [1i32];
        check_rc!(A::send(slice_ptr(&go), 1, dt, 1, 60, A::comm_world()), "go");
        let mut st = A::status_empty();
        check_rc!(A::wait(&mut req, &mut st), "wait");
        check_rc!(A::request_free(&mut req), "free once inactive");
    } else if me == 1 {
        let mut go = [0i32];
        let mut st = A::status_empty();
        check_rc!(A::recv(slice_ptr_mut(&mut go), 1, dt, 0, 60, A::comm_world(), &mut st),
            "recv go");
        let mut v = [0i32];
        check_rc!(A::recv(slice_ptr_mut(&mut v), 1, dt, 0, 6, A::comm_world(), &mut st),
            "recv payload");
        check!(v[0] == 11, "payload");
    }
    Ok(())
}

/// Regression guard for the PR-1 behavior that must *stay*: freeing an
/// active schedule-backed (collective) request is rejected — dropping
/// the schedule would strand unexecuted sends and deadlock peers.
fn free_active_sched_rejected<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int);
    if me == 0 {
        // No other rank has entered the barrier yet (they are gated on
        // the "go" message below), so this request is provably active.
        let mut req = A::request_null();
        check_rc!(A::ibarrier(A::comm_world(), &mut req), "ibarrier");
        let rc = A::request_free(&mut req);
        check!(rc != 0, "free of active collective request must fail");
        let go = [1i32];
        for r in 1..n {
            check_rc!(A::send(slice_ptr(&go), 1, dt, r, 61, A::comm_world()), "go");
        }
        let mut st = A::status_empty();
        check_rc!(A::wait(&mut req, &mut st), "wait ibarrier");
    } else {
        let mut go = [0i32];
        let mut st = A::status_empty();
        check_rc!(A::recv(slice_ptr_mut(&mut go), 1, dt, 0, 61, A::comm_world(), &mut st),
            "recv go");
        let mut req = A::request_null();
        check_rc!(A::ibarrier(A::comm_world(), &mut req), "ibarrier");
        check_rc!(A::wait(&mut req, &mut st), "wait ibarrier");
    }
    Ok(())
}

/// The PR-1 bugfix: request_free must *accept* an inactive persistent
/// request — including a persistent collective, whose retained schedule
/// is schedule-backed exactly like the requests PR 1 blanket-rejected.
fn free_inactive_collective<A: MpiAbi>(_r: usize) -> Result<(), String> {
    // Never started: free immediately.
    let mut req = A::request_null();
    check_rc!(A::barrier_init(A::comm_world(), &mut req), "barrier_init");
    check!(req != A::request_null(), "init handle non-null");
    check_rc!(A::request_free(&mut req), "free never-started persistent collective");
    check!(req == A::request_null(), "free nulls the handle");
    // Started, completed, inactive again: free as well.
    let mut req2 = A::request_null();
    check_rc!(A::barrier_init(A::comm_world(), &mut req2), "barrier_init (2)");
    check_rc!(A::start(&mut req2), "start");
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut req2, &mut st), "wait");
    check_rc!(A::request_free(&mut req2), "free after start+wait");
    Ok(())
}

/// A persistent receive that hits a truncation error completes with the
/// error in its status, returns to inactive, and restarts cleanly.
fn restart_after_error<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int);
    if me == 0 {
        let big = [1i32, 2, 3, 4];
        check_rc!(A::send(slice_ptr(&big), 4, dt, 1, 8, A::comm_world()), "send big");
        let fit = [5i32, 6];
        check_rc!(A::send(slice_ptr(&fit), 2, dt, 1, 8, A::comm_world()), "send fit");
    } else if me == 1 {
        let mut buf = [0i32; 2];
        let mut req = A::request_null();
        check_rc!(
            A::recv_init(slice_ptr_mut(&mut buf), 2, dt, 0, 8, A::comm_world(), &mut req),
            "recv_init"
        );
        // Round 1: sender ships 4 ints into a 2-int buffer — truncation,
        // reported in the status.
        check_rc!(A::start(&mut req), "start 1");
        let mut st = A::status_empty();
        check_rc!(A::wait(&mut req, &mut st), "wait 1");
        check!(
            A::err_class_of(A::status_error(&st)) == crate::abi::errors::MPI_ERR_TRUNCATE,
            "round 1 must report TRUNCATE in status, got {}",
            A::err_class_of(A::status_error(&st))
        );
        check!(req != A::request_null(), "handle survives the error");
        // Round 2: restart after the error; a fitting message lands.
        check_rc!(A::start(&mut req), "start 2");
        let mut st2 = A::status_empty();
        check_rc!(A::wait(&mut req, &mut st2), "wait 2");
        check!(A::status_error(&st2) == 0, "round 2 clean");
        check!(buf == [5, 6], "round 2 payload");
        check_rc!(A::request_free(&mut req), "free");
    }
    Ok(())
}

/// Persistent bcast: the root's buffer is re-read at every start (the
/// schedule is reused, but the data must be fresh).
fn coll_restart_fresh_data<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (_n, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let mut buf = [0i32; 4];
    let mut req = A::request_null();
    check_rc!(
        A::bcast_init(slice_ptr_mut(&mut buf), 4, dt, 0, A::comm_world(), &mut req),
        "bcast_init"
    );
    for k in 0..4 {
        if me == 0 {
            for (i, b) in buf.iter_mut().enumerate() {
                *b = k * 10 + i as i32;
            }
        } else {
            buf = [-1; 4];
        }
        check_rc!(A::start(&mut req), "start");
        let mut st = A::status_empty();
        check_rc!(A::wait(&mut req, &mut st), "wait");
        for (i, &b) in buf.iter().enumerate() {
            check!(b == k * 10 + i as i32, "round {k} bcast payload at {i}: got {b}");
        }
    }
    check_rc!(A::request_free(&mut req), "free");
    Ok(())
}

/// Startall over a mixed window: persistent pt2pt + a persistent
/// collective, completed by one waitall, restarted three times.
fn startall_mixed<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    let op = A::op(OpName::Sum);
    let mut contrib = [0i32];
    let mut sum = [0i32];
    let mut ar = A::request_null();
    check_rc!(
        A::allreduce_init(slice_ptr(&contrib), slice_ptr_mut(&mut sum), 1, dt, op,
            A::comm_world(), &mut ar),
        "allreduce_init"
    );
    let mut pbuf = [0i32];
    let mut p2p = A::request_null();
    if me == 0 {
        check_rc!(A::send_init(slice_ptr(&pbuf), 1, dt, 1, 13, A::comm_world(), &mut p2p),
            "send_init");
    } else if me == 1 {
        check_rc!(A::recv_init(slice_ptr_mut(&mut pbuf), 1, dt, 0, 13, A::comm_world(),
            &mut p2p), "recv_init");
    }
    for k in 1..=3i32 {
        contrib[0] = (me + 1) * k;
        if me == 0 {
            pbuf[0] = 1000 + k;
        }
        if me <= 1 {
            let mut reqs = vec![p2p, ar];
            check_rc!(A::startall(&mut reqs), "startall mixed");
            let mut sts = vec![A::status_empty(); 2];
            check_rc!(A::waitall(&mut reqs, &mut sts), "waitall mixed");
            p2p = reqs[0];
            ar = reqs[1];
            check!(p2p != A::request_null(), "pt2pt handle survives waitall");
            check!(ar != A::request_null(), "collective handle survives waitall");
        } else {
            let mut reqs = vec![ar];
            check_rc!(A::startall(&mut reqs), "startall coll");
            let mut sts = vec![A::status_empty(); 1];
            check_rc!(A::waitall(&mut reqs, &mut sts), "waitall coll");
            ar = reqs[0];
        }
        let expect = (1..=n).sum::<i32>() * k;
        check!(sum[0] == expect, "round {k} allreduce: got {}, want {expect}", sum[0]);
        if me == 1 {
            check!(pbuf[0] == 1000 + k, "round {k} pt2pt payload");
        }
    }
    check_rc!(A::request_free(&mut ar), "free allreduce");
    if me <= 1 {
        check_rc!(A::request_free(&mut p2p), "free pt2pt");
    }
    Ok(())
}

/// The rooted/pairwise persistent collectives move fresh data each
/// round: gather_init, scatter_init, alltoall_init.
fn gather_scatter_alltoall<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    let nu = n as usize;
    let dt = A::datatype(Dt::Int32);
    // gather_init: everyone contributes (me*100 + round).
    let mut gsend = [0i32];
    let mut grecv = vec![0i32; nu];
    let mut greq = A::request_null();
    check_rc!(
        A::gather_init(slice_ptr(&gsend), 1, dt, slice_ptr_mut(&mut grecv), 1, dt, 0,
            A::comm_world(), &mut greq),
        "gather_init"
    );
    // scatter_init: root 0 deals out (rank*1000 + round).
    let mut ssend = vec![0i32; nu];
    let mut srecv = [0i32];
    let mut sreq = A::request_null();
    check_rc!(
        A::scatter_init(slice_ptr(&ssend), 1, dt, slice_ptr_mut(&mut srecv), 1, dt, 0,
            A::comm_world(), &mut sreq),
        "scatter_init"
    );
    // alltoall_init: block for rank r is (me*10000 + r*100 + round).
    let mut asend = vec![0i32; nu];
    let mut arecv = vec![0i32; nu];
    let mut areq = A::request_null();
    check_rc!(
        A::alltoall_init(slice_ptr(&asend), 1, dt, slice_ptr_mut(&mut arecv), 1, dt,
            A::comm_world(), &mut areq),
        "alltoall_init"
    );
    for k in 0..3i32 {
        gsend[0] = me * 100 + k;
        if me == 0 {
            for (r, v) in ssend.iter_mut().enumerate() {
                *v = r as i32 * 1000 + k;
            }
        }
        for (r, v) in asend.iter_mut().enumerate() {
            *v = me * 10000 + r as i32 * 100 + k;
        }
        let mut reqs = vec![greq, sreq, areq];
        check_rc!(A::startall(&mut reqs), "startall");
        let mut sts = vec![A::status_empty(); 3];
        check_rc!(A::waitall(&mut reqs, &mut sts), "waitall");
        greq = reqs[0];
        sreq = reqs[1];
        areq = reqs[2];
        if me == 0 {
            for (r, &v) in grecv.iter().enumerate() {
                check!(v == r as i32 * 100 + k, "gather round {k} from {r}: got {v}");
            }
        }
        check!(srecv[0] == me * 1000 + k, "scatter round {k}: got {}", srecv[0]);
        for (r, &v) in arecv.iter().enumerate() {
            let want = r as i32 * 10000 + me * 100 + k;
            check!(v == want, "alltoall round {k} from {r}: got {v}, want {want}");
        }
    }
    check_rc!(A::request_free(&mut greq), "free gather");
    check_rc!(A::request_free(&mut sreq), "free scatter");
    check_rc!(A::request_free(&mut areq), "free alltoall");
    Ok(())
}
