//! Collective tests, including user-defined ops (callback translation)
//! and the `Ialltoallw`+`Testall` path (§6.2's worst case).

use super::util::*;
use super::TestFn;
use crate::api::{Dt, MpiAbi, OpName};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("coll.barrier_stagger", barrier_stagger::<A>),
        ("coll.bcast_all_roots", bcast_all_roots::<A>),
        ("coll.reduce_sum", reduce_sum::<A>),
        ("coll.reduce_minloc", reduce_minloc::<A>),
        ("coll.allreduce_in_place", allreduce_in_place::<A>),
        ("coll.allreduce_bitwise", allreduce_bitwise::<A>),
        ("coll.gather_scatter", gather_scatter::<A>),
        ("coll.allgather", allgather::<A>),
        ("coll.alltoall", alltoall::<A>),
        ("coll.alltoallw_heterogeneous", alltoallw_heterogeneous::<A>),
        ("coll.ialltoallw_testall", ialltoallw_testall::<A>),
        ("coll.scan_exscan", scan_exscan::<A>),
        ("coll.reduce_scatter_block", reduce_scatter_block::<A>),
        ("coll.user_op", user_op::<A>),
        ("coll.user_op_derived_dt", user_op_derived_dt::<A>),
        ("coll.ibcast_wait", ibcast_wait::<A>),
        ("coll.iallreduce_overlaps_pt2pt", iallreduce_overlaps_pt2pt::<A>),
        ("coll.igatherv_iscatterv_nonblocking", igatherv_iscatterv_nonblocking::<A>),
        ("coll.iallgather_ialltoall_nonblocking", iallgather_ialltoall_nonblocking::<A>),
        ("coll.iscan_family_waitall", iscan_family_waitall::<A>),
        ("coll.waitall_mixed_request_kinds", waitall_mixed_request_kinds::<A>),
        ("coll.nonblocking_out_of_order", nonblocking_out_of_order::<A>),
    ]
}

fn geom<A: MpiAbi>() -> (i32, i32) {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    (n, me)
}

fn barrier_stagger<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (_, me) = geom::<A>();
    // Stagger entry so the barrier actually orders something.
    std::thread::sleep(std::time::Duration::from_micros(50 * me as u64));
    for _ in 0..5 {
        check_rc!(A::barrier(A::comm_world()), "barrier");
    }
    Ok(())
}

fn bcast_all_roots<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int64);
    for root in 0..n {
        let mut v: [i64; 3] =
            if me == root { [root as i64, -1, root as i64 * 1000] } else { [0; 3] };
        check_rc!(A::bcast(slice_ptr_mut(&mut v), 3, dt, root, A::comm_world()), "bcast");
        check!(v == [root as i64, -1, root as i64 * 1000], "root {root}: got {v:?}");
    }
    Ok(())
}

fn reduce_sum<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Double);
    let send = [me as f64 + 1.0, 2.0];
    let mut recv = [0.0f64; 2];
    check_rc!(
        A::reduce(slice_ptr(&send), slice_ptr_mut(&mut recv), 2, dt, A::op(OpName::Sum),
            n - 1, A::comm_world()),
        "reduce"
    );
    if me == n - 1 {
        let total: f64 = (1..=n as i64).map(|x| x as f64).sum();
        check!(recv == [total, 2.0 * n as f64], "sum at root: {recv:?}");
    }
    Ok(())
}

fn reduce_minloc<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    #[repr(C)]
    #[derive(Clone, Copy, PartialEq, Debug)]
    struct Fi(f32, i32);
    let send = [Fi(100.0 - me as f32, me)];
    let mut recv = [Fi(0.0, -1)];
    check_rc!(
        A::reduce(slice_ptr(&send), slice_ptr_mut(&mut recv), 1, A::datatype(Dt::FloatInt),
            A::op(OpName::Minloc), 0, A::comm_world()),
        "reduce minloc"
    );
    if me == 0 {
        check!(recv[0] == Fi(100.0 - (n - 1) as f32, n - 1), "minloc: {recv:?}");
    }
    Ok(())
}

fn allreduce_in_place<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    let mut v = [me + 1, 10 * (me + 1)];
    check_rc!(
        A::allreduce(A::in_place(), slice_ptr_mut(&mut v), 2, dt, A::op(OpName::Sum),
            A::comm_world()),
        "allreduce in place"
    );
    let t: i32 = (1..=n).sum();
    check!(v == [t, 10 * t], "in-place sum: {v:?}");
    Ok(())
}

fn allreduce_bitwise<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::UInt64);
    let send = [1u64 << (me as u64 % 60)];
    let mut recv = [0u64];
    check_rc!(
        A::allreduce(slice_ptr(&send), slice_ptr_mut(&mut recv), 1, dt, A::op(OpName::Bor),
            A::comm_world()),
        "allreduce bor"
    );
    let mut want = 0u64;
    for r in 0..n as u64 {
        want |= 1 << (r % 60);
    }
    check!(recv[0] == want, "bor {:#x} want {:#x}", recv[0], want);
    Ok(())
}

fn gather_scatter<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    let send = [me * 2, me * 2 + 1];
    let mut all = vec![0i32; 2 * n as usize];
    check_rc!(
        A::gather(slice_ptr(&send), 2, dt, slice_ptr_mut(&mut all), 2, dt, 0, A::comm_world()),
        "gather"
    );
    if me == 0 {
        let want: Vec<i32> = (0..2 * n).collect();
        check!(all == want, "gathered {all:?}");
    }
    let mut back = [0i32; 2];
    check_rc!(
        A::scatter(slice_ptr(&all), 2, dt, slice_ptr_mut(&mut back), 2, dt, 0, A::comm_world()),
        "scatter"
    );
    check!(back == [me * 2, me * 2 + 1], "scattered back {back:?}");
    Ok(())
}

fn allgather<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Double);
    let send = [me as f64 * 0.5];
    let mut all = vec![-1.0f64; n as usize];
    check_rc!(
        A::allgather(slice_ptr(&send), 1, dt, slice_ptr_mut(&mut all), 1, dt, A::comm_world()),
        "allgather"
    );
    for (r, &x) in all.iter().enumerate() {
        check!(x == r as f64 * 0.5, "slot {r}: {x}");
    }
    Ok(())
}

fn alltoall<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    let send: Vec<i32> = (0..n).map(|d| me * 1000 + d).collect();
    let mut recv = vec![0i32; n as usize];
    check_rc!(
        A::alltoall(slice_ptr(&send), 1, dt, slice_ptr_mut(&mut recv), 1, dt, A::comm_world()),
        "alltoall"
    );
    let want: Vec<i32> = (0..n).map(|s| s * 1000 + me).collect();
    check!(recv == want, "transposed {recv:?}");
    Ok(())
}

fn alltoallw_heterogeneous<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    // Every peer pair exchanges one i32, but through per-peer datatypes —
    // the vector-of-datatypes conversion path.
    let dt = A::datatype(Dt::Int);
    let send: Vec<i32> = (0..n).map(|d| me * 100 + d).collect();
    let mut recv = vec![0i32; n as usize];
    let counts = vec![1i32; n as usize];
    let displs: Vec<i32> = (0..n).map(|d| d * 4).collect();
    let types = vec![dt; n as usize];
    check_rc!(
        A::alltoallw(slice_ptr(&send), &counts, &displs, &types, slice_ptr_mut(&mut recv),
            &counts, &displs, &types, A::comm_world()),
        "alltoallw"
    );
    let want: Vec<i32> = (0..n).map(|s| s * 100 + me).collect();
    check!(recv == want, "alltoallw {recv:?} want {want:?}");
    Ok(())
}

fn ialltoallw_testall<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    let send: Vec<i32> = (0..n).map(|d| me * 10 + d).collect();
    let mut recv = vec![0i32; n as usize];
    let counts = vec![1i32; n as usize];
    let displs: Vec<i32> = (0..n).map(|d| d * 4).collect();
    let types = vec![dt; n as usize];
    let mut req = A::request_null();
    check_rc!(
        A::ialltoallw(slice_ptr(&send), &counts, &displs, &types, slice_ptr_mut(&mut recv),
            &counts, &displs, &types, A::comm_world(), &mut req),
        "ialltoallw"
    );
    // Complete via Testall — the §6.2 request-map worst case.
    let mut reqs = vec![req];
    let mut flag = false;
    let mut sts = vec![A::status_empty()];
    let mut spins = 0u64;
    while !flag {
        check_rc!(A::testall(&mut reqs, &mut flag, &mut sts), "testall");
        spins += 1;
        if spins > 100_000_000 {
            return Err("ialltoallw never completed".to_string());
        }
    }
    check!(reqs[0] == A::request_null(), "request reset");
    let want: Vec<i32> = (0..n).map(|s| s * 10 + me).collect();
    check!(recv == want, "ialltoallw {recv:?}");
    Ok(())
}

fn scan_exscan<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (_n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int64);
    let send = [me as i64 + 1];
    let mut inc = [0i64];
    check_rc!(
        A::scan(slice_ptr(&send), slice_ptr_mut(&mut inc), 1, dt, A::op(OpName::Sum),
            A::comm_world()),
        "scan"
    );
    let want: i64 = (1..=me as i64 + 1).sum();
    check!(inc[0] == want, "scan: {} want {want}", inc[0]);
    let mut exc = [-7i64];
    check_rc!(
        A::exscan(slice_ptr(&send), slice_ptr_mut(&mut exc), 1, dt, A::op(OpName::Sum),
            A::comm_world()),
        "exscan"
    );
    if me == 0 {
        check!(exc[0] == -7, "rank 0 exscan untouched");
    } else {
        check!(exc[0] == (1..=me as i64).sum::<i64>(), "exscan: {}", exc[0]);
    }
    Ok(())
}

fn reduce_scatter_block<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    // Each rank contributes a vector of n blocks of 2; block r lands at
    // rank r, summed.
    let send: Vec<i32> = (0..2 * n).map(|i| i + me).collect();
    let mut recv = [0i32; 2];
    check_rc!(
        A::reduce_scatter_block(slice_ptr(&send), slice_ptr_mut(&mut recv), 2, dt,
            A::op(OpName::Sum), A::comm_world()),
        "reduce_scatter_block"
    );
    let rank_sum: i32 = (0..n).sum();
    check!(
        recv == [2 * me * n + rank_sum, (2 * me + 1) * n + rank_sum],
        "block at {me}: {recv:?}"
    );
    Ok(())
}

/// User op: componentwise (max, sum) over pairs of doubles — exercises
/// the callback translation (muk: static trampoline + datatype handle
/// conversion back into the standard ABI).
fn user_maxsum<A: MpiAbi>(inv: *const u8, inout: *mut u8, len: i32, _dt: A::Datatype) {
    // NB: reduction buffers are *packed* bytes — a portable user function
    // must not assume natural alignment (unaligned access, as a careful C
    // callback would memcpy).
    let a = inv as *const f64;
    let b = inout as *mut f64;
    for i in 0..len as usize {
        unsafe {
            let (x1, x2) = (a.add(2 * i).read_unaligned(), a.add(2 * i + 1).read_unaligned());
            let (y1, y2) = (b.add(2 * i).read_unaligned(), b.add(2 * i + 1).read_unaligned());
            b.add(2 * i).write_unaligned(x1.max(y1));
            b.add(2 * i + 1).write_unaligned(x2 + y2);
        }
    }
}

fn user_op<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    // Datatype: contiguous pair of doubles, so len counts pairs.
    let mut pair_t = A::datatype(Dt::Byte);
    check_rc!(A::type_contiguous(2, A::datatype(Dt::Double), &mut pair_t), "pair type");
    check_rc!(A::type_commit(&mut pair_t), "commit");
    let mut op = A::op(OpName::Sum);
    check_rc!(A::op_create(user_maxsum::<A>, true, &mut op), "op_create");

    let send = [me as f64, 1.0];
    let mut recv = [0.0f64, 0.0];
    check_rc!(
        A::allreduce(slice_ptr(&send), slice_ptr_mut(&mut recv), 1, pair_t, op, A::comm_world()),
        "allreduce user op"
    );
    check!(recv[0] == (n - 1) as f64, "max of ranks: {}", recv[0]);
    check!(recv[1] == n as f64, "sum of ones: {}", recv[1]);

    check_rc!(A::op_free(&mut op), "op_free");
    check_rc!(A::type_free(&mut pair_t), "type_free");
    Ok(())
}

/// User op receiving the *datatype handle*: verifies the handle arrives
/// in the caller's own ABI (the trampoline's conversion) by querying its
/// size through the same ABI.
fn user_size_probe<A: MpiAbi>(inv: *const u8, inout: *mut u8, len: i32, dt: A::Datatype) {
    let mut size = 0;
    let rc = A::type_size(dt, &mut size);
    // Fold: sum, but poison the result if the handle was not usable.
    let a = inv as *const i64;
    let b = inout as *mut i64;
    let poison = if rc != 0 || size != 8 { 1_000_000 } else { 0 };
    for i in 0..len as usize {
        unsafe {
            b.add(i)
                .write_unaligned(a.add(i).read_unaligned() + b.add(i).read_unaligned() + poison)
        };
    }
}

fn user_op_derived_dt<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let mut op = A::op(OpName::Sum);
    check_rc!(A::op_create(user_size_probe::<A>, true, &mut op), "op_create");
    let send = [me as i64];
    let mut recv = [0i64];
    check_rc!(
        A::allreduce(slice_ptr(&send), slice_ptr_mut(&mut recv), 1, A::datatype(Dt::Int64), op,
            A::comm_world()),
        "allreduce probe op"
    );
    let want: i64 = (0..n as i64).sum();
    check!(recv[0] == want, "datatype handle usable in callback: {} want {want}", recv[0]);
    check_rc!(A::op_free(&mut op), "op_free");
    Ok(())
}

// --- Nonblocking collective battery ----------------------------------------
//
// Exercises the schedule engine through the portable surface: request
// handles for collectives cross every representation (and, under muk,
// the request-word conversion), with completion via wait/waitall.

fn ibcast_wait<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int64);
    for root in 0..n {
        let mut v: [i64; 4] =
            if me == root { [root as i64, 7, -root as i64, 1] } else { [0; 4] };
        let mut req = A::request_null();
        check_rc!(A::ibcast(slice_ptr_mut(&mut v), 4, dt, root, A::comm_world(), &mut req),
            "ibcast");
        let mut st = A::status_empty();
        check_rc!(A::wait(&mut req, &mut st), "wait(ibcast)");
        check!(req == A::request_null(), "request reset after wait");
        check!(v == [root as i64, 7, -root as i64, 1], "root {root}: got {v:?}");
    }
    Ok(())
}

fn iallreduce_overlaps_pt2pt<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    // Start the collective...
    let send = [me + 1, 100];
    let mut recv = [0i32; 2];
    let mut req = A::request_null();
    check_rc!(
        A::iallreduce(slice_ptr(&send), slice_ptr_mut(&mut recv), 2, dt, A::op(OpName::Sum),
            A::comm_world(), &mut req),
        "iallreduce"
    );
    // ...then run pt2pt traffic on the *same* communicator while it is in
    // flight: a ring rotation with a tag of its own.
    let right = (me + 1) % n;
    let left = (me + n - 1) % n;
    let psend = [me * 11];
    let mut precv = [-1i32];
    let mut pst = A::status_empty();
    check_rc!(
        A::sendrecv(slice_ptr(&psend), 1, dt, right, 77, slice_ptr_mut(&mut precv), 1, dt,
            left, 77, A::comm_world(), &mut pst),
        "sendrecv during iallreduce"
    );
    check!(precv[0] == left * 11, "ring value {precv:?}");
    // Now complete the collective.
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut req, &mut st), "wait(iallreduce)");
    let t: i32 = (1..=n).sum();
    check!(recv == [t, 100 * n], "overlapped sum: {recv:?}");
    Ok(())
}

fn igatherv_iscatterv_nonblocking<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    // Rank r contributes r+1 ints; block displacements are prefix sums.
    let send: Vec<i32> = (0..me + 1).map(|i| me * 10 + i).collect();
    let counts: Vec<i32> = (0..n).map(|r| r + 1).collect();
    let displs: Vec<i32> = {
        let mut d = Vec::with_capacity(n as usize);
        let mut acc = 0;
        for r in 0..n {
            d.push(acc);
            acc += r + 1;
        }
        d
    };
    let total: i32 = counts.iter().sum();
    let mut gathered = vec![-1i32; total as usize];
    let mut req = A::request_null();
    check_rc!(
        A::igatherv(slice_ptr(&send), me + 1, dt, slice_ptr_mut(&mut gathered), &counts,
            &displs, dt, 0, A::comm_world(), &mut req),
        "igatherv"
    );
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut req, &mut st), "wait(igatherv)");
    if me == 0 {
        let mut want = Vec::new();
        for r in 0..n {
            for i in 0..r + 1 {
                want.push(r * 10 + i);
            }
        }
        check!(gathered == want, "gathered {gathered:?} want {want:?}");
    }
    // Scatter the variable blocks back.
    let mut back = vec![0i32; (me + 1) as usize];
    let mut req = A::request_null();
    check_rc!(
        A::iscatterv(slice_ptr(&gathered), &counts, &displs, dt, slice_ptr_mut(&mut back),
            me + 1, dt, 0, A::comm_world(), &mut req),
        "iscatterv"
    );
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut req, &mut st), "wait(iscatterv)");
    check!(back == send, "scattered back {back:?}");
    Ok(())
}

fn iallgather_ialltoall_nonblocking<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Double);
    // iallgather.
    let send = [me as f64 + 0.25];
    let mut all = vec![-1.0f64; n as usize];
    let mut req = A::request_null();
    check_rc!(
        A::iallgather(slice_ptr(&send), 1, dt, slice_ptr_mut(&mut all), 1, dt, A::comm_world(),
            &mut req),
        "iallgather"
    );
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut req, &mut st), "wait(iallgather)");
    for (r, &x) in all.iter().enumerate() {
        check!(x == r as f64 + 0.25, "slot {r}: {x}");
    }
    // ialltoall.
    let dt = A::datatype(Dt::Int);
    let send: Vec<i32> = (0..n).map(|d| me * 1000 + d).collect();
    let mut recv = vec![0i32; n as usize];
    let mut req = A::request_null();
    check_rc!(
        A::ialltoall(slice_ptr(&send), 1, dt, slice_ptr_mut(&mut recv), 1, dt, A::comm_world(),
            &mut req),
        "ialltoall"
    );
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut req, &mut st), "wait(ialltoall)");
    let want: Vec<i32> = (0..n).map(|s| s * 1000 + me).collect();
    check!(recv == want, "transposed {recv:?}");
    Ok(())
}

/// Three different schedule-backed collectives in flight at once,
/// completed by one waitall — mixed *collective* kinds.
fn iscan_family_waitall<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    let op = A::op(OpName::Sum);
    let scan_in = [me + 1];
    let mut scan_out = [0i32];
    let ex_in = [me + 1];
    let mut ex_out = [-9i32];
    let rsb_in: Vec<i32> = (0..n).flat_map(|b| [b + me, 2 * (b + me)]).collect();
    let mut rsb_out = [0i32; 2];
    let mut reqs = vec![A::request_null(); 3];
    check_rc!(
        A::iscan(slice_ptr(&scan_in), slice_ptr_mut(&mut scan_out), 1, dt, op, A::comm_world(),
            &mut reqs[0]),
        "iscan"
    );
    check_rc!(
        A::iexscan(slice_ptr(&ex_in), slice_ptr_mut(&mut ex_out), 1, dt, op, A::comm_world(),
            &mut reqs[1]),
        "iexscan"
    );
    check_rc!(
        A::ireduce_scatter_block(slice_ptr(&rsb_in), slice_ptr_mut(&mut rsb_out), 2, dt, op,
            A::comm_world(), &mut reqs[2]),
        "ireduce_scatter_block"
    );
    let mut sts = vec![A::status_empty(); 3];
    check_rc!(A::waitall(&mut reqs, &mut sts), "waitall(3 collectives)");
    for r in &reqs {
        check!(*r == A::request_null(), "requests reset");
    }
    check!(scan_out[0] == (1..=me + 1).sum::<i32>(), "iscan: {}", scan_out[0]);
    if me == 0 {
        check!(ex_out[0] == -9, "rank 0 iexscan buffer untouched: {}", ex_out[0]);
    } else {
        check!(ex_out[0] == (1..=me).sum::<i32>(), "iexscan: {}", ex_out[0]);
    }
    let rank_sum: i32 = (0..n).sum();
    check!(
        rsb_out == [me * n + rank_sum, 2 * (me * n + rank_sum)],
        "ireduce_scatter_block at {me}: {rsb_out:?}"
    );
    Ok(())
}

/// One waitall over pt2pt sends, pt2pt receives, a barrier, and a bcast:
/// mixed request *kinds* behind one completion call.
fn waitall_mixed_request_kinds<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    let right = (me + 1) % n;
    let left = (me + n - 1) % n;
    let psend = [me + 500];
    let mut precv = [-1i32];
    let mut bc = if me == 0 { [4242i32] } else { [0i32] };
    let mut reqs = vec![A::request_null(); 4];
    check_rc!(
        A::irecv(slice_ptr_mut(&mut precv), 1, dt, left, 5, A::comm_world(), &mut reqs[0]),
        "irecv"
    );
    check_rc!(
        A::isend(slice_ptr(&psend), 1, dt, right, 5, A::comm_world(), &mut reqs[1]),
        "isend"
    );
    check_rc!(A::ibarrier(A::comm_world(), &mut reqs[2]), "ibarrier");
    check_rc!(A::ibcast(slice_ptr_mut(&mut bc), 1, dt, 0, A::comm_world(), &mut reqs[3]),
        "ibcast");
    let mut sts = vec![A::status_empty(); 4];
    check_rc!(A::waitall(&mut reqs, &mut sts), "waitall(mixed kinds)");
    check!(precv[0] == left + 500, "pt2pt through mixed waitall: {precv:?}");
    check!(bc[0] == 4242, "bcast through mixed waitall: {bc:?}");
    check!(A::status_source(&sts[0]) == left, "recv status source");
    Ok(())
}

/// Two nonblocking collectives issued back-to-back and completed in
/// reverse order: the per-comm collective sequence keeps their traffic
/// apart even though their schedules overlap.
fn nonblocking_out_of_order<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = geom::<A>();
    let dt = A::datatype(Dt::Int);
    let mut bc = if me == 0 { [31i32, 41] } else { [0i32; 2] };
    let mut breq = A::request_null();
    check_rc!(A::ibcast(slice_ptr_mut(&mut bc), 2, dt, 0, A::comm_world(), &mut breq),
        "ibcast first");
    let send = [me];
    let mut recv = [0i32];
    let mut areq = A::request_null();
    check_rc!(
        A::iallreduce(slice_ptr(&send), slice_ptr_mut(&mut recv), 1, dt, A::op(OpName::Max),
            A::comm_world(), &mut areq),
        "iallreduce second"
    );
    // Complete the *second* collective first.
    let mut st = A::status_empty();
    check_rc!(A::wait(&mut areq, &mut st), "wait(iallreduce)");
    check!(recv[0] == n - 1, "max rank: {}", recv[0]);
    check_rc!(A::wait(&mut breq, &mut st), "wait(ibcast)");
    check!(bc == [31, 41], "bcast data after out-of-order waits: {bc:?}");
    Ok(())
}
