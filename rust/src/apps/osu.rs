//! OSU-microbenchmark analogues: `osu_mbw_mr` (message rate, Table 1)
//! and `osu_latency` (§6.1's "network cost of a single message").
//!
//! Same shape as the originals: mbw_mr posts a window of nonblocking
//! sends per iteration and waits for a one-byte ack; latency ping-pongs
//! a message and halves the round-trip.

use crate::api::{Dt, MpiAbi, OpName};

/// osu_mbw_mr parameters (defaults match OSU 7.x).
#[derive(Clone, Copy, Debug)]
pub struct MbwMrParams {
    /// Bytes per message (Table 1 uses 8).
    pub msg_size: usize,
    /// Nonblocking sends in flight per iteration.
    pub window: usize,
    /// Timed iterations.
    pub iters: usize,
    /// Untimed warmup iterations.
    pub warmup: usize,
}

impl Default for MbwMrParams {
    fn default() -> Self {
        MbwMrParams { msg_size: 8, window: 64, iters: 2000, warmup: 200 }
    }
}

/// Run on exactly 2 ranks; returns messages/second (valid on rank 0).
///
/// Pairs: rank 0 sends `window` isends to rank 1, then blocks on a
/// one-byte ack, `iters` times. Rate = `iters * window / elapsed`.
pub fn mbw_mr<A: MpiAbi>(p: MbwMrParams) -> f64 {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    assert!(n >= 2, "osu_mbw_mr needs 2 ranks");
    let dt = A::datatype(Dt::Byte);
    let world = A::comm_world();
    let sbuf = vec![0x5Au8; p.msg_size];
    let mut rbuf = vec![0u8; p.msg_size];
    let ack = [1u8];
    let mut ackbuf = [0u8];

    let mut rate = 0.0;
    if me == 0 {
        let mut reqs = vec![A::request_null(); p.window];
        let mut sts = vec![A::status_empty(); p.window];
        let mut t0 = 0.0;
        for iter in 0..(p.warmup + p.iters) {
            if iter == p.warmup {
                t0 = A::wtime();
            }
            for r in reqs.iter_mut() {
                A::isend(sbuf.as_ptr(), p.msg_size as i32, dt, 1, 100, world, r);
            }
            A::waitall(&mut reqs, &mut sts);
            let mut st = A::status_empty();
            A::recv(ackbuf.as_mut_ptr(), 1, dt, 1, 101, world, &mut st);
        }
        let dt_s = A::wtime() - t0;
        rate = (p.iters * p.window) as f64 / dt_s;
    } else if me == 1 {
        let mut reqs = vec![A::request_null(); p.window];
        let mut sts = vec![A::status_empty(); p.window];
        for _ in 0..(p.warmup + p.iters) {
            for r in reqs.iter_mut() {
                A::irecv(rbuf.as_mut_ptr(), p.msg_size as i32, dt, 0, 100, world, r);
            }
            A::waitall(&mut reqs, &mut sts);
            A::send(ack.as_ptr(), 1, dt, 0, 101, world);
        }
    }
    A::barrier(world);
    rate
}

/// osu_latency parameters.
#[derive(Clone, Copy, Debug)]
pub struct LatencyParams {
    pub msg_size: usize,
    pub iters: usize,
    pub warmup: usize,
}

impl Default for LatencyParams {
    fn default() -> Self {
        LatencyParams { msg_size: 8, iters: 1000, warmup: 100 }
    }
}

/// Ping-pong latency in seconds (one-way; valid on rank 0).
pub fn latency<A: MpiAbi>(p: LatencyParams) -> f64 {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    assert!(n >= 2, "osu_latency needs 2 ranks");
    let dt = A::datatype(Dt::Byte);
    let world = A::comm_world();
    let sbuf = vec![0x5Au8; p.msg_size];
    let mut rbuf = vec![0u8; p.msg_size];
    let mut st = A::status_empty();

    let mut lat = 0.0;
    if me == 0 {
        let mut t0 = 0.0;
        for iter in 0..(p.warmup + p.iters) {
            if iter == p.warmup {
                t0 = A::wtime();
            }
            A::send(sbuf.as_ptr(), p.msg_size as i32, dt, 1, 1, world);
            A::recv(rbuf.as_mut_ptr(), p.msg_size as i32, dt, 1, 2, world, &mut st);
        }
        lat = (A::wtime() - t0) / (2.0 * p.iters as f64);
    } else if me == 1 {
        for _ in 0..(p.warmup + p.iters) {
            A::recv(rbuf.as_mut_ptr(), p.msg_size as i32, dt, 0, 1, world, &mut st);
            A::send(sbuf.as_ptr(), p.msg_size as i32, dt, 0, 2, world);
        }
    }
    A::barrier(world);
    lat
}

/// osu_bw parameters.
#[derive(Clone, Copy, Debug)]
pub struct BwParams {
    /// Bytes per message.
    pub msg_size: usize,
    /// Nonblocking sends in flight per iteration (scaled down for large
    /// messages by the caller to bound resident memory).
    pub window: usize,
    /// Timed iterations.
    pub iters: usize,
    /// Untimed warmup iterations.
    pub warmup: usize,
}

impl Default for BwParams {
    fn default() -> Self {
        BwParams { msg_size: 1 << 16, window: 64, iters: 100, warmup: 10 }
    }
}

/// Uni-directional bandwidth in bytes/second (osu_bw analogue; valid on
/// rank 0). Rank 0 streams `window` nonblocking sends per iteration and
/// waits for a one-byte ack, so the wire — not the ack latency —
/// dominates for large messages. This is the bench that crosses the
/// eager→rendezvous threshold: the harness runs it once with the
/// protocol pinned to eager and once pinned to rendezvous.
pub fn bw<A: MpiAbi>(p: BwParams) -> f64 {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    assert!(n >= 2, "osu_bw needs 2 ranks");
    let dt = A::datatype(Dt::Byte);
    let world = A::comm_world();
    let sbuf = vec![0x5Au8; p.msg_size];
    let mut rbuf = vec![0u8; p.msg_size];
    let ack = [1u8];
    let mut ackbuf = [0u8];

    let mut rate = 0.0;
    if me == 0 {
        let mut reqs = vec![A::request_null(); p.window];
        let mut sts = vec![A::status_empty(); p.window];
        let mut t0 = 0.0;
        for iter in 0..(p.warmup + p.iters) {
            if iter == p.warmup {
                t0 = A::wtime();
            }
            for r in reqs.iter_mut() {
                A::isend(sbuf.as_ptr(), p.msg_size as i32, dt, 1, 300, world, r);
            }
            A::waitall(&mut reqs, &mut sts);
            let mut st = A::status_empty();
            A::recv(ackbuf.as_mut_ptr(), 1, dt, 1, 301, world, &mut st);
        }
        let dt_s = A::wtime() - t0;
        rate = (p.iters * p.window * p.msg_size) as f64 / dt_s;
    } else if me == 1 {
        let mut reqs = vec![A::request_null(); p.window];
        let mut sts = vec![A::status_empty(); p.window];
        for _ in 0..(p.warmup + p.iters) {
            for r in reqs.iter_mut() {
                A::irecv(rbuf.as_mut_ptr(), p.msg_size as i32, dt, 0, 300, world, r);
            }
            A::waitall(&mut reqs, &mut sts);
            A::send(ack.as_ptr(), 1, dt, 0, 301, world);
        }
    }
    A::barrier(world);
    rate
}

/// Which collective a [`coll_latency`] run times (the `abibench --coll`
/// scaling grid).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollBench {
    Barrier,
    Allreduce,
    Allgather,
    Alltoall,
}

impl CollBench {
    pub fn parse(s: &str) -> Option<CollBench> {
        Some(match s {
            "barrier" => CollBench::Barrier,
            "allreduce" => CollBench::Allreduce,
            "allgather" => CollBench::Allgather,
            "alltoall" => CollBench::Alltoall,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            CollBench::Barrier => "barrier",
            CollBench::Allreduce => "allreduce",
            CollBench::Allgather => "allgather",
            CollBench::Alltoall => "alltoall",
        }
    }
}

/// osu_allreduce/allgather/alltoall/barrier parameters.
#[derive(Clone, Copy, Debug)]
pub struct CollParams {
    pub bench: CollBench,
    /// Payload bytes: the full vector for allreduce, the per-peer
    /// contribution for allgather/alltoall (rounded down to whole
    /// `MPI_INT` elements, minimum one).
    pub msg_size: usize,
    pub iters: usize,
    pub warmup: usize,
}

impl Default for CollParams {
    fn default() -> Self {
        CollParams { bench: CollBench::Allreduce, msg_size: 1024, iters: 100, warmup: 10 }
    }
}

/// Mean seconds per collective call (valid on every rank; the harness
/// reads rank 0's copy). All ranks enter the operation `warmup + iters`
/// times; a barrier re-synchronizes the job right before the clock
/// starts so warmup stragglers don't bleed into the timed window, and
/// once more after it so no rank tears the fabric down early. Uses
/// `MPI_INT` + `MPI_SUM` so every schedule — whatever algorithm the
/// selector picked — produces bitwise-identical results.
pub fn coll_latency<A: MpiAbi>(p: CollParams) -> f64 {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    let world = A::comm_world();
    let dt = A::datatype(Dt::Int);
    let op = A::op(OpName::Sum);
    let count = (p.msg_size / 4).max(1) as i32;
    // Sized for the widest case (alltoall: count elements per peer).
    let slots = count as usize * n as usize;
    let sbuf = vec![me; slots];
    let mut rbuf = vec![0i32; slots];

    let mut t0 = 0.0;
    for iter in 0..(p.warmup + p.iters) {
        if iter == p.warmup {
            A::barrier(world);
            t0 = A::wtime();
        }
        match p.bench {
            CollBench::Barrier => {
                A::barrier(world);
            }
            CollBench::Allreduce => {
                A::allreduce(
                    sbuf.as_ptr() as *const u8,
                    rbuf.as_mut_ptr() as *mut u8,
                    count,
                    dt,
                    op,
                    world,
                );
            }
            CollBench::Allgather => {
                A::allgather(
                    sbuf.as_ptr() as *const u8,
                    count,
                    dt,
                    rbuf.as_mut_ptr() as *mut u8,
                    count,
                    dt,
                    world,
                );
            }
            CollBench::Alltoall => {
                A::alltoall(
                    sbuf.as_ptr() as *const u8,
                    count,
                    dt,
                    rbuf.as_mut_ptr() as *mut u8,
                    count,
                    dt,
                    world,
                );
            }
        }
    }
    let per_call = (A::wtime() - t0) / p.iters as f64;
    A::barrier(world);
    per_call
}

/// The `MPI_Type_size` throughput micro-measurement of §6.1: mean
/// nanoseconds per query over the builtin types. Pure representation
/// decoding — requires no job.
pub fn type_size_ns<A: MpiAbi>(iters: usize) -> f64 {
    let dts = [
        A::datatype(Dt::Char),
        A::datatype(Dt::Int),
        A::datatype(Dt::Float),
        A::datatype(Dt::Double),
        A::datatype(Dt::Int64),
    ];
    let mut sink = 0i64;
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let mut s = 0;
        A::type_size(dts[i % dts.len()], &mut s);
        sink = sink.wrapping_add(s as i64);
    }
    let e = t0.elapsed().as_nanos() as f64 / iters as f64;
    std::hint::black_box(sink);
    e
}
