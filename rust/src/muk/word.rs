//! Mukautuva's handle union.
//!
//! The paper's excerpt:
//!
//! ```c
//! typedef union {
//!     void     *p;  // Open-MPI
//!     int       i;  // MPICH
//!     intptr_t ip;
//! } MUK_Handle;
//! ```
//!
//! A Mukautuva user handle *is* the backend's handle, carried in a
//! pointer-sized word. [`AsWord`] is that union: every backend handle
//! type can be stored into / recovered from a word.

use crate::impls::ompi::{OmpiComm, OmpiDatatype, OmpiErrhandler, OmpiGroup, OmpiInfo, OmpiOp,
    OmpiRequest, OmpiSession, OmpiWin};

/// Round-trip a backend handle through a pointer-sized word.
pub trait AsWord: Copy {
    /// Store this handle into the union word.
    fn to_word(self) -> usize;
    /// Recover a handle from the union word.
    fn from_word(w: usize) -> Self;
}

/// MPICH-style `int` handles: the union's `.i` member.
impl AsWord for i32 {
    #[inline(always)]
    fn to_word(self) -> usize {
        self as u32 as usize
    }
    #[inline(always)]
    fn from_word(w: usize) -> i32 {
        w as u32 as i32
    }
}

macro_rules! ptr_as_word {
    ($($t:ident),*) => {$(
        /// Open-MPI-style pointer handles: the union's `.p` member.
        impl AsWord for $t {
            #[inline(always)]
            fn to_word(self) -> usize {
                self.0 as usize
            }
            #[inline(always)]
            fn from_word(w: usize) -> $t {
                $t(w as *const crate::impls::ompi::Desc)
            }
        }
    )*};
}

ptr_as_word!(OmpiComm, OmpiDatatype, OmpiOp, OmpiRequest, OmpiGroup, OmpiErrhandler, OmpiInfo,
    OmpiWin, OmpiSession);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn i32_roundtrip_preserves_sign_bit() {
        // MPICH user handles have the 0x80000000 bit set (negative i32).
        let h: i32 = 0x8400_0007u32 as i32;
        assert_eq!(<i32 as AsWord>::from_word(h.to_word()), h);
    }

    #[test]
    fn pointer_roundtrip() {
        let d = Box::leak(Box::new(0u64));
        let c = OmpiComm(d as *const u64 as *const crate::impls::ompi::Desc);
        assert_eq!(OmpiComm::from_word(c.to_word()), c);
    }

    #[test]
    fn backend_user_handles_never_alias_the_zero_page() {
        // The guarantee that lets MUK reuse backend handle values as its
        // own: MPICH user handles have high kind bits; OMPI handles are
        // heap addresses. Both exceed HUFFMAN_MAX.
        let mpich_user: i32 = crate::impls::mpich::KIND_DIRECT | crate::impls::mpich::T_COMM;
        assert!(mpich_user.to_word() > crate::abi::huffman::HUFFMAN_MAX);
    }
}
