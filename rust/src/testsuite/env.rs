//! Environment & init-state tests.

use super::util::*;
use super::TestFn;
use crate::api::MpiAbi;

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("env.initialized", initialized::<A>),
        ("env.world_size_rank", world_size_rank::<A>),
        ("env.versions", versions::<A>),
        ("env.wtime_monotone", wtime_monotone::<A>),
        ("env.processor_name", processor_name::<A>),
        ("env.comm_self", comm_self::<A>),
        ("env.error_strings", error_strings::<A>),
    ]
}

fn initialized<A: MpiAbi>(_rank: usize) -> Result<(), String> {
    check!(A::initialized(), "MPI must report initialized inside the job");
    check!(!A::finalized(), "not finalized yet");
    Ok(())
}

fn world_size_rank<A: MpiAbi>(rank: usize) -> Result<(), String> {
    let (mut size, mut r) = (0, -1);
    check_rc!(A::comm_size(A::comm_world(), &mut size), "Comm_size");
    check_rc!(A::comm_rank(A::comm_world(), &mut r), "Comm_rank");
    check!(size >= 1, "world size {size} >= 1");
    check!(r as usize == rank, "rank mismatch: MPI says {r}, launcher says {rank}");
    Ok(())
}

fn versions<A: MpiAbi>(_rank: usize) -> Result<(), String> {
    let (major, minor) = A::get_version();
    check!(major >= 4, "MPI version {major}.{minor} >= 4");
    let lib = A::get_library_version();
    check!(!lib.is_empty(), "library version string nonempty");
    check!(
        lib.len() <= crate::abi::constants::MPI_MAX_LIBRARY_VERSION_STRING,
        "library version fits MPI_MAX_LIBRARY_VERSION_STRING"
    );
    Ok(())
}

fn wtime_monotone<A: MpiAbi>(_rank: usize) -> Result<(), String> {
    let a = A::wtime();
    let b = A::wtime();
    check!(b >= a, "wtime must be monotone ({a} then {b})");
    Ok(())
}

fn processor_name<A: MpiAbi>(_rank: usize) -> Result<(), String> {
    let n = A::get_processor_name();
    check!(!n.is_empty(), "processor name nonempty");
    check!(n.len() < crate::abi::constants::MPI_MAX_PROCESSOR_NAME, "fits the limit");
    Ok(())
}

fn comm_self<A: MpiAbi>(_rank: usize) -> Result<(), String> {
    let (mut size, mut r) = (0, -1);
    check_rc!(A::comm_size(A::comm_self(), &mut size), "Comm_size(self)");
    check_rc!(A::comm_rank(A::comm_self(), &mut r), "Comm_rank(self)");
    check!(size == 1 && r == 0, "COMM_SELF is a singleton (size {size}, rank {r})");
    Ok(())
}

fn error_strings<A: MpiAbi>(_rank: usize) -> Result<(), String> {
    let code = A::err_from_canonical(crate::abi::errors::MPI_ERR_TRUNCATE);
    check!(code != 0, "error code for TRUNCATE is nonzero");
    check!(A::err_class_of(code) != 0, "class recoverable");
    let s = A::error_string(code);
    check!(s.to_lowercase().contains("trunc"), "string mentions truncation: {s:?}");
    Ok(())
}
