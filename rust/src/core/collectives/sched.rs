//! The collective **schedule engine**.
//!
//! Every collective is expressed as a per-rank *schedule*: an ordered
//! list of send / receive / local-reduce steps over the communicator's
//! collective context plane, advanced incrementally by the progress
//! engine ([`crate::core::request::progress`]). A nonblocking collective
//! (`MPI_Ibcast`, `MPI_Iallreduce`, …) is a request whose kind holds its
//! schedule; the blocking collectives are `wait(i<coll>())` over the same
//! schedules, so there is exactly one implementation of each algorithm.
//!
//! This is the schedule/progress design MPICH uses for its nonblocking
//! collectives (Zhou et al., "Designing and Prototyping Extensions to
//! MPI in MPICH"), shrunk to this engine's eager transport:
//!
//! * sends are eager — executing a send step enqueues an envelope and
//!   never blocks;
//! * a receive step *parks* the schedule until a matching envelope shows
//!   up in the unexpected queue, then applies its [`RecvAction`];
//! * tag phases (`base_tag + phase`, see [`super::PHASES_PER_COLL`])
//!   separate the rounds of one collective, while the per-comm collective
//!   sequence separates *concurrent* collectives — which is what makes
//!   out-of-order completion of overlapping nonblocking collectives safe.
//!
//! Schedules progress whenever the rank enters the progress engine
//! (any test/wait/recv), so an `iallreduce` overlaps pt2pt traffic and
//! other collectives on the same communicator.

use std::collections::VecDeque;

use super::{children_of, coll_begin, parent_of, CollCtx};
use crate::core::comm::comm_size;
use crate::core::datatype::pack::{pack, unpack};
use crate::core::request::{enqueue_send, new_request, ReqKind, StatusCore};
use crate::core::transport::{Envelope, MsgKind, Payload};
use crate::core::world::{with_ctx, RankCtx};
use crate::core::{err, CommId, DtId, OpId, RC, ReqId};

// ---------------------------------------------------------------------------
// Schedule representation
// ---------------------------------------------------------------------------

/// What to do with the bytes of a matched receive step.
pub(crate) enum RecvAction {
    /// Drop the payload (pure synchronization, e.g. barrier rounds).
    Discard,
    /// Replace the accumulator with the payload (tree broadcast).
    Store,
    /// Copy the payload into the accumulator at `offset` (gather phases).
    StoreAt { offset: usize, len: usize },
    /// Stash the payload in the auxiliary buffer (exscan's partial).
    StoreAux,
    /// Fold the payload into the accumulator: `accum = op(payload, accum)`
    /// (reduction trees and scan chains; fold order matches the blocking
    /// algorithms so non-commutative user ops see identical bracketing).
    Combine { op: OpId, count: usize, dt: DtId },
    /// Unpack the payload straight into user memory at `buf + displ`
    /// (rooted gathers, scatter leaves, alltoall blocks).
    Unpack { buf: usize, displ: isize, count: usize, dt: DtId },
}

/// One step of a per-rank collective schedule. Peers are *comm ranks*;
/// `phase` offsets the collective's base tag (bounded by
/// [`super::PHASES_PER_COLL`]).
pub(crate) enum Step {
    /// Eager-send bytes fixed at schedule-build time.
    Send { to: usize, phase: i32, data: Vec<u8> },
    /// Eager-send the accumulator (or `range` of it) *as of execution
    /// time* — for data produced by earlier receive steps.
    SendAccum { to: usize, phase: i32, range: Option<(usize, usize)> },
    /// Park until a message from `from` on `phase` arrives, then apply
    /// `action`.
    Recv { from: usize, phase: i32, action: RecvAction },
    /// `accum = op(aux, accum)` (exscan's forward combine).
    FoldAux { op: OpId, count: usize, dt: DtId },
    /// Unpack accumulator bytes (or `range` of them; or the aux buffer)
    /// into user memory at `buf + displ`.
    Unpack {
        buf: usize,
        displ: isize,
        count: usize,
        dt: DtId,
        range: Option<(usize, usize)>,
        from_aux: bool,
    },
}

/// A per-rank collective schedule: the restartable state of one
/// in-flight collective. Lives inside its request
/// ([`ReqKind::Sched`]) and is advanced by [`progress_scheds`].
pub struct Schedule {
    /// Member world ranks, comm-rank order (snapshot from coll_begin).
    members: Vec<usize>,
    /// The collective context id of the communicator.
    context: u32,
    /// Base tag of this collective (phases offset it).
    tag: i32,
    /// Remaining steps, executed front to back.
    steps: VecDeque<Step>,
    /// Working buffer (packed bytes) threaded through the steps.
    accum: Vec<u8>,
    /// Secondary buffer for algorithms needing two live values (exscan).
    aux: Vec<u8>,
    /// Payload bytes received so far (reported in the final status).
    recv_bytes: u64,
}

impl Schedule {
    fn new(cc: CollCtx) -> Schedule {
        Schedule {
            members: cc.members,
            context: cc.context,
            tag: cc.tag,
            steps: VecDeque::new(),
            accum: Vec::new(),
            aux: Vec::new(),
            recv_bytes: 0,
        }
    }

    fn push(&mut self, s: Step) {
        self.steps.push_back(s);
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

/// Clamped view of `buf[off..off+len]`. Ranges are derived from counts
/// the *local* rank passed; if a peer disagrees (a user error MPI reports
/// as truncation), the mismatch must not become a cross-thread panic.
fn ranged(buf: &[u8], range: Option<(usize, usize)>) -> &[u8] {
    match range {
        Some((off, len)) => {
            let start = off.min(buf.len());
            let end = off.saturating_add(len).min(buf.len());
            &buf[start..end]
        }
        None => buf,
    }
}

fn send_payload(ctx: &RankCtx, s: &Schedule, to: usize, phase: i32, payload: Payload) {
    let env = Envelope {
        src: ctx.rank as u32,
        context: s.context,
        tag: s.tag + phase,
        kind: MsgKind::Eager,
        seq: 0,
        payload,
    };
    enqueue_send(ctx, s.members[to], env);
}

fn apply_recv(ctx: &RankCtx, s: &mut Schedule, payload: Payload, action: RecvAction) -> RC<()> {
    let data = payload.as_slice();
    match action {
        RecvAction::Discard => Ok(()),
        RecvAction::Store => {
            s.accum = data.to_vec();
            Ok(())
        }
        RecvAction::StoreAt { offset, len } => {
            let end = (offset + len).min(s.accum.len());
            if offset < end {
                let take = (end - offset).min(data.len());
                s.accum[offset..offset + take].copy_from_slice(&data[..take]);
            }
            Ok(())
        }
        RecvAction::StoreAux => {
            s.aux = data.to_vec();
            Ok(())
        }
        RecvAction::Combine { op, count, dt } => {
            crate::core::op::apply(op, data, &mut s.accum, count, dt)
        }
        RecvAction::Unpack { buf, displ, count, dt } => {
            let t = ctx.tables.borrow();
            let dst = unsafe { (buf as *mut u8).offset(displ) };
            unpack(&t.dtypes, data, dst, count, dt)?;
            Ok(())
        }
    }
}

/// Run `s` as far as it will go without blocking. `Ok(true)` = finished.
fn advance(ctx: &RankCtx, s: &mut Schedule) -> RC<bool> {
    loop {
        let Some(step) = s.steps.pop_front() else { return Ok(true) };
        match step {
            Step::Send { to, phase, data } => {
                send_payload(ctx, s, to, phase, Payload::from_vec(data));
            }
            Step::SendAccum { to, phase, range } => {
                let payload = Payload::from_slice(ranged(&s.accum, range));
                send_payload(ctx, s, to, phase, payload);
            }
            Step::Recv { from, phase, action } => {
                let want_src = s.members[from] as i32;
                let tag = s.tag + phase;
                let matched = {
                    let mut st = ctx.state.borrow_mut();
                    let found =
                        st.unexpected.iter().position(|e| e.matches(s.context, want_src, tag));
                    found.map(|i| st.unexpected.remove(i).unwrap())
                };
                match matched {
                    Some(env) => {
                        s.recv_bytes += env.payload.len() as u64;
                        apply_recv(ctx, s, env.payload, action)?;
                    }
                    None => {
                        // Not here yet: park on this step.
                        s.steps.push_front(Step::Recv { from, phase, action });
                        return Ok(false);
                    }
                }
            }
            Step::FoldAux { op, count, dt } => {
                let aux = std::mem::take(&mut s.aux);
                let r = crate::core::op::apply(op, &aux, &mut s.accum, count, dt);
                s.aux = aux;
                r?;
            }
            Step::Unpack { buf, displ, count, dt, range, from_aux } => {
                let src = ranged(if from_aux { &s.aux } else { &s.accum }, range);
                let t = ctx.tables.borrow();
                let dst = unsafe { (buf as *mut u8).offset(displ) };
                unpack(&t.dtypes, src, dst, count, dt)?;
            }
        }
    }
}

fn complete_status(s: &Schedule) -> StatusCore {
    let mut st = StatusCore::empty();
    st.count_bytes = s.recv_bytes;
    st
}

/// Register a built schedule as a request, advancing it once immediately
/// (local-only schedules — size-1 comms, leaf-only work — complete here).
fn submit(ctx: &RankCtx, mut s: Schedule) -> RC<ReqId> {
    if advance(ctx, &mut s)? {
        return Ok(new_request(ctx, ReqKind::Send, Some(complete_status(&s))));
    }
    let rid = new_request(ctx, ReqKind::Sched(Box::new(s)), None);
    ctx.state.borrow_mut().active_scheds.push(rid);
    Ok(rid)
}

/// Progress-engine hook: advance every in-flight schedule. Called from
/// [`crate::core::request::progress`] after the fabric drain, so parked
/// receive steps see freshly-arrived envelopes.
///
/// Allocation-free: this sits inside every wait/test spin loop, so it
/// walks `active_scheds` in place (`swap_remove` on completion) instead
/// of snapshotting it.
pub(crate) fn progress_scheds(ctx: &RankCtx) {
    // Re-entrancy guard: a user reduction op may legally call back into
    // MPI (and thus into progress) while a Combine step runs.
    if ctx.sched_pump.get() {
        return;
    }
    if ctx.state.borrow().active_scheds.is_empty() {
        return;
    }
    ctx.sched_pump.set(true);
    enum Taken {
        Sched(Box<Schedule>),
        Keep,
        Drop,
    }
    let mut i = 0usize;
    loop {
        // Re-read the list each step: a user op callback may submit new
        // collectives (appends) while we pump.
        let Some(rid) = ctx.state.borrow().active_scheds.get(i).copied() else { break };
        // Move the schedule out of the request table so advancing it can
        // re-borrow tables (pack/unpack, user ops) freely.
        let taken = {
            let mut t = ctx.tables.borrow_mut();
            match t.reqs.get_mut(rid.0) {
                Some(req) if req.status.is_none() => {
                    match std::mem::replace(&mut req.kind, ReqKind::Send) {
                        ReqKind::Sched(s) => Taken::Sched(s),
                        other => {
                            req.kind = other;
                            Taken::Keep
                        }
                    }
                }
                // Completed and/or already freed by the user.
                _ => Taken::Drop,
            }
        };
        let keep = match taken {
            Taken::Keep => true,
            Taken::Drop => false,
            Taken::Sched(mut sched) => {
                let outcome = advance(ctx, &mut sched);
                let mut t = ctx.tables.borrow_mut();
                match t.reqs.get_mut(rid.0) {
                    None => false,
                    Some(req) => match outcome {
                        Ok(true) => {
                            req.status = Some(complete_status(&sched));
                            false
                        }
                        Ok(false) => {
                            req.kind = ReqKind::Sched(sched);
                            true
                        }
                        Err(e) => {
                            let mut st = complete_status(&sched);
                            st.error = e.class;
                            req.status = Some(st);
                            false
                        }
                    },
                }
            }
        };
        if keep {
            i += 1;
        } else {
            // The swapped-in tail element is unprocessed; revisit index i.
            ctx.state.borrow_mut().active_scheds.swap_remove(i);
        }
    }
    ctx.sched_pump.set(false);
}

// ---------------------------------------------------------------------------
// Build helpers
// ---------------------------------------------------------------------------

fn in_place(p: *const u8) -> bool {
    p as usize == crate::abi::constants::MPI_IN_PLACE
}

fn pack_user(ctx: &RankCtx, buf: *const u8, count: usize, dt: DtId) -> RC<Vec<u8>> {
    let t = ctx.tables.borrow();
    let mut v = Vec::new();
    pack(&t.dtypes, buf, count, dt, &mut v)?;
    Ok(v)
}

/// Pack `count` items of `dt` at byte displacement `displ` from `buf`.
fn pack_at(ctx: &RankCtx, buf: *const u8, displ: isize, count: usize, dt: DtId) -> RC<Vec<u8>> {
    let t = ctx.tables.borrow();
    let src = unsafe { buf.offset(displ) };
    let mut v = Vec::new();
    pack(&t.dtypes, src, count, dt, &mut v)?;
    Ok(v)
}

/// Unpack into user memory at byte displacement `displ` from `buf`.
fn unpack_at(
    ctx: &RankCtx,
    data: &[u8],
    buf: *mut u8,
    displ: isize,
    count: usize,
    dt: DtId,
) -> RC<()> {
    let t = ctx.tables.borrow();
    let dst = unsafe { buf.offset(displ) };
    unpack(&t.dtypes, data, dst, count, dt)?;
    Ok(())
}

fn packed_len(ctx: &RankCtx, count: usize, dt: DtId) -> RC<usize> {
    let t = ctx.tables.borrow();
    Ok(t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?.size * count)
}

fn extent_of(ctx: &RankCtx, dt: DtId) -> RC<isize> {
    let t = ctx.tables.borrow();
    Ok(t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?.extent)
}

fn check_root(cc: &CollCtx, root: i32) -> RC<usize> {
    if root < 0 || root as usize >= cc.size() {
        return Err(err!(MPI_ERR_ROOT));
    }
    Ok(root as usize)
}

// ---------------------------------------------------------------------------
// Schedule builders: the nonblocking collective family
// ---------------------------------------------------------------------------

/// `MPI_Ibarrier`: dissemination algorithm, one tag phase per round.
pub fn ibarrier(comm: CommId) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let me = cc.my_rank;
        let mut s = Schedule::new(cc);
        let mut k = 1usize;
        let mut round = 0i32;
        while k < n {
            let dst = (me + k) % n;
            let src = (me + n - k) % n;
            s.push(Step::Send { to: dst, phase: round, data: Vec::new() });
            s.push(Step::Recv { from: src, phase: round, action: RecvAction::Discard });
            k <<= 1;
            round += 1;
        }
        submit(ctx, s)
    })
}

/// Append a binomial-tree broadcast of the accumulator (rooted at comm
/// rank `root`, tag phase `phase`) to `s`.
fn push_bcast_tree(s: &mut Schedule, me: usize, n: usize, root: usize, phase: i32) {
    let vrank = (me + n - root) % n;
    if vrank != 0 {
        let parent_real = (parent_of(vrank) + root) % n;
        s.push(Step::Recv { from: parent_real, phase, action: RecvAction::Store });
    }
    for child in children_of(vrank, n) {
        let child_real = (child + root) % n;
        s.push(Step::SendAccum { to: child_real, phase, range: None });
    }
}

/// Append a binomial-tree reduction of the accumulator toward comm rank
/// `root` on tag phase `phase`.
fn push_reduce_tree(
    s: &mut Schedule,
    me: usize,
    n: usize,
    root: usize,
    phase: i32,
    op: OpId,
    count: usize,
    dt: DtId,
) {
    let vrank = (me + n - root) % n;
    for child in children_of(vrank, n) {
        let child_real = (child + root) % n;
        s.push(Step::Recv {
            from: child_real,
            phase,
            action: RecvAction::Combine { op, count, dt },
        });
    }
    if vrank != 0 {
        let parent_real = (parent_of(vrank) + root) % n;
        s.push(Step::SendAccum { to: parent_real, phase, range: None });
    }
}

/// `MPI_Ibcast`.
pub fn ibcast(buf: *mut u8, count: usize, dt: DtId, root: i32, comm: CommId) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let root = check_root(&cc, root)?;
        let n = cc.size();
        let me = cc.my_rank;
        let mut s = Schedule::new(cc);
        if n > 1 {
            if me == root {
                s.accum = pack_user(ctx, buf as *const u8, count, dt)?;
            }
            push_bcast_tree(&mut s, me, n, root, 0);
            if me != root {
                s.push(Step::Unpack {
                    buf: buf as usize,
                    displ: 0,
                    count,
                    dt,
                    range: None,
                    from_aux: false,
                });
            }
        }
        submit(ctx, s)
    })
}

/// `MPI_Ireduce`.
pub fn ireduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let root = check_root(&cc, root)?;
        let n = cc.size();
        let me = cc.my_rank;
        let contrib =
            if in_place(sendbuf) && me == root { recvbuf as *const u8 } else { sendbuf };
        let mut s = Schedule::new(cc);
        s.accum = pack_user(ctx, contrib, count, dt)?;
        push_reduce_tree(&mut s, me, n, root, 0, op, count, dt);
        if me == root {
            s.push(Step::Unpack {
                buf: recvbuf as usize,
                displ: 0,
                count,
                dt,
                range: None,
                from_aux: false,
            });
        }
        submit(ctx, s)
    })
}

/// `MPI_Iallreduce` (reduce to comm rank 0, then broadcast — two phases).
pub fn iallreduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let me = cc.my_rank;
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let mut s = Schedule::new(cc);
        s.accum = pack_user(ctx, contrib, count, dt)?;
        if n > 1 {
            push_reduce_tree(&mut s, me, n, 0, 0, op, count, dt);
            push_bcast_tree(&mut s, me, n, 0, 1);
        }
        s.push(Step::Unpack {
            buf: recvbuf as usize,
            displ: 0,
            count,
            dt,
            range: None,
            from_aux: false,
        });
        submit(ctx, s)
    })
}

/// `MPI_Igatherv` (displacements in recvtype extents, MPI-style).
#[allow(clippy::too_many_arguments)]
pub fn igatherv(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let root = check_root(&cc, root)?;
        let n = cc.size();
        let me = cc.my_rank;
        if me == root && (recvcounts.len() != n || displs.len() != n) {
            return Err(err!(MPI_ERR_COUNT));
        }
        let mut s = Schedule::new(cc);
        if me == root {
            let rext = extent_of(ctx, recvtype)?;
            if !in_place(sendbuf) {
                let own = pack_user(ctx, sendbuf, sendcount, sendtype)?;
                unpack_at(ctx, &own, recvbuf, rext * displs[me], recvcounts[me], recvtype)?;
            }
            for r in 0..n {
                if r == root {
                    continue;
                }
                s.push(Step::Recv {
                    from: r,
                    phase: 0,
                    action: RecvAction::Unpack {
                        buf: recvbuf as usize,
                        displ: rext * displs[r],
                        count: recvcounts[r],
                        dt: recvtype,
                    },
                });
            }
        } else {
            let bytes = pack_user(ctx, sendbuf, sendcount, sendtype)?;
            s.push(Step::Send { to: root, phase: 0, data: bytes });
        }
        submit(ctx, s)
    })
}

/// `MPI_Igather`.
#[allow(clippy::too_many_arguments)]
pub fn igather(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let counts = vec![recvcount; n];
    let displs: Vec<isize> = (0..n).map(|r| (r * recvcount) as isize).collect();
    igatherv(sendbuf, sendcount, sendtype, recvbuf, &counts, &displs, recvtype, root, comm)
}

/// `MPI_Iscatterv` (displacements in sendtype extents).
#[allow(clippy::too_many_arguments)]
pub fn iscatterv(
    sendbuf: *const u8,
    sendcounts: &[usize],
    displs: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let root = check_root(&cc, root)?;
        let n = cc.size();
        let me = cc.my_rank;
        if me == root && (sendcounts.len() != n || displs.len() != n) {
            return Err(err!(MPI_ERR_COUNT));
        }
        let mut s = Schedule::new(cc);
        if me == root {
            let sext = extent_of(ctx, sendtype)?;
            for r in 0..n {
                if r == root {
                    // In place: the root's block stays where it is.
                    if !in_place(recvbuf as *const u8) {
                        let own =
                            pack_at(ctx, sendbuf, sext * displs[r], sendcounts[r], sendtype)?;
                        unpack_at(ctx, &own, recvbuf, 0, recvcount, recvtype)?;
                    }
                } else {
                    let bytes =
                        pack_at(ctx, sendbuf, sext * displs[r], sendcounts[r], sendtype)?;
                    s.push(Step::Send { to: r, phase: 0, data: bytes });
                }
            }
        } else {
            s.push(Step::Recv {
                from: root,
                phase: 0,
                action: RecvAction::Unpack {
                    buf: recvbuf as usize,
                    displ: 0,
                    count: recvcount,
                    dt: recvtype,
                },
            });
        }
        submit(ctx, s)
    })
}

/// `MPI_Iscatter`.
#[allow(clippy::too_many_arguments)]
pub fn iscatter(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let counts = vec![sendcount; n];
    let displs: Vec<isize> = (0..n).map(|r| (r * sendcount) as isize).collect();
    iscatterv(sendbuf, &counts, &displs, sendtype, recvbuf, recvcount, recvtype, root, comm)
}

/// `MPI_Iallgatherv`: gather packed blocks into the accumulator at comm
/// rank 0 (phase 0), broadcast it (phase 1), unpack every block locally.
#[allow(clippy::too_many_arguments)]
pub fn iallgatherv(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let me = cc.my_rank;
        if recvcounts.len() != n || displs.len() != n {
            return Err(err!(MPI_ERR_COUNT));
        }
        let rext = extent_of(ctx, recvtype)?;
        let per = packed_len(ctx, 1, recvtype)?;
        // Packed block offsets in the accumulator.
        let mut offs = Vec::with_capacity(n);
        let mut total = 0usize;
        for &c in recvcounts {
            offs.push(total);
            total += per * c;
        }
        // My contribution (for MPI_IN_PLACE: my block of recvbuf).
        let own = if in_place(sendbuf) {
            pack_at(ctx, recvbuf as *const u8, rext * displs[me], recvcounts[me], recvtype)?
        } else {
            pack_user(ctx, sendbuf, sendcount, sendtype)?
        };
        let mut s = Schedule::new(cc);
        if me == 0 {
            s.accum = vec![0u8; total];
            let take = own.len().min(total - offs[0]);
            s.accum[offs[0]..offs[0] + take].copy_from_slice(&own[..take]);
            for r in 1..n {
                s.push(Step::Recv {
                    from: r,
                    phase: 0,
                    action: RecvAction::StoreAt { offset: offs[r], len: per * recvcounts[r] },
                });
            }
        } else {
            s.push(Step::Send { to: 0, phase: 0, data: own });
        }
        push_bcast_tree(&mut s, me, n, 0, 1);
        for r in 0..n {
            s.push(Step::Unpack {
                buf: recvbuf as usize,
                displ: rext * displs[r],
                count: recvcounts[r],
                dt: recvtype,
                range: Some((offs[r], per * recvcounts[r])),
                from_aux: false,
            });
        }
        submit(ctx, s)
    })
}

/// `MPI_Iallgather`.
#[allow(clippy::too_many_arguments)]
pub fn iallgather(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let counts = vec![recvcount; n];
    let displs: Vec<isize> = (0..n).map(|r| (r * recvcount) as isize).collect();
    iallgatherv(sendbuf, sendcount, sendtype, recvbuf, &counts, &displs, recvtype, comm)
}

/// `MPI_Ialltoallw` over the schedule engine: one eager send and one
/// parked receive per peer, all on phase 0 (peer identity disambiguates).
///
/// `MPI_IN_PLACE` works because *all* send blocks are packed at build
/// time, before any receive step can overwrite `recvbuf`: the in-place
/// send side is simply the receive side's layout.
pub fn ialltoallw(args: &super::AlltoallwArgs, comm: CommId) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let me = cc.my_rank;
        let inp = in_place(args.sendbuf);
        if args.recvcounts.len() != n || (!inp && args.sendcounts.len() != n) {
            return Err(err!(MPI_ERR_COUNT));
        }
        // Resolve the send side: for MPI_IN_PLACE the data to distribute
        // sits in recvbuf with the receive-side layout.
        let (sbuf, scounts, sdispls, stypes) = if inp {
            (args.recvbuf as *const u8, &args.recvcounts, &args.rdispls, &args.recvtypes)
        } else {
            (args.sendbuf, &args.sendcounts, &args.sdispls, &args.sendtypes)
        };
        let mut s = Schedule::new(cc);
        for r in 0..n {
            let bytes = pack_at(ctx, sbuf, sdispls[r], scounts[r], stypes[r])?;
            if r == me {
                // Self-exchange: local pack/unpack at build time.
                unpack_at(ctx, &bytes, args.recvbuf, args.rdispls[r], args.recvcounts[r],
                    args.recvtypes[r])?;
            } else {
                s.push(Step::Send { to: r, phase: 0, data: bytes });
            }
        }
        for r in 0..n {
            if r == me {
                continue;
            }
            s.push(Step::Recv {
                from: r,
                phase: 0,
                action: RecvAction::Unpack {
                    buf: args.recvbuf as usize,
                    displ: args.rdispls[r],
                    count: args.recvcounts[r],
                    dt: args.recvtypes[r],
                },
            });
        }
        submit(ctx, s)
    })
}

/// `MPI_Ialltoallv` (displacements in type extents).
#[allow(clippy::too_many_arguments)]
pub fn ialltoallv(
    sendbuf: *const u8,
    sendcounts: &[usize],
    sdispls_elems: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    rdispls_elems: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let sext = crate::core::datatype::type_get_extent(sendtype)?.1;
    let rext = crate::core::datatype::type_get_extent(recvtype)?.1;
    let args = super::AlltoallwArgs {
        sendbuf,
        sendcounts: sendcounts.to_vec(),
        sdispls: sdispls_elems.iter().map(|&d| d * sext).collect(),
        sendtypes: vec![sendtype; n],
        recvbuf,
        recvcounts: recvcounts.to_vec(),
        rdispls: rdispls_elems.iter().map(|&d| d * rext).collect(),
        recvtypes: vec![recvtype; n],
    };
    ialltoallw(&args, comm)
}

/// `MPI_Ialltoall`.
#[allow(clippy::too_many_arguments)]
pub fn ialltoall(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<ReqId> {
    let n = comm_size(comm)? as usize;
    let scounts = vec![sendcount; n];
    let sdispls: Vec<isize> = (0..n).map(|r| (r * sendcount) as isize).collect();
    let rcounts = vec![recvcount; n];
    let rdispls: Vec<isize> = (0..n).map(|r| (r * recvcount) as isize).collect();
    ialltoallv(sendbuf, &scounts, &sdispls, sendtype, recvbuf, &rcounts, &rdispls, recvtype, comm)
}

/// `MPI_Iscan` (inclusive, linear chain).
pub fn iscan(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let me = cc.my_rank;
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let mut s = Schedule::new(cc);
        s.accum = pack_user(ctx, contrib, count, dt)?;
        if me > 0 {
            s.push(Step::Recv {
                from: me - 1,
                phase: 0,
                action: RecvAction::Combine { op, count, dt },
            });
        }
        if me + 1 < n {
            s.push(Step::SendAccum { to: me + 1, phase: 0, range: None });
        }
        s.push(Step::Unpack {
            buf: recvbuf as usize,
            displ: 0,
            count,
            dt,
            range: None,
            from_aux: false,
        });
        submit(ctx, s)
    })
}

/// `MPI_Iexscan` (exclusive; rank 0's recvbuf stays untouched).
pub fn iexscan(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let me = cc.my_rank;
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let mut s = Schedule::new(cc);
        s.accum = pack_user(ctx, contrib, count, dt)?; // own contribution
        if me > 0 {
            s.push(Step::Recv { from: me - 1, phase: 0, action: RecvAction::StoreAux });
        }
        if me + 1 < n {
            if me > 0 {
                // forward = op(partial, own)
                s.push(Step::FoldAux { op, count, dt });
            }
            s.push(Step::SendAccum { to: me + 1, phase: 0, range: None });
        }
        if me > 0 {
            s.push(Step::Unpack {
                buf: recvbuf as usize,
                displ: 0,
                count,
                dt,
                range: None,
                from_aux: true,
            });
        }
        submit(ctx, s)
    })
}

/// `MPI_Ireduce_scatter_block`: reduce the full vector to comm rank 0
/// (phase 0), scatter the per-rank blocks from there (phase 1).
pub fn ireduce_scatter_block(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    recvcount: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<ReqId> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let me = cc.my_rank;
        let total = recvcount * n;
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let blk = packed_len(ctx, recvcount, dt)?;
        let mut s = Schedule::new(cc);
        s.accum = pack_user(ctx, contrib, total, dt)?;
        push_reduce_tree(&mut s, me, n, 0, 0, op, total, dt);
        if me == 0 {
            for r in 1..n {
                s.push(Step::SendAccum { to: r, phase: 1, range: Some((r * blk, blk)) });
            }
            s.push(Step::Unpack {
                buf: recvbuf as usize,
                displ: 0,
                count: recvcount,
                dt,
                range: Some((0, blk)),
                from_aux: false,
            });
        } else {
            s.push(Step::Recv {
                from: 0,
                phase: 1,
                action: RecvAction::Unpack {
                    buf: recvbuf as usize,
                    displ: 0,
                    count: recvcount,
                    dt,
                },
            });
        }
        submit(ctx, s)
    })
}
