"""L1 kernel correctness: Pallas vs pure-jnp oracle, swept with
hypothesis over shapes, seeds and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as kmm
from compile.kernels import reduce as kred
from compile.kernels.ref import dense_ref, matmul_ref, reduce_ref

TILE_ELEMS = kred.BLOCK_ROWS * kred.LANES


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


@settings(max_examples=20, deadline=None)
@given(
    op=st.sampled_from(kred.OPS),
    tiles=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_reduce_matches_ref(op, tiles, seed):
    n = tiles * TILE_ELEMS
    a = _rand(seed, (n,), jnp.float32)
    b = _rand(seed + 1, (n,), jnp.float32)
    got = kred.reduce_op(a, b, op=op)
    want = reduce_ref(a, b, op=op)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    op=st.sampled_from(kred.OPS),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_reduce_f64(op, seed):
    n = 2 * TILE_ELEMS
    a = _rand(seed, (n,), jnp.float64)
    b = _rand(seed + 9, (n,), jnp.float64)
    got = kred.reduce_op(a, b, op=op)
    np.testing.assert_allclose(got, reduce_ref(a, b, op=op), rtol=1e-12)


def test_reduce_rejects_unaligned_length():
    a = jnp.zeros((TILE_ELEMS + 1,), jnp.float32)
    with pytest.raises(AssertionError):
        kred.reduce_op(a, a, op="sum")


def test_reduce_special_values():
    n = TILE_ELEMS
    a = jnp.full((n,), jnp.inf, jnp.float32).at[0].set(-0.0)
    b = jnp.zeros((n,), jnp.float32).at[0].set(0.0)
    np.testing.assert_allclose(
        kred.reduce_op(a, b, op="min"), reduce_ref(a, b, op="min")
    )


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([128, 256]),
    k=st.sampled_from([128, 256, 384]),
    n=st.sampled_from([128, 256]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 3, (k, n), jnp.float32)
    np.testing.assert_allclose(kmm.matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-3)


def test_matmul_identity():
    x = _rand(5, (128, 128), jnp.float32)
    eye = jnp.eye(128, dtype=jnp.float32)
    np.testing.assert_allclose(kmm.matmul(x, eye), x, rtol=1e-6)


def test_dense_forward_and_grads_match_ref():
    x = _rand(11, (128, 256), jnp.float32)
    w = _rand(12, (256, 128), jnp.float32)
    b = _rand(13, (128,), jnp.float32)
    np.testing.assert_allclose(kmm.dense(x, w, b), dense_ref(x, w, b), rtol=1e-4, atol=1e-3)

    def f_pallas(w):
        return jnp.sum(kmm.dense(x, w, b) ** 2)

    def f_ref(w):
        return jnp.sum(dense_ref(x, w, b) ** 2)

    g_pallas = jax.grad(f_pallas)(w)
    g_ref = jax.grad(f_ref)(w)
    np.testing.assert_allclose(g_pallas, g_ref, rtol=1e-3, atol=1e-3)


def test_vmem_estimates_fit_budget():
    # Structural perf check (interpret mode gives no TPU timing): resident
    # VMEM per grid step must sit far inside a ~16 MiB budget.
    assert kred.vmem_bytes_per_step() < 1 << 20
    assert kmm.vmem_bytes_per_step() < 1 << 20
    assert kmm.mxu_utilization_estimate(256, 256, 128) == 1.0
    assert kmm.mxu_utilization_estimate(100, 256, 128) < 1.0
