//! One-sided op latency through every ABI layer: put / get / accumulate
//! under a passive-target (lock/flush) epoch, across the five ABI
//! configurations and both transports.
//!
//! What the layers add on this path: window-handle conversion (int bits
//! vs pointer deref vs zero-page word), `MPI_Aint` displacement
//! plumbing, and — for Mukautuva — the §5.4 constant translation
//! (assert bitmasks, lock types) on every synchronization call.
//!
//! `cargo bench --bench rma -- --smoke` runs one iteration per op on
//! one transport (the CI bit-rot guard).

use mpi_abi::api::{Dt, MpiAbi, OpName};
use mpi_abi::apps::{with_abi, AbiApp, AbiConfig};
use mpi_abi::bench::Table;
use mpi_abi::core::transport::TransportKind;
use mpi_abi::launcher::{run_job_ok, JobSpec};

const RANKS: usize = 2;
const SLOTS: usize = 64;

struct Results {
    put_us: f64,
    get_us: f64,
    acc_us: f64,
    fence_us: f64,
}

struct Rma {
    transport: TransportKind,
    iters: usize,
}

impl AbiApp<Results> for Rma {
    fn run<A: MpiAbi>(self) -> Results {
        let iters = self.iters;
        let out = run_job_ok(JobSpec::new(RANKS).with_transport(self.transport), move |rank| {
            A::init();
            let world = A::comm_world();
            let dt = A::datatype(Dt::Int32);
            let op = A::op(OpName::Sum);
            let mut mem = vec![0i32; SLOTS];
            let mut win = A::win_null();
            A::win_create(
                mem.as_mut_ptr() as *mut u8,
                std::mem::size_of_val(&mem[..]) as isize,
                4,
                A::info_null(),
                world,
                &mut win,
            );
            let v = [1i32];
            let mut g = [0i32];
            let mut r = Results { put_us: 0.0, get_us: 0.0, acc_us: 0.0, fence_us: 0.0 };

            // --- passive-target put / get / accumulate (+flush per op) ---
            // Rank 1 sits in the barrier, its progress engine applying
            // the one-sided traffic — the passive-target model.
            if rank == 0 {
                A::win_lock(A::lock_exclusive(), 1, 0, win);
                for _ in 0..iters.min(8) {
                    A::put(v.as_ptr() as *const u8, 1, dt, 1, 0, 1, dt, win);
                    A::win_flush(1, win);
                }
                let t0 = A::wtime();
                for _ in 0..iters {
                    A::put(v.as_ptr() as *const u8, 1, dt, 1, 0, 1, dt, win);
                    A::win_flush(1, win);
                }
                r.put_us = (A::wtime() - t0) / iters as f64 * 1e6;
                let t0 = A::wtime();
                for _ in 0..iters {
                    A::get(g.as_mut_ptr() as *mut u8, 1, dt, 1, 0, 1, dt, win);
                    A::win_flush(1, win);
                }
                r.get_us = (A::wtime() - t0) / iters as f64 * 1e6;
                let t0 = A::wtime();
                for _ in 0..iters {
                    A::accumulate(v.as_ptr() as *const u8, 1, dt, 1, 0, 1, dt, op, win);
                    A::win_flush(1, win);
                }
                r.acc_us = (A::wtime() - t0) / iters as f64 * 1e6;
                A::win_unlock(1, win);
            }
            A::barrier(world);

            // --- fence epoch cost (collective; both ranks measure) ---
            A::win_fence(0, win);
            let t0 = A::wtime();
            for _ in 0..iters {
                A::win_fence(0, win);
            }
            r.fence_us = (A::wtime() - t0) / iters as f64 * 1e6;
            A::win_fence(A::mode_nosucceed(), win);

            A::win_free(&mut win);
            A::finalize();
            r
        });
        out.into_iter()
            .reduce(|a, b| Results {
                put_us: a.put_us.max(b.put_us),
                get_us: a.get_us.max(b.get_us),
                acc_us: a.acc_us.max(b.acc_us),
                fence_us: a.fence_us.max(b.fence_us),
            })
            .unwrap()
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let transports: &[TransportKind] = if smoke {
        &[TransportKind::Spsc]
    } else {
        &[TransportKind::Spsc, TransportKind::Mutex]
    };
    println!("\nRMA op latency ({RANKS} ranks): 4-byte put/get/accumulate + flush, fence round");
    for &transport in transports {
        let iters = if smoke {
            1
        } else {
            match transport {
                TransportKind::Spsc => 2000,
                TransportKind::Mutex => 400,
            }
        };
        let mut table = Table::new(
            &format!("one-sided latency [{} transport]", transport.name()),
            &["ABI", "put µs", "get µs", "acc µs", "fence µs"],
        );
        for abi in AbiConfig::ALL {
            let r = with_abi(abi, Rma { transport, iters });
            table.row(&[
                abi.name().to_string(),
                format!("{:.2}", r.put_us),
                format!("{:.2}", r.get_us),
                format!("{:.2}", r.acc_us),
                format!("{:.2}", r.fence_us),
            ]);
        }
        println!("{}", table.render());
    }
    if smoke {
        println!("smoke run complete (1 iteration, spsc only)");
    } else {
        println!(
            "shape: put/get/acc pay one op message + flush round-trip; the muk rows add \
             window-handle + constant translation per call; fence adds the dissemination \
             rounds on the window's ctrl plane."
        );
    }
}
