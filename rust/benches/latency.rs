//! E3 — `osu_latency` analogue: one-way latency across message sizes and
//! both transports. Grounds §6.1's claim that any per-call ABI cost is
//! negligible against "at least 500 nanoseconds" of network cost: our
//! fabric's small-message latency sets the yardstick the translation
//! overheads (E1/E6) are compared against.

use mpi_abi::api::MpiAbi;
use mpi_abi::apps::osu::{latency, LatencyParams};
use mpi_abi::apps::{with_abi, AbiApp, AbiConfig};
use mpi_abi::bench::Table;
use mpi_abi::core::transport::TransportKind;
use mpi_abi::launcher::{run_job_ok, JobSpec};

struct Ping {
    transport: TransportKind,
    size: usize,
}

impl AbiApp<f64> for Ping {
    fn run<A: MpiAbi>(self) -> f64 {
        let mut best = f64::MAX;
        for _ in 0..3 {
            let out = run_job_ok(JobSpec::new(2).with_transport(self.transport), |_| {
                A::init();
                let r = latency::<A>(LatencyParams { msg_size: self.size, ..Default::default() });
                A::finalize();
                r
            });
            best = best.min(out[0]);
        }
        best
    }
}

fn main() {
    std::env::set_var("MPI_ABI_NO_XLA", "1");
    println!("\nE3 — osu_latency analogue (one-way, 2 ranks)");
    let sizes = [8usize, 64, 512, 4096, 65536];
    let mut table = Table::new(
        "One-way latency (ns)",
        &["bytes", "spsc flat", "spsc indexed", "spsc muk", "mutex indexed"],
    );
    let (mut base8, mut flat8) = (0.0, 0.0);
    for size in sizes {
        // Pre-index baseline: the seed's flat matcher + slab-path
        // blocking ops, restored by the env flag.
        std::env::set_var("MPI_ABI_FLAT_MATCH", "1");
        let flat = with_abi(AbiConfig::Mpich, Ping { transport: TransportKind::Spsc, size });
        std::env::remove_var("MPI_ABI_FLAT_MATCH");
        let spsc = with_abi(AbiConfig::Mpich, Ping { transport: TransportKind::Spsc, size });
        let muk = with_abi(AbiConfig::MukMpich, Ping { transport: TransportKind::Spsc, size });
        let mutex = with_abi(AbiConfig::Mpich, Ping { transport: TransportKind::Mutex, size });
        if size == 8 {
            base8 = spsc;
            flat8 = flat;
        }
        table.row(&[
            size.to_string(),
            format!("{:.0}", flat * 1e9),
            format!("{:.0}", spsc * 1e9),
            format!("{:.0}", muk * 1e9),
            format!("{:.0}", mutex * 1e9),
        ]);
    }
    println!("{}", table.render());
    println!(
        "shape: small-message fabric latency {:.0} ns — the \"network cost\" that dwarfs the ~ns ABI costs of E1/E6",
        base8 * 1e9
    );
    println!(
        "index win at 8 B: indexed matcher + zero-alloc blocking path is {:.2}x vs MPI_ABI_FLAT_MATCH=1 ({:.0} ns → {:.0} ns)",
        flat8 / base8,
        flat8 * 1e9,
        base8 * 1e9
    );
}
