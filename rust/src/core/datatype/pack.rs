//! Pack/unpack: user memory (laid out per the datatype's typemap) ↔
//! contiguous wire bytes.
//!
//! All transport payloads are packed bytes, so `MPI_Send(buf, 3, vector_t)`
//! walks the typemap gather-style, and the receive side scatters. This is
//! also the engine behind `MPI_Pack`/`MPI_Unpack`.
//!
//! Safety: `ptr` arguments are user buffer addresses paired with datatype
//! extents, exactly as at a C MPI boundary. The caller (ABI shim) is
//! responsible for the buffer being live and large enough — MPI semantics.
//!
//! Fast path: most types carry a **cached pack plan**
//! ([`DatatypeObj::plan`]) — the typemap flattened at construction into
//! `(offset, len)` runs — so the per-call work is a handful of memcpys
//! instead of a typemap recursion. Dense types (one run covering the
//! whole extent) collapse to a single memcpy for the entire array.

use super::{DatatypeObj, TypeKind};
use crate::core::slab::Slab;
use crate::core::{err, DtId, RC};

/// Whether `plan` is one hole-free run covering the full extent — the
/// whole array can then move in a single memcpy.
#[inline]
fn plan_is_dense(plan: &[(isize, usize)], obj: &DatatypeObj) -> bool {
    plan.len() == 1 && plan[0].0 == 0 && plan[0].1 == obj.size && obj.extent == obj.size as isize
}

/// Pack `count` items of `dt` starting at `ptr` into `out`.
pub fn pack(
    dtypes: &Slab<DatatypeObj>,
    ptr: *const u8,
    count: usize,
    dt: DtId,
    out: &mut Vec<u8>,
) -> RC<()> {
    let obj = dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?;
    out.reserve(obj.size * count);
    if let Some(plan) = &obj.plan {
        if plan_is_dense(plan, obj) {
            if obj.size * count > 0 {
                let bytes = unsafe { std::slice::from_raw_parts(ptr, obj.size * count) };
                out.extend_from_slice(bytes);
            }
            return Ok(());
        }
        for i in 0..count {
            let base = unsafe { ptr.offset(obj.extent * i as isize) };
            for &(off, len) in plan {
                let bytes = unsafe { std::slice::from_raw_parts(base.offset(off), len) };
                out.extend_from_slice(bytes);
            }
        }
        return Ok(());
    }
    for i in 0..count {
        let base = unsafe { ptr.offset(obj.extent * i as isize) };
        pack_one(dtypes, obj, base, out)?;
    }
    Ok(())
}

/// Pack only the packed-stream byte window `[start, start + len)` of
/// `count` items of `dt` at `ptr`, appending to `out`. This is the
/// rendezvous chunk path: the sender materialises one chunk at a time,
/// never the whole message. Returns `Ok(false)` when the type carries no
/// cached plan (deep recursion) — the caller falls back to a one-shot
/// full pack; every other type packs the window directly from the plan.
pub fn pack_range(
    dtypes: &Slab<DatatypeObj>,
    ptr: *const u8,
    count: usize,
    dt: DtId,
    start: usize,
    len: usize,
    out: &mut Vec<u8>,
) -> RC<bool> {
    let obj = dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?;
    let total = obj.size * count;
    let end = (start + len).min(total);
    if obj.size == 0 || start >= end {
        return Ok(true);
    }
    let plan = match &obj.plan {
        Some(p) => p,
        None => return Ok(false),
    };
    out.reserve(end - start);
    if plan_is_dense(plan, obj) {
        let bytes = unsafe { std::slice::from_raw_parts(ptr.add(start), end - start) };
        out.extend_from_slice(bytes);
        return Ok(true);
    }
    // Walk only the items the window intersects; inside each item walk
    // the plan with a running packed offset and copy the overlap.
    let first_item = start / obj.size;
    let last_item = (end - 1) / obj.size;
    for i in first_item..=last_item {
        let base = unsafe { ptr.offset(obj.extent * i as isize) };
        let mut packed = i * obj.size; // packed offset of this segment's start
        for &(off, seg_len) in plan {
            let seg_start = packed.max(start);
            let seg_end = (packed + seg_len).min(end);
            if seg_start < seg_end {
                let skip = seg_start - packed;
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        base.offset(off + skip as isize),
                        seg_end - seg_start,
                    )
                };
                out.extend_from_slice(bytes);
            }
            packed += seg_len;
            if packed >= end {
                return Ok(true);
            }
        }
    }
    Ok(true)
}

/// Scatter `data` into the packed-stream window starting at byte `start`
/// of `count` items of `dt` at `ptr` — the receive half of the rendezvous
/// chunk path. `data` beyond the type's total packed size is ignored (the
/// caller accounts truncation). Returns `Ok(false)` when the type carries
/// no cached plan; the caller then stages the stream and unpacks once at
/// completion.
pub fn unpack_range(
    dtypes: &Slab<DatatypeObj>,
    data: &[u8],
    ptr: *mut u8,
    count: usize,
    dt: DtId,
    start: usize,
) -> RC<bool> {
    let obj = dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?;
    let total = obj.size * count;
    let end = (start + data.len()).min(total);
    if obj.size == 0 || start >= end {
        return Ok(true);
    }
    let plan = match &obj.plan {
        Some(p) => p,
        None => return Ok(false),
    };
    if plan_is_dense(plan, obj) {
        unsafe { std::ptr::copy_nonoverlapping(data.as_ptr(), ptr.add(start), end - start) };
        return Ok(true);
    }
    let first_item = start / obj.size;
    let last_item = (end - 1) / obj.size;
    for i in first_item..=last_item {
        let base = unsafe { ptr.offset(obj.extent * i as isize) };
        let mut packed = i * obj.size;
        for &(off, seg_len) in plan {
            let seg_start = packed.max(start);
            let seg_end = (packed + seg_len).min(end);
            if seg_start < seg_end {
                let skip = seg_start - packed;
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        data.as_ptr().add(seg_start - start),
                        base.offset(off + skip as isize),
                        seg_end - seg_start,
                    );
                }
            }
            packed += seg_len;
            if packed >= end {
                return Ok(true);
            }
        }
    }
    Ok(true)
}

fn pack_one(
    dtypes: &Slab<DatatypeObj>,
    obj: &DatatypeObj,
    ptr: *const u8,
    out: &mut Vec<u8>,
) -> RC<()> {
    match &obj.kind {
        TypeKind::Builtin { .. } => {
            if obj.size > 0 {
                let bytes = unsafe { std::slice::from_raw_parts(ptr, obj.size) };
                out.extend_from_slice(bytes);
            }
            Ok(())
        }
        TypeKind::Contiguous { count, child } => {
            let c = dtypes.get(child.0).ok_or(err!(MPI_ERR_TYPE))?;
            for i in 0..*count {
                pack_one(dtypes, c, unsafe { ptr.offset(c.extent * i as isize) }, out)?;
            }
            Ok(())
        }
        TypeKind::Vector { count, blocklen, stride_bytes, child } => {
            let c = dtypes.get(child.0).ok_or(err!(MPI_ERR_TYPE))?;
            for i in 0..*count {
                let block = unsafe { ptr.offset(stride_bytes * i as isize) };
                for j in 0..*blocklen {
                    pack_one(dtypes, c, unsafe { block.offset(c.extent * j as isize) }, out)?;
                }
            }
            Ok(())
        }
        TypeKind::Indexed { blocks, child } => {
            let c = dtypes.get(child.0).ok_or(err!(MPI_ERR_TYPE))?;
            for &(len, disp) in blocks {
                let block = unsafe { ptr.offset(disp) };
                for j in 0..len {
                    pack_one(dtypes, c, unsafe { block.offset(c.extent * j as isize) }, out)?;
                }
            }
            Ok(())
        }
        TypeKind::Struct { blocks } => {
            for &(len, disp, t) in blocks {
                let c = dtypes.get(t.0).ok_or(err!(MPI_ERR_TYPE))?;
                let block = unsafe { ptr.offset(disp) };
                for j in 0..len {
                    pack_one(dtypes, c, unsafe { block.offset(c.extent * j as isize) }, out)?;
                }
            }
            Ok(())
        }
        TypeKind::Resized { child } | TypeKind::Dup { child } => {
            let c = dtypes.get(child.0).ok_or(err!(MPI_ERR_TYPE))?;
            pack_one(dtypes, c, ptr, out)
        }
    }
}

/// Unpack from `data` into `count` items of `dt` at `ptr`. Returns the
/// number of bytes consumed (may be less than `data.len()` if the sender
/// sent less; the caller computes truncation separately).
pub fn unpack(
    dtypes: &Slab<DatatypeObj>,
    data: &[u8],
    ptr: *mut u8,
    count: usize,
    dt: DtId,
) -> RC<usize> {
    let obj = dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?;
    if let Some(plan) = &obj.plan {
        if plan_is_dense(plan, obj) {
            let n = data.len().min(obj.size * count);
            if n > 0 {
                unsafe { std::ptr::copy_nonoverlapping(data.as_ptr(), ptr, n) };
            }
            return Ok(n);
        }
        let mut cursor = 0usize;
        'items: for i in 0..count {
            if cursor >= data.len() {
                break;
            }
            let base = unsafe { ptr.offset(obj.extent * i as isize) };
            for &(off, len) in plan {
                let take = len.min(data.len() - cursor);
                if take > 0 {
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            data.as_ptr().add(cursor),
                            base.offset(off),
                            take,
                        );
                    }
                    cursor += take;
                }
                if take < len {
                    break 'items;
                }
            }
        }
        return Ok(cursor);
    }
    let mut cursor = 0usize;
    for i in 0..count {
        if cursor >= data.len() {
            break;
        }
        let base = unsafe { ptr.offset(obj.extent * i as isize) };
        unpack_one(dtypes, obj, data, &mut cursor, base)?;
    }
    Ok(cursor)
}

fn unpack_one(
    dtypes: &Slab<DatatypeObj>,
    obj: &DatatypeObj,
    data: &[u8],
    cursor: &mut usize,
    ptr: *mut u8,
) -> RC<()> {
    match &obj.kind {
        TypeKind::Builtin { .. } => {
            let n = obj.size.min(data.len().saturating_sub(*cursor));
            if n > 0 {
                unsafe {
                    std::ptr::copy_nonoverlapping(data.as_ptr().add(*cursor), ptr, n);
                }
                *cursor += n;
            }
            Ok(())
        }
        TypeKind::Contiguous { count, child } => {
            let c = dtypes.get(child.0).ok_or(err!(MPI_ERR_TYPE))?;
            for i in 0..*count {
                if *cursor >= data.len() {
                    break;
                }
                unpack_one(dtypes, c, data, cursor, unsafe {
                    ptr.offset(c.extent * i as isize)
                })?;
            }
            Ok(())
        }
        TypeKind::Vector { count, blocklen, stride_bytes, child } => {
            let c = dtypes.get(child.0).ok_or(err!(MPI_ERR_TYPE))?;
            for i in 0..*count {
                let block = unsafe { ptr.offset(stride_bytes * i as isize) };
                for j in 0..*blocklen {
                    if *cursor >= data.len() {
                        return Ok(());
                    }
                    unpack_one(dtypes, c, data, cursor, unsafe {
                        block.offset(c.extent * j as isize)
                    })?;
                }
            }
            Ok(())
        }
        TypeKind::Indexed { blocks, child } => {
            let c = dtypes.get(child.0).ok_or(err!(MPI_ERR_TYPE))?;
            for &(len, disp) in blocks {
                let block = unsafe { ptr.offset(disp) };
                for j in 0..len {
                    if *cursor >= data.len() {
                        return Ok(());
                    }
                    unpack_one(dtypes, c, data, cursor, unsafe {
                        block.offset(c.extent * j as isize)
                    })?;
                }
            }
            Ok(())
        }
        TypeKind::Struct { blocks } => {
            for &(len, disp, t) in blocks {
                let c = dtypes.get(t.0).ok_or(err!(MPI_ERR_TYPE))?;
                let block = unsafe { ptr.offset(disp) };
                for j in 0..len {
                    if *cursor >= data.len() {
                        return Ok(());
                    }
                    unpack_one(dtypes, c, data, cursor, unsafe {
                        block.offset(c.extent * j as isize)
                    })?;
                }
            }
            Ok(())
        }
        TypeKind::Resized { child } | TypeKind::Dup { child } => {
            let c = dtypes.get(child.0).ok_or(err!(MPI_ERR_TYPE))?;
            unpack_one(dtypes, c, data, cursor, ptr)
        }
    }
}
