//! MPI-4 Sessions: the testsuite battery under all five ABI
//! configurations, plus engine-level coverage the in-job battery can't
//! express — sessions-*only* jobs (no `MPI_Init` anywhere), the shared
//! init refcount behind `MPI_Initialized`/`MPI_Finalized`, and
//! launcher-provided process sets.

use mpi_abi::api::{Dt, MpiAbi, OpName};
use mpi_abi::core::transport::TransportKind;
use mpi_abi::core::world::World;
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::launcher::{run_job_ok, run_on_world, JobSpec};
use mpi_abi::muk::{MukMpich, MukOmpi};
use mpi_abi::native_abi::NativeAbi;
use mpi_abi::testsuite;

fn run_session_battery<A: MpiAbi>(ranks: usize) {
    let reports = run_job_ok(JobSpec::new(ranks), |rank| {
        assert_eq!(A::init(), 0, "{} init", A::NAME);
        let results = testsuite::run_registry::<A>(rank, testsuite::session_registry::<A>());
        let report = testsuite::report(A::NAME, &results);
        let failed = results.iter().filter(|r| !r.passed).count();
        assert_eq!(A::finalize(), 0, "{} finalize", A::NAME);
        (report, failed)
    });
    let (report, failures) = &reports[0];
    if *failures > 0 {
        panic!("{report}");
    }
}

#[test]
fn session_battery_mpich_native() {
    run_session_battery::<MpichAbi>(4);
}

#[test]
fn session_battery_ompi_native() {
    run_session_battery::<OmpiAbi>(4);
}

#[test]
fn session_battery_muk_over_mpich() {
    run_session_battery::<MukMpich>(4);
}

#[test]
fn session_battery_muk_over_ompi() {
    run_session_battery::<MukOmpi>(4);
}

#[test]
fn session_battery_native_standard_abi() {
    run_session_battery::<NativeAbi>(4);
}

/// A whole job that never calls `MPI_Init`: sessions carry everything,
/// including a collective over a `MPI_Comm_create_from_group` comm.
#[test]
fn sessions_only_job_never_calls_init() {
    fn body<A: MpiAbi>(ranks: usize) {
        let out = run_job_ok(JobSpec::new(ranks), |_| {
            assert!(!A::initialized(), "nothing initialized yet");
            let mut s = A::session_null();
            assert_eq!(A::session_init(A::info_null(), A::errhandler_return(), &mut s), 0);
            assert!(A::initialized(), "a session initializes the library");
            assert!(!A::finalized());
            let mut g = unsafe { std::mem::zeroed::<A::Group>() };
            assert_eq!(
                A::group_from_session_pset(s, mpi_abi::core::session::PSET_WORLD, &mut g),
                0
            );
            let mut comm = A::comm_null();
            assert_eq!(
                A::comm_create_from_group(g, "test://sessions-only", A::info_null(),
                    A::errhandler_return(), &mut comm),
                0
            );
            A::group_free(&mut g);
            let one = 1i32;
            let mut sum = 0i32;
            assert_eq!(
                A::allreduce(&one as *const i32 as *const u8, &mut sum as *mut i32 as *mut u8,
                    1, A::datatype(Dt::Int), A::op(OpName::Sum), comm),
                0
            );
            A::comm_free(&mut comm);
            assert_eq!(A::session_finalize(&mut s), 0);
            assert!(A::finalized(), "last session finalize finalizes the library");
            assert!(A::initialized(), "initialized never resets");
            sum
        });
        for v in out {
            assert_eq!(v as usize, ranks, "{}", A::NAME);
        }
    }
    body::<MpichAbi>(3);
    body::<OmpiAbi>(3);
    body::<MukMpich>(3);
    body::<MukOmpi>(3);
    body::<NativeAbi>(3);
}

/// World finalize with a session still open must NOT report the library
/// finalized (the sessions-aware refcount contract of SPEC.md §6).
#[test]
fn world_finalize_with_open_session_keeps_library_alive() {
    let out = run_job_ok(JobSpec::new(2), |_| {
        let mut s = NativeAbi::session_null();
        assert_eq!(
            NativeAbi::session_init(NativeAbi::info_null(), NativeAbi::errhandler_return(),
                &mut s),
            0
        );
        assert_eq!(NativeAbi::init(), 0);
        assert_eq!(NativeAbi::finalize(), 0);
        let mid = (NativeAbi::initialized(), NativeAbi::finalized());
        assert_eq!(NativeAbi::session_finalize(&mut s), 0);
        let end = (NativeAbi::initialized(), NativeAbi::finalized());
        (mid, end)
    });
    for (mid, end) in out {
        assert_eq!(mid, (true, false), "world finalized but session alive");
        assert_eq!(end, (true, true), "all epochs closed");
    }
}

/// Launcher-provided process sets surface through the session queries
/// only on the ranks they contain.
#[test]
fn launcher_psets_surface_per_rank() {
    let ranks = 4;
    let psets = vec![
        ("app://even".to_string(), vec![0usize, 2]),
        ("app://odd".to_string(), vec![1usize, 3]),
    ];
    let world = World::new_with_psets(ranks, TransportKind::Spsc, psets);
    let out = run_on_world(world, ranks, |rank| {
        let mut s = NativeAbi::session_null();
        assert_eq!(
            NativeAbi::session_init(NativeAbi::info_null(), NativeAbi::errhandler_return(),
                &mut s),
            0
        );
        let mut n = 0;
        assert_eq!(NativeAbi::session_get_num_psets(s, &mut n), 0);
        let mut names = Vec::new();
        for i in 0..n {
            let mut name = String::new();
            assert_eq!(NativeAbi::session_get_nth_pset(s, i, &mut name), 0);
            names.push(name);
        }
        // A comm over "my" launcher set: even ranks pair up, odd ranks
        // pair up — same code path on both, tag string per set.
        let mine = if rank % 2 == 0 { "app://even" } else { "app://odd" };
        let mut g = unsafe { std::mem::zeroed::<<NativeAbi as MpiAbi>::Group>() };
        assert_eq!(NativeAbi::group_from_session_pset(s, mine, &mut g), 0);
        let mut comm = NativeAbi::comm_null();
        assert_eq!(
            NativeAbi::comm_create_from_group(g, mine, NativeAbi::info_null(),
                NativeAbi::errhandler_return(), &mut comm),
            0
        );
        NativeAbi::group_free(&mut g);
        let mut cs = 0;
        assert_eq!(NativeAbi::comm_size(comm, &mut cs), 0);
        NativeAbi::comm_free(&mut comm);
        assert_eq!(NativeAbi::session_finalize(&mut s), 0);
        (names, cs)
    });
    for (rank, outcome) in out.into_iter().enumerate() {
        let (names, cs) = match outcome {
            mpi_abi::launcher::RankOutcome::Ok(v) => v,
            other => panic!("rank {rank} failed: {other:?}"),
        };
        assert_eq!(cs, 2, "launcher-set comm spans its two members");
        let mine = if rank % 2 == 0 { "app://even" } else { "app://odd" };
        let other = if rank % 2 == 0 { "app://odd" } else { "app://even" };
        assert!(names.iter().any(|n| n == mine), "rank {rank} sees {mine} in {names:?}");
        assert!(!names.iter().any(|n| n == other), "rank {rank} must not see {other}");
    }
}

/// Sequential re-use of the *same* tag string is legal (MPI only needs
/// distinct tags for concurrent creations): the fabric's FIFO keeps the
/// two agreements ordered.
#[test]
fn same_tag_sequential_creates_are_ordered() {
    let out = run_job_ok(JobSpec::new(3), |_| {
        let mut s = NativeAbi::session_null();
        assert_eq!(
            NativeAbi::session_init(NativeAbi::info_null(), NativeAbi::errhandler_return(),
                &mut s),
            0
        );
        let mut g = unsafe { std::mem::zeroed::<<NativeAbi as MpiAbi>::Group>() };
        assert_eq!(
            NativeAbi::group_from_session_pset(s, mpi_abi::core::session::PSET_WORLD, &mut g),
            0
        );
        let mut sums = Vec::new();
        for round in 0..2i32 {
            let mut comm = NativeAbi::comm_null();
            assert_eq!(
                NativeAbi::comm_create_from_group(g, "test://same-tag", NativeAbi::info_null(),
                    NativeAbi::errhandler_return(), &mut comm),
                0
            );
            let v = round + 1;
            let mut sum = 0i32;
            assert_eq!(
                NativeAbi::allreduce(&v as *const i32 as *const u8,
                    &mut sum as *mut i32 as *mut u8, 1, NativeAbi::datatype(Dt::Int),
                    NativeAbi::op(OpName::Sum), comm),
                0
            );
            sums.push(sum);
            NativeAbi::comm_free(&mut comm);
        }
        NativeAbi::group_free(&mut g);
        assert_eq!(NativeAbi::session_finalize(&mut s), 0);
        sums
    });
    for sums in out {
        assert_eq!(sums, vec![3, 6]);
    }
}
