//! Predefined reduction-operation handle constants (Appendix A.1).
//!
//! The op block occupies `0b00001xxxxx` with intentional gaps between the
//! arithmetic / bitwise / logical / loc / accumulate groups so each group
//! can grow without breaking changes.

/// `MPI_OP_NULL` — the op-kind bits followed by zeros (the null rule).
pub const MPI_OP_NULL: usize = 0b0000100000;

// Arithmetic ops.
/// Zero-page Huffman constant for `MPI_SUM` (Appendix A.1).
pub const MPI_SUM: usize = 0b0000100001;
/// Zero-page Huffman constant for `MPI_MIN` (Appendix A.1).
pub const MPI_MIN: usize = 0b0000100010;
/// Zero-page Huffman constant for `MPI_MAX` (Appendix A.1).
pub const MPI_MAX: usize = 0b0000100011;
/// Zero-page Huffman constant for `MPI_PROD` (Appendix A.1).
pub const MPI_PROD: usize = 0b0000100100;

// Bitwise ops.
/// Zero-page Huffman constant for `MPI_BAND` (Appendix A.1).
pub const MPI_BAND: usize = 0b0000101000;
/// Zero-page Huffman constant for `MPI_BOR` (Appendix A.1).
pub const MPI_BOR: usize = 0b0000101001;
/// Zero-page Huffman constant for `MPI_BXOR` (Appendix A.1).
pub const MPI_BXOR: usize = 0b0000101010;

// Logical ops.
/// Zero-page Huffman constant for `MPI_LAND` (Appendix A.1).
pub const MPI_LAND: usize = 0b0000110000;
/// Zero-page Huffman constant for `MPI_LOR` (Appendix A.1).
pub const MPI_LOR: usize = 0b0000110001;
/// Zero-page Huffman constant for `MPI_LXOR` (Appendix A.1).
pub const MPI_LXOR: usize = 0b0000110010;

// Loc ops.
/// Zero-page Huffman constant for `MPI_MINLOC` (Appendix A.1).
pub const MPI_MINLOC: usize = 0b0000111000;
/// Zero-page Huffman constant for `MPI_MAXLOC` (Appendix A.1).
pub const MPI_MAXLOC: usize = 0b0000111001;

// Accumulate ops.
/// Zero-page Huffman constant for `MPI_REPLACE` (Appendix A.1).
pub const MPI_REPLACE: usize = 0b0000111100;
/// Zero-page Huffman constant for `MPI_NO_OP` (Appendix A.1).
pub const MPI_NO_OP: usize = 0b0000111101;

/// All predefined op constants with their MPI names.
pub const PREDEFINED_OPS: &[(&str, usize)] = &[
    ("MPI_OP_NULL", MPI_OP_NULL),
    ("MPI_SUM", MPI_SUM),
    ("MPI_MIN", MPI_MIN),
    ("MPI_MAX", MPI_MAX),
    ("MPI_PROD", MPI_PROD),
    ("MPI_BAND", MPI_BAND),
    ("MPI_BOR", MPI_BOR),
    ("MPI_BXOR", MPI_BXOR),
    ("MPI_LAND", MPI_LAND),
    ("MPI_LOR", MPI_LOR),
    ("MPI_LXOR", MPI_LXOR),
    ("MPI_MINLOC", MPI_MINLOC),
    ("MPI_MAXLOC", MPI_MAXLOC),
    ("MPI_REPLACE", MPI_REPLACE),
    ("MPI_NO_OP", MPI_NO_OP),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abi::huffman::{kind_of, HandleKind};

    #[test]
    fn groups_leave_reserved_gaps() {
        // A.1 reserves 0b00001001xx after PROD, 0b0000101xxx tail after
        // BXOR, etc. Verify the gaps exist (values absent from the table)
        // and still decode as Op-kind so future additions stay compatible.
        for gap in [0b0000100101usize, 0b0000101011, 0b0000110011, 0b0000111010] {
            assert!(!PREDEFINED_OPS.iter().any(|&(_, v)| v == gap));
            assert_eq!(kind_of(gap as u16), HandleKind::Op);
        }
    }

    #[test]
    fn names_resolve() {
        assert_eq!(crate::abi::op_name(MPI_SUM), Some("MPI_SUM"));
        assert_eq!(crate::abi::op_name(MPI_NO_OP), Some("MPI_NO_OP"));
        assert_eq!(crate::abi::op_name(0b0000100101), None);
    }
}
