"""L1 Pallas kernel: elementwise binary reduction (the MPI_Reduce /
MPI_Allreduce combine step).

The engine's reduction hot loop — ``inout[i] = op(in[i], inout[i])`` over
packed f32/f64 buffers — is the compute hot-spot MPI implementations
vectorize aggressively. Here it is written the TPU way:

* tiles are ``(BLOCK_ROWS, 128)``: 128 lanes (the VPU/MXU lane width),
  BLOCK_ROWS sublanes per step, so each grid step moves one VMEM-resident
  tile per operand;
* ``BlockSpec`` expresses the HBM→VMEM schedule; three buffers per step
  (a, b, out) with f32 tiles of 8×128 = 4 KiB each stay far inside the
  ~16 MiB VMEM budget and let the pipeliner double-buffer;
* ``interpret=True`` is mandatory for the CPU PJRT runtime (real-TPU
  lowering emits a Mosaic custom-call the CPU plugin cannot execute);
  the real-TPU efficiency estimate lives in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane width of the TPU vector unit; last dim of every tile.
LANES = 128
# Sublanes per tile: 8 f32 sublanes = the native (8, 128) f32 tile.
BLOCK_ROWS = 8

OPS = ("sum", "prod", "min", "max")


def _combine(op, a, b):
    if op == "sum":
        return a + b
    if op == "prod":
        return a * b
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    raise ValueError(f"unknown op {op}")


def _reduce_kernel(a_ref, b_ref, o_ref, *, op):
    # One VMEM tile per operand; elementwise combine on the VPU.
    o_ref[...] = _combine(op, a_ref[...], b_ref[...])


@functools.partial(jax.jit, static_argnames=("op",))
def reduce_op(a, b, *, op: str):
    """``op(a, b)`` elementwise via a tiled Pallas kernel.

    ``a``/``b``: rank-1 arrays whose length is a multiple of
    ``BLOCK_ROWS * LANES``. The wrapper reshapes to (rows, LANES) tiles and
    grids over row-blocks.
    """
    n = a.shape[0]
    tile_elems = BLOCK_ROWS * LANES
    assert n % tile_elems == 0, f"n={n} must be a multiple of {tile_elems}"
    rows = n // LANES
    a2 = a.reshape(rows, LANES)
    b2 = b.reshape(rows, LANES)
    grid = (rows // BLOCK_ROWS,)
    out = pl.pallas_call(
        functools.partial(_reduce_kernel, op=op),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), a.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        interpret=True,
    )(a2, b2)
    return out.reshape(n)


def vmem_bytes_per_step(dtype=jnp.float32) -> int:
    """VMEM footprint estimate per grid step (3 tiles resident, x2 for
    double buffering) — the §Perf roofline input."""
    itemsize = jnp.dtype(dtype).itemsize
    return 3 * 2 * BLOCK_ROWS * LANES * itemsize
