//! One-sided (RMA) tests: windows, the fence + passive-target epoch
//! machinery, Put/Get/Accumulate with builtin and derived datatypes, and
//! the epoch error rules. Every test runs against all five ABI
//! configurations — window handles, `MPI_Aint` displacements, and the
//! §5.4 assertion/lock-type constants are part of the binary contract.

use super::util::*;
use super::TestFn;
use crate::abi::types::Aint;
use crate::api::{Dt, MpiAbi, OpName};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("rma.fence_put_ring", fence_put_ring::<A>),
        ("rma.fence_get", fence_get::<A>),
        ("rma.fence_zero_ops", fence_zero_ops::<A>),
        ("rma.fence_ordering", fence_ordering::<A>),
        ("rma.self_put", self_put::<A>),
        ("rma.put_outside_epoch_errors", put_outside_epoch_errors::<A>),
        ("rma.accumulate_sum", accumulate_sum::<A>),
        ("rma.accumulate_derived_target", accumulate_derived_target::<A>),
        ("rma.put_derived_target", put_derived_target::<A>),
        ("rma.lock_exclusive_counter", lock_exclusive_counter::<A>),
        ("rma.lock_shared_readers", lock_shared_readers::<A>),
        ("rma.win_allocate", win_allocate::<A>),
        ("rma.get_address_aint", get_address_aint::<A>),
        ("rma.proc_null_target", proc_null_target::<A>),
    ]
}

fn world_geometry<A: MpiAbi>() -> (i32, i32) {
    let (mut size, mut rank) = (0, 0);
    A::comm_size(A::comm_world(), &mut size);
    A::comm_rank(A::comm_world(), &mut rank);
    (size, rank)
}

const I32_BYTES: i32 = std::mem::size_of::<i32>() as i32;

/// Create an i32 window over `mem`, run `f(win)`, then free the window.
/// The closing fence is `f`'s job (it knows the epoch structure).
fn with_i32_win<A: MpiAbi, F: FnOnce(A::Win) -> Result<(), String>>(
    mem: &mut [i32],
    f: F,
) -> Result<(), String> {
    let mut win = A::win_null();
    check_rc!(
        A::win_create(
            mem.as_mut_ptr() as *mut u8,
            std::mem::size_of_val(mem) as Aint,
            I32_BYTES,
            A::info_null(),
            A::comm_world(),
            &mut win,
        ),
        "win_create"
    );
    check!(win != A::win_null(), "win_create yields a non-null handle");
    f(win)?;
    check_rc!(A::win_free(&mut win), "win_free");
    check!(win == A::win_null(), "win_free nulls the handle");
    Ok(())
}

/// Each rank puts `1000 + me` into slot `me` of its right neighbor's
/// window; after the fence the slot written by the left neighbor holds
/// the left neighbor's value.
fn fence_put_ring<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let mut mem = vec![-1i32; n as usize];
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::win_fence(0, win), "opening fence");
        let right = (me + 1) % n;
        let v = [1000 + me];
        check_rc!(A::put(slice_ptr(&v), 1, dt, right, me as Aint, 1, dt, win), "put");
        check_rc!(A::win_fence(0, win), "closing fence");
        Ok(())
    })?;
    let left = ((me + n - 1) % n) as usize;
    check!(mem[left] == 1000 + left as i32, "slot {left} holds {} not {}", mem[left],
        1000 + left as i32);
    check_rc!(A::barrier(A::comm_world()), "exit barrier");
    Ok(())
}

/// Each rank fills its window, then gets the right neighbor's block.
fn fence_get<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let mut mem: Vec<i32> = (0..4).map(|i| me * 100 + i).collect();
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::win_fence(0, win), "opening fence");
        let right = (me + 1) % n;
        let mut got = [0i32; 4];
        check_rc!(A::get(slice_ptr_mut(&mut got), 4, dt, right, 0, 4, dt, win), "get");
        check_rc!(A::win_fence(0, win), "closing fence");
        for (i, &g) in got.iter().enumerate() {
            check!(g == right * 100 + i as i32, "got[{i}] = {g}");
        }
        Ok(())
    })
}

/// Fences with no operations between them must complete.
fn fence_zero_ops<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let mut mem = vec![0i32; 2];
    with_i32_win::<A, _>(&mut mem, |win| {
        for k in 0..4 {
            let rc = A::win_fence(0, win);
            check!(rc == 0, "zero-op fence {k} returned rc {rc}");
        }
        check_rc!(A::win_fence(A::mode_nosucceed(), win), "closing fence");
        Ok(())
    })
}

/// Successive fence epochs order puts: a value put in epoch 1 is visible
/// to a get in epoch 2.
fn fence_ordering<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    let mut mem = vec![0i32; 1];
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::win_fence(0, win), "fence 0");
        // Epoch 1: rank 0 puts into rank 1.
        if me == 0 {
            let v = [777i32];
            check_rc!(A::put(slice_ptr(&v), 1, dt, 1, 0, 1, dt, win), "put");
        }
        check_rc!(A::win_fence(0, win), "fence 1");
        // Epoch 2: the last rank reads it back from rank 1.
        let mut got = [0i32];
        if me == n - 1 {
            check_rc!(A::get(slice_ptr_mut(&mut got), 1, dt, 1, 0, 1, dt, win), "get");
        }
        check_rc!(A::win_fence(0, win), "fence 2");
        if me == n - 1 {
            check!(got[0] == 777, "epoch-2 get sees epoch-1 put: {}", got[0]);
        }
        Ok(())
    })
}

/// Put with the target being the origin itself (the local fast path).
fn self_put<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (_, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let mut mem = vec![0i32; 2];
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::win_fence(0, win), "opening fence");
        let v = [me * 3 + 1, me * 3 + 2];
        check_rc!(A::put(slice_ptr(&v), 2, dt, me, 0, 2, dt, win), "self put");
        check_rc!(A::win_fence(0, win), "closing fence");
        Ok(())
    })?;
    check!(mem == vec![me * 3 + 1, me * 3 + 2], "self put landed: {mem:?}");
    Ok(())
}

/// A Put outside any epoch is erroneous (`MPI_ERR_RMA_SYNC` class); the
/// same Put succeeds once a fence opens an epoch, and fails again after
/// a NOSUCCEED fence closes it.
fn put_outside_epoch_errors<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let mut mem = vec![0i32; 1];
    with_i32_win::<A, _>(&mut mem, |win| {
        let v = [me];
        let right = (me + 1) % n;
        let rc = A::put(slice_ptr(&v), 1, dt, right, 0, 1, dt, win);
        check!(rc != 0, "put before any fence must fail, got rc {rc}");
        check_rc!(A::win_fence(0, win), "opening fence");
        check_rc!(A::put(slice_ptr(&v), 1, dt, right, 0, 1, dt, win), "put in epoch");
        check_rc!(A::win_fence(A::mode_nosucceed(), win), "closing fence");
        let rc = A::put(slice_ptr(&v), 1, dt, right, 0, 1, dt, win);
        check!(rc != 0, "put after NOSUCCEED fence must fail, got rc {rc}");
        Ok(())
    })
}

/// Every rank accumulates into rank 0's slots with SUM; order-free.
fn accumulate_sum<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let op = A::op(OpName::Sum);
    let mut mem = vec![0i32; 3];
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::win_fence(0, win), "opening fence");
        let v = [1i32, me, 2 * me];
        check_rc!(A::accumulate(slice_ptr(&v), 3, dt, 0, 0, 3, dt, op, win), "accumulate");
        check_rc!(A::win_fence(0, win), "closing fence");
        Ok(())
    })?;
    if me == 0 {
        let ranksum: i32 = (0..n).sum();
        check!(mem[0] == n, "sum of ones: {}", mem[0]);
        check!(mem[1] == ranksum, "sum of ranks: {}", mem[1]);
        check!(mem[2] == 2 * ranksum, "sum of 2*ranks: {}", mem[2]);
    }
    check_rc!(A::barrier(A::comm_world()), "exit barrier");
    Ok(())
}

/// Accumulate into a *derived* (strided vector) target layout: MAX over
/// every even slot of rank 0's window.
fn accumulate_derived_target<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (_, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let op = A::op(OpName::Max);
    let mut vt = dt;
    check_rc!(A::type_vector(3, 1, 2, dt, &mut vt), "type_vector");
    check_rc!(A::type_commit(&mut vt), "type_commit");
    let mut mem = vec![-1i32; 6];
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::win_fence(0, win), "opening fence");
        let v = [me * 10, me * 10 + 1, me * 10 + 2];
        check_rc!(A::accumulate(slice_ptr(&v), 3, dt, 0, 0, 1, vt, op, win), "accumulate");
        check_rc!(A::win_fence(0, win), "closing fence");
        Ok(())
    })?;
    if me == 0 {
        let (n, _) = world_geometry::<A>();
        let top = (n - 1) * 10;
        check!(mem[0] == top && mem[2] == top + 1 && mem[4] == top + 2,
            "strided MAX landed: {mem:?}");
        check!(mem[1] == -1 && mem[3] == -1 && mem[5] == -1, "holes untouched: {mem:?}");
    }
    check_rc!(A::type_free(&mut vt), "type_free");
    check_rc!(A::barrier(A::comm_world()), "exit barrier");
    Ok(())
}

/// Put a contiguous origin block into a strided target layout.
fn put_derived_target<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    let mut vt = dt;
    check_rc!(A::type_vector(2, 1, 3, dt, &mut vt), "type_vector");
    check_rc!(A::type_commit(&mut vt), "type_commit");
    let mut mem = vec![0i32; 6];
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::win_fence(0, win), "opening fence");
        if me == 0 {
            let v = [41i32, 42];
            check_rc!(A::put(slice_ptr(&v), 2, dt, 1, 0, 1, vt, win), "strided put");
        }
        check_rc!(A::win_fence(0, win), "closing fence");
        Ok(())
    })?;
    if me == 1 {
        check!(mem == vec![41, 0, 0, 42, 0, 0], "strided put landed: {mem:?}");
    }
    check_rc!(A::type_free(&mut vt), "type_free");
    check_rc!(A::barrier(A::comm_world()), "exit barrier");
    Ok(())
}

/// Exclusive locks serialize read-modify-write: every rank increments a
/// counter at rank 0 under `MPI_Win_lock(EXCLUSIVE)` with a flush
/// between the get and the put. The final count proves mutual exclusion.
fn lock_exclusive_counter<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let mut mem = vec![0i32; 1];
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::win_lock(A::lock_exclusive(), 0, 0, win), "lock");
        let mut cur = [0i32];
        check_rc!(A::get(slice_ptr_mut(&mut cur), 1, dt, 0, 0, 1, dt, win), "get");
        check_rc!(A::win_flush(0, win), "flush");
        let next = [cur[0] + 1];
        check_rc!(A::put(slice_ptr(&next), 1, dt, 0, 0, 1, dt, win), "put");
        check_rc!(A::win_unlock(0, win), "unlock");
        // Every increment is complete at its unlock; the barrier makes
        // all of them happen-before the window is freed and read.
        check_rc!(A::barrier(A::comm_world()), "quiesce barrier");
        Ok(())
    })?;
    if me == 0 {
        check!(mem[0] == n, "counter reached {} not {n}", mem[0]);
    }
    check_rc!(A::barrier(A::comm_world()), "exit barrier");
    Ok(())
}

/// Shared locks admit concurrent readers.
fn lock_shared_readers<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (_, _me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let mut mem = vec![31337i32; 1];
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::barrier(A::comm_world()), "fill barrier");
        check_rc!(A::win_lock(A::lock_shared(), 0, 0, win), "shared lock");
        let mut got = [0i32];
        check_rc!(A::get(slice_ptr_mut(&mut got), 1, dt, 0, 0, 1, dt, win), "get");
        check_rc!(A::win_unlock(0, win), "unlock");
        check!(got[0] == 31337, "shared read: {}", got[0]);
        check_rc!(A::barrier(A::comm_world()), "exit barrier");
        Ok(())
    })
}

/// `MPI_Win_allocate`: the library owns the memory; ensure puts land in
/// the buffer the baseptr names.
fn win_allocate<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    let dt = A::datatype(Dt::Int32);
    let mut base: *mut u8 = std::ptr::null_mut();
    let mut win = A::win_null();
    check_rc!(
        A::win_allocate(
            (n as usize * std::mem::size_of::<i32>()) as Aint,
            I32_BYTES,
            A::info_null(),
            A::comm_world(),
            &mut base,
            &mut win,
        ),
        "win_allocate"
    );
    check!(!base.is_null(), "win_allocate returns a base pointer");
    check_rc!(A::win_fence(0, win), "opening fence");
    let right = (me + 1) % n;
    let v = [me + 500];
    check_rc!(A::put(slice_ptr(&v), 1, dt, right, me as Aint, 1, dt, win), "put");
    check_rc!(A::win_fence(0, win), "closing fence");
    let left = ((me + n - 1) % n) as usize;
    let got = unsafe { *(base as *const i32).add(left) };
    check!(got == left as i32 + 500, "allocated window slot {left} = {got}");
    check_rc!(A::win_free(&mut win), "win_free");
    Ok(())
}

/// `MPI_Get_address` / `MPI_Aint_add` / `MPI_Aint_diff` arithmetic.
fn get_address_aint<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let arr = [0u8; 16];
    let mut a0: Aint = 0;
    let mut a8: Aint = 0;
    check_rc!(A::get_address(arr.as_ptr(), &mut a0), "get_address");
    check_rc!(A::get_address(unsafe { arr.as_ptr().add(8) }, &mut a8), "get_address+8");
    check!(A::aint_diff(a8, a0) == 8, "aint_diff");
    check!(A::aint_add(a0, 8) == a8, "aint_add");
    Ok(())
}

/// RMA to `MPI_PROC_NULL` is a no-op that succeeds.
fn proc_null_target<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let dt = A::datatype(Dt::Int32);
    let mut mem = vec![0i32; 1];
    with_i32_win::<A, _>(&mut mem, |win| {
        check_rc!(A::win_fence(0, win), "opening fence");
        let v = [9i32];
        check_rc!(A::put(slice_ptr(&v), 1, dt, A::proc_null(), 0, 1, dt, win), "put null");
        let mut g = [0i32];
        check_rc!(A::get(slice_ptr_mut(&mut g), 1, dt, A::proc_null(), 0, 1, dt, win),
            "get null");
        check_rc!(A::win_fence(A::mode_nosucceed(), win), "closing fence");
        Ok(())
    })
}
