//! Smallest possible MPI application: every rank reports in.

use crate::api::MpiAbi;

/// Returns this rank's greeting (rank 0 typically prints all of them via
/// the launcher's collected outputs).
pub fn hello<A: MpiAbi>() -> String {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    format!(
        "Hello from rank {me}/{n} on {} [{}]",
        A::get_processor_name(),
        A::get_library_version()
    )
}
