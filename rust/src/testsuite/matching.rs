//! Matching-semantics regression battery for the indexed engine.
//!
//! MPI's matching rule — **posted order × arrival order** — is exactly
//! what the per-context exact buckets + wildcard FIFOs of
//! [`crate::core::match_index`] must preserve. Each test drives exact,
//! `MPI_ANY_SOURCE`, and `MPI_ANY_TAG` receives in *every posting
//! interleaving* against in-order and out-of-order arrivals, on one and
//! on two context planes (a dup'd communicator), and asserts the
//! delivery order the flat reference scan would produce.
//!
//! Determinism tricks (the tests must pass on both transports at any
//! timing): a single sender's messages arrive in send order (per-pair
//! FIFO), and a **synchronous-send sentinel** flushes the channel — when
//! the receiver has matched the sentinel, everything the sender sent
//! before it is already in the receiver's unexpected queues.

use super::util::*;
use super::TestFn;
use crate::api::{Dt, MpiAbi};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("match.single_sender_fifo_wildcards", single_sender_fifo_wildcards::<A>),
        ("match.posted_order_permutations", posted_order_permutations::<A>),
        ("match.unexpected_order_permutations", unexpected_order_permutations::<A>),
        ("match.two_contexts_isolated", two_contexts_isolated::<A>),
        ("match.any_source_two_senders", any_source_two_senders::<A>),
        ("match.out_of_order_tags", out_of_order_tags::<A>),
    ]
}

fn world_geometry<A: MpiAbi>() -> (i32, i32) {
    let (mut size, mut rank) = (0, 0);
    A::comm_size(A::comm_world(), &mut size);
    A::comm_rank(A::comm_world(), &mut rank);
    (size, rank)
}

/// All 3-element posting orders: position i gets receive-kind PERMS[p][i]
/// (0 = exact, 1 = ANY_SOURCE, 2 = ANY_TAG).
const PERMS: [[usize; 3]; 6] = [
    [0, 1, 2],
    [0, 2, 1],
    [1, 0, 2],
    [1, 2, 0],
    [2, 0, 1],
    [2, 1, 0],
];

/// Sender side of the channel-flush trick: a synchronous sentinel send
/// completes only when the receiver matched it — so everything sent
/// before it has, by per-pair FIFO, already been drained at the
/// receiver.
fn flush_sentinel_send<A: MpiAbi>(dest: i32, tag: i32) -> Result<(), String> {
    let dt = A::datatype(Dt::Int32);
    let one = [1i32];
    check_rc!(A::ssend(slice_ptr(&one), 1, dt, dest, tag, A::comm_world()), "sentinel ssend");
    Ok(())
}

/// Receiver side: matching the sentinel guarantees the sender's earlier
/// messages are all in the unexpected queues.
fn flush_sentinel_recv<A: MpiAbi>(src: i32, tag: i32) -> Result<(), String> {
    let dt = A::datatype(Dt::Int32);
    let mut got = [0i32];
    let mut st = A::status_empty();
    check_rc!(
        A::recv(slice_ptr_mut(&mut got), 1, dt, src, tag, A::comm_world(), &mut st),
        "sentinel recv"
    );
    check!(got[0] == 1, "sentinel payload");
    Ok(())
}

/// One sender, blocking receives: wildcard takes the earliest arrival,
/// exact skips past non-matching tags, and the leftover is picked up by
/// a source-exact ANY_TAG — regardless of how far the sender has
/// progressed when each receive is posted.
fn single_sender_fifo_wildcards<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    let world = A::comm_world();
    if me == 1 {
        for (v, tag) in [(501, 5), (502, 5), (503, 7)] {
            let v = [v];
            check_rc!(A::send(slice_ptr(&v), 1, dt, 0, tag, world), "send");
        }
    } else if me == 0 {
        let mut got = [0i32];
        let mut st = A::status_empty();
        // ANY/ANY: earliest message from rank 1 (per-pair FIFO ⇒ 501).
        check_rc!(
            A::recv(slice_ptr_mut(&mut got), 1, dt, A::any_source(), A::any_tag(), world, &mut st),
            "any/any recv"
        );
        check!(got[0] == 501, "wildcard takes earliest arrival, got {}", got[0]);
        check!(A::status_source(&st) == 1 && A::status_tag(&st) == 5, "status of 501");
        check!(A::get_count(&st, dt) == 1, "count of 501");
        // Exact tag 7 skips the still-queued 502.
        check_rc!(A::recv(slice_ptr_mut(&mut got), 1, dt, 1, 7, world, &mut st), "tag-7 recv");
        check!(got[0] == 503, "exact tag skips non-matching, got {}", got[0]);
        // Source-exact ANY_TAG picks up the leftover.
        check_rc!(
            A::recv(slice_ptr_mut(&mut got), 1, dt, 1, A::any_tag(), world, &mut st),
            "any-tag recv"
        );
        check!(got[0] == 502 && A::status_tag(&st) == 5, "leftover 502, got {}", got[0]);
    }
    Ok(())
}

/// Receives posted **before** the messages exist (the posted-side
/// index): in every interleaving of exact / ANY_SOURCE / ANY_TAG — all
/// matching the same (src, tag) stream — the i-th *posted* receive must
/// complete with the i-th *sent* message, whatever its wildcard kind.
fn posted_order_permutations<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    let world = A::comm_world();
    const TAG: i32 = 21;
    const GO: i32 = 91;
    for (p, perm) in PERMS.iter().enumerate() {
        if me == 0 {
            let mut bufs = [[0i32]; 3];
            let mut reqs = vec![A::request_null(); 3];
            // Post the three receives in this permutation's kind order.
            for (i, req) in reqs.iter_mut().enumerate() {
                let (src, tag) = match perm[i] {
                    0 => (1, TAG),
                    1 => (A::any_source(), TAG),
                    _ => (1, A::any_tag()),
                };
                check_rc!(
                    A::irecv(slice_ptr_mut(&mut bufs[i]), 1, dt, src, tag, world, req),
                    "irecv"
                );
            }
            // Only now release the sender.
            let go = [p as i32];
            check_rc!(A::send(slice_ptr(&go), 1, dt, 1, GO, world), "go send");
            let mut sts = vec![A::status_empty(); 3];
            check_rc!(A::waitall(&mut reqs, &mut sts), "waitall");
            for i in 0..3 {
                let want = (p * 10 + i) as i32;
                check!(
                    bufs[i][0] == want,
                    "perm {p}: posted[{i}] (kind {}) wanted {want}, got {}",
                    perm[i],
                    bufs[i][0]
                );
                check!(A::status_source(&sts[i]) == 1, "perm {p}: source of posted[{i}]");
                check!(A::status_tag(&sts[i]) == TAG, "perm {p}: tag of posted[{i}]");
            }
        } else if me == 1 {
            let mut go = [0i32];
            let mut st = A::status_empty();
            check_rc!(A::recv(slice_ptr_mut(&mut go), 1, dt, 0, GO, world, &mut st), "go recv");
            for i in 0..3 {
                let v = [(p * 10 + i) as i32];
                check_rc!(A::send(slice_ptr(&v), 1, dt, 0, TAG, world), "send");
            }
        }
    }
    Ok(())
}

/// Receives posted **after** the messages arrived (the unexpected-side
/// index): the sentinel flush guarantees all three messages are queued
/// unexpected, then every posting interleaving must still deliver in
/// arrival order.
fn unexpected_order_permutations<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    let world = A::comm_world();
    const TAG: i32 = 31;
    const FLUSH: i32 = 92;
    for (p, perm) in PERMS.iter().enumerate() {
        if me == 1 {
            for i in 0..3 {
                let v = [(p * 10 + i) as i32];
                check_rc!(A::send(slice_ptr(&v), 1, dt, 0, TAG, world), "send");
            }
            flush_sentinel_send::<A>(0, FLUSH)?;
        } else if me == 0 {
            flush_sentinel_recv::<A>(1, FLUSH)?;
            // All three messages are now unexpected; post in perm order.
            let mut bufs = [[0i32]; 3];
            let mut reqs = vec![A::request_null(); 3];
            for (i, req) in reqs.iter_mut().enumerate() {
                let (src, tag) = match perm[i] {
                    0 => (1, TAG),
                    1 => (A::any_source(), TAG),
                    _ => (1, A::any_tag()),
                };
                check_rc!(
                    A::irecv(slice_ptr_mut(&mut bufs[i]), 1, dt, src, tag, world, req),
                    "irecv"
                );
            }
            let mut sts = vec![A::status_empty(); 3];
            check_rc!(A::waitall(&mut reqs, &mut sts), "waitall");
            for i in 0..3 {
                let want = (p * 10 + i) as i32;
                check!(
                    bufs[i][0] == want,
                    "perm {p}: unexpected[{i}] (kind {}) wanted {want}, got {}",
                    perm[i],
                    bufs[i][0]
                );
            }
        }
    }
    Ok(())
}

/// Two context planes (world and a dup): wildcards never cross
/// contexts, and arrival order is tracked per plane. The sender
/// interleaves world and dup traffic; a sentinel flush makes all of it
/// unexpected before the receiver posts anything.
fn two_contexts_isolated<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    let world = A::comm_world();
    let mut dup = world;
    check_rc!(A::comm_dup(world, &mut dup), "comm_dup");
    let dt = A::datatype(Dt::Int32);
    let result = (|| -> Result<(), String> {
        if n < 2 {
            return Ok(());
        }
        const TAG: i32 = 3;
        const FLUSH: i32 = 93;
        if me == 1 {
            let v = [701i32];
            check_rc!(A::send(slice_ptr(&v), 1, dt, 0, TAG, world), "world send");
            let v = [702i32];
            check_rc!(A::send(slice_ptr(&v), 1, dt, 0, TAG, dup), "dup send");
            let v = [703i32];
            check_rc!(A::send(slice_ptr(&v), 1, dt, 0, 7, world), "world tag-7 send");
            flush_sentinel_send::<A>(0, FLUSH)?;
        } else if me == 0 {
            flush_sentinel_recv::<A>(1, FLUSH)?;
            let mut got = [0i32];
            let mut st = A::status_empty();
            // ANY/ANY on the dup must see only dup traffic.
            check_rc!(
                A::recv(slice_ptr_mut(&mut got), 1, dt, A::any_source(), A::any_tag(), dup, &mut st),
                "dup any/any"
            );
            check!(got[0] == 702, "dup wildcard sees only dup traffic, got {}", got[0]);
            // ANY/ANY on world: earliest *world* arrival (701, not 702/703).
            check_rc!(
                A::recv(
                    slice_ptr_mut(&mut got),
                    1,
                    dt,
                    A::any_source(),
                    A::any_tag(),
                    world,
                    &mut st
                ),
                "world any/any"
            );
            check!(got[0] == 701, "world wildcard takes earliest world arrival, got {}", got[0]);
            check!(A::status_tag(&st) == TAG, "world wildcard tag");
            check_rc!(
                A::recv(slice_ptr_mut(&mut got), 1, dt, 1, 7, world, &mut st),
                "world tag-7"
            );
            check!(got[0] == 703, "leftover world message, got {}", got[0]);
        }
        Ok(())
    })();
    check_rc!(A::comm_free(&mut dup), "comm_free");
    result
}

/// `MPI_ANY_SOURCE` against two concurrent senders: an exact-source
/// receive posted before a wildcard must end up with its source's
/// message whichever arrival order the transport produces, and the
/// wildcard takes the other.
fn any_source_two_senders<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 3 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    let world = A::comm_world();
    const TAG: i32 = 41;
    if me == 1 || me == 2 {
        let v = [100 + me];
        check_rc!(A::send(slice_ptr(&v), 1, dt, 0, TAG, world), "send");
    } else if me == 0 {
        let mut exact = [0i32];
        let mut any = [0i32];
        let mut reqs = vec![A::request_null(); 2];
        // Exact source 2 first, then the wildcard.
        check_rc!(A::irecv(slice_ptr_mut(&mut exact), 1, dt, 2, TAG, world, &mut reqs[0]), "irecv");
        check_rc!(
            A::irecv(slice_ptr_mut(&mut any), 1, dt, A::any_source(), TAG, world, &mut reqs[1]),
            "irecv any"
        );
        let mut sts = vec![A::status_empty(); 2];
        check_rc!(A::waitall(&mut reqs, &mut sts), "waitall");
        check!(exact[0] == 102, "exact recv pinned to source 2, got {}", exact[0]);
        check!(any[0] == 101, "wildcard got the remaining sender, got {}", any[0]);
        check!(A::status_source(&sts[0]) == 2, "exact status source");
        check!(A::status_source(&sts[1]) == 1, "wildcard status source");
    }
    Ok(())
}

/// Out-of-order tag arrivals against in-order exact receives: tags sent
/// 3,2,1 are received 1,2,3 via the exact buckets (each blocking recv
/// must skip everything queued before its match).
fn out_of_order_tags<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (n, me) = world_geometry::<A>();
    if n < 2 {
        return Ok(());
    }
    let dt = A::datatype(Dt::Int32);
    let world = A::comm_world();
    const FLUSH: i32 = 94;
    if me == 1 {
        for tag in [3, 2, 1] {
            let v = [800 + tag];
            check_rc!(A::send(slice_ptr(&v), 1, dt, 0, tag, world), "send");
        }
        flush_sentinel_send::<A>(0, FLUSH)?;
    } else if me == 0 {
        flush_sentinel_recv::<A>(1, FLUSH)?;
        for tag in [1, 2, 3] {
            let mut got = [0i32];
            let mut st = A::status_empty();
            check_rc!(A::recv(slice_ptr_mut(&mut got), 1, dt, 1, tag, world, &mut st), "recv");
            check!(got[0] == 800 + tag, "tag {tag} delivered its own message, got {}", got[0]);
            check!(A::status_tag(&st) == tag, "status tag {tag}");
        }
    }
    Ok(())
}
