//! The large-count (`MPI_Count`) battery, standalone: all five ABI
//! configurations × both transports (the ISSUE-6 acceptance grid).
//!
//! Two ranks per job: the batteries allocate sparse multi-GiB *virtual*
//! regions per rank (lazily committed), so the rank count — not the
//! logical transfer size — bounds resident memory.

use mpi_abi::api::MpiAbi;
use mpi_abi::core::transport::TransportKind;
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::launcher::{run_job_ok, JobSpec};
use mpi_abi::muk::{MukMpich, MukOmpi};
use mpi_abi::native_abi::NativeAbi;
use mpi_abi::testsuite;

fn run_battery<A: MpiAbi>(ranks: usize, transport: TransportKind) {
    let spec = JobSpec::new(ranks).with_transport(transport);
    let reports = run_job_ok(spec, |rank| {
        assert_eq!(A::init(), 0, "{} init", A::NAME);
        let results = testsuite::run_registry::<A>(rank, testsuite::bigcount_registry::<A>());
        let report = testsuite::report(A::NAME, &results);
        let failed = results.iter().filter(|r| !r.passed).count();
        assert_eq!(A::finalize(), 0, "{} finalize", A::NAME);
        (report, failed)
    });
    let (report, failures) = &reports[0];
    if *failures > 0 {
        panic!("[{} {:?}]\n{report}", A::NAME, transport);
    }
}

fn both_transports<A: MpiAbi>(ranks: usize) {
    run_battery::<A>(ranks, TransportKind::Spsc);
    run_battery::<A>(ranks, TransportKind::Mutex);
}

#[test]
fn bigcount_battery_mpich_native() {
    both_transports::<MpichAbi>(2);
}

#[test]
fn bigcount_battery_ompi_native() {
    both_transports::<OmpiAbi>(2);
}

#[test]
fn bigcount_battery_muk_over_mpich() {
    both_transports::<MukMpich>(2);
}

#[test]
fn bigcount_battery_muk_over_ompi() {
    both_transports::<MukOmpi>(2);
}

#[test]
fn bigcount_battery_native_standard_abi() {
    both_transports::<NativeAbi>(2);
}

/// Three ranks: the `MPI_Aint`-displacement allgatherv splits the
/// > 2 GiB span into two gaps and the middle rank lands between them.
#[test]
fn bigcount_battery_three_ranks() {
    run_battery::<NativeAbi>(3, TransportKind::Spsc);
}
