//! Requests, the request **lifecycle state machine**, and the progress
//! engine.
//!
//! Every nonblocking operation creates a request; blocking operations are
//! request + wait; persistent operations (`MPI_Send_init`,
//! `MPI_Recv_init`, the MPI-4 `*_init` collectives) create a request
//! *once* and re-arm it with `MPI_Start`. Progress is made inside
//! test/wait/recv loops (polling the fabric, matching posted receives
//! against arrivals, acking synchronous sends) — the single-threaded
//! progress model of most MPI implementations.
//!
//! # The lifecycle
//!
//! ```text
//!                    nonblocking path                persistent path
//!                    ----------------                ---------------
//!   isend/irecv ──► Active                *_init ──► Inactive ◄────────┐
//!                     │ op finishes                    │ MPI_Start     │
//!                     ▼                                ▼               │
//!                  Complete(status)                  Active            │
//!                     │ wait/test                      │ op finishes   │
//!                     ▼                                ▼               │
//!                  (freed)                           Complete(status)  │
//!                                                      │ wait/test ────┘
//!                                                      (request survives;
//!                                                       MPI_Request_free
//!                                                       only when Inactive)
//! ```
//!
//! The same three states drive every request kind; what differs is the
//! *re-arm recipe* ([`PersistSpec`]) a persistent request carries.
//! Schedule-backed (collective) requests keep their [`Schedule`] inside
//! [`ReqKind::Sched`] across restarts — `MPI_Start` resets and re-runs
//! it instead of rebuilding (see [`crate::core::collectives::sched`]).
//!
//! [`Schedule`]: crate::core::collectives::sched::Schedule

use super::transport::{Envelope, MsgKind, Payload};
use super::world::{with_ctx, RankCtx};
use super::{err, DtId, ReqId, RC};
use crate::abi::constants::MPI_PROC_NULL;

/// Implementation-independent status record. Each ABI converts this to its
/// own status layout — the translation the paper's §3.2 catalogues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StatusCore {
    /// World rank of the message source (or `MPI_PROC_NULL`).
    pub source: i32,
    /// Message tag.
    pub tag: i32,
    /// Canonical (standard-ABI) error class.
    pub error: i32,
    /// Received payload size in packed bytes.
    pub count_bytes: u64,
    /// `MPI_Test_cancelled` flag.
    pub cancelled: bool,
}

impl StatusCore {
    /// Status of a successfully matched receive.
    pub fn success(source: i32, tag: i32, count_bytes: u64) -> StatusCore {
        StatusCore { source, tag, error: 0, count_bytes, cancelled: false }
    }

    /// Status for a send completion or PROC_NULL op.
    pub fn empty() -> StatusCore {
        StatusCore {
            source: MPI_PROC_NULL,
            tag: crate::abi::constants::MPI_ANY_TAG,
            error: 0,
            count_bytes: 0,
            cancelled: false,
        }
    }
}

/// What a request is waiting for.
pub enum ReqKind {
    /// Eager send: complete at creation (buffer copied).
    Send,
    /// Synchronous send: complete when the ack for `sync_id` arrives.
    Ssend {
        /// Ack id the matching receive will echo back.
        sync_id: u64,
    },
    /// Posted receive.
    Recv {
        /// Destination buffer address.
        buf: usize,
        /// Element count.
        count: usize,
        /// Element datatype.
        dt: DtId,
        /// Matching source (world rank or `MPI_ANY_SOURCE`).
        src: i32,
        /// Matching tag (or `MPI_ANY_TAG`).
        tag: i32,
        /// Matching context plane.
        context: u32,
    },
    /// Nonblocking or persistent collective: a schedule advanced by the
    /// progress engine (see [`crate::core::collectives::sched`]).
    Sched(Box<crate::core::collectives::sched::Schedule>),
}

/// Lifecycle state of a request — see the module docs for the diagram.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReqState {
    /// Persistent request between starts (or before the first start).
    /// wait/test on an inactive request return immediately with an empty
    /// status (MPI 3.0 §3.7.3).
    Inactive,
    /// Operation in flight.
    Active,
    /// Operation finished; status not yet collected by wait/test.
    Complete(StatusCore),
}

/// The re-arm recipe of a persistent request: everything `MPI_Start`
/// needs to launch the operation again. Arguments were validated and
/// comm-resolved once, at `*_init` time — restarts skip straight to the
/// data path (the point of persistence).
#[derive(Clone, Copy, Debug)]
pub enum PersistSpec {
    /// `MPI_Send_init` / `MPI_Ssend_init`: each start re-packs the user
    /// buffer (picking up updated contents) and enqueues one envelope.
    Send {
        /// Source buffer address (re-read at every start).
        buf: usize,
        /// Element count.
        count: usize,
        /// Element datatype.
        dt: DtId,
        /// Destination world rank; `None` = `MPI_PROC_NULL` (each start
        /// completes immediately).
        dest_world: Option<usize>,
        /// Message tag.
        tag: i32,
        /// Pt2pt context plane of the communicator.
        context: u32,
        /// Synchronous mode (`MPI_Ssend_init`): active until acked.
        sync: bool,
    },
    /// `MPI_Recv_init`: each start re-posts the receive.
    Recv {
        /// Destination buffer address.
        buf: usize,
        /// Element count.
        count: usize,
        /// Element datatype.
        dt: DtId,
        /// Matching source: world rank, `MPI_ANY_SOURCE`, or
        /// `MPI_PROC_NULL` (start completes immediately).
        src: i32,
        /// Matching tag.
        tag: i32,
        /// Pt2pt context plane.
        context: u32,
    },
    /// Persistent collective: the [`Schedule`] living in this request's
    /// [`ReqKind::Sched`] is reset and re-armed by each start — reused,
    /// never rebuilt.
    ///
    /// [`Schedule`]: crate::core::collectives::sched::Schedule
    Coll,
}

/// One request-table entry: current kind, lifecycle state, and (for
/// persistent requests) the re-arm recipe.
pub struct RequestObj {
    /// What the request is currently doing (or armed to do).
    pub kind: ReqKind,
    /// Lifecycle state.
    pub state: ReqState,
    /// `Some` marks a persistent request; holds what `MPI_Start` re-arms.
    pub persist: Option<PersistSpec>,
}

/// Create a (nonpersistent) request in the table.
pub(crate) fn new_request(ctx: &RankCtx, kind: ReqKind, state: ReqState) -> ReqId {
    ReqId(ctx.tables.borrow_mut().reqs.insert(RequestObj { kind, state, persist: None }))
}

/// Create a persistent request in the table, born Inactive.
pub(crate) fn new_persistent(ctx: &RankCtx, kind: ReqKind, spec: PersistSpec) -> ReqId {
    ReqId(ctx.tables.borrow_mut().reqs.insert(RequestObj {
        kind,
        state: ReqState::Inactive,
        persist: Some(spec),
    }))
}

/// Post a receive request. The matching index either completes it on
/// the spot (a matching message already arrived) or files it for the
/// next arrival — there is no per-tick rescan (see
/// [`crate::core::match_index`]).
pub(crate) fn post_recv(
    ctx: &RankCtx,
    buf: usize,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    context: u32,
) -> ReqId {
    let id = new_request(ctx, ReqKind::Recv { buf, count, dt, src, tag, context }, ReqState::Active);
    let hit = ctx.state.borrow_mut().match_index.post(id, context, src, tag);
    if let Some(env) = hit {
        deliver(ctx, id, env);
    }
    id
}

/// Re-post an existing (persistent) receive request: set its armed kind,
/// mark Active, and hand it to the matching index.
pub(crate) fn repost_recv(
    ctx: &RankCtx,
    rid: ReqId,
    buf: usize,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    context: u32,
) {
    {
        let mut t = ctx.tables.borrow_mut();
        if let Some(req) = t.reqs.get_mut(rid.0) {
            req.kind = ReqKind::Recv { buf, count, dt, src, tag, context };
            req.state = ReqState::Active;
        }
    }
    let hit = ctx.state.borrow_mut().match_index.post(rid, context, src, tag);
    if let Some(env) = hit {
        deliver(ctx, rid, env);
    }
}

/// One progress cycle: flush deferred sends, drain the fabric (matching
/// every arrival as it lands), service one-sided traffic, then advance
/// every in-flight collective schedule.
pub(crate) fn progress(ctx: &RankCtx) {
    if let Some(code) = ctx.world.aborted() {
        std::panic::panic_any(super::world::AbortUnwind(code));
    }
    flush_pending_sends(ctx);
    drain_fabric(ctx);
    super::rma::progress_rma(ctx);
    super::collectives::sched::progress_scheds(ctx);
}

/// Retry deferred sends. Queues are keyed per destination: a
/// still-full ring parks only that destination's queue — traffic to
/// every other rank keeps flowing (no head-of-line blocking).
fn flush_pending_sends(ctx: &RankCtx) {
    let mut st = ctx.state.borrow_mut();
    if st.pending_sends.is_empty() {
        return;
    }
    let fabric = &ctx.world.fabric;
    st.pending_sends.retain(|&dst, q| {
        while let Some(env) = q.pop_front() {
            if let Err(env) = fabric.try_send(dst, env) {
                q.push_front(env);
                break; // this destination is still full; others continue
            }
        }
        !q.is_empty()
    });
}

/// Drain every inbound envelope and route it straight into the matching
/// index: an arrival that matches a posted receive is delivered
/// immediately; the rest are filed as unexpected (indexed by
/// `(context, src, tag)` for the O(1) exact-match lookup).
fn drain_fabric(ctx: &RankCtx) {
    if ctx.world.fabric.inbound_empty(ctx.rank) {
        return;
    }
    let mut inbox = std::mem::take(&mut ctx.state.borrow_mut().inbox);
    ctx.world.fabric.poll_into(ctx.rank, &mut inbox);
    for env in inbox.drain(..) {
        route_arrival(ctx, env);
    }
    ctx.state.borrow_mut().inbox = inbox;
}

/// Route one arrival: acks feed the Ssend ack set; data envelopes match
/// against the posted side or land in the unexpected index.
fn route_arrival(ctx: &RankCtx, env: Envelope) {
    let matched = {
        let mut st = ctx.state.borrow_mut();
        match env.kind {
            MsgKind::SsendAck => {
                st.ssend_acks.insert(env.seq);
                return;
            }
            MsgKind::Eager | MsgKind::EagerSync => st.match_index.arrive(env),
        }
    };
    if let Some((rid, env)) = matched {
        deliver(ctx, rid, env);
    }
}

/// Copy a matched message into the receive buffer and complete the request.
fn deliver(ctx: &RankCtx, rid: ReqId, env: Envelope) {
    let (buf, count, dt) = {
        let t = ctx.tables.borrow();
        let Some(req) = t.reqs.get(rid.0) else { return };
        let ReqKind::Recv { buf, count, dt, .. } = req.kind else { return };
        (buf, count, dt)
    };
    let status = deliver_inline(ctx, env, buf, count, dt);
    if let Some(req) = ctx.tables.borrow_mut().reqs.get_mut(rid.0) {
        req.state = ReqState::Complete(status);
    }
}

/// Unpack a matched envelope into a user buffer and build its status —
/// the shared tail of the request path ([`deliver`]) and the no-request
/// blocking-recv fast path ([`crate::core::engine`]). Also acks
/// synchronous sends (the message is matched the moment it is consumed).
pub(crate) fn deliver_inline(
    ctx: &RankCtx,
    env: Envelope,
    buf: usize,
    count: usize,
    dt: DtId,
) -> StatusCore {
    let status = {
        let t = ctx.tables.borrow();
        let data = env.payload.as_slice();
        // Capacity in packed bytes of the posted buffer.
        let cap = t.dtypes.get(dt.0).map(|o| o.size * count).unwrap_or(0);
        let truncated = data.len() > cap;
        let take = data.len().min(cap);
        let consumed =
            super::datatype::pack::unpack(&t.dtypes, &data[..take], buf as *mut u8, count, dt)
                .unwrap_or(0);
        let mut status = StatusCore::success(env.src as i32, env.tag, consumed as u64);
        if truncated {
            status.error = crate::abi::errors::MPI_ERR_TRUNCATE;
        }
        status
    };
    // Ack synchronous sends now that the message is matched.
    if env.kind == MsgKind::EagerSync {
        let ack = Envelope {
            src: ctx.rank as u32,
            context: env.context,
            tag: env.tag,
            kind: MsgKind::SsendAck,
            seq: env.seq,
            payload: Payload::empty(),
        };
        enqueue_send(ctx, env.src as usize, ack);
    }
    status
}

/// Send an envelope, preserving per-destination FIFO even under
/// backpressure (a destination's deferred envelopes drain before new
/// ones to it; other destinations are unaffected).
pub(crate) fn enqueue_send(ctx: &RankCtx, dst: usize, env: Envelope) {
    let mut st = ctx.state.borrow_mut();
    if let Some(q) = st.pending_sends.get_mut(&dst) {
        // Deferred traffic to this destination exists: queue behind it.
        q.push_back(env);
        return;
    }
    if let Err(env) = ctx.world.fabric.try_send(dst, env) {
        let mut q = std::collections::VecDeque::with_capacity(4);
        q.push_back(env);
        st.pending_sends.insert(dst, q);
    }
}

/// Poll a request's completion state; applies one progress cycle first.
pub(crate) fn poll_complete(ctx: &RankCtx, rid: ReqId) -> RC<Option<StatusCore>> {
    progress(ctx);
    finish_if_done(ctx, rid)
}

/// Check (without progressing) whether `rid` is complete, resolving
/// Ssend acks. Schedule-backed (collective) requests complete inside
/// [`progress`] — here they are simply pending until their status lands.
/// Inactive persistent requests count as complete with an empty status
/// (MPI 3.0 §3.7.3: wait on an inactive request returns immediately).
pub(crate) fn finish_if_done(ctx: &RankCtx, rid: ReqId) -> RC<Option<StatusCore>> {
    enum Next {
        Done(StatusCore),
        Pending,
        CheckSsend(u64),
    }
    let next = {
        let t = ctx.tables.borrow();
        let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
        match (&req.state, &req.kind) {
            (ReqState::Complete(s), _) => Next::Done(*s),
            (ReqState::Inactive, _) => Next::Done(StatusCore::empty()),
            (ReqState::Active, ReqKind::Ssend { sync_id }) => Next::CheckSsend(*sync_id),
            (ReqState::Active, _) => Next::Pending,
        }
    };
    match next {
        Next::Done(s) => Ok(Some(s)),
        Next::Pending => Ok(None),
        Next::CheckSsend(sync_id) => {
            let acked = ctx.state.borrow_mut().ssend_acks.remove(&sync_id);
            if acked {
                let s = StatusCore::empty();
                ctx.tables.borrow_mut().reqs.get_mut(rid.0).unwrap().state =
                    ReqState::Complete(s);
                Ok(Some(s))
            } else {
                Ok(None)
            }
        }
    }
}

/// Consume a completed request in wait/test: persistent requests return
/// to Inactive and stay in the table (the lifecycle's back edge);
/// nonpersistent requests are deallocated.
pub(crate) fn retire(ctx: &RankCtx, rid: ReqId) {
    let mut t = ctx.tables.borrow_mut();
    let persistent = t.reqs.get(rid.0).map(|r| r.persist.is_some()).unwrap_or(false);
    if persistent {
        if let Some(req) = t.reqs.get_mut(rid.0) {
            req.state = ReqState::Inactive;
        }
    } else {
        t.reqs.remove(rid.0);
    }
}

/// Whether `rid` names a persistent request (ABI layers use this to keep
/// the user's handle valid across wait/test instead of nulling it).
pub(crate) fn is_persistent(ctx: &RankCtx, rid: ReqId) -> bool {
    ctx.tables.borrow().reqs.get(rid.0).map(|r| r.persist.is_some()).unwrap_or(false)
}

/// Whether `rid` is an Inactive persistent request. Waitany/testany must
/// *ignore* inactive handles rather than report them complete (MPI 3.0
/// §3.7.5 — only wait/test/waitall return empty statuses for them).
pub(crate) fn is_inactive(ctx: &RankCtx, rid: ReqId) -> RC<bool> {
    let t = ctx.tables.borrow();
    let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
    Ok(req.state == ReqState::Inactive)
}

/// Block until `rid` completes; retire it; return its status.
pub(crate) fn wait_one(ctx: &RankCtx, rid: ReqId) -> RC<StatusCore> {
    loop {
        if let Some(s) = poll_complete(ctx, rid)? {
            retire(ctx, rid);
            return Ok(s);
        }
        std::thread::yield_now();
    }
}

/// Nonblocking completion check; retires on completion (`MPI_Test`).
pub(crate) fn test_one(ctx: &RankCtx, rid: ReqId) -> RC<Option<StatusCore>> {
    match poll_complete(ctx, rid)? {
        Some(s) => {
            retire(ctx, rid);
            Ok(Some(s))
        }
        None => Ok(None),
    }
}

/// `MPI_Cancel` — supported for unmatched receives (marks cancelled).
pub fn cancel(rid: ReqId) -> RC<()> {
    with_ctx(|ctx| {
        let is_recv_pending = {
            let t = ctx.tables.borrow();
            let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
            matches!(req.kind, ReqKind::Recv { .. }) && req.state == ReqState::Active
        };
        if is_recv_pending {
            ctx.state.borrow_mut().match_index.withdraw(rid);
            let mut t = ctx.tables.borrow_mut();
            let req = t.reqs.get_mut(rid.0).unwrap();
            let mut s = StatusCore::empty();
            s.cancelled = true;
            req.state = ReqState::Complete(s);
        }
        // Sends: cancel is best-effort; eager sends already completed.
        Ok(())
    })
}

/// `MPI_Request_free`.
///
/// Freeing an *active* schedule-backed request is rejected (dropping the
/// schedule would strand its unexecuted send steps and deadlock peers),
/// as is freeing a persistent request that is not Inactive — a started
/// persistent request stays "in use" until wait/test collects it, even
/// if the operation already finished internally (MPI-4 §3.9). **Inactive
/// persistent requests free cleanly** — including persistent
/// collectives, whose retained schedule is simply dropped with the
/// request.
pub fn request_free(rid: ReqId) -> RC<()> {
    with_ctx(|ctx| {
        let withdraw = {
            let t = ctx.tables.borrow();
            let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
            let active = req.state == ReqState::Active;
            if req.persist.is_some() && req.state != ReqState::Inactive {
                return Err(err!(MPI_ERR_REQUEST));
            }
            if active && matches!(req.kind, ReqKind::Sched(_)) {
                return Err(err!(MPI_ERR_REQUEST));
            }
            active && matches!(req.kind, ReqKind::Recv { .. })
        };
        // Freeing a still-posted receive: withdraw it from the matching
        // engine first, so the freed slot can be recycled without a stale
        // posted entry matching a foreign message into it.
        if withdraw {
            ctx.state.borrow_mut().match_index.withdraw(rid);
        }
        ctx.tables.borrow_mut().reqs.remove(rid.0).map(|_| ()).ok_or(err!(MPI_ERR_REQUEST))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::transport::{MsgKind, Payload, SPSC_CAPACITY};
    use crate::core::world::{bind_rank, test_world, unbind_rank};

    fn env(tag: i32) -> Envelope {
        Envelope {
            src: 0,
            context: 0,
            tag,
            kind: MsgKind::Eager,
            seq: 0,
            payload: Payload::empty(),
        }
    }

    /// Deterministic pin of the head-of-line-blocking fix: with *both*
    /// destination rings full and envelopes parked for each, draining
    /// ring 0→2 alone must let dst-2's deferred envelopes flow on the
    /// next flush even though dst-1's stay stuck. (The seed's single
    /// flush queue stopped at the first full destination, so dst-2
    /// traffic parked behind dst-1 entries never moved.)
    #[test]
    fn flush_is_keyed_per_destination() {
        std::thread::spawn(|| {
            let w = test_world(3);
            let ctx = bind_rank(w, 0);
            for _ in 0..SPSC_CAPACITY + 2 {
                enqueue_send(&ctx, 1, env(4));
                enqueue_send(&ctx, 2, env(6));
            }
            {
                let st = ctx.state.borrow();
                assert_eq!(st.pending_sends.get(&1).map(|q| q.len()), Some(2));
                assert_eq!(st.pending_sends.get(&2).map(|q| q.len()), Some(2));
            }
            // Play rank 2's role (single-threaded test): drain its ring.
            let mut sink = Vec::new();
            ctx.world.fabric.poll_into(2, &mut sink);
            assert_eq!(sink.len(), SPSC_CAPACITY);
            flush_pending_sends(&ctx);
            {
                let st = ctx.state.borrow();
                assert!(st.pending_sends.get(&2).is_none(), "dst-2 queue must drain");
                assert_eq!(
                    st.pending_sends.get(&1).map(|q| q.len()),
                    Some(2),
                    "dst-1 still parked (its ring is still full)"
                );
            }
            unbind_rank();
        })
        .join()
        .unwrap();
    }

    /// A send to a destination with parked traffic queues behind it
    /// (per-destination FIFO); sends to other destinations go straight
    /// to the fabric.
    #[test]
    fn enqueue_bypasses_other_destinations_backpressure() {
        std::thread::spawn(|| {
            let w = test_world(3);
            let ctx = bind_rank(w, 0);
            for _ in 0..SPSC_CAPACITY + 1 {
                enqueue_send(&ctx, 1, env(4));
            }
            enqueue_send(&ctx, 2, env(6));
            {
                let st = ctx.state.borrow();
                assert_eq!(st.pending_sends.get(&1).map(|q| q.len()), Some(1));
                assert!(st.pending_sends.get(&2).is_none(), "dst 2 must not be parked");
            }
            assert!(!ctx.world.fabric.inbound_empty(2), "dst-2 envelope reached the fabric");
            unbind_rank();
        })
        .join()
        .unwrap();
    }
}
