//! Mutex-guarded MPSC queue — the "slower shared-memory build" transport.
//!
//! Table 1's point is that *transport* choice (UCX vs OFI shm) moves the
//! message rate far more than any ABI decision. This queue models the slow
//! side: every enqueue takes a lock shared by all senders to one rank, and
//! the receiver takes the same lock to drain.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::envelope::Envelope;

/// One inbound queue per rank; all peers contend on the same mutex.
pub struct MutexQueue {
    q: Mutex<VecDeque<Envelope>>,
}

impl MutexQueue {
    /// Create an empty queue.
    pub fn new() -> MutexQueue {
        MutexQueue { q: Mutex::new(VecDeque::new()) }
    }

    /// Enqueue (any sender thread). Unbounded: the lock itself is the
    /// backpressure in this transport model.
    ///
    /// Models the OFI-shm protocol's bounce buffer: the payload takes an
    /// extra staging copy through a heap buffer before landing in the
    /// queue (the copy the UCX fast path avoids). On multi-core hosts the
    /// shared lock adds contention on top.
    #[inline]
    pub fn push(&self, mut env: Envelope) {
        let staged = env.payload.as_slice().to_vec();
        env.payload = super::envelope::Payload::from_vec(staged);
        self.q.lock().unwrap().push_back(env);
    }

    /// Dequeue the oldest message (receiver thread).
    #[inline]
    pub fn pop(&self) -> Option<Envelope> {
        self.q.lock().unwrap().pop_front()
    }

    /// Drain everything currently queued into `out` (receiver thread).
    /// One lock acquisition per progress poll instead of per message.
    #[inline]
    pub fn drain_into(&self, out: &mut Vec<Envelope>) {
        let mut g = self.q.lock().unwrap();
        out.extend(g.drain(..));
    }

    /// `true` if nothing is queued (takes the lock).
    pub fn is_empty(&self) -> bool {
        self.q.lock().unwrap().is_empty()
    }
}

impl Default for MutexQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::transport::envelope::{MsgKind, Payload};

    fn env(src: u32, tag: i32) -> Envelope {
        Envelope { src, context: 0, tag, kind: MsgKind::Eager, seq: 0, payload: Payload::empty() }
    }

    #[test]
    fn fifo() {
        let q = MutexQueue::new();
        q.push(env(0, 1));
        q.push(env(0, 2));
        assert_eq!(q.pop().unwrap().tag, 1);
        assert_eq!(q.pop().unwrap().tag, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn drain_preserves_order() {
        let q = MutexQueue::new();
        for t in 0..10 {
            q.push(env(1, t));
        }
        let mut out = Vec::new();
        q.drain_into(&mut out);
        assert_eq!(out.len(), 10);
        for (i, e) in out.iter().enumerate() {
            assert_eq!(e.tag, i as i32);
        }
        assert!(q.is_empty());
    }

    #[test]
    fn multi_producer() {
        let q = std::sync::Arc::new(MutexQueue::new());
        let mut handles = Vec::new();
        for src in 0..4u32 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for t in 0..100 {
                    q.push(env(src, t));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut count = 0;
        let mut last_tag_per_src = [-1i32; 4];
        while let Some(e) = q.pop() {
            // Per-producer FIFO must hold even under interleaving.
            assert!(e.tag > last_tag_per_src[e.src as usize]);
            last_tag_per_src[e.src as usize] = e.tag;
            count += 1;
        }
        assert_eq!(count, 400);
    }
}
