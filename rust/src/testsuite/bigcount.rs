//! Large-count (`MPI_Count` / "embiggened") tests: the `_c` family of
//! MPI-4 must round-trip counts and displacements beyond `INT_MAX`
//! through every ABI layer, while the classic `int`-count surface
//! reports `MPI_UNDEFINED` rather than silently truncating
//! (MPI-4.1 §3.2.5).
//!
//! Transfers with a *logical* payload or extent beyond 2 GiB are built
//! from sparse/strided derived types over lazily-committed zeroed
//! allocations, so the battery runs under bounded resident memory. If
//! the allocator cannot provide the (virtual) region, the test skips
//! gracefully instead of failing the suite.

use super::util::*;
use super::TestFn;
use crate::abi::types::{Aint, Count};
use crate::api::{Counts, Displs, Dt, MpiAbi};
use std::alloc::{alloc_zeroed, dealloc, Layout};

pub fn tests<A: MpiAbi>() -> Vec<(&'static str, TestFn)> {
    vec![
        ("bigcount.type_size_c_builtin", type_size_c_builtin::<A>),
        ("bigcount.type_contiguous_c_beyond_int_max", type_contiguous_c_beyond_int_max::<A>),
        ("bigcount.get_count_c_roundtrip_above_int_max", get_count_c_roundtrip::<A>),
        ("bigcount.classic_get_count_overflow_undefined", classic_get_count_undefined::<A>),
        ("bigcount.sparse_vector_2gib_logical_extent", sparse_vector_2gib::<A>),
        ("bigcount.allgatherv_c_aint_displs_beyond_2gib", allgatherv_c_wide_displs::<A>),
        ("bigcount.negative_counts_rejected", negative_counts_rejected::<A>),
    ]
}

/// A zeroed allocation that is virtual until written (calloc-style), so
/// multi-GiB *logical* regions cost only the pages actually touched.
/// `None` = allocator refused; callers skip rather than fail.
struct SparseBuf {
    ptr: *mut u8,
    layout: Layout,
}

impl SparseBuf {
    fn new(len: usize) -> Option<SparseBuf> {
        let layout = Layout::from_size_align(len, 8).ok()?;
        // SAFETY: layout has nonzero size for every caller below.
        let ptr = unsafe { alloc_zeroed(layout) };
        if ptr.is_null() {
            return None;
        }
        Some(SparseBuf { ptr, layout })
    }
}

impl Drop for SparseBuf {
    fn drop(&mut self) {
        // SAFETY: ptr/layout are exactly what alloc_zeroed returned.
        unsafe { dealloc(self.ptr, self.layout) };
    }
}

fn type_size_c_builtin<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let mut out: Count = -1;
    check_rc!(A::type_size_c(A::datatype(Dt::Int), &mut out), "Type_size_c");
    check!(out == 4, "int size_c 4, got {out}");
    let mut out: Count = -1;
    check_rc!(A::type_size_c(A::datatype(Dt::Double), &mut out), "Type_size_c");
    check!(out == 8, "double size_c 8, got {out}");
    Ok(())
}

/// A contiguous type of more than `INT_MAX` ints: constructible only
/// through the `_c` constructor, and its size is reportable only
/// through `type_size_c` (the classic query would need > 2^31 bytes).
fn type_contiguous_c_beyond_int_max<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let n: Count = (i32::MAX as Count) + 5; // 2^31 + 4 ints
    let mut t = A::datatype(Dt::Byte);
    check_rc!(A::type_contiguous_c(n, A::datatype(Dt::Int32), &mut t), "Type_contiguous_c");
    check_rc!(A::type_commit(&mut t), "commit");
    let mut size: Count = 0;
    check_rc!(A::type_size_c(t, &mut size), "Type_size_c");
    check!(size == n * 4, "size_c {} = 4 x (INT_MAX+5), got {size}", n * 4);
    check_rc!(A::type_free(&mut t), "free");
    Ok(())
}

/// `MPI_Status_set_elements_c` + `MPI_Get_count_c`: a synthesized
/// status carrying more than `INT_MAX` elements round-trips losslessly
/// through the wide accessors on every config — no multi-GiB transfer
/// needed to prove the 64-bit path.
fn get_count_c_roundtrip<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let byte = A::datatype(Dt::Byte);
    let n: Count = 3_000_000_000; // > 2^31 - 1
    let mut st = A::status_empty();
    check_rc!(A::status_set_elements_c(&mut st, byte, n), "Status_set_elements_c");
    let mut out: Count = 0;
    check_rc!(A::get_count_c(&st, byte, &mut out), "Get_count_c");
    check!(out == n, "count_c round-trip: want {n}, got {out}");
    let mut out: Count = 0;
    check_rc!(A::get_elements_c(&st, byte, &mut out), "Get_elements_c");
    check!(out == n, "elements_c round-trip: want {n}, got {out}");
    Ok(())
}

/// The classic `MPI_Get_count` must report `MPI_UNDEFINED` — not a
/// truncated value — when the true count exceeds `INT_MAX`
/// (MPI-4.1 §3.2.5), while `MPI_Get_count_c` on the same status stays
/// exact.
fn classic_get_count_undefined<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let int32 = A::datatype(Dt::Int32);
    let n: Count = (i32::MAX as Count) + 10;
    let mut st = A::status_empty();
    check_rc!(A::status_set_elements_c(&mut st, int32, n), "Status_set_elements_c");
    let classic = A::get_count(&st, int32);
    check!(classic == A::undefined(), "count > INT_MAX must be MPI_UNDEFINED, got {classic}");
    let mut wide: Count = 0;
    check_rc!(A::get_count_c(&st, int32, &mut wide), "Get_count_c");
    check!(wide == n, "wide count stays exact: want {n}, got {wide}");
    // An exactly-representable count still works through the classic
    // accessor (the guard must not over-fire).
    let mut st = A::status_empty();
    check_rc!(A::status_set_elements_c(&mut st, int32, 123), "Status_set_elements_c");
    check!(A::get_count(&st, int32) == 123, "small count still exact");
    Ok(())
}

/// Send one item of a strided vector type whose extent spans > 2 GiB of
/// logical address space, from a lazily-committed sparse buffer: only
/// the 40 000 one-byte blocks are real. The packed wire payload is
/// 40 000 bytes; resident memory stays bounded by the touched pages,
/// not the extent.
fn sparse_vector_2gib<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    if n < 2 {
        return Ok(());
    }
    const BLOCKS: usize = 40_000;
    const STRIDE: usize = 65_536;
    // Extent = (BLOCKS-1)*STRIDE + 1 ≈ 2.62e9 bytes > 2 GiB.
    const EXTENT: usize = (BLOCKS - 1) * STRIDE + 1;
    let byte = A::datatype(Dt::Byte);
    let mut vec_t = A::datatype(Dt::Byte);
    check_rc!(
        A::type_vector_c(BLOCKS as Count, 1, STRIDE as Count, byte, &mut vec_t),
        "Type_vector_c"
    );
    check_rc!(A::type_commit(&mut vec_t), "commit");
    let mut size: Count = 0;
    check_rc!(A::type_size_c(vec_t, &mut size), "Type_size_c");
    check!(size == BLOCKS as Count, "vector packs {BLOCKS} bytes, got {size}");

    if me == 0 {
        match SparseBuf::new(EXTENT) {
            Some(b) => {
                for i in 0..BLOCKS {
                    // SAFETY: i*STRIDE < EXTENT by construction.
                    unsafe { *b.ptr.add(i * STRIDE) = (i % 251) as u8 };
                }
                check_rc!(
                    A::send_c(b.ptr, 1, vec_t, 1, 40, A::comm_world()),
                    "send_c sparse vector"
                );
            }
            None => {
                // Allocator refused the virtual region: tell the peer
                // to skip (zero-byte message) rather than deadlock it.
                check_rc!(A::send_c(std::ptr::null(), 0, byte, 1, 40, A::comm_world()), "skip");
            }
        }
    } else if me == 1 {
        let mut rbuf = vec![0u8; BLOCKS];
        let mut st = A::status_empty();
        check_rc!(
            A::recv_c(rbuf.as_mut_ptr(), BLOCKS as Count, byte, 0, 40, A::comm_world(), &mut st),
            "recv_c"
        );
        let mut got: Count = 0;
        check_rc!(A::get_count_c(&st, byte, &mut got), "Get_count_c");
        if got == BLOCKS as Count {
            for (i, &v) in rbuf.iter().enumerate() {
                check!(v == (i % 251) as u8, "block {i}: got {v}");
            }
        } else {
            check!(got == 0, "either full transfer or sender-side skip, got {got}");
        }
    }
    check_rc!(A::type_free(&mut vec_t), "free");
    Ok(())
}

/// `MPI_Allgatherv_c` with `MPI_Aint` displacements: the last rank's
/// block lands beyond 2 GiB into the receive buffer — unreachable
/// through the classic `int` displacement array. The receive buffer is
/// a sparse zeroed region, so only the landed blocks are resident.
fn allgatherv_c_wide_displs<A: MpiAbi>(_r: usize) -> Result<(), String> {
    let (mut n, mut me) = (0, 0);
    A::comm_size(A::comm_world(), &mut n);
    A::comm_rank(A::comm_world(), &mut me);
    let n = n as usize;
    const BLK: usize = 1024;
    const TOP: usize = 2_200_000_000; // last block's byte offset, > 2 GiB
    let byte = A::datatype(Dt::Byte);
    let sbuf: Vec<u8> = (0..BLK).map(|i| ((me as usize) * 7 + i) as u8).collect();
    let counts: Vec<Count> = vec![BLK as Count; n];
    let displs: Vec<Aint> =
        (0..n).map(|r| if n == 1 { 0 } else { (r * (TOP / (n - 1))) as Aint }).collect();
    let rbuf = match SparseBuf::new(TOP + BLK) {
        Some(b) => b,
        None => return Ok(()), // can't get the virtual region: skip
    };
    check_rc!(
        A::allgatherv_c(
            sbuf.as_ptr(),
            BLK as Count,
            byte,
            rbuf.ptr,
            Counts::Count(&counts),
            Displs::Aint(&displs),
            byte,
            A::comm_world(),
        ),
        "Allgatherv_c"
    );
    for r in 0..n {
        let base = displs[r] as usize;
        for i in (0..BLK).step_by(97) {
            // SAFETY: base + i <= TOP + BLK - 1, inside the allocation.
            let got = unsafe { *rbuf.ptr.add(base + i) };
            let want = (r * 7 + i) as u8;
            check!(got == want, "rank {r} block byte {i}: got {got}, want {want}");
        }
    }
    check!(
        displs[n - 1] as usize >= 2 * 1024 * 1024 * 1024 || n == 1,
        "test must place the last block beyond 2 GiB"
    );
    Ok(())
}

/// Negative `MPI_Count` arguments are rejected with an error class, on
/// every layer (the muk WRAP layer validates before crossing the
/// vtable).
fn negative_counts_rejected<A: MpiAbi>(_r: usize) -> Result<(), String> {
    check_rc!(A::comm_set_errhandler(A::comm_world(), A::errhandler_return()), "errh");
    let int = A::datatype(Dt::Int);
    let mut t = A::datatype(Dt::Byte);
    check!(A::type_contiguous_c(-1, int, &mut t) != 0, "Type_contiguous_c(-1) must fail");
    check!(A::type_vector_c(-2, 1, 1, int, &mut t) != 0, "Type_vector_c(-2) must fail");
    let mut st = A::status_empty();
    check!(A::status_set_elements_c(&mut st, int, -3) != 0, "Status_set_elements_c(-3) must fail");
    let mut b = [0u8; 4];
    check!(A::send_c(b.as_ptr(), -1, int, 0, 41, A::comm_world()) != 0, "send_c(-1) must fail");
    let mut st = A::status_empty();
    check!(
        A::recv_c(b.as_mut_ptr(), -1, int, 0, 41, A::comm_world(), &mut st) != 0,
        "recv_c(-1) must fail"
    );
    check_rc!(A::comm_set_errhandler(A::comm_world(), A::errhandler_fatal()), "errh restore");
    check_rc!(A::barrier(A::comm_world()), "resync");
    Ok(())
}
