//! Gather/scatter/allgather collectives (linear, root-rooted; allgather
//! adds a broadcast phase).

use super::{bcast_bytes_cc, cc_clone, coll_begin, coll_recv, coll_send, CollCtx};
use crate::core::datatype::pack::{pack, unpack};
use crate::core::transport::Payload;
use crate::core::world::{with_ctx, RankCtx};
use crate::core::{err, CommId, DtId, RC};

fn in_place(p: *const u8) -> bool {
    p as usize == crate::abi::constants::MPI_IN_PLACE
}

fn pack_user(ctx: &RankCtx, buf: *const u8, count: usize, dt: DtId) -> RC<Vec<u8>> {
    let t = ctx.tables.borrow();
    let mut v = Vec::new();
    pack(&t.dtypes, buf, count, dt, &mut v)?;
    Ok(v)
}

fn unpack_at(
    ctx: &RankCtx,
    data: &[u8],
    buf: *mut u8,
    elem_offset: isize,
    count: usize,
    dt: DtId,
) -> RC<()> {
    let t = ctx.tables.borrow();
    let extent = t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?.extent;
    let dst = unsafe { buf.offset(extent * elem_offset) };
    unpack(&t.dtypes, data, dst, count, dt)?;
    Ok(())
}

fn pack_at(
    ctx: &RankCtx,
    buf: *const u8,
    elem_offset: isize,
    count: usize,
    dt: DtId,
) -> RC<Vec<u8>> {
    let t = ctx.tables.borrow();
    let extent = t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?.extent;
    let src = unsafe { buf.offset(extent * elem_offset) };
    let mut v = Vec::new();
    pack(&t.dtypes, src, count, dt, &mut v)?;
    Ok(v)
}

/// Core rooted gather with per-rank counts/displacements (in recvtype
/// extents). `counts.len() == size`.
#[allow(clippy::too_many_arguments)]
fn gatherv_cc(
    ctx: &RankCtx,
    cc: &CollCtx,
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    counts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    root: usize,
) -> RC<()> {
    if cc.my_rank == root {
        for r in 0..cc.size() {
            if r == root {
                if !in_place(sendbuf) {
                    let own = pack_user(ctx, sendbuf, sendcount, sendtype)?;
                    unpack_at(ctx, &own, recvbuf, displs[r], counts[r], recvtype)?;
                }
                continue;
            }
            let p = coll_recv(ctx, cc, r);
            unpack_at(ctx, p.as_slice(), recvbuf, displs[r], counts[r], recvtype)?;
        }
    } else {
        let bytes = pack_user(ctx, sendbuf, sendcount, sendtype)?;
        coll_send(ctx, cc, root, Payload::from_vec(bytes));
    }
    Ok(())
}

/// `MPI_Gather`.
#[allow(clippy::too_many_arguments)]
pub fn gather(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        if root < 0 || root as usize >= cc.size() {
            return Err(err!(MPI_ERR_ROOT));
        }
        let n = cc.size();
        let counts = vec![recvcount; n];
        let displs: Vec<isize> = (0..n).map(|r| (r * recvcount) as isize).collect();
        gatherv_cc(
            ctx, &cc, sendbuf, sendcount, sendtype, recvbuf, &counts, &displs, recvtype,
            root as usize,
        )
    })
}

/// `MPI_Gatherv` (displacements in recvtype extents).
#[allow(clippy::too_many_arguments)]
pub fn gatherv(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        if root < 0 || root as usize >= cc.size() {
            return Err(err!(MPI_ERR_ROOT));
        }
        gatherv_cc(
            ctx, &cc, sendbuf, sendcount, sendtype, recvbuf, recvcounts, displs, recvtype,
            root as usize,
        )
    })
}

/// `MPI_Scatter`.
#[allow(clippy::too_many_arguments)]
pub fn scatter(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    let n_counts;
    {
        n_counts = crate::core::comm::comm_size(comm)? as usize;
    }
    let counts = vec![sendcount; n_counts];
    let displs: Vec<isize> = (0..n_counts).map(|r| (r * sendcount) as isize).collect();
    scatterv(sendbuf, &counts, &displs, sendtype, recvbuf, recvcount, recvtype, root, comm)
}

/// `MPI_Scatterv` (displacements in sendtype extents).
#[allow(clippy::too_many_arguments)]
pub fn scatterv(
    sendbuf: *const u8,
    sendcounts: &[usize],
    displs: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        if root < 0 || root as usize >= cc.size() {
            return Err(err!(MPI_ERR_ROOT));
        }
        let root = root as usize;
        if cc.my_rank == root {
            for r in 0..cc.size() {
                if r == root {
                    if !in_place(recvbuf as *const u8) {
                        let own = pack_at(ctx, sendbuf, displs[r], sendcounts[r], sendtype)?;
                        let t = ctx.tables.borrow();
                        unpack(&t.dtypes, &own, recvbuf, recvcount, recvtype)?;
                    }
                    continue;
                }
                let bytes = pack_at(ctx, sendbuf, displs[r], sendcounts[r], sendtype)?;
                coll_send(ctx, &cc, r, Payload::from_vec(bytes));
            }
        } else {
            let p = coll_recv(ctx, &cc, root);
            let t = ctx.tables.borrow();
            unpack(&t.dtypes, p.as_slice(), recvbuf, recvcount, recvtype)?;
        }
        Ok(())
    })
}

/// `MPI_Allgather` (gather at 0, broadcast — two phases).
#[allow(clippy::too_many_arguments)]
pub fn allgather(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    let n = crate::core::comm::comm_size(comm)? as usize;
    let counts = vec![recvcount; n];
    let displs: Vec<isize> = (0..n).map(|r| (r * recvcount) as isize).collect();
    allgatherv(sendbuf, sendcount, sendtype, recvbuf, &counts, &displs, recvtype, comm)
}

/// `MPI_Allgatherv`.
#[allow(clippy::too_many_arguments)]
pub fn allgatherv(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        // For MPI_IN_PLACE the contribution is this rank's block of recvbuf.
        let (sb, sc, st);
        if in_place(sendbuf) {
            sb = {
                let t = ctx.tables.borrow();
                let ext = t.dtypes.get(recvtype.0).ok_or(err!(MPI_ERR_TYPE))?.extent;
                unsafe { (recvbuf as *const u8).offset(ext * displs[cc.my_rank]) }
            };
            sc = recvcounts[cc.my_rank];
            st = recvtype;
        } else {
            sb = sendbuf;
            sc = sendcount;
            st = sendtype;
        }
        gatherv_cc(ctx, &cc, sb, sc, st, recvbuf, recvcounts, displs, recvtype, 0)?;
        // Broadcast the fully-gathered packed buffer from 0 (phase 1).
        let total: usize = recvcounts.iter().sum();
        let mut bytes = if cc.my_rank == 0 {
            // Repack from recvbuf blocks so displaced layouts transmit
            // contiguously.
            let mut v = Vec::new();
            for r in 0..cc.size() {
                let b = pack_at(ctx, recvbuf as *const u8, displs[r], recvcounts[r], recvtype)?;
                v.extend_from_slice(&b);
            }
            v
        } else {
            let t = ctx.tables.borrow();
            let per = t.dtypes.get(recvtype.0).ok_or(err!(MPI_ERR_TYPE))?.size;
            vec![0u8; per * total]
        };
        let bc = CollCtx { tag: cc.tag + 1, ..cc_clone(&cc) };
        bcast_bytes_cc(ctx, &bc, &mut bytes, 0);
        if cc.my_rank != 0 {
            let mut off = 0usize;
            let per = {
                let t = ctx.tables.borrow();
                t.dtypes.get(recvtype.0).ok_or(err!(MPI_ERR_TYPE))?.size
            };
            for r in 0..cc.size() {
                let len = per * recvcounts[r];
                unpack_at(ctx, &bytes[off..off + len], recvbuf, displs[r], recvcounts[r], recvtype)?;
                off += len;
            }
        }
        Ok(())
    })
}
