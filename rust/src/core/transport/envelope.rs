//! Message envelopes — what actually travels between ranks.
//!
//! The payload uses a small-message inline buffer (no heap allocation for
//! messages ≤ [`INLINE_CAP`] bytes): `osu_mbw_mr`-style 8-byte message
//! rate is the paper's headline metric (Table 1), and a per-message
//! `Vec` allocation would swamp the ABI effects we are measuring.

/// Bytes stored inline in the envelope before spilling to the heap.
pub const INLINE_CAP: usize = 64;

/// Message payload: inline for small messages, heap for large.
pub enum Payload {
    /// ≤ [`INLINE_CAP`] bytes stored in the envelope itself.
    Inline {
        /// Used length of `bytes`.
        len: u8,
        /// Inline storage.
        bytes: [u8; INLINE_CAP],
    },
    /// Larger payloads spill to the heap.
    Heap(Vec<u8>),
}

impl Payload {
    /// Copy `data` into a payload.
    #[inline]
    pub fn from_slice(data: &[u8]) -> Payload {
        if data.len() <= INLINE_CAP {
            let mut bytes = [0u8; INLINE_CAP];
            bytes[..data.len()].copy_from_slice(data);
            Payload::Inline { len: data.len() as u8, bytes }
        } else {
            Payload::Heap(data.to_vec())
        }
    }

    /// Build a payload from an owned buffer. Small buffers (≤
    /// [`INLINE_CAP`]) are copied inline and the vector freed — so
    /// control-message replies and packed small messages built through
    /// `Vec` stay allocation-free on the wire, same as
    /// [`Payload::from_slice`]; larger buffers are taken over without a
    /// copy.
    #[inline]
    pub fn from_vec(data: Vec<u8>) -> Payload {
        if data.len() <= INLINE_CAP {
            let mut bytes = [0u8; INLINE_CAP];
            bytes[..data.len()].copy_from_slice(&data);
            Payload::Inline { len: data.len() as u8, bytes }
        } else {
            Payload::Heap(data)
        }
    }

    /// View the payload bytes.
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        match self {
            Payload::Inline { len, bytes } => &bytes[..*len as usize],
            Payload::Heap(v) => v.as_slice(),
        }
    }

    /// Payload size in bytes.
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            Payload::Inline { len, .. } => *len as usize,
            Payload::Heap(v) => v.len(),
        }
    }

    /// `true` for a zero-byte payload.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Empty payload (control messages).
    #[inline]
    pub fn empty() -> Payload {
        Payload::Inline { len: 0, bytes: [0u8; INLINE_CAP] }
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Payload({} bytes)", self.len())
    }
}

/// Wire-level message class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgKind {
    /// Plain eager message: payload is the packed data.
    Eager,
    /// Synchronous-mode eager message: receiver must reply [`MsgKind::SsendAck`]
    /// carrying the same `sync_seq` in `tag`.
    EagerSync,
    /// Ack for an `EagerSync`; `tag` carries the sender's sync sequence.
    SsendAck,
    /// Rendezvous request-to-send: a *matchable* control envelope standing
    /// in for a large message. Carries no payload — `total` is the full
    /// packed byte count (what probe reports) and `rndv` identifies the
    /// stream. The receiver answers with [`MsgKind::Cts`] once a receive
    /// matches it.
    Rts {
        /// Full packed size of the message this RTS announces.
        total: u64,
        /// Sender-local stream id; `(src, rndv)` is globally unique.
        rndv: u64,
    },
    /// Clear-to-send (receiver → sender): the receive matched, stream up
    /// to `credit` cumulative bytes. Never enters the matching index.
    Cts {
        /// Stream id from the RTS being answered.
        rndv: u64,
        /// Cumulative byte credit granted (bounds in-flight chunks).
        credit: u64,
    },
    /// One payload chunk of rendezvous stream `rndv`, covering packed
    /// bytes `[offset, offset + payload.len())`. Never enters the
    /// matching index — routed straight into the posted user buffer.
    RndvData {
        /// Stream id.
        rndv: u64,
        /// Packed-stream byte offset of this chunk.
        offset: u64,
    },
}

/// A message in flight between two ranks.
#[derive(Debug)]
pub struct Envelope {
    /// World rank of the sender.
    pub src: u32,
    /// Communicator context id (pt2pt or collective plane).
    pub context: u32,
    /// User tag (pt2pt) or collective tag (coll plane).
    pub tag: i32,
    /// Wire-level message class.
    pub kind: MsgKind,
    /// Per-(src, context) monotone sequence, for FIFO-ordering assertions.
    pub seq: u64,
    /// The packed bytes.
    pub payload: Payload,
}

impl Envelope {
    /// Does this envelope match a receive posted for `(src, tag, context)`?
    /// `src`/`tag` may be the ABI wildcards.
    #[inline]
    pub fn matches(&self, context: u32, src: i32, tag: i32) -> bool {
        use crate::abi::constants::{MPI_ANY_SOURCE, MPI_ANY_TAG};
        self.context == context
            && matches!(self.kind, MsgKind::Eager | MsgKind::EagerSync | MsgKind::Rts { .. })
            && (src == MPI_ANY_SOURCE || self.src == src as u32)
            && (tag == MPI_ANY_TAG || self.tag == tag)
    }

    /// Logical message size in bytes: what `MPI_Get_count` on a probe
    /// status must report. For an RTS this is the announced total (the
    /// control envelope itself carries no payload); for everything else
    /// it is the payload length.
    #[inline]
    pub fn data_len(&self) -> u64 {
        match self.kind {
            MsgKind::Rts { total, .. } => total,
            _ => self.payload.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abi::constants::{MPI_ANY_SOURCE, MPI_ANY_TAG};

    #[test]
    fn inline_payload_roundtrip() {
        let data = [7u8; 8];
        let p = Payload::from_slice(&data);
        assert!(matches!(p, Payload::Inline { .. }));
        assert_eq!(p.as_slice(), &data);
        assert_eq!(p.len(), 8);
    }

    #[test]
    fn heap_payload_above_inline_cap() {
        let data = vec![1u8; INLINE_CAP + 1];
        let p = Payload::from_slice(&data);
        assert!(matches!(p, Payload::Heap(_)));
        assert_eq!(p.as_slice(), &data[..]);
    }

    #[test]
    fn boundary_is_inline() {
        let data = vec![3u8; INLINE_CAP];
        assert!(matches!(Payload::from_slice(&data), Payload::Inline { .. }));
    }

    #[test]
    fn from_vec_inlines_small_buffers() {
        let p = Payload::from_vec(vec![9u8; 8]);
        assert!(matches!(p, Payload::Inline { .. }), "≤ INLINE_CAP must not stay heap");
        assert_eq!(p.as_slice(), &[9u8; 8]);
        let p = Payload::from_vec(vec![4u8; INLINE_CAP]);
        assert!(matches!(p, Payload::Inline { .. }), "boundary inlines");
        assert_eq!(p.len(), INLINE_CAP);
        let p = Payload::from_vec(vec![5u8; INLINE_CAP + 1]);
        assert!(matches!(p, Payload::Heap(_)), "> INLINE_CAP keeps the buffer");
        assert_eq!(p.len(), INLINE_CAP + 1);
        let p = Payload::from_vec(Vec::new());
        assert!(p.is_empty());
    }

    #[test]
    fn empty_payload() {
        let p = Payload::empty();
        assert!(p.is_empty());
        assert_eq!(p.as_slice(), &[] as &[u8]);
    }

    fn env(src: u32, context: u32, tag: i32) -> Envelope {
        Envelope { src, context, tag, kind: MsgKind::Eager, seq: 0, payload: Payload::empty() }
    }

    #[test]
    fn matching_rules() {
        let e = env(3, 7, 42);
        assert!(e.matches(7, 3, 42));
        assert!(e.matches(7, MPI_ANY_SOURCE, 42));
        assert!(e.matches(7, 3, MPI_ANY_TAG));
        assert!(e.matches(7, MPI_ANY_SOURCE, MPI_ANY_TAG));
        assert!(!e.matches(8, 3, 42), "context never wildcards");
        assert!(!e.matches(7, 2, 42));
        assert!(!e.matches(7, 3, 41));
    }

    #[test]
    fn acks_never_match_recvs() {
        let mut e = env(1, 7, 5);
        e.kind = MsgKind::SsendAck;
        assert!(!e.matches(7, MPI_ANY_SOURCE, MPI_ANY_TAG));
    }

    #[test]
    fn rts_matches_like_eager() {
        let mut e = env(3, 7, 42);
        e.kind = MsgKind::Rts { total: 1 << 30, rndv: 9 };
        assert!(e.matches(7, 3, 42));
        assert!(e.matches(7, MPI_ANY_SOURCE, MPI_ANY_TAG));
        assert!(!e.matches(8, 3, 42));
        assert!(!e.matches(7, 3, 41));
    }

    #[test]
    fn cts_and_chunks_never_match_recvs() {
        let mut e = env(1, 7, 5);
        e.kind = MsgKind::Cts { rndv: 1, credit: 4096 };
        assert!(!e.matches(7, MPI_ANY_SOURCE, MPI_ANY_TAG));
        e.kind = MsgKind::RndvData { rndv: 1, offset: 0 };
        assert!(!e.matches(7, MPI_ANY_SOURCE, MPI_ANY_TAG));
    }

    #[test]
    fn data_len_reports_announced_total_for_rts() {
        let mut e = env(0, 7, 1);
        e.kind = MsgKind::Rts { total: 5 << 20, rndv: 2 };
        assert_eq!(e.data_len(), 5 << 20, "probe must see the full size, not the control payload");
        e.kind = MsgKind::Eager;
        e.payload = Payload::from_slice(&[0u8; 12]);
        assert_eq!(e.data_len(), 12);
    }
}
