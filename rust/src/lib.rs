//! # mpi-abi — reproduction of *MPI Application Binary Interface
//! # Standardization* (EuroMPI 2023)
//!
//! A three-layer Rust + JAX/Pallas system implementing:
//!
//! * the proposed **standard MPI ABI** ([`abi`]): integer types, the
//!   32-byte status object, Huffman-coded handle constants, and the
//!   constant tables of §5 / Appendix A;
//! * a complete **MPI engine substrate** ([`core`]): communicators,
//!   groups, tag matching over two shared-memory transports, a datatype
//!   engine with pack/unpack, a request engine, collectives, reduction
//!   ops, attributes, info objects, and error handlers;
//! * two deliberately **divergent implementation ABIs** ([`impls`]):
//!   an MPICH-like integer-handle ABI and an Open-MPI-like
//!   pointer-handle ABI;
//! * **Mukautuva** ([`muk`]): the standalone translation layer that
//!   implements the standard ABI on top of either backend through
//!   dlsym-style symbol resolution, handle/constant/status/error-code
//!   conversion, callback trampolines and request-state maps;
//! * a **native standard-ABI build** ([`native_abi`]) — the
//!   `--enable-mpi-abi` analogue — implementing the standard ABI with no
//!   translation;
//! * a **PJRT runtime** ([`runtime`]) that loads the JAX/Pallas-compiled
//!   HLO artifacts (built once by `make artifacts`; Python is never on
//!   the request path) for the compute-heavy reduction and training-step
//!   paths;
//! * the [`launcher`], [`apps`] (OSU-style microbenchmarks, DDP trainer)
//!   [`testsuite`], and a hand-rolled [`bench`] harness.

pub mod abi;
pub mod api;
pub mod apps;
pub mod bench;
pub mod core;
pub mod impls;
pub mod native_abi;
pub mod launcher;
pub mod muk;
pub mod runtime;
pub mod testsuite;

/// Crate version string (reported as the "library version" of our MPI).
pub const LIBRARY_VERSION: &str = concat!("mpi-abi ", env!("CARGO_PKG_VERSION"));
