"""L1 Pallas kernels + pure-jnp reference oracles."""

from . import matmul, reduce, ref  # noqa: F401
