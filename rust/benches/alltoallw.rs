//! E5 — the §6.2 worst case: a nonblocking `MPI_Ialltoallw` (whose
//! converted datatype vectors Mukautuva parks in its request map),
//! followed by many point-to-point requests completed via `MPI_Testall`
//! — so *every* Testall poll pays a map lookup per request.
//!
//! Measured: time per Testall poll with the alltoallw request pending,
//! muk vs native; plus the request-map insert/lookup primitives.

use mpi_abi::api::{Dt, MpiAbi};
use mpi_abi::apps::{with_abi, AbiApp, AbiConfig};
use mpi_abi::bench::{bench, bench_external, Table};
use mpi_abi::launcher::{run_job_ok, JobSpec};

const PT2PT_REQS: usize = 64;
const POLLS: usize = 2000;

struct WorstCase;

impl AbiApp<f64> for WorstCase {
    /// Seconds per Testall poll over PT2PT_REQS+1 requests while an
    /// ialltoallw request (with map state) is pending.
    fn run<A: MpiAbi>(self) -> f64 {
        let out = run_job_ok(JobSpec::new(2), |rank| {
            A::init();
            let dt = A::datatype(Dt::Int);
            let world = A::comm_world();
            let n = 2usize;
            let mut elapsed = 0.0;
            if rank == 0 {
                // The ialltoallw whose state lands in the request map.
                let send: Vec<i32> = vec![1; n];
                let mut recv = vec![0i32; n];
                let counts = vec![1i32; n];
                let displs: Vec<i32> = (0..n as i32).map(|d| d * 4).collect();
                let types = vec![dt; n];
                let mut wreq = A::request_null();
                A::ialltoallw(
                    send.as_ptr() as *const u8,
                    &counts,
                    &displs,
                    &types,
                    recv.as_mut_ptr() as *mut u8,
                    &counts,
                    &displs,
                    &types,
                    world,
                    &mut wreq,
                );
                // A pile of pt2pt receives that will never complete during
                // the timed window (peer sends only afterwards).
                let mut bufs = vec![[0i32]; PT2PT_REQS];
                let mut reqs = vec![A::request_null(); PT2PT_REQS + 1];
                reqs[0] = wreq;
                for (i, b) in bufs.iter_mut().enumerate() {
                    A::irecv(b.as_mut_ptr() as *mut u8, 1, dt, 1, 500 + i as i32, world,
                        &mut reqs[i + 1]);
                }
                // Timed: Testall polls (all incomplete until peer sends).
                let t0 = A::wtime();
                let mut flag = false;
                let mut sts = vec![A::status_empty(); PT2PT_REQS + 1];
                for _ in 0..POLLS {
                    A::testall(&mut reqs, &mut flag, &mut sts);
                }
                elapsed = (A::wtime() - t0) / POLLS as f64;
                // Release the peer and drain everything.
                let go = [1i32];
                A::send(go.as_ptr() as *const u8, 1, dt, 1, 999, world);
                A::waitall(&mut reqs, &mut sts);
            } else {
                // Peer: participate in the alltoallw, then wait for the
                // release signal before completing the pt2pt pile.
                let send: Vec<i32> = vec![2; n];
                let mut recv = vec![0i32; n];
                let counts = vec![1i32; n];
                let displs: Vec<i32> = (0..n as i32).map(|d| d * 4).collect();
                let types = vec![dt; n];
                let mut wreq = A::request_null();
                A::ialltoallw(
                    send.as_ptr() as *const u8,
                    &counts,
                    &displs,
                    &types,
                    recv.as_mut_ptr() as *mut u8,
                    &counts,
                    &displs,
                    &types,
                    world,
                    &mut wreq,
                );
                let mut st = A::status_empty();
                A::wait(&mut wreq, &mut st);
                let mut go = [0i32];
                A::recv(go.as_mut_ptr() as *mut u8, 1, dt, 0, 999, world, &mut st);
                for i in 0..PT2PT_REQS {
                    let v = [i as i32];
                    A::send(v.as_ptr() as *const u8, 1, dt, 0, 500 + i as i32, world);
                }
            }
            A::finalize();
            elapsed
        });
        out[0]
    }
}

fn main() {
    std::env::set_var("MPI_ABI_NO_XLA", "1");
    println!(
        "\nE5 — §6.2 worst case: Testall over {} requests with pending ialltoallw map state",
        PT2PT_REQS + 1
    );
    let mut table = Table::new("Testall poll cost", &["ABI", "ns/poll", "ns/req"]);
    for abi in [AbiConfig::Mpich, AbiConfig::NativeAbi, AbiConfig::MukMpich, AbiConfig::MukOmpi] {
        let s = bench_external(&format!("testall/{}", abi.name()), 3, || {
            with_abi(abi, WorstCase)
        });
        println!("{}", s.report());
        table.row(&[
            abi.name().to_string(),
            format!("{:.0}", s.median * 1e9),
            format!("{:.1}", s.median * 1e9 / (PT2PT_REQS + 1) as f64),
        ]);
    }
    println!("{}", table.render());

    // The map primitives themselves.
    let mut sink = false;
    let s = bench("reqmap/contains (miss)", 2, 10, 200_000, || {
        sink ^= mpi_abi::muk::state::reqmap_contains(std::hint::black_box(0xABCD));
    });
    println!("{}", s.report());
    mpi_abi::muk::state::reqmap_insert(
        0x9999,
        mpi_abi::muk::state::WState { sendtypes: vec![1, 2], recvtypes: vec![3, 4] },
    );
    let s = bench("reqmap/contains (hit)", 2, 10, 200_000, || {
        sink ^= mpi_abi::muk::state::reqmap_contains(std::hint::black_box(0x9999));
    });
    println!("{}", s.report());
    mpi_abi::muk::state::reqmap_remove(0x9999);
    std::hint::black_box(sink);
    println!("\nshape: muk pays a per-request map lookup on every Testall — visible but bounded, and \"not currently optimized, due to the low probability of such a scenario\" (paper §6.2).");
}
