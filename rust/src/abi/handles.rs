//! Opaque handle types and non-datatype handle constants (§5.3, A.2).
//!
//! The proposal uses **incomplete struct pointers** for type safety:
//!
//! ```c
//! typedef struct MPI_ABI_Comm    *MPI_Comm;
//! typedef struct MPI_ABI_Request *MPI_Request;
//! ```
//!
//! In Rust we model each as a `#[repr(transparent)]` newtype over a
//! pointer-sized word. That preserves the two ABI-relevant facts: handles
//! are exactly one pointer wide (so they fit in a `void*` attribute,
//! §3.3), and distinct handle types are distinct *types* (the compiler
//! rejects passing an `AbiComm` where an `AbiDatatype` is expected —
//! the type-safety benefit the paper credits to Open MPI's design).
//!
//! Predefined constants are the zero-page Huffman values of
//! [`crate::abi::huffman`]; user handles are values above the zero page
//! (in a C implementation: heap pointers, which never point into page 0).
//!
//! # The handle-encoding scheme
//!
//! A handle is one pointer-sized word partitioned by value:
//!
//! | word value            | meaning                                      |
//! |-----------------------|----------------------------------------------|
//! | `0`                   | reserved (never a valid handle)              |
//! | `1 ..= HUFFMAN_MAX`   | predefined constant, 10-bit Huffman code     |
//! | `> HUFFMAN_MAX`       | runtime handle owned by the implementation   |
//!
//! The Huffman code itself encodes the handle *kind* (comm, group, op,
//! datatype, …) and, for fixed-size datatypes, `log2(size)` — see
//! [`crate::abi::huffman::decode`] and `fixed_size_of`. Invariants the
//! rest of the system relies on:
//!
//! * **Kind is decodable for constants.** Translation layers switch on
//!   the zero page without any table lookup ([`crate::abi::huffman`]),
//!   and misuse of a constant in the wrong argument slot is detectable
//!   by name (§5.4 diagnosability).
//! * **Runtime handles never collide with the zero page.** A C
//!   implementation guarantees this because page 0 is never mapped; our
//!   native build guarantees it by biasing engine ids above
//!   `HUFFMAN_MAX` (see `native_abi`'s `USER_BASE`).
//! * **The word is opaque above the zero page.** Only the owning
//!   implementation may interpret it; Mukautuva round-trips it through
//!   the word union untouched ([`crate::muk::word::AsWord`]).
//! * **Null handles are per-kind constants** (`MPI_COMM_NULL`,
//!   `MPI_REQUEST_NULL`, …), not `0`, so nullness is also kind-checked.

use crate::abi::huffman::HUFFMAN_MAX;

// --- Non-datatype predefined constants (Appendix A.2) ---------------------

/// Zero-page Huffman constant for `MPI_COMM_NULL` (Appendix A.2).
pub const MPI_COMM_NULL: usize = 0b0100000000;
/// Zero-page Huffman constant for `MPI_COMM_WORLD` (Appendix A.2).
pub const MPI_COMM_WORLD: usize = 0b0100000001;
/// Zero-page Huffman constant for `MPI_COMM_SELF` (Appendix A.2).
pub const MPI_COMM_SELF: usize = 0b0100000010;

/// Zero-page Huffman constant for `MPI_GROUP_NULL` (Appendix A.2).
pub const MPI_GROUP_NULL: usize = 0b0100000100;
/// Zero-page Huffman constant for `MPI_GROUP_EMPTY` (Appendix A.2).
pub const MPI_GROUP_EMPTY: usize = 0b0100000101;

/// Zero-page Huffman constant for `MPI_WIN_NULL` (Appendix A.2).
pub const MPI_WIN_NULL: usize = 0b0100001000;
/// Zero-page Huffman constant for `MPI_FILE_NULL` (Appendix A.2).
pub const MPI_FILE_NULL: usize = 0b0100001100;
/// Zero-page Huffman constant for `MPI_SESSION_NULL` (Appendix A.2).
pub const MPI_SESSION_NULL: usize = 0b0100010000;

/// Zero-page Huffman constant for `MPI_MESSAGE_NULL` (Appendix A.2).
pub const MPI_MESSAGE_NULL: usize = 0b0100010100;
/// Zero-page Huffman constant for `MPI_MESSAGE_NO_PROC` (Appendix A.2).
pub const MPI_MESSAGE_NO_PROC: usize = 0b0100010101;

/// Zero-page Huffman constant for `MPI_ERRHANDLER_NULL` (Appendix A.2).
pub const MPI_ERRHANDLER_NULL: usize = 0b0100011000;
/// Zero-page Huffman constant for `MPI_ERRORS_ARE_FATAL` (Appendix A.2).
pub const MPI_ERRORS_ARE_FATAL: usize = 0b0100011001;
/// Zero-page Huffman constant for `MPI_ERRORS_RETURN` (Appendix A.2).
pub const MPI_ERRORS_RETURN: usize = 0b0100011010;
/// Zero-page Huffman constant for `MPI_ERRORS_ABORT` (Appendix A.2).
pub const MPI_ERRORS_ABORT: usize = 0b0100011011;

/// Zero-page Huffman constant for `MPI_REQUEST_NULL` (Appendix A.2).
pub const MPI_REQUEST_NULL: usize = 0b0100100000;

/// Info handles are not in the published appendix excerpt; the spec draft
/// places them in the reserved `0b0100011100` block. We allocate:
pub const MPI_INFO_NULL: usize = 0b0100011100;
/// Zero-page Huffman constant for `MPI_INFO_ENV` (Appendix A.2).
pub const MPI_INFO_ENV: usize = 0b0100011101;

/// All predefined non-datatype, non-op handles with their MPI names.
pub const PREDEFINED_HANDLES: &[(&str, usize)] = &[
    ("MPI_COMM_NULL", MPI_COMM_NULL),
    ("MPI_COMM_WORLD", MPI_COMM_WORLD),
    ("MPI_COMM_SELF", MPI_COMM_SELF),
    ("MPI_GROUP_NULL", MPI_GROUP_NULL),
    ("MPI_GROUP_EMPTY", MPI_GROUP_EMPTY),
    ("MPI_WIN_NULL", MPI_WIN_NULL),
    ("MPI_FILE_NULL", MPI_FILE_NULL),
    ("MPI_SESSION_NULL", MPI_SESSION_NULL),
    ("MPI_MESSAGE_NULL", MPI_MESSAGE_NULL),
    ("MPI_MESSAGE_NO_PROC", MPI_MESSAGE_NO_PROC),
    ("MPI_ERRHANDLER_NULL", MPI_ERRHANDLER_NULL),
    ("MPI_ERRORS_ARE_FATAL", MPI_ERRORS_ARE_FATAL),
    ("MPI_ERRORS_RETURN", MPI_ERRORS_RETURN),
    ("MPI_ERRORS_ABORT", MPI_ERRORS_ABORT),
    ("MPI_INFO_NULL", MPI_INFO_NULL),
    ("MPI_INFO_ENV", MPI_INFO_ENV),
    ("MPI_REQUEST_NULL", MPI_REQUEST_NULL),
];

// --- Typed handle newtypes -------------------------------------------------

macro_rules! abi_handle {
    ($(#[$doc:meta])* $name:ident, $null:expr) => {
        $(#[$doc])*
        #[repr(transparent)]
        #[derive(Clone, Copy, PartialEq, Eq, Hash)]
        pub struct $name(pub usize);

        impl $name {
            /// The null handle constant for this type.
            pub const NULL: $name = $name($null);

            /// Raw word value (what crosses the C ABI).
            #[inline(always)]
            pub const fn raw(self) -> usize {
                self.0
            }

            /// `true` if this is the type's null handle.
            #[inline(always)]
            pub const fn is_null(self) -> bool {
                self.0 == $null
            }

            /// `true` if predefined (zero-page Huffman constant).
            #[inline(always)]
            pub const fn is_predefined(self) -> bool {
                self.0 <= HUFFMAN_MAX
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                if let Some(n) = crate::abi::handle_name(self.0) {
                    write!(f, "{}({})", stringify!($name), n)
                } else {
                    write!(f, "{}({:#x})", stringify!($name), self.0)
                }
            }
        }
    };
}

abi_handle!(
    /// `MPI_Comm` in the standard ABI.
    AbiComm,
    MPI_COMM_NULL
);
abi_handle!(
    /// `MPI_Group` in the standard ABI.
    AbiGroup,
    MPI_GROUP_NULL
);
abi_handle!(
    /// `MPI_Datatype` in the standard ABI.
    AbiDatatype,
    crate::abi::datatypes::MPI_DATATYPE_NULL
);
abi_handle!(
    /// `MPI_Op` in the standard ABI.
    AbiOp,
    crate::abi::ops::MPI_OP_NULL
);
abi_handle!(
    /// `MPI_Request` in the standard ABI.
    AbiRequest,
    MPI_REQUEST_NULL
);
abi_handle!(
    /// `MPI_Errhandler` in the standard ABI.
    AbiErrhandler,
    MPI_ERRHANDLER_NULL
);
abi_handle!(
    /// `MPI_Info` in the standard ABI.
    AbiInfo,
    MPI_INFO_NULL
);
abi_handle!(
    /// `MPI_Win` in the standard ABI — the one-sided subsystem's handle
    /// (windows, epochs, Put/Get/Accumulate; see [`crate::core::rma`]).
    AbiWin,
    MPI_WIN_NULL
);
abi_handle!(
    /// `MPI_Message` in the standard ABI.
    AbiMessage,
    MPI_MESSAGE_NULL
);
abi_handle!(
    /// `MPI_Session` in the standard ABI.
    AbiSession,
    MPI_SESSION_NULL
);

impl AbiComm {
    /// `MPI_COMM_WORLD`.
    pub const WORLD: AbiComm = AbiComm(MPI_COMM_WORLD);
    /// `MPI_COMM_SELF`.
    pub const SELF: AbiComm = AbiComm(MPI_COMM_SELF);
}

impl AbiGroup {
    /// `MPI_GROUP_EMPTY`.
    pub const EMPTY: AbiGroup = AbiGroup(MPI_GROUP_EMPTY);
}

impl AbiErrhandler {
    /// Zero-page Huffman constant for `ERRORS_ARE_FATAL` (Appendix A.2).
    pub const ERRORS_ARE_FATAL: AbiErrhandler = AbiErrhandler(MPI_ERRORS_ARE_FATAL);
    /// Zero-page Huffman constant for `ERRORS_RETURN` (Appendix A.2).
    pub const ERRORS_RETURN: AbiErrhandler = AbiErrhandler(MPI_ERRORS_RETURN);
    /// Zero-page Huffman constant for `ERRORS_ABORT` (Appendix A.2).
    pub const ERRORS_ABORT: AbiErrhandler = AbiErrhandler(MPI_ERRORS_ABORT);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_pointer_sized() {
        // §3.3: handles must fit in a `void*` (attributes) — exactly one
        // word in the standard ABI.
        assert_eq!(core::mem::size_of::<AbiComm>(), core::mem::size_of::<*mut u8>());
        assert_eq!(core::mem::size_of::<AbiDatatype>(), core::mem::size_of::<*mut u8>());
        assert_eq!(core::mem::size_of::<AbiRequest>(), core::mem::size_of::<*mut u8>());
    }

    #[test]
    fn null_and_predefined_predicates() {
        assert!(AbiComm::NULL.is_null());
        assert!(!AbiComm::WORLD.is_null());
        assert!(AbiComm::WORLD.is_predefined());
        assert!(!AbiComm(0x7f00_1234).is_predefined());
    }

    #[test]
    fn debug_prints_names() {
        assert_eq!(format!("{:?}", AbiComm::WORLD), "AbiComm(MPI_COMM_WORLD)");
        assert_eq!(format!("{:?}", AbiOp(crate::abi::ops::MPI_SUM)), "AbiOp(MPI_SUM)");
    }

    #[test]
    fn distinct_types_do_not_unify() {
        // Compile-time property; assert the runtime values still compare.
        let c = AbiComm::WORLD;
        let d = AbiDatatype(crate::abi::datatypes::MPI_INT);
        assert_ne!(c.raw(), d.raw());
    }
}
