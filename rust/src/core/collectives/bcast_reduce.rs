//! Broadcast, reduction, and scan collectives.

use super::{bcast_bytes_cc, cc_clone, coll_begin, coll_recv, coll_send, CollCtx};
use crate::core::datatype::pack::{pack, unpack};
use crate::core::transport::Payload;
use crate::core::world::{with_ctx, RankCtx};
use crate::core::{err, CommId, DtId, OpId, RC};

fn in_place(p: *const u8) -> bool {
    p as usize == crate::abi::constants::MPI_IN_PLACE
}

fn pack_user(ctx: &RankCtx, buf: *const u8, count: usize, dt: DtId) -> RC<Vec<u8>> {
    let t = ctx.tables.borrow();
    let mut v = Vec::new();
    pack(&t.dtypes, buf, count, dt, &mut v)?;
    Ok(v)
}

fn unpack_user(ctx: &RankCtx, data: &[u8], buf: *mut u8, count: usize, dt: DtId) -> RC<()> {
    let t = ctx.tables.borrow();
    unpack(&t.dtypes, data, buf, count, dt)?;
    Ok(())
}

fn packed_len(ctx: &RankCtx, count: usize, dt: DtId) -> RC<usize> {
    let t = ctx.tables.borrow();
    Ok(t.dtypes.get(dt.0).ok_or(err!(MPI_ERR_TYPE))?.size * count)
}

/// `MPI_Bcast`.
pub fn bcast(buf: *mut u8, count: usize, dt: DtId, root: i32, comm: CommId) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        if root < 0 || root as usize >= cc.size() {
            return Err(err!(MPI_ERR_ROOT));
        }
        let root = root as usize;
        if cc.size() <= 1 {
            return Ok(());
        }
        let mut bytes = if cc.my_rank == root {
            pack_user(ctx, buf, count, dt)?
        } else {
            vec![0u8; packed_len(ctx, count, dt)?]
        };
        bcast_bytes_cc(ctx, &cc, &mut bytes, root);
        if cc.my_rank != root {
            unpack_user(ctx, &bytes, buf, count, dt)?;
        }
        Ok(())
    })
}

/// Binomial-tree byte reduction of `accum` toward virtual rank 0 (= real
/// rank `root`). On return, `accum` at root holds the reduced bytes.
fn reduce_bytes_cc(
    ctx: &RankCtx,
    cc: &CollCtx,
    accum: &mut Vec<u8>,
    count: usize,
    dt: DtId,
    op: OpId,
    root: usize,
) -> RC<()> {
    let n = cc.size();
    if n <= 1 {
        return Ok(());
    }
    let vrank = (cc.my_rank + n - root) % n;
    // Receive from each child (in ascending child order) and fold.
    for child in super::children_of(vrank, n) {
        let child_real = (child + root) % n;
        let p = coll_recv(ctx, cc, child_real);
        crate::core::op::apply(op, p.as_slice(), accum, count, dt)?;
    }
    if vrank != 0 {
        let parent_real = (super::parent_of(vrank) + root) % n;
        coll_send(ctx, cc, parent_real, Payload::from_slice(accum));
    }
    Ok(())
}

/// `MPI_Reduce`.
pub fn reduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        if root < 0 || root as usize >= cc.size() {
            return Err(err!(MPI_ERR_ROOT));
        }
        let root = root as usize;
        let contrib = if in_place(sendbuf) && cc.my_rank == root {
            recvbuf as *const u8
        } else {
            sendbuf
        };
        let mut accum = pack_user(ctx, contrib, count, dt)?;
        reduce_bytes_cc(ctx, &cc, &mut accum, count, dt, op, root)?;
        if cc.my_rank == root {
            unpack_user(ctx, &accum, recvbuf, count, dt)?;
        }
        Ok(())
    })
}

/// `MPI_Allreduce` (reduce to 0, then broadcast — two tag phases of one
/// collective).
pub fn allreduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let mut accum = pack_user(ctx, contrib, count, dt)?;
        reduce_bytes_cc(ctx, &cc, &mut accum, count, dt, op, 0)?;
        let bc = CollCtx { tag: cc.tag + 1, ..cc_clone(&cc) };
        bcast_bytes_cc(ctx, &bc, &mut accum, 0);
        unpack_user(ctx, &accum, recvbuf, count, dt)?;
        Ok(())
    })
}

/// `MPI_Reduce_scatter_block`.
pub fn reduce_scatter_block(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    recvcount: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let total = recvcount * n;
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let mut accum = pack_user(ctx, contrib, total, dt)?;
        reduce_bytes_cc(ctx, &cc, &mut accum, total, dt, op, 0)?;
        // Scatter blocks from rank 0 (phase 1).
        let blk = packed_len(ctx, recvcount, dt)?;
        let sc = CollCtx { tag: cc.tag + 1, ..cc_clone(&cc) };
        if cc.my_rank == 0 {
            for r in 1..n {
                coll_send(ctx, &sc, r, Payload::from_slice(&accum[r * blk..(r + 1) * blk]));
            }
            unpack_user(ctx, &accum[..blk], recvbuf, recvcount, dt)?;
        } else {
            let p = coll_recv(ctx, &sc, 0);
            unpack_user(ctx, p.as_slice(), recvbuf, recvcount, dt)?;
        }
        Ok(())
    })
}

/// `MPI_Scan` (inclusive, linear chain).
pub fn scan(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let mut accum = pack_user(ctx, contrib, count, dt)?;
        if cc.my_rank > 0 {
            let prev = coll_recv(ctx, &cc, cc.my_rank - 1);
            // accum = op(prev, own): ranks 0..me fold in rank order.
            crate::core::op::apply(op, prev.as_slice(), &mut accum, count, dt)?;
        }
        if cc.my_rank + 1 < n {
            coll_send(ctx, &cc, cc.my_rank + 1, Payload::from_slice(&accum));
        }
        unpack_user(ctx, &accum, recvbuf, count, dt)?;
        Ok(())
    })
}

/// `MPI_Exscan` (exclusive; rank 0's recvbuf is untouched, as the
/// standard leaves it undefined).
pub fn exscan(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<()> {
    with_ctx(|ctx| {
        let cc = coll_begin(comm)?;
        let n = cc.size();
        let contrib = if in_place(sendbuf) { recvbuf as *const u8 } else { sendbuf };
        let own = pack_user(ctx, contrib, count, dt)?;
        let mut partial: Option<Vec<u8>> = None; // op(x0..x_{me-1})
        if cc.my_rank > 0 {
            let p = coll_recv(ctx, &cc, cc.my_rank - 1);
            partial = Some(p.as_slice().to_vec());
        }
        if cc.my_rank + 1 < n {
            let mut fwd = own.clone();
            if let Some(ref p) = partial {
                crate::core::op::apply(op, p, &mut fwd, count, dt)?;
            }
            coll_send(ctx, &cc, cc.my_rank + 1, Payload::from_vec(fwd));
        }
        if let Some(p) = partial {
            unpack_user(ctx, &p, recvbuf, count, dt)?;
        }
        Ok(())
    })
}
