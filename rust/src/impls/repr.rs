//! Representation backends and the generic ABI plumbing.
//!
//! A [`Repr`] captures exactly what differs between MPI ABIs:
//! handle representation (+ conversions to engine ids), status layout,
//! constant values (including wildcard integers), error-code encoding,
//! and the fast datatype-size mechanism (§6.1). [`Backed<R>`] then
//! implements the full [`MpiAbi`] API generically — the shared semantics
//! every implementation has, monomorphized per representation.

use std::marker::PhantomData;

use crate::abi::types::{Aint, Count};
use crate::api::{AttrCopyFn, AttrDeleteFn, Counts, Displs, Dt, ErrhFn, MpiAbi, OpName, UserOpFn};
use crate::core::request::StatusCore;
use crate::core::{collectives as coll, comm, datatype, engine, errh, group, info, obs, op, rma,
    session};
use crate::core::{CommId, DtId, ErrhId, GroupId, InfoId, OpId, RC, ReqId, SessionId, WinId};

/// What one MPI ABI fixes. See module docs.
pub trait Repr: 'static {
    /// Human name for reports ("mpich", "ompi", "abi").
    const NAME: &'static str;

    /// `MPI_Comm` in this ABI's representation.
    type Comm: Copy + PartialEq + std::fmt::Debug;
    /// `MPI_Datatype` in this ABI's representation.
    type Datatype: Copy + PartialEq + std::fmt::Debug;
    /// `MPI_Op` in this ABI's representation.
    type Op: Copy + PartialEq;
    /// `MPI_Request` in this ABI's representation.
    type Request: Copy + PartialEq + std::fmt::Debug;
    /// `MPI_Group` in this ABI's representation.
    type Group: Copy + PartialEq;
    /// `MPI_Errhandler` in this ABI's representation.
    type Errhandler: Copy + PartialEq;
    /// `MPI_Info` in this ABI's representation.
    type Info: Copy + PartialEq;
    /// `MPI_Win` in this ABI's representation.
    type Win: Copy + PartialEq + std::fmt::Debug;
    /// `MPI_Session` in this ABI's representation.
    type Session: Copy + PartialEq + std::fmt::Debug;
    /// The ABI's status struct.
    type Status: Copy;

    /// `MPI_COMM_WORLD`'s handle value.
    fn c_comm_world() -> Self::Comm;
    /// `MPI_COMM_SELF`'s handle value.
    fn c_comm_self() -> Self::Comm;
    /// `MPI_COMM_NULL`'s handle value.
    fn c_comm_null() -> Self::Comm;
    /// `MPI_REQUEST_NULL`'s handle value.
    fn c_request_null() -> Self::Request;
    /// `MPI_ERRORS_RETURN`'s handle value.
    fn c_errh_return() -> Self::Errhandler;
    /// `MPI_ERRORS_ARE_FATAL`'s handle value.
    fn c_errh_fatal() -> Self::Errhandler;
    /// `MPI_INFO_NULL`'s handle value.
    fn c_info_null() -> Self::Info;
    /// `MPI_WIN_NULL`'s handle value.
    fn c_win_null() -> Self::Win;
    /// `MPI_SESSION_NULL`'s handle value.
    fn c_session_null() -> Self::Session;
    /// The handle for a predefined datatype.
    fn c_datatype(d: Dt) -> Self::Datatype;
    /// The handle for a predefined reduction op.
    fn c_op(o: OpName) -> Self::Op;

    /// `MPI_LOCK_EXCLUSIVE` in this ABI's numbering (MPICH: 234).
    fn c_lock_exclusive() -> i32 {
        crate::abi::constants::MPI_LOCK_EXCLUSIVE
    }
    /// `MPI_LOCK_SHARED` in this ABI's numbering (MPICH: 235).
    fn c_lock_shared() -> i32 {
        crate::abi::constants::MPI_LOCK_SHARED
    }
    /// `MPI_MODE_NOCHECK` — Open MPI numbers the whole `MPI_MODE_*`
    /// family differently (1/2/4/8/16) from MPICH and the standard ABI.
    fn c_mode_nocheck() -> i32 {
        crate::abi::constants::MPI_MODE_NOCHECK
    }
    /// `MPI_MODE_NOSTORE` in this ABI's numbering.
    fn c_mode_nostore() -> i32 {
        crate::abi::constants::MPI_MODE_NOSTORE
    }
    /// `MPI_MODE_NOPUT` in this ABI's numbering.
    fn c_mode_noput() -> i32 {
        crate::abi::constants::MPI_MODE_NOPUT
    }
    /// `MPI_MODE_NOPRECEDE` in this ABI's numbering.
    fn c_mode_noprecede() -> i32 {
        crate::abi::constants::MPI_MODE_NOPRECEDE
    }
    /// `MPI_MODE_NOSUCCEED` in this ABI's numbering.
    fn c_mode_nosucceed() -> i32 {
        crate::abi::constants::MPI_MODE_NOSUCCEED
    }

    /// This ABI's `MPI_ANY_SOURCE` (ABIs number these differently!).
    fn c_any_source() -> i32;
    /// This ABI's `MPI_ANY_TAG`.
    fn c_any_tag() -> i32;
    /// This ABI's `MPI_PROC_NULL`.
    fn c_proc_null() -> i32;
    /// This ABI's `MPI_UNDEFINED`.
    fn c_undefined() -> i32;
    /// This ABI's `MPI_COMM_TYPE_SHARED` (split-type values differ per
    /// implementation too: MPICH 1, Open MPI 0).
    fn c_comm_type_shared() -> i32 {
        crate::abi::constants::MPI_COMM_TYPE_SHARED
    }
    /// This ABI's `MPI_IN_PLACE` sentinel.
    fn c_in_place() -> *const u8;

    /// Comm handle → engine id (the cost Mukautuva pays per call).
    fn comm_id(c: Self::Comm) -> RC<CommId>;
    /// Engine id → comm handle.
    fn comm_h(id: CommId) -> Self::Comm;
    /// Datatype handle → engine id.
    fn dt_id(d: Self::Datatype) -> RC<DtId>;
    /// Engine id → datatype handle.
    fn dt_h(id: DtId) -> Self::Datatype;
    /// Op handle → engine id.
    fn op_id(o: Self::Op) -> RC<OpId>;
    /// Engine id → op handle.
    fn op_h(id: OpId) -> Self::Op;
    /// Request handle → engine id.
    fn req_id(r: Self::Request) -> RC<ReqId>;
    /// Engine id → request handle.
    fn req_h(id: ReqId) -> Self::Request;
    /// Group handle → engine id.
    fn group_id(g: Self::Group) -> RC<GroupId>;
    /// Engine id → group handle.
    fn group_h(id: GroupId) -> Self::Group;
    /// Errhandler handle → engine id.
    fn errh_id(e: Self::Errhandler) -> RC<ErrhId>;
    /// Engine id → errhandler handle.
    fn errh_h(id: ErrhId) -> Self::Errhandler;
    /// Info handle → engine id.
    fn info_id(i: Self::Info) -> RC<InfoId>;
    /// Engine id → info handle.
    fn info_h(id: InfoId) -> Self::Info;
    /// Window handle → engine id.
    fn win_id(w: Self::Win) -> RC<WinId>;
    /// Engine id → window handle.
    fn win_h(id: WinId) -> Self::Win;
    /// Session handle → engine id.
    fn session_id(s: Self::Session) -> RC<SessionId>;
    /// Engine id → session handle.
    fn session_h(id: SessionId) -> Self::Session;

    /// Drop any per-handle allocation when a request handle is consumed
    /// (pointer-handle ABIs heap-allocate request descriptors).
    fn req_release(r: Self::Request) {
        let _ = r;
    }
    /// Likewise for freed datatype handles.
    fn dt_release(d: Self::Datatype) {
        let _ = d;
    }
    /// Likewise for freed comm handles.
    fn comm_release(c: Self::Comm) {
        let _ = c;
    }
    /// Likewise for freed op handles.
    fn op_release(o: Self::Op) {
        let _ = o;
    }
    /// Likewise for freed group handles.
    fn group_release(g: Self::Group) {
        let _ = g;
    }
    /// Likewise for freed errhandler handles.
    fn errh_release(e: Self::Errhandler) {
        let _ = e;
    }
    /// Likewise for freed info handles.
    fn info_release(i: Self::Info) {
        let _ = i;
    }
    /// Likewise for freed window handles.
    fn win_release(w: Self::Win) {
        let _ = w;
    }
    /// Likewise for finalized session handles.
    fn session_release(s: Self::Session) {
        let _ = s;
    }

    /// An empty status in this ABI's layout.
    fn status_empty() -> Self::Status;
    /// Convert the engine's status record into this ABI's layout.
    fn status_from_core(s: &StatusCore) -> Self::Status;
    /// Read `MPI_SOURCE` from this ABI's status layout.
    fn status_source(s: &Self::Status) -> i32;
    /// Read `MPI_TAG`.
    fn status_tag(s: &Self::Status) -> i32;
    /// Read `MPI_ERROR`.
    fn status_error(s: &Self::Status) -> i32;
    /// Read the cancelled flag.
    fn status_cancelled(s: &Self::Status) -> bool;
    /// Read the hidden received byte count.
    fn status_count_bytes(s: &Self::Status) -> u64;

    /// Encode a canonical error class into this ABI's error-code space.
    fn err_from_class(class: i32) -> i32;
    /// Decode this ABI's error code back to the canonical class.
    fn class_of_err(code: i32) -> i32;

    /// The ABI's fast `MPI_Type_size` mechanism (bit decode for MPICH,
    /// descriptor load for OMPI, Huffman decode + table for the standard
    /// ABI). `None` = take the slow engine path (derived datatypes).
    fn type_size_fast(d: Self::Datatype) -> Option<i32>;
}

/// Generic MPI implementation over a representation backend.
pub struct Backed<R: Repr>(PhantomData<R>);

// --- Shared glue -----------------------------------------------------------

/// Convert an engine error into this ABI's error code, running the comm's
/// error handler (fatal by default, per MPI).
fn fail<R: Repr>(comm: Option<CommId>, e: crate::core::MpiError) -> i32 {
    let class = match comm {
        Some(c) => {
            let h = comm::comm_get_errhandler(c).unwrap_or(crate::core::reserved::ERRH_ARE_FATAL);
            errh::invoke(c, h, e.class)
        }
        None => e.class,
    };
    R::err_from_class(class)
}

fn ret<R: Repr>(comm: Option<CommId>, r: RC<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => fail::<R>(comm, e),
    }
}

/// Canonicalize wildcard/special rank+tag inputs from ABI values to the
/// engine's (standard-ABI) values.
fn src_in<R: Repr>(src: i32) -> i32 {
    use crate::abi::constants as k;
    if src == R::c_any_source() {
        k::MPI_ANY_SOURCE
    } else if src == R::c_proc_null() {
        k::MPI_PROC_NULL
    } else {
        src
    }
}

fn dest_in<R: Repr>(dest: i32) -> i32 {
    use crate::abi::constants as k;
    if dest == R::c_proc_null() {
        k::MPI_PROC_NULL
    } else {
        dest
    }
}

fn tag_in<R: Repr>(tag: i32) -> i32 {
    use crate::abi::constants as k;
    if tag == R::c_any_tag() {
        k::MPI_ANY_TAG
    } else {
        tag
    }
}

/// De-canonicalize a status's source/error for this ABI.
fn status_out<R: Repr>(mut s: StatusCore) -> R::Status {
    use crate::abi::constants as k;
    if s.source == k::MPI_PROC_NULL {
        s.source = R::c_proc_null();
    } else if s.source == k::MPI_ANY_TAG {
        // never a source; keep
    }
    if s.tag == k::MPI_ANY_TAG {
        s.tag = R::c_any_tag();
    }
    if s.error != 0 {
        s.error = R::err_from_class(s.error);
    }
    R::status_from_core(&s)
}

/// Consume a completed request handle: release any per-handle allocation
/// and null it. Callers skip this for persistent requests, whose handles
/// stay valid across completion.
fn release_done<R: Repr>(req: &mut R::Request) {
    R::req_release(*req);
    *req = R::c_request_null();
}

/// Split an ABI request list into engine ids + their original indices,
/// skipping null handles — the shared front half of the any/some
/// completion family.
fn live_requests<R: Repr>(reqs: &[R::Request]) -> (Vec<ReqId>, Vec<usize>) {
    let null = R::c_request_null();
    let mut live = Vec::new();
    let mut map = Vec::new();
    for (i, &r) in reqs.iter().enumerate() {
        if r != null {
            if let Ok(id) = R::req_id(r) {
                live.push(id);
                map.push(i);
            }
        }
    }
    (live, map)
}

/// Write one completed entry of a waitsome/testsome result and release
/// the handle unless it is persistent (the shared back half).
fn some_outcome<R: Repr>(
    reqs: &mut [R::Request],
    live: &[ReqId],
    map: &[usize],
    done: Vec<(usize, StatusCore)>,
    outcount: &mut i32,
    indices: &mut [i32],
    statuses: &mut [R::Status],
) {
    *outcount = done.len() as i32;
    for (j, (k, s)) in done.into_iter().enumerate() {
        let i = map[k];
        if j < indices.len() {
            indices[j] = i as i32;
        }
        if j < statuses.len() {
            statuses[j] = status_out::<R>(s);
        }
        if !engine::request_is_persistent(live[k]) {
            release_done::<R>(&mut reqs[i]);
        }
    }
}

/// Canonicalize this ABI's window assertion bitmask to the engine's
/// (standard-ABI) bits.
fn assert_in<R: Repr>(a: i32) -> i32 {
    use crate::abi::constants as kc;
    let mut out = 0;
    if a & R::c_mode_nocheck() != 0 {
        out |= kc::MPI_MODE_NOCHECK;
    }
    if a & R::c_mode_nostore() != 0 {
        out |= kc::MPI_MODE_NOSTORE;
    }
    if a & R::c_mode_noput() != 0 {
        out |= kc::MPI_MODE_NOPUT;
    }
    if a & R::c_mode_noprecede() != 0 {
        out |= kc::MPI_MODE_NOPRECEDE;
    }
    if a & R::c_mode_nosucceed() != 0 {
        out |= kc::MPI_MODE_NOSUCCEED;
    }
    out
}

/// Canonicalize this ABI's lock-type constant.
fn lock_in<R: Repr>(lt: i32) -> i32 {
    use crate::abi::constants as kc;
    if lt == R::c_lock_exclusive() {
        kc::MPI_LOCK_EXCLUSIVE
    } else if lt == R::c_lock_shared() {
        kc::MPI_LOCK_SHARED
    } else {
        lt
    }
}

fn buf_in<R: Repr>(b: *const u8) -> *const u8 {
    if b == R::c_in_place() {
        crate::abi::constants::MPI_IN_PLACE as *const u8
    } else {
        b
    }
}

/// [`buf_in`] for receive buffers (the scatter family puts
/// `MPI_IN_PLACE` in `recvbuf`).
fn buf_in_mut<R: Repr>(b: *mut u8) -> *mut u8 {
    buf_in::<R>(b as *const u8) as *mut u8
}

macro_rules! conv {
    ($r:ident, $comm:expr, $e:expr) => {
        match $e {
            Ok(v) => v,
            Err(err) => return fail::<$r>($comm, err),
        }
    };
}

/// Store a nonblocking collective's engine request into the ABI's
/// request out-parameter (or run the comm's error handler).
macro_rules! coll_req {
    ($r:ident, $id:expr, $req:expr, $e:expr) => {
        match $e {
            Ok(rid) => {
                *$req = $r::req_h(rid);
                0
            }
            Err(err) => fail::<$r>(Some($id), err),
        }
    };
}

impl<R: Repr> MpiAbi for Backed<R> {
    const NAME: &'static str = R::NAME;

    type Comm = R::Comm;
    type Datatype = R::Datatype;
    type Op = R::Op;
    type Request = R::Request;
    type Group = R::Group;
    type Errhandler = R::Errhandler;
    type Info = R::Info;
    type Win = R::Win;
    type Session = R::Session;
    type Status = R::Status;

    fn comm_world() -> R::Comm {
        R::c_comm_world()
    }
    fn comm_self() -> R::Comm {
        R::c_comm_self()
    }
    fn comm_null() -> R::Comm {
        R::c_comm_null()
    }
    fn request_null() -> R::Request {
        R::c_request_null()
    }
    fn datatype(d: Dt) -> R::Datatype {
        R::c_datatype(d)
    }
    fn op(o: OpName) -> R::Op {
        R::c_op(o)
    }
    fn errhandler_return() -> R::Errhandler {
        R::c_errh_return()
    }
    fn errhandler_fatal() -> R::Errhandler {
        R::c_errh_fatal()
    }
    fn info_null() -> R::Info {
        R::c_info_null()
    }
    fn win_null() -> R::Win {
        R::c_win_null()
    }
    fn session_null() -> R::Session {
        R::c_session_null()
    }
    fn lock_exclusive() -> i32 {
        R::c_lock_exclusive()
    }
    fn lock_shared() -> i32 {
        R::c_lock_shared()
    }
    fn mode_nocheck() -> i32 {
        R::c_mode_nocheck()
    }
    fn mode_nostore() -> i32 {
        R::c_mode_nostore()
    }
    fn mode_noput() -> i32 {
        R::c_mode_noput()
    }
    fn mode_noprecede() -> i32 {
        R::c_mode_noprecede()
    }
    fn mode_nosucceed() -> i32 {
        R::c_mode_nosucceed()
    }
    fn any_source() -> i32 {
        R::c_any_source()
    }
    fn any_tag() -> i32 {
        R::c_any_tag()
    }
    fn proc_null() -> i32 {
        R::c_proc_null()
    }
    fn undefined() -> i32 {
        R::c_undefined()
    }
    fn in_place() -> *const u8 {
        R::c_in_place()
    }

    fn err_class_of(code: i32) -> i32 {
        R::class_of_err(code)
    }
    fn error_string(code: i32) -> String {
        crate::abi::errors::error_string(R::class_of_err(code)).to_string()
    }
    fn err_from_canonical(class: i32) -> i32 {
        R::err_from_class(class)
    }

    fn init() -> i32 {
        ret::<R>(None, engine::init())
    }
    fn finalize() -> i32 {
        ret::<R>(None, engine::finalize())
    }
    fn initialized() -> bool {
        engine::initialized()
    }
    fn finalized() -> bool {
        engine::finalized()
    }
    fn abort(_comm: R::Comm, code: i32) -> i32 {
        ret::<R>(None, engine::abort(code))
    }
    fn wtime() -> f64 {
        engine::wtime()
    }
    fn get_library_version() -> String {
        format!("{} [{} ABI]", engine::get_library_version(), R::NAME)
    }
    fn get_version() -> (i32, i32) {
        engine::get_version()
    }
    fn get_processor_name() -> String {
        engine::get_processor_name()
    }

    fn session_init(_info: R::Info, errh: R::Errhandler, out: &mut R::Session) -> i32 {
        // The info argument carries hints we don't consume; the error
        // handler converts like any other handle.
        let eid = conv!(R, None, R::errh_id(errh));
        match session::session_init(eid) {
            Ok(id) => {
                *out = R::session_h(id);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn session_finalize(s: &mut R::Session) -> i32 {
        let id = conv!(R, None, R::session_id(*s));
        let r = ret::<R>(None, session::session_finalize(id));
        if r == 0 {
            R::session_release(*s);
            *s = R::c_session_null();
        }
        r
    }

    fn session_get_num_psets(s: R::Session, out: &mut i32) -> i32 {
        let id = conv!(R, None, R::session_id(s));
        match session::session_num_psets(id) {
            Ok(v) => {
                *out = v;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn session_get_nth_pset(s: R::Session, n: i32, out: &mut String) -> i32 {
        let id = conv!(R, None, R::session_id(s));
        match session::session_nth_pset(id, n) {
            Ok(v) => {
                *out = v;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn session_get_pset_info(s: R::Session, pset: &str, out: &mut R::Info) -> i32 {
        let id = conv!(R, None, R::session_id(s));
        match session::session_pset_info(id, pset) {
            Ok(i) => {
                *out = R::info_h(i);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn group_from_session_pset(s: R::Session, pset: &str, out: &mut R::Group) -> i32 {
        let id = conv!(R, None, R::session_id(s));
        match session::group_from_pset(id, pset) {
            Ok(g) => {
                *out = R::group_h(g);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn comm_create_from_group(
        group: R::Group,
        stringtag: &str,
        _info: R::Info,
        errh: R::Errhandler,
        out: &mut R::Comm,
    ) -> i32 {
        let gid = conv!(R, None, R::group_id(group));
        let eid = conv!(R, None, R::errh_id(errh));
        // Validate the errhandler *before* the collective agreement: a
        // bit-valid-but-dead handle must not error on one rank after
        // the others have already completed the creation.
        if !errh::errhandler_exists(eid) {
            return fail::<R>(None, crate::core::MpiError::new(crate::abi::errors::MPI_ERR_ARG));
        }
        match session::comm_create_from_group(gid, stringtag) {
            Ok(new) => {
                if let Err(e) = comm::comm_set_errhandler(new, eid) {
                    return fail::<R>(None, e);
                }
                *out = R::comm_h(new);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn status_empty() -> R::Status {
        R::status_empty()
    }
    fn status_source(s: &R::Status) -> i32 {
        R::status_source(s)
    }
    fn status_tag(s: &R::Status) -> i32 {
        R::status_tag(s)
    }
    fn status_error(s: &R::Status) -> i32 {
        R::status_error(s)
    }
    fn status_cancelled(s: &R::Status) -> bool {
        R::status_cancelled(s)
    }
    fn get_count(s: &R::Status, dt: R::Datatype) -> i32 {
        let Ok(id) = R::dt_id(dt) else { return R::c_undefined() };
        let Ok(size) = datatype::type_size(id) else { return R::c_undefined() };
        if size == 0 {
            return 0;
        }
        let bytes = R::status_count_bytes(s);
        if bytes % size as u64 != 0 {
            R::c_undefined()
        } else if bytes / size as u64 > i32::MAX as u64 {
            // MPI-4.1 §3.2.5: the count does not fit in an `int` — the
            // classic entry point reports MPI_UNDEFINED; `get_count_c`
            // is the lossless path.
            R::c_undefined()
        } else {
            (bytes / size as u64) as i32
        }
    }

    fn get_elements(s: &R::Status, dt: R::Datatype) -> i32 {
        let Ok(id) = R::dt_id(dt) else { return R::c_undefined() };
        let mut core = StatusCore::empty();
        core.count_bytes = R::status_count_bytes(s);
        match engine::get_elements(&core, id) {
            Ok(v) if v == crate::abi::constants::MPI_UNDEFINED => R::c_undefined(),
            Ok(v) => v,
            Err(_) => R::c_undefined(),
        }
    }

    fn send_c(buf: *const u8, count: Count, dt: R::Datatype, dest: i32, tag: i32, c: R::Comm)
        -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        if count < 0 {
            return fail::<R>(Some(id),
                crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        }
        ret::<R>(
            Some(id),
            engine::send(buf, count as usize, d, dest_in::<R>(dest), tag, id,
                engine::SendMode::Standard),
        )
    }

    fn recv_c(
        buf: *mut u8,
        count: Count,
        dt: R::Datatype,
        src: i32,
        tag: i32,
        c: R::Comm,
        status: &mut R::Status,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        if count < 0 {
            return fail::<R>(Some(id),
                crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        }
        match engine::recv(buf, count as usize, d, src_in::<R>(src), tag_in::<R>(tag), id) {
            Ok(s) => {
                *status = status_out::<R>(s);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn get_count_c(s: &R::Status, dt: R::Datatype, out: &mut Count) -> i32 {
        let id = conv!(R, None, R::dt_id(dt));
        let size = conv!(R, None, datatype::type_size(id));
        let bytes = R::status_count_bytes(s);
        *out = if size == 0 {
            0
        } else if bytes % size as u64 != 0 {
            R::c_undefined() as Count
        } else {
            (bytes / size as u64) as Count
        };
        0
    }

    fn get_elements_c(s: &R::Status, dt: R::Datatype, out: &mut Count) -> i32 {
        let id = conv!(R, None, R::dt_id(dt));
        let mut core = StatusCore::empty();
        core.count_bytes = R::status_count_bytes(s);
        match engine::get_elements_c(&core, id) {
            Ok(v) if v == crate::abi::constants::MPI_UNDEFINED as Count => {
                *out = R::c_undefined() as Count;
                0
            }
            Ok(v) => {
                *out = v;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn status_set_elements_c(s: &mut R::Status, dt: R::Datatype, count: Count) -> i32 {
        let id = conv!(R, None, R::dt_id(dt));
        let size = conv!(R, None, datatype::type_size(id));
        if count < 0 {
            return fail::<R>(None,
                crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        }
        let Some(bytes) = (count as u64).checked_mul(size as u64) else {
            return fail::<R>(None,
                crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        };
        // Round-trip through the ABI layout: keep source/tag/error/
        // cancelled, replace the hidden byte count.
        let mut core = StatusCore::empty();
        core.source = R::status_source(s);
        core.tag = R::status_tag(s);
        core.error = R::status_error(s);
        core.cancelled = R::status_cancelled(s);
        core.count_bytes = bytes;
        *s = R::status_from_core(&core);
        0
    }

    fn type_size_c(dt: R::Datatype, out: &mut Count) -> i32 {
        if let Some(s) = R::type_size_fast(dt) {
            *out = s as Count;
            return 0;
        }
        let id = conv!(R, None, R::dt_id(dt));
        match datatype::type_size(id) {
            Ok(v) => {
                *out = v as Count;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn type_contiguous_c(count: Count, child: R::Datatype, out: &mut R::Datatype) -> i32 {
        let id = conv!(R, None, R::dt_id(child));
        if count < 0 {
            return fail::<R>(None,
                crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        }
        match datatype::type_contiguous(count as usize, id) {
            Ok(n) => {
                *out = R::dt_h(n);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn type_vector_c(
        count: Count,
        blocklen: Count,
        stride: Count,
        child: R::Datatype,
        out: &mut R::Datatype,
    ) -> i32 {
        let id = conv!(R, None, R::dt_id(child));
        if count < 0 || blocklen < 0 {
            return fail::<R>(None,
                crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        }
        match datatype::type_vector(count as usize, blocklen as usize, stride as isize, id) {
            Ok(n) => {
                *out = R::dt_h(n);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn allgatherv_c(
        sendbuf: *const u8,
        sendcount: Count,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcounts: Counts<'_>,
        displs: Displs<'_>,
        recvtype: R::Datatype,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        if sendcount < 0 {
            return fail::<R>(Some(id),
                crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        }
        let counts = recvcounts.to_counts();
        let disps = displs.to_aints();
        ret::<R>(
            Some(id),
            coll::allgatherv_c(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf, &counts,
                &disps, rd, id),
        )
    }

    fn comm_size(c: R::Comm, out: &mut i32) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match comm::comm_size(id) {
            Ok(v) => {
                *out = v;
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_rank(c: R::Comm, out: &mut i32) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match comm::comm_rank(id) {
            Ok(v) => {
                *out = v;
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_dup(c: R::Comm, out: &mut R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match engine::comm_dup(id) {
            Ok(new) => {
                *out = R::comm_h(new);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_split(c: R::Comm, color: i32, key: i32, out: &mut R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let color = if color == R::c_undefined() {
            crate::abi::constants::MPI_UNDEFINED
        } else {
            color
        };
        match engine::comm_split(id, color, key) {
            Ok(Some(new)) => {
                *out = R::comm_h(new);
                0
            }
            Ok(None) => {
                *out = R::c_comm_null();
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_split_type(c: R::Comm, split_type: i32, key: i32, out: &mut R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        // Translate this ABI's split-type numbering to canonical before
        // the engine sees it (checked before shared: OMPI's shared
        // value is 0, which no ABI uses for undefined).
        let split_type = if split_type == R::c_undefined() {
            crate::abi::constants::MPI_UNDEFINED
        } else if split_type == R::c_comm_type_shared() {
            crate::abi::constants::MPI_COMM_TYPE_SHARED
        } else {
            split_type
        };
        match engine::comm_split_type(id, split_type, key) {
            Ok(Some(new)) => {
                *out = R::comm_h(new);
                0
            }
            Ok(None) => {
                *out = R::c_comm_null();
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_free(c: &mut R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(*c));
        let r = ret::<R>(Some(id), comm::comm_free(id));
        if r == 0 {
            R::comm_release(*c);
            *c = R::c_comm_null();
        }
        r
    }

    fn comm_compare(a: R::Comm, b: R::Comm, out: &mut i32) -> i32 {
        let ia = conv!(R, None, R::comm_id(a));
        let ib = conv!(R, None, R::comm_id(b));
        match comm::comm_compare(ia, ib) {
            Ok(v) => {
                *out = v;
                0
            }
            Err(e) => fail::<R>(Some(ia), e),
        }
    }

    fn comm_set_name(c: R::Comm, name: &str) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        ret::<R>(Some(id), comm::comm_set_name(id, name))
    }

    fn comm_get_name(c: R::Comm, out: &mut String) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match comm::comm_get_name(id) {
            Ok(v) => {
                *out = v;
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_group(c: R::Comm, out: &mut R::Group) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match comm::comm_group(id) {
            Ok(g) => {
                *out = R::group_h(g);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn group_size(g: R::Group, out: &mut i32) -> i32 {
        let id = conv!(R, None, R::group_id(g));
        match group::group_size(id) {
            Ok(v) => {
                *out = v;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn group_rank(g: R::Group, out: &mut i32) -> i32 {
        let id = conv!(R, None, R::group_id(g));
        match group::group_rank(id) {
            Ok(v) => {
                *out = if v == crate::abi::constants::MPI_UNDEFINED { R::c_undefined() } else { v };
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn group_incl(g: R::Group, ranks: &[i32], out: &mut R::Group) -> i32 {
        let id = conv!(R, None, R::group_id(g));
        match group::group_incl(id, ranks) {
            Ok(n) => {
                *out = R::group_h(n);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn group_translate_ranks(a: R::Group, ranks: &[i32], b: R::Group, out: &mut [i32]) -> i32 {
        let ia = conv!(R, None, R::group_id(a));
        let ib = conv!(R, None, R::group_id(b));
        let canon: Vec<i32> = ranks.iter().map(|&r| src_in::<R>(r)).collect();
        match group::group_translate_ranks(ia, &canon, ib) {
            Ok(v) => {
                for (o, x) in out.iter_mut().zip(v) {
                    *o = if x == crate::abi::constants::MPI_UNDEFINED {
                        R::c_undefined()
                    } else if x == crate::abi::constants::MPI_PROC_NULL {
                        R::c_proc_null()
                    } else {
                        x
                    };
                }
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn group_free(g: &mut R::Group) -> i32 {
        let id = conv!(R, None, R::group_id(*g));
        let r = ret::<R>(None, group::group_free(id));
        if r == 0 {
            R::group_release(*g);
        }
        r
    }

    fn comm_set_errhandler(c: R::Comm, e: R::Errhandler) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let eid = conv!(R, Some(id), R::errh_id(e));
        ret::<R>(Some(id), comm::comm_set_errhandler(id, eid))
    }

    fn comm_get_errhandler(c: R::Comm, out: &mut R::Errhandler) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match comm::comm_get_errhandler(id) {
            Ok(e) => {
                *out = R::errh_h(e);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_create_errhandler(f: ErrhFn<Self>, out: &mut R::Errhandler) -> i32 {
        // The closure converts the engine comm id + canonical class into
        // *this ABI's* representation before invoking the user callback.
        let g = Box::new(move |c: CommId, class: i32| {
            f(R::comm_h(c), R::err_from_class(class));
        });
        match errh::errhandler_create(g) {
            Ok(id) => {
                *out = R::errh_h(id);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn errhandler_free(e: &mut R::Errhandler) -> i32 {
        let id = conv!(R, None, R::errh_id(*e));
        let r = ret::<R>(None, errh::errhandler_free(id));
        if r == 0 {
            R::errh_release(*e);
        }
        r
    }

    fn comm_revoke(c: R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        ret::<R>(Some(id), engine::comm_revoke(id))
    }

    fn comm_is_revoked(c: R::Comm, out: &mut bool) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match engine::comm_is_revoked(id) {
            Ok(v) => {
                *out = v;
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_shrink(c: R::Comm, out: &mut R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match engine::comm_shrink(id) {
            Ok(new) => {
                *out = R::comm_h(new);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_agree(c: R::Comm, flag: &mut i32) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match engine::comm_agree(id, *flag) {
            Ok(v) => {
                *flag = v;
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_ack_failed(c: R::Comm, num_to_ack: i32, num_acked: &mut i32) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match engine::comm_ack_failed(id, num_to_ack) {
            Ok(n) => {
                *num_acked = n;
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn send(buf: *const u8, count: i32, dt: R::Datatype, dest: i32, tag: i32, c: R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        ret::<R>(
            Some(id),
            engine::send(buf, count as usize, d, dest_in::<R>(dest), tag, id,
                engine::SendMode::Standard),
        )
    }

    fn ssend(buf: *const u8, count: i32, dt: R::Datatype, dest: i32, tag: i32, c: R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        ret::<R>(
            Some(id),
            engine::send(buf, count as usize, d, dest_in::<R>(dest), tag, id,
                engine::SendMode::Sync),
        )
    }

    fn recv(
        buf: *mut u8,
        count: i32,
        dt: R::Datatype,
        src: i32,
        tag: i32,
        c: R::Comm,
        status: &mut R::Status,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        match engine::recv(buf, count as usize, d, src_in::<R>(src), tag_in::<R>(tag), id) {
            Ok(s) => {
                *status = status_out::<R>(s);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn isend(
        buf: *const u8,
        count: i32,
        dt: R::Datatype,
        dest: i32,
        tag: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        match engine::isend(buf, count as usize, d, dest_in::<R>(dest), tag, id,
            engine::SendMode::Standard)
        {
            Ok(r) => {
                *req = R::req_h(r);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn issend(
        buf: *const u8,
        count: i32,
        dt: R::Datatype,
        dest: i32,
        tag: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        match engine::isend(buf, count as usize, d, dest_in::<R>(dest), tag, id,
            engine::SendMode::Sync)
        {
            Ok(r) => {
                *req = R::req_h(r);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn irecv(
        buf: *mut u8,
        count: i32,
        dt: R::Datatype,
        src: i32,
        tag: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        match engine::irecv(buf, count as usize, d, src_in::<R>(src), tag_in::<R>(tag), id) {
            Ok(r) => {
                *req = R::req_h(r);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn wait(req: &mut R::Request, status: &mut R::Status) -> i32 {
        if *req == R::c_request_null() {
            *status = R::status_empty();
            return 0;
        }
        let id = conv!(R, None, R::req_id(*req));
        match engine::wait(id) {
            Ok(s) => {
                // Persistent requests survive completion (back to
                // Inactive) and keep their handle; retired nonpersistent
                // ids are gone by now and report false.
                if !engine::request_is_persistent(id) {
                    release_done::<R>(req);
                }
                *status = status_out::<R>(s);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn test(req: &mut R::Request, flag: &mut bool, status: &mut R::Status) -> i32 {
        if *req == R::c_request_null() {
            *flag = true;
            *status = R::status_empty();
            return 0;
        }
        let id = conv!(R, None, R::req_id(*req));
        match engine::test(id) {
            Ok(Some(s)) => {
                if !engine::request_is_persistent(id) {
                    release_done::<R>(req);
                }
                *flag = true;
                *status = status_out::<R>(s);
                0
            }
            Ok(None) => {
                *flag = false;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn waitall(reqs: &mut [R::Request], statuses: &mut [R::Status]) -> i32 {
        let null = R::c_request_null();
        let ids: Vec<Option<ReqId>> = reqs
            .iter()
            .map(|&r| if r == null { None } else { R::req_id(r).ok() })
            .collect();
        let live: Vec<ReqId> = ids.iter().flatten().copied().collect();
        match engine::waitall(&live) {
            Ok(ss) => {
                let mut it = ss.into_iter();
                for (i, id) in ids.iter().enumerate() {
                    if let Some(rid) = id {
                        let s = it.next().unwrap();
                        if i < statuses.len() {
                            statuses[i] = status_out::<R>(s);
                        }
                        // Queried after the wait: persistent requests
                        // survive in the table; retired ones are gone
                        // and report false.
                        if !engine::request_is_persistent(*rid) {
                            release_done::<R>(&mut reqs[i]);
                        }
                    } else if i < statuses.len() {
                        statuses[i] = R::status_empty();
                    }
                }
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn testall(reqs: &mut [R::Request], flag: &mut bool, statuses: &mut [R::Status]) -> i32 {
        let null = R::c_request_null();
        let ids: Vec<Option<ReqId>> = reqs
            .iter()
            .map(|&r| if r == null { None } else { R::req_id(r).ok() })
            .collect();
        let live: Vec<ReqId> = ids.iter().flatten().copied().collect();
        match engine::testall(&live) {
            Ok(Some(ss)) => {
                *flag = true;
                let mut it = ss.into_iter();
                for (i, id) in ids.iter().enumerate() {
                    if let Some(rid) = id {
                        let s = it.next().unwrap();
                        if i < statuses.len() {
                            statuses[i] = status_out::<R>(s);
                        }
                        if !engine::request_is_persistent(*rid) {
                            release_done::<R>(&mut reqs[i]);
                        }
                    } else if i < statuses.len() {
                        statuses[i] = R::status_empty();
                    }
                }
                0
            }
            Ok(None) => {
                *flag = false;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn waitany(reqs: &mut [R::Request], index: &mut i32, status: &mut R::Status) -> i32 {
        let (live, map) = live_requests::<R>(reqs);
        if live.is_empty() {
            *index = R::c_undefined();
            *status = R::status_empty();
            return 0;
        }
        match engine::waitany(&live) {
            Ok(Some((k, s))) => {
                let i = map[k];
                *index = i as i32;
                *status = status_out::<R>(s);
                if !engine::request_is_persistent(live[k]) {
                    release_done::<R>(&mut reqs[i]);
                }
                0
            }
            // Every live request is an inactive persistent one: nothing
            // to wait for (MPI 3.0 §3.7.5).
            Ok(None) => {
                *index = R::c_undefined();
                *status = R::status_empty();
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn testany(
        reqs: &mut [R::Request],
        index: &mut i32,
        flag: &mut bool,
        status: &mut R::Status,
    ) -> i32 {
        let (live, map) = live_requests::<R>(reqs);
        if live.is_empty() {
            *flag = true;
            *index = R::c_undefined();
            *status = R::status_empty();
            return 0;
        }
        match engine::testany(&live) {
            Ok(engine::TestAnyOutcome::Completed(k, s)) => {
                let i = map[k];
                *flag = true;
                *index = i as i32;
                *status = status_out::<R>(s);
                if !engine::request_is_persistent(live[k]) {
                    release_done::<R>(&mut reqs[i]);
                }
                0
            }
            Ok(engine::TestAnyOutcome::NoneActive) => {
                *flag = true;
                *index = R::c_undefined();
                *status = R::status_empty();
                0
            }
            Ok(engine::TestAnyOutcome::Pending) => {
                *flag = false;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn waitsome(
        reqs: &mut [R::Request],
        outcount: &mut i32,
        indices: &mut [i32],
        statuses: &mut [R::Status],
    ) -> i32 {
        let (live, map) = live_requests::<R>(reqs);
        if live.is_empty() {
            *outcount = R::c_undefined();
            return 0;
        }
        match engine::waitsome(&live) {
            Ok(Some(done)) => {
                some_outcome::<R>(reqs, &live, &map, done, outcount, indices, statuses);
                0
            }
            Ok(None) => {
                *outcount = R::c_undefined();
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn testsome(
        reqs: &mut [R::Request],
        outcount: &mut i32,
        indices: &mut [i32],
        statuses: &mut [R::Status],
    ) -> i32 {
        let (live, map) = live_requests::<R>(reqs);
        if live.is_empty() {
            *outcount = R::c_undefined();
            return 0;
        }
        match engine::testsome(&live) {
            Ok(Some(done)) => {
                some_outcome::<R>(reqs, &live, &map, done, outcount, indices, statuses);
                0
            }
            Ok(None) => {
                *outcount = R::c_undefined();
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn probe(src: i32, tag: i32, c: R::Comm, status: &mut R::Status) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match engine::probe(src_in::<R>(src), tag_in::<R>(tag), id) {
            Ok(s) => {
                *status = status_out::<R>(s);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn iprobe(src: i32, tag: i32, c: R::Comm, flag: &mut bool, status: &mut R::Status) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match engine::iprobe(src_in::<R>(src), tag_in::<R>(tag), id) {
            Ok(Some(s)) => {
                *flag = true;
                *status = status_out::<R>(s);
                0
            }
            Ok(None) => {
                *flag = false;
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn cancel(req: &mut R::Request) -> i32 {
        let id = conv!(R, None, R::req_id(*req));
        ret::<R>(None, crate::core::request::cancel(id))
    }

    fn request_free(req: &mut R::Request) -> i32 {
        let id = conv!(R, None, R::req_id(*req));
        let r = ret::<R>(None, crate::core::request::request_free(id));
        if r == 0 {
            R::req_release(*req);
            *req = R::c_request_null();
        }
        r
    }

    fn sendrecv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        dest: i32,
        sendtag: i32,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        src: i32,
        recvtag: i32,
        c: R::Comm,
        status: &mut R::Status,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        match engine::sendrecv(
            sendbuf,
            sendcount as usize,
            sd,
            dest_in::<R>(dest),
            sendtag,
            recvbuf,
            recvcount as usize,
            rd,
            src_in::<R>(src),
            tag_in::<R>(recvtag),
            id,
        ) {
            Ok(s) => {
                *status = status_out::<R>(s);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn send_init(
        buf: *const u8,
        count: i32,
        dt: R::Datatype,
        dest: i32,
        tag: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        match engine::send_init(buf, count as usize, d, dest_in::<R>(dest), tag, id,
            engine::SendMode::Standard)
        {
            Ok(r) => {
                *req = R::req_h(r);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn ssend_init(
        buf: *const u8,
        count: i32,
        dt: R::Datatype,
        dest: i32,
        tag: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        match engine::send_init(buf, count as usize, d, dest_in::<R>(dest), tag, id,
            engine::SendMode::Sync)
        {
            Ok(r) => {
                *req = R::req_h(r);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn recv_init(
        buf: *mut u8,
        count: i32,
        dt: R::Datatype,
        src: i32,
        tag: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        match engine::recv_init(buf, count as usize, d, src_in::<R>(src), tag_in::<R>(tag), id) {
            Ok(r) => {
                *req = R::req_h(r);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn start(req: &mut R::Request) -> i32 {
        let id = conv!(R, None, R::req_id(*req));
        ret::<R>(None, engine::start(id))
    }

    fn startall(reqs: &mut [R::Request]) -> i32 {
        let mut ids = Vec::with_capacity(reqs.len());
        for &r in reqs.iter() {
            ids.push(conv!(R, None, R::req_id(r)));
        }
        ret::<R>(None, engine::startall(&ids))
    }

    fn type_size(dt: R::Datatype, out: &mut i32) -> i32 {
        // The §6.1 fast path: representation-specific size decode.
        if let Some(s) = R::type_size_fast(dt) {
            *out = s;
            return 0;
        }
        let id = conv!(R, None, R::dt_id(dt));
        match datatype::type_size(id) {
            Ok(v) => {
                *out = v as i32;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn type_get_extent(dt: R::Datatype, lb: &mut isize, extent: &mut isize) -> i32 {
        let id = conv!(R, None, R::dt_id(dt));
        match datatype::type_get_extent(id) {
            Ok((l, e)) => {
                *lb = l;
                *extent = e;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn type_contiguous(count: i32, child: R::Datatype, out: &mut R::Datatype) -> i32 {
        let id = conv!(R, None, R::dt_id(child));
        match datatype::type_contiguous(count as usize, id) {
            Ok(n) => {
                *out = R::dt_h(n);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn type_vector(
        count: i32,
        blocklen: i32,
        stride: i32,
        child: R::Datatype,
        out: &mut R::Datatype,
    ) -> i32 {
        let id = conv!(R, None, R::dt_id(child));
        match datatype::type_vector(count as usize, blocklen as usize, stride as isize, id) {
            Ok(n) => {
                *out = R::dt_h(n);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn type_create_struct(blocks: &[(i32, isize, R::Datatype)], out: &mut R::Datatype) -> i32 {
        let mut conv_blocks = Vec::with_capacity(blocks.len());
        for &(len, disp, t) in blocks {
            let id = conv!(R, None, R::dt_id(t));
            conv_blocks.push((len as usize, disp, id));
        }
        match datatype::type_struct(&conv_blocks) {
            Ok(n) => {
                *out = R::dt_h(n);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn type_commit(dt: &mut R::Datatype) -> i32 {
        let id = conv!(R, None, R::dt_id(*dt));
        ret::<R>(None, datatype::type_commit(id))
    }

    fn type_free(dt: &mut R::Datatype) -> i32 {
        let id = conv!(R, None, R::dt_id(*dt));
        let r = ret::<R>(None, datatype::type_free(id));
        if r == 0 {
            R::dt_release(*dt);
        }
        r
    }

    fn type_dup(dt: R::Datatype, out: &mut R::Datatype) -> i32 {
        let id = conv!(R, None, R::dt_id(dt));
        match datatype::type_dup(id) {
            Ok(n) => {
                *out = R::dt_h(n);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn op_create(f: UserOpFn<Self>, commute: bool, out: &mut R::Op) -> i32 {
        // Representation conversion for the callback's datatype argument
        // happens inside the library (closures allowed here; only
        // *external* layers like Mukautuva need static trampolines).
        let g: crate::core::op::UserOpFn = Box::new(move |inv, inout, len, dtid| {
            f(inv, inout, len, R::dt_h(dtid));
        });
        match op::op_create(g, commute) {
            Ok(id) => {
                *out = R::op_h(id);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn op_free(o: &mut R::Op) -> i32 {
        let id = conv!(R, None, R::op_id(*o));
        let r = ret::<R>(None, op::op_free(id));
        if r == 0 {
            R::op_release(*o);
        }
        r
    }

    fn barrier(c: R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        ret::<R>(Some(id), coll::barrier(id))
    }

    fn bcast(buf: *mut u8, count: i32, dt: R::Datatype, root: i32, c: R::Comm) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        ret::<R>(Some(id), coll::bcast(buf, count as usize, d, root, id))
    }

    fn reduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: R::Datatype,
        o: R::Op,
        root: i32,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        ret::<R>(Some(id), coll::reduce(buf_in::<R>(sendbuf), recvbuf, count as usize, d, oid,
            root, id))
    }

    fn allreduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: R::Datatype,
        o: R::Op,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        ret::<R>(Some(id), coll::allreduce(buf_in::<R>(sendbuf), recvbuf, count as usize, d, oid,
            id))
    }

    fn gather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        root: i32,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        ret::<R>(
            Some(id),
            coll::gather(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf,
                recvcount as usize, rd, root, id),
        )
    }

    fn scatter(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        root: i32,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        let rb = buf_in_mut::<R>(recvbuf);
        ret::<R>(
            Some(id),
            coll::scatter(sendbuf, sendcount as usize, sd, rb, recvcount as usize, rd, root, id),
        )
    }

    fn allgather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        ret::<R>(
            Some(id),
            coll::allgather(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf,
                recvcount as usize, rd, id),
        )
    }

    fn alltoall(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        ret::<R>(
            Some(id),
            coll::alltoall(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf,
                recvcount as usize, rd, id),
        )
    }

    fn alltoallw(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtypes: &[R::Datatype],
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtypes: &[R::Datatype],
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let args = conv!(
            R,
            Some(id),
            build_w_args::<R>(
                sendbuf, sendcounts, sdispls, sendtypes, recvbuf, recvcounts, rdispls, recvtypes
            )
        );
        ret::<R>(Some(id), coll::alltoallw(&args, id))
    }

    fn ialltoallw(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtypes: &[R::Datatype],
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtypes: &[R::Datatype],
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let args = conv!(
            R,
            Some(id),
            build_w_args::<R>(
                sendbuf, sendcounts, sdispls, sendtypes, recvbuf, recvcounts, rdispls, recvtypes
            )
        );
        match coll::ialltoallw(&args, id) {
            Ok(r) => {
                *req = R::req_h(r);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn scan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: R::Datatype,
        o: R::Op,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        ret::<R>(Some(id), coll::scan(buf_in::<R>(sendbuf), recvbuf, count as usize, d, oid, id))
    }

    fn exscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: R::Datatype,
        o: R::Op,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        ret::<R>(Some(id), coll::exscan(buf_in::<R>(sendbuf), recvbuf, count as usize, d, oid, id))
    }

    fn reduce_scatter_block(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        recvcount: i32,
        dt: R::Datatype,
        o: R::Op,
        c: R::Comm,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        ret::<R>(
            Some(id),
            coll::reduce_scatter_block(buf_in::<R>(sendbuf), recvbuf, recvcount as usize, d, oid,
                id),
        )
    }

    fn ibarrier(c: R::Comm, req: &mut R::Request) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        coll_req!(R, id, req, coll::ibarrier(id))
    }

    fn ibcast(
        buf: *mut u8,
        count: i32,
        dt: R::Datatype,
        root: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        coll_req!(R, id, req, coll::ibcast(buf, count as usize, d, root, id))
    }

    fn ireduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: R::Datatype,
        o: R::Op,
        root: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        coll_req!(R, id, req,
            coll::ireduce(buf_in::<R>(sendbuf), recvbuf, count as usize, d, oid, root, id))
    }

    fn iallreduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: R::Datatype,
        o: R::Op,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        coll_req!(R, id, req,
            coll::iallreduce(buf_in::<R>(sendbuf), recvbuf, count as usize, d, oid, id))
    }

    fn igather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        root: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        coll_req!(R, id, req,
            coll::igather(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf,
                recvcount as usize, rd, root, id))
    }

    fn igatherv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        displs: &[i32],
        recvtype: R::Datatype,
        root: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        let counts: Vec<usize> = recvcounts.iter().map(|&x| x as usize).collect();
        let disp: Vec<isize> = displs.iter().map(|&x| x as isize).collect();
        coll_req!(R, id, req,
            coll::igatherv(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf, &counts,
                &disp, rd, root, id))
    }

    fn iscatter(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        root: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        let rb = buf_in_mut::<R>(recvbuf);
        coll_req!(R, id, req,
            coll::iscatter(sendbuf, sendcount as usize, sd, rb, recvcount as usize, rd, root,
                id))
    }

    fn iscatterv(
        sendbuf: *const u8,
        sendcounts: &[i32],
        displs: &[i32],
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        root: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        let counts: Vec<usize> = sendcounts.iter().map(|&x| x as usize).collect();
        let disp: Vec<isize> = displs.iter().map(|&x| x as isize).collect();
        let rb = buf_in_mut::<R>(recvbuf);
        coll_req!(R, id, req,
            coll::iscatterv(sendbuf, &counts, &disp, sd, rb, recvcount as usize, rd, root, id))
    }

    fn iallgather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        coll_req!(R, id, req,
            coll::iallgather(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf,
                recvcount as usize, rd, id))
    }

    fn iallgatherv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        displs: &[i32],
        recvtype: R::Datatype,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        let counts: Vec<usize> = recvcounts.iter().map(|&x| x as usize).collect();
        let disp: Vec<isize> = displs.iter().map(|&x| x as isize).collect();
        coll_req!(R, id, req,
            coll::iallgatherv(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf, &counts,
                &disp, rd, id))
    }

    fn ialltoall(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        coll_req!(R, id, req,
            coll::ialltoall(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf,
                recvcount as usize, rd, id))
    }

    fn ialltoallv(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtype: R::Datatype,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        let sc: Vec<usize> = sendcounts.iter().map(|&x| x as usize).collect();
        let sdisp: Vec<isize> = sdispls.iter().map(|&x| x as isize).collect();
        let rc: Vec<usize> = recvcounts.iter().map(|&x| x as usize).collect();
        let rdisp: Vec<isize> = rdispls.iter().map(|&x| x as isize).collect();
        coll_req!(R, id, req,
            coll::ialltoallv(buf_in::<R>(sendbuf), &sc, &sdisp, sd, recvbuf, &rc, &rdisp, rd,
                id))
    }

    fn iscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: R::Datatype,
        o: R::Op,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        coll_req!(R, id, req,
            coll::iscan(buf_in::<R>(sendbuf), recvbuf, count as usize, d, oid, id))
    }

    fn iexscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: R::Datatype,
        o: R::Op,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        coll_req!(R, id, req,
            coll::iexscan(buf_in::<R>(sendbuf), recvbuf, count as usize, d, oid, id))
    }

    fn ireduce_scatter_block(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        recvcount: i32,
        dt: R::Datatype,
        o: R::Op,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        coll_req!(R, id, req,
            coll::ireduce_scatter_block(buf_in::<R>(sendbuf), recvbuf, recvcount as usize, d,
                oid, id))
    }

    fn barrier_init(c: R::Comm, req: &mut R::Request) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        coll_req!(R, id, req, coll::barrier_init(id))
    }

    fn bcast_init(
        buf: *mut u8,
        count: i32,
        dt: R::Datatype,
        root: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        coll_req!(R, id, req, coll::bcast_init(buf, count as usize, d, root, id))
    }

    fn allreduce_init(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: R::Datatype,
        o: R::Op,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let d = conv!(R, Some(id), R::dt_id(dt));
        let oid = conv!(R, Some(id), R::op_id(o));
        coll_req!(R, id, req,
            coll::allreduce_init(buf_in::<R>(sendbuf), recvbuf, count as usize, d, oid, id))
    }

    fn gather_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        root: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        coll_req!(R, id, req,
            coll::gather_init(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf,
                recvcount as usize, rd, root, id))
    }

    fn scatter_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        root: i32,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        let rb = buf_in_mut::<R>(recvbuf);
        coll_req!(R, id, req,
            coll::scatter_init(sendbuf, sendcount as usize, sd, rb, recvcount as usize, rd,
                root, id))
    }

    fn alltoall_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: R::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: R::Datatype,
        c: R::Comm,
        req: &mut R::Request,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        let sd = conv!(R, Some(id), R::dt_id(sendtype));
        let rd = conv!(R, Some(id), R::dt_id(recvtype));
        coll_req!(R, id, req,
            coll::alltoall_init(buf_in::<R>(sendbuf), sendcount as usize, sd, recvbuf,
                recvcount as usize, rd, id))
    }

    fn win_create(
        base: *mut u8,
        size: Aint,
        disp_unit: i32,
        _info: R::Info,
        c: R::Comm,
        win: &mut R::Win,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        if size < 0 {
            return fail::<R>(Some(id), crate::core::MpiError::new(crate::abi::errors::MPI_ERR_SIZE));
        }
        if disp_unit <= 0 {
            return fail::<R>(Some(id), crate::core::MpiError::new(crate::abi::errors::MPI_ERR_DISP));
        }
        match rma::win_create(base as usize, size as usize, disp_unit as usize, id) {
            Ok(w) => {
                *win = R::win_h(w);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn win_allocate(
        size: Aint,
        disp_unit: i32,
        _info: R::Info,
        c: R::Comm,
        baseptr: &mut *mut u8,
        win: &mut R::Win,
    ) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        if size < 0 {
            return fail::<R>(Some(id), crate::core::MpiError::new(crate::abi::errors::MPI_ERR_SIZE));
        }
        if disp_unit <= 0 {
            return fail::<R>(Some(id), crate::core::MpiError::new(crate::abi::errors::MPI_ERR_DISP));
        }
        match rma::win_allocate(size as usize, disp_unit as usize, id) {
            Ok((w, base)) => {
                *baseptr = base as *mut u8;
                *win = R::win_h(w);
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn win_free(win: &mut R::Win) -> i32 {
        let id = conv!(R, None, R::win_id(*win));
        let r = ret::<R>(None, rma::win_free(id));
        if r == 0 {
            R::win_release(*win);
            *win = R::c_win_null();
        }
        r
    }

    fn win_fence(assert: i32, win: R::Win) -> i32 {
        let id = conv!(R, None, R::win_id(win));
        ret::<R>(None, rma::win_fence(assert_in::<R>(assert), id))
    }

    fn win_lock(lock_type: i32, rank: i32, assert: i32, win: R::Win) -> i32 {
        let id = conv!(R, None, R::win_id(win));
        ret::<R>(None, rma::win_lock(lock_in::<R>(lock_type), rank, assert_in::<R>(assert), id))
    }

    fn win_unlock(rank: i32, win: R::Win) -> i32 {
        let id = conv!(R, None, R::win_id(win));
        ret::<R>(None, rma::win_unlock(rank, id))
    }

    fn win_flush(rank: i32, win: R::Win) -> i32 {
        let id = conv!(R, None, R::win_id(win));
        ret::<R>(None, rma::win_flush(rank, id))
    }

    fn put(
        origin: *const u8,
        origin_count: i32,
        origin_dt: R::Datatype,
        target_rank: i32,
        target_disp: Aint,
        target_count: i32,
        target_dt: R::Datatype,
        win: R::Win,
    ) -> i32 {
        if target_rank == R::c_proc_null() {
            return 0; // MPI: PROC_NULL target makes the op a no-op
        }
        let id = conv!(R, None, R::win_id(win));
        let od = conv!(R, None, R::dt_id(origin_dt));
        let td = conv!(R, None, R::dt_id(target_dt));
        if origin_count < 0 || target_count < 0 {
            return fail::<R>(None, crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        }
        ret::<R>(
            None,
            rma::put(origin, origin_count as usize, od, target_rank, target_disp,
                target_count as usize, td, id),
        )
    }

    fn get(
        origin: *mut u8,
        origin_count: i32,
        origin_dt: R::Datatype,
        target_rank: i32,
        target_disp: Aint,
        target_count: i32,
        target_dt: R::Datatype,
        win: R::Win,
    ) -> i32 {
        if target_rank == R::c_proc_null() {
            return 0;
        }
        let id = conv!(R, None, R::win_id(win));
        let od = conv!(R, None, R::dt_id(origin_dt));
        let td = conv!(R, None, R::dt_id(target_dt));
        if origin_count < 0 || target_count < 0 {
            return fail::<R>(None, crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        }
        ret::<R>(
            None,
            rma::get(origin, origin_count as usize, od, target_rank, target_disp,
                target_count as usize, td, id),
        )
    }

    fn accumulate(
        origin: *const u8,
        origin_count: i32,
        origin_dt: R::Datatype,
        target_rank: i32,
        target_disp: Aint,
        target_count: i32,
        target_dt: R::Datatype,
        o: R::Op,
        win: R::Win,
    ) -> i32 {
        if target_rank == R::c_proc_null() {
            return 0;
        }
        let id = conv!(R, None, R::win_id(win));
        let od = conv!(R, None, R::dt_id(origin_dt));
        let td = conv!(R, None, R::dt_id(target_dt));
        let oid = conv!(R, None, R::op_id(o));
        if origin_count < 0 || target_count < 0 {
            return fail::<R>(None, crate::core::MpiError::new(crate::abi::errors::MPI_ERR_COUNT));
        }
        ret::<R>(
            None,
            rma::accumulate(origin, origin_count as usize, od, target_rank, target_disp,
                target_count as usize, td, oid, id),
        )
    }

    fn comm_create_keyval(
        copy: Option<AttrCopyFn<Self>>,
        delete: Option<AttrDeleteFn<Self>>,
        extra_state: usize,
        out: &mut i32,
    ) -> i32 {
        use crate::core::attr::{KeyvalCopy, KeyvalDelete};
        let c = match copy {
            Some(f) => KeyvalCopy::User(Box::new(move |comm, kv, extra, val| {
                let (flag, newv) = f(R::comm_h(comm), kv, extra, val);
                Ok(flag.then_some(newv))
            })),
            None => KeyvalCopy::NullCopy,
        };
        let d = match delete {
            Some(f) => KeyvalDelete::User(Box::new(move |comm, kv, extra, val| {
                f(R::comm_h(comm), kv, extra, val);
                Ok(())
            })),
            None => KeyvalDelete::NullDelete,
        };
        match crate::core::attr::keyval_create(c, d, extra_state) {
            Ok(k) => {
                *out = k;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn comm_free_keyval(keyval: &mut i32) -> i32 {
        let r = ret::<R>(None, crate::core::attr::keyval_free(*keyval));
        if r == 0 {
            *keyval = crate::abi::constants::MPI_KEYVAL_INVALID;
        }
        r
    }

    fn comm_set_attr(c: R::Comm, keyval: i32, value: usize) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        ret::<R>(Some(id), crate::core::attr::set_attr(id, keyval, value))
    }

    fn comm_get_attr(c: R::Comm, keyval: i32, value: &mut usize, flag: &mut bool) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        match crate::core::attr::get_attr(id, keyval) {
            Ok(Some(v)) => {
                *value = v;
                *flag = true;
                0
            }
            Ok(None) => {
                *flag = false;
                0
            }
            Err(e) => fail::<R>(Some(id), e),
        }
    }

    fn comm_delete_attr(c: R::Comm, keyval: i32) -> i32 {
        let id = conv!(R, None, R::comm_id(c));
        ret::<R>(Some(id), crate::core::attr::delete_attr(id, keyval))
    }

    fn info_create(out: &mut R::Info) -> i32 {
        match info::info_create() {
            Ok(i) => {
                *out = R::info_h(i);
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn info_set(i: R::Info, key: &str, value: &str) -> i32 {
        let id = conv!(R, None, R::info_id(i));
        ret::<R>(None, info::info_set(id, key, value))
    }

    fn info_get(i: R::Info, key: &str, out: &mut String, flag: &mut bool) -> i32 {
        let id = conv!(R, None, R::info_id(i));
        match info::info_get(id, key) {
            Ok(Some(v)) => {
                *out = v;
                *flag = true;
                0
            }
            Ok(None) => {
                *flag = false;
                0
            }
            Err(e) => fail::<R>(None, e),
        }
    }

    fn info_free(i: &mut R::Info) -> i32 {
        let id = conv!(R, None, R::info_id(*i));
        let r = ret::<R>(None, info::info_free(id));
        if r == 0 {
            R::info_release(*i);
            *i = R::c_info_null();
        }
        r
    }

    // --- Tools interface (MPI_T) ---
    //
    // MPI_T errors never flow through communicator error handlers (the
    // tools interface is legal outside MPI_Init..Finalize, where no
    // communicator exists), so these map error classes directly via
    // `err_from_class` instead of `fail`/`ret`.

    fn t_init_thread(required: i32, provided: &mut i32) -> i32 {
        match obs::t_init_thread(required) {
            Ok(p) => {
                *provided = p;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_finalize() -> i32 {
        match obs::t_finalize() {
            Ok(()) => 0,
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_cvar_get_num(num: &mut i32) -> i32 {
        match obs::t_cvar_get_num() {
            Ok(n) => {
                *num = n;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_cvar_get_info(
        index: i32,
        name: &mut String,
        verbosity: &mut i32,
        bind: &mut i32,
        scope: &mut i32,
    ) -> i32 {
        match obs::t_cvar_get_info(index) {
            Ok((n, v, b, s)) => {
                *name = n;
                *verbosity = v;
                *bind = b;
                *scope = s;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_cvar_handle_alloc(index: i32, handle: &mut i32) -> i32 {
        match obs::t_cvar_handle_alloc(index) {
            Ok(h) => {
                *handle = h;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_cvar_read(handle: i32, value: &mut i64) -> i32 {
        match obs::t_cvar_read(handle) {
            Ok(v) => {
                *value = v;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_cvar_write(handle: i32, value: i64) -> i32 {
        match obs::t_cvar_write(handle, value) {
            Ok(()) => 0,
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_pvar_get_num(num: &mut i32) -> i32 {
        match obs::t_pvar_get_num() {
            Ok(n) => {
                *num = n;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_pvar_get_info(
        index: i32,
        name: &mut String,
        verbosity: &mut i32,
        class: &mut i32,
        bind: &mut i32,
    ) -> i32 {
        match obs::t_pvar_get_info(index) {
            Ok((n, v, c, b)) => {
                *name = n;
                *verbosity = v;
                *class = c;
                *bind = b;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_pvar_session_create(session: &mut i32) -> i32 {
        match obs::t_pvar_session_create() {
            Ok(s) => {
                *session = s;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_pvar_handle_alloc(session: i32, index: i32, handle: &mut i32) -> i32 {
        match obs::t_pvar_handle_alloc(session, index) {
            Ok(h) => {
                *handle = h;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_pvar_start(session: i32, handle: i32) -> i32 {
        match obs::t_pvar_start(session, handle) {
            Ok(()) => 0,
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_pvar_read(session: i32, handle: i32, value: &mut i64) -> i32 {
        match obs::t_pvar_read(session, handle) {
            Ok(v) => {
                *value = v;
                0
            }
            Err(e) => R::err_from_class(e.class),
        }
    }

    fn t_pvar_reset(session: i32, handle: i32) -> i32 {
        match obs::t_pvar_reset(session, handle) {
            Ok(()) => 0,
            Err(e) => R::err_from_class(e.class),
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn build_w_args<R: Repr>(
    sendbuf: *const u8,
    sendcounts: &[i32],
    sdispls: &[i32],
    sendtypes: &[R::Datatype],
    recvbuf: *mut u8,
    recvcounts: &[i32],
    rdispls: &[i32],
    recvtypes: &[R::Datatype],
) -> RC<coll::AlltoallwArgs> {
    let mut st = Vec::with_capacity(sendtypes.len());
    for &t in sendtypes {
        st.push(R::dt_id(t)?);
    }
    let mut rt = Vec::with_capacity(recvtypes.len());
    for &t in recvtypes {
        rt.push(R::dt_id(t)?);
    }
    Ok(coll::AlltoallwArgs {
        sendbuf: buf_in::<R>(sendbuf),
        sendcounts: sendcounts.iter().map(|&c| c as usize).collect(),
        sdispls: sdispls.iter().map(|&d| d as isize).collect(),
        sendtypes: st,
        recvbuf,
        recvcounts: recvcounts.iter().map(|&c| c as usize).collect(),
        rdispls: rdispls.iter().map(|&d| d as isize).collect(),
        recvtypes: rt,
    })
}
