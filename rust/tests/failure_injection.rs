//! Failure injection and edge cases across the ABI matrix: error classes
//! surface with the right values in every ABI's numbering, resource
//! exhaustion fails cleanly, and misuse is caught rather than UB.

use mpi_abi::api::{Dt, MpiAbi, OpName};
use mpi_abi::impls::{MpichAbi, OmpiAbi};
use mpi_abi::launcher::{run_job, run_job_ok, JobSpec, RankOutcome};
use mpi_abi::muk::{MukMpich, MukOmpi};
use mpi_abi::native_abi::NativeAbi;

fn with_errors_returned<A: MpiAbi, R>(f: impl FnOnce() -> R) -> R {
    A::comm_set_errhandler(A::comm_world(), A::errhandler_return());
    let r = f();
    A::comm_set_errhandler(A::comm_world(), A::errhandler_fatal());
    r
}

fn invalid_rank_class<A: MpiAbi>() {
    run_job_ok(JobSpec::new(1), |_| {
        A::init();
        with_errors_returned::<A, _>(|| {
            let v = [0i32];
            let rc = A::send(v.as_ptr() as *const u8, 1, A::datatype(Dt::Int), 77, 0,
                A::comm_world());
            assert_ne!(rc, 0);
            assert_eq!(A::err_class_of(rc), mpi_abi::abi::errors::MPI_ERR_RANK, "{}", A::NAME);
            // Error strings resolve in this ABI's code space.
            assert!(!A::error_string(rc).is_empty());
        });
        A::finalize();
    });
}

#[test]
fn invalid_rank_class_all_abis() {
    invalid_rank_class::<MpichAbi>();
    invalid_rank_class::<OmpiAbi>();
    invalid_rank_class::<MukMpich>();
    invalid_rank_class::<MukOmpi>();
    invalid_rank_class::<NativeAbi>();
}

fn invalid_tag_class<A: MpiAbi>() {
    run_job_ok(JobSpec::new(1), |_| {
        A::init();
        with_errors_returned::<A, _>(|| {
            let v = [0i32];
            let rc = A::send(v.as_ptr() as *const u8, 1, A::datatype(Dt::Int), 0, -5,
                A::comm_world());
            assert_eq!(A::err_class_of(rc), mpi_abi::abi::errors::MPI_ERR_TAG, "{}", A::NAME);
        });
        A::finalize();
    });
}

#[test]
fn invalid_tag_class_all_abis() {
    invalid_tag_class::<MpichAbi>();
    invalid_tag_class::<OmpiAbi>();
    invalid_tag_class::<MukMpich>();
    invalid_tag_class::<MukOmpi>();
    invalid_tag_class::<NativeAbi>();
}

#[test]
fn freeing_predefined_objects_fails_cleanly() {
    fn body<A: MpiAbi>() {
        run_job_ok(JobSpec::new(1), |_| {
            A::init();
            with_errors_returned::<A, _>(|| {
                let mut dt = A::datatype(Dt::Int);
                assert_ne!(A::type_free(&mut dt), 0, "{}: free builtin dtype", A::NAME);
                let mut op = A::op(OpName::Sum);
                assert_ne!(A::op_free(&mut op), 0, "{}: free builtin op", A::NAME);
                let mut w = A::comm_world();
                assert_ne!(A::comm_free(&mut w), 0, "{}: free COMM_WORLD", A::NAME);
            });
            A::finalize();
        });
    }
    body::<MpichAbi>();
    body::<OmpiAbi>();
    body::<MukMpich>();
    body::<MukOmpi>();
    body::<NativeAbi>();
}

#[test]
fn wait_on_request_null_is_noop() {
    fn body<A: MpiAbi>() {
        run_job_ok(JobSpec::new(1), |_| {
            A::init();
            let mut r = A::request_null();
            let mut st = A::status_empty();
            assert_eq!(A::wait(&mut r, &mut st), 0);
            assert_eq!(A::status_source(&st), A::proc_null());
            let mut flag = false;
            assert_eq!(A::test(&mut r, &mut flag, &mut st), 0);
            assert!(flag, "null request tests complete");
            A::finalize();
        });
    }
    body::<MpichAbi>();
    body::<OmpiAbi>();
    body::<MukMpich>();
    body::<MukOmpi>();
    body::<NativeAbi>();
}

#[test]
fn muk_trampoline_pool_exhaustion_returns_no_mem() {
    run_job_ok(JobSpec::new(1), |_| {
        type A = MukMpich;
        <A as MpiAbi>::init();
        fn f(_: *const u8, _: *mut u8, _: i32, _: mpi_abi::abi::handles::AbiDatatype) {}
        let mut ops = Vec::new();
        let mut rc = 0;
        // The static trampoline pool has 32 slots; the 33rd create must
        // fail with a resource error, like a real fixed pool.
        for _ in 0..40 {
            let mut op = <A as MpiAbi>::op(OpName::Sum);
            rc = <A as MpiAbi>::op_create(f, true, &mut op);
            if rc != 0 {
                break;
            }
            ops.push(op);
        }
        assert_eq!(ops.len(), mpi_abi::muk::callbacks::POOL_SIZE);
        assert_eq!(
            <A as MpiAbi>::err_class_of(rc),
            mpi_abi::abi::errors::MPI_ERR_NO_MEM
        );
        // Freeing releases slots for reuse.
        for mut op in ops {
            assert_eq!(<A as MpiAbi>::op_free(&mut op), 0);
        }
        let mut op = <A as MpiAbi>::op(OpName::Sum);
        assert_eq!(<A as MpiAbi>::op_create(f, true, &mut op), 0, "slots recycled");
        <A as MpiAbi>::op_free(&mut op);
        <A as MpiAbi>::finalize();
    });
}

#[test]
fn double_init_is_an_error() {
    run_job(JobSpec::new(1), |_| {
        type A = NativeAbi;
        assert_eq!(<A as MpiAbi>::init(), 0);
        // Second init must fail (errors pre-attached handlers are fatal;
        // init errors return directly since no comm exists yet).
        let rc = <A as MpiAbi>::init();
        assert_ne!(rc, 0);
        assert_eq!(<A as MpiAbi>::finalize(), 0);
        // Finalize twice is an error too.
        assert_ne!(<A as MpiAbi>::finalize(), 0);
    });
}

#[test]
fn fatal_errhandler_aborts_job() {
    let out = run_job(JobSpec::new(2), |rank| {
        type A = MpichAbi;
        <A as MpiAbi>::init();
        if rank == 0 {
            // Default handler is ERRORS_ARE_FATAL: this must abort the job.
            let v = [0i32];
            <A as MpiAbi>::send(
                v.as_ptr() as *const u8,
                1,
                <A as MpiAbi>::datatype(Dt::Int),
                1234,
                0,
                <A as MpiAbi>::comm_world(),
            );
            unreachable!("fatal errhandler must not return");
        } else {
            // Blocked peer must be taken down by the abort.
            let mut v = [0i32];
            let mut st = <A as MpiAbi>::status_empty();
            <A as MpiAbi>::recv(
                v.as_mut_ptr() as *mut u8,
                1,
                <A as MpiAbi>::datatype(Dt::Int),
                0,
                9,
                <A as MpiAbi>::comm_world(),
                &mut st,
            );
        }
    });
    assert!(matches!(out[0], RankOutcome::Aborted(_)));
    assert!(matches!(out[1], RankOutcome::Aborted(_)));
}

#[test]
fn zero_count_messages() {
    fn body<A: MpiAbi>() {
        run_job_ok(JobSpec::new(2), |rank| {
            A::init();
            let dt = A::datatype(Dt::Int);
            if rank == 0 {
                let rc = A::send(std::ptr::NonNull::<u8>::dangling().as_ptr(), 0, dt, 1, 0,
                    A::comm_world());
                assert_eq!(rc, 0, "{}: zero-count send", A::NAME);
            } else {
                let mut st = A::status_empty();
                let rc = A::recv(std::ptr::NonNull::<u8>::dangling().as_ptr(), 0, dt, 0, 0,
                    A::comm_world(), &mut st);
                assert_eq!(rc, 0, "{}: zero-count recv", A::NAME);
                assert_eq!(A::get_count(&st, dt), 0);
            }
            A::finalize();
        });
    }
    body::<MpichAbi>();
    body::<OmpiAbi>();
    body::<MukMpich>();
    body::<MukOmpi>();
    body::<NativeAbi>();
}

#[test]
fn self_messaging_on_comm_self() {
    fn body<A: MpiAbi>() {
        run_job_ok(JobSpec::new(1), |_| {
            A::init();
            let dt = A::datatype(Dt::Int);
            // isend to self on COMM_SELF, then recv.
            let v = [31i32];
            let mut req = A::request_null();
            assert_eq!(
                A::isend(v.as_ptr() as *const u8, 1, dt, 0, 5, A::comm_self(), &mut req),
                0
            );
            let mut got = [0i32];
            let mut st = A::status_empty();
            assert_eq!(
                A::recv(got.as_mut_ptr() as *mut u8, 1, dt, 0, 5, A::comm_self(), &mut st),
                0
            );
            assert_eq!(got[0], 31);
            assert_eq!(A::wait(&mut req, &mut st), 0);
            A::finalize();
        });
    }
    body::<MpichAbi>();
    body::<OmpiAbi>();
    body::<MukMpich>();
    body::<MukOmpi>();
    body::<NativeAbi>();
}

#[test]
fn large_alltoallw_with_derived_types_via_muk() {
    // Stress the §6.2 conversion path: alltoallw where every peer uses a
    // different derived datatype, through the translation layer.
    run_job_ok(JobSpec::new(3), |_| {
        type A = MukMpich;
        <A as MpiAbi>::init();
        let n = 3;
        let base = <A as MpiAbi>::datatype(Dt::Int);
        // Build per-peer types: contiguous(k+1) of int.
        let mut types = Vec::new();
        for k in 0..n {
            let mut t = base;
            assert_eq!(<A as MpiAbi>::type_contiguous(k as i32 + 1, base, &mut t), 0);
            assert_eq!(<A as MpiAbi>::type_commit(&mut t), 0);
            types.push(t);
        }
        // Every rank sends (k+1) ints to peer k; buffers sized to match.
        let send: Vec<i32> = (0..(1 + 2 + 3)).map(|i| i as i32).collect();
        let sdispls = [0i32, 4, 12]; // bytes: after 1 int, after 3 ints
        let counts = [1i32, 1, 1];
        let mut recv = vec![0i32; 3 * 3];
        let mut my_rank = 0;
        <A as MpiAbi>::comm_rank(<A as MpiAbi>::comm_world(), &mut my_rank);
        // Receive (my_rank+1) ints from each peer.
        let rdispls: Vec<i32> = (0..n as i32).map(|k| k * 4 * (my_rank + 1)).collect();
        let rtypes = vec![types[my_rank as usize]; n];
        let rc = <A as MpiAbi>::alltoallw(
            send.as_ptr() as *const u8,
            &counts,
            &sdispls,
            &types,
            recv.as_mut_ptr() as *mut u8,
            &counts,
            &rdispls,
            &rtypes,
            <A as MpiAbi>::comm_world(),
        );
        assert_eq!(rc, 0);
        // Peer k sent us the slice starting at sdispls[my_rank] of their
        // identical send buffer: (my_rank+1) ints starting at offset.
        let start = [0, 1, 3][my_rank as usize];
        for k in 0..n {
            for j in 0..(my_rank as usize + 1) {
                assert_eq!(
                    recv[k * (my_rank as usize + 1) + j],
                    (start + j) as i32,
                    "from peer {k} element {j}"
                );
            }
        }
        for mut t in types {
            <A as MpiAbi>::type_free(&mut t);
        }
        <A as MpiAbi>::finalize();
    });
}
