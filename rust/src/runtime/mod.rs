//! The PJRT runtime: loads the AOT-compiled HLO artifacts (produced once
//! by `make artifacts` — Python is never on the request path) and
//! executes them from the Rust hot paths.
//!
//! Two consumers:
//! * the reduction-op engine ([`try_xla_reduce`]) offloads large
//!   contiguous f32 SUM/PROD/MIN/MAX combines to the compiled Pallas
//!   kernel;
//! * the DDP application ([`crate::apps`]) runs the whole training
//!   step (`grad_step` + `sgd_update`) through compiled executables.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so each
//! rank thread owns its own lazily-created client, and executables are
//! compiled once per thread per artifact and cached.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use crate::core::datatype::ScalarKind;
use crate::core::op::BuiltinOp;

/// Artifact sizes the reduce kernels were lowered for (must match
/// `python/compile/aot.py`'s `REDUCE_SIZES`).
pub const REDUCE_SIZES: [usize; 3] = [4096, 65536, 1_048_576];

/// Environment switch: set `MPI_ABI_NO_XLA=1` to force the scalar path
/// (used by benches to ablate the offload).
fn xla_disabled() -> bool {
    std::env::var("MPI_ABI_NO_XLA").map(|v| v == "1").unwrap_or(false)
}

/// The reduce-combine offload is **opt-in** (`MPI_ABI_XLA_REDUCE=1`):
/// the §Perf ablation measured the CPU-interpret Pallas kernel at
/// 200–4000x the scalar loop (PJRT dispatch + interpret-lowered grid
/// loops), so on this substrate the practical roofline says scalar.
/// On a real TPU the VMEM/MXU estimates (DESIGN.md §Perf) flip this.
fn xla_reduce_enabled() -> bool {
    std::env::var("MPI_ABI_XLA_REDUCE").map(|v| v == "1").unwrap_or(false)
}

/// Locate the artifacts directory: `$MPI_ABI_ARTIFACTS`, else
/// `./artifacts`, else the crate-root artifacts dir.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(d) = std::env::var("MPI_ABI_ARTIFACTS") {
        let p = PathBuf::from(d);
        return p.is_dir().then_some(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.is_dir() {
        return Some(cwd);
    }
    let here = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    here.is_dir().then_some(here)
}

/// Per-thread PJRT state.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    execs: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

thread_local! {
    static RUNTIME: RefCell<Option<Option<Rc<Runtime>>>> = const { RefCell::new(None) };
}

/// The calling thread's runtime, if artifacts exist and XLA is enabled.
pub fn runtime() -> Option<Rc<Runtime>> {
    RUNTIME.with(|r| {
        let mut r = r.borrow_mut();
        if r.is_none() {
            *r = Some(init_runtime());
        }
        r.as_ref().unwrap().clone()
    })
}

/// Drop the calling thread's cached runtime so the next [`runtime`] call
/// re-evaluates the environment (used by benches to ablate the offload).
pub fn reset_thread_runtime() {
    RUNTIME.with(|r| *r.borrow_mut() = None);
}

fn init_runtime() -> Option<Rc<Runtime>> {
    if xla_disabled() {
        return None;
    }
    let dir = artifacts_dir()?;
    let client = xla::PjRtClient::cpu().ok()?;
    Some(Rc::new(Runtime { client, dir, execs: RefCell::new(HashMap::new()) }))
}

impl Runtime {
    /// Load + compile an artifact by name (cached per thread).
    pub fn executable(&self, name: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.execs.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// `true` if the artifact file exists (without compiling it).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).is_file()
    }

    /// Execute an artifact on f32 inputs; returns the outputs as f32
    /// vectors (the lowered functions return tuples).
    pub fn execute_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let l = xla::Literal::vec1(data);
                if shape.len() == 1 && shape[0] as usize == data.len() {
                    Ok(l)
                } else {
                    l.reshape(shape).map_err(anyhow::Error::from)
                }
            })
            .collect::<anyhow::Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(anyhow::Error::from))
            .collect()
    }
}

/// Offload hook for the reduction-op engine: `inout = op(in, inout)` over
/// `n` packed f32 scalars via the compiled Pallas kernel. Returns `false`
/// when the scalar loop should run instead (wrong type/op/size, runtime
/// unavailable, or execution error).
pub fn try_xla_reduce(
    op: BuiltinOp,
    kind: ScalarKind,
    inbuf: &[u8],
    inout: &mut [u8],
    n: usize,
) -> bool {
    if !xla_reduce_enabled() || kind != ScalarKind::F32 || !REDUCE_SIZES.contains(&n) {
        return false;
    }
    let opname = match op {
        BuiltinOp::Sum => "sum",
        BuiltinOp::Prod => "prod",
        BuiltinOp::Min => "min",
        BuiltinOp::Max => "max",
        _ => return false,
    };
    let Some(rt) = runtime() else { return false };
    let name = format!("reduce_{opname}_f32_{n}");
    // Copy out of the (possibly unaligned) packed buffers.
    let mut a = vec![0f32; n];
    let mut b = vec![0f32; n];
    unsafe {
        std::ptr::copy_nonoverlapping(inbuf.as_ptr(), a.as_mut_ptr() as *mut u8, 4 * n);
        std::ptr::copy_nonoverlapping(inout.as_ptr(), b.as_mut_ptr() as *mut u8, 4 * n);
    }
    match rt.execute_f32(&name, &[(&a, &[n as i64]), (&b, &[n as i64])]) {
        Ok(outs) if outs.len() == 1 && outs[0].len() == n => {
            unsafe {
                std::ptr::copy_nonoverlapping(
                    outs[0].as_ptr() as *const u8,
                    inout.as_mut_ptr(),
                    4 * n,
                );
            }
            true
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().is_some()
    }

    #[test]
    fn reduce_artifact_roundtrip() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let rt = runtime().expect("runtime");
        let n = 4096usize;
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..n).map(|i| (i % 7) as f32).collect();
        let out = rt
            .execute_f32("reduce_sum_f32_4096", &[(&a, &[n as i64]), (&b, &[n as i64])])
            .expect("execute");
        assert_eq!(out.len(), 1);
        for i in (0..n).step_by(97) {
            assert_eq!(out[0][i], a[i] + b[i]);
        }
    }

    #[test]
    fn xla_reduce_hook_matches_scalar() {
        if !have_artifacts() {
            return;
        }
        std::env::set_var("MPI_ABI_XLA_REDUCE", "1");
        reset_thread_runtime();
        let n = 4096usize;
        let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        let mut b: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        let want: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x.max(*y)).collect();
        let abytes = unsafe { std::slice::from_raw_parts(a.as_ptr() as *const u8, 4 * n) };
        let bbytes = unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr() as *mut u8, 4 * n) };
        let used = try_xla_reduce(BuiltinOp::Max, ScalarKind::F32, abytes, bbytes, n);
        assert!(used, "offload should engage at n=4096");
        assert_eq!(b, want);
    }

    #[test]
    fn hook_declines_wrong_shapes() {
        // Non-matching size → scalar path.
        let a = [0u8; 16];
        let mut b = [0u8; 16];
        assert!(!try_xla_reduce(BuiltinOp::Sum, ScalarKind::F32, &a, &mut b, 4));
        // f64 → scalar path (artifacts are f32-only).
        assert!(!try_xla_reduce(BuiltinOp::Sum, ScalarKind::F64, &a, &mut b, 2));
    }

    #[test]
    fn grad_step_executes_and_loss_is_finite() {
        if !have_artifacts() {
            return;
        }
        let rt = runtime().expect("runtime");
        if !rt.has_artifact("grad_step") {
            return;
        }
        // Shapes must match python/compile/model.py.
        let (d_in, d_hid, d_out, batch) = (256i64, 256i64, 128i64, 128i64);
        let w1 = vec![0.05f32; (d_in * d_hid) as usize];
        let b1 = vec![0.0f32; d_hid as usize];
        let w2 = vec![0.05f32; (d_hid * d_out) as usize];
        let b2 = vec![0.0f32; d_out as usize];
        let x = vec![0.1f32; (batch * d_in) as usize];
        let y = vec![0.3f32; batch as usize];
        let outs = rt
            .execute_f32(
                "grad_step",
                &[
                    (&w1, &[d_in, d_hid]),
                    (&b1, &[d_hid]),
                    (&w2, &[d_hid, d_out]),
                    (&b2, &[d_out]),
                    (&x, &[batch, d_in]),
                    (&y, &[batch]),
                ],
            )
            .expect("grad_step");
        assert_eq!(outs.len(), 5, "loss + 4 grads");
        assert!(outs[0][0].is_finite(), "loss finite: {}", outs[0][0]);
        assert_eq!(outs[1].len(), (d_in * d_hid) as usize);
    }
}
