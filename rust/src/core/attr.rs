//! Attribute caching (`MPI_Comm_create_keyval` / `MPI_Comm_set_attr` /…).
//!
//! Attributes matter to the ABI story for two reasons (§3.3): handle size
//! is capped at pointer size *because* "attributes can always hold an MPI
//! handle", and the copy/delete callbacks are among the functions a
//! translation layer must trampoline (§6.2). Attribute values are
//! word-sized (`void*`-equivalent `usize`).

use super::world::with_ctx;
use super::{err, CommId, RC};
use crate::abi::constants as k;

/// Copy callback result: whether to copy, and the (possibly transformed)
/// value. Registered layers wrap the ABI-level callback in this closure
/// form, converting handles/extra-state as needed.
pub type CopyFn = Box<dyn Fn(CommId, i32, usize, usize) -> RC<Option<usize>>>;
/// Delete callback.
pub type DeleteFn = Box<dyn Fn(CommId, i32, usize, usize) -> RC<()>>;

/// Keyval object.
pub struct KeyvalObj {
    /// Behavior on `MPI_Comm_dup`.
    pub copy: KeyvalCopy,
    /// Behavior on attribute/comm deletion.
    pub delete: KeyvalDelete,
    /// The user's extra-state word, passed to both callbacks.
    pub extra_state: usize,
}

/// A keyval's copy behavior.
pub enum KeyvalCopy {
    /// `MPI_COMM_NULL_COPY_FN` (0x0): never copied on dup.
    NullCopy,
    /// `MPI_COMM_DUP_FN` (0xD): copied verbatim on dup.
    Dup,
    /// User copy callback.
    User(CopyFn),
}

/// A keyval's delete behavior.
pub enum KeyvalDelete {
    /// `MPI_COMM_NULL_DELETE_FN` (0x0): nothing to do.
    NullDelete,
    /// User delete callback.
    User(DeleteFn),
}

/// External keyval key: positive integers from 1 (0 is reserved so the
/// standard's `MPI_KEYVAL_INVALID` (-106) can never collide).
pub type KeyvalKey = i32;

/// `MPI_Comm_create_keyval`.
pub fn keyval_create(copy: KeyvalCopy, delete: KeyvalDelete, extra_state: usize) -> RC<KeyvalKey> {
    with_ctx(|ctx| {
        let id = ctx.tables.borrow_mut().keyvals.insert(KeyvalObj { copy, delete, extra_state });
        Ok(id as i32 + 1)
    })
}

/// `MPI_Comm_free_keyval`.
pub fn keyval_free(key: KeyvalKey) -> RC<()> {
    if key <= 0 {
        return Err(err!(MPI_ERR_KEYVAL));
    }
    with_ctx(|ctx| {
        ctx.tables
            .borrow_mut()
            .keyvals
            .remove((key - 1) as u32)
            .map(|_| ())
            .ok_or(err!(MPI_ERR_KEYVAL))
    })
}

/// `MPI_Comm_set_attr`. The attribute value is word-sized, per §3.3.
pub fn set_attr(comm: CommId, key: KeyvalKey, value: usize) -> RC<()> {
    if key <= 0 {
        return Err(err!(MPI_ERR_KEYVAL));
    }
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        if !t.keyvals.contains((key - 1) as u32) && !is_predefined_key(key) {
            return Err(err!(MPI_ERR_KEYVAL));
        }
        let c = t.comms.get_mut(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        c.attrs.insert(key, value);
        Ok(())
    })
}

/// `MPI_Comm_get_attr`: `Ok(None)` = flag false.
pub fn get_attr(comm: CommId, key: KeyvalKey) -> RC<Option<usize>> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let c = t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        if let Some(&v) = c.attrs.get(&key) {
            return Ok(Some(v));
        }
        // Predefined attributes on COMM_WORLD.
        if comm == super::reserved::COMM_WORLD {
            return Ok(predefined_attr(key, ctx.world.size));
        }
        Ok(None)
    })
}

/// `MPI_Comm_delete_attr` (runs the delete callback).
pub fn delete_attr(comm: CommId, key: KeyvalKey) -> RC<()> {
    let (value, extra) = with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let c = t.comms.get_mut(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        let v = c.attrs.remove(&key).ok_or(err!(MPI_ERR_KEYVAL))?;
        let extra = t.keyvals.get((key - 1) as u32).map(|kv| kv.extra_state).unwrap_or(0);
        Ok((v, extra))
    })?;
    run_delete(comm, key, value, extra)
}

/// Copy attributes from `src` to `dst` on `MPI_Comm_dup`, honoring each
/// keyval's copy callback.
pub fn copy_attrs_for_dup(src: CommId, dst: CommId) -> RC<()> {
    // Snapshot attrs + copy behaviors without holding borrows during
    // callbacks (callbacks may call MPI).
    let snapshot: Vec<(KeyvalKey, usize, usize)> = with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let c = t.comms.get(src.0).ok_or(err!(MPI_ERR_COMM))?;
        Ok(c.attrs
            .iter()
            .map(|(&k, &v)| {
                let extra = t.keyvals.get((k - 1) as u32).map(|kv| kv.extra_state).unwrap_or(0);
                (k, v, extra)
            })
            .collect())
    })?;
    for (key, value, extra) in snapshot {
        let copied = run_copy(src, key, value, extra)?;
        if let Some(v) = copied {
            with_ctx(|ctx| {
                let mut t = ctx.tables.borrow_mut();
                let c = t.comms.get_mut(dst.0).ok_or(err!(MPI_ERR_COMM))?;
                c.attrs.insert(key, v);
                Ok(())
            })?;
        }
    }
    Ok(())
}

/// Run delete callbacks for all attributes of a comm being freed.
pub fn delete_all_attrs(comm: CommId) -> RC<()> {
    let keys: Vec<KeyvalKey> = with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let c = t.comms.get(comm.0).ok_or(err!(MPI_ERR_COMM))?;
        Ok(c.attrs.keys().copied().collect())
    })?;
    for key in keys {
        // Ignore missing-keyval errors: keyval may have been freed already
        // (MPI says keyval free is deferred; we simplify).
        let _ = delete_attr(comm, key);
    }
    Ok(())
}

fn run_copy(comm: CommId, key: KeyvalKey, value: usize, extra: usize) -> RC<Option<usize>> {
    // Move the callback out of the table during invocation (it may call
    // back into MPI).
    enum Plan {
        Keep(Option<usize>),
        Call(CopyFn),
    }
    let plan = with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let kv = match t.keyvals.get_mut((key - 1) as u32) {
            Some(kv) => kv,
            None => return Ok(Plan::Keep(None)), // predefined/foreign key: no copy
        };
        Ok(match &mut kv.copy {
            KeyvalCopy::NullCopy => Plan::Keep(None),
            KeyvalCopy::Dup => Plan::Keep(Some(value)),
            KeyvalCopy::User(_) => {
                let f = std::mem::replace(&mut kv.copy, KeyvalCopy::NullCopy);
                match f {
                    KeyvalCopy::User(f) => Plan::Call(f),
                    _ => unreachable!(),
                }
            }
        })
    })?;
    match plan {
        Plan::Keep(v) => Ok(v),
        Plan::Call(f) => {
            let out = f(comm, key, extra, value);
            with_ctx(|ctx| {
                let mut t = ctx.tables.borrow_mut();
                if let Some(kv) = t.keyvals.get_mut((key - 1) as u32) {
                    kv.copy = KeyvalCopy::User(f);
                }
                Ok(())
            })?;
            out
        }
    }
}

fn run_delete(comm: CommId, key: KeyvalKey, value: usize, extra: usize) -> RC<()> {
    enum Plan {
        Nothing,
        Call(DeleteFn),
    }
    let plan = with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let kv = match t.keyvals.get_mut((key - 1) as u32) {
            Some(kv) => kv,
            None => return Ok(Plan::Nothing),
        };
        Ok(match &mut kv.delete {
            KeyvalDelete::NullDelete => Plan::Nothing,
            KeyvalDelete::User(_) => {
                let f = std::mem::replace(&mut kv.delete, KeyvalDelete::NullDelete);
                match f {
                    KeyvalDelete::User(f) => Plan::Call(f),
                    _ => unreachable!(),
                }
            }
        })
    })?;
    match plan {
        Plan::Nothing => Ok(()),
        Plan::Call(f) => {
            let out = f(comm, key, extra, value);
            with_ctx(|ctx| {
                let mut t = ctx.tables.borrow_mut();
                if let Some(kv) = t.keyvals.get_mut((key - 1) as u32) {
                    kv.delete = KeyvalDelete::User(f);
                }
                Ok(())
            })?;
            out
        }
    }
}

fn is_predefined_key(key: KeyvalKey) -> bool {
    matches!(
        key,
        k::MPI_TAG_UB
            | k::MPI_HOST
            | k::MPI_IO
            | k::MPI_WTIME_IS_GLOBAL
            | k::MPI_UNIVERSE_SIZE
            | k::MPI_LASTUSEDCODE
            | k::MPI_APPNUM
    )
}

fn predefined_attr(key: KeyvalKey, world_size: usize) -> Option<usize> {
    match key {
        k::MPI_TAG_UB => Some(k::TAG_UB_VALUE as usize),
        k::MPI_WTIME_IS_GLOBAL => Some(1),
        k::MPI_UNIVERSE_SIZE => Some(world_size),
        k::MPI_IO => Some(0), // rank 0 does I/O; value is "any rank" semantics simplified
        _ => None,
    }
}
