//! Built-in applications, written against the portable [`crate::api::MpiAbi`]
//! surface (so any of the five ABI configurations can run them — the
//! "container retargeting" story of §4.7 in executable form).

pub mod ddp;
pub mod halo;
pub mod hello;
pub mod osu;

use crate::api::MpiAbi;
use crate::impls::{MpichAbi, OmpiAbi};
use crate::muk::{MukMpich, MukOmpi};
use crate::native_abi::NativeAbi;

/// The five ABI configurations of the evaluation (Table 1 + E4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AbiConfig {
    /// MPICH-like implementation, its own ABI.
    Mpich,
    /// Open-MPI-like implementation, its own ABI.
    Ompi,
    /// Standard ABI via Mukautuva over the MPICH-like backend.
    MukMpich,
    /// Standard ABI via Mukautuva over the Open-MPI-like backend.
    MukOmpi,
    /// Standard ABI implemented natively (`--enable-mpi-abi`).
    NativeAbi,
}

impl AbiConfig {
    pub const ALL: [AbiConfig; 5] = [
        AbiConfig::Mpich,
        AbiConfig::Ompi,
        AbiConfig::MukMpich,
        AbiConfig::MukOmpi,
        AbiConfig::NativeAbi,
    ];

    pub fn parse(s: &str) -> Option<AbiConfig> {
        Some(match s {
            "mpich" => AbiConfig::Mpich,
            "ompi" => AbiConfig::Ompi,
            "muk-mpich" | "muk:mpich" => AbiConfig::MukMpich,
            "muk-ompi" | "muk:ompi" => AbiConfig::MukOmpi,
            "abi" | "native-abi" => AbiConfig::NativeAbi,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            AbiConfig::Mpich => "mpich",
            AbiConfig::Ompi => "ompi",
            AbiConfig::MukMpich => "muk(mpich)",
            AbiConfig::MukOmpi => "muk(ompi)",
            AbiConfig::NativeAbi => "abi",
        }
    }
}

/// Run `f` monomorphized for the chosen ABI configuration — the runtime
/// analogue of "relink the binary against a different libmpi".
pub fn with_abi<R>(config: AbiConfig, f: impl AbiApp<R>) -> R {
    match config {
        AbiConfig::Mpich => f.run::<MpichAbi>(),
        AbiConfig::Ompi => f.run::<OmpiAbi>(),
        AbiConfig::MukMpich => f.run::<MukMpich>(),
        AbiConfig::MukOmpi => f.run::<MukOmpi>(),
        AbiConfig::NativeAbi => f.run::<NativeAbi>(),
    }
}

/// An application parameterized over the MPI ABI (a generic closure).
pub trait AbiApp<R> {
    fn run<A: MpiAbi>(self) -> R;
}
