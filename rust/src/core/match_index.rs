//! The indexed message-matching engine.
//!
//! MPI's matching rule is *posted order × arrival order*: an arriving
//! message completes the earliest-posted receive it matches, and a newly
//! posted receive completes against the earliest-arrived unexpected
//! message it matches. The seed engine kept both sides in flat
//! `VecDeque`s and re-ran an O(posted × unexpected) nested scan over
//! *all* contexts on every progress tick. This module replaces that with
//! per-context structures so the exact-match common case is O(1):
//!
//! ```text
//!   context id ──► ContextQueues
//!                    ├─ unexpected: (src, tag) ─► FIFO of stamped envelopes
//!                    ├─ posted exact: (src, tag) ─► FIFO of stamped recvs
//!                    └─ posted wildcard FIFO (ANY_SOURCE / ANY_TAG)
//! ```
//!
//! Every insertion carries a monotone stamp (one counter for arrivals,
//! one for posts). A lookup that could match several buckets — a
//! wildcard receive probing the unexpected side, or an arrival choosing
//! between the exact bucket and the wildcard FIFO — compares stamps and
//! takes the earliest, which is exactly the flat scan's answer without
//! the flat scan's cost.
//!
//! **The invariant** that makes insertion-time matching sufficient: the
//! two sides are mutually non-matching at rest. Every arrival is checked
//! against the posted side before it is stored; every post is checked
//! against the unexpected side before it is stored; removals never
//! create new matches. Under the engine's single-threaded progress model
//! that invariant makes a per-tick rescan unnecessary.
//!
//! The seed's flat structure survives behind `MPI_ABI_FLAT_MATCH=1`
//! (or [`crate::launcher::JobSpec::with_flat_match`]) as the perf
//! baseline `benches/latency.rs`, `benches/message_rate.rs`, and the
//! `abibench` harness regress against — same semantics, linear scans.

use std::collections::{HashMap, VecDeque};
use std::hash::{BuildHasherDefault, Hasher};

use super::transport::Envelope;
use super::ReqId;
use crate::abi::constants::{MPI_ANY_SOURCE, MPI_ANY_TAG};

// ---------------------------------------------------------------------------
// FxHash — matching sits on the per-message critical path, and SipHash's
// ~40 ns per probe would eat the win. This is the rustc-style
// multiply-rotate hash (no external crate in the offline set).
// ---------------------------------------------------------------------------

/// rustc-style multiply-rotate hasher for the small integer keys the
/// matching index uses (context ids, `(src, tag)` pairs).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const FX_SEED: u64 = 0x517c_c1b7_2722_0a95;

impl FxHasher {
    #[inline]
    fn add(&mut self, v: u64) {
        self.hash = (self.hash.rotate_left(5) ^ v).wrapping_mul(FX_SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.add(b as u64);
        }
    }
    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }
    #[inline]
    fn write_i32(&mut self, v: i32) {
        self.add(v as u32 as u64);
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }
    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
/// `HashMap` keyed with [`FxHasher`] (the index's only map type).
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

// ---------------------------------------------------------------------------
// Index structures
// ---------------------------------------------------------------------------

/// One posted receive as the index sees it: the request it completes,
/// its post stamp, and its matching pattern (src/tag may be wildcards).
#[derive(Clone, Copy, Debug)]
struct PostedRecv {
    rid: ReqId,
    stamp: u64,
    src: i32,
    tag: i32,
}

impl PostedRecv {
    /// Does this posted receive accept an arrival from `(src, tag)`?
    #[inline]
    fn accepts(&self, src: u32, tag: i32) -> bool {
        (self.src == MPI_ANY_SOURCE || self.src as u32 == src)
            && (self.tag == MPI_ANY_TAG || self.tag == tag)
    }
}

/// The matching state of one context plane.
#[derive(Default)]
struct ContextQueues {
    /// Arrived-but-unmatched messages, bucketed by concrete `(src, tag)`;
    /// each bucket is FIFO in arrival order, entries stamped globally.
    unexpected: FxHashMap<(u32, i32), VecDeque<(u64, Envelope)>>,
    /// Messages across all `unexpected` buckets (cheap emptiness test).
    n_unexpected: usize,
    /// Posted receives with a concrete `(src, tag)`, bucketed likewise.
    posted_exact: FxHashMap<(i32, i32), VecDeque<PostedRecv>>,
    /// Posted receives with `MPI_ANY_SOURCE` and/or `MPI_ANY_TAG`, in
    /// post order (the wildcard FIFO).
    posted_wild: VecDeque<PostedRecv>,
    /// Receives across both posted structures (cheap emptiness test).
    n_posted: usize,
}

impl ContextQueues {
    /// Earliest-arrived unexpected envelope matching `(src, tag)`
    /// (wildcards allowed), removed from its bucket.
    fn take_unexpected(&mut self, src: i32, tag: i32) -> Option<Envelope> {
        if self.n_unexpected == 0 {
            return None;
        }
        if src != MPI_ANY_SOURCE && tag != MPI_ANY_TAG {
            // Exact: one bucket probe, O(1).
            let key = (src as u32, tag);
            let q = self.unexpected.get_mut(&key)?;
            let (_, env) = q.pop_front().expect("index buckets are never left empty");
            if q.is_empty() {
                self.unexpected.remove(&key);
            }
            self.n_unexpected -= 1;
            return Some(env);
        }
        // Wildcard: compare bucket heads, take the earliest arrival.
        let mut best: Option<(u64, (u32, i32))> = None;
        for (&key, q) in self.unexpected.iter() {
            if (src == MPI_ANY_SOURCE || key.0 == src as u32)
                && (tag == MPI_ANY_TAG || key.1 == tag)
            {
                let head = q.front().expect("index buckets are never left empty").0;
                if best.map(|(s, _)| head < s).unwrap_or(true) {
                    best = Some((head, key));
                }
            }
        }
        let (_, key) = best?;
        let q = self.unexpected.get_mut(&key).unwrap();
        let (_, env) = q.pop_front().unwrap();
        if q.is_empty() {
            self.unexpected.remove(&key);
        }
        self.n_unexpected -= 1;
        Some(env)
    }

    /// Like [`ContextQueues::take_unexpected`] but non-destructive:
    /// a reference to the earliest matching envelope (`MPI_Iprobe`).
    fn peek_unexpected(&self, src: i32, tag: i32) -> Option<&Envelope> {
        if self.n_unexpected == 0 {
            return None;
        }
        if src != MPI_ANY_SOURCE && tag != MPI_ANY_TAG {
            let (_, env) = self.unexpected.get(&(src as u32, tag))?.front()?;
            return Some(env);
        }
        let mut best: Option<(u64, &Envelope)> = None;
        for (&key, q) in self.unexpected.iter() {
            if (src == MPI_ANY_SOURCE || key.0 == src as u32)
                && (tag == MPI_ANY_TAG || key.1 == tag)
            {
                let (stamp, env) = q.front().expect("index buckets are never left empty");
                if best.map(|(s, _)| *stamp < s).unwrap_or(true) {
                    best = Some((*stamp, env));
                }
            }
        }
        best.map(|(_, env)| env)
    }

    /// Earliest unexpected envelope on this context with `tag <
    /// tag_below` (the RMA op router: data-path tags sit below the
    /// fence-barrier band).
    fn take_tag_below(&mut self, tag_below: i32) -> Option<Envelope> {
        if self.n_unexpected == 0 {
            return None;
        }
        let mut best: Option<(u64, (u32, i32))> = None;
        for (&key, q) in self.unexpected.iter() {
            if key.1 < tag_below {
                let head = q.front().expect("index buckets are never left empty").0;
                if best.map(|(s, _)| head < s).unwrap_or(true) {
                    best = Some((head, key));
                }
            }
        }
        let (_, key) = best?;
        let q = self.unexpected.get_mut(&key).unwrap();
        let (_, env) = q.pop_front().unwrap();
        if q.is_empty() {
            self.unexpected.remove(&key);
        }
        self.n_unexpected -= 1;
        Some(env)
    }

    /// Earliest-posted receive accepting an arrival from `(src, tag)`,
    /// removed from its queue. Compares the exact bucket's head with the
    /// first matching wildcard (both FIFOs are post-ordered). The second
    /// tuple element reports whether the winner came from the wildcard
    /// FIFO (the `wildcard_matches` pvar).
    fn take_posted(&mut self, src: u32, tag: i32) -> Option<(ReqId, bool)> {
        if self.n_posted == 0 {
            return None;
        }
        let key = (src as i32, tag);
        let exact_stamp = self
            .posted_exact
            .get(&key)
            .map(|q| q.front().expect("index buckets are never left empty").stamp);
        let wild_pos = self.posted_wild.iter().position(|p| p.accepts(src, tag));
        let wild_stamp = wild_pos.map(|i| self.posted_wild[i].stamp);
        let use_exact = match (exact_stamp, wild_stamp) {
            (None, None) => return None,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some(e), Some(w)) => e < w,
        };
        self.n_posted -= 1;
        if use_exact {
            let q = self.posted_exact.get_mut(&key).unwrap();
            let p = q.pop_front().unwrap();
            if q.is_empty() {
                self.posted_exact.remove(&key);
            }
            Some((p.rid, false))
        } else {
            self.posted_wild.remove(wild_pos.unwrap()).map(|p| (p.rid, true))
        }
    }

    /// Store a posted receive (no unexpected match existed).
    fn push_posted(&mut self, p: PostedRecv) {
        if p.src == MPI_ANY_SOURCE || p.tag == MPI_ANY_TAG {
            self.posted_wild.push_back(p);
        } else {
            self.posted_exact.entry((p.src, p.tag)).or_default().push_back(p);
        }
        self.n_posted += 1;
    }

    /// Store an unexpected envelope (no posted match existed).
    fn push_unexpected(&mut self, stamp: u64, env: Envelope) {
        self.unexpected.entry((env.src, env.tag)).or_default().push_back((stamp, env));
        self.n_unexpected += 1;
    }

    /// Remove a posted receive by request id (cancel / request_free).
    fn withdraw(&mut self, rid: ReqId) -> bool {
        if let Some(i) = self.posted_wild.iter().position(|p| p.rid == rid) {
            self.posted_wild.remove(i);
            self.n_posted -= 1;
            return true;
        }
        let mut hit: Option<(i32, i32)> = None;
        for (&key, q) in self.posted_exact.iter_mut() {
            if let Some(i) = q.iter().position(|p| p.rid == rid) {
                q.remove(i);
                hit = Some(key);
                break;
            }
        }
        if let Some(key) = hit {
            if self.posted_exact.get(&key).map(|q| q.is_empty()).unwrap_or(false) {
                self.posted_exact.remove(&key);
            }
            self.n_posted -= 1;
            return true;
        }
        false
    }

    fn is_empty(&self) -> bool {
        self.n_unexpected == 0 && self.n_posted == 0
    }
}

// ---------------------------------------------------------------------------
// MatchIndex — the engine-facing surface (indexed or flat)
// ---------------------------------------------------------------------------

/// Matching-engine statistics backing the pvar registry
/// ([`crate::core::obs`]). Plain `u64`s — the index lives inside the
/// rank's single-threaded `RefCell`, so no atomics.
///
/// Counting rules (what "attempt" means): one per [`MatchIndex::arrive`]
/// and [`MatchIndex::post`] call, plus one per *successful*
/// [`MatchIndex::take_unexpected`] — the blocking-recv fast path
/// spin-probes `take_unexpected`, so counting failed probes would make
/// the counter timing-dependent. [`MatchIndex::take_tag_below`] (the RMA
/// router) is internal traffic and not counted.
#[derive(Clone, Copy, Debug, Default)]
pub struct MatchStats {
    /// Match attempts (arrivals routed + receives posted + successful
    /// unexpected takes).
    pub attempts: u64,
    /// Successful matches where a wildcard was involved: the taken
    /// posted receive was wildcard, or the probing pattern was.
    pub wildcard_matches: u64,
    /// High-water mark of the unexpected-message count.
    pub unexpected_hwm: u64,
    /// High-water mark of the posted-receive count.
    pub posted_hwm: u64,
}

/// The per-rank matching engine. All posted receives and unexpected
/// messages of every context plane live here; see the module docs for
/// the structure and the invariant.
pub struct MatchIndex {
    /// Pvar-registry statistics (attempts, wildcard matches, queue
    /// high-water marks).
    pub stats: MatchStats,
    /// `true` = flat-baseline mode (`MPI_ABI_FLAT_MATCH=1`): linear
    /// scans over two flat queues, the seed engine's data layout.
    flat: bool,
    /// context id → that plane's queues (indexed mode).
    contexts: FxHashMap<u32, ContextQueues>,
    /// Global arrival counter (stamps unexpected entries).
    arrival_stamp: u64,
    /// Global post counter (stamps posted entries).
    post_stamp: u64,
    /// Flat mode: all unexpected messages, arrival order.
    flat_unexpected: VecDeque<Envelope>,
    /// Flat mode: all posted receives, post order.
    flat_posted: VecDeque<(u32, PostedRecv)>,
}

impl MatchIndex {
    /// Build the index; mode from the `MPI_ABI_FLAT_MATCH` env flag
    /// unless the job overrode it (see [`MatchIndex::with_mode`]).
    pub fn new() -> MatchIndex {
        MatchIndex::with_mode(flat_match_env())
    }

    /// Build the index with an explicit mode (`flat = true` restores the
    /// seed's linear-scan baseline).
    pub fn with_mode(flat: bool) -> MatchIndex {
        MatchIndex {
            stats: MatchStats::default(),
            flat,
            contexts: FxHashMap::default(),
            arrival_stamp: 0,
            post_stamp: 0,
            flat_unexpected: VecDeque::new(),
            flat_posted: VecDeque::new(),
        }
    }

    /// Whether the flat baseline is active (the engine also disables the
    /// zero-alloc fast paths then, so the flag restores the pre-index
    /// behavior end to end).
    #[inline]
    pub fn is_flat(&self) -> bool {
        self.flat
    }

    /// Route an arriving data envelope. If a posted receive matches, it
    /// is removed from the index and returned with the envelope (the
    /// caller delivers); otherwise the envelope is stored unexpected.
    pub fn arrive(&mut self, env: Envelope) -> Option<(ReqId, Envelope)> {
        self.stats.attempts += 1;
        if self.flat {
            if let Some(i) = self
                .flat_posted
                .iter()
                .position(|(cx, p)| *cx == env.context && p.accepts(env.src, env.tag))
            {
                let (_, p) = self.flat_posted.remove(i).unwrap();
                if p.src == MPI_ANY_SOURCE || p.tag == MPI_ANY_TAG {
                    self.stats.wildcard_matches += 1;
                }
                return Some((p.rid, env));
            }
            self.flat_unexpected.push_back(env);
            self.note_unexpected_depth();
            return None;
        }
        let cq = self.contexts.entry(env.context).or_default();
        if let Some((rid, from_wild)) = cq.take_posted(env.src, env.tag) {
            if cq.is_empty() {
                self.contexts.remove(&env.context);
            }
            if from_wild {
                self.stats.wildcard_matches += 1;
            }
            return Some((rid, env));
        }
        self.arrival_stamp += 1;
        let stamp = self.arrival_stamp;
        cq.push_unexpected(stamp, env);
        self.note_unexpected_depth();
        None
    }

    /// Post a receive for `(context, src, tag)` (wildcards allowed). If
    /// an unexpected message matches, it is removed and returned (the
    /// caller delivers into `rid`); otherwise the receive is stored.
    pub fn post(&mut self, rid: ReqId, context: u32, src: i32, tag: i32) -> Option<Envelope> {
        self.stats.attempts += 1;
        if self.flat {
            if let Some(i) = self
                .flat_unexpected
                .iter()
                .position(|e| e.matches(context, src, tag))
            {
                if src == MPI_ANY_SOURCE || tag == MPI_ANY_TAG {
                    self.stats.wildcard_matches += 1;
                }
                return self.flat_unexpected.remove(i);
            }
            self.flat_posted.push_back((context, PostedRecv { rid, stamp: 0, src, tag }));
            self.note_posted_depth();
            return None;
        }
        let cq = self.contexts.entry(context).or_default();
        if let Some(env) = cq.take_unexpected(src, tag) {
            if cq.is_empty() {
                self.contexts.remove(&context);
            }
            if src == MPI_ANY_SOURCE || tag == MPI_ANY_TAG {
                self.stats.wildcard_matches += 1;
            }
            return Some(env);
        }
        self.post_stamp += 1;
        let stamp = self.post_stamp;
        cq.push_posted(PostedRecv { rid, stamp, src, tag });
        self.note_posted_depth();
        None
    }

    /// Remove a posted receive (`MPI_Cancel` / `MPI_Request_free` on a
    /// still-posted receive). Returns whether it was found.
    pub fn withdraw(&mut self, rid: ReqId) -> bool {
        if self.flat {
            if let Some(i) = self.flat_posted.iter().position(|(_, p)| p.rid == rid) {
                self.flat_posted.remove(i);
                return true;
            }
            return false;
        }
        let mut hit_cx = None;
        for (&cx, cq) in self.contexts.iter_mut() {
            if cq.withdraw(rid) {
                hit_cx = Some(cx);
                break;
            }
        }
        if let Some(cx) = hit_cx {
            if self.contexts.get(&cx).map(|c| c.is_empty()).unwrap_or(false) {
                self.contexts.remove(&cx);
            }
            return true;
        }
        false
    }

    /// Take the earliest unexpected message matching `(context, src,
    /// tag)` — `src`/`tag` may be wildcards. Used by the collective and
    /// RMA internals (which own their buffers and bypass the request
    /// table) and by the blocking-recv fast path.
    pub fn take_unexpected(&mut self, context: u32, src: i32, tag: i32) -> Option<Envelope> {
        let env = if self.flat {
            let i = self.flat_unexpected.iter().position(|e| e.matches(context, src, tag))?;
            self.flat_unexpected.remove(i)?
        } else {
            let cq = self.contexts.get_mut(&context)?;
            let env = cq.take_unexpected(src, tag)?;
            if cq.is_empty() {
                self.contexts.remove(&context);
            }
            env
        };
        // Only successful takes count: the blocking-recv fast path
        // spin-probes this, and failed probes are timing-dependent.
        self.stats.attempts += 1;
        if src == MPI_ANY_SOURCE || tag == MPI_ANY_TAG {
            self.stats.wildcard_matches += 1;
        }
        Some(env)
    }

    /// Peek the earliest unexpected message matching `(context, src,
    /// tag)` without removing it (`MPI_Iprobe`/`MPI_Probe`).
    pub fn peek_unexpected(&self, context: u32, src: i32, tag: i32) -> Option<&Envelope> {
        if self.flat {
            return self.flat_unexpected.iter().find(|e| e.matches(context, src, tag));
        }
        self.contexts.get(&context)?.peek_unexpected(src, tag)
    }

    /// Take the earliest unexpected message on `context` with `tag <
    /// tag_below` (the RMA progress router: every data/control tag sits
    /// below the fence-barrier band).
    pub fn take_tag_below(&mut self, context: u32, tag_below: i32) -> Option<Envelope> {
        if self.flat {
            let i = self
                .flat_unexpected
                .iter()
                .position(|e| e.context == context && e.tag < tag_below)?;
            return self.flat_unexpected.remove(i);
        }
        let cq = self.contexts.get_mut(&context)?;
        let env = cq.take_tag_below(tag_below)?;
        if cq.is_empty() {
            self.contexts.remove(&context);
        }
        Some(env)
    }

    /// Total unexpected messages held (diagnostics and tests).
    pub fn unexpected_len(&self) -> usize {
        if self.flat {
            return self.flat_unexpected.len();
        }
        self.contexts.values().map(|c| c.n_unexpected).sum()
    }

    /// Total posted receives held (diagnostics and tests).
    pub fn posted_len(&self) -> usize {
        if self.flat {
            return self.flat_posted.len();
        }
        self.contexts.values().map(|c| c.n_posted).sum()
    }

    /// Refresh the unexpected-queue high-water mark after a store.
    /// O(#contexts) in indexed mode — stores are already off the O(1)
    /// happy path, and context counts are small.
    fn note_unexpected_depth(&mut self) {
        let depth = self.unexpected_len() as u64;
        if depth > self.stats.unexpected_hwm {
            self.stats.unexpected_hwm = depth;
        }
    }

    /// Refresh the posted-queue high-water mark after a store.
    fn note_posted_depth(&mut self) {
        let depth = self.posted_len() as u64;
        if depth > self.stats.posted_hwm {
            self.stats.posted_hwm = depth;
        }
    }
}

impl Default for MatchIndex {
    fn default() -> Self {
        MatchIndex::new()
    }
}

/// Read the `MPI_ABI_FLAT_MATCH` baseline flag (value `1`).
pub fn flat_match_env() -> bool {
    std::env::var("MPI_ABI_FLAT_MATCH").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::transport::{MsgKind, Payload};

    fn env(src: u32, context: u32, tag: i32) -> Envelope {
        Envelope {
            src,
            context,
            tag,
            kind: MsgKind::Eager,
            seq: 0,
            payload: Payload::empty(),
        }
    }

    fn both_modes(f: impl Fn(&mut MatchIndex)) {
        for flat in [false, true] {
            let mut ix = MatchIndex::with_mode(flat);
            f(&mut ix);
        }
    }

    #[test]
    fn exact_bucket_is_fifo_by_arrival() {
        both_modes(|ix| {
            assert!(ix.arrive(env(1, 0, 5)).is_none());
            assert!(ix.arrive(env(1, 0, 5)).is_none());
            let a = ix.post(ReqId(10), 0, 1, 5);
            let b = ix.post(ReqId(11), 0, 1, 5);
            assert!(a.is_some() && b.is_some());
            assert_eq!(ix.unexpected_len(), 0);
            if !ix.is_flat() {
                assert!(ix.contexts.is_empty(), "emptied context entries must be freed");
            }
        });
    }

    #[test]
    fn arrival_picks_earliest_posted_across_exact_and_wildcard() {
        both_modes(|ix| {
            // Wildcard posted first, then exact: the wildcard wins.
            assert!(ix.post(ReqId(1), 0, MPI_ANY_SOURCE, 5).is_none());
            assert!(ix.post(ReqId(2), 0, 3, 5).is_none());
            let (rid, _) = ix.arrive(env(3, 0, 5)).unwrap();
            assert_eq!(rid, ReqId(1));
            let (rid, _) = ix.arrive(env(3, 0, 5)).unwrap();
            assert_eq!(rid, ReqId(2));
        });
    }

    #[test]
    fn exact_posted_before_wildcard_wins() {
        both_modes(|ix| {
            assert!(ix.post(ReqId(1), 0, 3, 5).is_none());
            assert!(ix.post(ReqId(2), 0, MPI_ANY_SOURCE, MPI_ANY_TAG).is_none());
            let (rid, _) = ix.arrive(env(3, 0, 5)).unwrap();
            assert_eq!(rid, ReqId(1));
            let (rid, _) = ix.arrive(env(7, 0, 9)).unwrap();
            assert_eq!(rid, ReqId(2));
        });
    }

    #[test]
    fn wildcard_recv_takes_earliest_arrival_across_buckets() {
        both_modes(|ix| {
            assert!(ix.arrive(env(2, 0, 8)).is_none()); // earliest
            assert!(ix.arrive(env(1, 0, 5)).is_none());
            let got = ix.post(ReqId(1), 0, MPI_ANY_SOURCE, MPI_ANY_TAG).unwrap();
            assert_eq!((got.src, got.tag), (2, 8));
            let got = ix.post(ReqId(2), 0, MPI_ANY_SOURCE, MPI_ANY_TAG).unwrap();
            assert_eq!((got.src, got.tag), (1, 5));
        });
    }

    #[test]
    fn contexts_are_isolated() {
        both_modes(|ix| {
            assert!(ix.arrive(env(1, 7, 5)).is_none());
            assert!(ix.post(ReqId(1), 8, 1, 5).is_none(), "other context must not match");
            assert!(ix.take_unexpected(8, 1, 5).is_none());
            assert!(ix.take_unexpected(7, 1, 5).is_some());
            // The posted recv on context 8 is still there.
            let (rid, _) = ix.arrive(env(1, 8, 5)).unwrap();
            assert_eq!(rid, ReqId(1));
        });
    }

    #[test]
    fn withdraw_removes_posted() {
        both_modes(|ix| {
            assert!(ix.post(ReqId(1), 0, 1, 5).is_none());
            assert!(ix.post(ReqId(2), 0, MPI_ANY_SOURCE, 5).is_none());
            assert!(ix.withdraw(ReqId(1)));
            assert!(!ix.withdraw(ReqId(1)), "second withdraw finds nothing");
            // The arrival now matches the wildcard (the exact is gone).
            let (rid, _) = ix.arrive(env(1, 0, 5)).unwrap();
            assert_eq!(rid, ReqId(2));
        });
    }

    #[test]
    fn peek_does_not_remove() {
        both_modes(|ix| {
            assert!(ix.arrive(env(4, 0, 6)).is_none());
            assert!(ix.peek_unexpected(0, 4, 6).is_some());
            assert!(ix.peek_unexpected(0, MPI_ANY_SOURCE, MPI_ANY_TAG).is_some());
            assert_eq!(ix.unexpected_len(), 1);
            assert!(ix.take_unexpected(0, 4, MPI_ANY_TAG).is_some());
            assert!(ix.peek_unexpected(0, 4, 6).is_none());
        });
    }

    #[test]
    fn take_tag_below_respects_band_and_order() {
        both_modes(|ix| {
            assert!(ix.arrive(env(1, 9, 100)).is_none());
            assert!(ix.arrive(env(1, 9, 2)).is_none());
            assert!(ix.arrive(env(2, 9, 3)).is_none());
            // 100 is above the band; 2 arrived before 3.
            let got = ix.take_tag_below(9, 50).unwrap();
            assert_eq!(got.tag, 2);
            let got = ix.take_tag_below(9, 50).unwrap();
            assert_eq!(got.tag, 3);
            assert!(ix.take_tag_below(9, 50).is_none());
            assert_eq!(ix.unexpected_len(), 1);
        });
    }

    #[test]
    fn posted_any_source_concrete_tag_filters() {
        both_modes(|ix| {
            assert!(ix.post(ReqId(1), 0, MPI_ANY_SOURCE, 5).is_none());
            assert!(ix.arrive(env(3, 0, 6)).is_none(), "tag 6 must not match tag-5 recv");
            let (rid, _) = ix.arrive(env(3, 0, 5)).unwrap();
            assert_eq!(rid, ReqId(1));
            assert_eq!(ix.unexpected_len(), 1);
            assert!(ix.take_unexpected(0, 3, 6).is_some());
        });
    }
}
