//! ULFM fault-tolerance battery.
//!
//! Each scenario launches its **own job** with a deterministic kill
//! spec ([`JobSpec::with_kill`]) instead of riding the shared
//! [`super::run_registry`] harness — that harness AND-reduces verdicts
//! over `MPI_COMM_WORLD`, which is exactly the kind of collective a
//! dead rank poisons. The scenarios cover the tentpole claims end to
//! end, through the portable [`MpiAbi`] surface only, so the same
//! source validates all five configurations × both transports:
//!
//! * a blocked receive from a dead peer **fails** with
//!   `MPI_ERR_PROC_FAILED` instead of hanging;
//! * a wildcard receive reports `MPI_ERR_PROC_FAILED_PENDING`, and
//!   `MPI_Comm_ack_failed` clears the pending state;
//! * `MPI_Comm_revoke` poisons both context planes — pending pt2pt
//!   *and* collectives fail with `MPI_ERR_REVOKED`, with no new
//!   message required to propagate it;
//! * `MPI_Comm_shrink` yields a working survivor communicator
//!   (barrier + pt2pt round-trip succeed on it);
//! * `MPI_Comm_agree` returns the AND over surviving contributions;
//! * a rank killed mid-rendezvous fails the receiver cleanly;
//! * the `ranks_failed` / `ops_failed_proc` / `comms_revoked` pvars
//!   read **exact** counts through MPI_T after an injected kill.

use super::util::*;
use crate::abi::errors as ec;
use crate::api::{Dt, MpiAbi};
use crate::core::transport::TransportKind;
use crate::launcher::{run_job, JobSpec, RankOutcome};

/// A ULFM scenario: runs a whole job on the given transport.
pub type UlfmScenario = fn(TransportKind) -> Result<(), String>;

pub fn scenarios<A: MpiAbi>() -> Vec<(&'static str, UlfmScenario)> {
    vec![
        ("ulfm.recv_from_dead_fails", recv_from_dead_fails::<A>),
        ("ulfm.wildcard_pending_then_ack", wildcard_pending_then_ack::<A>),
        ("ulfm.revoke_poisons_both_planes", revoke_poisons_both_planes::<A>),
        ("ulfm.shrink_then_barrier", shrink_then_barrier::<A>),
        ("ulfm.agree_returns_and", agree_returns_and::<A>),
        ("ulfm.rendezvous_kill_fails_receiver", rendezvous_kill_fails_receiver::<A>),
        ("ulfm.pvar_exact_counts_after_kill", pvar_exact_counts_after_kill::<A>),
    ]
}

/// Run a job and fold the per-rank outcomes into one verdict: every
/// rank must return `Ok(())`, except the victim (if any), whose one
/// legal outcome is [`RankOutcome::Killed`].
fn run_scenario<F>(spec: JobSpec, victim: Option<usize>, f: F) -> Result<(), String>
where
    F: Fn(usize) -> Result<(), String> + Sync,
{
    let out = run_job(spec, f);
    for (rank, o) in out.into_iter().enumerate() {
        match o {
            RankOutcome::Ok(Ok(())) => {}
            RankOutcome::Ok(Err(m)) => return Err(format!("rank {rank}: {m}")),
            RankOutcome::Killed if Some(rank) == victim => {}
            other => return Err(format!("rank {rank}: unexpected outcome: {other:?}")),
        }
    }
    Ok(())
}

/// Ticks the victim survives before the injector fires: small enough
/// that it always dies inside its first blocking call.
const KILL_TICKS: u64 = 3;

/// A blocked receive from a peer that dies must complete in error —
/// `MPI_ERR_PROC_FAILED`, resolvable to a string — not hang.
fn recv_from_dead_fails<A: MpiAbi>(t: TransportKind) -> Result<(), String> {
    let spec = JobSpec::new(2).with_transport(t).with_kill(1, KILL_TICKS);
    run_scenario(spec, Some(1), |rank| {
        check!(A::init() == 0, "init");
        let dt = A::datatype(Dt::Int);
        let world = A::comm_world();
        let mut st = A::status_empty();
        if rank == 1 {
            // Victim: block in a recv that can never match; each spin
            // runs the progress engine until the injector unwinds us.
            let mut v = 0i32;
            let _ = A::recv(ptr_mut(&mut v), 1, dt, 0, 31999, world, &mut st);
            return Ok(()); // unreachable: the injector fires first
        }
        A::comm_set_errhandler(world, A::errhandler_return());
        let mut v = 0i32;
        let rc = A::recv(ptr_mut(&mut v), 1, dt, 1, 7, world, &mut st);
        check!(rc != 0, "recv from dead peer returned success");
        check!(
            A::err_class_of(rc) == ec::MPI_ERR_PROC_FAILED,
            "class: want PROC_FAILED, got {}",
            A::err_class_of(rc)
        );
        check!(!A::error_string(rc).is_empty(), "PROC_FAILED has no error string");
        Ok(())
    })
}

/// A wildcard receive cannot block while an unacknowledged failure
/// exists: it reports `MPI_ERR_PROC_FAILED_PENDING`. After
/// `MPI_Comm_ack_failed`, the same wildcard receive completes normally
/// from a surviving sender.
fn wildcard_pending_then_ack<A: MpiAbi>(t: TransportKind) -> Result<(), String> {
    let spec = JobSpec::new(3).with_transport(t).with_kill(1, KILL_TICKS);
    run_scenario(spec, Some(1), |rank| {
        check!(A::init() == 0, "init");
        let dt = A::datatype(Dt::Int);
        let world = A::comm_world();
        let mut st = A::status_empty();
        match rank {
            1 => {
                let mut v = 0i32;
                let _ = A::recv(ptr_mut(&mut v), 1, dt, 0, 31999, world, &mut st);
                Ok(())
            }
            0 => {
                A::comm_set_errhandler(world, A::errhandler_return());
                let mut v = 0i32;
                let rc = A::recv(ptr_mut(&mut v), 1, dt, A::any_source(), 7, world, &mut st);
                check!(
                    A::err_class_of(rc) == ec::MPI_ERR_PROC_FAILED_PENDING,
                    "wildcard class: want PROC_FAILED_PENDING, got {}",
                    A::err_class_of(rc)
                );
                // Acknowledge the failure; wildcard receives may block
                // again afterwards.
                let mut acked = 0;
                check_rc!(A::comm_ack_failed(world, 16, &mut acked), "comm_ack_failed");
                check!(acked == 1, "acked failures: want 1, got {acked}");
                // Release rank 2, then the same wildcard recv succeeds.
                let go = 1i32;
                check_rc!(A::send(ptr(&go), 1, dt, 2, 8, world), "go send");
                let rc = A::recv(ptr_mut(&mut v), 1, dt, A::any_source(), 7, world, &mut st);
                check_rc!(rc, "post-ack wildcard recv");
                check!(v == 77, "payload: want 77, got {v}");
                check!(A::status_source(&st) == 2, "source: want 2");
                Ok(())
            }
            _ => {
                A::comm_set_errhandler(world, A::errhandler_return());
                let mut go = 0i32;
                check_rc!(A::recv(ptr_mut(&mut go), 1, dt, 0, 8, world, &mut st), "go recv");
                let payload = 77i32;
                check_rc!(A::send(ptr(&payload), 1, dt, 0, 7, world), "payload send");
                Ok(())
            }
        }
    })
}

/// `MPI_Comm_revoke` poisons both context planes with no failure in the
/// job at all: a *pending* irecv fails `MPI_ERR_REVOKED`, new sends are
/// refused at post time, and collectives on the revoked comm fail too.
fn revoke_poisons_both_planes<A: MpiAbi>(t: TransportKind) -> Result<(), String> {
    let spec = JobSpec::new(2).with_transport(t);
    run_scenario(spec, None, |rank| {
        check!(A::init() == 0, "init");
        let dt = A::datatype(Dt::Int);
        let world = A::comm_world();
        A::comm_set_errhandler(world, A::errhandler_return());
        let mut st = A::status_empty();
        if rank == 0 {
            // Post a receive that can never be satisfied, tell rank 1
            // it is pending, then wait: revocation must fail it without
            // any message arriving.
            let mut v = 0i32;
            let mut req = A::request_null();
            check_rc!(A::irecv(ptr_mut(&mut v), 1, dt, 1, 5, world, &mut req), "irecv");
            let posted = 1i32;
            check_rc!(A::send(ptr(&posted), 1, dt, 1, 6, world), "posted signal");
            let rc = A::wait(&mut req, &mut st);
            check!(
                A::err_class_of(rc) == ec::MPI_ERR_REVOKED,
                "pending irecv: want REVOKED, got {}",
                A::err_class_of(rc)
            );
            // The pt2pt plane refuses new traffic at post time.
            let rc = A::send(ptr(&posted), 1, dt, 1, 9, world);
            check!(
                A::err_class_of(rc) == ec::MPI_ERR_REVOKED,
                "post-revoke send: want REVOKED, got {}",
                A::err_class_of(rc)
            );
        } else {
            let mut v = 0i32;
            check_rc!(A::recv(ptr_mut(&mut v), 1, dt, 0, 6, world, &mut st), "posted signal");
            check_rc!(A::comm_revoke(world), "comm_revoke");
            let mut revoked = false;
            check_rc!(A::comm_is_revoked(world, &mut revoked), "comm_is_revoked");
            check!(revoked, "comm_is_revoked after revoke");
        }
        // Both ranks: the collective plane is poisoned too.
        let rc = A::barrier(world);
        check!(
            A::err_class_of(rc) == ec::MPI_ERR_REVOKED,
            "barrier on revoked comm: want REVOKED, got {}",
            A::err_class_of(rc)
        );
        Ok(())
    })
}

/// The full recovery sequence: detect the failure, revoke, agree,
/// shrink — then prove the shrunk comm *works*: right size and ranks, a
/// clean barrier, and a pt2pt round-trip between the survivors.
fn shrink_then_barrier<A: MpiAbi>(t: TransportKind) -> Result<(), String> {
    let spec = JobSpec::new(3).with_transport(t).with_kill(1, KILL_TICKS);
    run_scenario(spec, Some(1), |rank| {
        check!(A::init() == 0, "init");
        let dt = A::datatype(Dt::Int);
        let world = A::comm_world();
        let mut st = A::status_empty();
        if rank == 1 {
            let mut v = 0i32;
            let _ = A::recv(ptr_mut(&mut v), 1, dt, 0, 31999, world, &mut st);
            return Ok(());
        }
        A::comm_set_errhandler(world, A::errhandler_return());
        let mut v = 0i32;
        let rc = A::recv(ptr_mut(&mut v), 1, dt, 1, 3, world, &mut st);
        check!(
            A::err_class_of(rc) == ec::MPI_ERR_PROC_FAILED,
            "detection: want PROC_FAILED, got {}",
            A::err_class_of(rc)
        );
        check_rc!(A::comm_revoke(world), "comm_revoke");
        let mut flag = 1i32;
        check_rc!(A::comm_agree(world, &mut flag), "comm_agree");
        check!(flag == 1, "agree over survivors");
        let mut newc = A::comm_null();
        check_rc!(A::comm_shrink(world, &mut newc), "comm_shrink");
        A::comm_set_errhandler(newc, A::errhandler_return());
        let (mut size, mut me) = (0, 0);
        check_rc!(A::comm_size(newc, &mut size), "comm_size");
        check_rc!(A::comm_rank(newc, &mut me), "comm_rank");
        check!(size == 2, "shrunk size: want 2, got {size}");
        let want_rank = if rank == 0 { 0 } else { 1 };
        check!(me == want_rank, "shrunk rank: want {want_rank}, got {me}");
        check_rc!(A::barrier(newc), "barrier on shrunk comm");
        // Survivor round-trip on the fresh planes.
        if me == 0 {
            let x = 42i32;
            check_rc!(A::send(ptr(&x), 1, dt, 1, 11, newc), "shrunk send");
            let mut y = 0i32;
            check_rc!(A::recv(ptr_mut(&mut y), 1, dt, 1, 12, newc, &mut st), "shrunk recv");
            check!(y == 43, "round-trip payload");
        } else {
            let mut x = 0i32;
            check_rc!(A::recv(ptr_mut(&mut x), 1, dt, 0, 11, newc, &mut st), "shrunk recv");
            let y = x + 1;
            check_rc!(A::send(ptr(&y), 1, dt, 0, 12, newc), "shrunk send");
        }
        Ok(())
    })
}

/// `MPI_Comm_agree` is the AND over *surviving* contributions: with the
/// victim gone, 1 AND 0 is 0, then 1 AND 1 is 1.
fn agree_returns_and<A: MpiAbi>(t: TransportKind) -> Result<(), String> {
    let spec = JobSpec::new(3).with_transport(t).with_kill(1, KILL_TICKS);
    run_scenario(spec, Some(1), |rank| {
        check!(A::init() == 0, "init");
        let dt = A::datatype(Dt::Int);
        let world = A::comm_world();
        let mut st = A::status_empty();
        if rank == 1 {
            let mut v = 0i32;
            let _ = A::recv(ptr_mut(&mut v), 1, dt, 0, 31999, world, &mut st);
            return Ok(());
        }
        A::comm_set_errhandler(world, A::errhandler_return());
        // Detect the failure first so both survivors agree on who's left.
        let mut v = 0i32;
        let rc = A::recv(ptr_mut(&mut v), 1, dt, 1, 3, world, &mut st);
        check!(A::err_class_of(rc) == ec::MPI_ERR_PROC_FAILED, "detection");
        let mut flag = if rank == 0 { 1 } else { 0 };
        check_rc!(A::comm_agree(world, &mut flag), "comm_agree");
        check!(flag == 0, "1 AND 0: want 0, got {flag}");
        let mut flag = 1i32;
        check_rc!(A::comm_agree(world, &mut flag), "comm_agree");
        check!(flag == 1, "1 AND 1: want 1, got {flag}");
        Ok(())
    })
}

/// A peer killed while streaming a rendezvous payload fails the
/// receiver cleanly with `MPI_ERR_PROC_FAILED` — the half-filled
/// stream is torn down, not left to hang the receive.
fn rendezvous_kill_fails_receiver<A: MpiAbi>(t: TransportKind) -> Result<(), String> {
    // Threshold 0 forces the rendezvous protocol for every message;
    // 4 MiB takes far more progress ticks to stream than the victim
    // gets, so it always dies mid-transfer.
    let spec = JobSpec::new(2).with_transport(t).with_kill(1, 6).with_rndv_threshold(0);
    run_scenario(spec, Some(1), |rank| {
        check!(A::init() == 0, "init");
        let dt = A::datatype(Dt::Byte);
        let world = A::comm_world();
        let mut st = A::status_empty();
        const LEN: usize = 4 << 20;
        if rank == 1 {
            let big = vec![9u8; LEN];
            let _ = A::send(slice_ptr(&big), LEN as i32, dt, 0, 21, world);
            return Ok(()); // unreachable: dies while pumping the stream
        }
        A::comm_set_errhandler(world, A::errhandler_return());
        let mut buf = vec![0u8; LEN];
        let rc = A::recv(slice_ptr_mut(&mut buf), LEN as i32, dt, 1, 21, world, &mut st);
        check!(rc != 0, "mid-rendezvous kill: recv returned success");
        check!(
            A::err_class_of(rc) == ec::MPI_ERR_PROC_FAILED,
            "mid-rendezvous kill: want PROC_FAILED, got {}",
            A::err_class_of(rc)
        );
        Ok(())
    })
}

/// The observability contract (MPI_T): after one injected kill, one
/// failed operation, and one revocation, the `ranks_failed`,
/// `ops_failed_proc` and `comms_revoked` pvars read **exactly** 1/1/1
/// (then a second failed op reads exactly 2) — counters, not vibes.
fn pvar_exact_counts_after_kill<A: MpiAbi>(t: TransportKind) -> Result<(), String> {
    use crate::abi::constants as k;
    // Fixed pvar registry indices (SPEC.md §11 table; append-only).
    const PV_RANKS_FAILED: i32 = 17;
    const PV_OPS_FAILED: i32 = 18;
    const PV_COMMS_REVOKED: i32 = 19;
    let spec = JobSpec::new(3).with_transport(t).with_kill(1, KILL_TICKS);
    run_scenario(spec, Some(1), |rank| {
        check!(A::init() == 0, "init");
        let dt = A::datatype(Dt::Int);
        let world = A::comm_world();
        let mut st = A::status_empty();
        if rank == 1 {
            let mut v = 0i32;
            let _ = A::recv(ptr_mut(&mut v), 1, dt, 0, 31999, world, &mut st);
            return Ok(());
        }
        if rank == 2 {
            // Bystander: exits cleanly, touches nothing — the exact
            // counts below belong to rank 0 alone (ops_failed_proc is
            // a per-rank counter; the other two are world-level).
            return Ok(());
        }
        A::comm_set_errhandler(world, A::errhandler_return());
        let mut provided = 0;
        check_rc!(A::t_init_thread(k::MPI_THREAD_SINGLE, &mut provided), "t_init_thread");
        let mut session = -1;
        check_rc!(A::t_pvar_session_create(&mut session), "session_create");
        // Arm (and so baseline) the counters *before* the failures.
        let mut handles = [-1i32; 3];
        for (h, idx) in
            handles.iter_mut().zip([PV_RANKS_FAILED, PV_OPS_FAILED, PV_COMMS_REVOKED])
        {
            check_rc!(A::t_pvar_handle_alloc(session, idx, h), "pvar_handle_alloc");
            check_rc!(A::t_pvar_start(session, *h), "pvar_start");
        }
        let read = |h: i32| -> Result<i64, String> {
            let mut v = -1i64;
            let rc = A::t_pvar_read(session, h, &mut v);
            if rc != 0 {
                return Err(format!("pvar_read rc {rc}"));
            }
            Ok(v)
        };
        // First failed op against the dead rank.
        let mut v = 0i32;
        let rc = A::recv(ptr_mut(&mut v), 1, dt, 1, 3, world, &mut st);
        check!(A::err_class_of(rc) == ec::MPI_ERR_PROC_FAILED, "detection");
        check!(read(handles[0])? == 1, "ranks_failed: want exactly 1");
        check!(read(handles[1])? == 1, "ops_failed_proc: want exactly 1");
        check!(read(handles[2])? == 0, "comms_revoked before revoke: want 0");
        // A second failed op moves ops_failed_proc alone — a send this
        // time, refused at post time because its destination is dead.
        let rc = A::send(ptr(&v), 1, dt, 1, 4, world);
        check!(A::err_class_of(rc) == ec::MPI_ERR_PROC_FAILED, "dead-dst send");
        check!(read(handles[1])? == 2, "ops_failed_proc: want exactly 2");
        // One revocation. A second revoke of the same comm is a no-op
        // and must not double-count.
        check_rc!(A::comm_revoke(world), "comm_revoke");
        check_rc!(A::comm_revoke(world), "second comm_revoke");
        check!(read(handles[0])? == 1, "ranks_failed moved");
        check!(read(handles[2])? == 1, "comms_revoked: want exactly 1");
        check_rc!(A::t_finalize(), "t_finalize");
        Ok(())
    })
}
