//! Broadcast, reduction, and scan collectives — blocking entry points.
//!
//! Each is `wait(i<coll>())` over the schedule engine ([`super::sched`]);
//! the algorithms (binomial trees, reduce+bcast allreduce, linear scan
//! chains) live exactly once, as schedule builders.

use super::{sched, wait_coll};
use crate::core::{CommId, DtId, OpId, RC};

/// `MPI_Bcast`.
pub fn bcast(buf: *mut u8, count: usize, dt: DtId, root: i32, comm: CommId) -> RC<()> {
    wait_coll(sched::ibcast(buf, count, dt, root, comm)?)
}

/// `MPI_Reduce`.
pub fn reduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::ireduce(sendbuf, recvbuf, count, dt, op, root, comm)?)
}

/// `MPI_Allreduce` (reduce to 0, then broadcast — two tag phases of one
/// collective).
pub fn allreduce(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::iallreduce(sendbuf, recvbuf, count, dt, op, comm)?)
}

/// `MPI_Reduce_scatter_block`.
pub fn reduce_scatter_block(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    recvcount: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::ireduce_scatter_block(sendbuf, recvbuf, recvcount, dt, op, comm)?)
}

/// `MPI_Scan` (inclusive, linear chain).
pub fn scan(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::iscan(sendbuf, recvbuf, count, dt, op, comm)?)
}

/// `MPI_Exscan` (exclusive; rank 0's recvbuf is untouched, as the
/// standard leaves it undefined).
pub fn exscan(
    sendbuf: *const u8,
    recvbuf: *mut u8,
    count: usize,
    dt: DtId,
    op: OpId,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::iexscan(sendbuf, recvbuf, count, dt, op, comm)?)
}
