//! Collective-path ablations: allreduce cost across ABI configs and the
//! XLA (compiled Pallas kernel) vs scalar reduce-combine ablation — the
//! DESIGN.md §5 threshold study for the L1 offload.

use mpi_abi::api::{Dt, MpiAbi, OpName};
use mpi_abi::apps::{with_abi, AbiApp, AbiConfig};
use mpi_abi::bench::{bench, bench_external, Table};
use mpi_abi::core::datatype::ScalarKind;
use mpi_abi::core::op::{apply_builtin, BuiltinOp};
use mpi_abi::launcher::{run_job_ok, JobSpec};

struct Allreduce {
    count: usize,
    iters: usize,
}

impl AbiApp<f64> for Allreduce {
    fn run<A: MpiAbi>(self) -> f64 {
        let out = run_job_ok(JobSpec::new(2), |_| {
            A::init();
            let dt = A::datatype(Dt::Float);
            let op = A::op(OpName::Sum);
            let send = vec![1.0f32; self.count];
            let mut recv = vec![0.0f32; self.count];
            // Warmup (also compiles the XLA executable if enabled).
            for _ in 0..3 {
                A::allreduce(send.as_ptr() as *const u8, recv.as_mut_ptr() as *mut u8,
                    self.count as i32, dt, op, A::comm_world());
            }
            let t0 = A::wtime();
            for _ in 0..self.iters {
                A::allreduce(send.as_ptr() as *const u8, recv.as_mut_ptr() as *mut u8,
                    self.count as i32, dt, op, A::comm_world());
            }
            let e = (A::wtime() - t0) / self.iters as f64;
            A::finalize();
            e
        });
        out[0]
    }
}

fn main() {
    println!("\nCollective ablations (2 ranks, f32 SUM allreduce)");

    // (a) Allreduce across ABI configs at a small and a large count.
    std::env::set_var("MPI_ABI_NO_XLA", "1");
    let mut table = Table::new(
        "allreduce µs/op (scalar combine)",
        &["ABI", "count=1024", "count=65536"],
    );
    for abi in [AbiConfig::Mpich, AbiConfig::NativeAbi, AbiConfig::MukMpich] {
        let small = with_abi(abi, Allreduce { count: 1024, iters: 200 });
        let large = with_abi(abi, Allreduce { count: 65536, iters: 30 });
        table.row(&[
            abi.name().to_string(),
            format!("{:.1}", small * 1e6),
            format!("{:.1}", large * 1e6),
        ]);
    }
    println!("{}", table.render());

    // (b) XLA offload ablation on the raw combine step (no job needed).
    println!("reduce-combine kernel: scalar loop vs compiled Pallas (XLA)");
    for n in [4096usize, 65536, 1_048_576] {
        let a = vec![1.0f32; n];
        let mut b = vec![2.0f32; n];
        let abytes = unsafe { std::slice::from_raw_parts(a.as_ptr() as *const u8, 4 * n) };

        std::env::set_var("MPI_ABI_NO_XLA", "1");
        mpi_abi::runtime::reset_thread_runtime();
        let s = bench(&format!("combine/scalar n={n}"), 3, 10, (1 << 22) / n, || {
            let bb = unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr() as *mut u8, 4 * n) };
            apply_builtin(BuiltinOp::Sum, ScalarKind::F32, abytes, bb, n).unwrap();
        });
        println!("{}", s.report());
        let scalar = s.median;

        std::env::set_var("MPI_ABI_NO_XLA", "0");
        std::env::set_var("MPI_ABI_XLA_REDUCE", "1");
        mpi_abi::runtime::reset_thread_runtime();
        let used = {
            let bb = unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr() as *mut u8, 4 * n) };
            mpi_abi::runtime::try_xla_reduce(BuiltinOp::Sum, ScalarKind::F32, abytes, bb, n)
        };
        if used {
            let s = bench(&format!("combine/xla    n={n}"), 3, 10, ((1 << 22) / n).max(2), || {
                let bb =
                    unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr() as *mut u8, 4 * n) };
                mpi_abi::runtime::try_xla_reduce(BuiltinOp::Sum, ScalarKind::F32, abytes, bb, n);
            });
            println!("{}", s.report());
            println!(
                "  xla/scalar ratio at n={n}: {:.2}x {}",
                s.median / scalar,
                if s.median < scalar { "(offload wins)" } else { "(scalar wins — threshold above this)" }
            );
        } else {
            println!("  (no artifacts for n={n}; run `make artifacts`)");
        }
    }

    // (c) DDP step time (the end-to-end compute+comm composition).
    std::env::set_var("MPI_ABI_NO_XLA", "0");
    if mpi_abi::runtime::artifacts_dir().is_some() {
        struct Ddp;
        impl AbiApp<f64> for Ddp {
            fn run<A: MpiAbi>(self) -> f64 {
                let out = run_job_ok(JobSpec::new(2), |_| {
                    A::init();
                    let t0 = A::wtime();
                    let steps = 5;
                    mpi_abi::apps::ddp::train::<A>(mpi_abi::apps::ddp::DdpParams {
                        steps,
                        lr: 0.05,
                        log_every: 0,
                    });
                    let e = (A::wtime() - t0) / steps as f64;
                    A::finalize();
                    e
                });
                out[0]
            }
        }
        let s = bench_external("ddp/step (abi, 2 ranks)", 1, || with_abi(AbiConfig::NativeAbi, Ddp));
        println!("{}", s.report());
    }
}
