//! MPI-4 **Sessions**: library-friendly initialization without
//! `MPI_Init`.
//!
//! A session is an isolated initialization epoch: a library component
//! calls `MPI_Session_init`, discovers the **process sets** the launcher
//! exposes (`mpi://WORLD`, `mpi://SELF`, plus any launcher-provided
//! sets), builds an `MPI_Group` from one, and derives a communicator
//! with `MPI_Comm_create_from_group` — never touching `MPI_COMM_WORLD`
//! and never requiring (or forbidding) the world model. World init and
//! any number of sessions may coexist; finalize order is free. The
//! shared **init refcount** lives in [`super::world::RankCtx`]
//! (`active_inits` / `ever_inited`), and `MPI_Initialized` /
//! `MPI_Finalized` report over it (see [`super::engine::initialized`]).
//!
//! # Context-plane agreement without a parent communicator
//!
//! `MPI_Comm_create_from_group` is the interesting part: every other
//! comm constructor agrees on the new (pt2pt, coll) context planes by
//! broadcasting over a *parent* communicator (and RMA windows do the
//! same for their (ops, ctrl) pair) — but here there is no parent. The
//! engine instead reserves a hidden, world-spanning **bootstrap
//! communicator** ([`super::reserved::COMM_BOOTSTRAP`], context planes
//! 4/5, installed alongside WORLD/SELF and never exposed through any
//! ABI): group rank 0 allocates a fresh plane pair from the world
//! counter and sends it to each member over the bootstrap planes, using
//! a wire tag derived from the caller's **tag string** ([`pset_tag`]).
//! Concurrent creations over overlapping groups are disambiguated by
//! their tag strings exactly as MPI-4 §11.6 prescribes (callers must
//! pass distinct strings); sequential creations with the *same* string
//! are ordered by the fabric's per-(source, context, tag) FIFO.

use super::world::{with_ctx, RankCtx};
use super::{err, CommId, ErrhId, GroupId, InfoId, SessionId, RC};

/// The process set every session exposes: all ranks of the job.
pub const PSET_WORLD: &str = "mpi://WORLD";
/// The singleton process set: just the calling process.
pub const PSET_SELF: &str = "mpi://SELF";

/// Session table entry: the error handler given at init and the
/// process-set table snapshotted from the launcher at init time.
pub struct SessionObj {
    /// Error handler attached at `MPI_Session_init`.
    pub errhandler: ErrhId,
    /// Named process sets visible to this process, in query order:
    /// `mpi://WORLD`, `mpi://SELF`, then launcher-provided sets that
    /// contain the calling rank.
    pub psets: Vec<(String, Vec<usize>)>,
}

fn build_psets(ctx: &RankCtx) -> Vec<(String, Vec<usize>)> {
    let mut v = vec![
        (PSET_WORLD.to_string(), (0..ctx.world.size).collect()),
        (PSET_SELF.to_string(), vec![ctx.rank]),
    ];
    for (name, members) in ctx.world.psets() {
        if members.contains(&ctx.rank) {
            v.push((name.clone(), members.clone()));
        }
    }
    v
}

/// `MPI_Session_init`. Legal before (or entirely without) `MPI_Init`;
/// bumps the shared init refcount so the library stays active until the
/// last world/session finalize.
pub fn session_init(errh: ErrhId) -> RC<SessionId> {
    with_ctx(|ctx| {
        super::engine::ensure_world_objects(ctx);
        let psets = build_psets(ctx);
        let id = {
            let mut t = ctx.tables.borrow_mut();
            if !t.errhs.contains(errh.0) {
                return Err(err!(MPI_ERR_ERRHANDLER));
            }
            t.sessions.insert(SessionObj { errhandler: errh, psets })
        };
        ctx.note_init();
        Ok(SessionId(id))
    })
}

/// `MPI_Session_finalize`. Errors with `MPI_ERR_SESSION` on an unknown
/// (double-finalized) session; decrements the shared init refcount.
pub fn session_finalize(id: SessionId) -> RC<()> {
    with_ctx(|ctx| {
        if ctx.tables.borrow_mut().sessions.remove(id.0).is_none() {
            return Err(err!(MPI_ERR_SESSION));
        }
        ctx.note_finalize_one();
        Ok(())
    })
}

/// `MPI_Session_get_num_psets`.
pub fn session_num_psets(id: SessionId) -> RC<i32> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let s = t.sessions.get(id.0).ok_or(err!(MPI_ERR_SESSION))?;
        Ok(s.psets.len() as i32)
    })
}

/// `MPI_Session_get_nth_pset`: the nth process-set name, in the stable
/// order of [`SessionObj::psets`].
pub fn session_nth_pset(id: SessionId, n: i32) -> RC<String> {
    with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let s = t.sessions.get(id.0).ok_or(err!(MPI_ERR_SESSION))?;
        if n < 0 {
            return Err(err!(MPI_ERR_ARG));
        }
        s.psets.get(n as usize).map(|(name, _)| name.clone()).ok_or(err!(MPI_ERR_ARG))
    })
}

fn find_pset(s: &SessionObj, name: &str) -> RC<Vec<usize>> {
    // Process-set names are URIs and compare case-insensitively
    // (MPI-4 §11.3.2).
    s.psets
        .iter()
        .find(|(n, _)| n.eq_ignore_ascii_case(name))
        .map(|(_, m)| m.clone())
        .ok_or(err!(MPI_ERR_ARG))
}

/// `MPI_Session_get_pset_info`: an info object describing the named
/// set (key `mpi_size` = number of members, per MPI-4 §11.3.3). The
/// caller owns (and frees) the returned info.
pub fn session_pset_info(id: SessionId, name: &str) -> RC<InfoId> {
    let members = with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let s = t.sessions.get(id.0).ok_or(err!(MPI_ERR_SESSION))?;
        find_pset(s, name)
    })?;
    let info = super::info::info_create()?;
    super::info::info_set(info, "mpi_size", &members.len().to_string())?;
    Ok(info)
}

/// `MPI_Group_from_session_pset`. Unknown set names error with
/// `MPI_ERR_ARG` (the diagnosable "no such pset" failure).
pub fn group_from_pset(id: SessionId, name: &str) -> RC<GroupId> {
    let members = with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        let s = t.sessions.get(id.0).ok_or(err!(MPI_ERR_SESSION))?;
        find_pset(s, name)
    })?;
    super::group::group_from_members(members)
}

/// FNV-1a of the tag string — the full 64-bit digest. The wire tag is a
/// 23-bit fold of this ([`pset_tag`]); the agreement payload carries the
/// whole digest so a wire-tag collision between two *distinct* strings
/// is detected at the receiver instead of silently cross-wiring two
/// concurrent creations.
fn tag_hash64(tag: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in tag.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Derive the bootstrap wire tag from a `MPI_Comm_create_from_group`
/// tag string: [`tag_hash64`] folded into the tag range (strictly below
/// `MPI_TAG_UB`, never negative). Distinct strings give distinct wire
/// tags with overwhelming probability; the full digest riding in the
/// payload catches the residual collision case.
pub fn pset_tag(tag: &str) -> i32 {
    (tag_hash64(tag) & 0x007F_FFFF) as i32
}

/// `MPI_Comm_create_from_group`: collective over exactly the group's
/// members, **no parent communicator**. Group rank 0 allocates the new
/// comm's (pt2pt, coll) context planes and distributes them over the
/// hidden bootstrap communicator, keyed by the tag string (module docs).
pub fn comm_create_from_group(group: GroupId, tag: &str) -> RC<CommId> {
    let (members, my_world) = with_ctx(|ctx| {
        super::engine::ensure_world_objects(ctx);
        let t = ctx.tables.borrow();
        let g = t.groups.get(group.0).ok_or(err!(MPI_ERR_GROUP))?;
        Ok((g.members.clone(), ctx.rank))
    })?;
    // The caller must be a member (MPI-4 §11.6: collective over the group).
    let my_rank = members.iter().position(|&m| m == my_world).ok_or(err!(MPI_ERR_GROUP))?;
    let full_hash = tag_hash64(tag);
    let wire_tag = pset_tag(tag);
    let byte = super::datatype::builtin_id_of_abi(crate::abi::datatypes::MPI_BYTE)
        .ok_or(err!(MPI_ERR_INTERN))?;
    // Payload: the (pt2pt, coll) plane pair + the full 64-bit tag digest
    // (so a 23-bit wire-tag collision between distinct strings is
    // detected, not silently cross-wired).
    let mut bytes = [0u8; 16];
    if my_rank == 0 {
        let (p, c) = with_ctx(|ctx| Ok(ctx.world.alloc_context_pair()))?;
        bytes[..4].copy_from_slice(&p.to_le_bytes());
        bytes[4..8].copy_from_slice(&c.to_le_bytes());
        bytes[8..].copy_from_slice(&full_hash.to_le_bytes());
        // The bootstrap comm spans the world in world-rank order, so a
        // member's world rank *is* its bootstrap rank.
        for &m in &members[1..] {
            super::engine::send(
                bytes.as_ptr(),
                16,
                byte,
                m as i32,
                wire_tag,
                super::reserved::COMM_BOOTSTRAP,
                super::engine::SendMode::Standard,
            )?;
        }
    } else {
        super::engine::recv(
            bytes.as_mut_ptr(),
            16,
            byte,
            members[0] as i32,
            wire_tag,
            super::reserved::COMM_BOOTSTRAP,
        )?;
        let got = u64::from_le_bytes(bytes[8..].try_into().unwrap());
        if got != full_hash {
            // Two concurrent creations with distinct tag strings landed
            // on the same 23-bit wire tag: diagnosable, not silent.
            return Err(err!(MPI_ERR_OTHER));
        }
    }
    let p = u32::from_le_bytes(bytes[..4].try_into().unwrap());
    let c = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    super::comm::insert_comm(members, my_rank, p, c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pset_tag_is_a_legal_send_tag() {
        for s in ["", "a", "mpi-abi://halo", "org.mpi-forum.example", "🦀"] {
            let t = pset_tag(s);
            assert!(t >= 0, "{s:?} -> {t}");
            assert!((t as i64) < crate::abi::constants::TAG_UB_VALUE as i64, "{s:?} -> {t}");
        }
    }

    #[test]
    fn pset_tag_distinguishes_strings() {
        assert_ne!(pset_tag("a"), pset_tag("b"));
        assert_ne!(pset_tag("mpi://WORLD"), pset_tag("mpi://SELF"));
    }

    #[test]
    fn sessions_only_init_finalize_refcount() {
        std::thread::spawn(|| {
            let w = crate::core::world::test_world(1);
            let ctx = crate::core::world::bind_rank(w, 0);
            assert!(!crate::core::engine::initialized());
            assert!(!crate::core::engine::finalized());
            let s1 = session_init(crate::core::reserved::ERRH_RETURN).unwrap();
            let s2 = session_init(crate::core::reserved::ERRH_RETURN).unwrap();
            assert_ne!(s1, s2);
            assert!(crate::core::engine::initialized(), "a session initializes the library");
            assert!(!crate::core::engine::finalized());
            session_finalize(s1).unwrap();
            assert!(!crate::core::engine::finalized(), "one session still active");
            session_finalize(s2).unwrap();
            assert!(crate::core::engine::finalized(), "last finalize finalizes the library");
            assert!(crate::core::engine::initialized(), "initialized stays true after finalize");
            // Double finalize is diagnosable.
            let e = session_finalize(s2).unwrap_err();
            assert_eq!(e.class, crate::abi::errors::MPI_ERR_SESSION);
            drop(ctx);
            crate::core::world::unbind_rank();
        })
        .join()
        .unwrap();
    }

    #[test]
    fn pset_table_lists_world_and_self() {
        std::thread::spawn(|| {
            let w = crate::core::world::test_world(1);
            let _ctx = crate::core::world::bind_rank(w, 0);
            let s = session_init(crate::core::reserved::ERRH_RETURN).unwrap();
            assert_eq!(session_num_psets(s).unwrap(), 2);
            assert_eq!(session_nth_pset(s, 0).unwrap(), PSET_WORLD);
            assert_eq!(session_nth_pset(s, 1).unwrap(), PSET_SELF);
            assert_eq!(
                session_nth_pset(s, 2).unwrap_err().class,
                crate::abi::errors::MPI_ERR_ARG
            );
            let e = group_from_pset(s, "mpi://NOPE").unwrap_err();
            assert_eq!(e.class, crate::abi::errors::MPI_ERR_ARG);
            session_finalize(s).unwrap();
            crate::core::world::unbind_rank();
        })
        .join()
        .unwrap();
    }
}
