//! Gather/scatter/allgather collectives — blocking entry points over the
//! schedule engine ([`super::sched`]). Displacements are in type extents
//! (MPI-style); the schedule builders convert to byte offsets.

use super::{sched, wait_coll};
use crate::core::{CommId, DtId, RC};

/// `MPI_Gather`.
#[allow(clippy::too_many_arguments)]
pub fn gather(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::igather(sendbuf, sendcount, sendtype, recvbuf, recvcount, recvtype, root,
        comm)?)
}

/// `MPI_Gatherv` (displacements in recvtype extents).
#[allow(clippy::too_many_arguments)]
pub fn gatherv(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::igatherv(sendbuf, sendcount, sendtype, recvbuf, recvcounts, displs,
        recvtype, root, comm)?)
}

/// `MPI_Scatter`.
#[allow(clippy::too_many_arguments)]
pub fn scatter(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::iscatter(sendbuf, sendcount, sendtype, recvbuf, recvcount, recvtype, root,
        comm)?)
}

/// `MPI_Scatterv` (displacements in sendtype extents).
#[allow(clippy::too_many_arguments)]
pub fn scatterv(
    sendbuf: *const u8,
    sendcounts: &[usize],
    displs: &[isize],
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    root: i32,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::iscatterv(sendbuf, sendcounts, displs, sendtype, recvbuf, recvcount,
        recvtype, root, comm)?)
}

/// `MPI_Allgather` (gather at 0, broadcast — two phases).
#[allow(clippy::too_many_arguments)]
pub fn allgather(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcount: usize,
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::iallgather(sendbuf, sendcount, sendtype, recvbuf, recvcount, recvtype,
        comm)?)
}

/// `MPI_Allgatherv`.
#[allow(clippy::too_many_arguments)]
pub fn allgatherv(
    sendbuf: *const u8,
    sendcount: usize,
    sendtype: DtId,
    recvbuf: *mut u8,
    recvcounts: &[usize],
    displs: &[isize],
    recvtype: DtId,
    comm: CommId,
) -> RC<()> {
    wait_coll(sched::iallgatherv(sendbuf, sendcount, sendtype, recvbuf, recvcounts, displs,
        recvtype, comm)?)
}
