//! End-to-end driver (E8): data-parallel training through all three
//! layers — compiled JAX/Pallas compute (L1+L2) + MPI allreduce over the
//! standard ABI (L3). Logs the loss curve.
//!
//! ```bash
//! make artifacts && cargo run --release --example ddp_train [ranks] [steps]
//! ```

use mpi_abi::api::MpiAbi;
use mpi_abi::apps::ddp::{train, DdpParams};
use mpi_abi::launcher::{run_job_ok, JobSpec};
use mpi_abi::muk::MukMpich;
use mpi_abi::native_abi::NativeAbi;

fn run<A: MpiAbi>(ranks: usize, steps: usize) -> (Vec<(usize, f32)>, f32) {
    let out = run_job_ok(JobSpec::new(ranks), |_| {
        A::init();
        let r = train::<A>(DdpParams { steps, lr: 0.05, log_every: steps / 8 + 1 });
        A::finalize();
        (r.loss_curve, r.final_loss)
    });
    out.into_iter().next().unwrap()
}

fn main() {
    let mut args = std::env::args().skip(1);
    let ranks: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(2);
    let steps: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(30);

    println!("DDP training: {ranks} ranks x {steps} steps (native standard ABI)");
    let (curve, final_loss) = run::<NativeAbi>(ranks, steps);
    println!("\nloss curve (native abi):");
    for (s, l) in &curve {
        println!("  step {s:4}  loss {l:.6}");
    }
    let first = curve.first().unwrap().1;
    println!("final loss {final_loss:.6} (started {first:.6})");
    assert!(final_loss < first, "training must reduce the loss");

    // Same training, translated MPI: results should track closely (same
    // seeds, same arithmetic; only the MPI library changed).
    println!("\nre-running through Mukautuva(mpich) to show ABI-independence…");
    let (_, muk_loss) = run::<MukMpich>(ranks, steps);
    println!("final loss via muk(mpich): {muk_loss:.6}");
    assert!((muk_loss - final_loss).abs() < 1e-5, "loss must not depend on the ABI");
    println!("identical convergence across ABIs ✓");
}
