//! Handle / constant / status / error-code conversion between the
//! standard ABI ("MUK" side) and a backend implementation ABI.
//!
//! This is the heart of Mukautuva (§6.2): predefined constants are
//! translated by table (the big `CONVERT_MPI_*` switches of
//! `impl-wrap.so`), user handles pass through the word union
//! ([`super::word::AsWord`]), statuses are converted field-by-field
//! between layouts, and error codes hit the inlined success fast path
//! before the class mapping.
//!
//! # Conversion invariants
//!
//! Every `*_to_impl` / `*_to_muk` pair in this module maintains:
//!
//! 1. **Round-trip identity.** `x_to_muk(x_to_impl(w)) == w` for every
//!    valid standard-ABI word `w`, and symmetrically for backend
//!    handles. Constants map constant↔constant; runtime handles pass
//!    through the word union bit-identically (they must — the backend
//!    dereferences them).
//! 2. **Zero-page discrimination.** Only words `<=`
//!    [`crate::abi::huffman::HUFFMAN_MAX`] are candidates for the
//!    predefined-constant tables; anything above is reinterpreted as a
//!    backend handle without a lookup. This bounds per-call translation
//!    at O(1) and is why the fast path in the benches is flat.
//! 3. **Special integers translate by value, not bit pattern.**
//!    `MPI_ANY_SOURCE` etc. differ *numerically* between ABIs
//!    (MPICH: −2, OMPI: −1, standard: −101); ranks/tags that are not
//!    special pass through unchanged.
//! 4. **Success is free.** Error-code translation inlines the `== 0`
//!    fast path ([`ret_code`]); only failures pay the class mapping.
//! 5. **Statuses convert field-by-field, count included.** The hidden
//!    byte count crosses layouts via [`MukBackend::status_bytes`], so
//!    `MPI_Get_count` on a muk status equals what the backend would
//!    have reported (63-bit counts survive).
//! 6. **Temporary conversion state lives exactly as long as the
//!    operation.** Nonblocking calls that convert arrays (Ialltoallw's
//!    datatype vectors) park them in [`super::state`] keyed by the muk
//!    request word and free them on completion — the §6.2 request-map
//!    discipline.

use crate::abi::constants as std_k;
use crate::abi::handles as std_h;
use crate::abi::status::AbiStatus;
use crate::api::MpiAbi;
use crate::impls::mpich::MpichAbi;
use crate::impls::ompi::OmpiAbi;
use crate::muk::word::AsWord;

/// A backend Mukautuva can wrap: an [`MpiAbi`] whose handles fit the
/// word union, plus the predefined-constant mappings that the wrap
/// library compiles in from the backend's `mpi.h`.
pub trait MukBackend:
    MpiAbi<
    Comm: AsWord,
    Datatype: AsWord,
    Op: AsWord,
    Request: AsWord,
    Group: AsWord,
    Errhandler: AsWord,
    Info: AsWord,
    Win: AsWord,
    Session: AsWord,
>
{
    /// Backend handle for a predefined standard-ABI datatype constant.
    fn predef_dt(abi_const: usize) -> Option<Self::Datatype>;
    /// Standard-ABI constant for a backend *predefined* datatype handle.
    fn predef_dt_rev(h: Self::Datatype) -> Option<usize>;
    /// Backend handle for a predefined standard-ABI op constant.
    fn predef_op(abi_const: usize) -> Option<Self::Op>;
    /// Standard-ABI constant for a backend *predefined* op handle.
    fn predef_op_rev(h: Self::Op) -> Option<usize>;
    /// Raw byte count hidden in the backend's status layout (the wrap
    /// library is compiled against the backend's mpi.h and knows it).
    fn status_bytes(s: &Self::Status) -> u64;
    /// Inverse of [`MukBackend::status_bytes`]: a backend-layout status
    /// carrying `bytes` (for `WRAP_get_elements`, which must hand the
    /// backend a status in *its* layout).
    fn status_with_bytes(bytes: u64) -> Self::Status;
}

impl MukBackend for MpichAbi {
    fn predef_dt(abi_const: usize) -> Option<Self::Datatype> {
        let id = crate::core::datatype::builtin_id_of_abi(abi_const)?;
        Some(crate::impls::mpich::DT_HANDLES[id.0 as usize])
    }

    fn predef_dt_rev(h: i32) -> Option<usize> {
        use crate::impls::mpich as m;
        if m::kind_of(h) == m::KIND_BUILTIN && m::type_of(h) == m::T_DATATYPE {
            crate::core::datatype::abi_of_builtin_id(crate::core::DtId((h & 0xFF) as u32))
        } else {
            None
        }
    }

    fn predef_op(abi_const: usize) -> Option<Self::Op> {
        let id = crate::core::op::builtin_id_of_abi(abi_const)?;
        Some(crate::impls::mpich::op_handle(id.0 as usize))
    }

    fn predef_op_rev(h: i32) -> Option<usize> {
        use crate::impls::mpich as m;
        if m::kind_of(h) == m::KIND_BUILTIN && m::type_of(h) == m::T_OP {
            crate::core::op::abi_of_builtin_id(crate::core::OpId(m::payload_of(h) as u32))
        } else {
            None
        }
    }

    fn status_bytes(s: &Self::Status) -> u64 {
        s.count_bytes()
    }

    fn status_with_bytes(bytes: u64) -> Self::Status {
        use crate::impls::repr::Repr;
        let mut core = crate::core::request::StatusCore::empty();
        core.count_bytes = bytes;
        crate::impls::mpich::MpichRepr::status_from_core(&core)
    }
}

impl MukBackend for OmpiAbi {
    fn predef_dt(abi_const: usize) -> Option<Self::Datatype> {
        let id = crate::core::datatype::builtin_id_of_abi(abi_const)?;
        Some(<crate::impls::ompi::OmpiRepr as crate::impls::repr::Repr>::dt_h(id))
    }

    fn predef_dt_rev(h: Self::Datatype) -> Option<usize> {
        use crate::impls::repr::Repr;
        let id = crate::impls::ompi::OmpiRepr::dt_id(h).ok()?;
        if id.0 < crate::core::reserved::NUM_BUILTIN_DTYPES {
            crate::core::datatype::abi_of_builtin_id(id)
        } else {
            None
        }
    }

    fn predef_op(abi_const: usize) -> Option<Self::Op> {
        let id = crate::core::op::builtin_id_of_abi(abi_const)?;
        Some(<crate::impls::ompi::OmpiRepr as crate::impls::repr::Repr>::op_h(id))
    }

    fn predef_op_rev(h: Self::Op) -> Option<usize> {
        use crate::impls::repr::Repr;
        let id = crate::impls::ompi::OmpiRepr::op_id(h).ok()?;
        if id.0 < crate::core::reserved::NUM_BUILTIN_OPS {
            crate::core::op::abi_of_builtin_id(id)
        } else {
            None
        }
    }

    fn status_bytes(s: &Self::Status) -> u64 {
        s._ucount as u64
    }

    fn status_with_bytes(bytes: u64) -> Self::Status {
        use crate::impls::repr::Repr;
        let mut core = crate::core::request::StatusCore::empty();
        core.count_bytes = bytes;
        crate::impls::ompi::OmpiRepr::status_from_core(&core)
    }
}

// --- Handle conversions (the CONVERT_MPI_* functions) ------------------------

/// Standard-ABI `comm` word → backend handle (constants by table, runtime words through the union).
#[inline(always)]
pub fn comm_to_impl<A: MukBackend>(muk: usize) -> A::Comm {
    match muk {
        std_h::MPI_COMM_WORLD => A::comm_world(),
        std_h::MPI_COMM_SELF => A::comm_self(),
        std_h::MPI_COMM_NULL => A::comm_null(),
        w => A::Comm::from_word(w),
    }
}

/// Backend `comm` handle → standard-ABI word (inverse of `comm_to_impl`).
#[inline(always)]
pub fn comm_to_muk<A: MukBackend>(c: A::Comm) -> usize {
    if c == A::comm_world() {
        std_h::MPI_COMM_WORLD
    } else if c == A::comm_self() {
        std_h::MPI_COMM_SELF
    } else if c == A::comm_null() {
        std_h::MPI_COMM_NULL
    } else {
        c.to_word()
    }
}

/// Standard-ABI `dt` word → backend handle (constants by table, runtime words through the union).
#[inline(always)]
pub fn dt_to_impl<A: MukBackend>(muk: usize) -> A::Datatype {
    if muk <= crate::abi::huffman::HUFFMAN_MAX {
        if let Some(h) = A::predef_dt(muk) {
            return h;
        }
    }
    A::Datatype::from_word(muk)
}

/// Backend `dt` handle → standard-ABI word (inverse of `dt_to_impl`).
#[inline(always)]
pub fn dt_to_muk<A: MukBackend>(d: A::Datatype) -> usize {
    if let Some(c) = A::predef_dt_rev(d) {
        c
    } else {
        d.to_word()
    }
}

/// Standard-ABI `op` word → backend handle (constants by table, runtime words through the union).
#[inline(always)]
pub fn op_to_impl<A: MukBackend>(muk: usize) -> A::Op {
    if muk <= crate::abi::huffman::HUFFMAN_MAX {
        if let Some(h) = A::predef_op(muk) {
            return h;
        }
    }
    A::Op::from_word(muk)
}

/// Standard-ABI `req` word → backend handle (constants by table, runtime words through the union).
#[inline(always)]
pub fn req_to_impl<A: MukBackend>(muk: usize) -> A::Request {
    if muk == std_h::MPI_REQUEST_NULL {
        A::request_null()
    } else {
        A::Request::from_word(muk)
    }
}

/// Backend `req` handle → standard-ABI word (inverse of `req_to_impl`).
#[inline(always)]
pub fn req_to_muk<A: MukBackend>(r: A::Request) -> usize {
    if r == A::request_null() {
        std_h::MPI_REQUEST_NULL
    } else {
        r.to_word()
    }
}

/// Standard-ABI `errh` word → backend handle (constants by table, runtime words through the union).
#[inline(always)]
pub fn errh_to_impl<A: MukBackend>(muk: usize) -> A::Errhandler {
    match muk {
        std_h::MPI_ERRORS_RETURN => A::errhandler_return(),
        std_h::MPI_ERRORS_ARE_FATAL | std_h::MPI_ERRORS_ABORT => A::errhandler_fatal(),
        w => A::Errhandler::from_word(w),
    }
}

/// Backend `errh` handle → standard-ABI word (inverse of `errh_to_impl`).
#[inline(always)]
pub fn errh_to_muk<A: MukBackend>(e: A::Errhandler) -> usize {
    if e == A::errhandler_return() {
        std_h::MPI_ERRORS_RETURN
    } else if e == A::errhandler_fatal() {
        std_h::MPI_ERRORS_ARE_FATAL
    } else {
        e.to_word()
    }
}

/// Standard-ABI `group` word → backend handle (constants by table, runtime words through the union).
#[inline(always)]
pub fn group_to_impl<A: MukBackend>(muk: usize) -> A::Group {
    A::Group::from_word(muk)
}

/// Standard-ABI `info` word → backend handle (constants by table, runtime words through the union).
#[inline(always)]
pub fn info_to_impl<A: MukBackend>(muk: usize) -> A::Info {
    if muk == std_h::MPI_INFO_NULL {
        A::info_null()
    } else {
        A::Info::from_word(muk)
    }
}

/// Standard-ABI `win` word → backend handle (constants by table, runtime words through the union).
#[inline(always)]
pub fn win_to_impl<A: MukBackend>(muk: usize) -> A::Win {
    if muk == std_h::MPI_WIN_NULL {
        A::win_null()
    } else {
        A::Win::from_word(muk)
    }
}

/// Backend `win` handle → standard-ABI word (inverse of `win_to_impl`).
#[inline(always)]
pub fn win_to_muk<A: MukBackend>(w: A::Win) -> usize {
    if w == A::win_null() {
        std_h::MPI_WIN_NULL
    } else {
        w.to_word()
    }
}

/// `CONVERT_MPI_Session`: null constant ↔ backend null, runtime handles
/// through the word union — sessions ride the same union as every other
/// handle kind (the already-reserved `AbiSession` zero-page code).
#[inline(always)]
pub fn session_to_impl<A: MukBackend>(muk: usize) -> A::Session {
    if muk == std_h::MPI_SESSION_NULL {
        A::session_null()
    } else {
        A::Session::from_word(muk)
    }
}

/// Inverse of [`session_to_impl`].
#[inline(always)]
pub fn session_to_muk<A: MukBackend>(s: A::Session) -> usize {
    if s == A::session_null() {
        std_h::MPI_SESSION_NULL
    } else {
        s.to_word()
    }
}

/// Standard-ABI window assertion bits → the backend's numbering (Open
/// MPI's dense 1..16 family vs MPICH's 1024..16384 — a §5.4 divergence).
#[inline(always)]
pub fn assert_to_impl<A: MukBackend>(assert: i32) -> i32 {
    let mut out = 0;
    if assert & std_k::MPI_MODE_NOCHECK != 0 {
        out |= A::mode_nocheck();
    }
    if assert & std_k::MPI_MODE_NOSTORE != 0 {
        out |= A::mode_nostore();
    }
    if assert & std_k::MPI_MODE_NOPUT != 0 {
        out |= A::mode_noput();
    }
    if assert & std_k::MPI_MODE_NOPRECEDE != 0 {
        out |= A::mode_noprecede();
    }
    if assert & std_k::MPI_MODE_NOSUCCEED != 0 {
        out |= A::mode_nosucceed();
    }
    out
}

/// Standard-ABI lock type → the backend's value (MPICH: 234/235).
#[inline(always)]
pub fn lock_type_to_impl<A: MukBackend>(lt: i32) -> i32 {
    if lt == std_k::MPI_LOCK_EXCLUSIVE {
        A::lock_exclusive()
    } else if lt == std_k::MPI_LOCK_SHARED {
        A::lock_shared()
    } else {
        lt
    }
}

// --- Special integer constants -------------------------------------------------

/// Source-rank translation: `MPI_ANY_SOURCE`/`MPI_PROC_NULL` map by value, real ranks pass through.
#[inline(always)]
pub fn src_to_impl<A: MukBackend>(src: i32) -> i32 {
    if src == std_k::MPI_ANY_SOURCE {
        A::any_source()
    } else if src == std_k::MPI_PROC_NULL {
        A::proc_null()
    } else {
        src
    }
}

/// Destination-rank translation: `MPI_PROC_NULL` maps by value, real ranks pass through.
#[inline(always)]
pub fn dest_to_impl<A: MukBackend>(dest: i32) -> i32 {
    if dest == std_k::MPI_PROC_NULL {
        A::proc_null()
    } else {
        dest
    }
}

/// Tag translation: `MPI_ANY_TAG` maps by value, real tags pass through.
#[inline(always)]
pub fn tag_to_impl<A: MukBackend>(tag: i32) -> i32 {
    if tag == std_k::MPI_ANY_TAG {
        A::any_tag()
    } else {
        tag
    }
}

/// Buffer-sentinel translation: `MPI_IN_PLACE` maps to the backend's sentinel address.
#[inline(always)]
pub fn buf_to_impl<A: MukBackend>(b: *const u8) -> *const u8 {
    if b as usize == std_k::MPI_IN_PLACE {
        A::in_place()
    } else {
        b
    }
}

/// [`buf_to_impl`] for receive buffers (the scatter family puts
/// `MPI_IN_PLACE` in `recvbuf`).
#[inline(always)]
pub fn recvbuf_to_impl<A: MukBackend>(b: *mut u8) -> *mut u8 {
    buf_to_impl::<A>(b as *const u8) as *mut u8
}

// --- Status conversion -----------------------------------------------------------

/// Convert a backend status to the standard 32-byte status, translating
/// special source values and the error code.
pub fn status_to_muk<A: MukBackend>(s: &A::Status) -> AbiStatus {
    let mut source = A::status_source(s);
    if source == A::proc_null() {
        source = std_k::MPI_PROC_NULL;
    }
    let mut tag = A::status_tag(s);
    if tag == A::any_tag() {
        tag = std_k::MPI_ANY_TAG;
    }
    let code = A::status_error(s);
    let mut out = AbiStatus {
        MPI_SOURCE: source,
        MPI_TAG: tag,
        MPI_ERROR: ret_code::<A>(code),
        mpi_reserved: [0; 5],
    };
    // Recover the byte count for MPI_Get_count on the MUK side. The
    // backend status carries it in its own hidden layout.
    let bytes = status_count_bytes::<A>(s);
    out.set_count_and_cancelled(bytes, A::status_cancelled(s));
    out
}

/// Backend-hidden count extraction — the wrap library reads the
/// backend's status layout directly (it is compiled against that
/// `mpi.h`), so the full 63-bit count survives translation.
pub fn status_count_bytes<A: MukBackend>(s: &A::Status) -> u64 {
    A::status_bytes(s)
}

/// `RETURN_CODE_IMPL_TO_MUK`, with the success fast path inlined as in
/// the paper's listing.
#[inline(always)]
pub fn ret_code<A: MukBackend>(code: i32) -> i32 {
    if code == 0 {
        return 0;
    }
    error_code_impl_to_muk::<A>(code)
}

#[cold]
fn error_code_impl_to_muk<A: MukBackend>(code: i32) -> i32 {
    // Backend class numbering is canonical in both our backends once the
    // class is extracted; the standard ABI uses classes as codes.
    A::err_class_of(code)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Dt;

    #[test]
    fn comm_constants_translate_both_ways() {
        let w = comm_to_impl::<MpichAbi>(std_h::MPI_COMM_WORLD);
        assert_eq!(w, crate::impls::mpich::MPI_COMM_WORLD);
        assert_eq!(comm_to_muk::<MpichAbi>(w), std_h::MPI_COMM_WORLD);

        let w = comm_to_impl::<OmpiAbi>(std_h::MPI_COMM_WORLD);
        assert_eq!(comm_to_muk::<OmpiAbi>(w), std_h::MPI_COMM_WORLD);
    }

    #[test]
    fn dt_constants_translate() {
        use crate::abi::datatypes as adt;
        for c in [adt::MPI_INT, adt::MPI_DOUBLE, adt::MPI_BYTE, adt::MPI_INT64_T] {
            let m = dt_to_impl::<MpichAbi>(c);
            assert_eq!(dt_to_muk::<MpichAbi>(m), c, "mpich {c:#x}");
            let o = dt_to_impl::<OmpiAbi>(c);
            assert_eq!(dt_to_muk::<OmpiAbi>(o), c, "ompi {c:#x}");
        }
    }

    #[test]
    fn specials_translate() {
        assert_eq!(src_to_impl::<MpichAbi>(std_k::MPI_ANY_SOURCE), -2);
        assert_eq!(src_to_impl::<OmpiAbi>(std_k::MPI_ANY_SOURCE), -1);
        assert_eq!(dest_to_impl::<MpichAbi>(std_k::MPI_PROC_NULL), -1);
        assert_eq!(dest_to_impl::<OmpiAbi>(std_k::MPI_PROC_NULL), -2);
        assert_eq!(tag_to_impl::<MpichAbi>(7), 7);
    }

    #[test]
    fn error_codes_translate_with_fast_success() {
        assert_eq!(ret_code::<MpichAbi>(0), 0);
        let mpich_code = crate::impls::mpich::err_code(crate::abi::errors::MPI_ERR_TRUNCATE);
        assert_eq!(ret_code::<MpichAbi>(mpich_code), crate::abi::errors::MPI_ERR_TRUNCATE);
        assert_eq!(
            ret_code::<OmpiAbi>(crate::abi::errors::MPI_ERR_TRUNCATE),
            crate::abi::errors::MPI_ERR_TRUNCATE
        );
    }

    #[test]
    fn in_place_translates() {
        let muk = std_k::MPI_IN_PLACE as *const u8;
        assert_eq!(buf_to_impl::<MpichAbi>(muk), usize::MAX as *const u8);
        assert_eq!(buf_to_impl::<OmpiAbi>(muk), 1 as *const u8);
        let real = 0xdead0 as *const u8;
        assert_eq!(buf_to_impl::<MpichAbi>(real), real);
    }

    #[test]
    fn op_constants_translate() {
        use crate::abi::ops as aop;
        let m = op_to_impl::<MpichAbi>(aop::MPI_SUM);
        assert_eq!(m, 0x58000001);
        assert_eq!(MpichAbi::predef_op_rev(m), Some(aop::MPI_SUM));
        let o = op_to_impl::<OmpiAbi>(aop::MPI_MAXLOC);
        assert_eq!(OmpiAbi::predef_op_rev(o), Some(aop::MPI_MAXLOC));
    }

    #[test]
    fn byte_dt_used_for_count_recovery() {
        // status_count_bytes needs MPI_BYTE size 1 in both backends.
        let mut sz = 0;
        MpichAbi::type_size(MpichAbi::datatype(Dt::Byte), &mut sz);
        assert_eq!(sz, 1);
        let mut sz = 0;
        OmpiAbi::type_size(OmpiAbi::datatype(Dt::Byte), &mut sz);
        assert_eq!(sz, 1);
    }
}
