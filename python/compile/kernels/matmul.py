"""L1 Pallas kernel: tiled matmul for the L2 model's dense layers.

MXU-shaped: 128x128 output tiles, f32 accumulation, K streamed in
128-wide slabs so every operand tile is one native MXU operand. The
surrounding dense layer uses ``jax.custom_vjp`` so the backward pass also
runs through these kernels (grad through an interpret-mode pallas_call is
otherwise fragile across jax versions).

interpret=True throughout: CPU PJRT cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic array edge: output tiles are TILE x TILE.
TILE = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    # K-loop is folded into the grid's last dimension: accumulate partial
    # products into the output tile (revisited across k steps).
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@jax.jit
def matmul(x, w):
    """``x @ w`` via 128x128x128-tiled Pallas kernel.

    Shapes must be multiples of TILE in every dimension (the model pads
    its dims to 128 multiples — the usual MXU discipline).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % TILE == 0 and k % TILE == 0 and n % TILE == 0, (m, k, n)
    grid = (m // TILE, n // TILE, k // TILE)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, j)),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def dense(x, w, b):
    """Dense layer ``x @ w + b`` with a Pallas forward and Pallas backward."""
    return matmul(x, w) + b[None, :]


def _dense_fwd(x, w, b):
    return dense(x, w, b), (x, w)


def _dense_bwd(res, g):
    x, w = res
    # dx = g @ w^T ; dw = x^T @ g ; db = sum_rows(g) — all through the
    # same MXU-tiled kernel.
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


def mxu_utilization_estimate(m, k, n) -> float:
    """Fraction of MXU issue slots doing useful work for an (m,k)x(k,n)
    matmul with TILE-aligned dims: 1.0 when all dims are multiples of
    TILE (no padding waste) — the §Perf roofline input."""
    pad = lambda d: (d + TILE - 1) // TILE * TILE
    useful = m * k * n
    issued = pad(m) * pad(k) * pad(n)
    return useful / issued


def vmem_bytes_per_step(dtype=jnp.float32) -> int:
    """Three resident 128x128 tiles, double-buffered."""
    itemsize = jnp.dtype(dtype).itemsize
    return 3 * 2 * TILE * TILE * itemsize
