//! The MPI **API** surface, abstracted over ABIs.
//!
//! MPI is standardized as an API: the same *source* compiles against any
//! implementation, but each implementation's binary representation of
//! handles/statuses/constants differs — that is the paper's entire
//! problem statement. We model "recompiling the same source against a
//! different mpi.h" with a trait: [`MpiAbi`]'s associated types are the
//! opaque handles, associated functions return the predefined constants
//! (functions, not consts, because Open-MPI-style constants are
//! link-time addresses, §3.3), and generic code (the test suite, the OSU
//! benchmarks, the examples) is monomorphized per ABI exactly as C code
//! is recompiled per mpi.h.
//!
//! Callback registration uses plain `fn` pointers (as in C) — forcing
//! translation layers into the trampoline/state-map machinery the paper
//! describes (§6.2), rather than letting Rust closures smuggle state.

// The portable surface is itself part of the reproduced contract: every
// public item must say which MPI entity it stands for.
#![warn(missing_docs)]

/// Canonical names for the predefined datatypes the portable surface
/// exposes (each ABI maps them to its own handle representation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[allow(missing_docs)] // variants name their `MPI_*` datatype 1:1
pub enum Dt {
    Int,
    Float,
    Double,
    Byte,
    Char,
    Short,
    UInt16,
    Int32,
    Int64,
    UInt64,
    Aint,
    FloatInt,
    TwoInt,
}

/// Canonical names for the predefined reduction ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[allow(missing_docs)] // variants name their `MPI_*` op 1:1
pub enum OpName {
    Sum,
    Min,
    Max,
    Prod,
    Band,
    Bor,
    Bxor,
    Land,
    Lor,
    Lxor,
    Minloc,
    Maxloc,
}

/// Borrowed per-rank count array for the embiggened (`_c`) v-collectives
/// — the polymorphic count/displacement trick of ompi's
/// `count_disp_array.h`: one entry point accepts either the classic
/// `int[]` or the large-count `MPI_Count[]`, and the implementation
/// widens lazily per element instead of copying the array.
#[derive(Clone, Copy, Debug)]
pub enum Counts<'a> {
    /// Classic narrow `int[]` counts.
    Int(&'a [i32]),
    /// Large-count `MPI_Count[]` counts.
    Count(&'a [crate::abi::types::Count]),
}

impl Counts<'_> {
    /// Element `i`, widened to `MPI_Count`.
    pub fn get(&self, i: usize) -> crate::abi::types::Count {
        match self {
            Counts::Int(v) => v[i] as crate::abi::types::Count,
            Counts::Count(v) => v[i],
        }
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        match self {
            Counts::Int(v) => v.len(),
            Counts::Count(v) => v.len(),
        }
    }

    /// `true` when the array is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Widen into an owned `MPI_Count` vector (shim convenience).
    pub fn to_counts(&self) -> Vec<crate::abi::types::Count> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }
}

/// Borrowed per-rank displacement array for the embiggened (`_c`)
/// v-collectives: classic `int[]` or address-width `MPI_Aint[]`.
#[derive(Clone, Copy, Debug)]
pub enum Displs<'a> {
    /// Classic narrow `int[]` displacements.
    Int(&'a [i32]),
    /// Address-width `MPI_Aint[]` displacements (blocks beyond 2 GiB).
    Aint(&'a [crate::abi::types::Aint]),
}

impl Displs<'_> {
    /// Element `i`, widened to `MPI_Aint`.
    pub fn get(&self, i: usize) -> crate::abi::types::Aint {
        match self {
            Displs::Int(v) => v[i] as crate::abi::types::Aint,
            Displs::Aint(v) => v[i],
        }
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        match self {
            Displs::Int(v) => v.len(),
            Displs::Aint(v) => v.len(),
        }
    }

    /// `true` when the array is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Widen into an owned `MPI_Aint` vector (shim convenience).
    pub fn to_aints(&self) -> Vec<crate::abi::types::Aint> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }
}

/// User reduction function in ABI `A`: `(invec, inoutvec, len, datatype)`.
pub type UserOpFn<A> = fn(*const u8, *mut u8, i32, <A as MpiAbi>::Datatype);

/// Attribute copy callback: `(comm, keyval, extra_state, value) ->
/// (flag, new_value)`.
pub type AttrCopyFn<A> = fn(<A as MpiAbi>::Comm, i32, usize, usize) -> (bool, usize);

/// Attribute delete callback.
pub type AttrDeleteFn<A> = fn(<A as MpiAbi>::Comm, i32, usize, usize);

/// Error-handler callback: `(comm, error_code)`.
pub type ErrhFn<A> = fn(<A as MpiAbi>::Comm, i32);

/// An MPI ABI: the binary surface one compiles against.
///
/// Every method returns the ABI's own `int` error code (0 = success in
/// every known ABI; other values differ and must be translated by layers
/// like Mukautuva). Output parameters are `&mut` in Rust style.
#[allow(clippy::too_many_arguments)]
pub trait MpiAbi: 'static {
    /// Human name for reports ("mpich", "ompi", "muk(mpich)", "abi").
    const NAME: &'static str;

    /// `MPI_Comm` in this ABI's representation.
    type Comm: Copy + PartialEq + std::fmt::Debug;
    /// `MPI_Datatype` in this ABI's representation.
    type Datatype: Copy + PartialEq + std::fmt::Debug;
    /// `MPI_Op` in this ABI's representation.
    type Op: Copy + PartialEq;
    /// `MPI_Request` in this ABI's representation.
    type Request: Copy + PartialEq + std::fmt::Debug;
    /// `MPI_Group` in this ABI's representation.
    type Group: Copy + PartialEq;
    /// `MPI_Errhandler` in this ABI's representation.
    type Errhandler: Copy + PartialEq;
    /// `MPI_Info` in this ABI's representation.
    type Info: Copy + PartialEq;
    /// `MPI_Win` — the RMA window handle (in the paper's handle table
    /// alongside `MPI_Comm` and `MPI_Request`).
    type Win: Copy + PartialEq + std::fmt::Debug;
    /// `MPI_Session` — the MPI-4 sessions handle, reserved its own kind
    /// in the standard ABI's Huffman code from day one (§5.4 / A.2).
    type Session: Copy + PartialEq + std::fmt::Debug;
    /// The ABI's status struct (layouts differ! §3.2).
    type Status: Copy;

    // --- Predefined constants (functions: OMPI-style constants are
    // link-time addresses, not compile-time constants) ---
    /// The `MPI_COMM_WORLD` handle constant.
    fn comm_world() -> Self::Comm;
    /// The `MPI_COMM_SELF` handle constant.
    fn comm_self() -> Self::Comm;
    /// The `MPI_COMM_NULL` handle constant.
    fn comm_null() -> Self::Comm;
    /// The `MPI_REQUEST_NULL` handle constant.
    fn request_null() -> Self::Request;
    /// The handle for a predefined datatype.
    fn datatype(d: Dt) -> Self::Datatype;
    /// The handle for a predefined reduction op.
    fn op(o: OpName) -> Self::Op;
    /// The `MPI_ERRORS_RETURN` handle constant.
    fn errhandler_return() -> Self::Errhandler;
    /// The `MPI_ERRORS_ARE_FATAL` handle constant.
    fn errhandler_fatal() -> Self::Errhandler;
    /// The `MPI_INFO_NULL` handle constant.
    fn info_null() -> Self::Info;
    /// The `MPI_WIN_NULL` handle constant.
    fn win_null() -> Self::Win;
    /// The `MPI_SESSION_NULL` handle constant.
    fn session_null() -> Self::Session;

    /// Special integer constants — ABIs number these differently.
    fn any_source() -> i32;
    /// This ABI's `MPI_ANY_TAG` value.
    fn any_tag() -> i32;
    /// This ABI's `MPI_PROC_NULL` value.
    fn proc_null() -> i32;
    /// This ABI's `MPI_UNDEFINED` value.
    fn undefined() -> i32;
    /// This ABI's `MPI_COMM_TYPE_SHARED` split-type value (MPICH 1,
    /// Open MPI 0, standard ABI 1).
    fn comm_type_shared() -> i32 {
        crate::abi::constants::MPI_COMM_TYPE_SHARED
    }
    /// The `MPI_IN_PLACE` buffer sentinel.
    fn in_place() -> *const u8;
    /// `MPI_LOCK_EXCLUSIVE` — implementations number lock types
    /// differently (MPICH: 234, Open MPI: 1), §5.4.
    fn lock_exclusive() -> i32;
    /// `MPI_LOCK_SHARED`.
    fn lock_shared() -> i32;
    /// `MPI_MODE_NOCHECK` (window assertion bit; OMPI numbers the whole
    /// family differently from MPICH and the standard ABI).
    fn mode_nocheck() -> i32;
    /// `MPI_MODE_NOSTORE`.
    fn mode_nostore() -> i32;
    /// `MPI_MODE_NOPUT`.
    fn mode_noput() -> i32;
    /// `MPI_MODE_NOPRECEDE`.
    fn mode_noprecede() -> i32;
    /// `MPI_MODE_NOSUCCEED`.
    fn mode_nosucceed() -> i32;

    /// Success / canonical error classes in this ABI's numbering.
    fn err_class_of(code: i32) -> i32;
    /// `MPI_Error_string`.
    fn error_string(code: i32) -> String;
    /// This ABI's numeric value for a canonical (standard-ABI) class.
    fn err_from_canonical(class: i32) -> i32;

    // --- Environment ---
    /// `MPI_Init`.
    fn init() -> i32;
    /// `MPI_Finalize`.
    fn finalize() -> i32;
    /// `MPI_Initialized`.
    fn initialized() -> bool;
    /// `MPI_Finalized`.
    fn finalized() -> bool;
    /// `MPI_Abort`.
    fn abort(comm: Self::Comm, code: i32) -> i32;
    /// `MPI_Wtime`.
    fn wtime() -> f64;
    /// `MPI_Get_library_version`.
    fn get_library_version() -> String;
    /// `MPI_Get_version`: (version, subversion).
    fn get_version() -> (i32, i32);
    /// `MPI_Get_processor_name`.
    fn get_processor_name() -> String;

    // --- Sessions (MPI-4) ---
    //
    // The sessions model initializes MPI without `MPI_Init`: a session
    // is its own init epoch (world and N sessions may coexist; finalize
    // order is free), process sets are discovered by name, and
    // `MPI_Comm_create_from_group` derives a communicator with *no
    // parent* — concurrent creations are disambiguated by the caller's
    // tag string. `MPI_Session` is a first-class opaque handle in every
    // layer, exactly like `MPI_Comm` and `MPI_Win`.

    /// `MPI_Session_init`. The info argument carries requested runtime
    /// hints (ignored by this engine); the error handler is attached to
    /// the session.
    fn session_init(
        info: Self::Info,
        errh: Self::Errhandler,
        session: &mut Self::Session,
    ) -> i32;
    /// `MPI_Session_finalize`: nulls the handle on success; finalizing
    /// an already-finalized (null) session is an error.
    fn session_finalize(session: &mut Self::Session) -> i32;
    /// `MPI_Session_get_num_psets` (info argument elided: no matching
    /// criteria are supported).
    fn session_get_num_psets(session: Self::Session, out: &mut i32) -> i32;
    /// `MPI_Session_get_nth_pset`: the nth process-set name, in a
    /// stable order (`mpi://WORLD`, `mpi://SELF`, launcher sets).
    fn session_get_nth_pset(session: Self::Session, n: i32, out: &mut String) -> i32;
    /// `MPI_Session_get_pset_info`: an info object describing the named
    /// set (key `mpi_size`); the caller frees it.
    fn session_get_pset_info(session: Self::Session, pset: &str, out: &mut Self::Info) -> i32;
    /// `MPI_Group_from_session_pset`.
    fn group_from_session_pset(session: Self::Session, pset: &str, out: &mut Self::Group) -> i32;
    /// `MPI_Comm_create_from_group`: collective over exactly the
    /// group's members, no parent communicator; `stringtag`
    /// disambiguates concurrent creations over overlapping groups. The
    /// info argument is ignored; the error handler is attached to the
    /// new communicator.
    fn comm_create_from_group(
        group: Self::Group,
        stringtag: &str,
        info: Self::Info,
        errh: Self::Errhandler,
        out: &mut Self::Comm,
    ) -> i32;

    // --- Status accessors (layouts differ per ABI) ---
    /// An empty status in this ABI's layout.
    fn status_empty() -> Self::Status;
    /// Read `MPI_SOURCE` from this ABI's status layout.
    fn status_source(s: &Self::Status) -> i32;
    /// Read `MPI_TAG` from this ABI's status layout.
    fn status_tag(s: &Self::Status) -> i32;
    /// Read `MPI_ERROR` from this ABI's status layout.
    fn status_error(s: &Self::Status) -> i32;
    /// `MPI_Test_cancelled`.
    fn status_cancelled(s: &Self::Status) -> bool;
    /// `MPI_Get_count`.
    fn get_count(s: &Self::Status, dt: Self::Datatype) -> i32;
    /// `MPI_Get_elements`: basic-element count of the received data —
    /// unlike `get_count` it resolves partial items of a derived type
    /// down to their basic leaves.
    fn get_elements(s: &Self::Status, dt: Self::Datatype) -> i32;

    // --- Large-count (`MPI_Count`) entry points: the MPI-4 `_c` family.
    // Counts are 64-bit everywhere; classic `int` entry points stay
    // untouched and keep their MPI-4.1 truncation semantics
    // (`MPI_UNDEFINED` when a count exceeds `int` range). ---
    /// `MPI_Send_c`: standard-mode send with an `MPI_Count` count.
    fn send_c(
        buf: *const u8,
        count: crate::abi::types::Count,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Recv_c`: receive with an `MPI_Count` count.
    fn recv_c(
        buf: *mut u8,
        count: crate::abi::types::Count,
        dt: Self::Datatype,
        src: i32,
        tag: i32,
        comm: Self::Comm,
        status: &mut Self::Status,
    ) -> i32;
    /// `MPI_Get_count_c`: received-item count as `MPI_Count` — never
    /// truncates, so it round-trips transfers beyond `INT_MAX` items.
    fn get_count_c(s: &Self::Status, dt: Self::Datatype, out: &mut crate::abi::types::Count)
        -> i32;
    /// `MPI_Get_elements_c`: basic-element count as `MPI_Count`.
    fn get_elements_c(
        s: &Self::Status,
        dt: Self::Datatype,
        out: &mut crate::abi::types::Count,
    ) -> i32;
    /// `MPI_Status_set_elements_c`: overwrite the status's element count
    /// (exercised by layered libraries; also how a test synthesizes a
    /// beyond-2-GiB status without a beyond-2-GiB transfer).
    fn status_set_elements_c(
        s: &mut Self::Status,
        dt: Self::Datatype,
        count: crate::abi::types::Count,
    ) -> i32;
    /// `MPI_Type_size_c`: datatype size as `MPI_Count`.
    fn type_size_c(dt: Self::Datatype, out: &mut crate::abi::types::Count) -> i32;
    /// `MPI_Type_contiguous_c`: contiguous constructor with an
    /// `MPI_Count` count, for derived types whose logical payload
    /// exceeds 2 GiB.
    fn type_contiguous_c(
        count: crate::abi::types::Count,
        child: Self::Datatype,
        out: &mut Self::Datatype,
    ) -> i32;
    /// `MPI_Type_vector_c`: vector constructor with `MPI_Count`
    /// count/blocklength/stride — sparse multi-GiB extents under
    /// bounded real memory.
    fn type_vector_c(
        count: crate::abi::types::Count,
        blocklen: crate::abi::types::Count,
        stride: crate::abi::types::Count,
        child: Self::Datatype,
        out: &mut Self::Datatype,
    ) -> i32;
    /// `MPI_Allgatherv_c`: embiggened allgatherv — per-rank counts as
    /// [`Counts`] and displacements as [`Displs`] (polymorphic over the
    /// classic `int[]` and the wide `MPI_Count[]`/`MPI_Aint[]` layouts,
    /// à la ompi's `count_disp_array.h`).
    #[allow(clippy::too_many_arguments)]
    fn allgatherv_c(
        sendbuf: *const u8,
        sendcount: crate::abi::types::Count,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcounts: Counts<'_>,
        displs: Displs<'_>,
        recvtype: Self::Datatype,
        comm: Self::Comm,
    ) -> i32;

    // --- Communicators & groups ---
    /// `MPI_Comm_size`.
    fn comm_size(c: Self::Comm, out: &mut i32) -> i32;
    /// `MPI_Comm_rank`.
    fn comm_rank(c: Self::Comm, out: &mut i32) -> i32;
    /// `MPI_Comm_dup`.
    fn comm_dup(c: Self::Comm, out: &mut Self::Comm) -> i32;
    /// `MPI_Comm_split`.
    fn comm_split(c: Self::Comm, color: i32, key: i32, out: &mut Self::Comm) -> i32;
    /// `MPI_Comm_split_type` (`MPI_COMM_TYPE_SHARED` or
    /// `MPI_UNDEFINED`; `out` = `MPI_COMM_NULL` for undefined).
    fn comm_split_type(c: Self::Comm, split_type: i32, key: i32, out: &mut Self::Comm) -> i32;
    /// `MPI_Comm_free`.
    fn comm_free(c: &mut Self::Comm) -> i32;
    /// `MPI_Comm_compare`.
    fn comm_compare(a: Self::Comm, b: Self::Comm, out: &mut i32) -> i32;
    /// `MPI_Comm_set_name`.
    fn comm_set_name(c: Self::Comm, name: &str) -> i32;
    /// `MPI_Comm_get_name`.
    fn comm_get_name(c: Self::Comm, out: &mut String) -> i32;
    /// `MPI_Comm_group`.
    fn comm_group(c: Self::Comm, out: &mut Self::Group) -> i32;
    /// `MPI_Group_size`.
    fn group_size(g: Self::Group, out: &mut i32) -> i32;
    /// `MPI_Group_rank`.
    fn group_rank(g: Self::Group, out: &mut i32) -> i32;
    /// `MPI_Group_incl`.
    fn group_incl(g: Self::Group, ranks: &[i32], out: &mut Self::Group) -> i32;
    /// `MPI_Group_translate_ranks`.
    fn group_translate_ranks(
        a: Self::Group,
        ranks: &[i32],
        b: Self::Group,
        out: &mut [i32],
    ) -> i32;
    /// `MPI_Group_free`.
    fn group_free(g: &mut Self::Group) -> i32;
    /// `MPI_Comm_set_errhandler`.
    fn comm_set_errhandler(c: Self::Comm, e: Self::Errhandler) -> i32;
    /// `MPI_Comm_get_errhandler`.
    fn comm_get_errhandler(c: Self::Comm, out: &mut Self::Errhandler) -> i32;
    /// `MPI_Comm_create_errhandler`.
    fn comm_create_errhandler(f: ErrhFn<Self>, out: &mut Self::Errhandler) -> i32;
    /// `MPI_Errhandler_free`.
    fn errhandler_free(e: &mut Self::Errhandler) -> i32;

    // --- ULFM fault tolerance ---
    /// `MPI_Comm_revoke` (ULFM): poison the communicator — in-flight and
    /// future operations on it fail with `MPI_ERR_REVOKED` at every
    /// member.
    fn comm_revoke(c: Self::Comm) -> i32;
    /// `MPIX_Comm_is_revoked` (ULFM helper).
    fn comm_is_revoked(c: Self::Comm, out: &mut bool) -> i32;
    /// `MPI_Comm_shrink` (ULFM): build a new communicator over the
    /// surviving members of `c` (which may be revoked or contain failed
    /// processes).
    fn comm_shrink(c: Self::Comm, out: &mut Self::Comm) -> i32;
    /// `MPI_Comm_agree` (ULFM): fault-tolerant agreement — on return,
    /// `flag` holds the bitwise AND of all surviving members' values.
    fn comm_agree(c: Self::Comm, flag: &mut i32) -> i32;
    /// `MPI_Comm_ack_failed` (ULFM): acknowledge up to `num_to_ack`
    /// known process failures on `c`; `num_acked` reports how many are
    /// now acknowledged. Fully acknowledged failures stop wildcard
    /// receives from raising `MPI_ERR_PROC_FAILED_PENDING`.
    fn comm_ack_failed(c: Self::Comm, num_to_ack: i32, num_acked: &mut i32) -> i32;

    // --- Point-to-point ---
    /// `MPI_Send`.
    fn send(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Ssend`.
    fn ssend(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Recv`.
    fn recv(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        src: i32,
        tag: i32,
        comm: Self::Comm,
        status: &mut Self::Status,
    ) -> i32;
    /// `MPI_Isend`.
    fn isend(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Issend`.
    fn issend(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Irecv`.
    fn irecv(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        src: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Wait`.
    fn wait(req: &mut Self::Request, status: &mut Self::Status) -> i32;
    /// `MPI_Test`.
    fn test(req: &mut Self::Request, flag: &mut bool, status: &mut Self::Status) -> i32;
    /// `MPI_Waitall`.
    fn waitall(reqs: &mut [Self::Request], statuses: &mut [Self::Status]) -> i32;
    /// `MPI_Testall`.
    fn testall(reqs: &mut [Self::Request], flag: &mut bool, statuses: &mut [Self::Status]) -> i32;
    /// `MPI_Waitany`.
    fn waitany(reqs: &mut [Self::Request], index: &mut i32, status: &mut Self::Status) -> i32;
    /// `MPI_Testany` (§3.7.5): on return, `flag && index >= 0` means that
    /// request completed; `flag && index == MPI_UNDEFINED` means no
    /// active request exists in the list; `!flag` means none is done yet.
    fn testany(
        reqs: &mut [Self::Request],
        index: &mut i32,
        flag: &mut bool,
        status: &mut Self::Status,
    ) -> i32;
    /// `MPI_Waitsome`: blocks until ≥ 1 active request completes;
    /// `indices[..outcount]` name the completed slots (with their
    /// statuses in `statuses[..outcount]`). `outcount = MPI_UNDEFINED`
    /// when the list holds no active request. Inactive persistent
    /// requests are ignored, as in `waitany`.
    fn waitsome(
        reqs: &mut [Self::Request],
        outcount: &mut i32,
        indices: &mut [i32],
        statuses: &mut [Self::Status],
    ) -> i32;
    /// `MPI_Testsome`: like `waitsome` but never blocks — `outcount` may
    /// be 0 when active requests exist and none has completed.
    fn testsome(
        reqs: &mut [Self::Request],
        outcount: &mut i32,
        indices: &mut [i32],
        statuses: &mut [Self::Status],
    ) -> i32;
    /// `MPI_Probe`.
    fn probe(src: i32, tag: i32, comm: Self::Comm, status: &mut Self::Status) -> i32;
    /// `MPI_Iprobe`.
    fn iprobe(
        src: i32,
        tag: i32,
        comm: Self::Comm,
        flag: &mut bool,
        status: &mut Self::Status,
    ) -> i32;
    /// `MPI_Cancel`.
    fn cancel(req: &mut Self::Request) -> i32;
    /// `MPI_Request_free`.
    fn request_free(req: &mut Self::Request) -> i32;
    /// `MPI_Sendrecv`.
    fn sendrecv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        dest: i32,
        sendtag: i32,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        src: i32,
        recvtag: i32,
        comm: Self::Comm,
        status: &mut Self::Status,
    ) -> i32;

    // --- Persistent point-to-point (MPI_Send_init / MPI_Recv_init) ---
    //
    // `*_init` returns an **inactive** request that `start`/`startall`
    // re-arm any number of times; wait/test return it to inactive
    // instead of freeing it, and the handle stays valid (it only becomes
    // REQUEST_NULL through `request_free`, legal while inactive). The
    // lifecycle must behave identically across ABIs — it is part of the
    // binary contract the paper standardizes.
    /// `MPI_Send_init`.
    fn send_init(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Ssend_init`.
    fn ssend_init(
        buf: *const u8,
        count: i32,
        dt: Self::Datatype,
        dest: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Recv_init`.
    fn recv_init(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        src: i32,
        tag: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Start`.
    fn start(req: &mut Self::Request) -> i32;
    /// `MPI_Startall`.
    fn startall(reqs: &mut [Self::Request]) -> i32;

    // --- Datatypes ---
    /// `MPI_Type_size`.
    fn type_size(dt: Self::Datatype, out: &mut i32) -> i32;
    /// `MPI_Type_get_extent`.
    fn type_get_extent(dt: Self::Datatype, lb: &mut isize, extent: &mut isize) -> i32;
    /// `MPI_Type_contiguous`.
    fn type_contiguous(count: i32, child: Self::Datatype, out: &mut Self::Datatype) -> i32;
    /// `MPI_Type_vector`.
    fn type_vector(
        count: i32,
        blocklen: i32,
        stride: i32,
        child: Self::Datatype,
        out: &mut Self::Datatype,
    ) -> i32;
    /// `MPI_Type_create_struct`.
    fn type_create_struct(
        blocks: &[(i32, isize, Self::Datatype)],
        out: &mut Self::Datatype,
    ) -> i32;
    /// `MPI_Type_commit`.
    fn type_commit(dt: &mut Self::Datatype) -> i32;
    /// `MPI_Type_free`.
    fn type_free(dt: &mut Self::Datatype) -> i32;
    /// `MPI_Type_dup`.
    fn type_dup(dt: Self::Datatype, out: &mut Self::Datatype) -> i32;

    // --- Reduction ops ---
    /// `MPI_Op_create`.
    fn op_create(f: UserOpFn<Self>, commute: bool, out: &mut Self::Op) -> i32;
    /// `MPI_Op_free`.
    fn op_free(op: &mut Self::Op) -> i32;

    // --- Collectives ---
    /// `MPI_Barrier`.
    fn barrier(comm: Self::Comm) -> i32;
    /// `MPI_Bcast`.
    fn bcast(buf: *mut u8, count: i32, dt: Self::Datatype, root: i32, comm: Self::Comm) -> i32;
    /// `MPI_Reduce`.
    fn reduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        root: i32,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Allreduce`.
    fn allreduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Gather`.
    fn gather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Scatter`.
    fn scatter(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Allgather`.
    fn allgather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Alltoall`.
    fn alltoall(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Alltoallw`.
    fn alltoallw(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtypes: &[Self::Datatype],
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtypes: &[Self::Datatype],
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Ialltoallw`.
    fn ialltoallw(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtypes: &[Self::Datatype],
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtypes: &[Self::Datatype],
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Scan`.
    fn scan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Exscan`.
    fn exscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
    ) -> i32;
    /// `MPI_Reduce_scatter_block`.
    fn reduce_scatter_block(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        recvcount: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
    ) -> i32;

    // --- Nonblocking collectives (MPI 3.x) ---
    //
    // Every operation returns a request handle in this ABI's
    // representation; translation layers must convert it and keep any
    // per-call temporary state alive until completion (§6.2) — the
    // heaviest handle traffic in the API, which is why the benches
    // measure exactly these paths.
    /// `MPI_Ibarrier`.
    fn ibarrier(comm: Self::Comm, req: &mut Self::Request) -> i32;
    /// `MPI_Ibcast`.
    fn ibcast(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Ireduce`.
    fn ireduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Iallreduce`.
    fn iallreduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Igather`.
    fn igather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Igatherv`.
    fn igatherv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        displs: &[i32],
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Iscatter`.
    fn iscatter(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Iscatterv`.
    fn iscatterv(
        sendbuf: *const u8,
        sendcounts: &[i32],
        displs: &[i32],
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Iallgather`.
    fn iallgather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Iallgatherv`.
    fn iallgatherv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        displs: &[i32],
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Ialltoall`.
    fn ialltoall(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Ialltoallv`.
    fn ialltoallv(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Iscan`.
    fn iscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Iexscan`.
    fn iexscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Ireduce_scatter_block`.
    fn ireduce_scatter_block(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        recvcount: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;

    // --- Persistent collectives (MPI-4) ---
    //
    // Collective calls: every rank of `comm` must create the same
    // persistent collectives in the same order (they agree on a tag
    // plane at init time). Starts re-read the user buffers; the
    // schedule built at init is reused, never rebuilt.
    /// `MPI_Barrier_init`.
    fn barrier_init(comm: Self::Comm, req: &mut Self::Request) -> i32;
    /// `MPI_Bcast_init`.
    fn bcast_init(
        buf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Allreduce_init`.
    fn allreduce_init(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: Self::Datatype,
        op: Self::Op,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Gather_init`.
    fn gather_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Scatter_init`.
    fn scatter_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        root: i32,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;
    /// `MPI_Alltoall_init`.
    fn alltoall_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: Self::Datatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: Self::Datatype,
        comm: Self::Comm,
        req: &mut Self::Request,
    ) -> i32;

    // --- One-sided communication (RMA) ---
    //
    // `MPI_Win` is a first-class opaque handle: every layer represents
    // it its own way (int with T_WIN bits, pointer-to-descriptor,
    // zero-page word) and the translation layer round-trips it through
    // the word union like any other handle. Displacements are `MPI_Aint`
    // (§5.1) and assertion/lock-type constants differ per ABI (§5.4) —
    // use the `mode_*`/`lock_*` constant functions above.
    /// `MPI_Win_create`.
    fn win_create(
        base: *mut u8,
        size: crate::abi::types::Aint,
        disp_unit: i32,
        info: Self::Info,
        comm: Self::Comm,
        win: &mut Self::Win,
    ) -> i32;
    /// `MPI_Win_allocate`.
    fn win_allocate(
        size: crate::abi::types::Aint,
        disp_unit: i32,
        info: Self::Info,
        comm: Self::Comm,
        baseptr: &mut *mut u8,
        win: &mut Self::Win,
    ) -> i32;
    /// `MPI_Win_free`.
    fn win_free(win: &mut Self::Win) -> i32;
    /// `MPI_Win_fence`.
    fn win_fence(assert: i32, win: Self::Win) -> i32;
    /// `MPI_Win_lock`.
    fn win_lock(lock_type: i32, rank: i32, assert: i32, win: Self::Win) -> i32;
    /// `MPI_Win_unlock`.
    fn win_unlock(rank: i32, win: Self::Win) -> i32;
    /// `MPI_Win_flush`.
    fn win_flush(rank: i32, win: Self::Win) -> i32;
    /// `MPI_Put`.
    fn put(
        origin: *const u8,
        origin_count: i32,
        origin_dt: Self::Datatype,
        target_rank: i32,
        target_disp: crate::abi::types::Aint,
        target_count: i32,
        target_dt: Self::Datatype,
        win: Self::Win,
    ) -> i32;
    /// `MPI_Get`.
    fn get(
        origin: *mut u8,
        origin_count: i32,
        origin_dt: Self::Datatype,
        target_rank: i32,
        target_disp: crate::abi::types::Aint,
        target_count: i32,
        target_dt: Self::Datatype,
        win: Self::Win,
    ) -> i32;
    /// `MPI_Accumulate`.
    fn accumulate(
        origin: *const u8,
        origin_count: i32,
        origin_dt: Self::Datatype,
        target_rank: i32,
        target_disp: crate::abi::types::Aint,
        target_count: i32,
        target_dt: Self::Datatype,
        op: Self::Op,
        win: Self::Win,
    ) -> i32;
    /// `MPI_Get_address`: identical arithmetic in every ABI, but part of
    /// the binary surface because `MPI_Aint`'s width is pinned by §5.1.
    fn get_address(location: *const u8, out: &mut crate::abi::types::Aint) -> i32 {
        *out = location as crate::abi::types::Aint;
        0
    }
    /// `MPI_Aint_add` (MPI 3.1 §4.1.5: wraps like pointer arithmetic).
    fn aint_add(base: crate::abi::types::Aint, disp: crate::abi::types::Aint)
        -> crate::abi::types::Aint {
        base.wrapping_add(disp)
    }
    /// `MPI_Aint_diff`.
    fn aint_diff(addr1: crate::abi::types::Aint, addr2: crate::abi::types::Aint)
        -> crate::abi::types::Aint {
        addr1.wrapping_sub(addr2)
    }

    // --- Attributes ---
    /// `MPI_Comm_create_keyval`.
    fn comm_create_keyval(
        copy: Option<AttrCopyFn<Self>>,
        delete: Option<AttrDeleteFn<Self>>,
        extra_state: usize,
        out: &mut i32,
    ) -> i32;
    /// `MPI_Comm_free_keyval`.
    fn comm_free_keyval(keyval: &mut i32) -> i32;
    /// `MPI_Comm_set_attr`.
    fn comm_set_attr(c: Self::Comm, keyval: i32, value: usize) -> i32;
    /// `MPI_Comm_get_attr`.
    fn comm_get_attr(c: Self::Comm, keyval: i32, value: &mut usize, flag: &mut bool) -> i32;
    /// `MPI_Comm_delete_attr`.
    fn comm_delete_attr(c: Self::Comm, keyval: i32) -> i32;

    // --- Info ---
    /// `MPI_Info_create`.
    fn info_create(out: &mut Self::Info) -> i32;
    /// `MPI_Info_set`.
    fn info_set(i: Self::Info, key: &str, value: &str) -> i32;
    /// `MPI_Info_get`.
    fn info_get(i: Self::Info, key: &str, out: &mut String, flag: &mut bool) -> i32;
    /// `MPI_Info_free`.
    fn info_free(i: &mut Self::Info) -> i32;

    // --- Tools interface (MPI_T) ---
    //
    // The MPI_T layer is deliberately handle-free at this boundary:
    // cvar/pvar handles and pvar sessions are plain `i32` indices in
    // every ABI (the standard leaves their representation opaque, so the
    // smallest portable choice wins), which keeps the five configs
    // bit-identical without per-repr handle tables.

    /// `MPI_T_init_thread`.
    fn t_init_thread(required: i32, provided: &mut i32) -> i32;
    /// `MPI_T_finalize`.
    fn t_finalize() -> i32;
    /// `MPI_T_cvar_get_num`.
    fn t_cvar_get_num(num: &mut i32) -> i32;
    /// `MPI_T_cvar_get_info` (name + verbosity/bind/scope subset).
    fn t_cvar_get_info(
        index: i32,
        name: &mut String,
        verbosity: &mut i32,
        bind: &mut i32,
        scope: &mut i32,
    ) -> i32;
    /// `MPI_T_cvar_handle_alloc` (no-object bind, so no obj argument).
    fn t_cvar_handle_alloc(index: i32, handle: &mut i32) -> i32;
    /// `MPI_T_cvar_read`.
    fn t_cvar_read(handle: i32, value: &mut i64) -> i32;
    /// `MPI_T_cvar_write`.
    fn t_cvar_write(handle: i32, value: i64) -> i32;
    /// `MPI_T_pvar_get_num`.
    fn t_pvar_get_num(num: &mut i32) -> i32;
    /// `MPI_T_pvar_get_info` (name + verbosity/class/bind subset).
    fn t_pvar_get_info(
        index: i32,
        name: &mut String,
        verbosity: &mut i32,
        class: &mut i32,
        bind: &mut i32,
    ) -> i32;
    /// `MPI_T_pvar_session_create`.
    fn t_pvar_session_create(session: &mut i32) -> i32;
    /// `MPI_T_pvar_handle_alloc` (no-object bind, so no obj argument).
    fn t_pvar_handle_alloc(session: i32, index: i32, handle: &mut i32) -> i32;
    /// `MPI_T_pvar_start` (re-baselines counter-class variables).
    fn t_pvar_start(session: i32, handle: i32) -> i32;
    /// `MPI_T_pvar_read`.
    fn t_pvar_read(session: i32, handle: i32, value: &mut i64) -> i32;
    /// `MPI_T_pvar_reset`.
    fn t_pvar_reset(session: i32, handle: i32) -> i32;
}

/// Map a canonical [`Dt`] to the standard-ABI datatype constant.
pub fn dt_to_abi_const(d: Dt) -> usize {
    use crate::abi::datatypes as adt;
    match d {
        Dt::Int => adt::MPI_INT,
        Dt::Float => adt::MPI_FLOAT,
        Dt::Double => adt::MPI_DOUBLE,
        Dt::Byte => adt::MPI_BYTE,
        Dt::Char => adt::MPI_CHAR,
        Dt::Short => adt::MPI_SHORT,
        Dt::UInt16 => adt::MPI_UINT16_T,
        Dt::Int32 => adt::MPI_INT32_T,
        Dt::Int64 => adt::MPI_INT64_T,
        Dt::UInt64 => adt::MPI_UINT64_T,
        Dt::Aint => adt::MPI_AINT,
        Dt::FloatInt => adt::MPI_FLOAT_INT,
        Dt::TwoInt => adt::MPI_2INT,
    }
}

/// Map a canonical [`OpName`] to the standard-ABI op constant.
pub fn op_to_abi_const(o: OpName) -> usize {
    use crate::abi::ops as aop;
    match o {
        OpName::Sum => aop::MPI_SUM,
        OpName::Min => aop::MPI_MIN,
        OpName::Max => aop::MPI_MAX,
        OpName::Prod => aop::MPI_PROD,
        OpName::Band => aop::MPI_BAND,
        OpName::Bor => aop::MPI_BOR,
        OpName::Bxor => aop::MPI_BXOR,
        OpName::Land => aop::MPI_LAND,
        OpName::Lor => aop::MPI_LOR,
        OpName::Lxor => aop::MPI_LXOR,
        OpName::Minloc => aop::MPI_MINLOC,
        OpName::Maxloc => aop::MPI_MAXLOC,
    }
}
