//! Error handlers (`MPI_Errhandler`).
//!
//! Three predefined behaviors plus user handlers. A user handler is a
//! callback registered through some ABI; the registering layer supplies a
//! closure that converts the comm handle and error code into *its own*
//! representation before invoking the user function — the same trampoline
//! pattern Mukautuva needs (§6.2).

use super::slab::Slab;
use super::world::with_ctx;
use super::{err, CommId, ErrhId, RC};

/// What to do when an MPI call on a comm fails.
pub enum ErrhKind {
    /// `MPI_ERRORS_ARE_FATAL`: abort the job.
    AreFatal,
    /// `MPI_ERRORS_RETURN`: return the code to the caller.
    Return,
    /// `MPI_ERRORS_ABORT`: abort the processes of this comm (≈ job here).
    Abort,
    /// User handler: invoked with (engine comm id, canonical error class).
    /// The registering ABI layer owns representation conversion.
    User(Box<dyn Fn(CommId, i32)>),
}

/// Error-handler table entry.
pub struct ErrhObj {
    /// The handler's behavior.
    pub kind: ErrhKind,
    /// Predefined handlers are not freeable.
    pub predefined: bool,
}

/// Install the three predefined handlers at their reserved ids.
pub fn install_predefined(errhs: &mut Slab<ErrhObj>) {
    errhs.insert_at(
        super::reserved::ERRH_ARE_FATAL.0,
        ErrhObj { kind: ErrhKind::AreFatal, predefined: true },
    );
    errhs.insert_at(
        super::reserved::ERRH_RETURN.0,
        ErrhObj { kind: ErrhKind::Return, predefined: true },
    );
    errhs.insert_at(
        super::reserved::ERRH_ABORT.0,
        ErrhObj { kind: ErrhKind::Abort, predefined: true },
    );
}

/// `MPI_Comm_create_errhandler` (representation-converted by the caller).
pub fn errhandler_create(f: Box<dyn Fn(CommId, i32)>) -> RC<ErrhId> {
    with_ctx(|ctx| {
        Ok(ErrhId(ctx.tables.borrow_mut().errhs.insert(ErrhObj {
            kind: ErrhKind::User(f),
            predefined: false,
        })))
    })
}

/// Does `id` name a live error handler? (Validation before collective
/// operations that would otherwise fail on one rank only.)
pub fn errhandler_exists(id: ErrhId) -> bool {
    with_ctx(|ctx| Ok(ctx.tables.borrow().errhs.contains(id.0))).unwrap_or(false)
}

/// `MPI_Errhandler_free`.
pub fn errhandler_free(id: ErrhId) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        match t.errhs.get(id.0) {
            Some(e) if e.predefined => Err(err!(MPI_ERR_ARG)),
            Some(_) => {
                t.errhs.remove(id.0);
                Ok(())
            }
            None => Err(err!(MPI_ERR_ERRHANDLER)),
        }
    })
}

/// Run the error handler attached to `comm` for error class `class`.
/// Returns the class (for `Return`/`User`) or diverges (fatal/abort).
pub fn invoke(comm: CommId, errh: ErrhId, class: i32) -> i32 {
    let fatal = with_ctx(|ctx| {
        let t = ctx.tables.borrow();
        match t.errhs.get(errh.0).map(|e| &e.kind) {
            Some(ErrhKind::AreFatal) | Some(ErrhKind::Abort) | None => Ok(true),
            Some(ErrhKind::Return) => Ok(false),
            Some(ErrhKind::User(_)) => Ok(false), // invoked below, outside borrow
        }
    })
    .unwrap_or(true);
    if fatal {
        let _ = with_ctx(|ctx| {
            ctx.world.abort(class);
            Ok(())
        });
        std::panic::panic_any(super::world::AbortUnwind(class));
    }
    // Re-borrow to call a user handler if present. The handler may call
    // MPI functions, so we must not hold the tables borrow while invoking:
    // temporarily move the closure out.
    let user = with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        if let Some(e) = t.errhs.get_mut(errh.0) {
            if matches!(e.kind, ErrhKind::User(_)) {
                let k = std::mem::replace(&mut e.kind, ErrhKind::Return);
                return Ok(Some(k));
            }
        }
        Ok(None)
    })
    .unwrap_or(None);
    if let Some(ErrhKind::User(f)) = user {
        f(comm, class);
        let _ = with_ctx(|ctx| {
            let mut t = ctx.tables.borrow_mut();
            if let Some(e) = t.errhs.get_mut(errh.0) {
                e.kind = ErrhKind::User(f);
            }
            Ok(())
        });
    }
    class
}
