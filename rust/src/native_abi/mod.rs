//! The **native standard-ABI build**: the proposed ABI implemented
//! directly by the engine, with no translation layer — the analogue of
//! MPICH's `--enable-mpi-abi` prototype (§6.3), which Table 1 shows has
//! *no measurable overhead* versus the implementation's own ABI.
//!
//! Handles are the standard ABI's incomplete-struct-pointer words:
//! predefined constants are the zero-page Huffman codes of Appendix A;
//! runtime handles are "heap pointers" — here, engine ids bit-packed
//! above the zero page (a real C implementation returns actual heap
//! addresses; both satisfy the ABI's only requirement, namely that user
//! handles never collide with the zero page).
//!
//! `MPI_Type_size` uses the standard ABI's intended fast path: the
//! Huffman size bits for fixed-size types, and a small lookup table
//! (§5.4: "sufficiently compact so as to require a relatively small
//! lookup table") for variable-size builtins.

use once_cell::sync::Lazy;

use crate::abi::handles::*;
use crate::abi::status::AbiStatus;
use crate::api::{dt_to_abi_const, op_to_abi_const, Dt, OpName};
use crate::core::request::StatusCore;
use crate::core::{err, CommId, DtId, ErrhId, GroupId, InfoId, OpId, RC, ReqId, SessionId, WinId};
use crate::impls::repr::{Backed, Repr};

/// The public ABI type.
pub type NativeAbi = Backed<NativeRepr>;

/// User handles: `BASE + (engine_id << 4) | kind` — above the zero page,
/// kind-tagged so misuse is detectable (mirroring the bitmask error
/// checking the Huffman code enables for constants).
const USER_BASE: usize = 0x1000;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
enum UserKind {
    Comm = 1,
    Group,
    Datatype,
    Op,
    Request,
    Errhandler,
    Info,
    Win,
    Session,
}

#[inline(always)]
fn user_h(kind: UserKind, id: u32) -> usize {
    USER_BASE + ((id as usize) << 4) + kind as usize
}

#[inline(always)]
fn user_id(kind: UserKind, h: usize) -> Option<u32> {
    if h >= USER_BASE && (h & 0xF) == kind as usize {
        Some(((h - USER_BASE) >> 4) as u32)
    } else {
        None
    }
}

/// Variable-size builtin lookup table: Huffman value → size (the
/// "relatively small lookup table" of §5.4). Fixed-size types never
/// reach it — their size is in the handle bits.
static VAR_SIZE_TABLE: Lazy<[i16; 1024]> = Lazy::new(|| {
    let mut t = [-1i16; 1024];
    for &(_, v) in crate::abi::datatypes::PREDEFINED_DATATYPES {
        if crate::abi::huffman::fixed_size_of(v).is_none() {
            if let Some(s) = crate::abi::datatypes::platform_size_of(v) {
                t[v] = s as i16;
            }
        }
    }
    t
});

pub struct NativeRepr;

impl Repr for NativeRepr {
    const NAME: &'static str = "abi";

    type Comm = AbiComm;
    type Datatype = AbiDatatype;
    type Op = AbiOp;
    type Request = AbiRequest;
    type Group = AbiGroup;
    type Errhandler = AbiErrhandler;
    type Info = AbiInfo;
    type Win = AbiWin;
    type Session = AbiSession;
    type Status = AbiStatus;

    fn c_comm_world() -> AbiComm {
        AbiComm::WORLD
    }
    fn c_comm_self() -> AbiComm {
        AbiComm::SELF
    }
    fn c_comm_null() -> AbiComm {
        AbiComm::NULL
    }
    fn c_request_null() -> AbiRequest {
        AbiRequest::NULL
    }
    fn c_errh_return() -> AbiErrhandler {
        AbiErrhandler::ERRORS_RETURN
    }
    fn c_errh_fatal() -> AbiErrhandler {
        AbiErrhandler::ERRORS_ARE_FATAL
    }
    fn c_info_null() -> AbiInfo {
        AbiInfo::NULL
    }
    fn c_win_null() -> AbiWin {
        AbiWin::NULL
    }
    fn c_session_null() -> AbiSession {
        AbiSession::NULL
    }

    fn c_datatype(d: Dt) -> AbiDatatype {
        AbiDatatype(dt_to_abi_const(d))
    }

    fn c_op(o: OpName) -> AbiOp {
        AbiOp(op_to_abi_const(o))
    }

    fn c_any_source() -> i32 {
        crate::abi::constants::MPI_ANY_SOURCE
    }
    fn c_any_tag() -> i32 {
        crate::abi::constants::MPI_ANY_TAG
    }
    fn c_proc_null() -> i32 {
        crate::abi::constants::MPI_PROC_NULL
    }
    fn c_undefined() -> i32 {
        crate::abi::constants::MPI_UNDEFINED
    }
    fn c_in_place() -> *const u8 {
        crate::abi::constants::MPI_IN_PLACE as *const u8
    }

    #[inline]
    fn comm_id(c: AbiComm) -> RC<CommId> {
        match c.0 {
            MPI_COMM_WORLD => Ok(crate::core::reserved::COMM_WORLD),
            MPI_COMM_SELF => Ok(crate::core::reserved::COMM_SELF),
            h => user_id(UserKind::Comm, h).map(CommId).ok_or(err!(MPI_ERR_COMM)),
        }
    }

    #[inline]
    fn comm_h(id: CommId) -> AbiComm {
        match id {
            crate::core::reserved::COMM_WORLD => AbiComm::WORLD,
            crate::core::reserved::COMM_SELF => AbiComm::SELF,
            CommId(n) => AbiComm(user_h(UserKind::Comm, n)),
        }
    }

    #[inline]
    fn dt_id(d: AbiDatatype) -> RC<DtId> {
        if let Some(id) = crate::core::datatype::builtin_id_of_abi(d.0) {
            return Ok(id);
        }
        user_id(UserKind::Datatype, d.0).map(DtId).ok_or(err!(MPI_ERR_TYPE))
    }

    #[inline]
    fn dt_h(id: DtId) -> AbiDatatype {
        if let Some(abi) = crate::core::datatype::abi_of_builtin_id(id) {
            AbiDatatype(abi)
        } else {
            AbiDatatype(user_h(UserKind::Datatype, id.0))
        }
    }

    #[inline]
    fn op_id(o: AbiOp) -> RC<OpId> {
        if let Some(id) = crate::core::op::builtin_id_of_abi(o.0) {
            return Ok(id);
        }
        user_id(UserKind::Op, o.0).map(OpId).ok_or(err!(MPI_ERR_OP))
    }

    #[inline]
    fn op_h(id: OpId) -> AbiOp {
        if let Some(abi) = crate::core::op::abi_of_builtin_id(id) {
            if id.0 < crate::core::reserved::NUM_BUILTIN_OPS {
                return AbiOp(abi);
            }
        }
        AbiOp(user_h(UserKind::Op, id.0))
    }

    #[inline]
    fn req_id(r: AbiRequest) -> RC<ReqId> {
        user_id(UserKind::Request, r.0).map(ReqId).ok_or(err!(MPI_ERR_REQUEST))
    }

    #[inline]
    fn req_h(id: ReqId) -> AbiRequest {
        AbiRequest(user_h(UserKind::Request, id.0))
    }

    #[inline]
    fn group_id(g: AbiGroup) -> RC<GroupId> {
        match g.0 {
            MPI_GROUP_EMPTY => Ok(crate::core::reserved::GROUP_EMPTY),
            h => user_id(UserKind::Group, h).map(GroupId).ok_or(err!(MPI_ERR_GROUP)),
        }
    }

    #[inline]
    fn group_h(id: GroupId) -> AbiGroup {
        match id {
            crate::core::reserved::GROUP_EMPTY => AbiGroup::EMPTY,
            GroupId(n) => AbiGroup(user_h(UserKind::Group, n)),
        }
    }

    #[inline]
    fn errh_id(e: AbiErrhandler) -> RC<ErrhId> {
        match e.0 {
            MPI_ERRORS_ARE_FATAL => Ok(crate::core::reserved::ERRH_ARE_FATAL),
            MPI_ERRORS_RETURN => Ok(crate::core::reserved::ERRH_RETURN),
            MPI_ERRORS_ABORT => Ok(crate::core::reserved::ERRH_ABORT),
            h => user_id(UserKind::Errhandler, h).map(ErrhId).ok_or(err!(MPI_ERR_ARG)),
        }
    }

    #[inline]
    fn errh_h(id: ErrhId) -> AbiErrhandler {
        match id {
            crate::core::reserved::ERRH_ARE_FATAL => AbiErrhandler::ERRORS_ARE_FATAL,
            crate::core::reserved::ERRH_RETURN => AbiErrhandler::ERRORS_RETURN,
            crate::core::reserved::ERRH_ABORT => AbiErrhandler::ERRORS_ABORT,
            ErrhId(n) => AbiErrhandler(user_h(UserKind::Errhandler, n)),
        }
    }

    #[inline]
    fn info_id(i: AbiInfo) -> RC<InfoId> {
        match i.0 {
            MPI_INFO_ENV => Ok(crate::core::reserved::INFO_ENV),
            h => user_id(UserKind::Info, h).map(InfoId).ok_or(err!(MPI_ERR_INFO)),
        }
    }

    #[inline]
    fn info_h(id: InfoId) -> AbiInfo {
        match id {
            crate::core::reserved::INFO_ENV => AbiInfo(MPI_INFO_ENV),
            InfoId(n) => AbiInfo(user_h(UserKind::Info, n)),
        }
    }

    #[inline]
    fn win_id(w: AbiWin) -> RC<WinId> {
        user_id(UserKind::Win, w.0).map(WinId).ok_or(err!(MPI_ERR_WIN))
    }

    #[inline]
    fn win_h(id: WinId) -> AbiWin {
        AbiWin(user_h(UserKind::Win, id.0))
    }

    #[inline]
    fn session_id(s: AbiSession) -> RC<SessionId> {
        user_id(UserKind::Session, s.0).map(SessionId).ok_or(err!(MPI_ERR_SESSION))
    }

    #[inline]
    fn session_h(id: SessionId) -> AbiSession {
        AbiSession(user_h(UserKind::Session, id.0))
    }

    fn status_empty() -> AbiStatus {
        let mut s = AbiStatus::empty();
        s.MPI_SOURCE = Self::c_proc_null();
        s.MPI_TAG = Self::c_any_tag();
        s
    }

    fn status_from_core(c: &StatusCore) -> AbiStatus {
        let mut s = AbiStatus {
            MPI_SOURCE: c.source,
            MPI_TAG: c.tag,
            MPI_ERROR: c.error,
            mpi_reserved: [0; 5],
        };
        s.set_count_and_cancelled(c.count_bytes, c.cancelled);
        s
    }

    fn status_source(s: &AbiStatus) -> i32 {
        s.MPI_SOURCE
    }
    fn status_tag(s: &AbiStatus) -> i32 {
        s.MPI_TAG
    }
    fn status_error(s: &AbiStatus) -> i32 {
        s.MPI_ERROR
    }
    fn status_cancelled(s: &AbiStatus) -> bool {
        s.cancelled()
    }
    fn status_count_bytes(s: &AbiStatus) -> u64 {
        s.count_bytes()
    }

    /// The standard ABI uses the canonical classes as codes directly.
    fn err_from_class(class: i32) -> i32 {
        class
    }
    fn class_of_err(code: i32) -> i32 {
        code
    }

    /// The standard ABI's fast path: size bits for fixed-size types,
    /// the compact lookup table for variable-size builtins.
    #[inline(always)]
    fn type_size_fast(d: AbiDatatype) -> Option<i32> {
        if let Some(s) = crate::abi::huffman::fixed_size_of(d.0) {
            return Some(s as i32);
        }
        if d.0 < 1024 {
            let s = VAR_SIZE_TABLE[d.0];
            if s >= 0 {
                return Some(s as i32);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predefined_constants_are_zero_page() {
        assert!(crate::abi::huffman::is_zero_page(NativeRepr::c_comm_world().0));
        assert!(crate::abi::huffman::is_zero_page(NativeRepr::c_datatype(Dt::Int).0));
        assert!(crate::abi::huffman::is_zero_page(NativeRepr::c_op(OpName::Sum).0));
    }

    #[test]
    fn user_handles_avoid_zero_page() {
        let h = NativeRepr::comm_h(CommId(5));
        assert!(h.0 > crate::abi::huffman::HUFFMAN_MAX);
        assert_eq!(NativeRepr::comm_id(h).unwrap(), CommId(5));
    }

    #[test]
    fn kind_tag_detects_cross_kind_misuse() {
        // A request handle word passed as a comm: rejected by tag bits.
        let r = NativeRepr::req_h(ReqId(3));
        assert!(NativeRepr::comm_id(AbiComm(r.0)).is_err());
    }

    #[test]
    fn type_size_fast_paths() {
        // Fixed-size: pure bit decode.
        assert_eq!(NativeRepr::type_size_fast(AbiDatatype(crate::abi::datatypes::MPI_INT32_T)),
            Some(4));
        // Variable-size: table.
        assert_eq!(NativeRepr::type_size_fast(NativeRepr::c_datatype(Dt::Int)), Some(4));
        assert_eq!(NativeRepr::type_size_fast(NativeRepr::c_datatype(Dt::Double)), Some(8));
        // Derived: falls to the engine.
        assert_eq!(NativeRepr::type_size_fast(AbiDatatype(user_h(UserKind::Datatype, 99))), None);
    }

    #[test]
    fn status_is_the_standard_32_byte_object() {
        assert_eq!(core::mem::size_of::<<NativeRepr as Repr>::Status>(), 32);
    }
}
