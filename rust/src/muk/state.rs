//! Mukautuva's per-process (= per-rank-thread) mutable state: the
//! request→temporary-state map of §6.2, and the slot bookkeeping for the
//! callback trampoline pools.

use std::cell::RefCell;
use std::collections::HashMap;

/// Temporary state parked until a nonblocking operation completes —
/// for `MPI_Ialltoallw`, the converted datatype-handle vectors, which
/// the translation layer must keep alive (and eventually free) because
/// the backend may reference them until completion.
#[derive(Debug)]
pub struct WState {
    /// Converted send-side datatype handle words.
    pub sendtypes: Vec<usize>,
    /// Converted receive-side datatype handle words.
    pub recvtypes: Vec<usize>,
}

thread_local! {
    /// muk-request-word → temp state (std::map in real Mukautuva).
    static REQMAP: RefCell<HashMap<usize, WState>> = RefCell::new(HashMap::new());
    /// impl op handle word → trampoline slot.
    static OP_SLOT_OF: RefCell<HashMap<usize, usize>> = RefCell::new(HashMap::new());
    /// impl errhandler word → trampoline slot.
    static ERRH_SLOT_OF: RefCell<HashMap<usize, usize>> = RefCell::new(HashMap::new());
    /// keyval → (copy slot, delete slot).
    static KEYVAL_SLOTS: RefCell<HashMap<i32, (Option<usize>, Option<usize>)>> =
        RefCell::new(HashMap::new());
}

/// Park temporary conversion state under a muk request word.
pub fn reqmap_insert(req: usize, st: WState) {
    REQMAP.with(|m| m.borrow_mut().insert(req, st));
}

/// Lookup + removal on completion. Returns whether the request had state.
pub fn reqmap_remove(req: usize) -> Option<WState> {
    REQMAP.with(|m| m.borrow_mut().remove(&req))
}

/// The pure lookup cost the §6.2 worst case pays on *every* Testall.
pub fn reqmap_contains(req: usize) -> bool {
    REQMAP.with(|m| m.borrow().contains_key(&req))
}

/// Number of requests currently carrying parked state.
pub fn reqmap_len() -> usize {
    REQMAP.with(|m| m.borrow().len())
}

/// Record which trampoline slot backs a created op handle.
pub fn remember_op_slot(op_word: usize, slot: usize) {
    OP_SLOT_OF.with(|m| m.borrow_mut().insert(op_word, slot));
}

/// Look up (and forget) the trampoline slot of a freed op handle.
pub fn forget_op_slot(op_word: usize) -> Option<usize> {
    OP_SLOT_OF.with(|m| m.borrow_mut().remove(&op_word))
}

/// Record which trampoline slot backs a created errhandler handle.
pub fn remember_errh_slot(errh_word: usize, slot: usize) {
    ERRH_SLOT_OF.with(|m| m.borrow_mut().insert(errh_word, slot));
}

/// Look up (and forget) the trampoline slot of a freed errhandler.
pub fn forget_errh_slot(errh_word: usize) -> Option<usize> {
    ERRH_SLOT_OF.with(|m| m.borrow_mut().remove(&errh_word))
}

/// Record the (copy, delete) trampoline slots of a created keyval.
pub fn remember_keyval_slots(kv: i32, copy: Option<usize>, delete: Option<usize>) {
    KEYVAL_SLOTS.with(|m| m.borrow_mut().insert(kv, (copy, delete)));
}

/// Look up (and forget) the trampoline slots of a freed keyval.
pub fn forget_keyval_slots(kv: i32) -> Option<(Option<usize>, Option<usize>)> {
    KEYVAL_SLOTS.with(|m| m.borrow_mut().remove(&kv))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reqmap_roundtrip() {
        assert!(!reqmap_contains(0x9000));
        reqmap_insert(0x9000, WState { sendtypes: vec![1], recvtypes: vec![2] });
        assert!(reqmap_contains(0x9000));
        assert_eq!(reqmap_len(), 1);
        let st = reqmap_remove(0x9000).unwrap();
        assert_eq!(st.sendtypes, vec![1]);
        assert!(reqmap_remove(0x9000).is_none());
    }

    #[test]
    fn slot_maps() {
        remember_op_slot(42, 3);
        assert_eq!(forget_op_slot(42), Some(3));
        assert_eq!(forget_op_slot(42), None);
        remember_keyval_slots(7, Some(1), None);
        assert_eq!(forget_keyval_slots(7), Some((Some(1), None)));
    }
}
