//! The simulated parallel job: one [`World`] shared by all rank threads,
//! one thread-local [`RankCtx`] per rank (the analogue of an MPI process's
//! library globals).
//!
//! MPI libraries keep their state in process globals; our "processes" are
//! threads, so the same state lives in TLS. All engine entry points resolve
//! the current rank context through [`with_ctx`], which also models the
//! "MPI call before init / after finalize" failure modes.

use std::cell::{Cell, RefCell};
use std::collections::{HashSet, VecDeque};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::comm::CommObj;
use super::datatype::DatatypeObj;
use super::errh::ErrhObj;
use super::group::GroupObj;
use super::info::InfoObj;
use super::match_index::{FxHashMap, MatchIndex};
use super::obs::{ObsRank, TraceEvent, TraceSink, WorldObs};
use super::op::OpObj;
use super::request::RequestObj;
use super::rma::WinObj;
use super::session::SessionObj;
use super::slab::Slab;
use super::transport::{Envelope, Fabric, TransportKind};
use super::{attr::KeyvalObj, err, RC};

/// Sentinel in `abort_code` meaning "no abort requested".
const NO_ABORT: i64 = i64::MIN;

/// Job-global state shared by all ranks.
pub struct World {
    /// Number of ranks in the job.
    pub size: usize,
    /// The shared-memory network between ranks.
    pub fabric: Fabric,
    /// `MPI_Abort` latch: the exit code once some rank aborts.
    abort_code: AtomicI64,
    /// Epoch for `MPI_Wtime`.
    epoch: Instant,
    /// Allocator for communicator context ids (2 per comm: pt2pt, coll).
    context_counter: AtomicU32,
    /// Ranks that called `MPI_Finalize` (for `world_finalized` diagnostics).
    finalize_count: AtomicUsize,
    /// Job-global observability counters (rendezvous in-flight bytes,
    /// schedule builds/reuses) — the job-wide end of the pvar registry.
    /// Per-world (not process-global) so parallel test jobs in one
    /// process don't perturb each other's assertions.
    pub obs: WorldObs,
    /// Engine event tracing (`MPI_ABI_TRACE` or
    /// [`crate::launcher::JobSpec::with_trace`]): ranks bound to this
    /// world record trace-ring events. Read once per rank at bind time.
    trace: AtomicBool,
    /// Per-rank trace-event batches, merged here at finalize/unbind and
    /// drained by [`World::take_trace`].
    trace_sink: TraceSink,
    /// Launcher-provided named process sets (MPI-4 sessions): each is a
    /// (URI, member world ranks) pair surfaced by `MPI_Session_get_*`
    /// alongside the built-in `mpi://WORLD` / `mpi://SELF`.
    psets: Vec<(String, Vec<usize>)>,
    /// Flat-baseline matching (`MPI_ABI_FLAT_MATCH=1` or
    /// [`crate::launcher::JobSpec::with_flat_match`]): ranks bound to
    /// this world use the seed's linear-scan matcher and skip the
    /// zero-alloc fast paths — the perf baseline the benches regress
    /// against. Read once per rank at bind time.
    flat_match: AtomicBool,
    /// Eager/rendezvous switch point in packed bytes
    /// (`MPI_ABI_RNDV_THRESHOLD` or
    /// [`crate::launcher::JobSpec::with_rndv_threshold`]): sends whose
    /// packed size exceeds this go RTS/CTS + chunk streaming instead of
    /// one eager envelope. Read once per rank at bind time.
    rndv_threshold: AtomicUsize,
    /// Forced collective-algorithm choices (`MPI_ABI_COLL_ALGO` or
    /// [`crate::launcher::JobSpec::with_coll_algo`]), packed as a
    /// [`crate::core::collectives::CollAlgoForce`] word. `0` per
    /// operation means "auto" (the tuning table decides). Read once per
    /// rank at bind time.
    coll_algo: AtomicU32,
    /// ULFM failure registry: `dead[r]` is set when world rank `r` dies
    /// (the kill injector's victim). Every blocked or matched operation
    /// against a dead peer must then *fail* with `MPI_ERR_PROC_FAILED`
    /// rather than hang.
    dead: Vec<AtomicBool>,
    /// Count of dead ranks — the zero-check keeps the failure-free fast
    /// path to one relaxed load (also pvar `ranks_failed`).
    failed_count: AtomicUsize,
    /// Revoked context planes (`MPI_Comm_revoke` poisons both of a
    /// comm's planes): operations routed onto a revoked plane fail with
    /// `MPI_ERR_REVOKED`.
    revoked: Mutex<HashSet<u32>>,
    /// Count of revoked planes — same zero-check trick as `failed_count`.
    revoked_count: AtomicUsize,
    /// Deterministic rank-death injection (`JobSpec::with_kill` /
    /// `MPI_ABI_KILL`): `(victim world rank, progress ticks to survive)`.
    /// Read once per rank at bind time.
    kill: Mutex<Option<(usize, u64)>>,
}

/// Eager/rendezvous switch point when neither the env var nor the job
/// spec overrides it: 64 KiB, the classic network-eager cutoff.
pub const RNDV_THRESHOLD_DEFAULT: usize = 64 * 1024;

/// Read `MPI_ABI_RNDV_THRESHOLD` (packed bytes; `0` forces rendezvous
/// for every non-empty message), falling back to
/// [`RNDV_THRESHOLD_DEFAULT`].
pub fn rndv_threshold_env() -> usize {
    match std::env::var("MPI_ABI_RNDV_THRESHOLD") {
        Ok(v) => v.trim().parse().unwrap_or(RNDV_THRESHOLD_DEFAULT),
        Err(_) => RNDV_THRESHOLD_DEFAULT,
    }
}

impl World {
    pub fn new(size: usize, transport: TransportKind) -> Arc<World> {
        World::new_with_psets(size, transport, Vec::new())
    }

    /// [`World::new`] with launcher-provided process sets (the
    /// `mpiexec --pset` analogue; see [`crate::core::session`]).
    /// Panics on a malformed set (member rank out of range) — a launcher
    /// configuration error, caught before any rank can act on it.
    pub fn new_with_psets(
        size: usize,
        transport: TransportKind,
        psets: Vec<(String, Vec<usize>)>,
    ) -> Arc<World> {
        assert!(size >= 1, "world needs at least one rank");
        for (name, members) in &psets {
            for &m in members {
                assert!(m < size, "pset {name:?} member {m} out of range for {size} ranks");
            }
        }
        Arc::new(World {
            size,
            fabric: Fabric::new(transport, size),
            abort_code: AtomicI64::new(NO_ABORT),
            epoch: Instant::now(),
            // 0/1 = COMM_WORLD pt2pt/coll, 2/3 = COMM_SELF,
            // 4/5 = the hidden session-bootstrap comm.
            context_counter: AtomicU32::new(6),
            finalize_count: AtomicUsize::new(0),
            obs: WorldObs::new(),
            trace: AtomicBool::new(super::obs::trace_env()),
            trace_sink: Mutex::new(Vec::new()),
            psets,
            flat_match: AtomicBool::new(super::match_index::flat_match_env()),
            rndv_threshold: AtomicUsize::new(rndv_threshold_env()),
            coll_algo: AtomicU32::new(super::collectives::coll_algo_env().pack()),
            dead: (0..size).map(|_| AtomicBool::new(false)).collect(),
            failed_count: AtomicUsize::new(0),
            revoked: Mutex::new(HashSet::new()),
            revoked_count: AtomicUsize::new(0),
            kill: Mutex::new(None),
        })
    }

    /// Arm the deterministic rank-death injector: world rank `rank` dies
    /// after surviving `ticks` progress-engine cycles (the
    /// [`crate::launcher::JobSpec::with_kill`] application site). Read
    /// once per rank at bind time, so arm before launching.
    pub fn set_kill(&self, rank: usize, ticks: u64) {
        assert!(rank < self.size, "kill target {rank} out of range");
        *self.kill.lock().unwrap() = Some((rank, ticks));
    }

    /// The armed kill spec, if any.
    pub fn kill_spec(&self) -> Option<(usize, u64)> {
        *self.kill.lock().unwrap()
    }

    /// Mark world rank `rank` dead (the victim calls this as it unwinds,
    /// after draining its inbound fabric). Idempotent; bumps the
    /// `ranks_failed` pvar only on the first call per rank.
    pub fn mark_dead(&self, rank: usize) {
        if !self.dead[rank].swap(true, Ordering::SeqCst) {
            self.failed_count.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Whether world rank `rank` has died.
    pub fn is_dead(&self, rank: usize) -> bool {
        // Zero-check first: the failure-free fast path is one load.
        self.failed_count.load(Ordering::Relaxed) != 0
            && self.dead[rank].load(Ordering::SeqCst)
    }

    /// Whether any rank has died (one relaxed load — the hot-path guard).
    pub fn any_dead(&self) -> bool {
        self.failed_count.load(Ordering::Relaxed) != 0
    }

    /// Number of ranks that have died (pvar `ranks_failed`).
    pub fn ranks_failed(&self) -> u64 {
        self.failed_count.load(Ordering::SeqCst) as u64
    }

    /// World ranks currently marked dead, ascending.
    pub fn dead_snapshot(&self) -> Vec<usize> {
        (0..self.size).filter(|&r| self.dead[r].load(Ordering::SeqCst)).collect()
    }

    /// Poison context plane `ctx` (`MPI_Comm_revoke` registers *both* of
    /// the comm's planes). Returns true if the plane was newly revoked.
    pub fn revoke_context(&self, ctx: u32) -> bool {
        let mut set = self.revoked.lock().unwrap();
        let newly = set.insert(ctx);
        if newly {
            self.revoked_count.fetch_add(1, Ordering::SeqCst);
        }
        newly
    }

    /// Whether context plane `ctx` has been revoked.
    pub fn is_revoked(&self, ctx: u32) -> bool {
        // Zero-check first: no lock on the revoke-free fast path.
        self.revoked_count.load(Ordering::Relaxed) != 0
            && self.revoked.lock().unwrap().contains(&ctx)
    }

    /// Override the matching mode for ranks bound after this call (tests
    /// and benches that compare flat vs indexed without racing on the
    /// process-global env var).
    pub fn set_flat_match(&self, flat: bool) {
        self.flat_match.store(flat, Ordering::SeqCst);
    }

    /// Whether ranks of this world use the flat-baseline matcher.
    pub fn flat_match(&self) -> bool {
        self.flat_match.load(Ordering::SeqCst)
    }

    /// Override the eager/rendezvous switch point for ranks bound after
    /// this call (tests and benches that force one protocol without
    /// racing on the process-global env var). `0` forces rendezvous for
    /// every non-empty message.
    pub fn set_rndv_threshold(&self, bytes: usize) {
        self.rndv_threshold.store(bytes, Ordering::SeqCst);
    }

    /// The eager/rendezvous switch point (packed bytes) for this world.
    pub fn rndv_threshold(&self) -> usize {
        self.rndv_threshold.load(Ordering::SeqCst)
    }

    /// Override the forced collective-algorithm choices for ranks bound
    /// after this call (tests and benches that force one algorithm
    /// without racing on the process-global env var).
    pub fn set_coll_algo(&self, force: super::collectives::CollAlgoForce) {
        self.coll_algo.store(force.pack(), Ordering::SeqCst);
    }

    /// The forced collective-algorithm choices for this world.
    pub fn coll_algo(&self) -> super::collectives::CollAlgoForce {
        super::collectives::CollAlgoForce::unpack(self.coll_algo.load(Ordering::SeqCst))
    }

    /// Account `bytes` of rendezvous chunk payload entering the fabric
    /// (thin delegate onto the pvar registry's [`WorldObs`]).
    pub(crate) fn note_rndv_enqueue(&self, bytes: u64) {
        self.obs.note_rndv_enqueue(bytes);
    }

    /// Account `bytes` of rendezvous chunk payload consumed at a receiver.
    pub(crate) fn note_rndv_consume(&self, bytes: u64) {
        self.obs.note_rndv_consume(bytes);
    }

    /// High-water mark of rendezvous payload bytes simultaneously in
    /// flight — the bounded-buffering witness: for a chunked transfer
    /// this stays near `chunk × window`, never near the message size.
    /// (Pvar `rndv_inflight_peak`; kept as a thin read.)
    pub fn rndv_inflight_peak(&self) -> u64 {
        self.obs.rndv_inflight_peak.load(Ordering::Relaxed)
    }

    /// Enable/disable event tracing for ranks bound after this call
    /// (the [`crate::launcher::JobSpec::with_trace`] application site).
    pub fn set_trace(&self, on: bool) {
        self.trace.store(on, Ordering::SeqCst);
    }

    /// Whether ranks of this world record trace events.
    pub fn trace_enabled(&self) -> bool {
        self.trace.load(Ordering::SeqCst)
    }

    /// Nanoseconds since job start (trace timestamps; same epoch as
    /// [`World::wtime`]).
    pub fn elapsed_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Merge one rank's drained trace events into the job-level sink.
    pub(crate) fn push_trace(&self, rank: usize, events: Vec<TraceEvent>) {
        self.trace_sink.lock().unwrap().push((rank, events));
    }

    /// Drain the merged trace, sorted by rank (one viewer lane each).
    pub fn take_trace(&self) -> Vec<(usize, Vec<TraceEvent>)> {
        let mut v = std::mem::take(&mut *self.trace_sink.lock().unwrap());
        v.sort_by_key(|(rank, _)| *rank);
        v
    }

    /// The launcher-provided process sets (name, member world ranks).
    pub fn psets(&self) -> &[(String, Vec<usize>)] {
        &self.psets
    }

    /// Record one collective-schedule construction (see
    /// [`crate::core::collectives::schedules_built`]; thin delegate onto
    /// the pvar registry's [`WorldObs`]).
    pub(crate) fn note_sched_build(&self) {
        self.obs.note_sched_build();
    }

    /// Collective-schedule constructions in this job so far (pvar
    /// `sched_builds`; kept as a thin read).
    pub fn sched_builds(&self) -> u64 {
        self.obs.sched_builds.load(Ordering::Relaxed)
    }

    /// Allocate a fresh pair of context ids (pt2pt, coll) for a new comm.
    /// Called by exactly one rank per comm-creation; the result is
    /// distributed to the other members over the parent communicator.
    pub fn alloc_context_pair(&self) -> (u32, u32) {
        let base = self.context_counter.fetch_add(2, Ordering::Relaxed);
        (base, base + 1)
    }

    /// Seconds since job start (`MPI_Wtime`).
    pub fn wtime(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Request job abort with `code` (`MPI_Abort`). First caller wins.
    pub fn abort(&self, code: i32) {
        let _ = self.abort_code.compare_exchange(
            NO_ABORT,
            code as i64,
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
    }

    /// The abort code, if some rank aborted.
    pub fn aborted(&self) -> Option<i32> {
        match self.abort_code.load(Ordering::SeqCst) {
            NO_ABORT => None,
            c => Some(c as i32),
        }
    }

    pub(crate) fn note_finalize(&self) {
        self.finalize_count.fetch_add(1, Ordering::SeqCst);
    }
}

/// Panic payload used to unwind a rank when the job aborts; the launcher
/// downcasts this to report the code instead of a crash.
#[derive(Debug)]
pub struct AbortUnwind(pub i32);

/// Panic payload used to unwind a rank killed by the death injector.
/// Unlike [`AbortUnwind`], the launcher does *not* take the job down:
/// survivors keep running and observe the death as `MPI_ERR_PROC_FAILED`.
#[derive(Debug)]
pub struct KilledUnwind;

/// Object tables of one rank — the per-process handle tables of a real MPI.
#[allow(missing_docs)] // one slab per engine object kind; names say it all
pub struct Tables {
    pub comms: Slab<CommObj>,
    pub groups: Slab<GroupObj>,
    pub dtypes: Slab<DatatypeObj>,
    pub ops: Slab<OpObj>,
    pub reqs: Slab<RequestObj>,
    pub errhs: Slab<ErrhObj>,
    pub infos: Slab<InfoObj>,
    pub keyvals: Slab<KeyvalObj>,
    pub wins: Slab<WinObj>,
    pub sessions: Slab<SessionObj>,
    /// RMA context plane → window id, so the progress engine can route
    /// incoming one-sided traffic without scanning the window table.
    pub win_by_ctx: std::collections::HashMap<u32, u32>,
}

/// Mutable per-rank messaging state.
pub struct RankState {
    /// The matching engine: every context plane's posted receives and
    /// unexpected messages, indexed for O(1) exact matching (see
    /// [`crate::core::match_index`]).
    pub match_index: MatchIndex,
    /// Sends that hit transport backpressure, awaiting retry — keyed by
    /// destination so one full ring only stalls traffic to that rank
    /// (per-destination FIFO is preserved; other destinations flow).
    pub pending_sends: FxHashMap<usize, VecDeque<Envelope>>,
    /// Ssend acks received (sync ids).
    pub ssend_acks: HashSet<u64>,
    /// Next sync id for Ssend.
    pub next_sync_id: u64,
    /// Per-destination send sequence (FIFO diagnostics).
    pub send_seq: u64,
    /// Scratch buffer for fabric polls (reused to avoid allocation).
    pub inbox: Vec<Envelope>,
    /// Requests backed by in-flight collective schedules, advanced each
    /// progress cycle (see [`crate::core::collectives::sched`]).
    pub active_scheds: Vec<super::ReqId>,
    /// Outbound rendezvous streams, keyed by this rank's stream id.
    /// A send request completes when its id leaves this map.
    pub rndv_sends: FxHashMap<u64, super::request::RndvSend>,
    /// Inbound rendezvous streams, keyed by `(sender world rank, stream id)`.
    pub rndv_recvs: FxHashMap<(u32, u64), super::request::RndvRecv>,
    /// Next outbound rendezvous stream id (per-rank monotone; the pair
    /// with the sender's world rank is globally unique).
    pub next_rndv_id: u64,
    /// This rank's eager/rendezvous switch point, copied from the world
    /// at bind time (same pattern as the flat-match flag).
    pub rndv_threshold: usize,
    /// This rank's forced collective-algorithm choices, copied from the
    /// world at bind time; writable per rank through the
    /// `coll_*_algo` cvars (see [`crate::core::obs`]).
    pub coll_algo: super::collectives::CollAlgoForce,
}

impl RankState {
    fn new(
        flat_match: bool,
        rndv_threshold: usize,
        coll_algo: super::collectives::CollAlgoForce,
    ) -> RankState {
        RankState {
            match_index: MatchIndex::with_mode(flat_match),
            pending_sends: FxHashMap::default(),
            ssend_acks: HashSet::new(),
            next_sync_id: 1,
            send_seq: 0,
            inbox: Vec::with_capacity(64),
            active_scheds: Vec::new(),
            rndv_sends: FxHashMap::default(),
            rndv_recvs: FxHashMap::default(),
            next_rndv_id: 1,
            rndv_threshold,
            coll_algo,
        }
    }
}

/// One rank's complete library state.
pub struct RankCtx {
    /// The job this rank belongs to.
    pub world: Arc<World>,
    /// This rank's world rank.
    pub rank: usize,
    /// Handle tables (comms, datatypes, requests, …).
    pub tables: RefCell<Tables>,
    /// Messaging state (queues, acks, in-flight schedules).
    pub state: RefCell<RankState>,
    /// Per-rank observability: pvar counters, MPI_T sessions/handles,
    /// the trace ring (see [`crate::core::obs`]).
    pub obs: ObsRank,
    /// `MPI_Init` has run (the world model specifically).
    pub initialized: Cell<bool>,
    /// `MPI_Finalize` has run (the world model specifically).
    pub finalized: Cell<bool>,
    /// Currently-active initialization epochs: 1 while the world model
    /// is initialized and not yet finalized, plus 1 per live session.
    /// `MPI_Finalized` reports true only when this returns to zero —
    /// world and sessions share one refcount (MPI-4 §11).
    pub active_inits: Cell<u32>,
    /// Some initialization (world or session) has ever happened;
    /// `MPI_Initialized` reports this (and it never resets).
    pub ever_inited: Cell<bool>,
    /// The predefined world/self/bootstrap objects have been sized
    /// (done by whichever of `MPI_Init` / `MPI_Session_init` runs first).
    pub predef_sized: Cell<bool>,
    /// Re-entrancy latch for the collective schedule pump (a user
    /// reduction op may call back into MPI mid-advance).
    pub sched_pump: Cell<bool>,
    /// Progress-engine cycles survived so far (the kill injector's clock;
    /// only ticks while a kill is armed for this rank).
    pub ticks: Cell<u64>,
    /// If this rank is the armed kill victim: die after this many ticks.
    pub kill_at: Cell<Option<u64>>,
}

impl RankCtx {
    /// Record one initialization epoch opening (world init or
    /// `MPI_Session_init`).
    pub(crate) fn note_init(&self) {
        self.active_inits.set(self.active_inits.get() + 1);
        self.ever_inited.set(true);
    }

    /// Record one initialization epoch closing (world finalize or
    /// `MPI_Session_finalize`).
    pub(crate) fn note_finalize_one(&self) {
        debug_assert!(self.active_inits.get() > 0, "finalize without matching init");
        self.active_inits.set(self.active_inits.get().saturating_sub(1));
    }
}

thread_local! {
    static CURRENT: RefCell<Option<Rc<RankCtx>>> = const { RefCell::new(None) };
}

/// Bind this thread as `rank` of `world`, constructing the rank context
/// with all predefined objects installed. Called by the launcher before
/// the application runs (the "process created" moment, pre-`MPI_Init`).
pub fn bind_rank(world: Arc<World>, rank: usize) -> Rc<RankCtx> {
    assert!(rank < world.size, "rank {rank} out of bounds");
    let flat_match = world.flat_match();
    let rndv_threshold = world.rndv_threshold();
    let coll_algo = world.coll_algo();
    let trace_on = world.trace_enabled();
    let kill_at = match world.kill_spec() {
        Some((victim, ticks)) if victim == rank => Some(ticks),
        _ => None,
    };
    let ctx = Rc::new(RankCtx {
        world,
        rank,
        tables: RefCell::new(init_tables()),
        state: RefCell::new(RankState::new(flat_match, rndv_threshold, coll_algo)),
        obs: ObsRank::new(trace_on),
        initialized: Cell::new(false),
        finalized: Cell::new(false),
        active_inits: Cell::new(0),
        ever_inited: Cell::new(false),
        predef_sized: Cell::new(false),
        sched_pump: Cell::new(false),
        ticks: Cell::new(0),
        kill_at: Cell::new(kill_at),
    });
    CURRENT.with(|c| {
        let mut cur = c.borrow_mut();
        assert!(cur.is_none(), "thread already bound to a rank");
        *cur = Some(ctx.clone());
    });
    ctx
}

/// Unbind this thread (launcher, after the application returns). Any
/// trace events still in the rank's ring are flushed to the world sink
/// first — the catch-all for applications that never reach the world
/// model's `MPI_Finalize` (sessions-only runs).
pub fn unbind_rank() {
    CURRENT.with(|c| {
        if let Some(ctx) = c.borrow_mut().take() {
            super::obs::flush_trace(&ctx);
        }
    });
}

/// Run `f` with the current rank context. Errors with `MPI_ERR_OTHER` if
/// the thread is not bound (MPI call outside a job) — the paper notes
/// Mukautuva likewise does not fully support pre-init/post-finalize calls.
pub fn with_ctx<R>(f: impl FnOnce(&RankCtx) -> RC<R>) -> RC<R> {
    CURRENT.with(|c| {
        let cur = c.borrow();
        match cur.as_ref() {
            Some(ctx) => {
                if let Some(code) = ctx.world.aborted() {
                    std::panic::panic_any(AbortUnwind(code));
                }
                f(ctx)
            }
            None => Err(err!(MPI_ERR_OTHER)),
        }
    })
}

/// Like [`with_ctx`] but doesn't require `MPI_Init` to have been called —
/// for the handful of calls that are legal pre-init (`MPI_Initialized`,
/// `MPI_Finalized`, version queries).
pub fn try_ctx<R>(f: impl FnOnce(Option<&RankCtx>) -> R) -> R {
    CURRENT.with(|c| {
        let cur = c.borrow();
        f(cur.as_deref())
    })
}

/// Build the predefined object tables (§2 of DESIGN.md "reserved ids").
fn init_tables() -> Tables {
    let mut t = Tables {
        comms: Slab::new(),
        groups: Slab::new(),
        dtypes: Slab::new(),
        ops: Slab::new(),
        reqs: Slab::new(),
        errhs: Slab::new(),
        infos: Slab::new(),
        keyvals: Slab::new(),
        wins: Slab::new(),
        sessions: Slab::new(),
        win_by_ctx: std::collections::HashMap::new(),
    };
    super::group::install_predefined(&mut t.groups);
    super::comm::install_predefined(&mut t.comms);
    super::datatype::install_predefined(&mut t.dtypes);
    super::op::install_predefined(&mut t.ops);
    super::errh::install_predefined(&mut t.errhs);
    super::info::install_predefined(&mut t.infos);
    t
}

/// Convenience: world size/rank of the calling thread (post-bind).
pub fn current_rank() -> Option<usize> {
    CURRENT.with(|c| c.borrow().as_ref().map(|ctx| ctx.rank))
}

#[cfg(test)]
pub(crate) fn test_world(size: usize) -> Arc<World> {
    World::new(size, TransportKind::Spsc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_pairs_are_unique() {
        let w = test_world(2);
        let (a, b) = w.alloc_context_pair();
        let (c, d) = w.alloc_context_pair();
        assert_eq!(b, a + 1);
        assert_eq!(d, c + 1);
        assert!(c > b);
        // Predefined planes 0..6 (world, self, session bootstrap) are
        // never handed out.
        assert!(a >= 6);
    }

    #[test]
    fn dead_registry_and_revocation() {
        let w = test_world(3);
        assert!(!w.any_dead());
        assert!(!w.is_dead(1));
        w.mark_dead(1);
        w.mark_dead(1); // idempotent: counts once
        assert!(w.any_dead());
        assert!(w.is_dead(1));
        assert!(!w.is_dead(0));
        assert_eq!(w.ranks_failed(), 1);
        assert_eq!(w.dead_snapshot(), vec![1]);
        assert!(!w.is_revoked(8));
        assert!(w.revoke_context(8));
        assert!(!w.revoke_context(8)); // idempotent
        assert!(w.is_revoked(8));
        assert!(!w.is_revoked(9));
    }

    #[test]
    fn kill_spec_binds_only_victim() {
        let w = test_world(2);
        assert_eq!(w.kill_spec(), None);
        w.set_kill(1, 40);
        assert_eq!(w.kill_spec(), Some((1, 40)));
    }

    #[test]
    fn abort_first_caller_wins() {
        let w = test_world(1);
        assert_eq!(w.aborted(), None);
        w.abort(42);
        w.abort(7);
        assert_eq!(w.aborted(), Some(42));
    }

    #[test]
    fn wtime_is_monotone() {
        let w = test_world(1);
        let a = w.wtime();
        let b = w.wtime();
        assert!(b >= a);
    }

    #[test]
    fn unbound_thread_errors() {
        let r: RC<()> = with_ctx(|_| Ok(()));
        assert_eq!(r.unwrap_err().class, crate::abi::errors::MPI_ERR_OTHER);
    }

    #[test]
    fn bind_installs_predefined_objects() {
        std::thread::spawn(|| {
            let w = test_world(1);
            let ctx = bind_rank(w, 0);
            let t = ctx.tables.borrow();
            assert!(t.comms.contains(super::super::reserved::COMM_WORLD.0));
            assert!(t.comms.contains(super::super::reserved::COMM_SELF.0));
            assert!(t.groups.len() >= 3);
            assert_eq!(t.ops.len() as u32, super::super::reserved::NUM_BUILTIN_OPS);
            assert_eq!(t.dtypes.len() as u32, super::super::reserved::NUM_BUILTIN_DTYPES);
            assert!(t.errhs.len() >= 3);
            unbind_rank();
        })
        .join()
        .unwrap();
    }

    #[test]
    #[should_panic(expected = "already bound")]
    fn double_bind_panics() {
        // Run in a scoped thread so the panic doesn't poison other tests'
        // TLS.
        let w = test_world(1);
        let w2 = w.clone();
        std::thread::scope(|s| {
            s.spawn(move || {
                let _a = bind_rank(w2.clone(), 0);
                let _b = bind_rank(w2, 0); // panics
            })
            .join()
            .map_err(|e| std::panic::resume_unwind(e))
            .unwrap();
        });
    }
}
