//! Persistent-operation ablations (MPI-4): init-once/start-N versus
//! per-iteration nonblocking setup, through every ABI layer, on both
//! transports.
//!
//! What persistence amortizes in this engine: argument validation and
//! comm routing (pt2pt), request allocation/free per iteration, and —
//! for collectives — the whole schedule build (step list, tag plane,
//! staging buffers). The schedule-reuse claim is not just timed but
//! *proved*: the engine counts schedule constructions, and the
//! persistent start/wait loop must construct zero.

use mpi_abi::api::{Dt, MpiAbi, OpName};
use mpi_abi::apps::{with_abi, AbiApp, AbiConfig};
use mpi_abi::bench::Table;
use mpi_abi::core::collectives::schedules_built;
use mpi_abi::core::transport::TransportKind;
use mpi_abi::launcher::{run_job_ok, JobSpec};

const RANKS: usize = 2;
const PP_COUNT: usize = 256; // f32 elements per pt2pt message (small: per-op overhead dominates)
const AR_COUNT: usize = 1024; // f32 elements per allreduce

struct Results {
    /// Persistent ping-pong exchange, µs per iteration.
    pp_persist_us: f64,
    /// isend/irecv-per-iteration exchange, µs per iteration.
    pp_nb_us: f64,
    /// Persistent allreduce (start/wait), µs per iteration.
    ar_persist_us: f64,
    /// iallreduce-per-iteration, µs per iteration.
    ar_nb_us: f64,
    /// Schedules built during the persistent allreduce loop (must be 0).
    persist_builds: u64,
    /// Schedules built during the iallreduce loop (≈ ranks × iters).
    nb_builds: u64,
}

struct Persistent {
    transport: TransportKind,
    iters: usize,
}

impl AbiApp<Results> for Persistent {
    fn run<A: MpiAbi>(self) -> Results {
        let iters = self.iters;
        let out = run_job_ok(JobSpec::new(RANKS).with_transport(self.transport), move |rank| {
            A::init();
            let world = A::comm_world();
            let dt = A::datatype(Dt::Float);
            let op = A::op(OpName::Sum);
            let peer = (1 - rank) as i32;
            let me = rank as i32;
            let sendb = vec![1.0f32; PP_COUNT];
            let mut recvb = vec![0.0f32; PP_COUNT];
            let ar_send = vec![1.0f32; AR_COUNT];
            let mut ar_recv = vec![0.0f32; AR_COUNT];

            // --- pt2pt: persistent exchange (init once, startall/waitall per iter)
            let mut preqs = vec![A::request_null(); 2];
            A::send_init(sendb.as_ptr() as *const u8, PP_COUNT as i32, dt, peer, me, world,
                &mut preqs[0]);
            A::recv_init(recvb.as_mut_ptr() as *mut u8, PP_COUNT as i32, dt, peer, peer, world,
                &mut preqs[1]);
            // Warmup (primes rings and allocations on both paths).
            for _ in 0..5 {
                A::startall(&mut preqs);
                let mut sts = vec![A::status_empty(); 2];
                A::waitall(&mut preqs, &mut sts);
            }
            A::barrier(world);
            let t0 = A::wtime();
            for _ in 0..iters {
                A::startall(&mut preqs);
                let mut sts = vec![A::status_empty(); 2];
                A::waitall(&mut preqs, &mut sts);
            }
            let pp_persist = (A::wtime() - t0) / iters as f64;
            for r in preqs.iter_mut() {
                A::request_free(r);
            }

            // --- pt2pt: per-iteration isend/irecv (same traffic)
            A::barrier(world);
            let t0 = A::wtime();
            for _ in 0..iters {
                let mut reqs = vec![A::request_null(); 2];
                A::isend(sendb.as_ptr() as *const u8, PP_COUNT as i32, dt, peer, me, world,
                    &mut reqs[0]);
                A::irecv(recvb.as_mut_ptr() as *mut u8, PP_COUNT as i32, dt, peer, peer, world,
                    &mut reqs[1]);
                let mut sts = vec![A::status_empty(); 2];
                A::waitall(&mut reqs, &mut sts);
            }
            let pp_nb = (A::wtime() - t0) / iters as f64;

            // --- collective: persistent allreduce (schedule built once)
            let mut ar_req = A::request_null();
            A::allreduce_init(ar_send.as_ptr() as *const u8, ar_recv.as_mut_ptr() as *mut u8,
                AR_COUNT as i32, dt, op, world, &mut ar_req);
            A::barrier(world);
            let b0 = schedules_built();
            let t0 = A::wtime();
            for _ in 0..iters {
                A::start(&mut ar_req);
                let mut st = A::status_empty();
                A::wait(&mut ar_req, &mut st);
            }
            let ar_persist = (A::wtime() - t0) / iters as f64;
            let persist_builds = schedules_built() - b0;
            // Schedule-free rendezvous (pt2pt sendrecv, not a barrier):
            // the counter is process-global, so the peer's *next*
            // collective build must not land before both ranks have read
            // their delta.
            let token = [0u8];
            let mut tok = [0u8];
            let mut st = A::status_empty();
            A::sendrecv(token.as_ptr(), 1, A::datatype(Dt::Byte), peer, 77, tok.as_mut_ptr(),
                1, A::datatype(Dt::Byte), peer, 77, world, &mut st);
            // The acceptance invariant: starts reuse the schedule, so the
            // start/wait loop constructs none.
            assert_eq!(persist_builds, 0, "persistent starts must not rebuild schedules");
            A::request_free(&mut ar_req);

            // --- collective: per-iteration iallreduce (schedule per call)
            A::barrier(world);
            let b0 = schedules_built();
            let t0 = A::wtime();
            for _ in 0..iters {
                let mut req = A::request_null();
                A::iallreduce(ar_send.as_ptr() as *const u8, ar_recv.as_mut_ptr() as *mut u8,
                    AR_COUNT as i32, dt, op, world, &mut req);
                let mut st = A::status_empty();
                A::wait(&mut req, &mut st);
            }
            let ar_nb = (A::wtime() - t0) / iters as f64;
            let nb_builds = schedules_built() - b0;

            A::finalize();
            Results {
                pp_persist_us: pp_persist * 1e6,
                pp_nb_us: pp_nb * 1e6,
                ar_persist_us: ar_persist * 1e6,
                ar_nb_us: ar_nb * 1e6,
                persist_builds,
                nb_builds,
            }
        });
        // Slowest rank = the operation's latency; builds: take the max
        // observed delta (the counter is process-global).
        out.into_iter()
            .reduce(|a, b| Results {
                pp_persist_us: a.pp_persist_us.max(b.pp_persist_us),
                pp_nb_us: a.pp_nb_us.max(b.pp_nb_us),
                ar_persist_us: a.ar_persist_us.max(b.ar_persist_us),
                ar_nb_us: a.ar_nb_us.max(b.ar_nb_us),
                persist_builds: a.persist_builds.max(b.persist_builds),
                nb_builds: a.nb_builds.max(b.nb_builds),
            })
            .unwrap()
    }
}

fn main() {
    println!(
        "\nPersistent ops ({RANKS} ranks): init-once/start-N vs per-iteration nonblocking \
         ({PP_COUNT} f32 pt2pt, {AR_COUNT} f32 allreduce)"
    );
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        let iters = match transport {
            TransportKind::Spsc => 300,
            TransportKind::Mutex => 100,
        };
        let mut table = Table::new(
            &format!("persistent vs nonblocking [{} transport]", transport.name()),
            &[
                "ABI",
                "pp persist µs",
                "pp isend µs",
                "speedup",
                "ar persist µs",
                "ar icoll µs",
                "speedup",
                "builds/start",
            ],
        );
        for abi in AbiConfig::ALL {
            let r = with_abi(abi, Persistent { transport, iters });
            table.row(&[
                abi.name().to_string(),
                format!("{:.1}", r.pp_persist_us),
                format!("{:.1}", r.pp_nb_us),
                format!("{:.2}x", r.pp_nb_us / r.pp_persist_us),
                format!("{:.1}", r.ar_persist_us),
                format!("{:.1}", r.ar_nb_us),
                format!("{:.2}x", r.ar_nb_us / r.ar_persist_us),
                format!("{} vs {:.1}", 0, r.nb_builds as f64 / iters as f64),
            ]);
            let _ = r.persist_builds; // asserted 0 inside the job
        }
        println!("{}", table.render());
    }
    println!(
        "shape: persistent start/wait skips per-iteration validation/routing/allocation (pt2pt) \
         and the whole schedule build (collectives) — the builds/start column shows persistent \
         collectives constructing 0 schedules per start versus ~ranks for the i-collective; \
         speedups > 1.0x are the amortization the ROADMAP's hot-path item asks for."
    );
}
