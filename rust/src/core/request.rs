//! Requests and the progress engine.
//!
//! Every nonblocking operation creates a request; blocking operations are
//! request + wait. Progress is made inside test/wait/recv loops (polling
//! the fabric, matching posted receives against arrivals, acking
//! synchronous sends) — the single-threaded progress model of most MPI
//! implementations.

use super::transport::{Envelope, MsgKind, Payload};
use super::world::{with_ctx, RankCtx};
use super::{err, DtId, ReqId, RC};
use crate::abi::constants::MPI_PROC_NULL;

/// Implementation-independent status record. Each ABI converts this to its
/// own status layout — the translation the paper's §3.2 catalogues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StatusCore {
    pub source: i32,
    pub tag: i32,
    /// Canonical (standard-ABI) error class.
    pub error: i32,
    pub count_bytes: u64,
    pub cancelled: bool,
}

impl StatusCore {
    pub fn success(source: i32, tag: i32, count_bytes: u64) -> StatusCore {
        StatusCore { source, tag, error: 0, count_bytes, cancelled: false }
    }

    /// Status for a send completion or PROC_NULL op.
    pub fn empty() -> StatusCore {
        StatusCore {
            source: MPI_PROC_NULL,
            tag: crate::abi::constants::MPI_ANY_TAG,
            error: 0,
            count_bytes: 0,
            cancelled: false,
        }
    }
}

/// What a request is waiting for.
pub enum ReqKind {
    /// Eager send: complete at creation (buffer copied).
    Send,
    /// Synchronous send: complete when the ack for `sync_id` arrives.
    Ssend { sync_id: u64 },
    /// Posted receive.
    Recv { buf: usize, count: usize, dt: DtId, src: i32, tag: i32, context: u32 },
    /// Nonblocking collective: a schedule advanced by the progress engine
    /// (see [`crate::core::collectives::sched`]).
    Sched(Box<crate::core::collectives::sched::Schedule>),
}

pub struct RequestObj {
    pub kind: ReqKind,
    /// `Some` = complete.
    pub status: Option<StatusCore>,
}

/// Create a request in the table.
pub(crate) fn new_request(ctx: &RankCtx, kind: ReqKind, status: Option<StatusCore>) -> ReqId {
    ReqId(ctx.tables.borrow_mut().reqs.insert(RequestObj { kind, status }))
}

/// Post a receive request (and try to match it immediately against the
/// unexpected queue).
pub(crate) fn post_recv(
    ctx: &RankCtx,
    buf: usize,
    count: usize,
    dt: DtId,
    src: i32,
    tag: i32,
    context: u32,
) -> ReqId {
    let id = new_request(ctx, ReqKind::Recv { buf, count, dt, src, tag, context }, None);
    ctx.state.borrow_mut().posted.push_back(id);
    // Immediate match attempt: the message may already be here.
    match_posted(ctx);
    id
}

/// One progress cycle: flush deferred sends, drain the fabric, match,
/// then advance every in-flight collective schedule.
pub(crate) fn progress(ctx: &RankCtx) {
    if let Some(code) = ctx.world.aborted() {
        std::panic::panic_any(super::world::AbortUnwind(code));
    }
    flush_pending_sends(ctx);
    drain_fabric(ctx);
    match_posted(ctx);
    super::collectives::sched::progress_scheds(ctx);
}

fn flush_pending_sends(ctx: &RankCtx) {
    let mut st = ctx.state.borrow_mut();
    while let Some((dst, env)) = st.pending_sends.pop_front() {
        match ctx.world.fabric.try_send(dst, env) {
            Ok(()) => {}
            Err(env) => {
                st.pending_sends.push_front((dst, env));
                break;
            }
        }
    }
}

fn drain_fabric(ctx: &RankCtx) {
    let mut st = ctx.state.borrow_mut();
    if ctx.world.fabric.inbound_empty(ctx.rank) {
        return;
    }
    let mut inbox = std::mem::take(&mut st.inbox);
    ctx.world.fabric.poll_into(ctx.rank, &mut inbox);
    for env in inbox.drain(..) {
        match env.kind {
            MsgKind::SsendAck => {
                st.ssend_acks.insert(env.seq);
            }
            MsgKind::Eager | MsgKind::EagerSync => st.unexpected.push_back(env),
        }
    }
    st.inbox = inbox;
}

/// Try to complete posted receives against the unexpected queue, in post
/// order (MPI matching semantics: posted order × arrival order).
fn match_posted(ctx: &RankCtx) {
    loop {
        // Find the first posted request that has a matching message.
        let mut matched: Option<(usize, usize, ReqId)> = None; // (posted idx, unexpected idx, req)
        {
            let st = ctx.state.borrow();
            let t = ctx.tables.borrow();
            'outer: for (pi, &rid) in st.posted.iter().enumerate() {
                let Some(req) = t.reqs.get(rid.0) else { continue };
                let ReqKind::Recv { src, tag, context, .. } = req.kind else { continue };
                for (ui, env) in st.unexpected.iter().enumerate() {
                    if env.matches(context, src, tag) {
                        matched = Some((pi, ui, rid));
                        break 'outer;
                    }
                }
            }
        }
        let Some((pi, ui, rid)) = matched else { return };
        // Remove both, then deliver.
        let env = {
            let mut st = ctx.state.borrow_mut();
            st.posted.remove(pi);
            st.unexpected.remove(ui).expect("index valid")
        };
        deliver(ctx, rid, env);
    }
}

/// Copy a matched message into the receive buffer and complete the request.
fn deliver(ctx: &RankCtx, rid: ReqId, env: Envelope) {
    let mut t = ctx.tables.borrow_mut();
    let tables = &mut *t;
    let Some(req) = tables.reqs.get_mut(rid.0) else { return };
    let ReqKind::Recv { buf, count, dt, .. } = req.kind else { return };
    let data = env.payload.as_slice();
    // Capacity in packed bytes of the posted buffer.
    let cap = tables.dtypes.get(dt.0).map(|o| o.size * count).unwrap_or(0);
    let truncated = data.len() > cap;
    let take = data.len().min(cap);
    let consumed = super::datatype::pack::unpack(
        &tables.dtypes,
        &data[..take],
        buf as *mut u8,
        count,
        dt,
    )
    .unwrap_or(0);
    let mut status = StatusCore::success(env.src as i32, env.tag, consumed as u64);
    if truncated {
        status.error = crate::abi::errors::MPI_ERR_TRUNCATE;
    }
    req.status = Some(status);
    drop(t);
    // Ack synchronous sends now that the message is matched.
    if env.kind == MsgKind::EagerSync {
        let ack = Envelope {
            src: ctx.rank as u32,
            context: env.context,
            tag: env.tag,
            kind: MsgKind::SsendAck,
            seq: env.seq,
            payload: Payload::empty(),
        };
        enqueue_send(ctx, env.src as usize, ack);
    }
}

/// Send an envelope, preserving per-destination FIFO even under
/// backpressure (deferred envelopes drain before new ones).
pub(crate) fn enqueue_send(ctx: &RankCtx, dst: usize, env: Envelope) {
    let mut st = ctx.state.borrow_mut();
    let blocked = st.pending_sends.iter().any(|&(d, _)| d == dst);
    if blocked {
        st.pending_sends.push_back((dst, env));
        return;
    }
    if let Err(env) = ctx.world.fabric.try_send(dst, env) {
        st.pending_sends.push_back((dst, env));
    }
}

/// Poll a request's completion state; applies one progress cycle first.
pub(crate) fn poll_complete(ctx: &RankCtx, rid: ReqId) -> RC<Option<StatusCore>> {
    progress(ctx);
    finish_if_done(ctx, rid)
}

/// Check (without progressing) whether `rid` is complete, resolving
/// Ssend acks. Schedule-backed (collective) requests complete inside
/// [`progress`] — here they are simply pending until their status lands.
pub(crate) fn finish_if_done(ctx: &RankCtx, rid: ReqId) -> RC<Option<StatusCore>> {
    enum Next {
        Done(StatusCore),
        Pending,
        CheckSsend(u64),
    }
    let next = {
        let t = ctx.tables.borrow();
        let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
        match (&req.status, &req.kind) {
            (Some(s), _) => Next::Done(*s),
            (None, ReqKind::Ssend { sync_id }) => Next::CheckSsend(*sync_id),
            (None, _) => Next::Pending,
        }
    };
    match next {
        Next::Done(s) => Ok(Some(s)),
        Next::Pending => Ok(None),
        Next::CheckSsend(sync_id) => {
            let acked = ctx.state.borrow_mut().ssend_acks.remove(&sync_id);
            if acked {
                let s = StatusCore::empty();
                ctx.tables.borrow_mut().reqs.get_mut(rid.0).unwrap().status = Some(s);
                Ok(Some(s))
            } else {
                Ok(None)
            }
        }
    }
}

/// Block until `rid` completes; deallocate it; return its status.
pub(crate) fn wait_one(ctx: &RankCtx, rid: ReqId) -> RC<StatusCore> {
    loop {
        if let Some(s) = poll_complete(ctx, rid)? {
            ctx.tables.borrow_mut().reqs.remove(rid.0);
            return Ok(s);
        }
        std::thread::yield_now();
    }
}

/// Nonblocking completion check; deallocates on completion (`MPI_Test`).
pub(crate) fn test_one(ctx: &RankCtx, rid: ReqId) -> RC<Option<StatusCore>> {
    match poll_complete(ctx, rid)? {
        Some(s) => {
            ctx.tables.borrow_mut().reqs.remove(rid.0);
            Ok(Some(s))
        }
        None => Ok(None),
    }
}

/// `MPI_Cancel` — supported for unmatched receives (marks cancelled).
pub fn cancel(rid: ReqId) -> RC<()> {
    with_ctx(|ctx| {
        let is_recv_pending = {
            let t = ctx.tables.borrow();
            let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
            matches!(req.kind, ReqKind::Recv { .. }) && req.status.is_none()
        };
        if is_recv_pending {
            let mut st = ctx.state.borrow_mut();
            st.posted.retain(|&r| r != rid);
            drop(st);
            let mut t = ctx.tables.borrow_mut();
            let req = t.reqs.get_mut(rid.0).unwrap();
            let mut s = StatusCore::empty();
            s.cancelled = true;
            req.status = Some(s);
        }
        // Sends: cancel is best-effort; eager sends already completed.
        Ok(())
    })
}

/// `MPI_Request_free`.
pub fn request_free(rid: ReqId) -> RC<()> {
    with_ctx(|ctx| {
        let mut t = ctx.tables.borrow_mut();
        let req = t.reqs.get(rid.0).ok_or(err!(MPI_ERR_REQUEST))?;
        // Freeing an *active* nonblocking-collective request is erroneous
        // (MPI 3.0 §3.7.3); dropping the schedule would also strand its
        // unexecuted send steps and deadlock peers, so reject instead.
        if req.status.is_none() && matches!(req.kind, ReqKind::Sched(_)) {
            return Err(err!(MPI_ERR_REQUEST));
        }
        t.reqs.remove(rid.0).map(|_| ()).ok_or(err!(MPI_ERR_REQUEST))
    })
}
