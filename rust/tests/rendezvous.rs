//! Eager/rendezvous protocol-switch tests (the PR-6 tentpole): bit
//! identity across the threshold, RTS/recv arrival-order independence,
//! wildcard rendezvous, FIFO across interleaved protocols, backpressure
//! without head-of-line blocking, and the bounded-buffering witness — a
//! 256 MiB transfer whose in-flight payload never approaches the
//! message size.
//!
//! The threshold is forced per job via
//! [`JobSpec::with_rndv_threshold`], never via the process-global
//! `MPI_ABI_RNDV_THRESHOLD` env var, so parallel tests cannot race.

use mpi_abi::api::{Dt, MpiAbi};
use mpi_abi::core::request::{RNDV_CHUNK, RNDV_WINDOW_BYTES};
use mpi_abi::core::transport::TransportKind;
use mpi_abi::core::world::World;
use mpi_abi::impls::MpichAbi;
use mpi_abi::launcher::{run_job_ok, run_on_world, JobSpec};
use mpi_abi::muk::MukMpich;
use mpi_abi::native_abi::NativeAbi;

fn pattern(len: usize, seed: u8) -> Vec<u8> {
    (0..len).map(|i| (i as u8) ^ seed.wrapping_mul(31)).collect()
}

/// Messages of threshold−1, threshold, and threshold+1 packed bytes:
/// the first two stay eager, the third goes rendezvous (the switch is
/// strictly-greater), and all three arrive bit-identical.
fn boundary_bit_identity<A: MpiAbi>(transport: TransportKind) {
    const T: usize = 4096;
    let spec = JobSpec::new(2).with_transport(transport).with_rndv_threshold(T);
    run_job_ok(spec, |rank| {
        assert_eq!(A::init(), 0);
        let dt = A::datatype(Dt::Byte);
        let world = A::comm_world();
        for (i, len) in [T - 1, T, T + 1].into_iter().enumerate() {
            let tag = 10 + i as i32;
            if rank == 0 {
                let s = pattern(len, i as u8);
                assert_eq!(A::send(s.as_ptr(), len as i32, dt, 1, tag, world), 0);
            } else {
                let mut r = vec![0u8; len];
                let mut st = A::status_empty();
                assert_eq!(A::recv(r.as_mut_ptr(), len as i32, dt, 0, tag, world, &mut st), 0);
                assert_eq!(A::get_count(&st, dt), len as i32, "len at boundary {i}");
                assert_eq!(r, pattern(len, i as u8), "bit identity at boundary {i}");
            }
        }
        assert_eq!(A::finalize(), 0);
    });
}

#[test]
fn threshold_boundary_bit_identity_native_abi() {
    boundary_bit_identity::<NativeAbi>(TransportKind::Spsc);
    boundary_bit_identity::<NativeAbi>(TransportKind::Mutex);
}

#[test]
fn threshold_boundary_bit_identity_mpich_and_muk() {
    boundary_bit_identity::<MpichAbi>(TransportKind::Spsc);
    boundary_bit_identity::<MukMpich>(TransportKind::Spsc);
}

/// RTS arriving before the receive is posted (unexpected-RTS path) and
/// after (posted path): both deliver the full payload. The sender uses
/// isend so the handshake genuinely overlaps the receiver's delay.
#[test]
fn rts_before_and_after_recv_posted() {
    const LEN: usize = 300_000; // > default threshold, several chunks
    for transport in [TransportKind::Spsc, TransportKind::Mutex] {
        let spec = JobSpec::new(2).with_transport(transport);
        run_job_ok(spec, |rank| {
            assert_eq!(NativeAbi::init(), 0);
            type A = NativeAbi;
            let dt = A::datatype(Dt::Byte);
            let world = A::comm_world();
            // Round 1: RTS lands while no recv is posted.
            if rank == 0 {
                let s = pattern(LEN, 1);
                assert_eq!(A::send(s.as_ptr(), LEN as i32, dt, 1, 20, world), 0);
            } else {
                // Let the RTS (and nothing else: no CTS yet) arrive first.
                std::thread::sleep(std::time::Duration::from_millis(20));
                let mut r = vec![0u8; LEN];
                let mut st = A::status_empty();
                assert_eq!(A::recv(r.as_mut_ptr(), LEN as i32, dt, 0, 20, world, &mut st), 0);
                assert_eq!(r, pattern(LEN, 1), "unexpected-RTS path");
            }
            assert_eq!(A::barrier(world), 0);
            // Round 2: recv posted well before the send starts.
            if rank == 1 {
                let mut r = vec![0u8; LEN];
                let mut req = A::request_null();
                assert_eq!(A::irecv(r.as_mut_ptr(), LEN as i32, dt, 0, 21, world, &mut req), 0);
                let mut st = A::status_empty();
                assert_eq!(A::wait(&mut req, &mut st), 0);
                assert_eq!(r, pattern(LEN, 2), "posted-recv path");
            } else {
                std::thread::sleep(std::time::Duration::from_millis(20));
                let s = pattern(LEN, 2);
                assert_eq!(A::send(s.as_ptr(), LEN as i32, dt, 1, 21, world), 0);
            }
            assert_eq!(A::finalize(), 0);
        });
    }
}

/// ANY_SOURCE / ANY_TAG receives match rendezvous sends: the RTS is the
/// matchable envelope, so wildcards see it exactly like an eager send.
#[test]
fn wildcard_rendezvous() {
    const LEN: usize = 200_000;
    let spec = JobSpec::new(3).with_transport(TransportKind::Spsc);
    run_job_ok(spec, |rank| {
        assert_eq!(NativeAbi::init(), 0);
        type A = NativeAbi;
        let dt = A::datatype(Dt::Byte);
        let world = A::comm_world();
        if rank == 0 {
            let mut seen = [false; 3];
            for _ in 0..2 {
                let mut r = vec![0u8; LEN];
                let mut st = A::status_empty();
                assert_eq!(
                    A::recv(
                        r.as_mut_ptr(),
                        LEN as i32,
                        dt,
                        A::any_source(),
                        A::any_tag(),
                        world,
                        &mut st
                    ),
                    0
                );
                let src = A::status_source(&st);
                let tag = A::status_tag(&st);
                assert!(src == 1 || src == 2, "wildcard source {src}");
                assert_eq!(tag, 30 + src, "tag carried through the RTS");
                assert_eq!(A::get_count(&st, dt), LEN as i32);
                assert_eq!(r, pattern(LEN, src as u8), "payload from rank {src}");
                assert!(!seen[src as usize], "each sender matched once");
                seen[src as usize] = true;
            }
        } else {
            let s = pattern(LEN, rank as u8);
            assert_eq!(A::send(s.as_ptr(), LEN as i32, dt, 0, 30 + rank as i32, world), 0);
        }
        assert_eq!(NativeAbi::finalize(), 0);
    });
}

/// Alternating eager and rendezvous sends on the same (src, tag): MPI
/// non-overtaking must hold across the protocol switch — message k
/// matches the k-th receive whatever protocol carried it.
#[test]
fn interleaved_eager_rendezvous_fifo() {
    const SMALL: usize = 64;
    const BIG: usize = 150_000;
    let spec = JobSpec::new(2).with_transport(TransportKind::Spsc);
    run_job_ok(spec, |rank| {
        assert_eq!(NativeAbi::init(), 0);
        type A = NativeAbi;
        let dt = A::datatype(Dt::Byte);
        let world = A::comm_world();
        let len_of = |k: usize| if k % 2 == 0 { SMALL } else { BIG };
        if rank == 0 {
            for k in 0..8 {
                let s = pattern(len_of(k), k as u8);
                assert_eq!(A::send(s.as_ptr(), len_of(k) as i32, dt, 1, 40, world), 0);
            }
        } else {
            for k in 0..8 {
                let len = len_of(k);
                let mut r = vec![0u8; len];
                let mut st = A::status_empty();
                assert_eq!(A::recv(r.as_mut_ptr(), len as i32, dt, 0, 40, world, &mut st), 0);
                assert_eq!(A::get_count(&st, dt), len as i32, "message {k} length");
                assert_eq!(r, pattern(len, k as u8), "FIFO across protocols at {k}");
            }
        }
        assert_eq!(NativeAbi::finalize(), 0);
    });
}

/// Backpressure on a stalled rendezvous stream must not head-of-line
/// block the channel: with the big message's receive *not yet posted*
/// (so the sender is parked waiting for CTS), a later eager message on
/// another tag still goes through. Only then is the big receive posted.
#[test]
fn backpressure_is_not_head_of_line_blocking() {
    const BIG: usize = 8 * 1024 * 1024; // far beyond the credit window
    let spec = JobSpec::new(2).with_transport(TransportKind::Spsc);
    run_job_ok(spec, |rank| {
        assert_eq!(NativeAbi::init(), 0);
        type A = NativeAbi;
        let dt = A::datatype(Dt::Byte);
        let world = A::comm_world();
        if rank == 0 {
            let big = pattern(BIG, 5);
            let mut req = A::request_null();
            assert_eq!(A::isend(big.as_ptr(), BIG as i32, dt, 1, 50, world, &mut req), 0);
            // The eager message leaves while the rendezvous stream above
            // is still waiting for its first CTS.
            let small = [7u8; 16];
            assert_eq!(A::send(small.as_ptr(), 16, dt, 1, 51, world), 0);
            let mut st = A::status_empty();
            assert_eq!(A::wait(&mut req, &mut st), 0);
        } else {
            // Receive the eager message FIRST: it must not be stuck
            // behind the unserviced rendezvous handshake.
            let mut small = [0u8; 16];
            let mut st = A::status_empty();
            assert_eq!(A::recv(small.as_mut_ptr(), 16, dt, 0, 51, world, &mut st), 0);
            assert_eq!(small, [7u8; 16]);
            let mut big = vec![0u8; BIG];
            assert_eq!(A::recv(big.as_mut_ptr(), BIG as i32, dt, 0, 50, world, &mut st), 0);
            assert_eq!(big, pattern(BIG, 5), "big payload after the eager bypass");
        }
        assert_eq!(NativeAbi::finalize(), 0);
    });
}

/// The acceptance witness: a 256 MiB transfer's peak in-flight
/// rendezvous payload stays bounded by the credit window (chunk-sized
/// buffering), never approaching the message size — the receiver
/// streams chunks straight into the posted user buffer.
#[test]
fn peak_inflight_bounded_for_256mib_transfer() {
    const LEN: usize = 256 * 1024 * 1024;
    let world = World::new(2, TransportKind::Spsc);
    let outcomes = run_on_world(world.clone(), 2, |rank| {
        assert_eq!(NativeAbi::init(), 0);
        type A = NativeAbi;
        let dt = A::datatype(Dt::Byte);
        let comm = A::comm_world();
        if rank == 0 {
            let mut s = vec![0u8; LEN];
            // Cheap deterministic pattern, sparse enough to build fast.
            for i in (0..LEN).step_by(4096) {
                s[i] = (i / 4096) as u8;
            }
            assert_eq!(A::send(s.as_ptr(), LEN as i32, dt, 1, 60, comm), 0);
        } else {
            let mut r = vec![0u8; LEN];
            let mut st = A::status_empty();
            assert_eq!(A::recv(r.as_mut_ptr(), LEN as i32, dt, 0, 60, comm, &mut st), 0);
            assert_eq!(A::get_count(&st, dt), LEN as i32);
            for i in (0..LEN).step_by(4096) {
                assert_eq!(r[i], (i / 4096) as u8, "byte {i}");
            }
        }
        assert_eq!(A::finalize(), 0);
    });
    assert!(outcomes.iter().all(|o| o.is_ok()));
    let peak = world.rndv_inflight_peak();
    assert!(peak > 0, "a 256 MiB transfer must use the rendezvous path");
    // Bounded by the credit window plus one chunk of slack — five
    // orders of magnitude below the 256 MiB message.
    let bound = RNDV_WINDOW_BYTES + RNDV_CHUNK as u64;
    assert!(
        peak <= bound,
        "peak in-flight rendezvous payload {peak} B exceeds window bound {bound} B"
    );
}

/// Synchronous-mode semantics survive the switch: a large `MPI_Ssend`
/// completes only against a matching receive, and small Ssends (eager
/// size) still synchronize.
#[test]
fn ssend_across_the_threshold() {
    for len in [64usize, 1024 * 1024] {
        let spec = JobSpec::new(2).with_transport(TransportKind::Spsc);
        run_job_ok(spec, |rank| {
            assert_eq!(NativeAbi::init(), 0);
            type A = NativeAbi;
            let dt = A::datatype(Dt::Byte);
            let world = A::comm_world();
            if rank == 0 {
                let s = pattern(len, 9);
                assert_eq!(A::ssend(s.as_ptr(), len as i32, dt, 1, 70, world), 0);
            } else {
                std::thread::sleep(std::time::Duration::from_millis(10));
                let mut r = vec![0u8; len];
                let mut st = A::status_empty();
                assert_eq!(A::recv(r.as_mut_ptr(), len as i32, dt, 0, 70, world, &mut st), 0);
                assert_eq!(r, pattern(len, 9));
            }
            assert_eq!(NativeAbi::finalize(), 0);
        });
    }
}
