//! **Mukautuva** ("adaptable"): the standalone standard-ABI translation
//! layer (§6.2) — `libmuk.so` in the paper's architecture.
//!
//! Applications compile against the standard ABI (`Muk<…>` implements
//! [`MpiAbi`] with the standard handle/status/constant types). At init,
//! libmuk "dlopens" the chosen backend's wrap library and resolves every
//! `WRAP_*` symbol into a function-pointer vtable; every MPI call is one
//! indirect call through that vtable into the wrap layer, which performs
//! the representation conversion. This is the paper's *worst-case*
//! implementation of the standard ABI — the +Mukautuva rows of Table 1.

// The translation layer is itself a binary contract (the libmuk ⇄
// impl-wrap.so boundary): every public item must say what it converts.
#![warn(missing_docs)]

pub mod callbacks;
pub mod convert;
pub mod state;
pub mod word;
pub mod wrap;

use once_cell::sync::Lazy;

use crate::abi::handles::*;
use crate::abi::status::AbiStatus;
use crate::api::{dt_to_abi_const, op_to_abi_const, AttrCopyFn, AttrDeleteFn, Dt, ErrhFn, MpiAbi,
    OpName, UserOpFn};
use crate::impls::{MpichAbi, OmpiAbi};
use wrap::{build_symbols, SymbolTable, Vtable};

/// Which backend implementation libmuk redirects to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The MPICH-like integer-handle backend.
    Mpich,
    /// The Open-MPI-like pointer-handle backend.
    Ompi,
}

/// Backend selection marker (the `MUK_MPI=...` environment choice),
/// resolved to a vtable at first use ("dlopen at initialization").
pub trait BackendSel: 'static {
    /// Which backend this marker selects.
    const BACKEND: Backend;
    /// Display name ("muk(mpich)" / "muk(ompi)").
    const NAME: &'static str;
    /// The resolved WRAP vtable for this backend.
    fn vtable() -> &'static Vtable;
}

/// Marker: Mukautuva over the MPICH-like backend.
pub struct OverMpich;
/// Marker: Mukautuva over the Open-MPI-like backend.
pub struct OverOmpi;

static MPICH_SYMBOLS: Lazy<SymbolTable> = Lazy::new(|| build_symbols::<MpichAbi>("mpich-wrap"));
static OMPI_SYMBOLS: Lazy<SymbolTable> = Lazy::new(|| build_symbols::<OmpiAbi>("ompi-wrap"));
static MPICH_VTABLE: Lazy<Vtable> = Lazy::new(|| Vtable::resolve(&MPICH_SYMBOLS));
static OMPI_VTABLE: Lazy<Vtable> = Lazy::new(|| Vtable::resolve(&OMPI_SYMBOLS));

impl BackendSel for OverMpich {
    const BACKEND: Backend = Backend::Mpich;
    const NAME: &'static str = "muk(mpich)";
    fn vtable() -> &'static Vtable {
        &MPICH_VTABLE
    }
}

impl BackendSel for OverOmpi {
    const BACKEND: Backend = Backend::Ompi;
    const NAME: &'static str = "muk(ompi)";
    fn vtable() -> &'static Vtable {
        &OMPI_VTABLE
    }
}

/// The symbol table of a backend's wrap library (for inspection/tests).
pub fn symbols(b: Backend) -> &'static SymbolTable {
    match b {
        Backend::Mpich => &MPICH_SYMBOLS,
        Backend::Ompi => &OMPI_SYMBOLS,
    }
}

/// `libmuk` as an [`MpiAbi`]: standard-ABI types throughout; every call
/// dispatches through the backend's resolved vtable.
pub struct Muk<B: BackendSel>(std::marker::PhantomData<B>);

/// Mukautuva over the MPICH-like backend.
pub type MukMpich = Muk<OverMpich>;
/// Mukautuva over the Open-MPI-like backend.
pub type MukOmpi = Muk<OverOmpi>;

impl<B: BackendSel> MpiAbi for Muk<B> {
    const NAME: &'static str = B::NAME;

    type Comm = AbiComm;
    type Datatype = AbiDatatype;
    type Op = AbiOp;
    type Request = AbiRequest;
    type Group = AbiGroup;
    type Errhandler = AbiErrhandler;
    type Info = AbiInfo;
    type Win = AbiWin;
    type Session = AbiSession;
    type Status = AbiStatus;

    fn comm_world() -> AbiComm {
        AbiComm::WORLD
    }
    fn comm_self() -> AbiComm {
        AbiComm::SELF
    }
    fn comm_null() -> AbiComm {
        AbiComm::NULL
    }
    fn request_null() -> AbiRequest {
        AbiRequest::NULL
    }
    fn datatype(d: Dt) -> AbiDatatype {
        AbiDatatype(dt_to_abi_const(d))
    }
    fn op(o: OpName) -> AbiOp {
        AbiOp(op_to_abi_const(o))
    }
    fn errhandler_return() -> AbiErrhandler {
        AbiErrhandler::ERRORS_RETURN
    }
    fn errhandler_fatal() -> AbiErrhandler {
        AbiErrhandler::ERRORS_ARE_FATAL
    }
    fn info_null() -> AbiInfo {
        AbiInfo::NULL
    }
    fn win_null() -> AbiWin {
        AbiWin::NULL
    }
    fn session_null() -> AbiSession {
        AbiSession::NULL
    }
    fn lock_exclusive() -> i32 {
        crate::abi::constants::MPI_LOCK_EXCLUSIVE
    }
    fn lock_shared() -> i32 {
        crate::abi::constants::MPI_LOCK_SHARED
    }
    fn mode_nocheck() -> i32 {
        crate::abi::constants::MPI_MODE_NOCHECK
    }
    fn mode_nostore() -> i32 {
        crate::abi::constants::MPI_MODE_NOSTORE
    }
    fn mode_noput() -> i32 {
        crate::abi::constants::MPI_MODE_NOPUT
    }
    fn mode_noprecede() -> i32 {
        crate::abi::constants::MPI_MODE_NOPRECEDE
    }
    fn mode_nosucceed() -> i32 {
        crate::abi::constants::MPI_MODE_NOSUCCEED
    }
    fn any_source() -> i32 {
        crate::abi::constants::MPI_ANY_SOURCE
    }
    fn any_tag() -> i32 {
        crate::abi::constants::MPI_ANY_TAG
    }
    fn proc_null() -> i32 {
        crate::abi::constants::MPI_PROC_NULL
    }
    fn undefined() -> i32 {
        crate::abi::constants::MPI_UNDEFINED
    }
    fn in_place() -> *const u8 {
        crate::abi::constants::MPI_IN_PLACE as *const u8
    }
    fn err_class_of(code: i32) -> i32 {
        code
    }
    fn error_string(code: i32) -> String {
        crate::abi::errors::error_string(code).to_string()
    }
    fn err_from_canonical(class: i32) -> i32 {
        class
    }

    fn init() -> i32 {
        (B::vtable().init)()
    }
    fn finalize() -> i32 {
        (B::vtable().finalize)()
    }
    fn initialized() -> bool {
        (B::vtable().initialized)()
    }
    fn finalized() -> bool {
        (B::vtable().finalized)()
    }
    fn abort(c: AbiComm, code: i32) -> i32 {
        (B::vtable().abort)(c.0, code)
    }
    fn wtime() -> f64 {
        (B::vtable().wtime)()
    }
    fn get_library_version() -> String {
        let mut s = String::new();
        (B::vtable().get_library_version)(&mut s);
        s
    }
    fn get_version() -> (i32, i32) {
        let (mut a, mut b) = (0, 0);
        (B::vtable().get_version)(&mut a, &mut b);
        (a, b)
    }
    fn get_processor_name() -> String {
        let mut s = String::new();
        (B::vtable().get_processor_name)(&mut s);
        s
    }

    fn session_init(info: AbiInfo, errh: AbiErrhandler, session: &mut AbiSession) -> i32 {
        (B::vtable().session_init)(info.0, errh.0, &mut session.0)
    }
    fn session_finalize(session: &mut AbiSession) -> i32 {
        (B::vtable().session_finalize)(&mut session.0)
    }
    fn session_get_num_psets(session: AbiSession, out: &mut i32) -> i32 {
        (B::vtable().session_get_num_psets)(session.0, out)
    }
    fn session_get_nth_pset(session: AbiSession, n: i32, out: &mut String) -> i32 {
        (B::vtable().session_get_nth_pset)(session.0, n, out)
    }
    fn session_get_pset_info(session: AbiSession, pset: &str, out: &mut AbiInfo) -> i32 {
        (B::vtable().session_get_pset_info)(session.0, pset, &mut out.0)
    }
    fn group_from_session_pset(session: AbiSession, pset: &str, out: &mut AbiGroup) -> i32 {
        (B::vtable().group_from_session_pset)(session.0, pset, &mut out.0)
    }
    fn comm_create_from_group(
        group: AbiGroup,
        stringtag: &str,
        info: AbiInfo,
        errh: AbiErrhandler,
        out: &mut AbiComm,
    ) -> i32 {
        (B::vtable().comm_create_from_group)(group.0, stringtag, info.0, errh.0, &mut out.0)
    }

    fn status_empty() -> AbiStatus {
        let mut s = AbiStatus::empty();
        s.MPI_SOURCE = crate::abi::constants::MPI_PROC_NULL;
        s.MPI_TAG = crate::abi::constants::MPI_ANY_TAG;
        s
    }
    fn status_source(s: &AbiStatus) -> i32 {
        s.MPI_SOURCE
    }
    fn status_tag(s: &AbiStatus) -> i32 {
        s.MPI_TAG
    }
    fn status_error(s: &AbiStatus) -> i32 {
        s.MPI_ERROR
    }
    fn status_cancelled(s: &AbiStatus) -> bool {
        s.cancelled()
    }
    fn get_count(s: &AbiStatus, dt: AbiDatatype) -> i32 {
        let mut out = 0;
        (B::vtable().get_count)(s as *const AbiStatus, dt.0, &mut out);
        out
    }
    fn get_elements(s: &AbiStatus, dt: AbiDatatype) -> i32 {
        let mut out = 0;
        (B::vtable().get_elements)(s as *const AbiStatus, dt.0, &mut out);
        out
    }

    fn send_c(
        buf: *const u8,
        count: crate::abi::types::Count,
        dt: AbiDatatype,
        dest: i32,
        tag: i32,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().send_c)(buf, count, dt.0, dest, tag, c.0)
    }
    fn recv_c(
        buf: *mut u8,
        count: crate::abi::types::Count,
        dt: AbiDatatype,
        src: i32,
        tag: i32,
        c: AbiComm,
        status: &mut AbiStatus,
    ) -> i32 {
        (B::vtable().recv_c)(buf, count, dt.0, src, tag, c.0, status as *mut AbiStatus)
    }
    fn get_count_c(s: &AbiStatus, dt: AbiDatatype, out: &mut crate::abi::types::Count) -> i32 {
        (B::vtable().get_count_c)(s as *const AbiStatus, dt.0, out)
    }
    fn get_elements_c(s: &AbiStatus, dt: AbiDatatype, out: &mut crate::abi::types::Count) -> i32 {
        (B::vtable().get_elements_c)(s as *const AbiStatus, dt.0, out)
    }
    fn status_set_elements_c(
        s: &mut AbiStatus,
        dt: AbiDatatype,
        count: crate::abi::types::Count,
    ) -> i32 {
        (B::vtable().status_set_elements_c)(s as *mut AbiStatus, dt.0, count)
    }
    fn type_size_c(dt: AbiDatatype, out: &mut crate::abi::types::Count) -> i32 {
        (B::vtable().type_size_c)(dt.0, out)
    }
    fn type_contiguous_c(
        count: crate::abi::types::Count,
        child: AbiDatatype,
        out: &mut AbiDatatype,
    ) -> i32 {
        (B::vtable().type_contiguous_c)(count, child.0, &mut out.0)
    }
    fn type_vector_c(
        count: crate::abi::types::Count,
        blocklen: crate::abi::types::Count,
        stride: crate::abi::types::Count,
        child: AbiDatatype,
        out: &mut AbiDatatype,
    ) -> i32 {
        (B::vtable().type_vector_c)(count, blocklen, stride, child.0, &mut out.0)
    }
    fn allgatherv_c(
        sendbuf: *const u8,
        sendcount: crate::abi::types::Count,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcounts: crate::api::Counts<'_>,
        displs: crate::api::Displs<'_>,
        recvtype: AbiDatatype,
        c: AbiComm,
    ) -> i32 {
        // Widen once at the boundary: the wrap ABI carries the arrays in
        // their wide (`MPI_Count[]`/`MPI_Aint[]`) layout.
        let counts = recvcounts.to_counts();
        let disps = displs.to_aints();
        (B::vtable().allgatherv_c)(sendbuf, sendcount, sendtype.0, recvbuf, &counts, &disps,
            recvtype.0, c.0)
    }

    fn comm_size(c: AbiComm, out: &mut i32) -> i32 {
        (B::vtable().comm_size)(c.0, out)
    }
    fn comm_rank(c: AbiComm, out: &mut i32) -> i32 {
        (B::vtable().comm_rank)(c.0, out)
    }
    fn comm_dup(c: AbiComm, out: &mut AbiComm) -> i32 {
        (B::vtable().comm_dup)(c.0, &mut out.0)
    }
    fn comm_split(c: AbiComm, color: i32, key: i32, out: &mut AbiComm) -> i32 {
        (B::vtable().comm_split)(c.0, color, key, &mut out.0)
    }
    fn comm_split_type(c: AbiComm, split_type: i32, key: i32, out: &mut AbiComm) -> i32 {
        (B::vtable().comm_split_type)(c.0, split_type, key, &mut out.0)
    }
    fn comm_free(c: &mut AbiComm) -> i32 {
        (B::vtable().comm_free)(&mut c.0)
    }
    fn comm_compare(a: AbiComm, b: AbiComm, out: &mut i32) -> i32 {
        (B::vtable().comm_compare)(a.0, b.0, out)
    }
    fn comm_set_name(c: AbiComm, name: &str) -> i32 {
        (B::vtable().comm_set_name)(c.0, name)
    }
    fn comm_get_name(c: AbiComm, out: &mut String) -> i32 {
        (B::vtable().comm_get_name)(c.0, out)
    }
    fn comm_group(c: AbiComm, out: &mut AbiGroup) -> i32 {
        (B::vtable().comm_group)(c.0, &mut out.0)
    }
    fn group_size(g: AbiGroup, out: &mut i32) -> i32 {
        (B::vtable().group_size)(g.0, out)
    }
    fn group_rank(g: AbiGroup, out: &mut i32) -> i32 {
        (B::vtable().group_rank)(g.0, out)
    }
    fn group_incl(g: AbiGroup, ranks: &[i32], out: &mut AbiGroup) -> i32 {
        (B::vtable().group_incl)(g.0, ranks, &mut out.0)
    }
    fn group_translate_ranks(a: AbiGroup, ranks: &[i32], b: AbiGroup, out: &mut [i32]) -> i32 {
        (B::vtable().group_translate_ranks)(a.0, ranks, b.0, out)
    }
    fn group_free(g: &mut AbiGroup) -> i32 {
        (B::vtable().group_free)(&mut g.0)
    }
    fn comm_set_errhandler(c: AbiComm, e: AbiErrhandler) -> i32 {
        (B::vtable().comm_set_errhandler)(c.0, e.0)
    }
    fn comm_get_errhandler(c: AbiComm, out: &mut AbiErrhandler) -> i32 {
        (B::vtable().comm_get_errhandler)(c.0, &mut out.0)
    }
    fn comm_create_errhandler(f: ErrhFn<Self>, out: &mut AbiErrhandler) -> i32 {
        (B::vtable().comm_create_errhandler)(f, &mut out.0)
    }
    fn errhandler_free(e: &mut AbiErrhandler) -> i32 {
        (B::vtable().errhandler_free)(&mut e.0)
    }

    fn comm_revoke(c: AbiComm) -> i32 {
        (B::vtable().comm_revoke)(c.0)
    }
    fn comm_is_revoked(c: AbiComm, out: &mut bool) -> i32 {
        (B::vtable().comm_is_revoked)(c.0, out)
    }
    fn comm_shrink(c: AbiComm, out: &mut AbiComm) -> i32 {
        (B::vtable().comm_shrink)(c.0, &mut out.0)
    }
    fn comm_agree(c: AbiComm, flag: &mut i32) -> i32 {
        (B::vtable().comm_agree)(c.0, flag)
    }
    fn comm_ack_failed(c: AbiComm, num_to_ack: i32, num_acked: &mut i32) -> i32 {
        (B::vtable().comm_ack_failed)(c.0, num_to_ack, num_acked)
    }

    fn send(buf: *const u8, count: i32, dt: AbiDatatype, dest: i32, tag: i32, c: AbiComm) -> i32 {
        (B::vtable().send)(buf, count, dt.0, dest, tag, c.0)
    }
    fn ssend(buf: *const u8, count: i32, dt: AbiDatatype, dest: i32, tag: i32, c: AbiComm) -> i32 {
        (B::vtable().ssend)(buf, count, dt.0, dest, tag, c.0)
    }
    fn recv(
        buf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        src: i32,
        tag: i32,
        c: AbiComm,
        status: &mut AbiStatus,
    ) -> i32 {
        (B::vtable().recv)(buf, count, dt.0, src, tag, c.0, status as *mut AbiStatus)
    }
    fn isend(
        buf: *const u8,
        count: i32,
        dt: AbiDatatype,
        dest: i32,
        tag: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().isend)(buf, count, dt.0, dest, tag, c.0, &mut req.0)
    }
    fn issend(
        buf: *const u8,
        count: i32,
        dt: AbiDatatype,
        dest: i32,
        tag: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().issend)(buf, count, dt.0, dest, tag, c.0, &mut req.0)
    }
    fn irecv(
        buf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        src: i32,
        tag: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().irecv)(buf, count, dt.0, src, tag, c.0, &mut req.0)
    }

    fn send_init(
        buf: *const u8,
        count: i32,
        dt: AbiDatatype,
        dest: i32,
        tag: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().send_init)(buf, count, dt.0, dest, tag, c.0, &mut req.0)
    }
    fn ssend_init(
        buf: *const u8,
        count: i32,
        dt: AbiDatatype,
        dest: i32,
        tag: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().ssend_init)(buf, count, dt.0, dest, tag, c.0, &mut req.0)
    }
    fn recv_init(
        buf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        src: i32,
        tag: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().recv_init)(buf, count, dt.0, src, tag, c.0, &mut req.0)
    }
    fn start(req: &mut AbiRequest) -> i32 {
        (B::vtable().start)(&mut req.0)
    }
    fn startall(reqs: &mut [AbiRequest]) -> i32 {
        let mut words: Vec<usize> = reqs.iter().map(|r| r.0).collect();
        let rc = (B::vtable().startall)(&mut words);
        if rc == 0 {
            for (i, w) in words.iter().enumerate() {
                reqs[i] = AbiRequest(*w);
            }
        }
        rc
    }

    fn wait(req: &mut AbiRequest, status: &mut AbiStatus) -> i32 {
        let key = req.0;
        let rc = (B::vtable().wait)(&mut req.0, status as *mut AbiStatus);
        if rc == 0 && req.is_null() {
            state::reqmap_remove(key);
        }
        rc
    }

    fn test(req: &mut AbiRequest, flag: &mut bool, status: &mut AbiStatus) -> i32 {
        let key = req.0;
        let rc = (B::vtable().test)(&mut req.0, flag, status as *mut AbiStatus);
        if rc == 0 && *flag {
            state::reqmap_remove(key);
        }
        rc
    }

    fn waitall(reqs: &mut [AbiRequest], statuses: &mut [AbiStatus]) -> i32 {
        let keys: Vec<usize> = reqs.iter().map(|r| r.0).collect();
        let mut words: Vec<usize> = keys.clone();
        let rc = (B::vtable().waitall)(&mut words, statuses.as_mut_ptr());
        if rc == 0 {
            for (i, w) in words.iter().enumerate() {
                reqs[i] = AbiRequest(*w);
                state::reqmap_remove(keys[i]);
            }
        }
        rc
    }

    fn testall(reqs: &mut [AbiRequest], flag: &mut bool, statuses: &mut [AbiStatus]) -> i32 {
        // §6.2 worst case: every Testall looks up every request in the
        // map, whether or not it has state.
        let keys: Vec<usize> = reqs.iter().map(|r| r.0).collect();
        for k in &keys {
            let _ = state::reqmap_contains(*k);
        }
        let mut words: Vec<usize> = keys.clone();
        let rc = (B::vtable().testall)(&mut words, flag, statuses.as_mut_ptr());
        if rc == 0 && *flag {
            for (i, w) in words.iter().enumerate() {
                reqs[i] = AbiRequest(*w);
                state::reqmap_remove(keys[i]);
            }
        }
        rc
    }

    fn waitany(reqs: &mut [AbiRequest], index: &mut i32, status: &mut AbiStatus) -> i32 {
        let keys: Vec<usize> = reqs.iter().map(|r| r.0).collect();
        let mut words: Vec<usize> = keys.clone();
        let rc = (B::vtable().waitany)(&mut words, index, status as *mut AbiStatus);
        if rc == 0 && *index >= 0 {
            let i = *index as usize;
            reqs[i] = AbiRequest(words[i]);
            state::reqmap_remove(keys[i]);
        }
        rc
    }

    fn testany(
        reqs: &mut [AbiRequest],
        index: &mut i32,
        flag: &mut bool,
        status: &mut AbiStatus,
    ) -> i32 {
        let keys: Vec<usize> = reqs.iter().map(|r| r.0).collect();
        let mut words: Vec<usize> = keys.clone();
        let rc = (B::vtable().testany)(&mut words, index, flag, status as *mut AbiStatus);
        if rc == 0 && *flag && *index >= 0 {
            let i = *index as usize;
            reqs[i] = AbiRequest(words[i]);
            state::reqmap_remove(keys[i]);
        }
        rc
    }

    fn waitsome(
        reqs: &mut [AbiRequest],
        outcount: &mut i32,
        indices: &mut [i32],
        statuses: &mut [AbiStatus],
    ) -> i32 {
        let keys: Vec<usize> = reqs.iter().map(|r| r.0).collect();
        let mut words: Vec<usize> = keys.clone();
        let rc = (B::vtable().waitsome)(&mut words, outcount, indices, statuses.as_mut_ptr());
        if rc == 0 && *outcount >= 0 {
            for j in 0..*outcount as usize {
                let i = indices[j] as usize;
                reqs[i] = AbiRequest(words[i]);
                state::reqmap_remove(keys[i]);
            }
        }
        rc
    }

    fn testsome(
        reqs: &mut [AbiRequest],
        outcount: &mut i32,
        indices: &mut [i32],
        statuses: &mut [AbiStatus],
    ) -> i32 {
        let keys: Vec<usize> = reqs.iter().map(|r| r.0).collect();
        let mut words: Vec<usize> = keys.clone();
        let rc = (B::vtable().testsome)(&mut words, outcount, indices, statuses.as_mut_ptr());
        if rc == 0 && *outcount >= 0 {
            for j in 0..*outcount as usize {
                let i = indices[j] as usize;
                reqs[i] = AbiRequest(words[i]);
                state::reqmap_remove(keys[i]);
            }
        }
        rc
    }

    fn probe(src: i32, tag: i32, c: AbiComm, status: &mut AbiStatus) -> i32 {
        (B::vtable().probe)(src, tag, c.0, status as *mut AbiStatus)
    }
    fn iprobe(src: i32, tag: i32, c: AbiComm, flag: &mut bool, status: &mut AbiStatus) -> i32 {
        (B::vtable().iprobe)(src, tag, c.0, flag, status as *mut AbiStatus)
    }
    fn cancel(req: &mut AbiRequest) -> i32 {
        (B::vtable().cancel)(&mut req.0)
    }
    fn request_free(req: &mut AbiRequest) -> i32 {
        let key = req.0;
        let rc = (B::vtable().request_free)(&mut req.0);
        if rc == 0 {
            state::reqmap_remove(key);
        }
        rc
    }

    #[allow(clippy::too_many_arguments)]
    fn sendrecv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        dest: i32,
        sendtag: i32,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        src: i32,
        recvtag: i32,
        c: AbiComm,
        status: &mut AbiStatus,
    ) -> i32 {
        (B::vtable().sendrecv)(sendbuf, sendcount, sendtype.0, dest, sendtag, recvbuf, recvcount,
            recvtype.0, src, recvtag, c.0, status as *mut AbiStatus)
    }

    fn type_size(dt: AbiDatatype, out: &mut i32) -> i32 {
        (B::vtable().type_size)(dt.0, out)
    }
    fn type_get_extent(dt: AbiDatatype, lb: &mut isize, extent: &mut isize) -> i32 {
        (B::vtable().type_get_extent)(dt.0, lb, extent)
    }
    fn type_contiguous(count: i32, child: AbiDatatype, out: &mut AbiDatatype) -> i32 {
        (B::vtable().type_contiguous)(count, child.0, &mut out.0)
    }
    fn type_vector(
        count: i32,
        blocklen: i32,
        stride: i32,
        child: AbiDatatype,
        out: &mut AbiDatatype,
    ) -> i32 {
        (B::vtable().type_vector)(count, blocklen, stride, child.0, &mut out.0)
    }
    fn type_create_struct(blocks: &[(i32, isize, AbiDatatype)], out: &mut AbiDatatype) -> i32 {
        let conv: Vec<(i32, isize, usize)> =
            blocks.iter().map(|&(l, d, t)| (l, d, t.0)).collect();
        (B::vtable().type_create_struct)(&conv, &mut out.0)
    }
    fn type_commit(dt: &mut AbiDatatype) -> i32 {
        (B::vtable().type_commit)(&mut dt.0)
    }
    fn type_free(dt: &mut AbiDatatype) -> i32 {
        (B::vtable().type_free)(&mut dt.0)
    }
    fn type_dup(dt: AbiDatatype, out: &mut AbiDatatype) -> i32 {
        (B::vtable().type_dup)(dt.0, &mut out.0)
    }

    fn op_create(f: UserOpFn<Self>, commute: bool, out: &mut AbiOp) -> i32 {
        (B::vtable().op_create)(f, commute, &mut out.0)
    }
    fn op_free(op: &mut AbiOp) -> i32 {
        (B::vtable().op_free)(&mut op.0)
    }

    fn barrier(c: AbiComm) -> i32 {
        (B::vtable().barrier)(c.0)
    }
    fn bcast(buf: *mut u8, count: i32, dt: AbiDatatype, root: i32, c: AbiComm) -> i32 {
        (B::vtable().bcast)(buf, count, dt.0, root, c.0)
    }
    fn reduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        op: AbiOp,
        root: i32,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().reduce)(sendbuf, recvbuf, count, dt.0, op.0, root, c.0)
    }
    fn allreduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        op: AbiOp,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().allreduce)(sendbuf, recvbuf, count, dt.0, op.0, c.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn gather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        root: i32,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().gather)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount, recvtype.0,
            root, c.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn scatter(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        root: i32,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().scatter)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount, recvtype.0,
            root, c.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn allgather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().allgather)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount, recvtype.0,
            c.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn alltoall(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().alltoall)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount, recvtype.0,
            c.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn alltoallw(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtypes: &[AbiDatatype],
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtypes: &[AbiDatatype],
        c: AbiComm,
    ) -> i32 {
        let st: Vec<usize> = sendtypes.iter().map(|t| t.0).collect();
        let rt: Vec<usize> = recvtypes.iter().map(|t| t.0).collect();
        (B::vtable().alltoallw)(sendbuf, sendcounts, sdispls, &st, recvbuf, recvcounts, rdispls,
            &rt, c.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn ialltoallw(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtypes: &[AbiDatatype],
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtypes: &[AbiDatatype],
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        let st: Vec<usize> = sendtypes.iter().map(|t| t.0).collect();
        let rt: Vec<usize> = recvtypes.iter().map(|t| t.0).collect();
        (B::vtable().ialltoallw)(sendbuf, sendcounts, sdispls, &st, recvbuf, recvcounts, rdispls,
            &rt, c.0, &mut req.0)
    }
    fn scan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        op: AbiOp,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().scan)(sendbuf, recvbuf, count, dt.0, op.0, c.0)
    }
    fn exscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        op: AbiOp,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().exscan)(sendbuf, recvbuf, count, dt.0, op.0, c.0)
    }
    fn reduce_scatter_block(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        recvcount: i32,
        dt: AbiDatatype,
        op: AbiOp,
        c: AbiComm,
    ) -> i32 {
        (B::vtable().reduce_scatter_block)(sendbuf, recvbuf, recvcount, dt.0, op.0, c.0)
    }

    fn ibarrier(c: AbiComm, req: &mut AbiRequest) -> i32 {
        (B::vtable().ibarrier)(c.0, &mut req.0)
    }
    fn ibcast(
        buf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        root: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().ibcast)(buf, count, dt.0, root, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn ireduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        op: AbiOp,
        root: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().ireduce)(sendbuf, recvbuf, count, dt.0, op.0, root, c.0, &mut req.0)
    }
    fn iallreduce(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        op: AbiOp,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().iallreduce)(sendbuf, recvbuf, count, dt.0, op.0, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn igather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        root: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().igather)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount, recvtype.0,
            root, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn igatherv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        displs: &[i32],
        recvtype: AbiDatatype,
        root: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().igatherv)(sendbuf, sendcount, sendtype.0, recvbuf, recvcounts, displs,
            recvtype.0, root, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn iscatter(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        root: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().iscatter)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount, recvtype.0,
            root, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn iscatterv(
        sendbuf: *const u8,
        sendcounts: &[i32],
        displs: &[i32],
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        root: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().iscatterv)(sendbuf, sendcounts, displs, sendtype.0, recvbuf, recvcount,
            recvtype.0, root, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn iallgather(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().iallgather)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount, recvtype.0,
            c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn iallgatherv(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        displs: &[i32],
        recvtype: AbiDatatype,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().iallgatherv)(sendbuf, sendcount, sendtype.0, recvbuf, recvcounts, displs,
            recvtype.0, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn ialltoall(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().ialltoall)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount, recvtype.0,
            c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn ialltoallv(
        sendbuf: *const u8,
        sendcounts: &[i32],
        sdispls: &[i32],
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcounts: &[i32],
        rdispls: &[i32],
        recvtype: AbiDatatype,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().ialltoallv)(sendbuf, sendcounts, sdispls, sendtype.0, recvbuf, recvcounts,
            rdispls, recvtype.0, c.0, &mut req.0)
    }
    fn iscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        op: AbiOp,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().iscan)(sendbuf, recvbuf, count, dt.0, op.0, c.0, &mut req.0)
    }
    fn iexscan(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        op: AbiOp,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().iexscan)(sendbuf, recvbuf, count, dt.0, op.0, c.0, &mut req.0)
    }
    fn ireduce_scatter_block(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        recvcount: i32,
        dt: AbiDatatype,
        op: AbiOp,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().ireduce_scatter_block)(sendbuf, recvbuf, recvcount, dt.0, op.0, c.0,
            &mut req.0)
    }

    fn barrier_init(c: AbiComm, req: &mut AbiRequest) -> i32 {
        (B::vtable().barrier_init)(c.0, &mut req.0)
    }
    fn bcast_init(
        buf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        root: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().bcast_init)(buf, count, dt.0, root, c.0, &mut req.0)
    }
    fn allreduce_init(
        sendbuf: *const u8,
        recvbuf: *mut u8,
        count: i32,
        dt: AbiDatatype,
        op: AbiOp,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().allreduce_init)(sendbuf, recvbuf, count, dt.0, op.0, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn gather_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        root: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().gather_init)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount,
            recvtype.0, root, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn scatter_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        root: i32,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().scatter_init)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount,
            recvtype.0, root, c.0, &mut req.0)
    }
    #[allow(clippy::too_many_arguments)]
    fn alltoall_init(
        sendbuf: *const u8,
        sendcount: i32,
        sendtype: AbiDatatype,
        recvbuf: *mut u8,
        recvcount: i32,
        recvtype: AbiDatatype,
        c: AbiComm,
        req: &mut AbiRequest,
    ) -> i32 {
        (B::vtable().alltoall_init)(sendbuf, sendcount, sendtype.0, recvbuf, recvcount,
            recvtype.0, c.0, &mut req.0)
    }

    fn win_create(
        base: *mut u8,
        size: crate::abi::types::Aint,
        disp_unit: i32,
        info: AbiInfo,
        c: AbiComm,
        win: &mut AbiWin,
    ) -> i32 {
        (B::vtable().win_create)(base, size, disp_unit, info.0, c.0, &mut win.0)
    }

    fn win_allocate(
        size: crate::abi::types::Aint,
        disp_unit: i32,
        info: AbiInfo,
        c: AbiComm,
        baseptr: &mut *mut u8,
        win: &mut AbiWin,
    ) -> i32 {
        (B::vtable().win_allocate)(size, disp_unit, info.0, c.0, baseptr, &mut win.0)
    }

    fn win_free(win: &mut AbiWin) -> i32 {
        (B::vtable().win_free)(&mut win.0)
    }

    fn win_fence(assert: i32, win: AbiWin) -> i32 {
        (B::vtable().win_fence)(assert, win.0)
    }

    fn win_lock(lock_type: i32, rank: i32, assert: i32, win: AbiWin) -> i32 {
        (B::vtable().win_lock)(lock_type, rank, assert, win.0)
    }

    fn win_unlock(rank: i32, win: AbiWin) -> i32 {
        (B::vtable().win_unlock)(rank, win.0)
    }

    fn win_flush(rank: i32, win: AbiWin) -> i32 {
        (B::vtable().win_flush)(rank, win.0)
    }

    #[allow(clippy::too_many_arguments)]
    fn put(
        origin: *const u8,
        origin_count: i32,
        origin_dt: AbiDatatype,
        target_rank: i32,
        target_disp: crate::abi::types::Aint,
        target_count: i32,
        target_dt: AbiDatatype,
        win: AbiWin,
    ) -> i32 {
        (B::vtable().put)(origin, origin_count, origin_dt.0, target_rank, target_disp,
            target_count, target_dt.0, win.0)
    }

    #[allow(clippy::too_many_arguments)]
    fn get(
        origin: *mut u8,
        origin_count: i32,
        origin_dt: AbiDatatype,
        target_rank: i32,
        target_disp: crate::abi::types::Aint,
        target_count: i32,
        target_dt: AbiDatatype,
        win: AbiWin,
    ) -> i32 {
        (B::vtable().get)(origin, origin_count, origin_dt.0, target_rank, target_disp,
            target_count, target_dt.0, win.0)
    }

    #[allow(clippy::too_many_arguments)]
    fn accumulate(
        origin: *const u8,
        origin_count: i32,
        origin_dt: AbiDatatype,
        target_rank: i32,
        target_disp: crate::abi::types::Aint,
        target_count: i32,
        target_dt: AbiDatatype,
        op: AbiOp,
        win: AbiWin,
    ) -> i32 {
        (B::vtable().accumulate)(origin, origin_count, origin_dt.0, target_rank, target_disp,
            target_count, target_dt.0, op.0, win.0)
    }

    fn comm_create_keyval(
        copy: Option<AttrCopyFn<Self>>,
        delete: Option<AttrDeleteFn<Self>>,
        extra_state: usize,
        out: &mut i32,
    ) -> i32 {
        (B::vtable().comm_create_keyval)(copy, delete, extra_state, out)
    }
    fn comm_free_keyval(keyval: &mut i32) -> i32 {
        (B::vtable().comm_free_keyval)(keyval)
    }
    fn comm_set_attr(c: AbiComm, keyval: i32, value: usize) -> i32 {
        (B::vtable().comm_set_attr)(c.0, keyval, value)
    }
    fn comm_get_attr(c: AbiComm, keyval: i32, value: &mut usize, flag: &mut bool) -> i32 {
        (B::vtable().comm_get_attr)(c.0, keyval, value, flag)
    }
    fn comm_delete_attr(c: AbiComm, keyval: i32) -> i32 {
        (B::vtable().comm_delete_attr)(c.0, keyval)
    }

    fn info_create(out: &mut AbiInfo) -> i32 {
        (B::vtable().info_create)(&mut out.0)
    }
    fn info_set(i: AbiInfo, key: &str, value: &str) -> i32 {
        (B::vtable().info_set)(i.0, key, value)
    }
    fn info_get(i: AbiInfo, key: &str, out: &mut String, flag: &mut bool) -> i32 {
        (B::vtable().info_get)(i.0, key, out, flag)
    }
    fn info_free(i: &mut AbiInfo) -> i32 {
        (B::vtable().info_free)(&mut i.0)
    }

    // --- Tools interface (MPI_T): integer-only, straight through ---

    fn t_init_thread(required: i32, provided: &mut i32) -> i32 {
        (B::vtable().t_init_thread)(required, provided)
    }
    fn t_finalize() -> i32 {
        (B::vtable().t_finalize)()
    }
    fn t_cvar_get_num(num: &mut i32) -> i32 {
        (B::vtable().t_cvar_get_num)(num)
    }
    fn t_cvar_get_info(
        index: i32,
        name: &mut String,
        verbosity: &mut i32,
        bind: &mut i32,
        scope: &mut i32,
    ) -> i32 {
        (B::vtable().t_cvar_get_info)(index, name, verbosity, bind, scope)
    }
    fn t_cvar_handle_alloc(index: i32, handle: &mut i32) -> i32 {
        (B::vtable().t_cvar_handle_alloc)(index, handle)
    }
    fn t_cvar_read(handle: i32, value: &mut i64) -> i32 {
        (B::vtable().t_cvar_read)(handle, value)
    }
    fn t_cvar_write(handle: i32, value: i64) -> i32 {
        (B::vtable().t_cvar_write)(handle, value)
    }
    fn t_pvar_get_num(num: &mut i32) -> i32 {
        (B::vtable().t_pvar_get_num)(num)
    }
    fn t_pvar_get_info(
        index: i32,
        name: &mut String,
        verbosity: &mut i32,
        class: &mut i32,
        bind: &mut i32,
    ) -> i32 {
        (B::vtable().t_pvar_get_info)(index, name, verbosity, class, bind)
    }
    fn t_pvar_session_create(session: &mut i32) -> i32 {
        (B::vtable().t_pvar_session_create)(session)
    }
    fn t_pvar_handle_alloc(session: i32, index: i32, handle: &mut i32) -> i32 {
        (B::vtable().t_pvar_handle_alloc)(session, index, handle)
    }
    fn t_pvar_start(session: i32, handle: i32) -> i32 {
        (B::vtable().t_pvar_start)(session, handle)
    }
    fn t_pvar_read(session: i32, handle: i32, value: &mut i64) -> i32 {
        (B::vtable().t_pvar_read)(session, handle, value)
    }
    fn t_pvar_reset(session: i32, handle: i32) -> i32 {
        (B::vtable().t_pvar_reset)(session, handle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symbol_tables_are_complete_and_distinct() {
        let m = symbols(Backend::Mpich);
        let o = symbols(Backend::Ompi);
        assert_eq!(m.len(), o.len());
        assert!(m.len() >= 70, "expected a full WRAP surface, got {}", m.len());
        // Same names, different monomorphized addresses.
        let f_m: fn(usize, &mut i32) -> i32 = unsafe { m.dlsym("WRAP_comm_size") };
        let f_o: fn(usize, &mut i32) -> i32 = unsafe { o.dlsym("WRAP_comm_size") };
        assert_ne!(f_m as usize, f_o as usize);
    }

    #[test]
    #[should_panic(expected = "missing symbol")]
    fn dlsym_missing_symbol_panics() {
        let m = symbols(Backend::Mpich);
        let _: fn() -> i32 = unsafe { m.dlsym("WRAP_No_such_function") };
    }

    #[test]
    fn vtables_resolve() {
        let v = OverMpich::vtable();
        // Calling type_size through the vtable outside a job still works:
        // it's pure representation decoding (MPICH fast path).
        let mut out = 0;
        let rc = (v.type_size)(crate::abi::datatypes::MPI_INT, &mut out);
        assert_eq!(rc, 0);
        assert_eq!(out, 4);
        let v = OverOmpi::vtable();
        let mut out = 0;
        let rc = (v.type_size)(crate::abi::datatypes::MPI_DOUBLE, &mut out);
        assert_eq!(rc, 0);
        assert_eq!(out, 8);
    }
}
